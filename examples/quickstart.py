"""Quickstart: the paper's pipeline end to end, in miniature.

1. Build a small simulated DRAM module fleet (the measurement rig).
2. Run the characterization campaign and fit VAMPIRE.
3. Score traces through the ONE estimator entry point,
   ``model.estimate(traces, vendors, mode=...)`` — every leaf of the
   returned report is a (traces x vendors) matrix evaluated in a single
   batched dispatch, and the same call shape works for the datasheet
   baselines (Micron calculator, DRAMPower).
4. Validate against held-out measurements vs the baselines.
5. Save/load the fitted model (versioned .npz + manifest, schema v2).
6. Estimate the energy of an application trace and of a framework tensor.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import device_sim, encodings, params as P, traces
from repro.core.baselines_power import DRAMPowerModel
from repro.core.validate import run_validation
from repro.core.vampire import Vampire


def main():
    print("== 1. simulated fleet (9 modules, 3 vendors) ==")
    fleet = device_sim.make_fleet(
        [P.ModuleSpec(v, i, 2015) for v in range(3) for i in range(3)])

    print("== 2. characterization campaign + VAMPIRE fit ==")
    model = Vampire.fit(fleet, probe_modules=2, probe_reps=64, n_rows=8)
    for v, vc in model.by_vendor.items():
        print(f"  vendor {'ABC'[v]}: col-interleaved read fit "
          f"I = {vc.datadep[1,0,0]:.1f} + {vc.datadep[1,0,1]:.3f}*ones "
          f"+ {vc.datadep[1,0,2]:.4f}*toggles  (paper Table 2: "
          f"{P.TABLE5[v][1][0][0]:.1f}, {P.TABLE5[v][1][0][1]:.3f}, "
          f"{P.TABLE5[v][1][0][2]:.4f})")

    print("== 3. the unified estimate() entry point ==")
    from repro.core import idd_loops
    sweeps = [idd_loops.validation_sweep(n) for n in (8, 64, 512)]
    rep = model.estimate(sweeps)                    # (3 traces, 3 vendors)
    print(f"  mean currents (mA), traces x vendors:\n"
          f"{np.asarray(rep.avg_current_ma).round(1)}")
    lo, mid, hi = model.estimate(sweeps, mode="range")
    print(f"  process-variation band, trace 1 vendor A: "
          f"[{float(lo.avg_current_ma[1,0]):.1f}, "
          f"{float(hi.avg_current_ma[1,0]):.1f}] mA")
    nodata = model.estimate(sweeps, mode="distribution",
                            ones_frac=0.5, toggle_frac=0.25)
    print(f"  no-data-trace mode (ones=0.5, toggle=0.25): "
          f"{float(nodata.avg_current_ma[1,0]):.1f} mA")
    # the baselines answer through the *same* protocol + batched path
    dp = DRAMPowerModel.from_vampire(model)
    print(f"  DRAMPower, same call: "
          f"{np.asarray(dp.estimate(sweeps).avg_current_ma).round(1)[1]}")

    print("== 3b. structural-variation surfaces (paper Figs 19-22) ==")
    # mode='surface' decomposes the same energy per (bank, row-band) cell:
    # leaves are (traces, vendors, banks, row_bands); summing the cell
    # axes recovers mode='mean' exactly.
    from repro.core import validate
    surf = model.estimate([validate.surface_sweep_trace()], mode="surface")
    per_bank = np.asarray(surf.energy_pj)[0].sum(axis=2)   # (vendors, banks)
    print("  per-bank energy (uJ), vendors x banks:")
    for v in range(per_bank.shape[0]):
        cells = " ".join(f"{e/1e6:6.2f}" for e in per_bank[v])
        print(f"    vendor {'ABC'[v]}: {cells}")
    hot = np.unravel_index(np.asarray(surf.energy_pj)[0, 2].argmax(),
                           surf.energy_pj.shape[2:])
    print(f"  vendor C's hottest structural cell: bank {hot[0]}, "
          f"row band {hot[1]}")

    print("== 3c. the impl registry: HOW the matrix is evaluated ==")
    # impl= picks a registered evaluation path (model_api.resolve_impl):
    # 'vectorized' (jnp/XLA, default), 'pallas' (fused kernels — compiled
    # on TPU, interpret-mode elsewhere), 'reference' (per-command oracle).
    # Every estimator kind supports every impl for every mode.
    from repro.core import model_api
    for impl in model_api.registered_impls():
        r = model.estimate(sweeps, impl=impl)
        print(f"  impl={impl:10s} ({model_api.impl_execution_mode(impl)}): "
              f"trace 1 vendor A {float(r.avg_current_ma[1,0]):.2f} mA")
    # new impls register like estimator kinds:
    #   model_api.register_impl(model_api.EstimateImpl(
    #       "my-impl", "description", modes=("mean",)))

    print("== 3d. low-power states: power-down & self-refresh (Fig 14) ==")
    # Traces speak the full background-state lattice — PDE/PDE_SLOW/SRE
    # entries, NOP dwell, PDX/SRX exits — and the integrator bills each
    # dwell cycle at the fitted per-state current (i_pd / i_pd_slow /
    # i_actpd / i_sr), in every impl. The policy study picks the deepest
    # state each idle gap can absorb:
    from repro.core import applications
    pd = applications.powerdown_study(model, traces.SPEC_APPS[21],  # povray
                                      0, n_requests=300)
    print(f"  break-even idle: {pd['breakeven_cycles']:.0f} cycles; "
          f"breakeven-policy saving {pd['breakeven_saving'] * 100:.1f}% "
          f"(windows entered: {pd['breakeven_modes']})")
    # the paper's Fig 14: measured currents sit well below the worst-case
    # datasheet values, deepest for the low-power states
    print("  measured/datasheet IDD ratios (per vendor):")
    for line in validate.render_fig14_table(
            validate.measured_over_datasheet(model)).splitlines():
        print(f"    {line}")

    print("== 3e. the protocol linter: every trace is JEDEC-checked ==")
    # Every generator self-checks through repro.analysis.trace_lint (21
    # declarative JEDEC rules — tRCD/tRP/tRAS, tFAW, bank & background
    # state, refresh cadence), and serving ingestion rejects illegal
    # traces with structured diagnostics:
    from repro.analysis import trace_lint
    from repro.core import dram
    legal = idd_loops.idd0(reps=4)
    print(f"  idd0 loop: {len(trace_lint.lint_trace(legal))} violations")
    rushed = dram.CommandTrace(legal.cmd, legal.bank, legal.row, legal.col,
                               legal.data,
                               legal.dt.at[0].set(2))  # ACT->PRE in 2 cyc
    try:
        trace_lint.check_generated(rushed, "quickstart")
    except trace_lint.TraceProtocolError as e:
        d = e.diagnostics[0]
        print(f"  corrupted copy rejected: rule={d.rule} "
              f"command #{d.cmd_index} bank {d.bank} "
              f"(short by {d.margin} cycles)")

    print("== 3f. estimation-as-a-service: the serving loop ==")
    # repro.serving turns the per-request loop into a continuously
    # batched service: ragged arrivals land in a bucketed TraceBatch
    # ring (re-padded in place, so the jit cache stays bounded), the
    # engine keeps the model device-resident (shard_map'd when a
    # multi-device mesh is passed), and admission routes every trace
    # through the 3e linter gate — illegal ones come back as structured
    # rejections, never silently priced.
    from repro.serving import EstimationService, ServiceConfig
    svc = EstimationService(model, ServiceConfig())
    arrivals = [idd_loops.validation_sweep(n) for n in (1, 4, 8, 16)]
    tickets, _ = svc.submit_many(arrivals)
    bad = svc.submit(rushed)                 # the corrupted trace from 3e
    print(f"  corrupted arrival rejected at admission: rules={bad.rules}")
    svc.close()                              # drain + refuse new traffic
    rows = [svc.result(t) for t in tickets]
    print(f"  {len(rows)} results; arrival 2, vendor A: "
          f"{float(rows[2].avg_current_ma[0]):.1f} mA")
    m = svc.metrics()
    print(f"  metrics: admitted={m.admitted} dispatches={m.dispatches} "
          f"fill={m.batch_fill:.2f} programs={m.engine_programs} "
          f"p50={m.latency_p50_ms:.0f}ms")

    print("== 3g. fleet scale: synthetic fleets + chunked surface maps ==")
    # device_sim.synth_fleet_params synthesizes a vendor-consistent fleet
    # of ANY size from counter-based RNG (seed-stable per module id: a
    # 10k-module fleet's first 1k modules ARE the 1k fleet), and the
    # chunked surface dispatch maps the whole module axis under bounded
    # memory — module_chunk modules in flight at a time, bitwise-equal to
    # the one-shot dispatch.  The stacked fleet params themselves are
    # memoized device-resident (fleet.fleet_stacked): repeat campaign /
    # surface calls never restack.  Kernel launch geometry (block size,
    # grid-major order) comes from the committed autotune table
    # (repro.kernels.autotune; regenerate with
    #   python -m repro.kernels.autotune).
    from repro.core import fleet as fleet_mod
    from repro.core.dram import batch_traces
    vend, big = device_sim.synth_fleet_params(5000)
    trace, weight = batch_traces(
        [(idd_loops.validation_sweep(8, reps=12), 2)])
    surf_fleet = fleet_mod.fleet_surface_energy(big, trace, weight,
                                                module_chunk=512)
    e = np.asarray(surf_fleet.energy_pj)[0].sum(axis=(1, 2))  # per module
    print(f"  5000-module surface map, chunk=512: per-module energy "
          f"p5={np.percentile(e, 5)/1e6:.2f} "
          f"p95={np.percentile(e, 95)/1e6:.2f} uJ "
          f"(vendor medians: "
          + " ".join(f"{'ABC'[v]}={np.median(e[vend == v])/1e6:.2f}"
                     for v in range(3)) + ")")

    print("== 3h. online recalibration: drift -> detect -> refit ==")
    # Deployed modules drift (temperature cycles, aging) away from their
    # day-one characterization.  fit() is a registry like impl=:
    # fitter='campaign' is the one-shot fit from step 2, bit-for-bit;
    # fitter='streaming' returns a StreamingFitter that folds noisy
    # telemetry slices into decayed per-probe-cell statistics, scores
    # drift from standardized residuals, and refits treedef-stably — so
    # the serving engine hot-swaps the refreshed parameters with ZERO
    # recompiles (observe_telemetry does all three in one call).
    from repro.core import model_api as _mapi, recalibrate
    cfg = recalibrate.RecalConfig(probe_modules=2, probe_reps=64, n_rows=8,
                                  slice_size=10_000)
    fitter = _mapi.fit("vampire", fleet, fitter="streaming",
                       init_model=model, config=cfg)
    svc2 = EstimationService(model, ServiceConfig(), fitter=fitter)
    drift = device_sim.DriftProcess(step_tick=3, step_frac=0.15)
    src = recalibrate.TelemetrySource(fleet, cfg, drift=drift)
    for tick in range(1, 5):
        cur, idx = src.measure(tick)
        rep_t = svc2.observe_telemetry(cur, idx, tick)
        print(f"  tick {tick}: drift score {rep_t.score:5.1f} "
              f"{'-> REFIT + hot-swap' if rep_t.triggered else '(quiet)'}")
    m2 = svc2.metrics()
    print(f"  recalibrations={m2.recalibrations} "
          f"drift_peak={m2.drift_peak:.1f} "
          f"programs={svc2.engine.cache_size()} (unchanged by the swap)")

    print("== 4. validation vs baselines (paper Fig 24) ==")
    res = run_validation(model, fleet=fleet,
                         n_values=(0, 2, 8, 32, 128, 512, 764))
    print(res.summary())

    print("== 5. versioned serialization (schema v2) ==")
    import tempfile, os
    path = os.path.join(tempfile.mkdtemp(), "vampire.npz")
    model.save(path)
    loaded = Vampire.load(path)
    print(f"  round-trip OK: "
          f"{np.allclose(np.asarray(loaded.estimate(sweeps).energy_pj), np.asarray(rep.energy_pj))}")

    print("== 6. energy of an app trace, per encoding (one dispatch) ==")
    tr = traces.app_trace(traces.SPEC_APPS[7], n_requests=500)  # libquantum
    study = encodings.encoding_energy_study({"libquantum": tr}, model)
    for enc in encodings.ENCODINGS:
        print(f"  {enc:10s}: {study['libquantum'][enc]/1e6:.2f} uJ")

    print("== 7. TPU/HBM adaptation: tensor read energy ==")
    import jax
    from repro.core import hbm
    m = hbm.HbmEnergyModel.from_vampire(model.params(0))
    x = jax.random.normal(jax.random.key(0), (1024, 1024), jax.numpy.bfloat16)
    ones, togg = hbm.tensor_stats(x)
    pj = m.read_energy_pj(x.size * 2, ones, togg)
    print(f"  bf16 activation tensor: ones={ones:.3f} toggle={togg:.3f} "
          f"-> {pj/1e6:.2f} uJ per full read of {x.size*2/1e6:.1f} MB")


if __name__ == "__main__":
    main()
