"""Quickstart: the paper's pipeline end to end, in miniature.

1. Build a small simulated DRAM module fleet (the measurement rig).
2. Run the characterization campaign and fit VAMPIRE.
3. Validate against held-out measurements vs DRAMPower / Micron.
4. Estimate the energy of an application trace and of a framework tensor.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import device_sim, encodings, params as P, traces
from repro.core.validate import run_validation
from repro.core.vampire import Vampire


def main():
    print("== 1. simulated fleet (9 modules, 3 vendors) ==")
    fleet = device_sim.make_fleet(
        [P.ModuleSpec(v, i, 2015) for v in range(3) for i in range(3)])

    print("== 2. characterization campaign + VAMPIRE fit ==")
    model = Vampire.fit(fleet, probe_modules=2, probe_reps=64, n_rows=8)
    for v, vc in model.by_vendor.items():
        print(f"  vendor {'ABC'[v]}: col-interleaved read fit "
          f"I = {vc.datadep[1,0,0]:.1f} + {vc.datadep[1,0,1]:.3f}*ones "
          f"+ {vc.datadep[1,0,2]:.4f}*toggles  (paper Table 2: "
          f"{P.TABLE5[v][1][0][0]:.1f}, {P.TABLE5[v][1][0][1]:.3f}, "
          f"{P.TABLE5[v][1][0][2]:.4f})")

    print("== 3. validation vs baselines (paper Fig 24) ==")
    res = run_validation(model, fleet=fleet,
                         n_values=(0, 2, 8, 32, 128, 512, 764))
    print(res.summary())

    print("== 4. energy of an app trace, per encoding (one dispatch) ==")
    tr = traces.app_trace(traces.SPEC_APPS[7], n_requests=500)  # libquantum
    study = encodings.encoding_energy_study({"libquantum": tr}, model)
    for enc in encodings.ENCODINGS:
        print(f"  {enc:10s}: {study['libquantum'][enc]/1e6:.2f} uJ")

    print("== 5. TPU/HBM adaptation: tensor read energy ==")
    import jax
    from repro.core import hbm
    m = hbm.HbmEnergyModel.from_vampire(model.params(0))
    x = jax.random.normal(jax.random.key(0), (1024, 1024), jax.numpy.bfloat16)
    ones, togg = hbm.tensor_stats(x)
    pj = m.read_energy_pj(x.size * 2, ones, togg)
    print(f"  bf16 activation tensor: ones={ones:.3f} toggle={togg:.3f} "
          f"-> {pj/1e6:.2f} uJ per full read of {x.size*2/1e6:.1f} MB")


if __name__ == "__main__":
    main()
