"""Fleet-wide structural-variation surfaces (paper Figs 19-22).

Renders the per-(bank, row-band) energy heatmaps three ways, all through
the ONE batched ``mode='surface'`` dispatch — no per-module Python sweeps:

1. The fitted VAMPIRE model's surfaces per vendor (what the model predicts
   a module of each vendor does structurally).
2. The GROUND-TRUTH surfaces of every module in the fleet at once
   (``fleet.fleet_surface_energy``: the same engine with stacked
   per-module true params on the vendor axis) — showing the surface is
   structural: modules of one vendor share it.
3. A datasheet baseline's surface, which is structurally flat — the
   paper's point that IDD-only models cannot see Figs 19-22 at all.

    PYTHONPATH=src python examples/structural_surfaces.py
"""
import numpy as np

from repro.core import device_sim, estimate_batch, fleet, validate
from repro.core import params as P
from repro.core.baselines_power import DRAMPowerModel
from repro.core.vampire import Vampire


def main():
    modules = device_sim.make_fleet(
        [P.ModuleSpec(v, i, 2015) for v in range(3) for i in range(3)])
    model = Vampire.fit(modules, probe_modules=2, probe_reps=64, n_rows=8)
    workload = validate.surface_sweep_trace()

    print("== 1. fitted VAMPIRE surfaces (energy share per cell) ==")
    maps = validate.structural_surface_maps(model, [workload])
    for v in range(maps.shape[0]):
        print(validate.render_surface_heatmap(
            maps[v], f"vendor {'ABC'[v]} (fitted)"))

    print("\n== 2. ground truth: the WHOLE fleet, one dispatch ==")
    tb = estimate_batch.TraceBatch.from_traces([workload])
    rep = fleet.fleet_surface_energy(modules, tb.trace, tb.weight)
    energy = np.asarray(rep.energy_pj)[0]           # (modules, 8, bands)
    # modules of one vendor share their surface: that is what makes the
    # variation structural (paper Section 6)
    for v in range(3):
        rows = [i for i, m in enumerate(modules) if m.spec.vendor == v]
        surfs = energy[rows] / energy[rows].sum(axis=(1, 2), keepdims=True)
        spread = float(np.ptp(surfs, axis=0).max())
        print(validate.render_surface_heatmap(
            surfs.mean(axis=0),
            f"vendor {'ABC'[v]} (true, {len(rows)} modules, "
            f"max module-to-module spread {spread:.4f})"))

    print("\n== 3. a datasheet baseline sees none of this ==")
    dp = DRAMPowerModel.from_vampire(model)
    flat = validate.structural_surface_maps(dp, [workload])
    rel = flat[2] / flat[2].mean()
    print(validate.render_surface_heatmap(flat[2], "vendor C (DRAMPower)"))
    print(f"DRAMPower cell spread: {np.ptp(rel):.4f} "
          f"(structurally flat; workload placement only)")


if __name__ == "__main__":
    main()
