"""Batched serving example: prefill + decode with KV caches on a small
model, reporting latency percentiles and throughput.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2.5-3b
"""
import argparse

from repro.launch.serve import ServeJob, run


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2.5-3b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--decode-tokens", type=int, default=48)
    args = p.parse_args()
    res = run(ServeJob(arch=args.arch, smoke=True, batch=args.batch,
                       prompt_len=args.prompt_len,
                       decode_tokens=args.decode_tokens))
    print(f"prefill: {res['prefill_s']:.2f}s")
    print(f"decode:  p50={res['decode_p50_ms']:.1f}ms "
          f"p99={res['decode_p99_ms']:.1f}ms  "
          f"{res['tokens_per_s']:.1f} tok/s")
    print("sample token ids:", res["tokens"][0][:16].tolist())


if __name__ == "__main__":
    main()
