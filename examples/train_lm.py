"""End-to-end training driver example: a ~100M-parameter qwen2.5-style model
for a few hundred steps on CPU, with checkpointing, an injected failure +
automatic recovery, straggler monitoring, and per-step HBM energy estimates
from the paper's power model.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs.qwen2_5_3b import CONFIG as QWEN3B
from repro.launch.train import TrainJob, run


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = p.parse_args()

    # ~100M params: scale qwen2.5 down but keep the architecture family
    cfg = dataclasses.replace(
        QWEN3B, name="qwen2.5-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv=2, d_head=64, d_ff=2048, vocab=32000, attention_block=128)

    job = TrainJob(arch=cfg.name, config=cfg, steps=args.steps,
                   batch=8, seq=256, ckpt_dir=args.ckpt, ckpt_every=25,
                   fail_at=(60,), power_every=50)
    res = run(job)
    print(f"ran {res['steps_run']} steps; loss {res['losses'][0]:.3f} -> "
          f"{res['final_loss']:.3f}; recoveries={res['recoveries']}")
    for s, e in res["energies"]:
        print(f"  step {s:4d}: est. HBM energy {e:.3f} J/step/device")


if __name__ == "__main__":
    main()
