"""The paper's Section 10 case study, extended to framework tensors:
evaluate the four data encodings on (a) the synthetic SPEC-like suite and
(b) real tensor corpora from a trained LM (weights / activations / token
streams), using the fitted VAMPIRE model.

    PYTHONPATH=src python examples/power_encoding_study.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encodings, traces
from repro.core.vampire import reference_vampire


def tensor_trace(arr, n_requests=400, read_frac=0.7):
    """Wrap a tensor's bytes into a DRAM command trace."""
    lines = traces.lines_from_bytes(np.asarray(arr).tobytes())
    app = traces.AppSpec("tensor", 0.5, 0.6, read_frac, "random", 99)
    return traces.app_trace(app, n_requests=min(n_requests, len(lines)),
                            lines=lines)


def main():
    model = reference_vampire()
    vendor = 0

    print("== synthetic SPEC-like apps (paper Fig 26) ==")
    tba = {app.name: traces.app_trace(app, n_requests=400)
           for app in traces.SPEC_APPS[:8]}
    # all 8 apps x 4 encodings scored in ONE batched dispatch
    study = encodings.encoding_energy_study(tba, model, vendors=(vendor,))
    savings = []
    for name, per_enc in study.items():
        base = per_enc["baseline"]
        vals = [f"{enc}={per_enc[enc]/base:.3f}"
                for enc in ("bdi", "optimized", "owi")]
        savings.append(1 - per_enc["owi"] / base)
        print(f"  {name:12s} " + " ".join(vals))
    print(f"  OWI mean saving: {np.mean(savings)*100:.1f}% "
          f"(paper: 12.2%)")

    print("== framework tensor corpora ==")
    key = jax.random.key(0)
    corpora = {
        "bf16_weights": jax.random.normal(key, (256, 512), jnp.bfloat16)
        * 0.02,
        "bf16_activations": jax.nn.relu(
            jax.random.normal(key, (256, 512), jnp.bfloat16)),
        "int8_quantized": (jax.random.normal(key, (512, 512)) * 30)
        .astype(jnp.int8),
        "token_ids": jax.random.randint(key, (4096,), 0, 32000, jnp.int32),
    }
    for name, arr in corpora.items():
        tr = tensor_trace(arr)
        rep = model.estimate(
            [tr, encodings.encode_trace(tr, "owi")], (vendor,))
        base, owi = np.asarray(rep.energy_pj, np.float64)[:, 0]
        from repro.kernels.bdi.ops import compression_ratio
        lines = traces.trace_request_lines(tr)
        cr = float(compression_ratio(jnp.asarray(lines)))
        print(f"  {name:18s} OWI energy x{owi/base:.3f}  "
              f"BDI compressibility {cr:.2f}")


if __name__ == "__main__":
    main()
