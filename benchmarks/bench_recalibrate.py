"""Online recalibration from streaming telemetry (ours): the
``fitter='streaming'`` path of the fitter registry.  Emits the
``BENCH_recalibrate.json`` artifact CI uploads and gates.

Three stories, on a 6-module drifting fleet over 120 telemetry ticks:

* **tracking** — mean absolute current error of the recalibrated model vs
  the model left frozen after its one-shot campaign fit, both against the
  reconstructed drifted ground truth.  Gated:
  ``frozen_over_recalibrated_mape`` must hold >=5x (the frozen model goes
  stale the way the paper showed datasheets do), and
  ``oracle_over_recalibrated_mape`` >=0.4 (the streaming fit stays within
  ~2x of a full campaign refit run fresh on the final drifted fleet).
* **update cost** — the per-tick incremental work (fold one telemetry
  slice into the decayed sufficient statistics + drift score) vs a full
  campaign refit.  Gated: ``full_refit_over_update`` >=50x — the point of
  maintaining running moments is that a tick costs a scatter, not a
  campaign.
* **detector** — trigger count and peak drift score ride along
  (informational; TP/FP behavior is gated in the test suite).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ARTIFACTS, row
from repro.core import device_sim, model_api, recalibrate
from repro.core import params as P

ARTIFACT = os.path.join(ARTIFACTS, "BENCH_recalibrate.json")

N_VENDORS = 3
MODULES_PER_VENDOR = 2
TICKS = 120
CHECKPOINTS = (30, 60, 90, 120)
FIT_KW = dict(probe_modules=2, probe_reps=64, n_rows=8)
CONFIG = recalibrate.RecalConfig(probe_reps=64, n_rows=8, probe_modules=2,
                                 decay=0.7, slice_size=120)
DRIFT = device_sim.DriftProcess(temp_amp=0.01, temp_period=64.0,
                                aging_rate=8e-3, act_aging_rate=5e-3,
                                noise_sigma=1e-3)


def run() -> list[str]:
    specs = [P.ModuleSpec(v, i, 2015) for v in range(N_VENDORS)
             for i in range(MODULES_PER_VENDOR)]
    fleet_mods = device_sim.make_fleet(specs)

    t0 = time.perf_counter()
    fitter = model_api.fit("vampire", fleet_mods, fitter="streaming",
                           config=CONFIG)
    fit_s = time.perf_counter() - t0
    frozen = fitter.model

    src = recalibrate.TelemetrySource(fleet_mods, CONFIG, drift=DRIFT)
    tb = src.batch
    update_s: list[float] = []
    refit_s: list[float] = []
    triggers = 0
    peak_score = 0.0
    frozen_mape: dict[str, float] = {}
    recal_mape: dict[str, float] = {}
    for tick in range(1, TICKS + 1):
        cur, idx = src.measure(tick)
        t0 = time.perf_counter()
        rep = fitter.observe(cur, idx, tick)
        jax.block_until_ready(fitter.stats.mean)
        update_s.append(time.perf_counter() - t0)
        peak_score = max(peak_score, rep.score)
        if rep.triggered:
            triggers += 1
            t0 = time.perf_counter()
            fitter.refit()
            refit_s.append(time.perf_counter() - t0)
        if tick in CHECKPOINTS:
            truth = src.true_params_at(tick)
            frozen_mape[str(tick)] = recalibrate.fleet_current_mape(
                frozen, tb.trace, tb.weight, specs, truth)
            recal_mape[str(tick)] = recalibrate.fleet_current_mape(
                fitter.model, tb.trace, tb.weight, specs, truth)

    # the oracle: a full campaign refit, fresh, on the final drifted fleet
    final = CHECKPOINTS[-1]
    truth = src.true_params_at(final)
    drifted = [device_sim.SimulatedModule(
        s, jax.tree_util.tree_map(lambda x, i=i: x[i], truth))
        for i, s in enumerate(specs)]
    t0 = time.perf_counter()
    oracle = model_api.fit("vampire", drifted, fitter="campaign", **FIT_KW)
    full_refit_s = time.perf_counter() - t0
    oracle_mape = recalibrate.fleet_current_mape(
        oracle, tb.trace, tb.weight, specs, truth)

    update_p50 = float(np.percentile(update_s, 50))
    blob = {
        "bench": "recalibrate",
        "backend": jax.default_backend(),
        "modules": len(specs),
        "ticks": TICKS,
        "slice_size": CONFIG.slice_size,
        "decay": CONFIG.decay,
        "drift": {"temp_amp": DRIFT.temp_amp, "aging_rate": DRIFT.aging_rate,
                  "act_aging_rate": DRIFT.act_aging_rate},
        "initial_fit_s": fit_s,
        "frozen_mape": frozen_mape,
        "recalibrated_mape": recal_mape,
        "oracle_mape": oracle_mape,
        "update_ms_p50": update_p50 * 1e3,
        "streaming_refit_ms_p50": (float(np.percentile(refit_s, 50)) * 1e3
                                   if refit_s else 0.0),
        "full_refit_s": full_refit_s,
        "detector_triggers": triggers,
        "detector_peak_score": peak_score,
        # the gated ratios
        "frozen_over_recalibrated_mape": (frozen_mape[str(final)]
                                          / recal_mape[str(final)]),
        "oracle_over_recalibrated_mape": (oracle_mape
                                          / recal_mape[str(final)]),
        "full_refit_over_update": full_refit_s / update_p50,
    }

    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=2)

    return [
        row("recalibrate.update_tick", update_p50 * 1e6,
            f"slice={CONFIG.slice_size};decay={CONFIG.decay}"),
        row("recalibrate.full_refit", full_refit_s * 1e6,
            f"refit_over_update={blob['full_refit_over_update']:.0f}x"),
        row("recalibrate.tracking", blob["recalibrated_mape"][str(final)],
            f"frozen_over_recal="
            f"{blob['frozen_over_recalibrated_mape']:.1f}x;"
            f"oracle_over_recal="
            f"{blob['oracle_over_recalibrated_mape']:.2f};"
            f"triggers={triggers};artifact=BENCH_recalibrate.json"),
    ]
