"""Continuously batched serving vs the per-request estimation loop (ours):
the sustained-throughput win of ``repro.serving`` — ring-bucketed pad
shapes, resident model, windowed dispatch — over the request-at-a-time
``estimate([trace])`` loop the old ``serve.power_report`` path embodied,
measured on a ragged 256-trace arrival mix.  Emits ``BENCH_serve.json``
(speedup + batch fill gated by ``check_bench``; absolute traces/s and
latency percentiles recorded but hardware-exempt) and cross-checks every
service result against the one-shot batched ``estimate()`` dispatch."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ARTIFACTS, fitted_vampire, row
from repro.core import estimate_batch, traces
from repro.serving import EstimationService, ServiceConfig

N_TRACES = 256
N_SHAPES = 32          # distinct (app, n_requests) combos in the mix
BURST = 32             # arrival burst size (one dispatch window each)
ARTIFACT = os.path.join(ARTIFACTS, "BENCH_serve.json")


def _arrival_mix():
    """256 ragged traces drawn from 32 distinct shapes, interleaved the
    way traffic arrives (no sorted-by-length convenience): raggedness is
    real, but the per-request baseline's compile count stays bounded."""
    shapes = [(traces.SPEC_APPS[i % len(traces.SPEC_APPS)],
               40 + 9 * i) for i in range(N_SHAPES)]
    return [traces.app_trace(app, n_requests=n)
            for i in range(N_TRACES)
            for app, n in [shapes[(i * 7) % N_SHAPES]]]


def _service_run(svc, trs):
    """Drive one arrival sweep: bursts in, a dispatch tick per burst, a
    drain at the end (the shutdown flush)."""
    tickets = []
    for i in range(0, len(trs), BURST):
        tk, _ = svc.submit_many(trs[i:i + BURST])
        tickets.extend(tk)
        svc.step()
    svc.drain()
    return tickets


def run() -> list[str]:
    model = fitted_vampire()
    vendors = list(model.vendors)
    trs = _arrival_mix()

    # The HEADLINE metric is the sustained single-pass time: the arrival
    # mix streamed once, end to end, compiles included.  Serving traffic's
    # shape stream is unbounded, so the per-request loop keeps compiling —
    # one program per distinct arrival shape — while the ring's bucketing
    # bounds the service at one program per bucket shape.  Capping the mix
    # at 32 distinct shapes (8 arrivals amortize each compile) is already
    # GENEROUS to the per-request baseline; warm-cache times, where the
    # loop's whole shape vocabulary magically pre-exists, are recorded as
    # informational only.

    # ---- the service: bucketed windows, resident model -----------------
    svc = EstimationService(model, ServiceConfig())
    t0 = time.perf_counter()
    _service_run(svc, trs)
    service_sustained_s = time.perf_counter() - t0
    service_warm_s = float("inf")
    for _ in range(3):
        warm = EstimationService(config=ServiceConfig(), engine=svc.engine)
        t0 = time.perf_counter()
        tickets = _service_run(warm, trs)
        service_warm_s = min(service_warm_s, time.perf_counter() - t0)
    rows = np.stack([np.asarray(warm.result(t).energy_pj) for t in tickets])
    metrics = warm.metrics()

    # ---- per-request loop: one exact-shape estimate([tr]) per arrival --
    t0 = time.perf_counter()
    per_request = np.stack(
        [np.asarray(model.estimate([tr], vendors).energy_pj)[0]
         for tr in trs])
    loop_sustained_s = time.perf_counter() - t0
    loop_warm_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for tr in trs:
            jax.block_until_ready(model.estimate([tr], vendors).energy_pj)
        loop_warm_s = min(loop_warm_s, time.perf_counter() - t0)

    # acceptance bar: both paths ≡ the one-shot batched dispatch
    tb = estimate_batch.TraceBatch.from_traces(trs)
    oneshot = np.asarray(model.estimate(tb, vendors).energy_pj)
    np.testing.assert_allclose(rows, oneshot, rtol=1e-4)
    np.testing.assert_allclose(per_request, oneshot, rtol=1e-4)

    speedup = loop_sustained_s / service_sustained_s
    blob = {
        "bench": "serve",
        "n_traces": N_TRACES,
        "n_shapes": N_SHAPES,
        "n_vendors": len(vendors),
        "burst": BURST,
        "trace_commands_min": int(min(t.n for t in trs)),
        "trace_commands_max": int(max(t.n for t in trs)),
        "per_request_sustained_s": loop_sustained_s,
        "per_request_warm_s": loop_warm_s,
        "service_sustained_s": service_sustained_s,
        "service_warm_s": service_warm_s,
        "per_request_traces_per_s": N_TRACES / loop_sustained_s,
        "service_traces_per_s": N_TRACES / service_sustained_s,
        "service_speedup_vs_per_request": speedup,
        "speedup_warm": loop_warm_s / service_warm_s,
        "batch_fill": metrics.batch_fill,
        "dispatches": metrics.dispatches,
        "engine_programs": metrics.engine_programs,
        "latency_p50_ms": metrics.latency_p50_ms,
        "latency_p99_ms": metrics.latency_p99_ms,
        "dispatch_p50_ms": metrics.dispatch_p50_ms,
        "dispatch_p99_ms": metrics.dispatch_p99_ms,
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=2)

    return [
        row("serve.per_request", loop_sustained_s * 1e6,
            f"traces={N_TRACES};shapes={N_SHAPES};"
            f"traces_per_s={N_TRACES/loop_sustained_s:.1f};"
            f"warm_s={loop_warm_s:.2f}"),
        row("serve.service", service_sustained_s * 1e6,
            f"traces={N_TRACES};dispatches={metrics.dispatches};"
            f"fill={metrics.batch_fill:.2f};"
            f"traces_per_s={N_TRACES/service_sustained_s:.1f};"
            f"p50={metrics.latency_p50_ms:.0f}ms;"
            f"p99={metrics.latency_p99_ms:.0f}ms;"
            f"speedup_vs_per_request={speedup:.1f}x;"
            f"artifact=BENCH_serve.json"),
    ]
