"""VAMPIRE evaluation throughput (ours): commands/second of the scan
oracle vs the vectorized path vs the Pallas-fused path on a large
application trace, plus campaign fit time (batched fleet engine vs the
serial oracle). Fleet-scale use means 1e9+ command traces; the paper's
own tooling is a serial C++ program."""
from __future__ import annotations

import time

import jax

from benchmarks.common import fitted_vampire, row, timer
from repro.core import traces
from repro.core.energy_model import (trace_energy_scan,
                                     trace_energy_vectorized)
from repro.kernels.vampire_energy.ops import trace_energy_kernel


def _bench_campaign_fit() -> list[str]:
    """Reduced-fleet campaign (the tests' configuration) fitted through both
    engines, plus the 50-module fleet through the batched engine."""
    from benchmarks.common import full_fleet
    from repro.core import device_sim
    from repro.core import params as P
    from repro.core.vampire import Vampire

    reduced = device_sim.make_fleet(
        [P.ModuleSpec(v, i, 2015) for v in range(3) for i in range(3)])
    kw = dict(probe_modules=2, probe_reps=64, n_rows=8)
    out = []
    t0 = time.perf_counter()
    Vampire.fit(reduced, engine="batched", **kw)  # cold: plan + XLA compile
    dt_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    Vampire.fit(reduced, engine="batched", **kw)
    dt_b = time.perf_counter() - t0
    t0 = time.perf_counter()
    Vampire.fit(reduced, engine="serial", **kw)
    dt_s = time.perf_counter() - t0
    out.append(row("campaign.fit_reduced_serial", dt_s * 1e6, "oracle"))
    out.append(row("campaign.fit_reduced_batched", dt_b * 1e6,
                   f"speedup_vs_serial={dt_s/dt_b:.1f}x;"
                   f"cold_s={dt_cold:.1f}"))
    t0 = time.perf_counter()
    Vampire.fit(full_fleet(), probe_modules=5, probe_reps=128, n_rows=16,
                engine="batched")
    dt_f = time.perf_counter() - t0
    out.append(row("campaign.fit_fleet50_batched", dt_f * 1e6,
                   "modules=50;probe_reps=128"))
    return out


def _bench(fn, tr, pp, reps=3):
    r = fn(tr, pp)  # compile
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(tr, pp)
        jax.block_until_ready(r)
    dt = (time.perf_counter() - t0) / reps
    return dt, float(r.avg_current_ma)


def run() -> list[str]:
    out = []
    model = fitted_vampire()
    pp = model.params(0)
    tr = traces.app_trace(traces.SPEC_APPS[3], n_requests=30_000)
    n = int(tr.n)
    with timer() as t:
        dt_scan, i_scan = _bench(trace_energy_scan, tr, pp, reps=1)
        dt_vec, i_vec = _bench(trace_energy_vectorized, tr, pp)
        dt_ker, i_ker = _bench(trace_energy_kernel, tr, pp)
    out.append(row("throughput.scan", dt_scan * 1e6,
                   f"cmds_per_s={n/dt_scan:.3e};I={i_scan:.1f}mA"))
    out.append(row("throughput.vectorized", dt_vec * 1e6,
                   f"cmds_per_s={n/dt_vec:.3e};speedup_vs_scan="
                   f"{dt_scan/dt_vec:.1f}x;I={i_vec:.1f}mA"))
    out.append(row("throughput.pallas_fused", dt_ker * 1e6,
                   f"cmds_per_s={n/dt_ker:.3e};speedup_vs_scan="
                   f"{dt_scan/dt_ker:.1f}x;I={i_ker:.1f}mA"))
    out += _bench_campaign_fit()
    return out
