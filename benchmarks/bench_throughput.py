"""VAMPIRE evaluation throughput (ours): commands/second of the scan
oracle vs the vectorized path vs the Pallas-fused path on a large
application trace. Fleet-scale use means 1e9+ command traces; the paper's
own tooling is a serial C++ program."""
from __future__ import annotations

import time

import jax

from benchmarks.common import fitted_vampire, row, timer
from repro.core import traces
from repro.core.energy_model import (trace_energy_scan,
                                     trace_energy_vectorized)
from repro.kernels.vampire_energy.ops import trace_energy_kernel


def _bench(fn, tr, pp, reps=3):
    r = fn(tr, pp)  # compile
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(tr, pp)
        jax.block_until_ready(r)
    dt = (time.perf_counter() - t0) / reps
    return dt, float(r.avg_current_ma)


def run() -> list[str]:
    out = []
    model = fitted_vampire()
    pp = model.params(0)
    tr = traces.app_trace(traces.SPEC_APPS[3], n_requests=30_000)
    n = int(tr.n)
    with timer() as t:
        dt_scan, i_scan = _bench(trace_energy_scan, tr, pp, reps=1)
        dt_vec, i_vec = _bench(trace_energy_vectorized, tr, pp)
        dt_ker, i_ker = _bench(trace_energy_kernel, tr, pp)
    out.append(row("throughput.scan", dt_scan * 1e6,
                   f"cmds_per_s={n/dt_scan:.3e};I={i_scan:.1f}mA"))
    out.append(row("throughput.vectorized", dt_vec * 1e6,
                   f"cmds_per_s={n/dt_vec:.3e};speedup_vs_scan="
                   f"{dt_scan/dt_vec:.1f}x;I={i_vec:.1f}mA"))
    out.append(row("throughput.pallas_fused", dt_ker * 1e6,
                   f"cmds_per_s={n/dt_ker:.3e};speedup_vs_scan="
                   f"{dt_scan/dt_ker:.1f}x;I={i_ker:.1f}mA"))
    return out
