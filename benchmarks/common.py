"""Shared benchmark infrastructure: the full-fleet characterization is
expensive (it is the paper's entire measurement campaign), so it is cached
on disk and reused across benchmark modules.

The cache is a regular schema-v2 model blob (``model_api.save_estimator``:
.npz + JSON manifest) whose manifest ``meta`` records the fit
configuration; a blob written by different code or a different campaign
config is refit, not trusted.  The raw campaign sweeps ride along in the
blob (the per-figure benchmarks plot them)."""
from __future__ import annotations

import os
import time

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")
CACHE = os.path.join(ARTIFACTS, "vampire_fit.npz")
FIT_KW = dict(probe_modules=5, probe_reps=128, n_rows=16)
# v7: the protocol linter forced legal IDD3N/IDD7 schedules (shared
# all-banks setup, staggered precharges), so the probe traces — and with
# them the fitted state — changed; pre-linter caches must refit
_CACHE_META = {"cache": "bench-fit", "rev": "v7", "engine": "batched",
               "fit_kw": {k: int(v) for k, v in sorted(FIT_KW.items())}}

_model = None
_model_engine = None
_fleet = None


def full_fleet():
    global _fleet
    if _fleet is None:
        from repro.core import device_sim
        _fleet = device_sim.make_fleet()
    return _fleet


def fitted_vampire(refit: bool = False, engine: str = "batched"):
    """The paper's 50-module campaign, run through the batched fleet engine
    (pass ``engine='serial'`` for the one-measurement-at-a-time oracle).
    Only the default batched fit is cached (in memory and on disk); asking
    for a different engine than the cached one forces a refit."""
    global _model, _model_engine
    if _model is not None and not refit and engine == _model_engine:
        return _model
    os.makedirs(ARTIFACTS, exist_ok=True)
    from repro.core import model_api
    if os.path.exists(CACHE) and not refit and engine == "batched":
        try:
            manifest = model_api.read_manifest(CACHE)
            if manifest and manifest.get("meta") == _CACHE_META:
                _model = model_api.load_estimator(CACHE)
                _model_engine = engine
                return _model
        except Exception:
            pass
    from repro.core.vampire import Vampire
    t0 = time.time()
    _model = Vampire.fit(full_fleet(), engine=engine, **FIT_KW)
    _model_engine = engine
    print(f"# characterization campaign ({engine}): {time.time()-t0:.0f}s")
    if engine == "batched":
        _model.save(CACHE, meta=_CACHE_META)
    return _model


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.0f},{derived}"
