"""Shared benchmark infrastructure: the full-fleet characterization is
expensive (it is the paper's entire measurement campaign), so it is cached
on disk and reused across benchmark modules."""
from __future__ import annotations

import os
import pickle
import time

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")
CACHE = os.path.join(ARTIFACTS, "vampire_fit.pkl")
# provenance of the on-disk fit cache: (schema, engine, fit kwargs); a blob
# written by different code or a different campaign config is refit, not
# trusted
FIT_KW = dict(probe_modules=5, probe_reps=128, n_rows=16)
# v3: fleet engine shares the structural feature pass across modules (PR 2)
_CACHE_TAG = ("v3", "batched", tuple(sorted(FIT_KW.items())))

_model = None
_model_engine = None
_fleet = None


def full_fleet():
    global _fleet
    if _fleet is None:
        from repro.core import device_sim
        _fleet = device_sim.make_fleet()
    return _fleet


def fitted_vampire(refit: bool = False, engine: str = "batched"):
    """The paper's 50-module campaign, run through the batched fleet engine
    (pass ``engine='serial'`` for the one-measurement-at-a-time oracle).
    Only the default batched fit is cached (in memory and on disk); asking
    for a different engine than the cached one forces a refit."""
    global _model, _model_engine
    if _model is not None and not refit and engine == _model_engine:
        return _model
    os.makedirs(ARTIFACTS, exist_ok=True)
    if os.path.exists(CACHE) and not refit and engine == "batched":
        try:
            with open(CACHE, "rb") as f:
                blob = pickle.load(f)
            if isinstance(blob, dict) and blob.get("tag") == _CACHE_TAG:
                _model = blob["model"]
                _model_engine = engine
                return _model
        except Exception:
            pass
    from repro.core.vampire import Vampire
    t0 = time.time()
    _model = Vampire.fit(full_fleet(), engine=engine, **FIT_KW)
    _model_engine = engine
    print(f"# characterization campaign ({engine}): {time.time()-t0:.0f}s")
    for vc in _model.by_vendor.values():
        vc.build_params()
    if engine == "batched":
        with open(CACHE, "wb") as f:
            pickle.dump({"tag": _CACHE_TAG, "model": _model}, f)
    return _model


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.0f},{derived}"
