"""Shared benchmark infrastructure: the full-fleet characterization is
expensive (it is the paper's entire measurement campaign), so it is cached
on disk and reused across benchmark modules."""
from __future__ import annotations

import os
import pickle
import time

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")
CACHE = os.path.join(ARTIFACTS, "vampire_fit.pkl")

_model = None
_fleet = None


def full_fleet():
    global _fleet
    if _fleet is None:
        from repro.core import device_sim
        _fleet = device_sim.make_fleet()
    return _fleet


def fitted_vampire(refit: bool = False):
    """The paper's 50-module campaign, cached."""
    global _model
    if _model is not None and not refit:
        return _model
    os.makedirs(ARTIFACTS, exist_ok=True)
    if os.path.exists(CACHE) and not refit:
        try:
            with open(CACHE, "rb") as f:
                _model = pickle.load(f)
            return _model
        except Exception:
            pass
    from repro.core.vampire import Vampire
    t0 = time.time()
    _model = Vampire.fit(full_fleet(), probe_modules=5, probe_reps=128,
                         n_rows=16)
    print(f"# characterization campaign: {time.time()-t0:.0f}s")
    for vc in _model.by_vendor.values():
        vc.build_params()
    with open(CACHE, "wb") as f:
        pickle.dump(_model, f)
    return _model


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.0f},{derived}"
