"""Paper Fig 24 / Section 9.1: model validation MAPE — VAMPIRE vs
DRAMPower vs the Micron power calculator against 'measured' current."""
from __future__ import annotations

from benchmarks.common import fitted_vampire, full_fleet, row, timer
from repro.core.validate import run_validation

PAPER = {"vampire": 6.8, "drampower": 32.4, "micron": 160.6}


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
        res = run_validation(model, fleet=full_fleet())
    for name in ("vampire", "drampower", "micron"):
        per_v = res.mape[name]
        out.append(row(
            f"validation.mape.{name}", t.us / 3,
            f"A={per_v.get(0, 0):.1f}%;B={per_v.get(1, 0):.1f}%;"
            f"C={per_v.get(2, 0):.1f}%;mean={res.mape_mean[name]:.1f}%;"
            f"paper={PAPER[name]:.1f}%"))
    return out
