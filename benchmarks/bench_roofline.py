"""Roofline summary (deliverable g): reads the dry-run artifacts and emits
per-cell roofline terms. The full table lives in EXPERIMENTS.md; this
benchmark asserts the artifacts exist and surfaces the key aggregates."""
from __future__ import annotations

import os

from benchmarks.common import row, timer
from repro.launch import roofline


def run() -> list[str]:
    out = []
    art_dir = os.path.join(os.path.dirname(__file__), "..",
                           "artifacts", "dryrun")
    if not os.path.isdir(art_dir):
        return [row("roofline.missing", 0,
                    "run python -m repro.launch.dryrun --all first")]
    with timer() as t:
        rows = roofline.load_artifacts(art_dir, mesh_tag="16x16")
    if not rows:
        return [row("roofline.missing", t.us, "no 16x16 artifacts")]
    for r in rows:
        out.append(row(
            f"roofline.{r.arch}.{r.shape}", t.us / len(rows),
            f"compute_s={r.compute_s:.3e};memory_s={r.memory_s:.3e};"
            f"collective_s={r.collective_s:.3e};dominant={r.dominant};"
            f"roofline_frac={r.roofline_fraction:.3f};"
            f"model_over_hlo_flops={r.flops_ratio:.2f};"
            f"peak_GiB={r.peak_gib:.2f}"))
    by_dom = {}
    for r in rows:
        by_dom[r.dominant] = by_dom.get(r.dominant, 0) + 1
    out.append(row("roofline.summary", t.us,
                   f"cells={len(rows)};" + ";".join(
                       f"{k}_bound={v}" for k, v in sorted(by_dom.items()))))
    return out
