"""Batched vs reference linter throughput (ours): the JEDEC trace linter
must stay cheap enough to run on every generator construction and on every
serving ingestion, so this benchmark times the jitted batched engine
against the per-command Python reference walk over a (traces x commands)
grid and emits the ``BENCH_analysis.json`` artifact the regression gate
checks.  The gated ratio is a collapse guard: on CPU the vectorized
engine roughly matches the lean single-pass Python walk (the 8-bank
cummax tables are memory-bound), but a shape-unstable dispatch that
recompiles per call — or a silent fallback to per-trace serial linting —
drops the ratio by one to two orders of magnitude."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import ARTIFACTS, row
from repro.analysis import trace_lint
from repro.core import idd_loops, traces

ARTIFACT = os.path.join(ARTIFACTS, "BENCH_analysis.json")

#: (n_traces, approx commands per trace) measurement grid
GRID = [(8, 128), (32, 512), (64, 2048)]


def _fleet(n_traces: int, n_commands: int):
    """Ragged lint corpus around the requested command count."""
    out = []
    for i in range(n_traces):
        app = traces.SPEC_APPS[i % len(traces.SPEC_APPS)]
        # ~2 commands per request (ACT/RD/PRE amortized + refresh)
        tr = traces.app_trace(app, n_requests=max(n_commands // 2, 4))
        out.append(tr)
    return out


def run() -> list[str]:
    rows, grids = [], []
    for n_traces, n_commands in GRID:
        trs = _fleet(n_traces, n_commands)
        total_cmds = sum(int(t.n) for t in trs)

        t0 = time.perf_counter()
        diags = trace_lint.lint_traces(trs)   # compile + first run
        cold_s = time.perf_counter() - t0
        batched_s = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            diags = trace_lint.lint_traces(trs)
            batched_s = min(batched_s, time.perf_counter() - t0)

        # reference walk over a subsample (the full grid would dominate
        # bench wall-clock), scaled to the fleet size
        sample = trs[:max(len(trs) // 8, 1)]
        t0 = time.perf_counter()
        ref_diags = []
        for i, tr in enumerate(sample):
            ref_diags.extend(trace_lint.reference_lint(tr, trace_index=i))
        reference_s = (time.perf_counter() - t0) * (len(trs) / len(sample))

        # both engines agree the fleet is clean (generators self-check)
        assert diags == [] and ref_diags == []

        speedup = reference_s / batched_s
        grids.append({
            "n_traces": n_traces,
            "commands_per_trace": n_commands,
            "total_commands": total_cmds,
            "batched_s": batched_s,
            "batched_cold_s": cold_s,
            "reference_s": reference_s,
            "batched_commands_per_s": total_cmds / batched_s,
            "batched_speedup_vs_reference": speedup,
        })
        rows.append(row(
            f"analysis.lint[{n_traces}x{n_commands}]", batched_s * 1e6,
            f"cmds={total_cmds};cmds_per_s={total_cmds/batched_s:.0f};"
            f"speedup_vs_reference={speedup:.1f}x"))

    blob = {
        "bench": "analysis",
        "n_rules": len(trace_lint.RULES),
        "grids": grids,
        "batched_speedup_vs_reference": min(
            g["batched_speedup_vs_reference"] for g in grids),
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=2)
    rows[-1] += ";artifact=BENCH_analysis.json"
    return rows
