"""Unified-protocol dispatch overhead (ours, PR 3): what the model-API
redesign buys per call.

Two comparisons, emitted to ``artifacts/BENCH_model_api.json`` (uploaded
by CI like ``BENCH_estimate.json``):

* ``old_path`` vs ``unified``: the pre-redesign per-call pipeline re-padded
  the trace set and re-stacked the per-vendor ``PowerParams`` pytree on
  EVERY ``estimate_many`` call; the unified ``model.estimate`` stacks once
  at fit time and memoizes the padding, so the per-call overhead is one
  dict lookup.  Same jitted engine underneath — the delta is pure API tax.
* ``baseline_serial`` vs ``baseline_batched``: the pre-redesign
  ``validate.py`` scored Micron/DRAMPower with a per-(sweep, vendor)
  Python loop of tiny JAX programs; the protocol baselines score the whole
  grid in one vmapped dispatch over the shared structural-feature pass.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ARTIFACTS, fitted_vampire, row
from repro.core import baselines_power, estimate_batch, traces
from repro.core.fleet import stack_params

N_TRACES = 36
N_REPEATS = 12
ARTIFACT = os.path.join(ARTIFACTS, "BENCH_model_api.json")


def _trace_fleet():
    reps = -(-N_TRACES // len(traces.SPEC_APPS))
    apps = (traces.SPEC_APPS * reps)[:N_TRACES]
    return [traces.app_trace(app, n_requests=120 + 10 * (i % 4))
            for i, app in enumerate(apps)]


def _best_of(fn, n=N_REPEATS) -> float:
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[str]:
    model = fitted_vampire()
    vendors = list(model.vendors)
    trs = _trace_fleet()

    # ---- old per-call pipeline: re-pad + re-stack on every call ----------
    def old_path():
        tb = estimate_batch.TraceBatch.from_traces(trs)
        stacked = stack_params([model.params(v) for v in vendors])
        return estimate_batch.batched_reports(tb.trace, tb.weight, stacked)

    # ---- unified path: fit-time stack, memoized padding ------------------
    def unified():
        return model.estimate(trs, vendors)

    jax.block_until_ready(old_path())        # shared engine warm-up
    jax.block_until_ready(unified())
    old_s = _best_of(old_path)
    new_s = _best_of(unified)
    np.testing.assert_allclose(
        np.asarray(old_path().energy_pj), np.asarray(unified().energy_pj),
        rtol=2e-6)

    # ---- baselines: the validate.py grid, serial loop vs one dispatch ----
    micron = baselines_power.MicronModel.from_vampire(model)
    ds = {v: model.by_vendor[v].idd_datasheet for v in vendors}

    def baseline_serial():
        return [baselines_power.micron_power(tr, ds[v]).avg_current_ma
                for tr in trs for v in vendors]

    def baseline_batched():
        return micron.estimate(trs, vendors)

    jax.block_until_ready(baseline_serial())
    jax.block_until_ready(baseline_batched())
    serial_s = _best_of(baseline_serial, n=3)
    batched_s = _best_of(baseline_batched)
    grid = np.asarray(baseline_batched().avg_current_ma,
                      np.float64).reshape(-1)
    np.testing.assert_allclose(
        grid, np.asarray(baseline_serial(), np.float64), rtol=2e-6)

    n_pairs = len(trs) * len(vendors)
    blob = {
        "bench": "model_api",
        "n_traces": len(trs),
        "n_vendors": len(vendors),
        "old_path_s": old_s,
        "unified_s": new_s,
        "per_call_overhead_removed_us": (old_s - new_s) * 1e6,
        "unified_speedup": old_s / new_s,
        "baseline_serial_s": serial_s,
        "baseline_batched_s": batched_s,
        "baseline_speedup": serial_s / batched_s,
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=2)

    return [
        row("model_api.old_path", old_s * 1e6,
            f"pairs={n_pairs};restack_per_call=yes"),
        row("model_api.unified", new_s * 1e6,
            f"pairs={n_pairs};speedup_vs_old={old_s/new_s:.1f}x;"
            f"artifact=BENCH_model_api.json"),
        row("model_api.baseline_serial", serial_s * 1e6,
            f"pairs={n_pairs};loop=per_(trace,vendor)"),
        row("model_api.baseline_batched", batched_s * 1e6,
            f"pairs={n_pairs};speedup_vs_serial={serial_s/batched_s:.1f}x"),
    ]
