"""Paper Section 9.3's other two example applications (beyond-paper
implementations): variation-aware page allocation and power-down
scheduling, evaluated with the fitted VAMPIRE model."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fitted_vampire, row, timer
from repro.core import applications as A
from repro.core import traces


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
        # page allocation: vendor C has the largest structural variation
        for app_i in (3, 12):  # mcf, bwaves
            res = A.page_allocation_study(model, traces.SPEC_APPS[app_i],
                                          vendor=2)
            out.append(row(
                f"apps93.page_alloc.{res['app']}.C", 0,
                f"saving={res['saving_frac']*100:.2f}%;"
                f"baseline_uJ={res['baseline_pj']/1e6:.2f}"))
        # power-down scheduling: break-even per vendor + policy sweep
        for v in range(3):
            be = A.breakeven_idle_cycles(model.params(v))
            out.append(row(f"apps93.pd_breakeven.{'ABC'[v]}", 0,
                           f"breakeven_cycles={be:.0f}"
                           f"({be*2.5:.0f}ns)"))
        res = A.powerdown_study(model, traces.SPEC_APPS[21], vendor=0)
        out.append(row(
            "apps93.pd_policy.povray.A", 0,
            f"aggressive={res['aggressive_saving']*100:.1f}%;"
            f"breakeven={res['breakeven_saving']*100:.1f}%;"
            f"lazy={res['lazy_saving']*100:.1f}%"))
    # patch in the elapsed time
    return [r.replace(",0,", f",{t.us/len(out):.0f},") for r in out]
