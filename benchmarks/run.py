"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Usage:
    PYTHONPATH=src python -m benchmarks.run [--only idd,validation]
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    ("idd", "benchmarks.bench_idd"),                    # Figs 5-14
    ("datadep", "benchmarks.bench_datadep"),            # Figs 15-16, Tbl 2/5
    ("toggle", "benchmarks.bench_toggle"),              # Fig 18
    ("structural", "benchmarks.bench_structural"),      # Figs 19-22
    ("generational", "benchmarks.bench_generational"),  # Fig 23
    ("validation", "benchmarks.bench_validation"),      # Fig 24
    ("apps", "benchmarks.bench_apps"),                  # Fig 25
    ("encodings", "benchmarks.bench_encodings"),        # Fig 26
    ("applications", "benchmarks.bench_applications"),  # Sec 9.3 examples
    ("throughput", "benchmarks.bench_throughput"),      # ours
    ("estimate", "benchmarks.bench_estimate"),          # ours (PR 2)
    ("model_api", "benchmarks.bench_model_api"),        # ours (PR 3)
    ("kernels", "benchmarks.bench_kernels"),            # ours (PR 4)
    ("analysis", "benchmarks.bench_analysis"),          # ours (PR 7)
    ("serve", "benchmarks.bench_serve"),                # ours (PR 8)
    ("roofline", "benchmarks.bench_roofline"),          # deliverable (g)
    ("fleetscale", "benchmarks.bench_fleetscale"),      # ours (PR 9)
    ("recalibrate", "benchmarks.bench_recalibrate"),    # ours (PR 10)
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma-separated subset of benchmark names")
    args = p.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, modpath in MODULES:
        if only and name not in only:
            continue
        try:
            mod = __import__(modpath, fromlist=["run"])
            for line in mod.run():
                print(line)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
