"""Paper Figs 19-22 / Section 6: structural variation across banks & rows."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fitted_vampire, row, timer
from repro.core import params as P


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
    for v in range(3):
        vc = model.by_vendor[v]
        # Fig 19: one-bank-open idle current normalized to bank 0
        idle = vc.i2n + vc.bank_open_delta
        norm = idle / idle[0]
        out.append(row(
            f"structural.bank_idle.{'ABC'[v]}", t.us / 9,
            f"max_vs_bank0={np.max(norm) - 1:.3f};"
            f"mean_vs_bank0={np.mean(norm[1:] - 1):.3f};"
            f"paper_C_max=0.236;paper_C_avg=0.154"))
        # Fig 20/21: read/write current variation across banks
        out.append(row(
            f"structural.bank_rw.{'ABC'[v]}", t.us / 9,
            f"read_spread={np.ptp(vc.bank_read_factor):.3f}"
            f"(true {np.ptp(P.BANK_READ_FACTORS[v]):.3f});"
            f"write_spread={np.ptp(vc.bank_write_factor):.3f}(true 0)"))
        # Fig 22: activation current vs ones in the row address
        frac_at_15 = vc.row_ones_slope * 15
        out.append(row(
            f"structural.row_ones.{'ABC'[v]}", t.us / 9,
            f"increase_at_15_ones={frac_at_15:.3f}"
            f"(true {P.ROW_ONES_SLOPE[v] * 15:.3f});"
            f"fit_r2={vc.row_sweep['r2']:.3f};paper_B=0.146"))
    return out
