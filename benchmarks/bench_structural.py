"""Paper Figs 19-22 / Section 6: structural variation across banks & rows,
plus the ``mode='surface'`` engine benchmark (ours, PR 5): the fleet-wide
per-(bank, row-band) surface decomposition timed per (traces, vendors,
banks) grid against the per-trace Python sweep it replaces.  Emits the
``BENCH_structural.json`` artifact CI uploads and gates
(``benchmarks/check_bench.py`` enforces the batched-vs-sweep ratio floor;
wall-clock numbers stay informational)."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ARTIFACTS, fitted_vampire, row, timer
from repro.core import device_sim, estimate_batch, model_api, validate
from repro.core import params as P
from repro.core.dram import N_BANKS, N_ROW_BANDS

ARTIFACT = os.path.join(ARTIFACTS, "BENCH_structural.json")
GRIDS = ((8, 3), (32, 3))     # (traces, vendors); banks x bands fixed 8x8
SWEEP_REPS = 2
WARM_REPEATS = 4


def _surface_traces(n: int):
    """n structurally-interesting traces of ONE shape (the serial sweep
    re-dispatches per trace; one shape keeps its compile count honest)."""
    return [validate.surface_sweep_trace(reps=SWEEP_REPS) for _ in range(n)]


def _time_call(fn):
    jax.block_until_ready(fn())          # cold (compile included)
    best = float("inf")
    for _ in range(WARM_REPEATS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
    for v in range(3):
        vc = model.by_vendor[v]
        # Fig 19: one-bank-open idle current normalized to bank 0
        idle = vc.i2n + vc.bank_open_delta
        norm = idle / idle[0]
        out.append(row(
            f"structural.bank_idle.{'ABC'[v]}", t.us / 9,
            f"max_vs_bank0={np.max(norm) - 1:.3f};"
            f"mean_vs_bank0={np.mean(norm[1:] - 1):.3f};"
            f"paper_C_max=0.236;paper_C_avg=0.154"))
        # Fig 20/21: read/write current variation across banks
        out.append(row(
            f"structural.bank_rw.{'ABC'[v]}", t.us / 9,
            f"read_spread={np.ptp(vc.bank_read_factor):.3f}"
            f"(true {np.ptp(P.BANK_READ_FACTORS[v]):.3f});"
            f"write_spread={np.ptp(vc.bank_write_factor):.3f}(true 0)"))
        # Fig 22: activation current vs ones in the row address
        frac_at_15 = vc.row_ones_slope * 15
        out.append(row(
            f"structural.row_ones.{'ABC'[v]}", t.us / 9,
            f"increase_at_15_ones={frac_at_15:.3f}"
            f"(true {P.ROW_ONES_SLOPE[v] * 15:.3f});"
            f"fit_r2={vc.row_sweep['r2']:.3f};paper_B=0.146"))
        # Figs 19-22 as ONE surface: fitted vs planted per-(bank, row-band)
        fitted = np.asarray(vc.act_surface)
        planted = device_sim.structural_surface(v)
        out.append(row(
            f"structural.surface_recovery.{'ABC'[v]}", t.us / 9,
            f"max_abs_err={np.abs(fitted - planted).max():.4f};"
            f"planted_spread={np.ptp(planted):.3f};"
            f"hot_cell_found="
            f"{bool(fitted.argmax() == planted.argmax())}"))

    # ---- the surface engine per (traces, vendors, banks) grid -------------
    pallas_exec = model_api.impl_execution_mode("pallas")
    grids = []
    for n_traces, n_vendors in GRIDS:
        vendors = list(model.vendors)[:n_vendors]
        tb = estimate_batch.TraceBatch.from_traces(_surface_traces(n_traces))
        entry = {"traces": n_traces, "vendors": n_vendors,
                 "banks": N_BANKS, "row_bands": N_ROW_BANDS,
                 "commands_per_trace": int(tb.trace.cmd.shape[1])}

        batched = _time_call(
            lambda: model.estimate(tb, vendors, mode="surface").energy_pj)
        # the per-module Python sweep mode='surface' replaces: one
        # dispatch per (trace, vendor) pair through the same engine
        singles = [jax.tree_util.tree_map(lambda x, i=i: x[i:i + 1],
                                          tb.trace)
                   for i in range(n_traces)]

        def python_sweep():
            outs = []
            for i, trace1 in enumerate(singles):
                for vd in vendors:
                    outs.append(model.estimate(
                        estimate_batch.TraceBatch(
                            trace1, tb.weight[i:i + 1]),
                        (vd,), mode="surface").energy_pj)
            return outs

        sweep = _time_call(python_sweep)
        pallas = _time_call(
            lambda: model.estimate(tb, vendors, mode="surface",
                                   impl="pallas").energy_pj)
        entry["batched_warm_s"] = batched
        entry["python_sweep_warm_s"] = sweep
        entry["pallas_warm_s"] = pallas
        entry["surface_speedup_vs_python_sweep"] = sweep / batched
        grids.append(entry)
        tag = f"{n_traces}x{n_vendors}x{N_BANKS}"
        out.append(row(
            f"structural.surface_batched.{tag}", batched * 1e6,
            f"python_sweep_us={sweep * 1e6:.0f};"
            f"speedup={entry['surface_speedup_vs_python_sweep']:.1f}x"))
        out.append(row(
            f"structural.surface_pallas.{tag}", pallas * 1e6,
            f"exec={pallas_exec}"))

    largest = grids[-1]
    blob = {
        "bench": "structural",
        "backend": jax.default_backend(),
        "pallas_execution": pallas_exec,
        "banks": N_BANKS,
        "row_bands": N_ROW_BANDS,
        "grids": grids,
        # ratio metrics (gated by benchmarks/check_bench.py); wall-clock
        # entries above are informational
        "surface_speedup_vs_python_sweep":
            largest["surface_speedup_vs_python_sweep"],
        "surface_recovery_max_abs_err": float(max(
            np.abs(np.asarray(model.by_vendor[v].act_surface)
                   - device_sim.structural_surface(v)).max()
            for v in model.by_vendor)),
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=2)
    out.append(row(
        "structural.summary", largest["batched_warm_s"] * 1e6,
        f"largest_grid={largest['traces']}x{largest['vendors']}x{N_BANKS};"
        f"speedup_vs_sweep={blob['surface_speedup_vs_python_sweep']:.1f}x;"
        f"artifact=BENCH_structural.json"))
    return out
