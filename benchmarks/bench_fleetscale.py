"""Fleet-scale surface estimation (ours): the chunked, zero-restack
dispatch over synthetic 1k/10k-module fleets.  Emits the
``BENCH_fleetscale.json`` artifact CI uploads and gates.

Three stories, all hardware-normalized where gated:

* **throughput** — modules/s of the chunked surface map at 1k and 10k
  modules, vs the legacy per-module restack loop (stack one module's
  params, dispatch one module's surface, repeat — the pattern the
  memoized ``fleet_stacked`` + chunked dispatch replaced).  The gated
  ``speedup_vs_restack`` ratio must hold >=5x.
* **parity** — the chunked dispatch must reproduce the one-shot surface
  BITWISE at 1k modules (``parity_exact`` gates at 1.0; the paths share
  one charge program by construction).
* **memory** — peak-RSS proxy (``ru_maxrss``) snapshots around each
  phase: the chunked 10k map must not grow live memory like the fleet
  (informational — RSS is a monotonic per-process high-water mark)."""
from __future__ import annotations

import json
import os
import resource
import time

import jax
import numpy as np

from benchmarks.common import ARTIFACTS, row
from repro.core import device_sim, estimate_batch, idd_loops
from repro.core.dram import batch_traces

ARTIFACT = os.path.join(ARTIFACTS, "BENCH_fleetscale.json")
FLEET_SIZES = (1_000, 10_000)
MODULE_CHUNK = 256
N_RESTACK_MODULES = 48      # legacy-loop sample (extrapolated to modules/s)
WARM_REPEATS = 3


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _surface_batch():
    """A small, heterogeneous trace batch (the surface map's trace axis is
    narrow; the module axis is the scale story)."""
    trs = [(idd_loops.validation_sweep(8, reps=12), 2),
           (idd_loops.validation_sweep(16, reps=8), 2)]
    return batch_traces(trs)


def _time(fn, repeats: int = WARM_REPEATS):
    jax.block_until_ready(fn())            # cold (compile absorbed)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _legacy_restack_loop(trace, weight, stacked, n_modules: int):
    """The pre-chunked pattern: per module, stack that module's params and
    dispatch its surface — one restack + one dispatch per module."""
    from repro.core.fleet import stack_params
    for i in range(n_modules):
        pp_i = jax.tree_util.tree_map(lambda x: x[i], stacked)
        one = stack_params([pp_i])
        out = estimate_batch.batched_surface_reports(trace, weight, one)
    return out


def run() -> list[str]:
    trace, weight = _surface_batch()
    lines = []
    blob = {
        "bench": "fleetscale",
        "backend": jax.default_backend(),
        "module_chunk": MODULE_CHUNK,
        "traces": int(trace.cmd.shape[0]),
        "commands_per_trace": int(trace.cmd.shape[1]),
        "rss_mb_start": _rss_mb(),
        "fleets": {},
    }

    # ---- throughput: chunked surface map at each fleet size -------------
    for n in FLEET_SIZES:
        _, stacked = device_sim.synth_fleet_params(n)
        warm_s = _time(lambda: estimate_batch.chunked_surface_reports(
            trace, weight, stacked, module_chunk=MODULE_CHUNK).energy_pj)
        entry = {"modules": n, "warm_s": warm_s,
                 "modules_per_s": n / warm_s,
                 "rss_mb_after_chunked": _rss_mb()}
        blob["fleets"][str(n)] = entry
        lines.append(row(f"fleetscale.chunked.{n}", warm_s * 1e6,
                         f"modules_per_s={entry['modules_per_s']:.0f};"
                         f"chunk={MODULE_CHUNK}"))

    # ---- parity: chunked == one-shot, bitwise, at 1k modules ------------
    n_par = FLEET_SIZES[0]
    _, stacked = device_sim.synth_fleet_params(n_par)
    one_shot = estimate_batch.batched_surface_reports(trace, weight, stacked)
    chunked = estimate_batch.chunked_surface_reports(
        trace, weight, stacked, module_chunk=MODULE_CHUNK)
    exact = all(
        np.array_equal(np.asarray(getattr(one_shot, f)),
                       np.asarray(getattr(chunked, f)))
        for f in one_shot._fields)
    oneshot_s = _time(lambda: estimate_batch.batched_surface_reports(
        trace, weight, stacked).energy_pj)
    blob["parity_exact"] = 1.0 if exact else 0.0
    blob["oneshot_1k_warm_s"] = oneshot_s
    blob["rss_mb_after_oneshot"] = _rss_mb()
    blob["chunked_over_oneshot_warm"] = (
        blob["fleets"][str(n_par)]["warm_s"] / oneshot_s)

    # ---- the legacy per-module restack loop -----------------------------
    restack_s = _time(lambda: _legacy_restack_loop(
        trace, weight, stacked, N_RESTACK_MODULES), repeats=2)
    restack_mps = N_RESTACK_MODULES / restack_s
    blob["restack_sample_modules"] = N_RESTACK_MODULES
    blob["restack_modules_per_s"] = restack_mps
    blob["speedup_vs_restack"] = (
        blob["fleets"][str(FLEET_SIZES[-1])]["modules_per_s"] / restack_mps)

    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=2)
    lines.append(row(
        "fleetscale.summary",
        blob["fleets"][str(FLEET_SIZES[-1])]["warm_s"] * 1e6,
        f"modules={FLEET_SIZES[-1]};parity_exact={exact};"
        f"speedup_vs_restack={blob['speedup_vs_restack']:.1f}x;"
        f"artifact=BENCH_fleetscale.json"))
    return lines
