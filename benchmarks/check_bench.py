"""Benchmark-regression gate: compare freshly emitted ``BENCH_*.json``
artifacts against committed baseline snapshots on RATIO metrics.

Wall-clock numbers vary with runner hardware and stay informational; the
ratios (batched-vs-serial speedup, unified-vs-old-path speedup,
pallas-vs-vectorized speedup, surface-vs-python-sweep speedup) are
hardware-normalized and must not collapse.  A fresh ratio passes when it
clears EITHER the absolute floor (a healthy run on any hardware) OR the
baseline-relative bar ``baseline * (1 - rel_slack)`` (no large regression
against the committed snapshot) — so noisy runners don't flake, while an
order-of-magnitude regression (e.g. the batched path silently falling back
to serial dispatches) fails loudly.

Usage (what CI runs after the bench steps, replacing the old blanket
``continue-on-error``):

    python -m benchmarks.check_bench --fresh artifacts \
        --baseline "$RUNNER_TEMP/bench-baseline"

Exit status 0 = all gates pass; 1 = regression (reasons on stdout).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys


@dataclasses.dataclass(frozen=True)
class RatioCheck:
    """One gated ratio metric inside a benchmark artifact."""
    path: tuple[str, ...]          # key path into the JSON blob
    floor: float                   # absolute pass bar (healthy-run value)
    rel_slack: float = 0.5         # allowed fraction below the baseline
    # key path of a boolean in the FRESH blob gating applicability (e.g.
    # pallas speed bars only apply when the kernels actually compiled)
    applies_if: tuple[str, ...] | None = None


# artifact file -> its gated ratios.  Floors sit far below healthy values
# (estimate speedup is ~30x warm on the committed snapshot, model_api ~13x,
# baseline batching ~3700x) but far above what any real regression yields.
CHECKS: dict[str, tuple[RatioCheck, ...]] = {
    "BENCH_estimate.json": (
        RatioCheck(("speedup_warm",), floor=4.0),
        RatioCheck(("speedup_cold",), floor=2.0),
    ),
    "BENCH_model_api.json": (
        RatioCheck(("unified_speedup",), floor=3.0),
        RatioCheck(("baseline_speedup",), floor=50.0),
    ),
    "BENCH_kernels.json": (
        # the compiled-path speed bar: fused beats vectorized on the
        # largest grid.  Off-TPU the kernels run in interpret mode and the
        # bar does not apply (parity is covered by the test suite).
        RatioCheck(("grids", "-1", "pallas_speedup_vs_vectorized_warm"),
                   floor=1.0, rel_slack=0.9,
                   applies_if=("speed_bar_applies",)),
    ),
    "BENCH_structural.json": (
        RatioCheck(("surface_speedup_vs_python_sweep",), floor=3.0),
    ),
    "BENCH_analysis.json": (
        # the jitted batched linter vs the per-command Python reference
        # walk.  On CPU CI the vectorized engine only roughly matches the
        # lean Python walk (healthy ~0.5-1.0; accelerators pull well
        # ahead), so the bar is a COLLAPSE guard: a per-call recompile or
        # a serialized per-trace fallback drops this ratio by 10-100x.
        RatioCheck(("batched_speedup_vs_reference",), floor=0.15),
    ),
    "BENCH_serve.json": (
        # sustained single-pass serving (compiles included — the arrival
        # shape stream is what the per-request loop keeps recompiling on):
        # the ring-bucketed service must hold a wide margin, and its
        # dispatch windows must stay usefully full.  Warm-cache times and
        # absolute traces/s are recorded but hardware-exempt.
        RatioCheck(("service_speedup_vs_per_request",), floor=5.0),
        RatioCheck(("batch_fill",), floor=0.5),
    ),
    "BENCH_fleetscale.json": (
        # fleet-scale surface map, hardware-normalized: the chunked
        # zero-restack dispatch must hold a wide modules/s margin over the
        # legacy per-module restack loop (healthy ~100x+ on CPU), and the
        # chunked result must stay BITWISE equal to the one-shot surface
        # (the paths share one charge program by construction; 1.0 = every
        # report leaf array-equal at the 1k-module parity point).
        RatioCheck(("speedup_vs_restack",), floor=5.0),
        RatioCheck(("parity_exact",), floor=1.0, rel_slack=0.0),
    ),
    "BENCH_recalibrate.json": (
        # online recalibration from streaming telemetry: the frozen
        # one-shot fit must go >=5x stale relative to the recalibrated
        # model under the benchmark's drift (healthy ~8x), the streaming
        # fit must stay near a fresh full-campaign oracle refit (healthy
        # oracle/recal ~0.5-0.7; a lagging or broken incremental fit drops
        # this toward the frozen model's ratio), and the per-tick
        # incremental update must stay orders of magnitude cheaper than a
        # full campaign refit (healthy ~1000x+).
        RatioCheck(("frozen_over_recalibrated_mape",), floor=5.0),
        RatioCheck(("oracle_over_recalibrated_mape",), floor=0.4),
        RatioCheck(("full_refit_over_update",), floor=50.0),
    ),
    "BENCH_idd.json": (
        # Section 4 / Fig 14 physics, hardware-independent by construction:
        # frequency extrapolation must stay a good fit (paper worst R^2 =
        # 0.9783), the low-power states must keep measuring well below
        # datasheet (worst healthy reduction ~0.18, IDD3P vendor B), and
        # idle standby must stay well above slow-PDN / self-refresh draw
        # (~3.3x / ~2.4x healthy) or power-down scheduling is pointless.
        RatioCheck(("ratios", "extrapolation_r2_worst"), floor=0.97,
                   rel_slack=0.02),
        RatioCheck(("ratios", "lowpower_reduction_worst"), floor=0.10),
        RatioCheck(("ratios", "idle_over_slow_pdn_worst"), floor=1.5),
        RatioCheck(("ratios", "idle_over_self_refresh_worst"), floor=1.5),
    ),
}


def lookup(blob: dict, path: tuple[str, ...]):
    """Walk a key path; integer-looking components index into lists."""
    node = blob
    for key in path:
        if isinstance(node, list):
            node = node[int(key)]
        else:
            node = node[key]
    return node


def check_artifact(name: str, fresh: dict, baseline: dict | None,
                   checks: tuple[RatioCheck, ...]) -> list[str]:
    """Failure messages for one artifact (empty = gate passes)."""
    failures = []
    for chk in checks:
        label = f"{name}:{'.'.join(chk.path)}"
        if chk.applies_if is not None:
            try:
                applies = bool(lookup(fresh, chk.applies_if))
            except (KeyError, IndexError, TypeError):
                failures.append(
                    f"{label}: applicability flag "
                    f"{'.'.join(chk.applies_if)} missing from fresh "
                    f"artifact")
                continue
            if not applies:
                continue
        try:
            value = float(lookup(fresh, chk.path))
        except (KeyError, IndexError, TypeError):
            failures.append(f"{label}: metric missing from fresh artifact")
            continue
        bars = [f"floor {chk.floor:g}"]
        if value >= chk.floor:
            continue
        if baseline is not None:
            try:
                base = float(lookup(baseline, chk.path))
            except (KeyError, IndexError, TypeError):
                base = None
            if base is not None:
                bar = base * (1.0 - chk.rel_slack)
                bars.append(f"baseline {base:g} * {1 - chk.rel_slack:g} "
                            f"= {bar:g}")
                if value >= bar:
                    continue
        failures.append(f"{label}: {value:g} regressed below "
                        f"{' and '.join(bars)}")
    return failures


def validate_baselines(baseline_dir: str,
                       checks: dict[str, tuple[RatioCheck, ...]] = CHECKS
                       ) -> list[str]:
    """Schema-validate the committed baseline snapshots themselves, so a
    malformed or orphaned baseline fails the gate loudly instead of
    silently disabling its relative bar (a missing/unparseable baseline
    would otherwise just fall back to the absolute floor)."""
    failures = []
    import glob
    for path in sorted(glob.glob(os.path.join(baseline_dir,
                                              "BENCH_*.json"))):
        name = os.path.basename(path)
        if name not in checks:
            failures.append(f"{name}: committed baseline has no CHECKS "
                            f"entry (add its gated ratios or delete it)")
            continue
        try:
            with open(path) as f:
                blob = json.load(f)
        except (OSError, ValueError) as exc:
            failures.append(f"{name}: baseline unreadable: {exc}")
            continue
        if not isinstance(blob, dict):
            failures.append(f"{name}: baseline root is "
                            f"{type(blob).__name__}, expected object")
            continue
        for chk in checks[name]:
            label = f"{name}:{'.'.join(chk.path)}"
            if chk.applies_if is not None:
                try:
                    if not bool(lookup(blob, chk.applies_if)):
                        continue
                except (KeyError, IndexError, TypeError):
                    pass  # missing flag: still require the metric
            try:
                value = float(lookup(blob, chk.path))
            except (KeyError, IndexError, TypeError, ValueError):
                failures.append(f"{label}: baseline metric missing or "
                                f"non-numeric")
                continue
            if not (value == value and abs(value) != float("inf")):
                failures.append(f"{label}: baseline metric is {value}")
    return failures


def run_gate(fresh_dir: str, baseline_dir: str,
             checks: dict[str, tuple[RatioCheck, ...]] = CHECKS
             ) -> list[str]:
    """All failure messages across the artifact set."""
    failures = validate_baselines(baseline_dir, checks)
    for name, artifact_checks in sorted(checks.items()):
        fresh_path = os.path.join(fresh_dir, name)
        base_path = os.path.join(baseline_dir, name)
        if not os.path.exists(fresh_path):
            failures.append(f"{name}: fresh artifact missing (bench step "
                            f"did not emit it)")
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        baseline = None
        if os.path.exists(base_path):
            with open(base_path) as f:
                baseline = json.load(f)
        failures.extend(check_artifact(name, fresh, baseline,
                                       artifact_checks))
    return failures


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fresh", default="artifacts",
                   help="directory holding freshly emitted BENCH_*.json")
    p.add_argument("--baseline", required=True,
                   help="directory holding the committed baseline snapshots")
    args = p.parse_args()
    failures = run_gate(args.fresh, args.baseline)
    if failures:
        print("benchmark-regression gate FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        sys.exit(1)
    print(f"benchmark-regression gate passed "
          f"({sum(len(c) for c in CHECKS.values())} ratio checks over "
          f"{len(CHECKS)} artifacts)")


if __name__ == "__main__":
    main()
