"""Paper Fig 23 / Section 7: generational power trends (Vendor C
2011/2012/2015) — measured savings are far below datasheet savings."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timer
from repro.core import device_sim, idd_loops
from repro.core import params as P
from repro.core.characterize import derive_datasheets


def _measure(year: int, key: str) -> float:
    specs = ([P.ModuleSpec(2, 100 + i, year) for i in range(3)]
             if year == 2011 else
             [P.ModuleSpec(2, 200 + i, year) for i in range(4)]
             if year == 2012 else
             [P.ModuleSpec(2, i, 2015) for i in range(6)])
    mods = device_sim.make_fleet(specs)
    loop = idd_loops.IDD_LOOPS[key]()
    return float(np.mean([m.measure_current(loop) for m in mods]))


def run() -> list[str]:
    paper = {"IDD0": (192.1, 64.0), "IDD4R": (212.2, 140.6),
             "IDD4W": (200.2, 147.4)}
    results = []
    with timer() as t:
        ds2015 = derive_datasheets()[2]
        for key in ("IDD2N", "IDD0", "IDD4R", "IDD4W"):
            m = {y: _measure(y, key) for y in (2011, 2012, 2015)}
            gen_ds = P.GEN_DATASHEET_SCALE.get(
                key, P.GEN_DATASHEET_SCALE["IDD2N"])
            ds = {y: ds2015[key] * gen_ds[i]
                  for i, y in enumerate((2011, 2012, 2015))}
            results.append((key, m[2011] - m[2015], ds[2011] - ds[2015]))
    out = []
    for key, meas_saving, ds_saving in results:
        frac = meas_saving / ds_saving if ds_saving else float("nan")
        extra = ""
        if key in paper:
            extra = (f";paper_promised={paper[key][0]:.0f}"
                     f";paper_measured={paper[key][1]:.0f}")
        out.append(row(
            f"generational.{key}.C", t.us / 4,
            f"measured_saving_mA={meas_saving:.1f};"
            f"datasheet_saving_mA={ds_saving:.1f};"
            f"achieved_frac={frac:.2f}" + extra))
    return out
