"""Paper Figs 15-16 + Tables 2/5: data-dependency sweeps and Eq.-2 fits."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fitted_vampire, row, timer
from repro.core import params as P
from repro.core.characterize import IL_MODES


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
    for v in range(3):
        vc = model.by_vendor[v]
        # Fig 15: swing of read/write current over the full ones range
        rd = vc.ones_sweep[("none", "RD")]
        wr = vc.ones_sweep[("none", "WR")]
        rd_swing = float(rd["current"].max() - rd["current"].min())
        wr_swing = float(wr["current"].max() - wr["current"].min())
        out.append(row(f"datadep.ones_swing.{'ABC'[v]}", t.us / 3,
                       f"read_swing_mA={rd_swing:.1f};"
                       f"write_swing_mA={wr_swing:.1f};"
                       f"paper_A_read=434;paper_A_write=311"))
        # Table 2/5 recovery per interleave mode (column mode == Table 2)
        for mi, mode in enumerate(IL_MODES):
            fit = vc.datadep[mi]
            truth = P.TABLE5[v][mi]
            err0 = abs(fit[0][0] - truth[0][0]) / truth[0][0] * 100
            out.append(row(
                f"datadep.table5.{'ABC'[v]}.{mode}", t.us / 12,
                f"rd_Izero={fit[0][0]:.1f}(true {truth[0][0]:.1f});"
                f"rd_dIone={fit[0][1]:.3f}(true {truth[0][1]:.3f});"
                f"wr_dIone={fit[1][1]:.3f}(true {truth[1][1]:.3f});"
                f"Izero_err%={err0:.1f}"))
        # model-vs-measurement error (paper: <=1.40%, avg 0.34%)
        errs = []
        for (mode, op), sweep in vc.ones_sweep.items():
            mi = IL_MODES.index(mode)
            oi = 0 if op == "RD" else 1
            pred = (vc.datadep[mi, oi, 0]
                    + vc.datadep[mi, oi, 1] * sweep["ones"]
                    + vc.datadep[mi, oi, 2] * sweep["toggles"])
            errs += list(np.abs(pred - sweep["corrected"])
                         / np.abs(sweep["corrected"]) * 100)
        out.append(row(f"datadep.model_err.{'ABC'[v]}", t.us / 3,
                       f"max%={np.max(errs):.2f};mean%={np.mean(errs):.2f};"
                       f"paper_max=1.40;paper_mean=0.34"))
    return out
