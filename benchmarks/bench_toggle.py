"""Paper Fig 18: toggle sensitivity (mA per toggling wire) per interleave
mode — must be much smaller than the ones effect, and bank+col < col."""
from __future__ import annotations

from benchmarks.common import fitted_vampire, row, timer
from repro.core import params as P


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
    for v in range(3):
        vc = model.by_vendor[v]
        col_rd = float(vc.datadep[1, 0, 2])
        bankcol_rd = float(vc.datadep[3, 0, 2])
        ones_rd = float(vc.datadep[1, 0, 1])
        out.append(row(
            f"toggle.sensitivity.{'ABC'[v]}", t.us / 3,
            f"col_mA_per_bit={col_rd:.4f}(true {P.TABLE5[v][1][0][2]:.4f});"
            f"bankcol_mA_per_bit={bankcol_rd:.4f}"
            f"(true {P.TABLE5[v][3][0][2]:.4f});"
            f"ones_effect_x={abs(ones_rd / max(col_rd, 1e-6)):.1f}"))
    return out
