"""Fused-kernel vs vectorized vs reference estimation throughput (ours):
the impl registry's three evaluation paths timed cold+warm over a sweep of
(traces, vendors) grid sizes, through the ONE ``model.estimate`` entry
point.  Emits the ``BENCH_kernels.json`` artifact CI uploads.

Off-TPU the ``pallas`` impl runs in interpret mode (the registry's
capability fallback): numbers are recorded with
``pallas_execution='interpret'`` and are parity checks, not perf — the
speed bar (fused beats vectorized on the largest grid) applies to the
compiled path only."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ARTIFACTS, fitted_vampire, row
from repro.core import estimate_batch, model_api, traces
from repro.kernels import autotune

ARTIFACT = os.path.join(ARTIFACTS, "BENCH_kernels.json")
GRIDS = ((8, 1), (8, 3), (32, 3), (128, 3))   # (traces, vendors)
# interpret mode runs each grid cell as a Python-loop iteration, so the
# wide trace row degrades superlinearly (~160x the vectorized path at 128
# traces) while adding nothing the 32-trace row doesn't already cover —
# the sweep caps there and records the cap in the artifact
INTERPRET_MAX_TRACES = 32
N_REQUESTS = 120
WARM_REPEATS = {"vectorized": 8, "pallas": 3, "reference": 2}


def _trace_fleet(n: int):
    """n app traces cycling 4 distinct shapes (bounds the per-shape
    compile count of the pair-at-a-time reference oracle)."""
    return [traces.app_trace(traces.SPEC_APPS[i % 4], n_requests=N_REQUESTS)
            for i in range(n)]


def _time_impl(model, tb, vendors, impl: str):
    t0 = time.perf_counter()
    jax.block_until_ready(model.estimate(tb, vendors, impl=impl))
    cold_s = time.perf_counter() - t0
    warm_s = float("inf")
    for _ in range(WARM_REPEATS[impl]):
        t0 = time.perf_counter()
        rep = model.estimate(tb, vendors, impl=impl)
        jax.block_until_ready(rep)
        warm_s = min(warm_s, time.perf_counter() - t0)
    return rep, {"cold_s": cold_s, "warm_s": warm_s}


def run() -> list[str]:
    model = fitted_vampire()
    pallas_exec = model_api.impl_execution_mode("pallas")
    sweep_grids = (GRIDS if pallas_exec == "compiled" else
                   tuple(g for g in GRIDS if g[0] <= INTERPRET_MAX_TRACES))
    grids = []
    lines = []
    for n_traces, n_vendors in sweep_grids:
        vendors = list(model.vendors)[:n_vendors]
        trs = _trace_fleet(n_traces)
        tb = estimate_batch.TraceBatch.from_traces(trs)
        entry = {"traces": n_traces, "vendors": n_vendors,
                 "commands_per_trace": int(tb.trace.cmd.shape[1])}
        reps = {}
        for impl in ("vectorized", "pallas", "reference"):
            reps[impl], entry[impl] = _time_impl(model, tb, vendors, impl)
        # all three paths must agree before their timings mean anything
        for impl in ("pallas", "reference"):
            np.testing.assert_allclose(
                np.asarray(reps[impl].energy_pj),
                np.asarray(reps["vectorized"].energy_pj), rtol=1e-5)
        entry["pallas_speedup_vs_vectorized_warm"] = (
            entry["vectorized"]["warm_s"] / entry["pallas"]["warm_s"])
        grids.append(entry)
        tag = f"{n_traces}x{n_vendors}"
        lines.append(row(
            f"kernels.vectorized.{tag}", entry["vectorized"]["warm_s"] * 1e6,
            f"cold_s={entry['vectorized']['cold_s']:.2f}"))
        lines.append(row(
            f"kernels.pallas.{tag}", entry["pallas"]["warm_s"] * 1e6,
            f"cold_s={entry['pallas']['cold_s']:.2f};exec={pallas_exec};"
            f"speedup_vs_vectorized="
            f"{entry['pallas_speedup_vs_vectorized_warm']:.2f}x"))
        lines.append(row(
            f"kernels.reference.{tag}", entry["reference"]["warm_s"] * 1e6,
            f"cold_s={entry['reference']['cold_s']:.2f}"))

    largest = grids[-1]
    blob = {
        "bench": "kernels",
        "backend": jax.default_backend(),
        "pallas_execution": pallas_exec,
        "interpret_max_traces": (None if pallas_exec == "compiled"
                                 else INTERPRET_MAX_TRACES),
        # the autotuned launch configs these timings actually ran with
        "autotune": {
            "backend_key": autotune.backend_key(),
            "table": autotune.choices(),
            "per_grid": {
                f"{e['traces']}x{e['vendors']}": autotune.best_config(
                    "vampire_energy", e["traces"],
                    e["commands_per_trace"])
                for e in grids},
        },
        "grids": grids,
        # the acceptance bar tracks the COMPILED fused path; interpret mode
        # (any non-TPU backend) is parity-checked but speed-exempt
        "largest_grid_pallas_beats_vectorized": bool(
            largest["pallas_speedup_vs_vectorized_warm"] > 1.0),
        "speed_bar_applies": pallas_exec == "compiled",
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=2)
    lines.append(row(
        "kernels.summary", largest["pallas"]["warm_s"] * 1e6,
        f"largest_grid={largest['traces']}x{largest['vendors']};"
        f"exec={pallas_exec};artifact=BENCH_kernels.json"))
    return lines
