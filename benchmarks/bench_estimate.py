"""Serial vs batched estimation throughput (ours): the runtime-estimation
dispatch win of the unified ``model.estimate`` matrix path over the
one-(trace, vendor)-per-call loop, measured on a ragged fleet of >= 32
application traces x all vendors. Emits the ``BENCH_estimate.json``
artifact CI uploads so the perf trajectory of the estimation path is
tracked across PRs."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ARTIFACTS, fitted_vampire, row
from repro.core import estimate_batch, traces
from repro.core.energy_model import trace_energy_vectorized

N_TRACES = 128
ARTIFACT = os.path.join(ARTIFACTS, "BENCH_estimate.json")


def _trace_fleet():
    """>= 32 ragged app traces spanning the synthetic SPEC suite."""
    reps = -(-N_TRACES // len(traces.SPEC_APPS))
    apps = (traces.SPEC_APPS * reps)[:N_TRACES]
    return [traces.app_trace(app, n_requests=140 + 12 * (i % 5))
            for i, app in enumerate(apps)]


def run() -> list[str]:
    model = fitted_vampire()
    vendors = list(model.vendors)
    trs = _trace_fleet()
    n_pairs = len(trs) * len(vendors)

    # warm timings take the min over repeats: this box is shared, and the
    # min is the standard estimator that rejects scheduler contention noise
    # ---- batched: one padded TraceBatch, one dispatch --------------------
    tb = estimate_batch.TraceBatch.from_traces(trs)
    t0 = time.perf_counter()
    jax.block_until_ready(model.estimate(tb, vendors))
    cold_batched_s = time.perf_counter() - t0
    batched_s = float("inf")
    for _ in range(8):
        t0 = time.perf_counter()
        rep = model.estimate(tb, vendors)
        jax.block_until_ready(rep)
        batched_s = min(batched_s, time.perf_counter() - t0)

    # ---- serial: one jitted program per (trace shape, vendor), through
    # the INDEPENDENT per-trace integrator (trace_energy_vectorized), so
    # the agreement assert below still cross-checks the batched engine
    # against a different code path (the pre-batching reference)
    def serial_one(tr, v):
        return trace_energy_vectorized(tr, model.params(v))

    t0 = time.perf_counter()
    for tr in trs:                       # warm every per-shape compile
        for v in vendors:
            serial_one(tr, v)
    cold_serial_s = time.perf_counter() - t0
    serial_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        serial = np.zeros((len(trs), len(vendors)))
        for i, tr in enumerate(trs):
            for j, v in enumerate(vendors):
                serial[i, j] = float(serial_one(tr, v).energy_pj)
        serial_s = min(serial_s, time.perf_counter() - t0)

    # the two paths must agree (the batched engine's acceptance bar)
    np.testing.assert_allclose(np.asarray(rep.energy_pj, np.float64),
                               serial, rtol=2e-6)

    speedup = serial_s / batched_s
    blob = {
        "bench": "estimate",
        "n_traces": len(trs),
        "n_vendors": len(vendors),
        "trace_commands_min": int(min(t.n for t in trs)),
        "trace_commands_max": int(max(t.n for t in trs)),
        "serial_s": serial_s,
        "serial_cold_s": cold_serial_s,
        "batched_s": batched_s,
        "batched_cold_s": cold_batched_s,
        "serial_traces_per_s": len(trs) / serial_s,
        "batched_traces_per_s": len(trs) / batched_s,
        "speedup_warm": speedup,
        "speedup_cold": cold_serial_s / max(cold_batched_s, 1e-9),
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=2)

    return [
        row("estimate.serial", serial_s * 1e6,
            f"pairs={n_pairs};traces_per_s={len(trs)/serial_s:.1f};"
            f"cold_s={cold_serial_s:.1f}"),
        row("estimate.batched", batched_s * 1e6,
            f"pairs={n_pairs};traces_per_s={len(trs)/batched_s:.1f};"
            f"speedup_vs_serial={speedup:.1f}x;"
            f"cold_s={cold_batched_s:.1f};artifact=BENCH_estimate.json"),
    ]
