"""Paper Fig 26 / Section 10: DRAM energy under the four cache-line
encodings, normalized to Baseline. Target: OWI ~ -12.2% mean (up to
-28.6%), Optimized ~ 0, BDI ~ 0."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fitted_vampire, row, timer
from repro.core import encodings, traces


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
        tba = {app.name: traces.app_trace(app, n_requests=1000)
               for app in traces.SPEC_APPS}
        # all apps x 4 encodings x 3 vendors: ONE batched dispatch
        # (vendor-averaged, as in Fig 26)
        study = encodings.encoding_energy_study(tba, model, vendors=range(3))
        ratios = {enc: [study[app][enc] / study[app]["baseline"]
                        for app in tba]
                  for enc in encodings.ENCODINGS}
    paper = {"baseline": (1.0, 1.0), "bdi": (1.0, 1.0),
             "optimized": (1.0, 1.0), "owi": (0.878, 0.714)}
    for enc in encodings.ENCODINGS:
        r = np.array(ratios[enc])
        out.append(row(
            f"encodings.{enc}", t.us / 4,
            f"mean={np.mean(r):.3f};min={np.min(r):.3f};max={np.max(r):.3f};"
            f"paper_mean={paper[enc][0]:.3f};paper_best={paper[enc][1]:.3f}"))
    return out
