"""Paper Fig 26 / Section 10: DRAM energy under the four cache-line
encodings, normalized to Baseline. Target: OWI ~ -12.2% mean (up to
-28.6%), Optimized ~ 0, BDI ~ 0."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fitted_vampire, row, timer
from repro.core import encodings, traces


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
        ratios = {e: [] for e in encodings.ENCODINGS}
        for app in traces.SPEC_APPS:
            tr = traces.app_trace(app, n_requests=1000)
            base = None
            for enc in encodings.ENCODINGS:
                te = encodings.encode_trace(tr, enc)
                # average across vendors, as in Fig 26
                e = float(np.mean([model.estimate(te, v).energy_pj
                                   for v in range(3)]))
                if enc == "baseline":
                    base = e
                ratios[enc].append(e / base)
    paper = {"baseline": (1.0, 1.0), "bdi": (1.0, 1.0),
             "optimized": (1.0, 1.0), "owi": (0.878, 0.714)}
    for enc in encodings.ENCODINGS:
        r = np.array(ratios[enc])
        out.append(row(
            f"encodings.{enc}", t.us / 4,
            f"mean={np.mean(r):.3f};min={np.min(r):.3f};max={np.max(r):.3f};"
            f"paper_mean={paper[enc][0]:.3f};paper_best={paper[enc][1]:.3f}"))
    return out
