"""Paper Fig 25 / Section 9.2: application-level relative error of
DRAMPower vs VAMPIRE over the synthetic SPEC-like workload suite."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fitted_vampire, row, timer
from repro.core import baselines_power, traces


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
        drampower = baselines_power.DRAMPowerModel.from_vampire(model)
        trs = [traces.app_trace(app, n_requests=1200)
               for app in traces.SPEC_APPS]
        intense = {app.name: app.intensity for app in traces.SPEC_APPS}
        # both models over the whole (apps x vendors) grid: one unified-
        # protocol dispatch each
        vamp = np.asarray(model.estimate(trs).energy_pj, np.float64)
        dp = np.asarray(drampower.estimate(trs).energy_pj, np.float64)
        rel = {v: [(app.name, float((dp[i, v] - vamp[i, v]) / vamp[i, v]
                                    * 100))
                   for i, app in enumerate(traces.SPEC_APPS)]
               for v in range(3)}
    paper = {0: 58.3, 1: 45.0, 2: 33.5}
    for v in range(3):
        errs = np.array([abs(e) for _, e in rel[v]])
        worst = max(rel[v], key=lambda kv: abs(kv[1]))
        out.append(row(
            f"apps.drampower_vs_vampire.{'ABC'[v]}", t.us / 3,
            f"mean_rel_err={np.mean(errs):.1f}%;max={worst[1]:.1f}%"
            f"@{worst[0]};paper_mean={paper[v]:.1f}%"))
    # memory-intensive apps are over-estimated more (paper's observation)
    v = 0
    hi = np.mean([abs(e) for n, e in rel[v] if intense[n] > 0.4])
    lo = np.mean([abs(e) for n, e in rel[v] if intense[n] < 0.1])
    out.append(row("apps.intensity_effect.A", t.us / 3,
                   f"memory_bound_err={hi:.1f}%;compute_bound_err={lo:.1f}%"))
    return out
