"""Paper Figs 5-14 / Section 4: measured IDD values vs datasheet.

For every IDD loop and vendor: the per-module measured distribution
(mean/min/max), the measured/datasheet ratio, and the paper's reported
ratio for comparison."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fitted_vampire, row, timer
from repro.core import params as P
from repro.core.characterize import IDD_KEYS


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
    for key in IDD_KEYS:
        for v in range(3):
            vc = model.by_vendor[v]
            meas = vc.idd_measured[key]
            ds = vc.idd_datasheet[key]
            ratio = float(np.mean(meas)) / ds
            paper = P.MEASURED_OVER_DATASHEET[key][v]
            rng = (np.max(meas) - np.min(meas)) / ds
            out.append(row(
                f"idd.{key}.{'ABC'[v]}", t.us / 27,
                f"mean_mA={np.mean(meas):.1f};datasheet_mA={ds:.1f};"
                f"ratio={ratio:.3f};paper_ratio={paper:.3f};"
                f"norm_range={rng:.3f}"))
    # Section 4 frequency-extrapolation goodness of fit
    worst = min(min(vc.idd_extrapolation_r2.values())
                for vc in model.by_vendor.values())
    out.append(row("idd.extrapolation_r2", t.us / 27,
                   f"worst_r2={worst:.4f};paper_worst=0.9783"))
    return out
