"""Paper Figs 5-14 / Section 4: measured IDD values vs datasheet.

For every IDD loop and vendor: the per-module measured distribution
(mean/min/max), the measured/datasheet ratio, and the paper's reported
ratio for comparison — the low-power loops (IDD2P0/IDD3P/IDD6, PR 6)
included.  Emits ``artifacts/BENCH_idd.json`` with hardware-independent
ratio metrics (gated by ``check_bench``): worst frequency-extrapolation
R^2, worst low-power measured-below-datasheet reduction, and the
idle-standby-over-slow-power-down current ratio that makes power-down
scheduling worth anything at all."""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import ARTIFACTS, fitted_vampire, row, timer
from repro.core import params as P
from repro.core.characterize import IDD_KEYS

ARTIFACT = os.path.join(ARTIFACTS, "BENCH_idd.json")

# the background-state LUT keys (paper Fig 14's headline reductions)
LOWPOWER_KEYS = ("IDD2P1", "IDD2P0", "IDD3P", "IDD6")


def run() -> list[str]:
    out = []
    with timer() as t:
        model = fitted_vampire()
    n_rows = len(IDD_KEYS) * 3
    per_key: dict[str, dict[str, dict]] = {}
    for key in IDD_KEYS:
        per_key[key] = {}
        for v in range(3):
            vc = model.by_vendor[v]
            meas = vc.idd_measured[key]
            ds = vc.idd_datasheet[key]
            ratio = float(np.mean(meas)) / ds
            paper = P.MEASURED_OVER_DATASHEET[key][v]
            rng = (np.max(meas) - np.min(meas)) / ds
            per_key[key]["ABC"[v]] = {
                "measured_mean_ma": float(np.mean(meas)),
                "datasheet_ma": float(ds),
                "ratio": ratio,
                "paper_ratio": float(paper),
            }
            out.append(row(
                f"idd.{key}.{'ABC'[v]}", t.us / n_rows,
                f"mean_mA={np.mean(meas):.1f};datasheet_mA={ds:.1f};"
                f"ratio={ratio:.3f};paper_ratio={paper:.3f};"
                f"norm_range={rng:.3f}"))
    # Section 4 frequency-extrapolation goodness of fit
    worst_r2 = min(min(vc.idd_extrapolation_r2.values())
                   for vc in model.by_vendor.values())
    out.append(row("idd.extrapolation_r2", t.us / n_rows,
                   f"worst_r2={worst_r2:.4f};paper_worst=0.9783"))

    # hardware-independent ratios for the regression gate: the measured
    # low-power currents must stay well below datasheet (Fig 14), and
    # idle standby must stay well above slow power-down, or the whole
    # power-down machinery stops mattering
    lowpower_reduction_worst = min(
        1.0 - per_key[k][ab]["ratio"]
        for k in LOWPOWER_KEYS for ab in "ABC")
    idle_over_slow = [
        per_key["IDD2N"][ab]["measured_mean_ma"]
        / per_key["IDD2P0"][ab]["measured_mean_ma"] for ab in "ABC"]
    idle_over_sr = [
        per_key["IDD2N"][ab]["measured_mean_ma"]
        / per_key["IDD6"][ab]["measured_mean_ma"] for ab in "ABC"]
    blob = {
        "keys": list(IDD_KEYS),
        "lowpower_keys": list(LOWPOWER_KEYS),
        "per_key": per_key,
        "ratios": {
            "extrapolation_r2_worst": float(worst_r2),
            "lowpower_reduction_worst": float(lowpower_reduction_worst),
            "idle_over_slow_pdn_worst": float(min(idle_over_slow)),
            "idle_over_self_refresh_worst": float(min(idle_over_sr)),
        },
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=2)
    for name, val in blob["ratios"].items():
        out.append(row(f"idd.{name}", t.us / n_rows, f"value={val:.4f}"))
    return out
