"""Fault tolerance: failure injection, retry-with-restore, stragglers.

At 1000+ node scale the mean time between node failures is minutes-to-hours;
the training driver must treat "a step crashed" as a normal event. The
pattern implemented here (and exercised in tests/examples):

  while step < total:
      try:  step_fn()
      except Fault:  restore_from_checkpoint(); continue

`FaultInjector` simulates hardware faults deterministically at configured
steps (a single process cannot lose a real TPU). `StragglerMonitor` tracks
per-step wall times and flags slow outliers — on a real pod this feeds the
controller that re-shards around slow hosts; here it drives test assertions
and logging.
"""
from __future__ import annotations

import dataclasses
import time


class SimulatedFault(RuntimeError):
    """Stands in for a node loss / ICI timeout / preemption."""


@dataclasses.dataclass
class FaultInjector:
    fail_at_steps: tuple[int, ...] = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFault(f"injected fault at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0        # x median
    window: int = 50
    times: list = dataclasses.field(default_factory=list)
    flagged: list = dataclasses.field(default_factory=list)

    def record(self, step: int, seconds: float):
        self.times.append(seconds)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = sorted(self.times)[len(self.times) // 2]
        if len(self.times) >= 5 and seconds > self.threshold * med:
            self.flagged.append((step, seconds, med))
            return True
        return False


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False
