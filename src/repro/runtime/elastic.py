"""Elastic rescaling: resume a run on a different mesh.

The combination of (a) checkpoint restore with target shardings and (b) the
stateless data pipeline makes rescaling a pure control-plane operation:

1. build the new mesh (fewer/more pods or a different (data, model) split),
2. recompute PartitionSpecs from the same logical rules on the new mesh,
3. restore the latest checkpoint with the new shardings,
4. continue from the stored step (the data pipeline is a function of step).

`reshard_plan` verifies the new mesh divides every parameter the rules
shard — exactly the check a cluster controller runs before committing to a
rescale."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.models.meta import ShardingRules, is_meta, specs_for


def reshard_plan(meta_tree, rules: ShardingRules, new_mesh):
    """Partition specs for the new mesh + a report of axes that had to fall
    back to replication (divisibility)."""
    specs = specs_for(meta_tree, rules, mesh=new_mesh)
    fallbacks = []

    def check(path, m, spec):
        ideal = rules.spec(m)
        if tuple(ideal) != tuple(spec):
            fallbacks.append((jax.tree_util.keystr(path), tuple(ideal),
                              tuple(spec)))

    jax.tree_util.tree_map_with_path(check, meta_tree, specs,
                                     is_leaf=lambda x: is_meta(x))
    return specs, fallbacks


def shardings_from_specs(spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: hasattr(x, "_normalized_spec") or
        type(x).__name__ == "PartitionSpec")
