"""Deterministic, shardable synthetic token pipeline.

Batches are pure functions of (seed, step, shard): every host can generate
exactly its slice of the global batch with no coordination, restarts resume
bit-identically from the step counter (the checkpoint stores only `step`),
and elastic re-sharding is just a different shard decomposition of the same
global batch. Token statistics follow a Zipf-ish unigram mixture so that
embedding-gather traffic and the power model's data-value statistics are
non-degenerate.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2


class SyntheticDataset:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # stationary Zipf unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self._probs = jnp.asarray(p / p.sum(), dtype=jnp.float32)

    def global_batch(self, step: int) -> dict:
        """Full (global_batch, seq_len) batch for one step."""
        return self.shard_batch(step, shard=0, n_shards=1)

    def shard_batch(self, step: int, shard: int, n_shards: int) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        b_loc = cfg.global_batch // n_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(cfg.seed), step), shard)
        toks = jax.random.choice(
            key, cfg.vocab, shape=(b_loc, cfg.seq_len + 1), p=self._probs)
        toks = toks.astype(jnp.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def make_global_array(self, step: int, mesh, pspec) -> dict:
        """Assemble a sharded global batch on a mesh (per-shard generation,
        the multi-host pattern; on one process this is a plain device_put)."""
        from jax.sharding import NamedSharding
        batch = self.global_batch(step)
        sharding = NamedSharding(mesh, pspec)
        return {k: jax.device_put(v, sharding) for k, v in batch.items()}
