"""qwen2-7b [dense] 28L d=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
GQA with QKV bias. [arXiv:2407.10671; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", n_layers=28, d_model=3584, n_heads=28, n_kv=4,
    d_head=128, d_ff=18944, vocab=152064, qkv_bias=True)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
    d_head=16, d_ff=128, vocab=256, qkv_bias=True, attention_block=32)
