"""granite-8b [dense] 36L d=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
Llama-style code model. [arXiv:2405.04324; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", n_layers=36, d_model=4096, n_heads=32, n_kv=8,
    d_head=128, d_ff=14336, vocab=49152)

SMOKE = ModelConfig(
    name="granite-8b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
    d_head=16, d_ff=128, vocab=256, attention_block=32)
