"""mamba2-780m [ssm] 48L d=1536, attention-free SSD (state-space duality),
ssm_state=128, vocab=50280. No MLPs (pure Mamba2 blocks), tied embeddings.
[arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", n_layers=48, d_model=1536, n_heads=24, n_kv=24,
    d_head=64, d_ff=0, vocab=50280, pattern=("mamba",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    tie_embeddings=True, subquadratic=True)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=4,
    d_head=16, d_ff=0, vocab=256, pattern=("mamba",),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8),
    tie_embeddings=True, subquadratic=True, attention_block=32)
