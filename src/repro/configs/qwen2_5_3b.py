"""qwen2.5-3b [dense] 36L d=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
GQA with QKV bias, tied embeddings. [hf:Qwen/Qwen2.5-3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", n_layers=36, d_model=2048, n_heads=16, n_kv=2,
    d_head=128, d_ff=11008, vocab=151936, qkv_bias=True,
    tie_embeddings=True, rope_theta=1_000_000.0)

SMOKE = ModelConfig(
    name="qwen2.5-3b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
    d_head=16, d_ff=128, vocab=256, qkv_bias=True, tie_embeddings=True,
    attention_block=32)
