"""Architecture registry + assigned input shapes.

Every assigned (architecture x shape) cell is enumerable through
``all_cells()``; ``input_specs()`` produces ShapeDtypeStruct stand-ins for
each step function's inputs (no device allocation), which is what the
multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.lm import LM
from repro.models.meta import abstractify

_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "granite-8b": "granite_8b",
    "qwen2-7b": "qwen2_7b",
    "yi-34b": "yi_34b",
    "mamba2-780m": "mamba2_780m",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "whisper-small": "whisper_small",
    "jamba-1.5-large-398b": "jamba15_large_398b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_applicable(arch: str, shape: str) -> bool:
    """long_500k needs sub-quadratic attention: run only for SSM/hybrid
    (see DESIGN.md Arch-applicability)."""
    if shape == "long_500k":
        return get_config(arch).subquadratic
    return True


def all_cells():
    return [(a, s) for a in ARCH_NAMES for s in SHAPES
            if cell_applicable(a, s)]


def skipped_cells():
    return [(a, s) for a in ARCH_NAMES for s in SHAPES
            if not cell_applicable(a, s)]


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; weak-type-correct, shardable)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                batch_override: int | None = None) -> dict:
    """Step-function inputs for the given (arch, shape) cell."""
    b = batch_override or shape.global_batch
    s = shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        specs = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
        if cfg.aux_seq:
            specs["aux"] = _sds((b, cfg.aux_seq, cfg.d_model), dt)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.aux_seq:
            specs["aux"] = _sds((b, cfg.aux_seq, cfg.d_model), dt)
        return specs
    if shape.kind == "decode":
        lm = LM(cfg)
        cache_meta = lm.init_cache_meta(b, s)
        return {"tokens": _sds((b, 1), jnp.int32),
                "caches": abstractify(cache_meta)}
    raise ValueError(shape.kind)
