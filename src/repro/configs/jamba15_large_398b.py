"""jamba-1.5-large-398b [hybrid] 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536; Mamba+attention 1:7 interleave, MoE 16 experts top-2 every
second layer. [arXiv:2403.19887; hf]"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", n_layers=72, d_model=8192, n_heads=64,
    n_kv=8, d_head=128, d_ff=24576, vocab=65536,
    pattern=("mamba", "mamba", "mamba", "mamba", "attn",
             "mamba", "mamba", "mamba"),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, every=2),
    subquadratic=True)

SMOKE = ModelConfig(
    name="jamba-1.5-large-398b-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv=2, d_head=16, d_ff=128, vocab=256,
    pattern=("mamba", "mamba", "mamba", "mamba", "attn",
             "mamba", "mamba", "mamba"),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, every=2),
    subquadratic=True, attention_block=32)
