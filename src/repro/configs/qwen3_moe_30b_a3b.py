"""qwen3-moe-30b-a3b [moe] 48L d=2048 32H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, expert d_ff=768, no shared experts.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
    n_kv=4, d_head=128, d_ff=768, vocab=151936,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
    rope_theta=1_000_000.0)

SMOKE = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv=2, d_head=16, d_ff=64, vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
    attention_block=32)
