"""yi-34b [dense] 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Llama-arch GQA. [arXiv:2403.04652; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv=8,
    d_head=128, d_ff=20480, vocab=64000, rope_theta=5_000_000.0)

SMOKE = ModelConfig(
    name="yi-34b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
    d_head=16, d_ff=128, vocab=256, attention_block=32)
