"""deepseek-v2-lite-16b [moe] 27L d=2048 16H, MLA (kv_lora=512),
MoE 64 routed experts top-6 + 2 shared, expert d_ff=1408, vocab=102400.
(The real model's first layer is a dense MLP; we make all 27 MoE for
uniform layer stacking — noted in DESIGN.md.) [arXiv:2405.04434; hf]"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16,
    n_kv=16, d_head=192, d_ff=1408, vocab=102400, attn_kind="mla",
    mla=MLAConfig(kv_lora=512, d_nope=128, d_rope=64, d_v=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2))

SMOKE = ModelConfig(
    name="deepseek-v2-lite-16b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv=4, d_head=48, d_ff=64, vocab=256, attn_kind="mla",
    mla=MLAConfig(kv_lora=32, d_nope=32, d_rope=16, d_v=32),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, n_shared=1),
    attention_block=32)
