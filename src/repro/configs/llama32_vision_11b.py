"""llama-3.2-vision-11b [vlm] 40L d=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers every 5th layer. The vision
tower is a STUB: input_specs() provides precomputed patch embeddings
(B, 1601, d_model). [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", n_layers=40, d_model=4096, n_heads=32,
    n_kv=8, d_head=128, d_ff=14336, vocab=128256,
    pattern=("attn", "attn", "attn", "xattn", "attn"),
    aux_seq=1601, rope_theta=500_000.0)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke", n_layers=5, d_model=64, n_heads=4,
    n_kv=2, d_head=16, d_ff=128, vocab=256,
    pattern=("attn", "attn", "attn", "xattn", "attn"), aux_seq=16,
    attention_block=32)
