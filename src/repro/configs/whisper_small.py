"""whisper-small [audio] enc-dec, 12L encoder + 12L decoder, d=768 12H
d_ff=3072 vocab=51865. The conv/mel frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, 1500, d). RoPE substitutes for
learned positions (noted in DESIGN.md). [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", n_layers=12, d_model=768, n_heads=12, n_kv=12,
    d_head=64, d_ff=3072, vocab=51865, n_encoder_layers=12, aux_seq=1500,
    rope_theta=10_000.0)

SMOKE = ModelConfig(
    name="whisper-small-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=4,
    d_head=16, d_ff=128, vocab=256, n_encoder_layers=2, aux_seq=16,
    attention_block=32)
