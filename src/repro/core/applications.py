"""The paper's Section 9.3 example applications, implemented.

The paper names three uses VAMPIRE enables; Section 10 develops the third
(data encodings — see `encodings.py`). This module implements the first two:

1. **Variation-aware physical page allocation**: using the fitted
   structural model (per-bank idle/read factors, row-address-ones
   activation slope), place frequently-accessed pages in the
   cheapest (bank, row) locations and quantify the energy saved vs. a
   variation-oblivious allocator.

2. **Power-down scheduling**: from the fitted idle / power-down currents
   and entry/exit overheads, derive the break-even idle time per vendor
   and evaluate a timeout-based low-power policy on application traces —
   picking among fast power-down, slow power-down (DLL off), and
   self-refresh per idle-gap length (the deepest state whose exit
   latency the gap can absorb).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import dram, traces
from repro.core.dram import PDE, PDX, PRE, PREA, NOP, RD, WR, ACT, TIMING
from repro.core.energy_model import PowerParams

_T = TIMING


# ---------------------------------------------------------------------------
# 1. Variation-aware page allocation
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PagePlan:
    bank_order: np.ndarray      # banks sorted cheapest-first for reads
    row_classes: np.ndarray     # row-address popcount per candidate row
    est_saving_frac: float


def rank_banks_for_reads(pp: PowerParams) -> np.ndarray:
    """Banks sorted by (read factor, idle increment): the allocator targets
    read-heavy hot pages, then open-page residency cost."""
    rf = np.asarray(pp.bank_read_factor)
    idle = np.asarray(pp.bank_open_delta)
    score = rf + idle / max(float(np.max(idle)), 1e-9) * 0.01
    return np.argsort(score)


def cheap_rows(n_rows: int, total_rows: int = 1 << dram.ROW_BITS
               ) -> np.ndarray:
    """Rows sorted by address popcount (activation energy grows with it)."""
    rows = np.arange(total_rows, dtype=np.int64)
    pops = np.zeros(total_rows, dtype=np.int16)
    for b in range(dram.ROW_BITS):
        pops += ((rows >> b) & 1).astype(np.int16)
    order = np.argsort(pops, kind="stable")
    return rows[order[:n_rows]]


def remap_trace(trace, pp: PowerParams, hot_frac: float = 0.25):
    """Re-map the hottest (bank,row) pages of a trace onto the cheapest
    banks/rows per the structural model. Returns the re-mapped trace.

    The remap is a pure address transformation (data untouched): exactly
    what an OS page allocator could do with VAMPIRE's structural tables.
    """
    cmd = np.asarray(trace.cmd)
    bank = np.asarray(trace.bank).copy()
    row = np.asarray(trace.row).copy()

    rw = (cmd == RD) | (cmd == WR) | (cmd == ACT)
    pages, counts = np.unique(
        np.stack([bank[rw], row[rw]], axis=1), axis=0, return_counts=True)
    hot_idx = np.argsort(-counts)[:max(1, int(len(pages) * hot_frac))]
    hot_pages = pages[hot_idx]

    bank_order = rank_banks_for_reads(pp)
    target_rows = cheap_rows(len(hot_pages))
    mapping = {}
    for i, (b, r) in enumerate(hot_pages):
        nb = int(bank_order[i % len(bank_order)])
        nr = int(target_rows[i])
        mapping[(int(b), int(r))] = (nb, nr)

    # apply; non-hot pages keep their location (collisions with relocated
    # hot rows are acceptable for the study: same row ids in other banks)
    for i in range(len(cmd)):
        key = (int(bank[i]), int(row[i]))
        if key in mapping:
            bank[i], row[i] = mapping[key]

    import jax.numpy as jnp
    return trace._replace(bank=jnp.asarray(bank, jnp.int32),
                          row=jnp.asarray(row, jnp.int32))


def page_allocation_study(model, app: traces.AppSpec, vendor: int,
                          n_requests: int = 800) -> dict:
    tr = traces.app_trace(app, n_requests=n_requests)
    remapped = remap_trace(tr, model.params(vendor))
    # both variants through one unified-protocol dispatch
    energy = np.asarray(
        model.estimate([tr, remapped], (vendor,)).energy_pj, np.float64)
    base, opt = float(energy[0, 0]), float(energy[1, 0])
    return {"app": app.name, "vendor": "ABC"[vendor],
            "baseline_pj": base, "remapped_pj": opt,
            "saving_frac": 1 - opt / base}


# ---------------------------------------------------------------------------
# 2. Power-down scheduling
# ---------------------------------------------------------------------------
def breakeven_idle_cycles(pp: PowerParams) -> float:
    """Idle cycles beyond which entering fast power-down wins.

    Cost of powering down: the PRE-all + PDE/PDX overhead cycles spent at
    i2n plus losing the open rows (one extra ACT on resume, amortized
    pessimistically as one full activate charge). Benefit: (i2n - i_pd)
    per idle cycle.
    """
    i2n = float(pp.i2n)
    i_pd = float(pp.i_pd)
    overhead_cycles = _T.tRP + _T.tCKE + _T.tXP
    overhead_charge = overhead_cycles * i2n + float(pp.q_actpre)
    per_cycle_gain = max(i2n - i_pd, 1e-6)
    return overhead_charge / per_cycle_gain


# the resume penalty must stay small next to the idle it prices: a gap
# qualifies for a state only when it is this many exit latencies long
IDLE_EXIT_HEADROOM = 8


def select_idle_state(gap_cycles: int):
    """The deepest low-power state whose exit latency the gap can absorb
    (performance-neutral rule).  Returns (entry_cmd, exit_cmd,
    exit_cycles): self-refresh for long gaps, slow power-down (DLL off)
    for medium ones, fast power-down otherwise."""
    if gap_cycles >= IDLE_EXIT_HEADROOM * _T.tXS:
        return dram.SRE, dram.SRX, _T.tXS
    if gap_cycles >= IDLE_EXIT_HEADROOM * _T.tXPDLL:
        return dram.PDE_SLOW, PDX, _T.tXPDLL
    return PDE, PDX, _T.tXP


_ENTRY_CMDS = (PDE, dram.PDE_SLOW, dram.SRE)


def apply_powerdown_policy(trace, timeout_cycles: int):
    """Insert {PREA, entry, NOP-dwell, exit} into idle gaps >= timeout (a
    classic timeout policy), picking the low-power state per gap length
    via :func:`select_idle_state`; gaps already powered down are left
    untouched.

    The rewrite goes through :class:`traces.TraceBuilder`, so the inserted
    PREA lands only once tRAS/tWR allow it and accesses to banks a window
    closed lazily re-activate first; when the trace carries refreshes they
    are re-placed afterwards (windows push the original schedule past
    tREFI), and the result is protocol-linted."""
    cmd = np.asarray(trace.cmd).tolist()
    bank = np.asarray(trace.bank).tolist()
    row = np.asarray(trace.row).tolist()
    col = np.asarray(trace.col).tolist()
    dt = np.asarray(trace.dt).tolist()
    data = np.asarray(trace.data)

    bld = traces.TraceBuilder(pad_nop=True)
    n = len(cmd)
    in_lp = False  # inside a low-power window the trace already has
    for i in range(n):
        c = cmd[i]
        b = bank[i]
        r = row[i]
        if c in _ENTRY_CMDS:
            in_lp = True
        elif c in (PDX, dram.SRX):
            in_lp = False
        if c in (RD, WR):
            # an inserted window may have closed this bank since the
            # original schedule opened it
            bld.require_open(b, r)
        if c == ACT:
            if bld.open_row[b] == r:
                continue  # a lazy re-activation already opened it
            if bld.open_row[b] >= 0:
                bld.emit(PRE, b, dt=_T.tRP)
        gap = dt[i] - (_T.tBURST if c in (RD, WR) else 0)
        if not in_lp and c in (RD, WR, NOP) and gap >= timeout_cycles \
                and (i + 1 >= n or cmd[i + 1] not in _ENTRY_CMDS):
            # truncate this slot to its busy part, spend the gap in the
            # selected state: entry bills powered-up, the dwell rides a
            # NOP slot, the exit slot is the last billed at low power
            entry, exit_cmd, exit_dt = select_idle_state(gap)
            busy = dt[i] - gap
            dwell = max(gap - _T.tRP - _T.tCKE - exit_dt, 1)
            bld.emit(c, b, r, col[i], data[i], max(busy, 1))
            bld.emit(PREA, dt=_T.tRP)
            bld.emit(entry, dt=_T.tCKE)
            bld.emit(NOP, dt=dwell)
            bld.emit(exit_cmd, dt=exit_dt)
        else:
            bld.emit(c, b, r, col[i], data[i], dt[i])

    if any(c == dram.REF for c in cmd):
        # the windows stretched wall-clock time between the original
        # refreshes: rebuild the refresh schedule (lints its output)
        return traces.reschedule_refresh(bld.build())
    return bld.build("applications.apply_powerdown_policy")


def powerdown_study(model, app: traces.AppSpec, vendor: int,
                    n_requests: int = 800) -> dict:
    """Evaluate the VAMPIRE-derived break-even timeout vs. naive timeouts.

    NOTE: energies are compared at equal work; the PD trace is longer in
    wall-clock (exit latencies), which the paper's second example is
    precisely about pricing correctly.
    """
    pp = model.params(vendor)
    be = breakeven_idle_cycles(pp)
    tr = traces.app_trace(app, n_requests=n_requests)
    policies = (("aggressive", max(int(be * 0.25), 8)),
                ("breakeven", max(int(be), 8)),
                ("lazy", max(int(be * 8), 8)))
    # the baseline and every policy variant in ONE batched dispatch
    variants = [tr] + [apply_powerdown_policy(tr, timeout)
                       for _, timeout in policies]
    energy = np.asarray(
        model.estimate(variants, (vendor,)).energy_pj, np.float64)[:, 0]
    base = float(energy[0])
    results = {"app": app.name, "vendor": "ABC"[vendor],
               "breakeven_cycles": be, "baseline_pj": base}
    for (name, _), var, e in zip(policies, variants[1:], energy[1:]):
        results[f"{name}_pj"] = float(e)
        results[f"{name}_saving"] = 1 - float(e) / base
        c = np.asarray(var.cmd)
        results[f"{name}_modes"] = {
            "fast": int((c == PDE).sum()),
            "slow": int((c == dram.PDE_SLOW).sum()),
            "sr": int((c == dram.SRE).sum())}
    return results
