"""Cache-line data encodings (paper Section 10).

Four encodings applied to line data before it is written to DRAM:

* ``baseline``  — identity.
* ``bdi``       — Base-Delta-Immediate compression [127]: the encoded line is
  the packed (base, deltas) representation padded with zeros; incompressible
  lines pass through unchanged.
* ``optimized`` — per-application byte-frequency LUT: the most frequent byte
  values get the codes with the fewest ones (code assignment sorted by
  (popcount, value)). Lowers read power (read current grows with ones).
* ``owi``       — Optimized-with-Write-Inversion: stored cells hold the
  Optimized encoding; the bus carries its bitwise complement on *writes*
  (write current falls with ones), the plain encoding on reads.

Each encoding provides ``encode_lines`` (numpy, offline trace transform) and
an energy-evaluation entry point that rewrites a trace's RD/WR data and adds
the one-cycle LUT latency for optimized/owi (Section 10.1).
"""
from __future__ import annotations

import numpy as np

from repro.core import dram
from repro.core.dram import RD, WR, CommandTrace, LINE_BYTES, LINE_WORDS

ENCODINGS = ("baseline", "bdi", "optimized", "owi")


# ---------------------------------------------------------------------------
# byte <-> word helpers (numpy, vectorized over lines)
# ---------------------------------------------------------------------------
def words_to_bytes(lines: np.ndarray) -> np.ndarray:
    """(n, 16) uint32 -> (n, 64) uint8."""
    lines = np.asarray(lines, dtype=np.uint32)
    out = np.empty(lines.shape[:-1] + (LINE_BYTES,), dtype=np.uint8)
    for i in range(4):
        out[..., i::4] = (lines >> (8 * i)) & 0xFF
    return out


def bytes_to_words(b: np.ndarray) -> np.ndarray:
    """(n, 64) uint8 -> (n, 16) uint32."""
    b = np.asarray(b, dtype=np.uint32)
    return (b[..., 0::4] | (b[..., 1::4] << 8) | (b[..., 2::4] << 16)
            | (b[..., 3::4] << 24)).astype(np.uint32)


def byte_histogram(lines: np.ndarray) -> np.ndarray:
    return np.bincount(words_to_bytes(lines).reshape(-1), minlength=256)


# ---------------------------------------------------------------------------
# Optimized / OWI
# ---------------------------------------------------------------------------
def popcount_sorted_codes() -> np.ndarray:
    """All byte values sorted by (popcount, value): the code alphabet."""
    vals = np.arange(256)
    pc = np.array([bin(v).count("1") for v in range(256)])
    return vals[np.lexsort((vals, pc))].astype(np.uint8)


def optimized_lut(hist: np.ndarray) -> np.ndarray:
    """byte value -> encoded byte, most frequent value gets fewest ones."""
    order = np.argsort(-np.asarray(hist), kind="stable")  # freq desc
    codes = popcount_sorted_codes()
    lut = np.empty(256, dtype=np.uint8)
    lut[order] = codes
    return lut


def apply_lut(lines: np.ndarray, lut: np.ndarray) -> np.ndarray:
    return bytes_to_words(np.asarray(lut)[words_to_bytes(lines)])


def invert_lines(lines: np.ndarray) -> np.ndarray:
    return (~np.asarray(lines, dtype=np.uint32)).astype(np.uint32)


# ---------------------------------------------------------------------------
# BDI (Base-Delta-Immediate) [127]
# schemes evaluated per 64 B line, smallest encoded size wins:
#   zeros(1B) | rep8(8B) | b8d1(16B) | b8d2(24B) | b8d4(40B)
#   | b4d1(20B) | b4d2(36B) | b2d1(34B) | raw(64B)
# ---------------------------------------------------------------------------
def _fits(deltas: np.ndarray, nbytes: int) -> np.ndarray:
    lim = 1 << (8 * nbytes - 1)
    return np.all((deltas >= -lim) & (deltas < lim), axis=-1)


def bdi_encode_lines(lines: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Encode each line with the best BDI scheme.

    Returns (encoded_lines (n,16) uint32, encoded_size_bytes (n,) int32).
    The encoded line is the compressed representation packed at the start
    and zero padding after (what would sit on the bus / in the cells).
    """
    lines = np.asarray(lines, dtype=np.uint32)
    n = lines.shape[0]
    by = words_to_bytes(lines)                       # (n, 64)
    best = np.full(n, 64, dtype=np.int32)
    out = by.copy()

    def consider(mask, size, encoded_bytes):
        nonlocal best, out
        mask = mask & (size < best)
        if not np.any(mask):
            return
        buf = np.zeros((int(mask.sum()), LINE_BYTES), dtype=np.uint8)
        eb = encoded_bytes[mask]
        buf[:, :eb.shape[1]] = eb
        out[mask] = buf
        best[mask] = size

    # all-zeros
    consider(np.all(by == 0, axis=1), 1, np.zeros((n, 1), dtype=np.uint8))

    for base_bytes, delta_bytes in ((8, 1), (8, 2), (8, 4),
                                    (4, 1), (4, 2), (2, 1)):
        k = LINE_BYTES // base_bytes
        vals = np.zeros((n, k), dtype=np.int64)
        for i in range(base_bytes):
            vals |= by[:, i::base_bytes].astype(np.int64) << (8 * i)
        # interpret as signed for delta arithmetic
        sign = np.int64(1) << (8 * base_bytes - 1)
        if base_bytes < 8:
            vals = (vals ^ sign) - sign
        base = vals[:, :1]
        deltas = vals - base
        ok = _fits(deltas, delta_bytes)
        size = base_bytes + k * delta_bytes
        # also the repeated-value special case (all deltas zero)
        rep = np.all(deltas == 0, axis=1)
        enc = np.zeros((n, size), dtype=np.uint8)
        for i in range(base_bytes):
            enc[:, i] = (base[:, 0] >> (8 * i)) & 0xFF
        d = deltas.astype(np.int64)
        for j in range(k):
            for i in range(delta_bytes):
                enc[:, base_bytes + j * delta_bytes + i] = (
                    (d[:, j] >> (8 * i)) & 0xFF)
        consider(rep, base_bytes,
                 enc[:, :base_bytes].reshape(n, base_bytes))
        consider(ok & ~rep, size, enc)

    return bytes_to_words(out), best


# ---------------------------------------------------------------------------
# Trace-level application
# ---------------------------------------------------------------------------
def encode_trace(trace: CommandTrace, encoding: str,
                 lut: np.ndarray | None = None,
                 conform_refresh: bool = True) -> CommandTrace:
    """Rewrite RD/WR data per the encoding; optimized/owi add one cycle of
    LUT latency to every RD/WR (Section 10.1).

    The added LUT cycles stretch the trace, which would silently push the
    refreshes ``traces.app_trace`` scheduled past the tREFI deadline (the
    same deadline-accounting bug class PR 1 fixed inside ``app_trace``), so
    by default the refresh schedule is recomputed afterwards
    (``traces.reschedule_refresh``); ``conform_refresh=False`` keeps the
    raw stretched trace for slot-by-slot comparisons."""
    if encoding == "baseline":
        return trace
    cmd = np.asarray(trace.cmd)
    data = np.asarray(trace.data, dtype=np.uint32).copy()
    dt = np.asarray(trace.dt).copy()
    is_rw = (cmd == RD) | (cmd == WR)
    lut_latency = False

    if encoding == "bdi":
        data[is_rw], _ = bdi_encode_lines(data[is_rw])
    elif encoding in ("optimized", "owi"):
        if lut is None:
            lut = optimized_lut(byte_histogram(data[is_rw]))
        enc = apply_lut(data[is_rw], lut)
        if encoding == "owi":
            wr_mask = cmd[is_rw] == WR
            enc[wr_mask] = invert_lines(enc[wr_mask])
        data[is_rw] = enc
        dt[is_rw] = dt[is_rw] + 1  # LUT adds one DRAM cycle
        lut_latency = True
    else:
        raise ValueError(encoding)

    import jax.numpy as jnp
    out = trace._replace(data=jnp.asarray(data),
                         dt=jnp.asarray(dt, dtype=jnp.int32))
    if lut_latency and conform_refresh:
        from repro.core import traces as traces_lib
        from repro.analysis import trace_lint
        out = traces_lib.reschedule_refresh(out)
        trace_lint.check_generated(out, "encodings.encode_trace")
    return out


def encoding_energy_study(traces_by_app: dict[str, CommandTrace],
                          model, vendors=None
                          ) -> dict[str, dict[str, float]]:
    """Total DRAM energy (pJ) of every (app, encoding) pair, averaged over
    ``vendors``, scored in ONE batched dispatch.

    ``model`` is any estimator implementing the unified protocol
    (``repro.core.model_api``).  All ``len(traces_by_app) x 4`` encoded
    traces are padded into a single ``estimate_batch.TraceBatch`` and the
    full (traces x vendors) report matrix comes from one ``model.estimate``
    call — the per-pair Python-loop version dispatched (and compiled) one
    JAX program per (app, encoding, vendor) triple."""
    vendors = list(model.vendors) if vendors is None else list(vendors)
    apps = list(traces_by_app)
    encoded = [encode_trace(traces_by_app[app], enc)
               for app in apps for enc in ENCODINGS]
    rep = model.estimate(encoded, vendors)
    energy = np.asarray(rep.energy_pj, dtype=np.float64).mean(axis=1)
    energy = energy.reshape(len(apps), len(ENCODINGS))
    return {app: {enc: float(energy[i, j])
                  for j, enc in enumerate(ENCODINGS)}
            for i, app in enumerate(apps)}
