"""Command-trace generators: JEDEC IDD measurement loops (Section 4) and the
paper's custom characterization microbenchmarks (Sections 5-7, 9.1).

Each generator returns a :class:`CommandTrace` representing the steady-state
loop, already tiled enough times that loop-edge effects are negligible —
mirroring the paper's modified-SoftMC continuous looping (Section 3.1).
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core import dram
from repro.core.dram import (ACT, PRE, PREA, RD, WR, REF, PDE, PDX,
                             PDE_SLOW, SRE, SRX, NOP,
                             CommandTrace, TIMING, line_from_byte,
                             line_with_n_ones, make_trace, tile_trace)

_T = TIMING
DEFAULT_REPS = 64
IDLE_SLOT = 512  # cycles of NOP used for idle loops


def _lints(fn):
    """Run the protocol linter on the generated loop (strict): a JEDEC
    measurement loop that violates the very timings it measures would
    measure the wrong thing.  Generators that return ``(trace, skip)``
    tuples lint the trace element; ``REPRO_TRACE_LINT=off`` disables."""
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        out = fn(*args, **kwargs)
        # CommandTrace is itself a NamedTuple: check for it first, then for
        # the (trace, skip) tuple convention of the sweep-point generators
        trace = out if isinstance(out, CommandTrace) else out[0]
        from repro.analysis import trace_lint
        trace_lint.check_generated(trace, f"idd_loops.{fn.__name__}")
        return out
    return wrapper


def _loop(cmds, banks, rows, cols, datas, dts, reps=DEFAULT_REPS):
    tr = make_trace(cmds, banks, rows, cols,
                    np.stack([np.asarray(d, dtype=np.uint32) for d in datas]),
                    dts)
    return tile_trace(tr, reps)


_Z = np.zeros(dram.LINE_WORDS, dtype=np.uint32)


# ---------------------------------------------------------------------------
# JEDEC IDD loops
# ---------------------------------------------------------------------------
@_lints
def idd2n(reps=4) -> CommandTrace:
    """Idle, all banks precharged."""
    return _loop([PREA, NOP], [0, 0], [0, 0], [0, 0], [_Z, _Z],
                 [_T.tRP, IDLE_SLOT], reps)


@_lints
def idd3n(reps=4) -> CommandTrace:
    """Idle, all banks open (activate all 8 once, then idle).

    The activates are a one-shot setup prefix, not part of the tiled loop
    body: re-issuing ACT to a bank that is already open is protocol-illegal
    (the linter's BANK_ACT_OPEN rule), so only the NOP dwell repeats."""
    setup = make_trace([ACT] * 8, list(range(8)), [0] * 8, [0] * 8,
                       np.stack([_Z] * 8), [_T.tRC] * 8)
    loop = _loop([NOP], [0], [0], [0], [_Z], [IDLE_SLOT * 8], reps)
    return dram.concat_traces(setup, loop)


@_lints
def idd0(reps=DEFAULT_REPS, bank=0, row=0) -> CommandTrace:
    """Repeated ACT/PRE to one bank at tRC."""
    return _loop([ACT, PRE], [bank] * 2, [row] * 2, [0, 0], [_Z, _Z],
                 [_T.tRAS, _T.tRP], reps)


@_lints
def idd1(reps=DEFAULT_REPS, data=None) -> CommandTrace:
    """Repeated ACT/RD/PRE to one bank at tRC (JEDEC pattern 0x00)."""
    d = line_from_byte(0x00) if data is None else data
    return _loop([ACT, RD, PRE], [0] * 3, [0] * 3, [0, 0, 0], [_Z, d, _Z],
                 [_T.tRCD, _T.tRAS - _T.tRCD, _T.tRP], reps)


def _all_banks_open_prefix():
    cmds = [ACT] * 8
    return (cmds, list(range(8)), [0] * 8, [0] * 8, [_Z] * 8, [_T.tRC] * 8)


@_lints
def idd4r(reps=DEFAULT_REPS, data=None) -> CommandTrace:
    """Back-to-back reads across all 8 banks (JEDEC pattern 0x33)."""
    d = line_from_byte(0x33) if data is None else data
    pc, pb, pr, pcol, pd_, pdt = _all_banks_open_prefix()
    cmds, banks, cols, datas, dts = [], [], [], [], []
    for i in range(16):  # two sweeps over banks, alternating column
        cmds.append(RD)
        banks.append(i % 8)
        cols.append(i // 8)
        datas.append(d)
        dts.append(_T.tCCD)
    setup = make_trace(pc, pb, pr, pcol, np.stack(pd_), pdt)
    loop = _loop(cmds, banks, [0] * 16, cols, datas, dts, reps)
    return dram.concat_traces(setup, loop)


@_lints
def idd4w(reps=DEFAULT_REPS, data=None) -> CommandTrace:
    d = line_from_byte(0x33) if data is None else data
    pc, pb, pr, pcol, pd_, pdt = _all_banks_open_prefix()
    cmds, banks, cols, datas, dts = [], [], [], [], []
    for i in range(16):
        cmds.append(WR)
        banks.append(i % 8)
        cols.append(i // 8)
        datas.append(d)
        dts.append(_T.tCCD)
    setup = make_trace(pc, pb, pr, pcol, np.stack(pd_), pdt)
    loop = _loop(cmds, banks, [0] * 16, cols, datas, dts, reps)
    return dram.concat_traces(setup, loop)


@_lints
def idd7(reps=DEFAULT_REPS, data=None) -> CommandTrace:
    """Interleaved {ACT, RD, auto-PRE} across all 8 banks at max rate.

    Each bank's precharge is deferred by two bank slots — it rides as a
    zero-width command just before ACT(b+2), which puts it at ACT(b)+20 and
    clears tRAS=14 (precharging right after the read, at ACT+10, is what
    the linter's tRAS rule flags in the naive schedule).  The final read
    slot is stretched by 4 cycles so the last two banks' wrap-around
    precharges also clear tRAS, giving an 84-cycle steady-state period."""
    d = line_from_byte(0x33) if data is None else data
    cmds, banks, rows, cols, datas, dts = [], [], [], [], [], []
    for b in range(8):
        if b >= 2:
            cmds.append(PRE); banks.append(b - 2); rows.append(0)
            cols.append(0); datas.append(_Z); dts.append(0)
        cmds += [ACT, RD]
        banks += [b] * 2
        rows += [0] * 2
        cols += [0] * 2
        datas += [_Z, d]
        dts += [_T.tRCD, _T.tCCD if b < 7 else _T.tCCD + 4]
    for b in (6, 7):
        cmds.append(PRE); banks.append(b); rows.append(0)
        cols.append(0); datas.append(_Z); dts.append(0)
    return _loop(cmds, banks, rows, cols, datas, dts, reps)


@_lints
def idd5b(reps=16) -> CommandTrace:
    """Continuous refresh bursts (banks already precharged)."""
    return _loop([REF], [0], [0], [0], [_Z], [_T.tRFC], reps)


@_lints
def idd2p1(reps=4) -> CommandTrace:
    """Fast power-down, no banks active."""
    return _loop([PREA, PDE, NOP], [0] * 3, [0] * 3, [0] * 3, [_Z] * 3,
                 [_T.tRP, _T.tCKE, IDLE_SLOT * 4], reps)


@_lints
def idd2p0(reps=4) -> CommandTrace:
    """Slow power-down (DLL off), no banks active."""
    return _loop([PREA, PDE_SLOW, NOP], [0] * 3, [0] * 3, [0] * 3, [_Z] * 3,
                 [_T.tRP, _T.tCKE, IDLE_SLOT * 4], reps)


@_lints
def idd3p(reps=4) -> CommandTrace:
    """Active power-down: bank 0 open at entry, exit through PDX + PREA
    (ACT is illegal during power-down, so the loop must leave the
    power-down state before re-activating on the next repetition)."""
    return _loop([ACT, PDE, NOP, PDX, PREA], [0] * 5, [0] * 5, [0] * 5,
                 [_Z] * 5,
                 [_T.tRCD, _T.tCKE, IDLE_SLOT * 8, _T.tXP, _T.tRP], reps)


@_lints
def idd6(reps=4) -> CommandTrace:
    """Self-refresh: all banks precharged, long dwell, tXS exit."""
    return _loop([PREA, SRE, NOP, SRX], [0] * 4, [0] * 4, [0] * 4, [_Z] * 4,
                 [_T.tRP, _T.tCKE, IDLE_SLOT * 8, _T.tXS], reps)


# NOTE: new keys are appended at the END so existing campaign probe-key
# indices (and hence the seeded measurement-noise stream) stay stable.
IDD_LOOPS = {
    "IDD2N": idd2n, "IDD3N": idd3n, "IDD0": idd0, "IDD1": idd1,
    "IDD4R": idd4r, "IDD4W": idd4w, "IDD7": idd7, "IDD5B": idd5b,
    "IDD2P1": idd2p1,
    "IDD2P0": idd2p0, "IDD3P": idd3p, "IDD6": idd6,
}


# ---------------------------------------------------------------------------
# Section 5.1 — number-of-ones sweeps (single bank, single row, single col)
# ---------------------------------------------------------------------------
@_lints
def ones_sweep_point(n_ones: int, op: int = RD, reps=DEFAULT_REPS,
                     bank=0, row=0) -> CommandTrace:
    d = line_with_n_ones(n_ones)
    setup = make_trace([ACT], [bank], [row], [0], np.stack([_Z]), [_T.tRCD])
    loop = _loop([op] * 4, [bank] * 4, [row] * 4, [0] * 4, [d] * 4,
                 [_T.tCCD] * 4, reps)
    return dram.concat_traces(setup, loop), 2  # skip setup + first access


# ---------------------------------------------------------------------------
# Section 5.2 — interleaving / toggle tests
# ---------------------------------------------------------------------------
@_lints
def interleave_sweep_point(data_a, data_b, il: str, op: int = RD,
                           reps=DEFAULT_REPS) -> CommandTrace:
    """Alternate between two data values with the given interleaving kind:
    'none' (same bank+col), 'col', 'bank', 'bankcol'.

    For 'bankcol' each bank's column must change between its visits (else
    back-to-back accesses classify as plain bank interleaving), so the loop
    touches (b0,c0),(b1,c2),(b0,c1),(b1,c3).
    """
    data_a = np.asarray(data_a, dtype=np.uint32)
    data_b = np.asarray(data_b, dtype=np.uint32)
    if il == "none":
        banks, cols, datas = [0, 0], [0, 0], [data_a, data_a]
    elif il == "col":
        banks, cols, datas = [0, 0], [0, 1], [data_a, data_b]
    elif il == "bank":
        banks, cols, datas = [0, 1], [0, 0], [data_a, data_b]
    elif il == "bankcol":
        banks, cols = [0, 1, 0, 1], [0, 2, 1, 3]
        datas = [data_a, data_b, data_a, data_b]
    else:
        raise ValueError(il)
    n_banks_used = max(banks) + 1
    setup = make_trace([ACT] * n_banks_used, list(range(n_banks_used)),
                       [0] * n_banks_used, [0] * n_banks_used,
                       np.stack([_Z] * n_banks_used), [_T.tRC] * n_banks_used)
    # Pre-touch each (bank, col) once so per-bank last-column state is primed
    # and the steady-state loop classifies with the intended mode.
    prime = make_trace([op] * len(banks), banks, [0] * len(banks), cols,
                       np.stack(datas), [_T.tCCD] * len(banks))
    k = len(banks)
    loop = _loop([op] * (2 * k), banks * 2, [0] * (2 * k), cols * 2,
                 datas * 2, [_T.tCCD] * (2 * k), reps)
    skip = n_banks_used + len(banks)
    return dram.concat_traces(setup, prime, loop), skip


# ---------------------------------------------------------------------------
# Section 6 — structural variation probes
# ---------------------------------------------------------------------------
@_lints
def bank_idle_probe(bank: int, reps=4) -> CommandTrace:
    """One bank open (row 0, all-zero data), idle."""
    setup = make_trace([PREA, ACT], [0, bank], [0, 0], [0, 0],
                       np.stack([_Z, _Z]), [_T.tRP, _T.tRCD])
    loop = _loop([NOP], [bank], [0], [0], [_Z], [IDLE_SLOT * 4], reps)
    return dram.concat_traces(setup, loop), 2


def bank_read_probe(bank: int, op: int = RD, reps=DEFAULT_REPS) -> CommandTrace:
    return ones_sweep_point(0, op=op, reps=reps, bank=bank)


def row_act_probe(row: int, reps=DEFAULT_REPS):
    """IDD0-style ACT/PRE loop on a specific row (Section 6.1.2)."""
    return idd0(reps=reps, row=row), 0


def surface_act_probe(bank: int, row: int, reps=DEFAULT_REPS):
    """ACT/PRE loop on one (bank, row) — the structural-variation surface
    campaign's probe (Section 6 / Figs 19-22): the caller picks rows of
    equal address popcount across row bands, so cell-to-cell current
    differences isolate the per-(bank, row-band) surface factor."""
    return idd0(reps=reps, bank=bank, row=row), 0


@_lints
def column_read_probe(col: int, reps=DEFAULT_REPS) -> CommandTrace:
    d = line_from_byte(0x00)
    setup = make_trace([ACT], [0], [0], [col], np.stack([_Z]), [_T.tRCD])
    loop = _loop([RD] * 4, [0] * 4, [0] * 4, [col] * 4, [d] * 4,
                 [_T.tCCD] * 4, reps)
    return dram.concat_traces(setup, loop), 2


# ---------------------------------------------------------------------------
# Section 9.1 — validation workload {ACT, n x RD, PRE}
# ---------------------------------------------------------------------------
@_lints
def validation_sweep(n_reads: int, reps=8, byte=0xAA) -> CommandTrace:
    d = line_from_byte(byte)
    cmds = [ACT] + [RD] * n_reads + [PRE]
    banks = [0] * (n_reads + 2)
    rows = [128] * (n_reads + 2)
    cols = [0] + [i % 2 for i in range(n_reads)] + [0]
    datas = [_Z] + [d] * n_reads + [_Z]
    dts = ([max(_T.tRCD, _T.tRAS if n_reads == 0 else _T.tRCD)]
           + [_T.tCCD] * n_reads + [_T.tRP])
    # honor tRAS: if reads finish before tRAS, stretch the final read slot
    used = dts[0] + _T.tCCD * max(n_reads - 1, 0)
    if used < _T.tRAS:
        if n_reads:
            dts[n_reads] = dts[n_reads] + (_T.tRAS - used)
        else:
            dts[0] = _T.tRAS
    return _loop(cmds, banks, rows, cols, datas, dts, reps)
