"""repro.core — the paper's contribution: measurement-grounded DRAM power
modeling (VAMPIRE), its characterization pipeline, baselines, and the data
encoding case study, plus the TPU/HBM adaptation used by the framework."""

from repro.core.dram import (CommandTrace, Timing, TIMING, VDD,  # noqa: F401
                             make_trace, concat_traces, tile_trace)
from repro.core.energy_model import (PowerParams, EnergyReport,  # noqa: F401
                                     trace_energy_scan,
                                     trace_energy_vectorized)
from repro.core.vampire import Vampire, reference_vampire  # noqa: F401
from repro.core.model_api import (Estimator, load_estimator,  # noqa: F401
                                  make_estimator, save_estimator)
from repro.core.baselines_power import (DRAMPowerModel,  # noqa: F401
                                        MicronModel)
