"""Model validation (paper Section 9.1).

Runs the paper's held-out validation workload — {ACT, n x RD, PRE} sweeps
with n in [0, 764], data 0xAA, bank 0 / row 128, column-interleaved — on a
randomly selected subset of modules (8 from Vendor A, 7 from B, 7 from C),
and reports the mean absolute percentage error (MAPE) of VAMPIRE, DRAMPower,
and the Micron power model against the 'measured' current.

Both sides of the comparison go through the batched engines: the VAMPIRE
predictions for the whole (sweep x vendor) grid are ONE
``model.estimate_many`` dispatch (``repro.core.estimate_batch``), and the
fleet's ground-truth measurements are one padded probe batch through
``fleet.run_probes`` with stable per-sweep noise keys.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import baselines_power, device_sim, idd_loops
from repro.core import fleet as fleet_lib
from repro.core import params as P
from repro.core.vampire import Vampire

# n values swept in the validation experiments (paper: 0..764)
N_READS = (0, 1, 2, 3, 4, 5, 6, 8, 10, 12, 16, 20, 24, 32, 48, 64, 96, 128,
           192, 256, 382, 512, 764)
VALIDATION_COUNTS = {0: 8, 1: 7, 2: 7}  # modules per vendor (paper Sec 9.1)

# noise-key base for the validation sweeps: disjoint from the campaign's
# IDD (0+) and probe (4096+) key ranges so validation measurements never
# reuse a campaign measurement's noise draw
_VALIDATION_KEY_BASE = 1 << 14


@dataclasses.dataclass
class ValidationResult:
    mape: dict[str, dict[int, float]]        # model -> vendor -> MAPE %
    mape_mean: dict[str, float]              # model -> mean across vendors
    raw: dict                                 # per (vendor, n): all numbers

    def summary(self) -> str:
        lines = ["model      MAPE(A)  MAPE(B)  MAPE(C)   mean"]
        for m, per_v in self.mape.items():
            lines.append(
                f"{m:10s} {per_v.get(0, float('nan')):7.1f}% "
                f"{per_v.get(1, float('nan')):7.1f}% "
                f"{per_v.get(2, float('nan')):7.1f}% "
                f"{self.mape_mean[m]:6.1f}%")
        return "\n".join(lines)


def select_validation_modules(fleet_modules=None, seed: int = 42):
    fleet_modules = (device_sim.make_fleet() if fleet_modules is None
                     else fleet_modules)
    rng = np.random.default_rng(seed)
    chosen = []
    for v, k in VALIDATION_COUNTS.items():
        mods = device_sim.vendor_modules(fleet_modules, v)
        k = min(k, len(mods))
        idx = rng.choice(len(mods), size=k, replace=False)
        chosen += [mods[i] for i in idx]
    return chosen


def run_validation(model: Vampire, fleet=None, n_values=N_READS,
                   seed: int = 42) -> ValidationResult:
    modules = select_validation_modules(fleet, seed=seed)
    ds = {v: model.by_vendor[v].idd_datasheet for v in model.by_vendor}

    n_values = list(n_values)
    sweeps = [idd_loops.validation_sweep(n) for n in n_values]
    vendors = sorted({m.spec.vendor for m in modules})

    # ---- VAMPIRE: the whole (sweep x vendor) grid in one dispatch --------
    vamp = np.asarray(
        model.estimate_many(sweeps, vendors).avg_current_ma, np.float64)

    preds = {name: {} for name in ("vampire", "drampower", "micron")}
    for j, v in enumerate(vendors):
        for i, n in enumerate(n_values):
            preds["vampire"][(v, n)] = float(vamp[i, j])
            preds["drampower"][(v, n)] = float(
                baselines_power.drampower(sweeps[i], ds[v]).avg_current_ma)
            preds["micron"][(v, n)] = float(
                baselines_power.micron_power(sweeps[i], ds[v])
                .avg_current_ma)

    # ---- ground truth: one padded probe batch over the held-out modules --
    points = [fleet_lib.ProbePoint(("validation", n), tr, 0,
                                   _VALIDATION_KEY_BASE + i)
              for i, (n, tr) in enumerate(zip(n_values, sweeps))]
    measured_mat = fleet_lib.run_probes(modules, points, engine="batched")

    raw = {}
    errs: dict[str, dict[int, list[float]]] = {
        name: {0: [], 1: [], 2: []} for name in preds}
    for mi, m in enumerate(modules):
        v = m.spec.vendor
        for i, n in enumerate(n_values):
            measured = float(measured_mat[mi, i])
            raw[(v, m.spec.module_id, n)] = {
                "measured": measured,
                **{name: preds[name][(v, n)] for name in preds}}
            for name in preds:
                errs[name][v].append(
                    abs(preds[name][(v, n)] - measured) / measured * 100.0)

    mape = {name: {v: float(np.mean(e)) for v, e in per_v.items() if e}
            for name, per_v in errs.items()}
    mape_mean = {name: float(np.mean(list(per_v.values())))
                 for name, per_v in mape.items()}
    return ValidationResult(mape=mape, mape_mean=mape_mean, raw=raw)
