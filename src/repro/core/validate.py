"""Model validation (paper Section 9.1).

Runs the paper's held-out validation workload — {ACT, n x RD, PRE} sweeps
with n in [0, 764], data 0xAA, bank 0 / row 128, column-interleaved — on a
randomly selected subset of modules (8 from Vendor A, 7 from B, 7 from C),
and reports the mean absolute percentage error (MAPE) of VAMPIRE, DRAMPower,
and the Micron power model against the 'measured' current.

Every model is scored through the unified estimator protocol
(``repro.core.model_api``): the whole (sweep x vendor) prediction grid of
each estimator is ONE ``estimate`` dispatch over a shared padded
``TraceBatch`` — VAMPIRE and the datasheet baselines ride the identical
batched code path, there is no per-(sweep, vendor) Python loop.  The
fleet's ground-truth measurements are one padded probe batch through
``fleet.run_probes`` with stable per-sweep noise keys.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import device_sim, dram, estimate_batch, idd_loops
from repro.core import fleet as fleet_lib
from repro.core.baselines_power import DRAMPowerModel, MicronModel
from repro.core.model_api import Estimator
from repro.core.vampire import Vampire

# n values swept in the validation experiments (paper: 0..764)
N_READS = (0, 1, 2, 3, 4, 5, 6, 8, 10, 12, 16, 20, 24, 32, 48, 64, 96, 128,
           192, 256, 382, 512, 764)
VALIDATION_COUNTS = {0: 8, 1: 7, 2: 7}  # modules per vendor (paper Sec 9.1)

# noise-key base for the validation sweeps: disjoint from the campaign's
# IDD (0+) and probe (4096+) key ranges so validation measurements never
# reuse a campaign measurement's noise draw
_VALIDATION_KEY_BASE = 1 << 14


@dataclasses.dataclass
class ValidationResult:
    mape: dict[str, dict[int, float]]        # model -> vendor -> MAPE %
    mape_mean: dict[str, float]              # model -> mean across vendors
    raw: dict                                 # per (vendor, n): all numbers

    def summary(self) -> str:
        lines = ["model      MAPE(A)  MAPE(B)  MAPE(C)   mean"]
        for m, per_v in self.mape.items():
            lines.append(
                f"{m:10s} {per_v.get(0, float('nan')):7.1f}% "
                f"{per_v.get(1, float('nan')):7.1f}% "
                f"{per_v.get(2, float('nan')):7.1f}% "
                f"{self.mape_mean[m]:6.1f}%")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Structural-variation surfaces (paper Section 6, Figs 19-22 as fleet maps)
# ---------------------------------------------------------------------------
def surface_sweep_trace(reps: int = 4):
    """A workload touching every (bank, row-band) structural cell — one
    ACT/RD/PRE visit per cell at the surface campaign's constant-popcount
    probe rows — so a ``mode='surface'`` report over it populates the whole
    Fig 19-22 heatmap."""
    from repro.core.characterize import surface_probe_row
    from repro.core.dram import ACT, PRE, RD, TIMING, line_from_byte
    cmds, banks, rows, cols, datas, dts = [], [], [], [], [], []
    d = line_from_byte(0xAA)
    z = np.zeros(dram.LINE_WORDS, dtype=np.uint32)
    for b in range(dram.N_BANKS):
        for band in range(dram.N_ROW_BANDS):
            r = surface_probe_row(band)
            cmds += [ACT, RD, PRE]
            banks += [b] * 3
            rows += [r] * 3
            cols += [0] * 3
            datas += [z, d, z]
            dts += [TIMING.tRCD, TIMING.tRAS - TIMING.tRCD, TIMING.tRP]
    tr = dram.make_trace(cmds, banks, rows, cols, np.stack(datas), dts)
    return dram.tile_trace(tr, reps)


def structural_surface_maps(model: Estimator, traces=None, vendors=None,
                            impl: str = "vectorized") -> np.ndarray:
    """Fleet-wide Fig 19-22 heatmaps from the ``mode='surface'`` output:
    per-vendor (banks, row_bands) energy shares, normalized so each
    vendor's surface sums to 1.  ``traces`` defaults to
    :func:`surface_sweep_trace`; any estimator kind works — the baselines
    render structurally flat maps, which is the paper's contrast."""
    if traces is None:
        traces = [surface_sweep_trace()]
    rep = model.estimate(traces, vendors, mode="surface", impl=impl)
    energy = np.asarray(rep.energy_pj, np.float64).sum(axis=0)  # (V, 8, R)
    return energy / energy.sum(axis=(1, 2), keepdims=True)


def render_surface_heatmap(surface: np.ndarray, title: str = "") -> str:
    """ASCII rendering of one (banks, row_bands) surface, normalized to
    its own mean (1.00 == structurally flat cell)."""
    surface = np.asarray(surface, np.float64)
    rel = surface / surface.mean()
    lines = [title] if title else []
    lines.append("bank\\band " + " ".join(f"{b:>5d}"
                                          for b in range(surface.shape[1])))
    for b in range(surface.shape[0]):
        lines.append(f"  bank {b}  " + " ".join(f"{v:5.2f}"
                                                for v in rel[b]))
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Measured vs. datasheet (paper Section 4 / Fig 14)
# ---------------------------------------------------------------------------
def measured_over_datasheet(model: Vampire) -> dict[int, dict[str, float]]:
    """Paper Fig 14: per-vendor measured/datasheet ratio of every IDD key
    the campaign ran — the low-power keys (IDD2P1, IDD2P0, IDD3P, IDD6)
    included, which is the figure's headline: the low-power states sit
    far below their worst-case datasheet values (roughly 50-80% of them),
    so datasheet-driven models overestimate idle-heavy workloads most."""
    out: dict[int, dict[str, float]] = {}
    for v, vc in model.by_vendor.items():
        out[v] = {k: float(np.mean(vc.idd_measured[k])) / ds
                  for k, ds in vc.idd_datasheet.items()
                  if k in vc.idd_measured and ds > 0}
    return out


def render_fig14_table(ratios: dict[int, dict[str, float]]) -> str:
    """ASCII rendering of the Fig 14 ratios, one row per IDD key."""
    vendors = sorted(ratios)
    keys = [k for k in ratios[vendors[0]]]
    lines = ["IDD key   " + " ".join(f"  {'ABC'[v]}  " for v in vendors)]
    for k in keys:
        lines.append(f"{k:8s} " + " ".join(
            f"{ratios[v].get(k, float('nan')):5.2f}" for v in vendors))
    return "\n".join(lines)


def select_validation_modules(fleet_modules=None, seed: int = 42):
    fleet_modules = (device_sim.make_fleet() if fleet_modules is None
                     else fleet_modules)
    rng = np.random.default_rng(seed)
    chosen = []
    for v, k in VALIDATION_COUNTS.items():
        mods = device_sim.vendor_modules(fleet_modules, v)
        k = min(k, len(mods))
        idx = rng.choice(len(mods), size=k, replace=False)
        chosen += [mods[i] for i in idx]
    return chosen


def default_estimators(model: Vampire) -> dict[str, Estimator]:
    """The paper's comparison set: the fitted VAMPIRE model plus both
    datasheet baselines built from its derived per-vendor datasheets."""
    return {"vampire": model,
            "drampower": DRAMPowerModel.from_vampire(model),
            "micron": MicronModel.from_vampire(model)}


def run_validation(model: Vampire, fleet=None, n_values=N_READS,
                   seed: int = 42,
                   estimators: dict[str, Estimator] | None = None
                   ) -> ValidationResult:
    """Score ``estimators`` (default: VAMPIRE + Micron + DRAMPower built
    from ``model``) against held-out fleet measurements.  Any object
    implementing the estimator protocol can ride along — each one's full
    (sweep x vendor) grid is a single batched dispatch."""
    modules = select_validation_modules(fleet, seed=seed)
    if estimators is None:
        estimators = default_estimators(model)

    n_values = list(n_values)
    sweeps = [idd_loops.validation_sweep(n) for n in n_values]
    vendors = sorted({m.spec.vendor for m in modules})

    # ---- every estimator: the whole (sweep x vendor) grid, one dispatch --
    batch = estimate_batch.TraceBatch.from_traces(sweeps)
    grids = {name: np.asarray(est.estimate(batch, vendors).avg_current_ma,
                              np.float64)
             for name, est in estimators.items()}        # each (S, V)

    # ---- ground truth: one padded probe batch over the held-out modules --
    points = [fleet_lib.ProbePoint(("validation", n), tr, 0,
                                   _VALIDATION_KEY_BASE + i)
              for i, (n, tr) in enumerate(zip(n_values, sweeps))]
    measured_mat = fleet_lib.run_probes(modules, points, engine="batched")

    vcol = {v: j for j, v in enumerate(vendors)}
    raw = {}
    errs: dict[str, dict[int, list[float]]] = {
        name: {v: [] for v in vendors} for name in grids}
    for mi, m in enumerate(modules):
        v = m.spec.vendor
        for i, n in enumerate(n_values):
            measured = float(measured_mat[mi, i])
            raw[(v, m.spec.module_id, n)] = {
                "measured": measured,
                **{name: float(grids[name][i, vcol[v]]) for name in grids}}
            for name in grids:
                errs[name][v].append(
                    abs(float(grids[name][i, vcol[v]]) - measured)
                    / measured * 100.0)

    mape = {name: {v: float(np.mean(e)) for v, e in per_v.items() if e}
            for name, per_v in errs.items()}
    mape_mean = {name: float(np.mean(list(per_v.values())))
                 for name, per_v in mape.items()}
    return ValidationResult(mape=mape, mape_mean=mape_mean, raw=raw)
