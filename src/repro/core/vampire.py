"""VAMPIRE — Variation-Aware model of Memory Power Informed by Real
Experiments (paper Section 9), fitted from the characterization campaign.

Public API (the unified estimator protocol, ``repro.core.model_api``)
---------------------------------------------------------------------
``Vampire.fit(fleet)``       run the campaign and build the model — a thin
    shim onto ``model_api.fit('vampire', fleet, fitter='campaign')``, the
    registry-routed fitting entry point (``fitter='streaming'`` is the
    online-recalibration path, ``repro.core.recalibrate``).
``model.estimate(traces, vendors=None, *, mode='mean', impl='vectorized',
                 data=DataProfile(...) | None,
                 ones_frac=None, toggle_frac=None)``
    ONE entry point for every estimation question.  ``traces`` is a single
    trace, a sequence of ragged traces, or a prebuilt
    ``estimate_batch.TraceBatch``; the full (traces x vendors) report
    matrix is evaluated in one jitted ``vmap(vmap)`` dispatch and every
    leaf of the returned ``EnergyReport`` has shape ``(traces, vendors)``.

    * ``mode='mean'``          the report matrix.
    * ``mode='range'``         (lo, mean, hi) matrices across each vendor's
      process-variation band (captured from the per-module IDD spread).
    * ``mode='distribution'``  the paper's no-data-trace mode: the caller
      supplies ``ones_frac``/``toggle_frac`` (scalar or per trace) instead
      of actual 64-byte values.
    * ``mode='surface'``       the structural-variation decomposition
      (paper Section 6 / Figs 19-22): report leaves are ``(traces,
      vendors, banks, row_bands)``-shaped, each command's charge grouped
      onto its (bank, row-band) cell; summing the cell axes recovers
      ``mode='mean'`` exactly.
    * ``impl`` resolves through the registry (``model_api.resolve_impl``):
      ``'vectorized'`` is the jnp/XLA batched engine, ``'pallas'`` the
      fused (traces x vendors) Pallas kernel family (compiled on TPU,
      interpret-mode elsewhere), and ``'reference'`` (alias ``'scan'``)
      the pair-at-a-time per-command oracle kept for cross-checking.

``model.save(path)`` / ``Vampire.load(path)``
    schema-v2 ``.npz`` + JSON-manifest serialization; v1 pickle blobs
    still load with a ``DeprecationWarning`` (``repro.core.model_api``).

The model IS a pytree
---------------------
The fitted state lives in a :class:`FleetModel`: per-vendor
:class:`PowerParams` stacked once at fit time along a leading vendor axis,
with the variation bands, datasheet IDD tables, and vendor ids as array
leaves.  ``Vampire`` itself is a registered pytree whose children are those
leaves (the raw characterization record rides along as static aux data), so
a fitted model can be passed straight through ``jax.jit`` / ``jax.vmap`` /
``jax.device_put`` — e.g. ``jax.jit(lambda m: m.estimate(batch))(model)``
compiles with the model as a traced argument.

The pre-unification methods (``estimate(trace, vendor)`` positional,
``estimate_range``, ``estimate_distribution`` and their ``*_many``
variants) remain as thin shims that delegate to ``estimate`` and emit
``DeprecationWarning``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterize, device_sim, model_api
from repro.core.dram import CommandTrace
from repro.core.energy_model import (EnergyReport, PowerParams, _report,
                                     charge_from_features,
                                     distribution_features,
                                     extract_structural_features,
                                     finalize_features, scale_report,
                                     surface_charge, surface_cycles,
                                     trace_charges_scan, trace_energy_scan)
from repro.core.fleet import stack_params


class FleetModel(NamedTuple):
    """The pytree-native fitted state: every leaf carries a leading vendor
    axis, so the whole bundle jits, vmaps, and shards as one unit."""
    params: PowerParams        # stacked (V, ...) fitted per-vendor params
    band: jax.Array            # (V, 2) multiplicative (lo, hi) variation
    idd_datasheet: jax.Array   # (V, K) datasheet IDDs (keys in `idd_keys`)
    vendor_ids: jax.Array      # (V,) int32


def _squeeze_pair(rep: EnergyReport) -> EnergyReport:
    """(1, 1)-shaped report matrix -> scalar-leaf report (legacy shape)."""
    return jax.tree_util.tree_map(lambda x: x[0, 0], rep)


def _shim_warning(old: str, new: str):
    warnings.warn(
        f"Vampire.{old} is deprecated; call Vampire.{new} instead "
        "(the unified estimator protocol, repro.core.model_api).",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class Vampire(model_api.StackedEstimatorMixin):
    by_vendor: dict[int, characterize.VendorCharacterization]
    # multiplicative process-variation band per vendor (lo, hi) captured from
    # the spread of per-module IDD measurements during characterization
    variation_band: dict[int, tuple[float, float]] = None  # type: ignore

    kind = "vampire"

    def __post_init__(self):
        if self.variation_band is None:
            self.variation_band = {}
            for v, vc in self.by_vendor.items():
                rel = []
                for key in ("IDD0", "IDD4R", "IDD4W"):
                    arr = vc.idd_measured[key]
                    rel.append(arr / np.mean(arr))
                rel = np.concatenate(rel)
                self.variation_band[v] = (float(np.min(rel)),
                                          float(np.max(rel)))

    # ------------------------------------------------------------------ fit
    @classmethod
    def fit(cls, fleet=None, **kw) -> "Vampire":
        """Run the characterization campaign and build the model.

        Thin shim onto ``model_api.fit('vampire', fleet,
        fitter='campaign', **kw)`` — the registry-routed fitting entry
        point; bit-for-bit identical to the pre-registry fit.

        ``engine='batched'`` (default) runs the campaign through the vmapped
        fleet engine (``repro.core.fleet``); ``engine='serial'`` replays it
        one measurement at a time (the correctness oracle)."""
        return model_api.fit("vampire", fleet, fitter="campaign", **kw)

    @property
    def vendors(self) -> tuple[int, ...]:
        return tuple(sorted(self.by_vendor))

    def params(self, vendor: int) -> PowerParams:
        return self.by_vendor[vendor].fitted

    # -------------------------------------------------- the pytree bundle
    @property
    def fleet(self) -> FleetModel:
        fm = self.__dict__.get("_fleet")
        if fm is None:
            fm = self._build_fleet()
            self.__dict__["_fleet"] = fm
        return fm

    def _build_fleet(self) -> FleetModel:
        vs = self.vendors
        for v in vs:
            if self.by_vendor[v].fitted is None:
                self.by_vendor[v].build_params()
        idd_keys = sorted(self.by_vendor[vs[0]].idd_datasheet)
        return FleetModel(
            params=stack_params([self.by_vendor[v].fitted for v in vs]),
            band=jnp.asarray([self.variation_band[v] for v in vs],
                             jnp.float32),
            idd_datasheet=jnp.asarray(
                [[self.by_vendor[v].idd_datasheet[k] for k in idd_keys]
                 for v in vs], jnp.float32),
            vendor_ids=jnp.asarray(vs, jnp.int32))

    def _stacked_for(self, idx: tuple[int, ...]):
        """(stacked params, band) rows for the requested vendor indices;
        subsets are sliced once and memoized per vendor tuple
        (``model_api.StackedEstimatorMixin``)."""
        fm = self.fleet
        if idx == tuple(range(fm.band.shape[0])):
            return fm.params, fm.band

        def build():
            sel = jnp.asarray(idx, jnp.int32)
            return (jax.tree_util.tree_map(lambda x: x[sel], fm.params),
                    fm.band[sel])

        return self._memo_subset(idx, fm, build)

    # ------------------------------------------------------------- estimate
    def estimate(self, traces, vendors=None, *legacy_impl,
                 mode: model_api.EstimateMode = "mean",
                 impl: str = "vectorized", data=None,
                 ones_frac=None, toggle_frac=None):
        """The unified entry point (see the module docstring).

        NOTE: portable protocol code must pass ``vendors`` as a sequence
        (or ``None``).  The (single trace, bare int vendor) call shape is
        reserved for the legacy ``estimate(trace, vendor)`` form — it
        emits ``DeprecationWarning`` and returns the historical
        scalar-leaf report rather than a (1, 1) matrix."""
        if legacy_impl or (isinstance(traces, CommandTrace)
                           and isinstance(vendors, (int, np.integer))):
            if not (isinstance(traces, CommandTrace)
                    and isinstance(vendors, (int, np.integer))):
                raise TypeError("positional impl is only accepted by the "
                                "legacy estimate(trace, vendor, impl) form "
                                "(one CommandTrace, one int vendor)")
            if mode != "mean" or data is not None \
                    or ones_frac is not None or toggle_frac is not None:
                # the legacy form is mean-mode only; silently forcing
                # mode='mean' here would return numerically wrong results
                raise TypeError(
                    "the legacy estimate(trace, vendor) form does not "
                    "accept mode/ones_frac/toggle_frac; pass vendors as a "
                    "sequence, e.g. estimate([trace], (vendor,), mode=...)")
            _shim_warning("estimate(trace, vendor)",
                          "estimate(traces, vendors)")
            impl = legacy_impl[0] if legacy_impl else impl
            return _squeeze_pair(self._estimate(
                traces, (int(vendors),), mode="mean", impl=impl))
        return self._estimate(traces, vendors, mode=mode, impl=impl,
                              data=data, ones_frac=ones_frac,
                              toggle_frac=toggle_frac)

    def _estimate(self, traces, vendors=None, *, mode="mean",
                  impl="vectorized", data=None, ones_frac=None,
                  toggle_frac=None):
        from repro.core import estimate_batch
        profile = model_api.normalize_data_profile(data, ones_frac,
                                                   toggle_frac)
        model_api.validate_data_profile(mode, profile)
        ones_frac, toggle_frac = profile.ones_frac, profile.toggle_frac
        impl = model_api.resolve_impl(impl, mode=mode).name
        model_api.require_impl_path(self.kind, impl,
                                    ("vectorized", "pallas", "reference"))
        _, idx = model_api.resolve_vendor_indices(self.vendors, vendors)
        stacked, band = self._stacked_for(idx)
        tb = self._batch_cache.get(traces)

        if mode == "surface":
            if impl == "vectorized":
                return estimate_batch.batched_surface_reports(
                    tb.trace, tb.weight, stacked)
            if impl == "pallas":
                return estimate_batch.pallas_batched_surface_reports(
                    tb.trace, tb.weight, stacked)
            return self._reference_surface(traces, tb, stacked)

        if mode == "distribution":
            if impl == "vectorized":
                return estimate_batch.batched_distribution_reports(
                    tb.trace, tb.weight, stacked,
                    jnp.asarray(ones_frac, jnp.float32),
                    jnp.asarray(toggle_frac, jnp.float32))
            if impl == "pallas":
                return estimate_batch.pallas_batched_distribution_reports(
                    tb.trace, tb.weight, stacked, ones_frac, toggle_frac)
            return self._reference_matrix(traces, tb, stacked,
                                          ones_frac=ones_frac,
                                          toggle_frac=toggle_frac)

        if impl == "vectorized":
            if mode == "range":
                return estimate_batch.batched_range_reports(
                    tb.trace, tb.weight, stacked, band)
            return estimate_batch.batched_reports(tb.trace, tb.weight,
                                                  stacked)
        if impl == "pallas":
            if mode == "range":
                return estimate_batch.pallas_batched_range_reports(
                    tb.trace, tb.weight, stacked, band)
            return estimate_batch.pallas_batched_reports(tb.trace, tb.weight,
                                                         stacked)
        mean = self._reference_matrix(traces, tb, stacked)
        if mode == "mean":
            return mean
        lo = scale_report(mean, band[None, :, 0])
        hi = scale_report(mean, band[None, :, 1])
        return lo, mean, hi

    def _reference_matrix(self, traces, tb, stacked: PowerParams, *,
                          ones_frac=None, toggle_frac=None) -> EnergyReport:
        """``impl='reference'``: the pair-at-a-time oracle — the lax.scan
        per-command state machine for measured-data modes, the per-trace
        feature-override path for ``mode='distribution'``."""
        from repro.core.estimate_batch import original_traces
        originals = original_traces(traces, tb)
        if ones_frac is not None:
            of = np.broadcast_to(np.asarray(ones_frac, np.float32),
                                 (len(originals),))
            tf = np.broadcast_to(np.asarray(toggle_frac, np.float32),
                                 (len(originals),))

            def one_pair(tr, pp, i):
                sf = distribution_features(
                    extract_structural_features(tr), of[i], tf[i])
                charges = charge_from_features(
                    tr, finalize_features(sf, pp), pp)
                return _report(jnp.sum(charges), tr.total_cycles())

            per_trace = [jax.vmap(lambda pp, tr=tr, i=i: one_pair(tr, pp, i)
                                  )(stacked)
                         for i, tr in enumerate(originals)]
        else:
            per_trace = [jax.vmap(lambda pp, tr=tr: trace_energy_scan(tr, pp)
                                  )(stacked) for tr in originals]
        return jax.tree_util.tree_map(lambda *rows: jnp.stack(rows),
                                      *per_trace)

    def _reference_surface(self, traces, tb, stacked: PowerParams
                           ) -> EnergyReport:
        """``impl='reference'`` for ``mode='surface'``: the per-command
        lax.scan oracle's charge stream, grouped onto the (bank, row-band)
        cells one (trace, vendor) pair at a time."""
        from repro.core.estimate_batch import original_traces
        originals = original_traces(traces, tb)

        def one_pair(tr, pp):
            charges = trace_charges_scan(tr, pp)
            w = jnp.ones_like(charges)
            return _report(surface_charge(tr, w, charges),
                           surface_cycles(tr, w))

        per_trace = [jax.vmap(lambda pp, tr=tr: one_pair(tr, pp))(stacked)
                     for tr in originals]
        return jax.tree_util.tree_map(lambda *rows: jnp.stack(rows),
                                      *per_trace)

    # --------------------------------------------------- deprecated shims
    def estimate_range(self, trace: CommandTrace, vendor: int,
                       impl: str = "vectorized"
                       ) -> tuple[EnergyReport, EnergyReport, EnergyReport]:
        _shim_warning("estimate_range", "estimate(..., mode='range')")
        return tuple(_squeeze_pair(r) for r in self._estimate(
            trace, (int(vendor),), mode="range", impl=impl))

    def estimate_distribution(self, trace: CommandTrace, vendor: int,
                              ones_frac: float, toggle_frac: float
                              ) -> EnergyReport:
        _shim_warning("estimate_distribution",
                      "estimate(..., mode='distribution')")
        return _squeeze_pair(self._estimate(
            trace, (int(vendor),), mode="distribution",
            ones_frac=ones_frac, toggle_frac=toggle_frac))

    def estimate_many(self, traces, vendors=None) -> EnergyReport:
        _shim_warning("estimate_many", "estimate")
        return self._estimate(traces, vendors)

    def estimate_range_many(self, traces, vendors=None
                            ) -> tuple[EnergyReport, EnergyReport,
                                       EnergyReport]:
        _shim_warning("estimate_range_many", "estimate(..., mode='range')")
        return self._estimate(traces, vendors, mode="range")

    def estimate_distribution_many(self, traces, vendors=None, *,
                                   ones_frac, toggle_frac) -> EnergyReport:
        _shim_warning("estimate_distribution_many",
                      "estimate(..., mode='distribution')")
        return self._estimate(traces, vendors, mode="distribution",
                              ones_frac=ones_frac, toggle_frac=toggle_frac)

    # ------------------------------------------------------------------ io
    def save(self, path: str, *, meta: dict | None = None):
        """Schema-v2 ``.npz`` + JSON-manifest blob (``repro.core.model_api``);
        round-trips the fitted params, bands, datasheets, and — when present
        — the raw campaign sweeps the benchmarks plot."""
        model_api.save_estimator(self, path, meta=meta)

    @classmethod
    def load(cls, path: str) -> "Vampire":
        """Load a ``save`` blob (v2 ``.npz``, or a v1 pickle with a
        ``DeprecationWarning``)."""
        model = model_api.load_estimator(path)
        if not isinstance(model, cls):
            raise TypeError(f"{path} holds a {type(model).__name__}, "
                            "not a Vampire model")
        return model


def _vampire_flatten(m: Vampire):
    return (m.fleet,), (m._aux_static((m.by_vendor, m.variation_band)),)


def _vampire_unflatten(aux, children) -> Vampire:
    m = object.__new__(Vampire)
    by_vendor, band = aux[0].value
    m.by_vendor = by_vendor
    m.variation_band = band
    m.__dict__["_fleet"] = children[0]
    m.__dict__["_aux"] = aux[0]   # keep treedefs equal across round trips
    return m


jax.tree_util.register_pytree_node(Vampire, _vampire_flatten,
                                   _vampire_unflatten)


def reference_vampire() -> Vampire:
    """A quick-fit VAMPIRE on a reduced fleet (for tests/examples)."""
    from repro.core import params as P
    fleet = device_sim.make_fleet(
        [P.ModuleSpec(v, i, 2015) for v in range(3) for i in range(3)])
    return Vampire.fit(fleet, probe_modules=2, probe_reps=64, n_rows=8)
