"""VAMPIRE — Variation-Aware model of Memory Power Informed by Real
Experiments (paper Section 9), fitted from the characterization campaign.

Public API
----------
``Vampire.fit(fleet)``        run the campaign and build the model
``model.estimate(trace, vendor)``           EnergyReport (mean module)
``model.estimate_range(trace, vendor)``     (lo, mean, hi) EnergyReports
                                            across the process variation
                                            captured per vendor
``model.estimate_distribution(trace, vendor, ones_frac, toggle_frac)``
    the paper's no-data-trace mode: the caller supplies a distribution of
    ones / toggling instead of actual 64-byte values.

Batched API (the production estimation path; see
``repro.core.estimate_batch``) — each evaluates the full
(traces x vendors) matrix in ONE jitted dispatch over NOP/dt=0-padded
traces, with every report leaf shaped ``(traces, vendors)``:

``model.estimate_many(traces, vendors)``          EnergyReport matrix
``model.estimate_range_many(traces, vendors)``    (lo, mean, hi) matrices,
    the variation band vmapped across the same dispatch
``model.estimate_distribution_many(traces, vendors, ones_frac=, toggle_frac=)``
    batched no-data-trace mode (fractions scalar or per trace)

``traces`` may be a single trace, a sequence of ragged traces, or a
prebuilt ``estimate_batch.TraceBatch`` (reuse one when scoring the same
set repeatedly — padding is then paid once).

Per-trace implementations: ``impl='vectorized'`` (production),
``impl='scan'`` (oracle), ``impl='kernel'`` (Pallas-fused per-command
energy; see ``repro.kernels.vampire_energy``).
"""
from __future__ import annotations

import dataclasses
import pickle

import jax.numpy as jnp
import numpy as np

from repro.core import characterize, device_sim
from repro.core.dram import CommandTrace
from repro.core.energy_model import (EnergyReport, PowerParams,
                                     charge_from_features,
                                     distribution_features,
                                     extract_structural_features,
                                     finalize_features, scale_report,
                                     trace_energy_scan,
                                     trace_energy_vectorized, _report)


@dataclasses.dataclass
class Vampire:
    by_vendor: dict[int, characterize.VendorCharacterization]
    # multiplicative process-variation band per vendor (lo, hi) captured from
    # the spread of per-module IDD measurements during characterization
    variation_band: dict[int, tuple[float, float]] = None  # type: ignore

    def __post_init__(self):
        if self.variation_band is None:
            self.variation_band = {}
            for v, vc in self.by_vendor.items():
                rel = []
                for key in ("IDD0", "IDD4R", "IDD4W"):
                    arr = vc.idd_measured[key]
                    rel.append(arr / np.mean(arr))
                rel = np.concatenate(rel)
                self.variation_band[v] = (float(np.min(rel)),
                                          float(np.max(rel)))

    # ------------------------------------------------------------------ fit
    @classmethod
    def fit(cls, fleet=None, **kw) -> "Vampire":
        """Run the characterization campaign and build the model.

        ``engine='batched'`` (default) runs the campaign through the vmapped
        fleet engine (``repro.core.fleet``); ``engine='serial'`` replays it
        one measurement at a time (the correctness oracle)."""
        return cls(by_vendor=characterize.characterize_fleet(fleet, **kw))

    def params(self, vendor: int) -> PowerParams:
        return self.by_vendor[vendor].fitted

    # ------------------------------------------------------------- estimate
    def estimate(self, trace: CommandTrace, vendor: int,
                 impl: str = "vectorized") -> EnergyReport:
        pp = self.params(vendor)
        if impl == "vectorized":
            return trace_energy_vectorized(trace, pp)
        if impl == "scan":
            return trace_energy_scan(trace, pp)
        if impl == "kernel":
            from repro.kernels.vampire_energy import ops as vops
            return vops.trace_energy_kernel(trace, pp)
        raise ValueError(impl)

    def estimate_range(self, trace: CommandTrace, vendor: int,
                       impl: str = "vectorized"
                       ) -> tuple[EnergyReport, EnergyReport, EnergyReport]:
        """(lo, mean, hi) EnergyReports across the vendor's process-variation
        band. The band is a multiplicative current factor, so charge and
        energy carry it too — callers comparing *energy* (e.g. the encoding
        study) see the same relative band as callers comparing current."""
        rep = self.estimate(trace, vendor, impl)
        lo, hi = self.variation_band[vendor]
        return scale_report(rep, lo), rep, scale_report(rep, hi)

    # -------------------------------------------------------- batched path
    def estimate_many(self, traces, vendors=None) -> EnergyReport:
        """Energy reports for every (trace, vendor) pair in ONE dispatch.

        ``traces``: a sequence of (ragged) traces, a single trace, or a
        prebuilt ``estimate_batch.TraceBatch``; ``vendors`` defaults to all
        fitted vendors. Every leaf of the returned report has shape
        ``(len(traces), len(vendors))``."""
        from repro.core import estimate_batch
        return estimate_batch.estimate_many(self, traces, vendors)

    def estimate_range_many(self, traces, vendors=None
                            ) -> tuple[EnergyReport, EnergyReport,
                                       EnergyReport]:
        """Batched ``estimate_range``: (lo, mean, hi) report matrices with
        the per-vendor variation band vmapped over the dispatch."""
        from repro.core import estimate_batch
        return estimate_batch.estimate_range_many(self, traces, vendors)

    def estimate_distribution_many(self, traces, vendors=None, *,
                                   ones_frac, toggle_frac) -> EnergyReport:
        """Batched no-data-trace mode; fractions are scalars or per-trace
        arrays."""
        from repro.core import estimate_batch
        return estimate_batch.estimate_distribution_many(
            self, traces, vendors, ones_frac=ones_frac,
            toggle_frac=toggle_frac)

    def estimate_distribution(self, trace: CommandTrace, vendor: int,
                              ones_frac: float, toggle_frac: float
                              ) -> EnergyReport:
        """Traces without data values: approximate data dependency with a
        user-supplied expected fraction of ones and of toggling wires."""
        pp = self.params(vendor)
        sf = distribution_features(extract_structural_features(trace),
                                   ones_frac, toggle_frac)
        charges = charge_from_features(trace, finalize_features(sf, pp), pp)
        return _report(jnp.sum(charges), trace.total_cycles())

    # ------------------------------------------------------------------ io
    def save(self, path: str):
        blob = {v: {"datadep": np.asarray(vc.datadep),
                    "i2n": vc.i2n,
                    "bank_open_delta": np.asarray(vc.bank_open_delta),
                    "bank_read_factor": np.asarray(vc.bank_read_factor),
                    "bank_write_factor": np.asarray(vc.bank_write_factor),
                    "q_actpre": vc.q_actpre,
                    "row_ones_slope": vc.row_ones_slope,
                    "q_ref": vc.q_ref, "i_pd": vc.i_pd,
                    "idd_datasheet": vc.idd_datasheet,
                    "band": self.variation_band[v]}
                for v, vc in self.by_vendor.items()}
        with open(path, "wb") as f:
            pickle.dump(blob, f)

    @classmethod
    def load(cls, path: str) -> "Vampire":
        """Rebuild a fitted model from a ``save`` blob.

        The blob stores only the fitted quantities (not the raw campaign
        sweeps), so the reconstructed ``VendorCharacterization`` carries
        empty measurement containers — everything ``estimate*`` needs
        (fitted :class:`PowerParams`, datasheet values, the variation band)
        round-trips exactly."""
        with open(path, "rb") as f:
            blob = pickle.load(f)
        by_vendor = {}
        bands = {}
        for v, d in blob.items():
            vc = characterize.VendorCharacterization(
                vendor=v,
                idd_measured={},
                idd_datasheet=dict(d["idd_datasheet"]),
                idd_extrapolation_r2={},
                datadep=np.asarray(d["datadep"]),
                datadep_r2=np.zeros((4, 2)),
                ones_sweep={},
                i2n=float(d["i2n"]),
                bank_open_delta=np.asarray(d["bank_open_delta"]),
                bank_read_factor=np.asarray(d["bank_read_factor"]),
                bank_write_factor=np.asarray(d["bank_write_factor"]),
                q_actpre=float(d["q_actpre"]),
                row_ones_slope=float(d["row_ones_slope"]),
                row_sweep={},
                q_ref=float(d["q_ref"]),
                i_pd=float(d["i_pd"]))
            vc.build_params()
            by_vendor[v] = vc
            bands[v] = tuple(d["band"])
        return cls(by_vendor=by_vendor, variation_band=bands)


def reference_vampire() -> Vampire:
    """A quick-fit VAMPIRE on a reduced fleet (for tests/examples)."""
    from repro.core import params as P
    fleet = device_sim.make_fleet(
        [P.ModuleSpec(v, i, 2015) for v in range(3) for i in range(3)])
    return Vampire.fit(fleet, probe_modules=2, probe_reps=64, n_rows=8)
