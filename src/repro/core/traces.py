"""Application-level DRAM command traces (paper Sections 9.2 and 10).

The paper drives its application studies with Pin-captured SPEC CPU2006
memory traces replayed through Ramulator. Without those proprietary inputs we
generate *synthetic application traces* from a small behavioral model —
memory intensity, row-buffer locality, read/write mix, and a byte-value
distribution — with per-app parameters chosen to span the same qualitative
range (memory-bound vs. compute-bound, sparse vs. dense data). The same
machinery also converts arbitrary byte buffers (e.g. framework tensors) into
traces, which is how the TPU/HBM adaptation feeds the model.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import dram
from repro.core.dram import (ACT, PRE, RD, WR, REF, CommandTrace, TIMING,
                             LINE_BYTES, LINE_WORDS, N_BANKS)

_T = TIMING


# ---------------------------------------------------------------------------
# Byte-value distributions ("what the data looks like")
# ---------------------------------------------------------------------------
def _dist_zeros(rng):
    p = np.full(256, 0.0008)
    p[0x00] = 0.70
    p[0xFF] = 0.05
    p[0x01] = 0.05
    return p / p.sum()


def _dist_ascii(rng):
    p = np.full(256, 0.0004)
    for c in range(0x61, 0x7B):      # lowercase letters
        p[c] = 0.025
    p[0x20] = 0.12                    # space
    for c in range(0x41, 0x5B):
        p[c] = 0.004
    for c in range(0x30, 0x3A):
        p[c] = 0.006
    p[0x0A] = 0.01
    return p / p.sum()


def _dist_int_small(rng):
    # two's-complement integers: many 0x00 high bytes but also many 0xFF
    # sign-extension bytes (8 ones each) — the OWI sweet spot
    p = np.full(256, 0.0008)
    for v, w in ((0x00, 0.32), (0x01, 0.06), (0x02, 0.03), (0x03, 0.02),
                 (0xFF, 0.24), (0xFE, 0.05), (0xFD, 0.02), (0x04, 0.01),
                 (0x08, 0.01), (0x7F, 0.02)):
        p[v] = w
    return p / p.sum()


def _dist_fp32(rng):
    # float exponent bytes cluster at 0x3F/0xBF (6-7 ones) with uniform
    # mantissas
    p = np.full(256, 0.002)
    for v, w in ((0x3F, 0.12), (0xBF, 0.10), (0x40, 0.06), (0xC0, 0.05),
                 (0x3E, 0.05), (0xBE, 0.04), (0x00, 0.08), (0x80, 0.03),
                 (0x7F, 0.03)):
        p[v] = w
    return p / p.sum()


def _dist_pointer(rng):
    # 64-bit heap pointers: 0x00007f.. prefixes -> lots of 0x00 AND 0x7F/0xFF
    p = np.full(256, 0.0015)
    p[0x00] = 0.26
    p[0x7F] = 0.14
    p[0xFF] = 0.06
    p[0x55] = 0.04
    for v in range(0x10, 0x90, 0x08):
        p[v] = 0.01
    return p / p.sum()


def _dist_random(rng):
    return np.full(256, 1.0 / 256)


BYTE_DISTS = {
    "zeros": _dist_zeros, "ascii": _dist_ascii, "int_small": _dist_int_small,
    "fp32": _dist_fp32, "pointer": _dist_pointer, "random": _dist_random,
}


# ---------------------------------------------------------------------------
# Application behavioral model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AppSpec:
    name: str
    intensity: float      # mean fraction of bus cycles doing data bursts
    row_hit: float        # row-buffer hit probability
    read_frac: float
    data_dist: str
    seed: int = 0


# 23 synthetic applications mirroring the qualitative spread of the paper's
# SPEC CPU2006 suite (memory-bound <-> compute-bound; varied data content).
SPEC_APPS = [
    AppSpec("perlbench",  0.16, 0.75, 0.70, "ascii",     1),
    AppSpec("bzip2",      0.30, 0.55, 0.60, "random",    2),
    AppSpec("gcc",        0.25, 0.65, 0.65, "pointer",   3),
    AppSpec("mcf",        0.75, 0.25, 0.75, "pointer",   4),
    AppSpec("gobmk",      0.12, 0.70, 0.68, "int_small", 5),
    AppSpec("hmmer",      0.22, 0.90, 0.55, "int_small", 6),
    AppSpec("sjeng",      0.10, 0.72, 0.66, "int_small", 7),
    AppSpec("libquantum", 0.82, 0.95, 0.80, "zeros",     8),
    AppSpec("h264ref",    0.26, 0.88, 0.58, "int_small", 9),
    AppSpec("omnetpp",    0.55, 0.30, 0.70, "pointer",  10),
    AppSpec("astar",      0.45, 0.45, 0.72, "pointer",  11),
    AppSpec("xalancbmk",  0.50, 0.40, 0.74, "ascii",    12),
    AppSpec("bwaves",     0.72, 0.90, 0.65, "fp32",     13),
    AppSpec("gamess",     0.08, 0.82, 0.60, "fp32",     14),
    AppSpec("milc",       0.70, 0.82, 0.62, "fp32",     15),
    AppSpec("zeusmp",     0.50, 0.85, 0.61, "fp32",     16),
    AppSpec("gromacs",    0.18, 0.74, 0.63, "fp32",     17),
    AppSpec("cactusADM",  0.62, 0.86, 0.55, "fp32",     18),
    AppSpec("leslie3d",   0.66, 0.86, 0.60, "fp32",     19),
    AppSpec("namd",       0.10, 0.80, 0.64, "fp32",     20),
    AppSpec("soplex",     0.64, 0.35, 0.73, "fp32",     21),
    AppSpec("povray",     0.07, 0.78, 0.62, "fp32",     22),
    AppSpec("lbm",        0.85, 0.93, 0.50, "fp32",     23),
]


def sample_lines(dist_name: str, n_lines: int,
                 rng: np.random.Generator) -> np.ndarray:
    """(n_lines, 16) uint32 lines with bytes drawn from the distribution."""
    p = BYTE_DISTS[dist_name](rng)
    b = rng.choice(256, size=(n_lines, LINE_BYTES), p=p).astype(np.uint32)
    return (b[:, 0::4] | (b[:, 1::4] << 8) | (b[:, 2::4] << 16)
            | (b[:, 3::4] << 24)).astype(np.uint32)


def lines_from_bytes(buf: bytes | np.ndarray) -> np.ndarray:
    """Pack an arbitrary byte buffer into (n_lines, 16) uint32 lines."""
    b = np.frombuffer(bytes(buf), dtype=np.uint8)
    pad = (-len(b)) % LINE_BYTES
    if pad:
        b = np.concatenate([b, np.zeros(pad, dtype=np.uint8)])
    b = b.reshape(-1, LINE_BYTES).astype(np.uint32)
    return (b[:, 0::4] | (b[:, 1::4] << 8) | (b[:, 2::4] << 16)
            | (b[:, 3::4] << 24)).astype(np.uint32)


def app_trace(app: AppSpec, n_requests: int = 2000,
              lines: np.ndarray | None = None) -> CommandTrace:
    """Generate the command trace for one synthetic application."""
    rng = np.random.default_rng(np.random.SeedSequence([29, app.seed]))
    if lines is None:
        lines = sample_lines(app.data_dist, n_requests, rng)
    n_requests = min(n_requests, lines.shape[0])

    cmds, banks, rows, cols, datas, dts = [], [], [], [], [], []
    open_row = -np.ones(N_BANKS, dtype=np.int64)
    # gap model: mean bus idle cycles between requests from intensity
    mean_gap = _T.tBURST * (1.0 - app.intensity) / max(app.intensity, 0.01)
    cycles_since_ref = 0.0
    zline = np.zeros(LINE_WORDS, dtype=np.uint32)

    bank_seq = rng.integers(0, N_BANKS, size=n_requests)
    hit_seq = rng.random(n_requests) < app.row_hit
    rd_seq = rng.random(n_requests) < app.read_frac
    row_seq = rng.integers(0, 1 << dram.ROW_BITS, size=n_requests)
    col_seq = rng.integers(0, dram.COLS_PER_ROW, size=n_requests)
    gap_seq = rng.geometric(1.0 / (1.0 + mean_gap), size=n_requests) - 1

    for i in range(n_requests):
        b = int(bank_seq[i])
        if hit_seq[i] and open_row[b] >= 0:
            r = int(open_row[b])
        else:
            r = int(row_seq[i])
            if open_row[b] >= 0:
                cmds.append(PRE); banks.append(b); rows.append(0)
                cols.append(0); datas.append(zline); dts.append(_T.tRP)
                cycles_since_ref += _T.tRP
            cmds.append(ACT); banks.append(b); rows.append(r)
            cols.append(0); datas.append(zline); dts.append(_T.tRCD)
            cycles_since_ref += _T.tRCD
            open_row[b] = r
        op = RD if rd_seq[i] else WR
        gap = int(gap_seq[i])
        if gap > 128:
            # long idle: finish the burst, precharge, then spend the gap in
            # the deepest low-power state whose exit latency the gap can
            # absorb (fast PDN / slow PDN / self-refresh).  The entry slot
            # bills at the powered-up rate, the dwell rides on a NOP slot,
            # and the exit slot is the last one billed at the low-power
            # rate — the integrator's entry/exit billing semantics.
            if gap > 2048:
                entry, exit_cmd, exit_dt = dram.SRE, dram.SRX, _T.tXS
            elif gap > 512:
                entry, exit_cmd, exit_dt = dram.PDE_SLOW, dram.PDX, \
                    _T.tXPDLL
            else:
                entry, exit_cmd, exit_dt = dram.PDE, dram.PDX, _T.tXP
            cmds.append(op); banks.append(b); rows.append(r)
            cols.append(int(col_seq[i])); datas.append(lines[i])
            dts.append(_T.tBURST)
            cmds.append(dram.PREA); banks.append(0); rows.append(0)
            cols.append(0); datas.append(zline); dts.append(_T.tRP)
            cmds.append(entry); banks.append(0); rows.append(0)
            cols.append(0); datas.append(zline); dts.append(_T.tCKE)
            cmds.append(dram.NOP); banks.append(0); rows.append(0)
            cols.append(0); datas.append(zline); dts.append(gap)
            cmds.append(exit_cmd); banks.append(0); rows.append(0)
            cols.append(0); datas.append(zline); dts.append(exit_dt)
            open_row[:] = -1
            if entry == dram.SRE:
                # self-refresh maintains cell charge internally: the
                # refresh deadline restarts at exit
                cycles_since_ref = 0.0
            else:
                cycles_since_ref += (_T.tBURST + _T.tRP + _T.tCKE + gap
                                     + exit_dt)
            continue
        dt = _T.tBURST + gap
        cmds.append(op); banks.append(b); rows.append(r)
        cols.append(int(col_seq[i])); datas.append(lines[i]); dts.append(dt)
        cycles_since_ref += dt
        if cycles_since_ref >= _T.tREFI:
            # refresh: close all banks, REF, reopen lazily
            cmds.append(dram.PREA); banks.append(0); rows.append(0)
            cols.append(0); datas.append(zline); dts.append(_T.tRP)
            cmds.append(REF); banks.append(0); rows.append(0); cols.append(0)
            datas.append(zline); dts.append(_T.tRFC)
            open_row[:] = -1
            cycles_since_ref = 0.0

    return dram.make_trace(cmds, banks, rows, cols,
                           np.stack(datas).astype(np.uint32), dts)


def reschedule_refresh(trace: CommandTrace,
                       period: int = _T.tREFI) -> CommandTrace:
    """Re-place the PREA+REF refresh pairs of a trace so every refresh
    interval meets the ``period`` deadline under the trace's *current* dts.

    Trace transforms that stretch command slots (e.g. the encoding LUT
    latency, Section 10.1) push the refreshes ``app_trace`` scheduled past
    the tREFI deadline — the same deadline-accounting bug class PR 1 fixed
    inside ``app_trace`` itself. This pass rebuilds the schedule with the
    generator's own rule: strip the existing PREA+REF pairs, walk the
    commands counting every slot's dt, refresh after the RD/WR that crosses
    the deadline, and lazily re-ACT banks the moved refresh closed (with a
    PRE first when a different row is open). RD/WR order, data, and slot
    durations are preserved; traces without REF pass through unchanged.
    """
    cmd = np.asarray(trace.cmd)
    if not (cmd == REF).any():
        return trace
    data = np.asarray(trace.data, dtype=np.uint32)
    n = len(cmd)

    keep = np.ones(n, dtype=bool)
    keep[cmd == REF] = False
    prea_before_ref = np.flatnonzero((cmd[:-1] == dram.PREA)
                                     & (cmd[1:] == REF))
    keep[prea_before_ref] = False

    # plain-int working lists: the walk is a Python loop, so per-element
    # numpy scalar access would dominate its cost; data lines are carried
    # as source-row indices and gathered once at the end
    kept = np.flatnonzero(keep)
    cmd_l = cmd[kept].tolist()
    bank_l = np.asarray(trace.bank)[kept].tolist()
    row_l = np.asarray(trace.row)[kept].tolist()
    col_l = np.asarray(trace.col)[kept].tolist()
    dt_l = np.asarray(trace.dt)[kept].tolist()
    src_l = kept.tolist()

    cmds, banks, rows, cols, srcs, dts = [], [], [], [], [], []
    open_row = [-1] * N_BANKS
    since = 0

    def emit(c, b, r, co, src, t):
        nonlocal since
        cmds.append(c); banks.append(b); rows.append(r)
        cols.append(co); srcs.append(src); dts.append(t)
        since += t

    for k in range(len(cmd_l)):
        c = cmd_l[k]
        b = bank_l[k]
        r = row_l[k]
        if (c == RD or c == WR) and open_row[b] != r:
            # the moved refresh closed this bank (or another row is open)
            if open_row[b] >= 0:
                emit(PRE, b, 0, 0, -1, _T.tRP)
            emit(ACT, b, r, 0, -1, _T.tRCD)
            open_row[b] = r
        if c == ACT:
            if open_row[b] == r:
                continue  # bank already open at this row: redundant
            if open_row[b] >= 0:
                emit(PRE, b, 0, 0, -1, _T.tRP)
            open_row[b] = r
        elif c == PRE:
            open_row[b] = -1
        elif c == dram.PREA:
            open_row = [-1] * N_BANKS
        emit(c, b, r, col_l[k], src_l[k], dt_l[k])
        if c == dram.SRX:
            since = 0  # self-refresh restarted the deadline internally
        if (c == RD or c == WR) and since >= period:
            emit(dram.PREA, 0, 0, 0, -1, _T.tRP)
            emit(REF, 0, 0, 0, -1, _T.tRFC)
            open_row = [-1] * N_BANKS
            since = 0

    src = np.asarray(srcs)
    out_data = np.zeros((len(src), LINE_WORDS), dtype=np.uint32)
    has_data = src >= 0
    out_data[has_data] = data[src[has_data]]
    # hand make_trace numpy arrays: jnp.asarray on a large Python list
    # walks it element by element and would dominate the whole pass
    return dram.make_trace(np.asarray(cmds, np.int32),
                           np.asarray(banks, np.int32),
                           np.asarray(rows, np.int32),
                           np.asarray(cols, np.int32), out_data,
                           dts=np.asarray(dts, np.int32))


def refresh_deadline_overshoot(trace: CommandTrace,
                               period: int = _T.tREFI) -> int:
    """Worst-case cycles by which any refresh interval of the trace exceeds
    the scheduling deadline (counted exactly as ``app_trace`` counts it: the
    PREA+REF slots start a new interval). <= the final slot's dt when the
    schedule conforms; large when refreshes have drifted."""
    cmd = np.asarray(trace.cmd)
    dt = np.asarray(trace.dt, dtype=np.int64)
    worst = 0
    since = 0
    for i in range(len(cmd)):
        if cmd[i] == REF:
            worst = max(worst, since - period)
            since = 0
            continue
        if cmd[i] == dram.SRX:
            since = 0  # self-refresh maintained the cells internally
            continue
        if cmd[i] == dram.PREA and i + 1 < len(cmd) and cmd[i + 1] == REF:
            continue  # the refresh pair's own slots open the next interval
        since += int(dt[i])
    return int(max(worst, since - period))


def trace_request_lines(trace: CommandTrace) -> np.ndarray:
    """The (n_rw, 16) data lines of the RD/WR commands in a trace."""
    cmd = np.asarray(trace.cmd)
    mask = (cmd == RD) | (cmd == WR)
    return np.asarray(trace.data)[mask]
