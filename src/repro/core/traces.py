"""Application-level DRAM command traces (paper Sections 9.2 and 10).

The paper drives its application studies with Pin-captured SPEC CPU2006
memory traces replayed through Ramulator. Without those proprietary inputs we
generate *synthetic application traces* from a small behavioral model —
memory intensity, row-buffer locality, read/write mix, and a byte-value
distribution — with per-app parameters chosen to span the same qualitative
range (memory-bound vs. compute-bound, sparse vs. dense data). The same
machinery also converts arbitrary byte buffers (e.g. framework tensors) into
traces, which is how the TPU/HBM adaptation feeds the model.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core import dram
from repro.core.dram import (ACT, NOP, PDE, PDE_SLOW, PDX, PRE, PREA, RD,
                             REF, SRE, SRX, WR, CommandTrace, TIMING,
                             LINE_BYTES, LINE_WORDS, N_BANKS)

_T = TIMING
_NEG = -(1 << 30)   # "never happened" sentinel time


class TraceBuilder:
    """Emit-order command builder that lands every command on a
    protocol-legal cycle by stretching the *previous* slot's ``dt`` (never
    reordering): the generator states WHAT happens, the builder owns WHEN.

    It tracks the same state the protocol linter
    (``repro.analysis.trace_lint``) checks — per-bank open rows and
    ACT/PRE/RD/WR times, the rolling four-activate window, global
    write-to-read turnaround, and the refresh / power-down-exit lockouts —
    and is a no-op (zero stretched cycles) on schedules that are already
    legal.  Exit lockouts are applied conservatively to every non-NOP
    command (tXPDLL formally binds only RD/WR), which can only lengthen a
    schedule, never break one.

    With ``pad_nop=True`` required lead time rides on an inserted NOP slot
    instead of stretching the previous slot's dt — for rewrites
    (:func:`reschedule_refresh`, the power-down policy) whose contract is
    that the source trace's slot durations are preserved."""

    def __init__(self, pad_nop: bool = False):
        self.pad_nop = pad_nop
        self.cmds: list[int] = []
        self.banks: list[int] = []
        self.rows: list[int] = []
        self.cols: list[int] = []
        self.datas: list = []
        self.dts: list[int] = []
        self.t = 0
        self.stretched = 0                # total cycles added by waits
        self.open_row = [-1] * N_BANKS
        self._act_t = [_NEG] * N_BANKS
        self._close_t = [_NEG] * N_BANKS
        self._wr_t = [_NEG] * N_BANKS
        self._rd_t = [_NEG] * N_BANKS
        self._acts = collections.deque(maxlen=4)
        self._last_act = self._last_wr = self._last_rw = _NEG
        self._busy_until = 0              # tRFC / tXP / tXPDLL / tXS
        self._slow_entry = False

    def _earliest(self, c: int, b: int) -> int:
        t = _NEG
        if c != NOP:
            t = max(t, self._busy_until)
        if c == ACT:
            t = max(t, self._close_t[b] + _T.tRP, self._act_t[b] + _T.tRC,
                    self._last_act + _T.tRRD)
            if len(self._acts) == 4:
                t = max(t, self._acts[0] + _T.tFAW)
        elif c == RD or c == WR:
            t = max(t, self._act_t[b] + _T.tRCD, self._last_rw + _T.tCCD)
            if c == RD:
                t = max(t, self._last_wr + _T.tBURST + _T.tWTR)
        elif c == PRE or c == PREA:
            for tb in (range(N_BANKS) if c == PREA else (b,)):
                if self.open_row[tb] >= 0:
                    t = max(t, self._act_t[tb] + _T.tRAS,
                            self._wr_t[tb] + _T.tBURST + _T.tWR,
                            self._rd_t[tb] + _T.tRTP)
        return t

    def emit(self, c, b=0, r=0, co=0, data=None, dt=0) -> None:
        c, b, r = int(c), int(b), int(r)
        need = self._earliest(c, b)
        if need > self.t:
            self.stretched += need - self.t
            if self.pad_nop or not self.dts:
                self.cmds.append(NOP)
                self.banks.append(0)
                self.rows.append(0)
                self.cols.append(0)
                self.datas.append(None)
                self.dts.append(need - self.t)
            else:
                self.dts[-1] += need - self.t
            self.t = need
        self.cmds.append(c)
        self.banks.append(b)
        self.rows.append(r)
        self.cols.append(int(co))
        self.datas.append(data)
        self.dts.append(int(dt))
        if c == ACT:
            self._act_t[b] = self.t
            self.open_row[b] = r
            self._acts.append(self.t)
            self._last_act = self.t
        elif c == PRE:
            self._close_t[b] = self.t
            self.open_row[b] = -1
        elif c == PREA:
            for tb in range(N_BANKS):
                self._close_t[tb] = self.t
                self.open_row[tb] = -1
        elif c == RD:
            self._rd_t[b] = self.t
            self._last_rw = self.t
        elif c == WR:
            self._wr_t[b] = self.t
            self._last_wr = self.t
            self._last_rw = self.t
        elif c == REF:
            self._busy_until = max(self._busy_until, self.t + _T.tRFC)
        elif c == PDE:
            self._slow_entry = False
        elif c == PDE_SLOW:
            self._slow_entry = True
        elif c == PDX:
            exit_lat = _T.tXPDLL if self._slow_entry else _T.tXP
            self._busy_until = max(self._busy_until, self.t + exit_lat)
        elif c == SRX:
            self._busy_until = max(self._busy_until, self.t + _T.tXS)
        self.t += int(dt)

    def require_open(self, b: int, r: int) -> None:
        """PRE (when another row is open) + ACT so row ``r`` of bank ``b``
        is open — the lazy re-activation every post-refresh / post-window
        access needs."""
        b, r = int(b), int(r)
        if self.open_row[b] == r:
            return
        if self.open_row[b] >= 0:
            self.emit(PRE, b, dt=_T.tRP)
        self.emit(ACT, b, r, dt=_T.tRCD)

    def build(self, origin: str | None = None) -> CommandTrace:
        """Materialize the trace (and lint it when ``origin`` is given)."""
        n = len(self.cmds)
        data = np.zeros((n, LINE_WORDS), dtype=np.uint32)
        for i, d in enumerate(self.datas):
            if d is not None:
                data[i] = d
        out = dram.make_trace(np.asarray(self.cmds, np.int32),
                              np.asarray(self.banks, np.int32),
                              np.asarray(self.rows, np.int32),
                              np.asarray(self.cols, np.int32), data,
                              dts=np.asarray(self.dts, np.int32))
        if origin is not None:
            from repro.analysis import trace_lint
            trace_lint.check_generated(out, origin)
        return out


# ---------------------------------------------------------------------------
# Byte-value distributions ("what the data looks like")
# ---------------------------------------------------------------------------
def _dist_zeros(rng):
    p = np.full(256, 0.0008)
    p[0x00] = 0.70
    p[0xFF] = 0.05
    p[0x01] = 0.05
    return p / p.sum()


def _dist_ascii(rng):
    p = np.full(256, 0.0004)
    for c in range(0x61, 0x7B):      # lowercase letters
        p[c] = 0.025
    p[0x20] = 0.12                    # space
    for c in range(0x41, 0x5B):
        p[c] = 0.004
    for c in range(0x30, 0x3A):
        p[c] = 0.006
    p[0x0A] = 0.01
    return p / p.sum()


def _dist_int_small(rng):
    # two's-complement integers: many 0x00 high bytes but also many 0xFF
    # sign-extension bytes (8 ones each) — the OWI sweet spot
    p = np.full(256, 0.0008)
    for v, w in ((0x00, 0.32), (0x01, 0.06), (0x02, 0.03), (0x03, 0.02),
                 (0xFF, 0.24), (0xFE, 0.05), (0xFD, 0.02), (0x04, 0.01),
                 (0x08, 0.01), (0x7F, 0.02)):
        p[v] = w
    return p / p.sum()


def _dist_fp32(rng):
    # float exponent bytes cluster at 0x3F/0xBF (6-7 ones) with uniform
    # mantissas
    p = np.full(256, 0.002)
    for v, w in ((0x3F, 0.12), (0xBF, 0.10), (0x40, 0.06), (0xC0, 0.05),
                 (0x3E, 0.05), (0xBE, 0.04), (0x00, 0.08), (0x80, 0.03),
                 (0x7F, 0.03)):
        p[v] = w
    return p / p.sum()


def _dist_pointer(rng):
    # 64-bit heap pointers: 0x00007f.. prefixes -> lots of 0x00 AND 0x7F/0xFF
    p = np.full(256, 0.0015)
    p[0x00] = 0.26
    p[0x7F] = 0.14
    p[0xFF] = 0.06
    p[0x55] = 0.04
    for v in range(0x10, 0x90, 0x08):
        p[v] = 0.01
    return p / p.sum()


def _dist_random(rng):
    return np.full(256, 1.0 / 256)


BYTE_DISTS = {
    "zeros": _dist_zeros, "ascii": _dist_ascii, "int_small": _dist_int_small,
    "fp32": _dist_fp32, "pointer": _dist_pointer, "random": _dist_random,
}


# ---------------------------------------------------------------------------
# Application behavioral model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AppSpec:
    name: str
    intensity: float      # mean fraction of bus cycles doing data bursts
    row_hit: float        # row-buffer hit probability
    read_frac: float
    data_dist: str
    seed: int = 0


# 23 synthetic applications mirroring the qualitative spread of the paper's
# SPEC CPU2006 suite (memory-bound <-> compute-bound; varied data content).
SPEC_APPS = [
    AppSpec("perlbench",  0.16, 0.75, 0.70, "ascii",     1),
    AppSpec("bzip2",      0.30, 0.55, 0.60, "random",    2),
    AppSpec("gcc",        0.25, 0.65, 0.65, "pointer",   3),
    AppSpec("mcf",        0.75, 0.25, 0.75, "pointer",   4),
    AppSpec("gobmk",      0.12, 0.70, 0.68, "int_small", 5),
    AppSpec("hmmer",      0.22, 0.90, 0.55, "int_small", 6),
    AppSpec("sjeng",      0.10, 0.72, 0.66, "int_small", 7),
    AppSpec("libquantum", 0.82, 0.95, 0.80, "zeros",     8),
    AppSpec("h264ref",    0.26, 0.88, 0.58, "int_small", 9),
    AppSpec("omnetpp",    0.55, 0.30, 0.70, "pointer",  10),
    AppSpec("astar",      0.45, 0.45, 0.72, "pointer",  11),
    AppSpec("xalancbmk",  0.50, 0.40, 0.74, "ascii",    12),
    AppSpec("bwaves",     0.72, 0.90, 0.65, "fp32",     13),
    AppSpec("gamess",     0.08, 0.82, 0.60, "fp32",     14),
    AppSpec("milc",       0.70, 0.82, 0.62, "fp32",     15),
    AppSpec("zeusmp",     0.50, 0.85, 0.61, "fp32",     16),
    AppSpec("gromacs",    0.18, 0.74, 0.63, "fp32",     17),
    AppSpec("cactusADM",  0.62, 0.86, 0.55, "fp32",     18),
    AppSpec("leslie3d",   0.66, 0.86, 0.60, "fp32",     19),
    AppSpec("namd",       0.10, 0.80, 0.64, "fp32",     20),
    AppSpec("soplex",     0.64, 0.35, 0.73, "fp32",     21),
    AppSpec("povray",     0.07, 0.78, 0.62, "fp32",     22),
    AppSpec("lbm",        0.85, 0.93, 0.50, "fp32",     23),
]


def sample_lines(dist_name: str, n_lines: int,
                 rng: np.random.Generator) -> np.ndarray:
    """(n_lines, 16) uint32 lines with bytes drawn from the distribution."""
    p = BYTE_DISTS[dist_name](rng)
    b = rng.choice(256, size=(n_lines, LINE_BYTES), p=p).astype(np.uint32)
    return (b[:, 0::4] | (b[:, 1::4] << 8) | (b[:, 2::4] << 16)
            | (b[:, 3::4] << 24)).astype(np.uint32)


def lines_from_bytes(buf: bytes | np.ndarray) -> np.ndarray:
    """Pack an arbitrary byte buffer into (n_lines, 16) uint32 lines."""
    b = np.frombuffer(bytes(buf), dtype=np.uint8)
    pad = (-len(b)) % LINE_BYTES
    if pad:
        b = np.concatenate([b, np.zeros(pad, dtype=np.uint8)])
    b = b.reshape(-1, LINE_BYTES).astype(np.uint32)
    return (b[:, 0::4] | (b[:, 1::4] << 8) | (b[:, 2::4] << 16)
            | (b[:, 3::4] << 24)).astype(np.uint32)


def app_trace(app: AppSpec, n_requests: int = 2000,
              lines: np.ndarray | None = None) -> CommandTrace:
    """Generate the command trace for one synthetic application.

    Commands are emitted through :class:`TraceBuilder`, so every request
    lands on a protocol-legal cycle (the builder stretches the previous
    slot when a back-to-back random schedule would violate e.g. tWTR or
    tRAS), and the result is linted before it is returned.
    """
    rng = np.random.default_rng(np.random.SeedSequence([29, app.seed]))
    if lines is None:
        lines = sample_lines(app.data_dist, n_requests, rng)
    n_requests = min(n_requests, lines.shape[0])

    bld = TraceBuilder()
    ref_anchor = 0  # builder time when the current refresh interval began
    # gap model: mean bus idle cycles between requests from intensity
    mean_gap = _T.tBURST * (1.0 - app.intensity) / max(app.intensity, 0.01)

    bank_seq = rng.integers(0, N_BANKS, size=n_requests)
    hit_seq = rng.random(n_requests) < app.row_hit
    rd_seq = rng.random(n_requests) < app.read_frac
    row_seq = rng.integers(0, 1 << dram.ROW_BITS, size=n_requests)
    col_seq = rng.integers(0, dram.COLS_PER_ROW, size=n_requests)
    gap_seq = rng.geometric(1.0 / (1.0 + mean_gap), size=n_requests) - 1

    for i in range(n_requests):
        b = int(bank_seq[i])
        if hit_seq[i] and bld.open_row[b] >= 0:
            r = bld.open_row[b]
        else:
            r = int(row_seq[i])
            if bld.open_row[b] >= 0:
                bld.emit(PRE, b, dt=_T.tRP)
            bld.emit(ACT, b, r, dt=_T.tRCD)
        op = RD if rd_seq[i] else WR
        gap = int(gap_seq[i])
        if gap > 128:
            # long idle: finish the burst, precharge, then spend the gap in
            # the deepest low-power state whose exit latency the gap can
            # absorb (fast PDN / slow PDN / self-refresh).  The entry slot
            # bills at the powered-up rate, the dwell rides on a NOP slot,
            # and the exit slot is the last one billed at the low-power
            # rate — the integrator's entry/exit billing semantics.
            if gap > 2048:
                entry, exit_cmd, exit_dt = dram.SRE, dram.SRX, _T.tXS
            elif gap > 512:
                entry, exit_cmd, exit_dt = dram.PDE_SLOW, dram.PDX, \
                    _T.tXPDLL
            else:
                entry, exit_cmd, exit_dt = dram.PDE, dram.PDX, _T.tXP
            bld.emit(op, b, r, int(col_seq[i]), lines[i], dt=_T.tBURST)
            bld.emit(PREA, dt=_T.tRP)
            if (entry != dram.SRE
                    and bld.t - ref_anchor + _T.tCKE + gap + exit_dt
                    >= _T.tREFI):
                # no refresh can be issued inside the power-down window, so
                # when the window would cross the deadline, refresh now
                # (re-stating PREA after keeps the [PREA, entry] adjacency
                # every power-down consumer in the repo expects)
                bld.emit(REF, dt=_T.tRFC)
                bld.emit(PREA, dt=0)
                ref_anchor = bld.t
            bld.emit(entry, dt=_T.tCKE)
            bld.emit(NOP, dt=gap)
            bld.emit(exit_cmd, dt=exit_dt)
            if entry == dram.SRE:
                # self-refresh maintains cell charge internally: the
                # refresh deadline restarts at exit
                ref_anchor = bld.t
            continue
        bld.emit(op, b, r, int(col_seq[i]), lines[i], dt=_T.tBURST + gap)
        if bld.t - ref_anchor >= _T.tREFI:
            # refresh: close all banks, REF, reopen lazily
            bld.emit(PREA, dt=_T.tRP)
            bld.emit(REF, dt=_T.tRFC)
            ref_anchor = bld.t

    return bld.build("traces.app_trace")


def reschedule_refresh(trace: CommandTrace,
                       period: int = _T.tREFI) -> CommandTrace:
    """Re-place the PREA+REF refresh pairs of a trace so every refresh
    interval meets the ``period`` deadline under the trace's *current* dts.

    Trace transforms that stretch command slots (e.g. the encoding LUT
    latency, Section 10.1) push the refreshes ``app_trace`` scheduled past
    the tREFI deadline — the same deadline-accounting bug class PR 1 fixed
    inside ``app_trace`` itself. This pass rebuilds the schedule with the
    generator's own rule: strip the existing PREA+REF pairs, walk the
    commands counting every slot's dt, refresh after the RD/WR that crosses
    the deadline, and lazily re-ACT banks the moved refresh closed (with a
    PRE first when a different row is open). RD/WR order, data, and slot
    durations are preserved — the :class:`TraceBuilder` walk adds a NOP
    wait slot when an inserted refresh pair needs lead time (e.g. tWR
    before its PREA); traces without REF pass through unchanged.
    """
    cmd = np.asarray(trace.cmd)
    if not (cmd == REF).any():
        return trace
    data = np.asarray(trace.data, dtype=np.uint32)
    n = len(cmd)

    keep = np.ones(n, dtype=bool)
    keep[cmd == REF] = False
    prea_before_ref = np.flatnonzero((cmd[:-1] == dram.PREA)
                                     & (cmd[1:] == REF))
    keep[prea_before_ref] = False

    # plain-int working lists: the walk is a Python loop, so per-element
    # numpy scalar access would dominate its cost
    kept = np.flatnonzero(keep)
    cmd_l = cmd[kept].tolist()
    bank_l = np.asarray(trace.bank)[kept].tolist()
    row_l = np.asarray(trace.row)[kept].tolist()
    col_l = np.asarray(trace.col)[kept].tolist()
    dt_l = np.asarray(trace.dt)[kept].tolist()
    data_l = [data[s] for s in kept]

    bld = TraceBuilder(pad_nop=True)
    anchor = 0
    n_kept = len(cmd_l)

    for k in range(n_kept):
        c = cmd_l[k]
        b = bank_l[k]
        r = row_l[k]
        if c == RD or c == WR:
            # the moved refresh may have closed this bank (or left another
            # row open): lazily re-open before replaying the access
            bld.require_open(b, r)
        if c == ACT:
            if bld.open_row[b] == r:
                continue  # bank already open at this row: redundant
            if bld.open_row[b] >= 0:
                bld.emit(PRE, b, dt=_T.tRP)
        if c == PDE or c == PDE_SLOW:
            # no refresh can be issued inside the power-down window: when
            # dwelling through it would cross the deadline, refresh first
            win = dt_l[k]
            j = k + 1
            while j < n_kept:
                win += dt_l[j]
                if cmd_l[j] == PDX:
                    break
                j += 1
            if bld.t - anchor + win >= period:
                if any(o >= 0 for o in bld.open_row):
                    bld.emit(PREA, dt=_T.tRP)
                bld.emit(REF, dt=_T.tRFC)
                # re-state PREA so the [PREA, entry] adjacency every
                # power-down consumer expects survives the inserted REF
                bld.emit(PREA, dt=0)
                anchor = bld.t
        bld.emit(c, b, r, col_l[k], data_l[k], dt_l[k])
        if c == SRX:
            anchor = bld.t  # self-refresh restarted the deadline internally
        if (c == RD or c == WR) and bld.t - anchor >= period:
            bld.emit(PREA, dt=_T.tRP)
            bld.emit(REF, dt=_T.tRFC)
            anchor = bld.t

    return bld.build("traces.reschedule_refresh")


def refresh_deadline_overshoot(trace: CommandTrace,
                               period: int = _T.tREFI) -> int:
    """Worst-case cycles by which any refresh interval of the trace exceeds
    the scheduling deadline (counted exactly as ``app_trace`` counts it: the
    PREA+REF slots start a new interval). <= the final slot's dt when the
    schedule conforms; large when refreshes have drifted."""
    cmd = np.asarray(trace.cmd)
    dt = np.asarray(trace.dt, dtype=np.int64)
    worst = 0
    since = 0
    for i in range(len(cmd)):
        if cmd[i] == REF:
            worst = max(worst, since - period)
            since = 0
            continue
        if cmd[i] == dram.SRX:
            since = 0  # self-refresh maintained the cells internally
            continue
        if cmd[i] == dram.PREA and i + 1 < len(cmd) and cmd[i + 1] == REF:
            continue  # the refresh pair's own slots open the next interval
        since += int(dt[i])
    return int(max(worst, since - period))


def trace_request_lines(trace: CommandTrace) -> np.ndarray:
    """The (n_rw, 16) data lines of the RD/WR commands in a trace."""
    cmd = np.asarray(trace.cmd)
    mask = (cmd == RD) | (cmd == WR)
    return np.asarray(trace.data)[mask]
