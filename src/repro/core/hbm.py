"""TPU/HBM adaptation of the paper's model (hardware-adaptation layer).

The paper characterizes DDR3L DIMMs. On the target hardware (TPU v5e pods)
the memory system is HBM2e: no exposed ACT/PRE command stream, but the same
physics — read/write energy depends on bytes moved and, per the paper's key
observation O2, on the *data values* moved. This module extrapolates the
fitted VAMPIRE read/write data-dependency model to an HBM-like energy-per-
byte model and combines it with the *compiled* per-step HBM traffic from the
dry-run cost analysis. It is an explicitly-labeled extrapolation (see
DESIGN.md §6): constants are rescaled, the functional form is the paper's.

Energy-per-bit scaling: DDR3L at 1.35 V measured here costs ~hundreds of mA
for a 64 B burst in ~10 ns => O(10) pJ/bit at the device level. Published
HBM2e figures are ~3.5-4 pJ/bit device+PHY. We rescale the fitted DDR3L
model by the ratio of its own all-zeros read energy to an HBM2e anchor, and
keep the paper's *relative* data dependency (ones fraction, toggle rate).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dram import LINE_BITS, LINE_BYTES, TCK_NS, TIMING, VDD
from repro.core.energy_model import PowerParams

# HBM2e anchor: pJ per bit for a random-data read at the device+PHY level.
HBM2E_PJ_PER_BIT_READ = 3.9
HBM2E_PJ_PER_BIT_WRITE = 4.1
# v5e HBM capacity/bandwidth for idle/refresh share estimation
HBM_BW_BYTES = 819e9
HBM_STATIC_W = 6.0  # background+refresh per chip stack, coarse anchor


@dataclasses.dataclass(frozen=True)
class HbmEnergyModel:
    """Data-dependent HBM read/write energy, VAMPIRE functional form."""
    pj_per_line_read_zero: float
    pj_per_line_read_per_one: float
    pj_per_line_read_per_toggle: float
    pj_per_line_write_zero: float
    pj_per_line_write_per_one: float
    pj_per_line_write_per_toggle: float

    @classmethod
    def from_vampire(cls, pp: PowerParams) -> "HbmEnergyModel":
        """Rescale the fitted DDR3L model to HBM2e anchors, preserving the
        paper's relative data dependency."""
        dd = np.asarray(pp.datadep)  # (4,2,3); use bank-interleaved mode (2)
        rd0, rd1, rdt = dd[2, 0]
        wr0, wr1, wrt = dd[2, 1]
        burst_ns = TIMING.tBURST * TCK_NS
        # DDR3L per-line energies (pJ) at 0 / per-one / per-toggle:
        e_rd0 = rd0 * VDD * burst_ns
        e_rd1 = (rd1 + float(pp.io_read_ma_per_one)) * VDD * burst_ns
        e_rdt = rdt * VDD * burst_ns
        e_wr0 = (wr0 + float(pp.io_write_ma_per_zero) * LINE_BITS
                 ) * VDD * burst_ns
        e_wr1 = (wr1 - float(pp.io_write_ma_per_zero)) * VDD * burst_ns
        e_wrt = wrt * VDD * burst_ns
        # rescale so a random line (50% ones) hits the HBM2e anchor
        tgt_rd = HBM2E_PJ_PER_BIT_READ * LINE_BITS
        tgt_wr = HBM2E_PJ_PER_BIT_WRITE * LINE_BITS
        s_rd = tgt_rd / (e_rd0 + e_rd1 * LINE_BITS / 2)
        s_wr = tgt_wr / (e_wr0 + e_wr1 * LINE_BITS / 2)
        return cls(e_rd0 * s_rd, e_rd1 * s_rd, e_rdt * s_rd,
                   e_wr0 * s_wr, e_wr1 * s_wr, e_wrt * s_wr)

    # ------------------------------------------------------------------
    def read_energy_pj(self, n_bytes, ones_frac, toggle_frac=0.25):
        lines = n_bytes / LINE_BYTES
        return lines * (self.pj_per_line_read_zero
                        + self.pj_per_line_read_per_one * ones_frac * LINE_BITS
                        + self.pj_per_line_read_per_toggle
                        * toggle_frac * LINE_BITS)

    def write_energy_pj(self, n_bytes, ones_frac, toggle_frac=0.25):
        lines = n_bytes / LINE_BYTES
        return lines * (self.pj_per_line_write_zero
                        + self.pj_per_line_write_per_one
                        * ones_frac * LINE_BITS
                        + self.pj_per_line_write_per_toggle
                        * toggle_frac * LINE_BITS)


def tensor_stats(x: jax.Array) -> tuple[float, float]:
    """(ones_fraction, toggle_fraction) of a tensor's raw bytes, via the
    popcount/toggle kernels (pure-jnp fallback if Pallas is unavailable)."""
    from repro.kernels.popcount import ops as pops
    from repro.kernels.toggle import ops as tops
    lines = _tensor_lines(x)
    ones = pops.line_ones(lines)
    togg = tops.line_toggles_seq(lines)
    n = lines.shape[0]
    return (float(jnp.sum(ones)) / (n * LINE_BITS),
            float(jnp.sum(togg)) / (max(n - 1, 1) * LINE_BITS))


def _tensor_lines(x: jax.Array) -> jax.Array:
    """View a tensor's bytes as (n_lines, 16) uint32 cache lines."""
    raw = jax.lax.bitcast_convert_type(
        x.reshape(-1), _u32_compatible(x.dtype)).reshape(-1).astype(jnp.uint32)
    if x.dtype.itemsize == 2:
        raw = raw[0::2] | (raw[1::2] << 16)
    elif x.dtype.itemsize == 1:
        raw = (raw[0::4] | (raw[1::4] << 8) | (raw[2::4] << 16)
               | (raw[3::4] << 24))
    n = (raw.shape[0] // 16) * 16
    return raw[:n].reshape(-1, 16)


def _u32_compatible(dtype):
    if dtype == jnp.float32 or dtype == jnp.int32 or dtype == jnp.uint32:
        return jnp.uint32
    if dtype.itemsize == 2:
        return jnp.uint16
    if dtype.itemsize == 1:
        return jnp.uint8
    raise ValueError(f"unsupported dtype {dtype}")


@dataclasses.dataclass
class StepEnergyReport:
    """Per-train/serve-step HBM energy estimate for one device."""
    read_bytes: float
    write_bytes: float
    read_pj: float
    write_pj: float
    static_pj: float
    total_pj: float
    ones_frac: float
    toggle_frac: float

    @property
    def total_j(self):
        return self.total_pj * 1e-12


def step_energy(model: HbmEnergyModel, *, read_bytes: float,
                write_bytes: float, step_seconds: float,
                ones_frac: float = 0.5, toggle_frac: float = 0.25
                ) -> StepEnergyReport:
    """Combine compiled-step traffic with data statistics -> energy."""
    rpj = float(model.read_energy_pj(read_bytes, ones_frac, toggle_frac))
    wpj = float(model.write_energy_pj(write_bytes, ones_frac, toggle_frac))
    spj = HBM_STATIC_W * step_seconds * 1e12
    return StepEnergyReport(read_bytes, write_bytes, rpj, wpj, spj,
                            rpj + wpj + spj, ones_frac, toggle_frac)
