"""Parameter tables transcribed from the paper, plus simulation anchors.

Three kinds of numbers live here:

1. **Verbatim paper data** — Table 5 data-dependency parameters (all four
   interleaving modes), the measured/datasheet IDD ratios of Section 4, the
   structural-variation magnitudes of Section 6, and the generational trends
   of Section 7. These define the *ground truth* behavior of the simulated
   module fleet (`device_sim`).
2. **Calibration anchors** — measured-mean IDD currents the paper reports
   numerically (IDD0/IDD1/IDD4*) or that we choose consistently with the
   paper's figures (idle/refresh/power-down levels, which the paper shows
   only graphically). Datasheet values are *derived* as measured / ratio so
   the reproduction is self-consistent by construction.
3. **Variation magnitudes** — per-vendor process-variation sigmas calibrated
   to the paper's reported normalized ranges, and measurement-noise levels.

Vendors are indexed 0=A, 1=B, 2=C throughout.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

VENDORS = ("A", "B", "C")
N_VENDORS = 3

# ---------------------------------------------------------------------------
# Table 5: data-dependency model parameters (mA).
#   I_total = I_zero + dI_one * N_ones + dI_tog * N_toggles
# Index order: [vendor][il_mode][op] -> (I_zero, dI_one, dI_tog)
# op: 0 = read, 1 = write; il_mode order matches dram.IL_* codes.
# ---------------------------------------------------------------------------
# (vendor, mode, op) table; modes: none, col, bank, bank+col
TABLE5 = np.array([
    # Vendor A
    [[[250.88, 0.449, 0.0000], [489.61, -0.217, 0.0000]],   # none
     [[246.44, 0.433, 0.0515], [531.18, -0.246, 0.0461]],   # col
     [[287.24, 0.244, 0.0200], [534.93, -0.249, 0.0225]],   # bank
     [[277.13, 0.267, 0.0200], [537.58, -0.249, 0.0225]]],  # bank+col
    # Vendor B
    [[[226.69, 0.164, 0.0000], [447.95, -0.191, 0.0000]],
     [[217.42, 0.157, 0.0947], [466.84, -0.215, 0.0166]],
     [[228.14, 0.159, 0.0364], [419.99, -0.179, 0.0078]],
     [[223.61, 0.152, 0.0364], [420.43, -0.179, 0.0078]]],
    # Vendor C
    [[[222.11, 0.134, 0.0000], [343.41, -0.000, 0.0000]],
     [[234.42, 0.154, 0.0856], [368.29, -0.116, 0.0229]],
     [[289.99, 0.034, 0.0455], [304.33, -0.054, 0.0455]],
     [[266.51, 0.099, 0.0090], [323.22, -0.072, 0.0090]]],
], dtype=np.float64)  # shape (3 vendors, 4 modes, 2 ops, 3 params)

# ---------------------------------------------------------------------------
# Measured-mean IDD anchors (mA). IDD0/IDD1 are the paper's own numbers
# (Section 4.2); idle / refresh / power-down levels are consistent with the
# paper's box plots (shown graphically only).
# ---------------------------------------------------------------------------
MEASURED_IDD = {
    #            A       B       C
    "IDD2N":  ( 32.0,   60.0,   45.0),   # idle, all banks precharged
    "IDD3N":  ( 46.0,   72.0,  135.3),   # idle, all banks open (C's large
                                          # per-bank increments, Sec 6.1.1)
    "IDD0":   ( 72.2,   70.4,   58.1),   # act/pre loop (paper Section 4.2)
    "IDD1":   (107.4,  114.9,   87.9),   # act/rd/pre loop (paper Section 4.2)
    "IDD5B":  (182.0,  164.0,  195.0),   # refresh burst
    "IDD2P1": ( 10.9,   41.6,   23.1),   # fast power-down (reductions of
                                          # 65.8/30.6/48.7% vs IDD2N, Sec 4.5)
    # The rest of the low-power lattice (Sec 4.2 / Fig 14: the paper reports
    # the low-power states as first-class IDD values). Ordered consistently
    # with JEDEC: IDD2P0 (slow PDN, DLL off) < IDD2P1 (fast PDN) < IDD2N,
    # and IDD2P1 < IDD3P (active PDN, banks open) < IDD3N; IDD6
    # (self-refresh) sits near the slow power-down floor.
    "IDD2P0": (  5.2,   18.4,    9.7),   # slow power-down, DLL off
    "IDD3P":  ( 19.8,   52.3,   38.9),   # active power-down (banks open)
    "IDD6":   (  7.4,   24.1,   13.6),   # self-refresh
}

# Section 4: average measured current as a fraction of the datasheet value.
# Datasheet values in the simulation are DERIVED as measured / ratio.
MEASURED_OVER_DATASHEET = {
    "IDD2N":  (0.383, 0.766, 0.549),
    "IDD3N":  (0.234, 0.532, 0.334),
    "IDD0":   (0.402, 0.426, 0.454),
    "IDD1":   (0.480, 0.470, 0.500),   # "very similar trends to IDD0"
    "IDD4R":  (0.526, 0.947, 1.114),   # raw (includes I/O driver current)
    "IDD4R_CORRECTED": (0.459, 0.795, 0.954),
    "IDD4W":  (0.491, 0.545, 0.590),
    "IDD7":   (0.584, 0.435, 0.527),
    "IDD5B":  (0.886, 0.720, 0.880),
    "IDD2P1": (0.55, 0.80, 0.65),      # consistent w/ Fig 14 (graphical)
    "IDD2P0": (0.52, 0.78, 0.61),      # low-power states follow the same
    "IDD3P":  (0.58, 0.82, 0.67),      # below-datasheet pattern (Fig 14,
    "IDD6":   (0.49, 0.75, 0.59),      # graphical)
}

# Full normalized range (max-min across same-vendor modules) as a fraction of
# the datasheet value -- used to calibrate process-variation sigma.
NORMALIZED_RANGE = {
    "IDD2N":  (0.147, 0.375, 0.20),    # Sec 4.1 (A range given; B given)
    "IDD3N":  (0.088, 0.193, 0.124),
    "IDD7":   (0.101, 0.179, 0.181),
    "IDD2P1": (0.048, 0.479, 0.173),
    "IDD2P0": (0.052, 0.455, 0.168),
    "IDD3P":  (0.050, 0.462, 0.170),
    "IDD6":   (0.055, 0.441, 0.165),
}

# Per-vendor multiplicative process-variation sigma for current parameters.
# Calibrated so module-to-module normalized ranges land near the table above
# (range ~ 4 sigma for ~15 modules) and so a vendor-mean fitted model shows
# per-module validation MAPE near the paper's 6.8% (Section 9.1).
PROCESS_SIGMA = (0.085, 0.095, 0.088)

# Per-module variation of the I/O driver strength (the rig measures the
# drivers; a vendor-mean fitted model cannot capture per-module driver
# variation, which contributes irreducible validation error).
IO_DRIVER_SIGMA = 0.15

# Relative measurement noise per averaged current sample (the paper averages
# >= 100 multimeter samples per test; residual noise is small).
MEASUREMENT_NOISE = 0.004

# Small unmodeled quadratic data dependence (fraction of the linear term at
# full-ones), so a linear fitted model retains irreducible error, consistent
# with the paper's <=1.40% worst-case model error in Sec 5.3.
ONES_QUAD_FRACTION = 0.012

# ---------------------------------------------------------------------------
# Section 5.1: I/O driver current. During reads the module's I/O drivers
# drive ones on the bus; vendor IDD4R specs EXCLUDE this, the rig measures
# it. We model it as a per-driven-one current on the 64 data wires.
# Fig 15 vs Fig 16 for Vendor A: ~434 mA total swing vs ~230 mA after
# subtracting the I/O estimate over 512 ones => ~0.4 mA/one io component.
# ---------------------------------------------------------------------------
IO_DRIVER_MA_PER_ONE_READ = 0.40   # module drives '1's on reads
IO_DRIVER_MA_PER_ZERO_WRITE = 0.39  # module drives '0's on writes

# ---------------------------------------------------------------------------
# Section 6.1.1: structural variation across banks (deterministic per vendor,
# identical for all modules of a vendor => "structural").
# Per-bank background-current increments when a bank is open (mA). Vendors A
# and B are ~uniform (Fig 19 shows little variation); Vendor C's increments
# are large and uneven, so the one-bank-open idle current varies by an
# average of 15.4% and up to 23.6% relative to Bank 0, as in the paper.
# sum(delta) == IDD3N - IDD2N for each vendor.
# ---------------------------------------------------------------------------
BANK_OPEN_DELTA = np.array([
    [1.753, 1.748, 1.751, 1.749, 1.752, 1.747, 1.750, 1.750],  # A (sum 14)
    [1.502, 1.497, 1.503, 1.501, 1.499, 1.498, 1.500, 1.500],  # B (sum 12)
    [5.000, 16.62, 11.00, 14.90, 9.200, 13.50, 8.080, 12.00],  # C (sum 90.3)
], dtype=np.float64)

BANK_READ_FACTORS = np.array([
    [1.000, 1.031, 0.985, 1.044, 0.992, 1.038, 0.978, 1.022],  # A
    [1.000, 0.973, 1.028, 0.981, 1.035, 0.969, 1.024, 0.988],  # B
    [1.000, 1.052, 0.964, 1.041, 0.957, 1.063, 0.972, 1.035],  # C (differs
], dtype=np.float64)                                            # from idle)

BANK_WRITE_FACTORS = np.ones((3, 8), dtype=np.float64)  # Fig 21: no variation

# Section 6.1.2: activation current grows linearly with ones in the row
# address. Fractional increase at 15 ones: A ~12%, B 14.6%, C ~3%.
ROW_ONES_SLOPE = np.array([0.12, 0.146, 0.03]) / 15.0  # per address-one

# Section 6 / Figs 19-22: structural variation SURFACE — the same banks and
# row regions across modules of one model consistently draw more activation
# charge than others. Modeled as a per-vendor multiplicative factor on the
# ACT(+PRE) charge per (bank, row band), sampled seed-stably per VENDOR
# (structural: identical for every module of a model, unlike the per-module
# process sigmas above) and normalized so band 0 — where every JEDEC loop
# and characterization probe lives — is exactly 1.0 per bank. Vendors A/B
# show mild surfaces; Vendor C's is strongly uneven, matching its outsized
# bank-to-bank structural variation in the paper.
STRUCTURAL_SURFACE_SIGMA = (0.03, 0.04, 0.10)

# ---------------------------------------------------------------------------
# Section 7: generational trends (Vendor C parts from 2011/2012 vs 2015).
# Datasheet IDDs promise large savings; measured savings are much smaller.
# We store per-generation multiplicative scale factors on measured currents
# and on datasheet currents, normalized to the 2015 part == 1.0, chosen to
# reproduce the paper's deltas (e.g. IDD0: promised -192.1 mA vs measured
# -64.0 mA moving 2011->2015).
# ---------------------------------------------------------------------------
GENERATIONS = (2011, 2012, 2015)
# measured-current scale (older parts draw somewhat more):
GEN_MEASURED_SCALE = {
    "IDD2N": (1.45, 1.20, 1.00),
    "IDD0":  (2.10, 1.55, 1.00),   # 58.1*2.10-58.1 = 63.9 mA measured saving
    "IDD4R": (1.41, 1.22, 1.00),   # ~140.6 mA measured saving
    "IDD4W": (1.73, 1.35, 1.00),   # ~147.4 mA measured saving
}
# datasheet scale (vendors promised much larger savings):
GEN_DATASHEET_SCALE = {
    "IDD2N": (1.95, 1.45, 1.00),
    "IDD0":  (2.50, 1.80, 1.00),   # 128*2.5-128 = 192 mA promised saving
    "IDD4R": (1.69, 1.35, 1.00),   # ~212 mA promised saving
    "IDD4W": (1.60, 1.30, 1.00),   # ~200 mA promised saving
}

# ---------------------------------------------------------------------------
# Module fleet roster (Table 1 + Table 3 of the paper).
# ---------------------------------------------------------------------------
class ModuleSpec(NamedTuple):
    vendor: int        # 0=A, 1=B, 2=C
    module_id: int     # unique within vendor
    year: int          # assembly year (2015 fleet unless generational study)
    chips: int = 4     # x16 chips per rank


def paper_fleet() -> list[ModuleSpec]:
    """The 50-module fleet of Table 1: 14 x A, 13 x B, 23 x C."""
    fleet = []
    for i in range(14):
        fleet.append(ModuleSpec(0, i, 2015))
    for i in range(13):
        fleet.append(ModuleSpec(1, i, 2014))
    for i in range(23):
        fleet.append(ModuleSpec(2, i, 2015))
    return fleet


def generational_fleet() -> list[ModuleSpec]:
    """Table 3: 3 modules from 2011 and 4 from 2012 (Vendor C)."""
    fleet = [ModuleSpec(2, 100 + i, 2011) for i in range(3)]
    fleet += [ModuleSpec(2, 200 + i, 2012) for i in range(4)]
    return fleet


def datasheet_idd(key: str, vendor: int) -> float:
    """Datasheet (spec) current derived from measured anchors and Section 4
    measured/datasheet ratios. For IDD4R/IDD4W/IDD7 the measured anchor is
    not an explicit table entry; callers should use `derive_datasheets()`."""
    return MEASURED_IDD[key][vendor] / MEASURED_OVER_DATASHEET[key][vendor]
