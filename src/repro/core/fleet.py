"""Batched fleet-evaluation engine for the characterization campaign.

The paper's campaign is 50 modules x 9 IDD loops x hundreds of
data-dependency/structural probe points. Evaluated serially (one
``measure_current`` per (module, probe) pair) that is thousands of
separately-dispatched, separately-compiled JAX calls; here the whole
campaign collapses into a handful of fixed-shape batched dispatches:

* :func:`stack_params` stacks per-module :class:`PowerParams` pytrees along
  a leading module axis (the layout ``energy_model.PowerParams`` was designed
  for).
* probe points of unequal length are NOP/dt=0-padded into one
  ``(probes, commands)`` batch with a skip/validity mask
  (:func:`repro.core.dram.batch_traces`).
* :func:`fleet_measure_current` evaluates the whole (modules, probes) current
  matrix with a single jitted ``vmap(vmap(...))`` over the shared integrator.
* measurement noise comes from the counter-based RNG in ``device_sim`` and is
  applied to the full matrix at once — bit-identical to what the serial
  oracle draws per call, so both engines fit the same parameters.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import device_sim
from repro.core.dram import CommandTrace, batch_traces
from repro.core.energy_model import (PowerParams, charge_from_features,
                                     extract_structural_features,
                                     finalize_features, masked_totals)


def stack_params(params: Sequence[PowerParams]) -> PowerParams:
    """Stack per-module parameter pytrees along a leading module axis.

    Vectorized leaf concatenation: one host-side ``np.stack`` per leaf
    POSITION (16 for ``PowerParams``) and one device transfer each —
    not a ``jnp.stack`` with one operand per module, which builds (and
    eagerly dispatches) an M-operand concatenate and dominated the old
    per-call restack at fleet scale.  Falls back to the tree_map stack
    under tracing (leaves are tracers, not host arrays)."""
    params = list(params)
    leaves0, treedef = jax.tree_util.tree_flatten(params[0])
    if any(isinstance(leaf, jax.core.Tracer) for leaf in leaves0):
        return jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves), *params)
    cols = zip(*(jax.tree_util.tree_flatten(p)[0] for p in params))
    stacked = [jnp.asarray(np.stack([np.asarray(x) for x in col]))
               for col in cols]
    return jax.tree_util.tree_unflatten(treedef, stacked)


class FleetStackCache:
    """Memoized, device-resident stacked fleet params — the zero-restack
    dispatch artifact.

    The campaign engines historically re-ran ``stack_params`` over the
    whole module list on EVERY ``run_probes`` / ``fleet_surface_energy``
    call (twice per vendor per fit, once per surface map).  Here the
    stacked ``PowerParams`` is built once per fleet and reused: keyed on
    fleet identity (the module objects, which own immutable params) plus
    the target mesh, placed device-resident via
    ``model_api.device_resident`` — sharded over the module axis
    (``NamedSharding`` on the mesh's ``model`` axis) when a dividing
    multi-device mesh is passed, replicated otherwise — so repeat
    dispatches neither restack nor re-transfer parameters."""

    def __init__(self, maxsize: int = 8):
        self.maxsize = maxsize
        self._entries: dict = {}     # key -> (modules_ref, stacked)
        self._order: list = []
        self.hits = 0
        self.misses = 0

    def stacked(self, modules, mesh=None) -> PowerParams:
        from repro.core import model_api
        key = (tuple(id(m) for m in modules), mesh)
        hit = self._entries.get(key)
        if hit is not None:
            self.hits += 1
            self._order.remove(key)
            self._order.append(key)
            return hit[1]
        self.misses += 1
        stacked = stack_params([m.params for m in modules])
        axis = None
        if mesh is not None and mesh.shape.get("model", 1) > 1 \
                and len(modules) % mesh.shape["model"] == 0:
            axis = "model"
        stacked = model_api.device_resident(stacked, mesh, axis=axis)
        # hold a strong ref to the module list: the id()-keyed entry must
        # never outlive (or alias) the objects it is keyed on
        self._entries[key] = (tuple(modules), stacked)
        self._order.append(key)
        while len(self._order) > self.maxsize:
            self._entries.pop(self._order.pop(0))
        return stacked

    def clear(self):
        self._entries.clear()
        self._order.clear()


#: the process-wide fleet-stack cache both campaign engines route through
FLEET_STACK_CACHE = FleetStackCache()


def fleet_stacked(modules, mesh=None) -> PowerParams:
    """The cached stacked params of a fleet: accepts a module sequence
    (memoized via :data:`FLEET_STACK_CACHE`) or an already-stacked
    ``PowerParams`` (returned as-is — the synthetic-fleet path, where no
    module objects exist)."""
    if isinstance(modules, PowerParams):
        return modules
    return FLEET_STACK_CACHE.stacked(tuple(modules), mesh)


@dataclasses.dataclass(frozen=True)
class ProbePoint:
    """One measurement of the campaign: a looped microbenchmark trace, the
    number of setup commands to skip, and a stable noise key."""
    label: tuple
    trace: CommandTrace
    skip: int
    key: int


@dataclasses.dataclass
class ProbeBatch:
    """A padded, fixed-shape batch of probe points (see ``batch_traces``)."""
    trace: CommandTrace   # (P, N) leading probe axis on every field
    weight: jax.Array     # (P, N) float32 measurement mask
    keys: np.ndarray      # (P,) noise keys

    @classmethod
    def from_points(cls, points: Sequence[ProbePoint]) -> "ProbeBatch":
        trace, weight = batch_traces([(p.trace, p.skip) for p in points])
        return cls(trace, weight, np.asarray([p.key for p in points]))

    def select(self, idx) -> "ProbeBatch":
        """Row-gather a sub-batch: the padded trace/weight rows at ``idx``
        plus their noise keys.  A fixed-size ``idx`` keeps downstream
        jitted dispatches on one compiled program — the telemetry path
        (``repro.core.recalibrate``) round-robins fixed-width cell slices
        through this."""
        idx = np.asarray(idx)
        trace = jax.tree_util.tree_map(lambda x: x[idx], self.trace)
        return ProbeBatch(trace, self.weight[idx], self.keys[idx])

    def with_keys(self, keys: np.ndarray) -> "ProbeBatch":
        """The same padded batch under different noise keys (each
        telemetry tick re-keys its slice so the rig draws fresh noise)."""
        return ProbeBatch(self.trace, self.weight, np.asarray(keys))


def batched_pair_totals(tr: CommandTrace, w: jax.Array, sf,
                        stacked: PowerParams):
    """The shared core of both batched engines (campaign measurement here,
    model estimation in ``repro.core.estimate_batch``): one padded item's
    (per-paramset masked charge, masked cycles). The parameter-independent
    structural pass ``sf`` ran ONCE for the item; only the open-bank
    background finalize + charge accumulation is vmapped over the stacked
    parameter sets."""
    def one_paramset(pp: PowerParams):
        charges = charge_from_features(tr, finalize_features(sf, pp), pp)
        return masked_totals(tr, w, charges)

    charge, cycles = jax.vmap(one_paramset)(stacked)
    return charge, cycles[0]


@jax.jit
def fleet_measure_current(trace: CommandTrace, weight: jax.Array,
                          stacked: PowerParams) -> jax.Array:
    """Noise-free average current of every (module, probe) pair.

    ``trace``/``weight`` are a ProbeBatch's padded fields; ``stacked`` is
    ``stack_params`` over the fleet. Returns a float32 (modules, probes)
    matrix."""
    def one_probe(tr: CommandTrace, w: jax.Array):
        charge, cycles = batched_pair_totals(
            tr, w, extract_structural_features(tr), stacked)
        return charge / jnp.maximum(cycles.astype(jnp.float32), 1.0)

    return jax.vmap(one_probe)(trace, weight).T  # -> (modules, probes)


def fleet_measure_current_pallas(trace: CommandTrace, weight: jax.Array,
                                 stacked: PowerParams) -> jax.Array:
    """The ``impl='pallas'`` twin of :func:`fleet_measure_current`: the
    same (modules, probes) matrix through the fused batched kernel family
    (``kernels/vampire_energy``), with the probe axis as the kernel's
    trace axis and the module axis as its vendor axis.  The true simulator
    params' ``ones_quad`` curvature is part of the kernel, so the
    characterization campaign measures identical currents on this path."""
    from repro.kernels.vampire_energy import ops as vops
    charge, cycles = vops.batched_charge_matrix(trace, weight, stacked)
    return (charge / jnp.maximum(cycles.astype(jnp.float32), 1.0)[:, None]).T


def fleet_surface_energy(modules, trace: CommandTrace, weight: jax.Array,
                         impl: str = "vectorized", *, mesh=None,
                         module_chunk: int | None = None,
                         trace_chunk: int | None = None):
    """Ground-truth structural-variation surfaces of the WHOLE module
    fleet in one batched dispatch (paper Figs 19-22 as fleet-wide maps):
    an :class:`~repro.core.energy_model.EnergyReport` whose leaves are
    ``(traces, modules, banks, row_bands)``-shaped — the estimation
    engine's surface dispatch with the stacked per-module *true* params on
    the vendor axis.  ``impl`` is ``'vectorized'`` or ``'pallas'``.
    ``modules`` is a module sequence (stacked once and memoized —
    :func:`fleet_stacked`) or an already-stacked ``PowerParams`` (the
    synthetic-fleet path, ``device_sim.synth_fleet_params``).

    With a ``(data, model)`` ``mesh`` (``launch.mesh.make_local_mesh``),
    the dispatch ``shard_map``\\ s the trace axis over ``data`` and the
    module axis over ``model`` — every (trace, module) pair is independent,
    so the sharded result is bitwise identical to the single-device one.
    Falls back to the plain dispatch when the axes don't divide the mesh
    (or the mesh is a single device), with identical numerics either way.

    ``module_chunk`` (optionally ``trace_chunk``) switches to the
    memory-bounded chunked dispatch
    (``estimate_batch.chunked_surface_reports``) — exact parity with the
    one-shot path, live memory bounded to one chunk's intermediates, the
    fleet-scale path for 10k+ module fleets.  Chunking and mesh sharding
    are mutually exclusive (pass one or the other)."""
    from repro.core import estimate_batch, model_api
    impl = model_api.resolve_impl(impl, mode="surface").name
    if impl == "reference":
        raise ValueError("impl='reference' for the fleet surface is the "
                         "per-command oracle; score modules one at a time")
    if module_chunk is not None or trace_chunk is not None:
        if mesh is not None:
            raise ValueError("module_chunk/trace_chunk and mesh are "
                             "mutually exclusive surface strategies")
        stacked = fleet_stacked(modules)
        return estimate_batch.chunked_surface_reports(
            trace, weight, stacked,
            module_chunk=(stacked.i2n.shape[0] if module_chunk is None
                          else module_chunk),
            trace_chunk=trace_chunk, impl=impl)
    stacked = fleet_stacked(modules, mesh)
    n_modules = stacked.i2n.shape[0]
    if mesh is not None:
        n_data = mesh.shape.get("data", 1)
        n_model = mesh.shape.get("model", 1)
        if (n_data * n_model > 1
                and trace.cmd.shape[0] % n_data == 0
                and n_modules % n_model == 0):
            return _sharded_surface_fn(mesh, impl == "pallas")(
                trace, weight, stacked)
    dispatch = (estimate_batch.pallas_batched_surface_reports
                if impl == "pallas"
                else estimate_batch.batched_surface_reports)
    return dispatch(trace, weight, stacked)


@functools.lru_cache(maxsize=8)
def _sharded_surface_fn(mesh, pallas: bool):
    """The jitted shard_map'd surface dispatch for one (mesh, impl) pair:
    traces over 'data', modules over 'model'.  Memoized so repeat calls on
    the same mesh reuse the compiled program.

    Only the CHARGE program is shard_map'd — the ``_report`` finalization
    runs outside it, exactly like the unsharded and chunked dispatches, so
    all three paths share one finalization program and stay bitwise
    identical to each other."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import estimate_batch
    from repro.core.energy_model import _report
    from repro.kernels.common import interpret_default
    interpret = interpret_default() if pallas else False

    def charge_fn(trace, weight, stacked):
        return estimate_batch._surface_chunk_charge(
            trace, weight, stacked, pallas, interpret)

    sharded_charge = jax.jit(shard_map(
        charge_fn, mesh=mesh,
        in_specs=(P("data"), P("data"), P("model")),
        out_specs=P("data", "model"),
        check_rep=False))

    def run(trace, weight, stacked):
        charge = sharded_charge(trace, weight, stacked)
        cycles = estimate_batch._surface_cycles_batch(trace, weight)
        return _report(charge,
                       jnp.broadcast_to(cycles[:, None], charge.shape))

    return run


@functools.lru_cache(maxsize=8)
def _sharded_measure_fn(mesh, pallas: bool):
    """The jitted shard_map'd campaign measurement for one (mesh, impl)
    pair: probes over 'data', modules over 'model' — the (modules, probes)
    current matrix with every axis evaluated where its shard lives."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    measure = (fleet_measure_current_pallas if pallas
               else fleet_measure_current)
    return jax.jit(shard_map(
        measure, mesh=mesh,
        in_specs=(P("data"), P("data"), P("model")),
        out_specs=P("model", "data"),
        check_rep=False))


def run_probes(modules, points: Sequence[ProbePoint], *,
               engine: str = "batched", noisy: bool = True,
               batch: ProbeBatch | None = None,
               impl: str = "vectorized", mesh=None) -> np.ndarray:
    """Measure every probe point on every module -> (modules, probes) mA.

    ``engine='batched'`` is the production path (a single jitted dispatch per
    padded batch shape); ``engine='serial'`` replays the campaign one
    ``measure_current`` call at a time and is kept as the correctness
    oracle — both draw identical per-(module, probe) noise. Callers issuing
    the same point list repeatedly should pass a prebuilt ``batch`` to skip
    re-padding (see ``characterize.CampaignPlan``).

    ``impl`` picks the batched engine's evaluation path through the shared
    registry: ``'vectorized'`` (vmapped jnp) or ``'pallas'`` (the fused
    kernels).  The per-command oracle is spelled ``engine='serial'`` here;
    contradictions are loud errors rather than silent substitutions
    (``impl='reference'`` with the batched engine points at
    ``engine='serial'``, ``impl='pallas'`` with the serial engine raises).

    The stacked fleet params come from the zero-restack cache
    (:func:`fleet_stacked`) — repeat calls over the same fleet reuse one
    device-resident stacked artifact instead of restacking per call.
    With a dividing multi-device ``mesh`` the measurement ``shard_map``\\ s
    probes over ``data`` and modules over ``model`` (bitwise identical to
    the single-device dispatch — every (module, probe) pair is
    independent)."""
    from repro.core import model_api
    impl = model_api.resolve_impl(impl).name
    if engine == "serial":
        if impl == "pallas":
            raise ValueError("engine='serial' is the per-command oracle; "
                             "impl='pallas' requires engine='batched'")
        return np.asarray(
            [[m.measure_current(p.trace, noisy=noisy, skip=p.skip,
                                probe_key=p.key)
              for p in points] for m in modules])
    if engine != "batched":
        raise ValueError(f"unknown engine {engine!r}")
    if impl == "reference":
        raise ValueError("impl='reference' for the campaign is "
                         "engine='serial' (the per-command oracle)")
    if batch is None:
        batch = ProbeBatch.from_points(points)
    stacked = fleet_stacked(modules, mesh)
    measure = (fleet_measure_current_pallas if impl == "pallas"
               else fleet_measure_current)
    if mesh is not None:
        n_data = mesh.shape.get("data", 1)
        n_model = mesh.shape.get("model", 1)
        if (n_data * n_model > 1
                and batch.trace.cmd.shape[0] % n_data == 0
                and stacked.i2n.shape[0] % n_model == 0):
            measure = _sharded_measure_fn(mesh, impl == "pallas")
    currents = np.asarray(measure(batch.trace, batch.weight, stacked),
                          dtype=np.float64)
    if noisy:
        currents = currents * device_sim.measurement_noise_factors(
            [m.spec for m in modules], batch.keys)
    return currents
