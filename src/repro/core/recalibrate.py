"""Online recalibration from streaming telemetry (the ``'streaming'``
fitter of the ``model_api`` fitter registry).

The offline campaign (``repro.core.characterize``) measures every probe
cell once and inverts the slot accounting once — and then the planted
ground truth keeps drifting (``device_sim.DriftProcess``: temperature,
aging), so the fitted ``FleetModel`` goes stale exactly the way the paper
showed datasheets do.  This module closes the loop:

* :class:`TelemetrySource` — the drifting rig.  Each tick it measures a
  fixed-width round-robin SLICE of the campaign's probe cells on the live
  (drifted) fleet, re-keying the measurement noise per tick.  One jitted
  dispatch per tick (drift factors + slot integrator fused), one compiled
  program across all ticks.
* :class:`StreamingFitter` — the estimation side.  It maintains decayed
  running sufficient statistics per probe cell (per module x cell moment
  arrays, a jit-able pytree updated by ONE compiled, f64-free step —
  :func:`fitting.decayed_moment_update`), scores each incoming slice
  against the current model's predicted cell currents (per-key
  standardized residuals — the drift detector), and on demand re-runs the
  campaign's *exact* inversion (``characterize.invert_campaign``) over the
  decayed cell means, emitting a TREEDEF-STABLE ``Vampire`` refresh: the
  new model unflattens against the original treedef (identity-hashed aux),
  so ``ServingEngine.update_model`` swaps it in with zero new compiled
  programs.
* :func:`fleet_current_mape` — the evaluation yardstick tests and
  ``benchmarks/bench_recalibrate.py`` gate on: model-predicted vs
  ground-truth loop currents over a validation batch.

Telemetry noise keys live at :data:`_TELEMETRY_KEY_BASE` (1 << 24), far
above the campaign's ``_IDD_KEY_BASE``/``_PROBE_KEY_BASE`` and the
simulator's ad-hoc counter base (1 << 20), striding by tick so every tick
draws fresh, reconstructible noise.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterize, device_sim, fitting, fleet, model_api
from repro.core import params as P
from repro.core.characterize import IDD_KEYS
from repro.core.device_sim import DEFAULT_DRIFT, DriftProcess
from repro.core.fleet import ProbeBatch

# Per-tick telemetry noise keys: base + tick * stride + campaign key.  The
# stride clears every campaign key (< _PROBE_KEY_BASE + a few hundred) and
# the base clears the simulator's ad-hoc counter family (1 << 20), so no
# (module, key) noise draw ever collides across families or ticks.
_TELEMETRY_KEY_BASE = 1 << 24
_TELEMETRY_KEY_STRIDE = 1 << 13


@dataclasses.dataclass(frozen=True)
class RecalConfig:
    """Shape of the telemetry stream and the incremental fit.

    The campaign-plan knobs (``probe_reps``/``n_rows``/``rng_seed``) pick
    WHICH probe cells exist — they must match between the telemetry source
    and the fitter, which is why both take one config.  ``decay`` is the
    per-observation retention of old evidence per cell (1.0 = plain
    running mean); ``slice_size`` is the fixed telemetry width per tick;
    ``drift_threshold`` is the standardized-residual trigger;
    ``detector_floor`` is the relative systematic-error floor folded into
    the residual scale (the linear fit cannot reproduce the planted
    ``ones_quad`` curvature exactly, so pure measurement-noise scaling
    would false-positive on a healthy model)."""
    probe_reps: int = 64
    n_rows: int = 8
    rng_seed: int = 0
    probe_modules: int = 2
    decay: float = 0.9
    slice_size: int = 64
    drift_threshold: float = 3.0
    detector_floor: float = 0.01
    seed_weight: float = 1.0


@functools.lru_cache(maxsize=4)
def _recal_cells(probe_reps: int, n_rows: int, rng_seed: int):
    """(plan, points, padded batch) of the full probe-cell set: the
    campaign's IDD loops first (cells 0..11), then every probe point."""
    plan = characterize.campaign_plan(probe_reps=probe_reps, n_rows=n_rows,
                                      rng_seed=rng_seed)
    points = tuple(plan.idd_points) + tuple(plan.probe_points)
    return plan, points, ProbeBatch.from_points(points)


def recal_cells(config: RecalConfig):
    return _recal_cells(config.probe_reps, config.n_rows, config.rng_seed)


def cell_group(label: tuple) -> str:
    """The drift detector's per-key grouping of a probe-cell label."""
    if label[0] == "idd":
        return f"idd/{label[1]}"
    return str(label[0])


# ---------------------------------------------------------------------------
# The drifting rig
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("drift",))
def _drifted_slice_currents(trace, weight, base_stack, vendors, module_ids,
                            tick, drift: DriftProcess):
    """Noise-free (modules, slice) currents of the drifted fleet at a
    tick: drift factors + slot integrator in one compiled program (tick is
    traced, so every tick reuses it)."""
    drifted = device_sim.apply_drift(base_stack, vendors, module_ids, tick,
                                     drift)
    return fleet.fleet_measure_current(trace, weight, drifted)


class TelemetrySource:
    """Per-tick probe-cell telemetry from a drifting simulated fleet.

    Each tick measures a fixed-width round-robin slice of the cell set on
    every module, under the seed-stable drifted ground truth
    (``device_sim.apply_drift``) and fresh per-tick measurement noise —
    the streaming stand-in for the rig's continuous monitoring loop."""

    def __init__(self, modules, config: RecalConfig | None = None, *,
                 drift: DriftProcess = DEFAULT_DRIFT, noisy: bool = True):
        self.modules = list(modules)
        self.config = RecalConfig() if config is None else config
        self.drift = drift
        self.noisy = noisy
        self.specs = [m.spec for m in self.modules]
        self.plan, self.points, self.batch = recal_cells(self.config)
        self.n_cells = len(self.points)
        self.base_stack = fleet.stack_params(
            [m.params for m in self.modules])
        self._v = jnp.asarray([s.vendor for s in self.specs], jnp.uint32)
        self._m = jnp.asarray([s.module_id for s in self.specs], jnp.uint32)

    def slice_indices(self, tick: int) -> np.ndarray:
        """The round-robin cell slice of a tick (fixed width, so the
        measurement and the stats update each stay one program)."""
        width = min(self.config.slice_size, self.n_cells)
        return (tick * width + np.arange(width)) % self.n_cells

    def measure(self, tick: int, cell_idx=None):
        """-> ((modules, cells) currents, cell indices) at ``tick``."""
        idx = (self.slice_indices(tick) if cell_idx is None
               else np.asarray(cell_idx))
        sub = self.batch.select(idx)
        cur = _drifted_slice_currents(sub.trace, sub.weight,
                                      self.base_stack, self._v, self._m,
                                      jnp.uint32(tick), self.drift)
        cur = np.asarray(cur, np.float64)
        if self.noisy:
            keys = (_TELEMETRY_KEY_BASE
                    + np.int64(tick) * _TELEMETRY_KEY_STRIDE
                    + np.asarray(sub.keys, np.int64))
            cur = cur * device_sim.measurement_noise_factors(self.specs,
                                                             keys)
        return cur, idx

    def true_params_at(self, tick: int):
        """The reconstructed ground-truth parameter stack at any tick."""
        return device_sim.apply_drift(self.base_stack, self._v, self._m,
                                      jnp.uint32(tick), self.drift)


# ---------------------------------------------------------------------------
# The incremental fitter
# ---------------------------------------------------------------------------
class RunningStats(NamedTuple):
    """Decayed per-(module, cell) sufficient statistics — a jit-able
    pytree of f32 moment arrays (evidence mass + exponentially weighted
    mean current)."""
    weight: jax.Array   # (modules, cells) f32
    mean: jax.Array     # (modules, cells) f32


@jax.jit
def _update_stats(stats: RunningStats, currents, cell_idx, decay,
                  predicted, scale_floor):
    """ONE incremental update step (compiled once, f32 end to end): decay
    the observed cells' moments into the new observations and score the
    incoming slice against the current model's predicted cell currents.

    Returns ``(stats', z)`` where ``z`` is the per-cell standardized
    residual of the slice's module-mean current vs the model prediction —
    scaled by measurement noise of the mean plus the relative systematic
    floor (see ``RecalConfig.detector_floor``)."""
    w = stats.weight[:, cell_idx]
    m = stats.mean[:, cell_idx]
    new_w, new_m = fitting.decayed_moment_update(w, m, currents, decay)
    out = RunningStats(stats.weight.at[:, cell_idx].set(new_w),
                       stats.mean.at[:, cell_idx].set(new_m))
    n_modules = currents.shape[0]
    meas = jnp.mean(currents, axis=0)
    pred = jnp.mean(predicted[:, cell_idx], axis=0)
    noise = P.MEASUREMENT_NOISE / np.sqrt(n_modules)
    scale = jnp.abs(pred) * (noise + scale_floor) + 1e-6
    return out, (meas - pred) / scale


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """One telemetry tick's drift verdict."""
    tick: int
    score: float                 # worst per-key standardized residual
    by_key: dict[str, float]     # mean |z| per probe-cell group
    triggered: bool


class StreamingFitter:
    """The ``'streaming'`` fitter: decayed sufficient statistics per probe
    cell, a per-key drift detector, and treedef-stable model refreshes.

    Build one via ``model_api.fit(fitter='streaming')`` (or
    :func:`streaming_fitter`), feed it telemetry with :meth:`observe`, and
    hand :meth:`refit` results to ``ServingEngine.update_model`` — the
    refreshed model reuses the original model's treedef (identity-hashed
    aux), so every warm compiled program keeps hitting."""

    def __init__(self, model, specs, config: RecalConfig | None = None):
        self.config = RecalConfig() if config is None else config
        self.specs = list(specs)
        self.plan, self.points, self.batch = recal_cells(self.config)
        self.n_cells = len(self.points)
        self.groups = [cell_group(p.label) for p in self.points]
        self.model = model
        self._treedef = jax.tree_util.tree_flatten(model)[1]
        vendor_order = list(model.vendors)
        self._vendor_rows = {
            v: [i for i, s in enumerate(self.specs) if s.vendor == v]
            for v in vendor_order}
        self._pred_rows = np.asarray(
            [vendor_order.index(s.vendor) for s in self.specs])
        self._decay = jnp.float32(self.config.decay)
        self._floor = jnp.float32(self.config.detector_floor)
        self._refresh_predictions()
        seed_w = jnp.full((len(self.specs), self.n_cells),
                          self.config.seed_weight, jnp.float32)
        # seed the moments with the model's own predicted currents: every
        # cell is defined before its first telemetry arrives, and a refit
        # with no evidence reproduces (approximately) the current model
        self.stats = RunningStats(seed_w, self._predicted)
        self.ticks_observed = 0
        self.last_report: DriftReport | None = None

    def _refresh_predictions(self) -> None:
        """(modules, cells) noise-free currents the CURRENT model implies
        for every probe cell — the drift detector's reference (same
        compiled integrator as the telemetry source)."""
        pred_stack = jax.tree_util.tree_map(
            lambda x: x[self._pred_rows], self.model.fleet.params)
        self._predicted = jnp.asarray(fleet.fleet_measure_current(
            self.batch.trace, self.batch.weight, pred_stack), jnp.float32)

    # ------------------------------------------------------------- ingest
    def observe(self, currents, cell_idx, tick: int) -> DriftReport:
        """Fold one telemetry slice into the sufficient statistics and
        score it for drift.  ``currents`` is (modules, cells) over the
        SAME module order as ``specs``; ``cell_idx`` indexes the cell
        set."""
        idx = jnp.asarray(np.asarray(cell_idx), jnp.int32)
        cur = jnp.asarray(np.asarray(currents), jnp.float32)
        self.stats, z = _update_stats(self.stats, cur, idx, self._decay,
                                      self._predicted, self._floor)
        z = np.abs(np.asarray(z, np.float64))
        by_key: dict[str, list] = {}
        for j, cell in enumerate(np.asarray(cell_idx)):
            by_key.setdefault(self.groups[int(cell)], []).append(z[j])
        scores = {k: float(np.mean(v)) for k, v in sorted(by_key.items())}
        score = max(scores.values()) if scores else 0.0
        self.ticks_observed += 1
        self.last_report = DriftReport(
            tick=int(tick), score=score, by_key=scores,
            triggered=score >= self.config.drift_threshold)
        return self.last_report

    # -------------------------------------------------------------- refit
    def refit(self):
        """Invert the decayed cell means into a fresh parameter stack and
        emit the treedef-stable model refresh (also adopted as the
        detector's new reference)."""
        mean = np.asarray(self.stats.mean, np.float64)
        fitted = []
        for v, rows in self._vendor_rows.items():
            idd = {key: mean[rows, i] for i, key in enumerate(IDD_KEYS)}
            probe_rows = rows[:self.config.probe_modules]
            pm = mean[probe_rows, len(IDD_KEYS):].mean(axis=0)
            cur = {pt.label: float(pm[i])
                   for i, pt in enumerate(self.plan.probe_points)}
            vc = characterize.invert_campaign(self.plan, v, cur, idd)
            fitted.append(vc.fitted)
        new_fm = self.model.fleet._replace(
            params=fleet.stack_params(fitted))
        self.model = jax.tree_util.tree_unflatten(
            self._treedef, jax.tree_util.tree_leaves(new_fm))
        self._refresh_predictions()
        return self.model


def streaming_fitter(modules=None, *, init_model=None,
                     config: RecalConfig | None = None, **campaign_kw):
    """Factory behind ``model_api.fit(..., fitter='streaming')``: prime a
    :class:`StreamingFitter` on an initial model (``init_model=``, or a
    fresh campaign fit of the fleet with the config's plan knobs)."""
    modules = device_sim.make_fleet() if modules is None else list(modules)
    config = RecalConfig() if config is None else config
    if init_model is None:
        init_model = model_api.fit(
            "vampire", modules, fitter="campaign",
            probe_modules=config.probe_modules,
            probe_reps=config.probe_reps, n_rows=config.n_rows,
            rng_seed=config.rng_seed, **campaign_kw)
    return StreamingFitter(init_model, [m.spec for m in modules], config)


# ---------------------------------------------------------------------------
# Evaluation yardstick
# ---------------------------------------------------------------------------
def fleet_current_mape(model, trace, weight, specs, true_stacked) -> float:
    """Mean absolute relative current error of ``model`` against a
    (possibly drifted) ground-truth parameter stack over a padded
    validation batch: both sides run through the same compiled integrator
    (``fleet.fleet_measure_current``), the model's side with each module's
    vendor-fitted params."""
    vendor_order = list(model.vendors)
    rows = np.asarray([vendor_order.index(s.vendor) for s in specs])
    pred_stack = jax.tree_util.tree_map(lambda x: x[rows],
                                        model.fleet.params)
    est = np.asarray(fleet.fleet_measure_current(trace, weight, pred_stack),
                     np.float64)
    truth = np.asarray(fleet.fleet_measure_current(trace, weight,
                                                   true_stacked),
                       np.float64)
    return float(np.mean(np.abs(est - truth) / np.maximum(truth, 1e-9)))
