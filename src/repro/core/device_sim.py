"""Simulated DRAM module fleet — the stand-in for the paper's 50 physical
DDR3L SO-DIMMs plus the FPGA/SoftMC + current-probe measurement rig.

Ground truth per module = the shared energy integrator with *true* parameters
drawn around the paper's published per-vendor values (Table 5, Section 4/6/7),
perturbed by seeded per-module process variation, carrying the vendor's
structural per-(bank, row-band) activation surface (:func:`structural_surface`
— identical across modules of a vendor, which is what distinguishes it from
process variation), plus effects a fitted linear model cannot capture exactly:

* multiplicative measurement noise per test (the rig averages >=100 samples),
* a small quadratic term in the ones-dependence (``ones_quad``),
* per-row random activation-charge jitter (process, not structural).

Everything is seeded by (vendor, module_id, year): re-creating a module gives
bit-identical behavior, which is what lets the characterization pipeline be
deterministic and the validation honest (fit on some modules / workloads,
validate on others).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import params as P
from repro.core.dram import CommandTrace, N_BANKS, N_ROW_BANDS
from repro.core.energy_model import (EnergyReport, PowerParams,
                                     trace_energy_vectorized)

from repro.core.dram import TIMING as _T


def _gen_scale(key: str, year: int) -> float:
    table = P.GEN_MEASURED_SCALE.get(key)
    if table is None or year >= 2015:
        return 1.0
    idx = {2011: 0, 2012: 1}.get(year, 2)
    return table[idx]


@functools.lru_cache(maxsize=None)
def structural_surface(vendor: int) -> np.ndarray:
    """The planted per-(bank, row-band) structural ACT-charge surface of a
    vendor (paper Section 6 / Figs 19-22): one seed-stable (8, N_ROW_BANDS)
    multiplicative factor map shared by EVERY module of the vendor — that
    sharing is what makes it structural rather than process variation.
    Band 0 (the band every standard loop and probe addresses) is the
    per-bank reference: exactly 1.0."""
    rng = np.random.default_rng(
        np.random.SeedSequence([29, vendor]))
    sig = P.STRUCTURAL_SURFACE_SIGMA[vendor]
    surf = np.exp(rng.normal(0.0, sig, (N_BANKS, N_ROW_BANDS)))
    surf /= surf[:, :1]          # band 0 == 1.0 per bank (reference band)
    return surf


def true_vendor_params(vendor: int, year: int = 2015) -> PowerParams:
    """Vendor-mean ground-truth parameters (no process variation)."""
    datadep = jnp.asarray(P.TABLE5[vendor], dtype=jnp.float32)
    gen_rw = _gen_scale("IDD4R", year)
    gen_w = _gen_scale("IDD4W", year)
    scale_rw = jnp.asarray([[gen_rw], [gen_w]], dtype=jnp.float32)  # (2,1)
    datadep = datadep * scale_rw[None, :, :]

    i2n = P.MEASURED_IDD["IDD2N"][vendor] * _gen_scale("IDD2N", year)
    delta = np.asarray(P.BANK_OPEN_DELTA[vendor]) * _gen_scale("IDD2N", year)

    # q_actpre from the measured IDD0 anchor.  Loop background follows the
    # integrator's semantics (state BEFORE each command): the bank is
    # closed during the ACT slot (tRAS) and open during the PRE slot
    # (tRP), so the open-bank increment weights tRP — making the simulated
    # IDD0 loop land exactly on the anchor.
    idd0 = P.MEASURED_IDD["IDD0"][vendor] * _gen_scale("IDD0", year)
    trc_cyc = float(_T.tRAS + _T.tRP)
    bg_loop = (i2n * _T.tRAS + (i2n + float(delta[0])) * _T.tRP) / trc_cyc
    q_actpre = max((idd0 - bg_loop), 5.0) * trc_cyc

    idd5b = P.MEASURED_IDD["IDD5B"][vendor]
    q_ref = (idd5b - i2n) * float(_T.tRFC)

    return PowerParams(
        datadep=datadep,
        i2n=jnp.asarray(i2n, jnp.float32),
        bank_open_delta=jnp.asarray(delta, jnp.float32),
        bank_read_factor=jnp.asarray(P.BANK_READ_FACTORS[vendor], jnp.float32),
        bank_write_factor=jnp.asarray(P.BANK_WRITE_FACTORS[vendor],
                                      jnp.float32),
        q_actpre=jnp.asarray(q_actpre, jnp.float32),
        row_ones_slope=jnp.asarray(P.ROW_ONES_SLOPE[vendor], jnp.float32),
        q_ref=jnp.asarray(q_ref, jnp.float32),
        i_pd=jnp.asarray(P.MEASURED_IDD["IDD2P1"][vendor], jnp.float32),
        io_read_ma_per_one=jnp.asarray(P.IO_DRIVER_MA_PER_ONE_READ,
                                       jnp.float32),
        io_write_ma_per_zero=jnp.asarray(P.IO_DRIVER_MA_PER_ZERO_WRITE,
                                         jnp.float32),
        ones_quad=jnp.asarray(P.ONES_QUAD_FRACTION, jnp.float32),
        act_surface=jnp.asarray(structural_surface(vendor), jnp.float32),
        # the rest of the background-state LUT (paper Sec 4.2 / Fig 14).
        # i_sr subsumes the per-REF charge: refresh is internal during
        # self-refresh, so the anchor is the whole self-refresh current.
        i_pd_slow=jnp.asarray(P.MEASURED_IDD["IDD2P0"][vendor], jnp.float32),
        i_actpd=jnp.asarray(P.MEASURED_IDD["IDD3P"][vendor], jnp.float32),
        i_sr=jnp.asarray(P.MEASURED_IDD["IDD6"][vendor], jnp.float32),
    )


def _module_rng(spec: P.ModuleSpec) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([17, spec.vendor, spec.module_id, spec.year]))


def true_module_params(spec: P.ModuleSpec) -> PowerParams:
    """Per-module ground truth = vendor mean x seeded process variation."""
    base = true_vendor_params(spec.vendor, spec.year)
    rng = _module_rng(spec)
    sig = P.PROCESS_SIGMA[spec.vendor]

    def f(scale=1.0):  # one lognormal-ish multiplicative factor
        return float(np.exp(rng.normal(0.0, sig * scale)))

    dd = np.asarray(base.datadep)
    dd = dd * np.array([f(), f(0.6), f(1.5)])[None, None, :]
    io_sig = P.IO_DRIVER_SIGMA
    io_f = float(np.exp(rng.normal(0.0, io_sig)))
    io_f2 = float(np.exp(rng.normal(0.0, io_sig)))
    # act_surface is deliberately NOT perturbed here: the surface is
    # structural — bit-identical across every module of the vendor.
    # NOTE: the low-power draws are appended AFTER every pre-existing draw
    # (f() calls consume the module rng in order) so adding leaves never
    # moves the seeded stream of the leaves that came before them.
    return base._replace(
        datadep=jnp.asarray(dd, jnp.float32),
        i2n=base.i2n * f(1.2),
        bank_open_delta=base.bank_open_delta * f(),
        q_actpre=base.q_actpre * f(),
        q_ref=base.q_ref * f(0.5),
        i_pd=base.i_pd * f(1.5 if spec.vendor == 1 else 0.6),
        io_read_ma_per_one=base.io_read_ma_per_one * io_f,
        io_write_ma_per_zero=base.io_write_ma_per_zero * io_f2,
        i_pd_slow=base.i_pd_slow * f(0.6),
        i_actpd=base.i_actpd * f(0.6),
        i_sr=base.i_sr * f(0.5),
    )


# ---------------------------------------------------------------------------
# Synthetic fleets of arbitrary size: the scale-out twin of the paper's
# 50-module rig.
#
# ``true_module_params`` draws its process variation from a *sequential*
# numpy stream (order-sensitive by design — see the NOTE there), which is
# perfect for the 50 bench modules but serializes at fleet scale: 10k-50k
# modules would mean 10k-50k Python RNG walks.  The synthetic-fleet family
# below instead derives every module's variation from the counter-based
# JAX RNG (``fold_in`` on (vendor, module id, year), the same discipline
# as the measurement noise), so a whole fleet's parameter stack is ONE
# vmapped draw: vendor-consistent (same per-vendor means, process sigmas,
# IO-driver sigma and structural surfaces as the rig), seed-stable per
# module id (module k's params never depend on the fleet size around it),
# and float32 end to end.  Synthetic modules are a separate seeded family
# from the rig's numpy stream — fleet-scale studies, not refits of the
# paper's 50.
# ---------------------------------------------------------------------------
_SYNTH_ROOT = 0xF1EE7

#: per-draw sigma scales, mirroring the ``true_module_params`` draw list
#: (datadep x3, io x2, i2n, bank_open_delta, q_actpre, q_ref, i_pd,
#: i_pd_slow, i_actpd, i_sr); the i_pd column is vendor-dependent and
#: patched in-place inside ``_synth_factors``.
_SYNTH_SCALES = (1.0, 0.6, 1.5, None, None, 1.2, 1.0, 1.0, 0.5, None,
                 0.6, 0.6, 0.5)


@jax.jit
def _synth_factors(vendors, module_ids, years):
    """(n,) module identities -> (n, 13) multiplicative lognormal process
    factors, one counter-based draw per module (vectorized, order-free)."""
    base = jax.random.key(_SYNTH_ROOT)
    sig = jnp.asarray(P.PROCESS_SIGMA, jnp.float32)[vendors]      # (n,)

    def draws(v, m, y):
        k = jax.random.fold_in(
            jax.random.fold_in(jax.random.fold_in(base, v), m), y)
        return jax.random.normal(k, (13,), jnp.float32)

    z = jax.vmap(draws)(vendors, module_ids, years)               # (n, 13)
    io = jnp.full_like(sig, P.IO_DRIVER_SIGMA)
    i_pd_scale = jnp.where(vendors == 1, 1.5, 0.6) * sig
    cols = [io if s is None else s * sig for s in _SYNTH_SCALES]
    cols[3], cols[4], cols[9] = io, io, i_pd_scale
    return jnp.exp(z * jnp.stack(cols, axis=1))


def synth_fleet_params(n_modules: int | None = None, *, year: int = 2015,
                       vendors=None, module_ids=None):
    """Ground-truth ``PowerParams`` stack for a synthetic fleet of
    arbitrary size -> ``((n,) vendor ids, stacked params)`` with a leading
    module axis on every leaf.

    Vendors default to round-robin over the three rig vendors (so any
    prefix of a bigger fleet is itself a valid fleet); pass ``vendors``
    (and optionally ``module_ids``) to pin the mix.  Entirely vectorized:
    no per-module Python loop anywhere, which is what lets
    ``benchmarks/bench_fleetscale.py`` stand up 10k-50k module fleets."""
    if vendors is None:
        if n_modules is None:
            raise ValueError("need n_modules or an explicit vendors array")
        vendors = np.arange(int(n_modules), dtype=np.uint32) % 3
    vendors = np.asarray(vendors, np.uint32)
    if module_ids is None:
        module_ids = np.arange(vendors.shape[0], dtype=np.uint32)
    module_ids = np.asarray(module_ids, np.uint32)
    years = np.full(vendors.shape, year, np.uint32)

    base = jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves),
        *[true_vendor_params(v, year) for v in range(3)])
    v_idx = jnp.asarray(vendors, jnp.int32)
    g = jax.tree_util.tree_map(lambda x: x[v_idx], base)
    f = _synth_factors(jnp.asarray(vendors), jnp.asarray(module_ids),
                       jnp.asarray(years))
    stacked = g._replace(
        datadep=g.datadep * f[:, None, None, 0:3],
        i2n=g.i2n * f[:, 5],
        bank_open_delta=g.bank_open_delta * f[:, 6, None],
        q_actpre=g.q_actpre * f[:, 7],
        q_ref=g.q_ref * f[:, 8],
        i_pd=g.i_pd * f[:, 9],
        io_read_ma_per_one=g.io_read_ma_per_one * f[:, 3],
        io_write_ma_per_zero=g.io_write_ma_per_zero * f[:, 4],
        i_pd_slow=g.i_pd_slow * f[:, 10],
        i_actpd=g.i_actpd * f[:, 11],
        i_sr=g.i_sr * f[:, 12],
    )
    return vendors, stacked


# ---------------------------------------------------------------------------
# Measurement noise: counter-based, seed-stable, vectorizable.
#
# Each measurement's multiplicative noise is a pure function of
# (module identity, probe key), computed with JAX's counter-based RNG, so the
# noise a probe sees is independent of measurement *order*: the serial
# correctness oracle and the batched fleet engine draw bit-identical factors
# for the same (module, probe) pair, and a whole (modules, probes) matrix of
# factors is one vectorized call.
# ---------------------------------------------------------------------------
_NOISE_ROOT = 0x5EED
# probe keys below this are reserved for explicitly-keyed campaign probes;
# ad-hoc (unkeyed) measurements draw from a per-module counter above it.
_ADHOC_KEY_BASE = 1 << 20


@jax.jit
def _noise_normals(vendors, module_ids, years, probe_keys):
    """(M,) module identity arrays x (K,) probe keys -> (M, K) unit normals."""
    base = jax.random.key(_NOISE_ROOT)

    def module_key(v, m, y):
        return jax.random.fold_in(
            jax.random.fold_in(jax.random.fold_in(base, v), m), y)

    keys = jax.vmap(module_key)(vendors, module_ids, years)
    return jax.vmap(lambda k: jax.vmap(
        lambda p: jax.random.normal(jax.random.fold_in(k, p)))(probe_keys)
    )(keys)


def measurement_noise_factors(specs, probe_keys) -> np.ndarray:
    """The (len(specs), len(probe_keys)) matrix of multiplicative measurement
    noise factors — lognormal with sigma ``params.MEASUREMENT_NOISE``."""
    v = jnp.asarray([s.vendor for s in specs], jnp.uint32)
    m = jnp.asarray([s.module_id for s in specs], jnp.uint32)
    y = jnp.asarray([s.year for s in specs], jnp.uint32)
    z = _noise_normals(v, m, y, jnp.asarray(probe_keys, jnp.uint32))
    return np.exp(P.MEASUREMENT_NOISE * np.asarray(z))


@dataclasses.dataclass
class SimulatedModule:
    """One simulated DIMM attached to the simulated measurement rig."""
    spec: P.ModuleSpec
    params: PowerParams = None  # type: ignore[assignment]

    def __post_init__(self):
        if self.params is None:
            self.params = true_module_params(self.spec)
        self._adhoc_probe_counter = _ADHOC_KEY_BASE

    # -- the "multimeter": average current over a looped microbenchmark ----
    def measure_current(self, trace: CommandTrace, noisy: bool = True,
                        skip: int = 0, probe_key: int | None = None) -> float:
        """Average current. ``skip`` drops the first N commands (one-time
        setup) from the average — the rig starts sampling only once the
        steady-state loop is running, as in the paper's methodology.
        ``probe_key`` pins the measurement-noise draw to a stable key so
        serial and batched campaign engines agree; without it, each call
        consumes the module's ad-hoc counter."""
        if skip:
            from repro.core.energy_model import per_command_energy
            e = per_command_energy(trace, self.params)[skip:]
            cyc = jnp.sum(trace.dt[skip:], dtype=jnp.int32)
            from repro.core.dram import TCK_NS, VDD
            cur = float(jnp.sum(e) / (TCK_NS * VDD)
                        / jnp.maximum(cyc.astype(jnp.float32), 1.0))
        else:
            rep = trace_energy_vectorized(trace, self.params)
            cur = float(rep.avg_current_ma)
        if noisy:
            if probe_key is None:
                probe_key = self._adhoc_probe_counter
                self._adhoc_probe_counter += 1
            cur *= float(measurement_noise_factors([self.spec],
                                                   [probe_key])[0, 0])
        return cur

    def measure_report(self, trace: CommandTrace) -> EnergyReport:
        return trace_energy_vectorized(trace, self.params)


def make_fleet(specs=None) -> list[SimulatedModule]:
    specs = P.paper_fleet() if specs is None else specs
    return [SimulatedModule(s) for s in specs]


def vendor_modules(fleet, vendor: int):
    return [m for m in fleet if m.spec.vendor == vendor]


# ---------------------------------------------------------------------------
# Drift: the planted ground truth does not hold still after the one-shot
# characterization campaign.  Real modules wander with temperature and age
# monotonically, which is exactly why a fitted FleetModel goes stale the
# way the datasheets did (the recalibration story,
# ``repro.core.recalibrate``).
#
# The drift trajectory is a PURE FUNCTION of (vendor, module id, tick) —
# counter-based ``fold_in`` draws plus closed-form temperature/aging
# curves, never a random walk — so any tick's ground truth is
# reconstructible directly (no history to replay), the serial and batched
# telemetry engines agree bit-for-bit, and a whole fleet's factors at a
# tick are one vmapped draw.
# ---------------------------------------------------------------------------
_DRIFT_ROOT = 0xD81F7

#: PowerParams fields scaled by the background/leakage drift factor
#: (temperature-sensitive standby and low-power currents + refresh charge).
DRIFT_BG_FIELDS = ("i2n", "bank_open_delta", "i_pd", "i_pd_slow",
                   "i_actpd", "i_sr", "q_ref")
#: PowerParams fields scaled by the activation/data drift factor
#: (aging-dominated charge and drive currents).
DRIFT_ACT_FIELDS = ("q_actpre", "datadep")


@dataclasses.dataclass(frozen=True)
class DriftProcess:
    """Seed-stable temperature/aging drift of the planted parameters.

    * ``temp_amp``/``temp_period`` — a sinusoidal ambient-temperature
      trajectory (fractional amplitude, ticks per cycle) with a seeded
      per-module phase: thermal wander, reversible.
    * ``aging_rate``/``act_aging_rate`` — monotone linear degradation per
      tick of the background and activation groups: aging, irreversible.
    * ``noise_sigma`` — per-tick lognormal jitter, counter-based on
      (vendor, module, tick).
    * ``step_tick``/``step_frac`` — an optional planted vendor-wide step
      change (both factor groups) at a known tick: the drift-detector
      test fixture.

    Frozen + hashable so the factor computation can be jitted with the
    process as a static argument."""
    temp_amp: float = 0.03
    temp_period: float = 96.0
    aging_rate: float = 1.2e-3
    act_aging_rate: float = 8e-4
    noise_sigma: float = 0.002
    step_tick: int | None = None
    step_frac: float = 0.0


DEFAULT_DRIFT = DriftProcess()
NO_DRIFT = DriftProcess(temp_amp=0.0, aging_rate=0.0, act_aging_rate=0.0,
                        noise_sigma=0.0)


@functools.partial(jax.jit, static_argnames=("drift",))
def _drift_factor_arrays(vendors, module_ids, tick, drift: DriftProcess):
    """(n,) module identities x scalar tick -> ((n,) bg, (n,) act)
    multiplicative drift factors, straight from the closed form."""
    base = jax.random.key(_DRIFT_ROOT)
    t = jnp.asarray(tick, jnp.float32)
    tick_i = jnp.asarray(tick, jnp.uint32)

    def per_module(v, m):
        k = jax.random.fold_in(jax.random.fold_in(base, v), m)
        phase = jax.random.uniform(jax.random.fold_in(k, 0),
                                   maxval=2.0 * jnp.pi)
        z = jax.random.normal(jax.random.fold_in(
            jax.random.fold_in(k, 1), tick_i), (2,), jnp.float32)
        return phase, z

    phase, z = jax.vmap(per_module)(jnp.asarray(vendors, jnp.uint32),
                                    jnp.asarray(module_ids, jnp.uint32))
    season = jnp.sin(2.0 * jnp.pi * t / drift.temp_period + phase)
    step = jnp.float32(1.0)
    if drift.step_tick is not None:
        step = 1.0 + drift.step_frac * (t >= drift.step_tick).astype(
            jnp.float32)
    bg = ((1.0 + drift.temp_amp * season)
          * (1.0 + drift.aging_rate * t)
          * jnp.exp(drift.noise_sigma * z[:, 0]) * step)
    act = ((1.0 + 0.5 * drift.temp_amp * season)
           * (1.0 + drift.act_aging_rate * t)
           * jnp.exp(drift.noise_sigma * z[:, 1]) * step)
    return bg, act


def drift_factors(vendors, module_ids, tick: int,
                  drift: DriftProcess = DEFAULT_DRIFT):
    """Reconstruct the ((n,) bg, (n,) act) drift factors at any tick."""
    bg, act = _drift_factor_arrays(jnp.atleast_1d(jnp.asarray(vendors)),
                                   jnp.atleast_1d(jnp.asarray(module_ids)),
                                   tick, drift)
    return np.asarray(bg), np.asarray(act)


def apply_drift(stacked: PowerParams, vendors, module_ids, tick,
                drift: DriftProcess = DEFAULT_DRIFT) -> PowerParams:
    """Drifted ground truth at ``tick`` for a module-stacked params pytree
    (leading module axis on every leaf, as built by ``fleet.stack_params``
    or :func:`synth_fleet_params`)."""
    bg, act = _drift_factor_arrays(jnp.asarray(vendors, jnp.uint32),
                                   jnp.asarray(module_ids, jnp.uint32),
                                   tick, drift)
    updates = {}
    for field in DRIFT_BG_FIELDS + DRIFT_ACT_FIELDS:
        leaf = getattr(stacked, field)
        f = bg if field in DRIFT_BG_FIELDS else act
        extra = leaf.ndim - f.ndim
        updates[field] = leaf * f.reshape(f.shape + (1,) * extra)
    return stacked._replace(**updates)


def drifted_module_params(spec: P.ModuleSpec, tick: int,
                          drift: DriftProcess = DEFAULT_DRIFT) -> PowerParams:
    """One module's drifted ground truth at ``tick`` (rig family)."""
    base = true_module_params(spec)
    stacked = jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], base)
    out = apply_drift(stacked, [spec.vendor], [spec.module_id], tick, drift)
    return jax.tree_util.tree_map(lambda x: x[0], out)


def drifted_fleet(fleet, tick: int,
                  drift: DriftProcess = DEFAULT_DRIFT):
    """The rig fleet with every module's params replaced by the drifted
    ground truth at ``tick`` (fresh ``SimulatedModule`` objects; the input
    fleet is untouched)."""
    return [SimulatedModule(m.spec,
                            drifted_module_params(m.spec, tick, drift))
            for m in fleet]
