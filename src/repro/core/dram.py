"""DRAM geometry, commands, timing, and command-trace representation.

Everything here models the exact device class characterized by the paper:
DDR3L-800 SO-DIMMs, one rank, 8 banks, 64-byte cache lines (512 bits),
nominal VDD = 1.35 V. Traces are JAX pytrees so the whole power pipeline
(ground-truth simulation, VAMPIRE, baselines) is jit/vmap-able.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Device constants (DDR3L-800, matching Table 1 of the paper)
# ---------------------------------------------------------------------------
VDD = 1.35                  # volts (DDR3L nominal)
N_BANKS = 8
LINE_BYTES = 64             # one cache line per RD/WR across the rank
LINE_BITS = LINE_BYTES * 8  # 512
LINE_WORDS = LINE_BYTES // 4  # 16 uint32 words
ROW_BITS = 15               # 32k rows per bank (2 GB single-rank module)
COLS_PER_ROW = 128          # 128 cache lines per 8 kB row
# Structural-variation surface geometry (paper Section 6 / Figs 19-22): rows
# are grouped into equal contiguous bands for the per-(bank, row-band)
# energy decomposition; band 0 (rows < 4096) is the reference band every
# standard loop and probe lives in.
N_ROW_BANDS = 8
ROW_BAND_SHIFT = ROW_BITS - 3   # row >> 12 -> band in [0, 8)
MT_PER_S = 800e6            # transfer rate used for all tests (FPGA limit)
CLOCK_HZ = MT_PER_S / 2     # 400 MHz DRAM clock
TCK_NS = 1e9 / CLOCK_HZ     # 2.5 ns


class Timing(NamedTuple):
    """DDR3L-800 timing parameters, in DRAM clock cycles (tCK = 2.5 ns)."""
    tRCD: int = 6    # 13.75 ns
    tRP: int = 6     # 13.75 ns
    tRAS: int = 14   # 35 ns
    tRC: int = 20    # tRAS + tRP
    tCCD: int = 4    # column-to-column (== burst length / 2 at DDR)
    tBURST: int = 4  # 8 beats DDR -> 4 clocks on the bus
    tRFC: int = 64   # 160 ns (2 Gb parts)
    tREFI: int = 3120  # 7.8 us
    tWR: int = 6     # 15 ns write recovery
    tRTP: int = 4    # read-to-precharge
    tCKE: int = 3    # power-down entry/exit
    tXP: int = 5     # exit from a (fast/active) power-down to a command
    tXPDLL: int = 24  # exit from slow power-down (DLL relock), 10 ns+
    tXS: int = 74    # exit from self-refresh to a command (tRFC + margin)
    # NOTE: new fields append at the END (positional Timing() constructions
    # and the analysis linter's rule table both rely on field order).
    tRRD: int = 4    # ACT-to-ACT, different banks (rolling)
    tFAW: int = 16   # four-activate window: at most 4 ACTs per tFAW
    tWTR: int = 4    # write-to-read turnaround (after the write burst)

TIMING = Timing()

# ---------------------------------------------------------------------------
# Commands
# ---------------------------------------------------------------------------
NOP = 0
ACT = 1
PRE = 2   # precharge one bank
RD = 3
WR = 4
REF = 5
PDE = 6   # fast power-down entry (DLL on); active power-down if banks open
PDX = 7   # power-down exit (fast, slow, and active power-down)
PREA = 8  # precharge all banks
PDE_SLOW = 9   # slow (precharge) power-down entry, DLL off
SRE = 10       # self-refresh entry (refresh becomes internal)
SRX = 11       # self-refresh exit

CMD_NAMES = {NOP: "NOP", ACT: "ACT", PRE: "PRE", RD: "RD", WR: "WR",
             REF: "REF", PDE: "PDE", PDX: "PDX", PREA: "PREA",
             PDE_SLOW: "PDE_SLOW", SRE: "SRE", SRX: "SRX"}

# Interleaving modes for the data-dependency model (paper Table 5).
IL_NONE = 0      # same bank & same column as previous RD/WR
IL_COL = 1       # same bank, different column
IL_BANK = 2      # different bank, same column as that bank's last access
IL_BANKCOL = 3   # different bank, different column
N_IL_MODES = 4
IL_NAMES = {IL_NONE: "none", IL_COL: "col", IL_BANK: "bank",
            IL_BANKCOL: "bank+col"}


class CommandTrace(NamedTuple):
    """A DRAM command trace as a structure of arrays.

    ``dt`` is the number of DRAM clock cycles from this command's issue slot
    to the next command's issue slot (i.e. the duration "owned" by this
    command); the trace's total duration is ``sum(dt)`` cycles. This is the
    same information content as DRAMPower-style timestamped traces but
    integrates trivially.
    """
    cmd: jax.Array    # (N,) int32, one of the command codes above
    bank: jax.Array   # (N,) int32 in [0, 8)
    row: jax.Array    # (N,) int32 in [0, 2^15)
    col: jax.Array    # (N,) int32 in [0, 128)
    data: jax.Array   # (N, 16) uint32 -- 64-byte line; zeros for non-RD/WR
    dt: jax.Array     # (N,) int32 cycles

    @property
    def n(self) -> int:
        return self.cmd.shape[0]

    def total_cycles(self):
        # int32 is plenty per trace chunk (<2^31 cycles ~ 5s of DRAM time);
        # long application traces are evaluated in chunks (see traces.py).
        return jnp.sum(self.dt, dtype=jnp.int32)

    def total_ns(self):
        return self.total_cycles() * TCK_NS


# commands that are illegal while in a power-down state (the clock-enable
# pin is low: no bank, data, or refresh activity may be issued; NOP, the
# exits, re-entry, and precharge at the tile seam stay legal)
_PDN_ILLEGAL = (ACT, RD, WR, REF, SRE)
# while in self-refresh ONLY NOP and the self-refresh exit are legal
_SR_LEGAL = (NOP, SRX)


def validate_low_power_transitions(cmds) -> None:
    """Raise ``ValueError`` on commands issued inside a low-power state
    that the device cannot accept (e.g. ``ACT`` during self-refresh).

    Walks the same background-state machine the integrator derives
    (``energy_model.structural_state``); called on every concrete
    ``make_trace`` so illegal traces fail at construction, before any
    energy is billed for them."""
    cmd = np.asarray(cmds)
    if not np.isin(cmd, (PDE, PDE_SLOW, SRE)).any():
        return  # no low-power entry -> nothing to check
    in_pdn = in_sr = False
    for i, c in enumerate(cmd.reshape(-1).tolist()):
        if in_sr and c not in _SR_LEGAL:
            raise ValueError(
                f"illegal command {CMD_NAMES.get(c, c)} at index {i}: "
                f"only NOP/SRX are legal during self-refresh")
        if in_pdn and c in _PDN_ILLEGAL:
            raise ValueError(
                f"illegal command {CMD_NAMES.get(c, c)} at index {i}: "
                f"not legal during power-down (exit with PDX first)")
        if c in (PDE, PDE_SLOW):
            in_pdn = True
        elif c == PDX:
            in_pdn = False
        elif c == SRE:
            in_sr = True
        elif c == SRX:
            in_sr = False


def make_trace(cmds, banks=None, rows=None, cols=None, data=None, dts=None,
               default_dt: int = 1) -> CommandTrace:
    """Build a CommandTrace from (possibly python-list) fields.

    Concrete (non-traced) command streams are checked against the
    low-power transition rules (:func:`validate_low_power_transitions`).
    The full protocol linter (``repro.analysis.trace_lint`` — every JEDEC
    timing rule, bank-state and background-state legality) additionally
    runs on every concrete construction when ``REPRO_TRACE_LINT`` is set
    to ``warn`` or ``strict``; it is off by default here because unit
    tests legitimately build toy traces with symbolic 1-cycle slots.  The
    repo's own generators (``idd_loops``, ``traces.app_trace``, encodings,
    the power-down policy) lint their outputs unconditionally."""
    try:
        validate_low_power_transitions(cmds)
    except ValueError:
        raise
    except Exception:
        pass  # traced/abstract inputs cannot be walked -- skip validation
    cmd = jnp.asarray(cmds, dtype=jnp.int32)
    n = cmd.shape[0]
    z = jnp.zeros(n, dtype=jnp.int32)
    bank = z if banks is None else jnp.asarray(banks, dtype=jnp.int32)
    row = z if rows is None else jnp.asarray(rows, dtype=jnp.int32)
    col = z if cols is None else jnp.asarray(cols, dtype=jnp.int32)
    if data is None:
        dat = jnp.zeros((n, LINE_WORDS), dtype=jnp.uint32)
    else:
        dat = jnp.asarray(data, dtype=jnp.uint32)
        if dat.ndim == 1:
            dat = jnp.broadcast_to(dat[None, :], (n, LINE_WORDS))
    dt = (jnp.full(n, default_dt, dtype=jnp.int32) if dts is None
          else jnp.asarray(dts, dtype=jnp.int32))
    trace = CommandTrace(cmd, bank, row, col, dat, dt)
    import os
    if os.environ.get("REPRO_TRACE_LINT", "off") != "off":
        from repro.analysis import trace_lint
        trace_lint.check_trace(trace, origin="make_trace",
                               mode=os.environ["REPRO_TRACE_LINT"])
    return trace


def concat_traces(*traces: CommandTrace) -> CommandTrace:
    return CommandTrace(*[jnp.concatenate(f) for f in zip(*traces)])


def tile_trace(trace: CommandTrace, reps: int) -> CommandTrace:
    """Repeat a command loop ``reps`` times (paper's loop-until-measured)."""
    return CommandTrace(
        jnp.tile(trace.cmd, reps), jnp.tile(trace.bank, reps),
        jnp.tile(trace.row, reps), jnp.tile(trace.col, reps),
        jnp.tile(trace.data, (reps, 1)), jnp.tile(trace.dt, reps))


def pad_trace(trace: CommandTrace, length: int) -> CommandTrace:
    """NOP-pad a trace to ``length`` commands with ``dt == 0`` slots.

    A NOP that owns zero cycles draws zero charge and leaves every piece of
    integrator state (bank open/closed, power-down, previous-RD/WR data)
    untouched, so energy/current over the padded trace equals the original —
    this is what lets sweep points of unequal length share one compiled
    shape in the batched fleet engine.
    """
    n = trace.n
    assert length >= n, (length, n)
    pad = length - n
    if pad == 0:
        return trace
    zi = jnp.zeros(pad, dtype=jnp.int32)
    return CommandTrace(
        jnp.concatenate([trace.cmd, jnp.full(pad, NOP, dtype=jnp.int32)]),
        jnp.concatenate([trace.bank, zi]),
        jnp.concatenate([trace.row, zi]),
        jnp.concatenate([trace.col, zi]),
        jnp.concatenate([trace.data,
                         jnp.zeros((pad, LINE_WORDS), dtype=jnp.uint32)]),
        jnp.concatenate([trace.dt, zi]))


def batch_traces(traces_and_skips) -> tuple[CommandTrace, jax.Array]:
    """Stack variable-length traces into one fixed-shape batch.

    ``traces_and_skips`` is a sequence of ``(trace, skip)`` pairs; ``skip``
    generalizes the serial ``measure_current(skip=)`` handling: the first
    ``skip`` commands (one-time setup) are masked out of the average, as is
    all NOP/dt=0 padding. Returns ``(batch, weight)`` where every field of
    ``batch`` has a leading probe axis ``(P, N, ...)`` and ``weight`` is a
    float32 ``(P, N)`` mask of commands that count toward the measurement.
    """
    pairs = list(traces_and_skips)
    length = max(tr.n for tr, _ in pairs)
    padded = [pad_trace(tr, length) for tr, _ in pairs]
    batch = CommandTrace(*[jnp.stack(f) for f in zip(*padded)])
    idx = np.arange(length)
    weight = np.stack([(idx >= skip) & (idx < tr.n)
                       for tr, skip in pairs]).astype(np.float32)
    return batch, jnp.asarray(weight)


# ---------------------------------------------------------------------------
# Data-pattern helpers
# ---------------------------------------------------------------------------
def line_from_byte(byte_value: int) -> np.ndarray:
    """64-byte line where every byte equals ``byte_value`` (JEDEC style)."""
    b = byte_value & 0xFF
    w = b | (b << 8) | (b << 16) | (b << 24)
    return np.full(LINE_WORDS, w, dtype=np.uint32)


def line_with_n_ones(n_ones: int, rng: np.random.Generator | None = None) -> np.ndarray:
    """A 512-bit line with exactly ``n_ones`` ones (random positions)."""
    assert 0 <= n_ones <= LINE_BITS
    bits = np.zeros(LINE_BITS, dtype=np.uint8)
    if rng is None:
        bits[:n_ones] = 1  # deterministic: low bits first
    else:
        idx = rng.choice(LINE_BITS, size=n_ones, replace=False)
        bits[idx] = 1
    words = np.zeros(LINE_WORDS, dtype=np.uint32)
    for w in range(LINE_WORDS):
        chunk = bits[w * 32:(w + 1) * 32]
        words[w] = np.uint32(sum(int(b) << i for i, b in enumerate(chunk)))
    return words


def row_band(row):
    """Row-band index of a row address (int, numpy, or jax array)."""
    return row >> ROW_BAND_SHIFT


def popcount_u32(x: jax.Array) -> jax.Array:
    """Per-element population count of a uint32 array (pure jnp)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def line_ones(data: jax.Array) -> jax.Array:
    """Number of ones per 64-byte line. data: (..., 16) uint32 -> (...) int32."""
    return jnp.sum(popcount_u32(data), axis=-1)


def line_toggles(data: jax.Array, prev: jax.Array) -> jax.Array:
    """Number of bus wires that toggle between two consecutive lines."""
    return line_ones(jnp.bitwise_xor(data.astype(jnp.uint32),
                                     prev.astype(jnp.uint32)))
