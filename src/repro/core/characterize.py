"""The full characterization campaign (paper Sections 4-6) and VAMPIRE fit.

Pipeline (mirrors the paper's methodology):

1. Run each JEDEC IDD loop on every module in the fleet -> per-module
   measured currents, per-vendor distributions (Section 4).
2. Derive the *datasheet* values the vendor would publish: vendor-mean loop
   current divided by the paper's measured/datasheet ratios, published at
   1066/1333/1600 MT/s, then extrapolated back to 800 MT/s by linear
   least squares exactly as in Section 4 (Eq. 1).
3. Data-dependency sweeps (Section 5): ones sweeps and same-ones/controlled-
   toggle pair sweeps for each interleaving mode and op; fit Eq. 2 per
   (mode, op) with the I/O-driver estimate subtracted -> Table 5 recovery.
4. Structural probes (Section 6): per-bank idle/read/write, per-row
   activation, per-column read, and the per-(bank, row-band) SURFACE
   campaign — one constant-row-popcount ACT/PRE loop per surface cell, so
   current differences across cells isolate the planted structural surface
   from the row-address-ones slope (Figs 19-22 recovery).
5. Assemble fitted per-vendor :class:`PowerParams` -> the VAMPIRE model.

Every measurement of the campaign is declared up front as a
:class:`CampaignPlan` of probe points, which either engine can execute:
``engine='batched'`` (default) evaluates padded fixed-shape probe batches
against all modules in a handful of vmapped dispatches (see
``repro.core.fleet``); ``engine='serial'`` replays the campaign one
``measure_current`` call at a time and serves as the correctness oracle —
both draw identical per-(module, probe) measurement noise, so they fit the
same parameters to float32 tolerance.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import device_sim, dram, fitting, fleet, idd_loops
from repro.core import params as P
from repro.core.dram import RD, WR, LINE_BITS
from repro.core.energy_model import PowerParams, trace_energy_vectorized
from repro.core.fleet import ProbeBatch, ProbePoint

# low-power keys appended at the END so pre-existing loops keep their
# stable noise-key indices (a key IS the measurement's noise draw).
IDD_KEYS = ("IDD2N", "IDD3N", "IDD0", "IDD1", "IDD4R", "IDD4W", "IDD7",
            "IDD5B", "IDD2P1", "IDD2P0", "IDD3P", "IDD6")
IL_MODES = ("none", "col", "bank", "bankcol")
OPS = (RD, WR)

ONES_POINTS = (0, 64, 128, 192, 256, 320, 384, 448, 512)
PAIR_ONES = (64, 128, 192, 256, 320, 384, 448)
PAIR_TOGGLES = (0, 32, 64, 128, 192, 256)

# stable noise-key bases: IDD loops and probe-subset points must never share
# a key (a key IS the measurement's noise draw, per module)
_IDD_KEY_BASE = 0
_PROBE_KEY_BASE = 4096


def _feasible(n_ones: int, togg: int) -> bool:
    h = togg // 2
    return h <= n_ones and h <= LINE_BITS - n_ones


def pair_lines(n_ones: int, togg: int, seed: int = 0):
    """Two 512-bit lines, each with ``n_ones`` ones, differing in exactly
    ``togg`` bit positions (flip togg/2 ones and togg/2 zeros)."""
    rng = np.random.default_rng(seed + 7919 * n_ones + togg)
    a_bits = np.zeros(LINE_BITS, dtype=np.uint8)
    on = rng.choice(LINE_BITS, size=n_ones, replace=False)
    a_bits[on] = 1
    b_bits = a_bits.copy()
    h = togg // 2
    ones_idx = np.flatnonzero(a_bits == 1)
    zeros_idx = np.flatnonzero(a_bits == 0)
    b_bits[rng.choice(ones_idx, size=h, replace=False)] = 0
    b_bits[rng.choice(zeros_idx, size=h, replace=False)] = 1

    def pack(bits):
        w = np.zeros(dram.LINE_WORDS, dtype=np.uint32)
        for i in range(dram.LINE_WORDS):
            chunk = bits[i * 32:(i + 1) * 32]
            w[i] = np.uint32(sum(int(b) << j for j, b in enumerate(chunk)))
        return w
    return pack(a_bits), pack(b_bits)


# ---------------------------------------------------------------------------
# Datasheet derivation ("what the vendor publishes")
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def derive_datasheets() -> dict[int, dict[str, float]]:
    """Per-vendor datasheet IDD values at 800 MT/s, derived so that the
    vendor-mean *true* loop current over datasheet equals the paper's
    Section 4 ratios. Independent of measurement noise by construction."""
    out: dict[int, dict[str, float]] = {}
    for v in range(3):
        pp = device_sim.true_vendor_params(v)
        ds = {}
        for key in IDD_KEYS:
            loop = idd_loops.IDD_LOOPS[key]()
            true_mean = float(trace_energy_vectorized(loop, pp).avg_current_ma)
            ds[key] = true_mean / P.MEASURED_OVER_DATASHEET[key][v]
        out[v] = ds
    return out


def published_freq_tables() -> dict[int, dict[str, np.ndarray]]:
    """Datasheet IDD tables at 1066/1333/1600 MT/s per vendor."""
    ds = derive_datasheets()
    return {v: {k: fitting.synth_datasheet_freq_table(
                    ds[v][k], seed=100 * v + i)
                for i, k in enumerate(IDD_KEYS)}
            for v in ds}


def extrapolated_datasheets() -> tuple[dict[int, dict[str, float]],
                                       dict[int, dict[str, float]]]:
    """Fit the published frequency tables back to 800 MT/s (Section 4's
    procedure). Returns (values, r2s)."""
    tables = published_freq_tables()
    vals: dict[int, dict[str, float]] = {}
    r2s: dict[int, dict[str, float]] = {}
    for v, t in tables.items():
        vals[v], r2s[v] = {}, {}
        for k, freq_vals in t.items():
            i800, r2 = fitting.extrapolate_idd_to_800(freq_vals)
            vals[v][k] = i800
            r2s[v][k] = r2
    return vals, r2s


# ---------------------------------------------------------------------------
# Campaign result containers
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class VendorCharacterization:
    vendor: int
    idd_measured: dict[str, np.ndarray]          # per-module currents
    idd_datasheet: dict[str, float]              # extrapolated to 800 MT/s
    idd_extrapolation_r2: dict[str, float]
    datadep: np.ndarray                          # (4 modes, 2 ops, 3) fitted
    datadep_r2: np.ndarray                       # (4, 2)
    ones_sweep: dict                             # raw sweep data for benches
    i2n: float
    bank_open_delta: np.ndarray                  # (8,)
    bank_read_factor: np.ndarray                 # (8,)
    bank_write_factor: np.ndarray                # (8,)
    q_actpre: float
    row_ones_slope: float
    row_sweep: dict
    q_ref: float
    i_pd: float
    # rest of the background-state LUT (Section 4.2 / Fig 14); None for
    # pre-lattice model blobs -> fall back to the fast power-down current
    i_pd_slow: float = None  # type: ignore[assignment]
    i_actpd: float = None  # type: ignore[assignment]
    i_sr: float = None  # type: ignore[assignment]
    # per-(bank, row-band) structural surface recovered by the surface
    # campaign; None (-> neutral all-ones) for pre-surface model blobs
    act_surface: np.ndarray = None  # type: ignore[assignment]
    fitted: PowerParams = None  # type: ignore[assignment]

    def build_params(self) -> PowerParams:
        import jax.numpy as jnp
        if self.act_surface is None:
            self.act_surface = np.ones((dram.N_BANKS, dram.N_ROW_BANDS))
        self.fitted = PowerParams(
            datadep=jnp.asarray(self.datadep, jnp.float32),
            i2n=jnp.asarray(self.i2n, jnp.float32),
            bank_open_delta=jnp.asarray(self.bank_open_delta, jnp.float32),
            bank_read_factor=jnp.asarray(self.bank_read_factor, jnp.float32),
            bank_write_factor=jnp.asarray(self.bank_write_factor, jnp.float32),
            q_actpre=jnp.asarray(self.q_actpre, jnp.float32),
            row_ones_slope=jnp.asarray(self.row_ones_slope, jnp.float32),
            q_ref=jnp.asarray(self.q_ref, jnp.float32),
            i_pd=jnp.asarray(self.i_pd, jnp.float32),
            io_read_ma_per_one=jnp.asarray(P.IO_DRIVER_MA_PER_ONE_READ,
                                           jnp.float32),
            io_write_ma_per_zero=jnp.asarray(P.IO_DRIVER_MA_PER_ZERO_WRITE,
                                             jnp.float32),
            ones_quad=jnp.asarray(0.0, jnp.float32),  # model is linear
            act_surface=jnp.asarray(self.act_surface, jnp.float32),
            i_pd_slow=jnp.asarray(
                self.i_pd if self.i_pd_slow is None else self.i_pd_slow,
                jnp.float32),
            i_actpd=jnp.asarray(
                self.i_pd if self.i_actpd is None else self.i_actpd,
                jnp.float32),
            i_sr=jnp.asarray(
                self.i_pd if self.i_sr is None else self.i_sr, jnp.float32),
        )
        return self.fitted


def _io_estimate(op: int, ones: np.ndarray) -> np.ndarray:
    """The paper's 'conservative estimate' of rig-visible I/O current."""
    ones = np.asarray(ones, dtype=np.float64)
    if op == RD:
        return P.IO_DRIVER_MA_PER_ONE_READ * ones
    return P.IO_DRIVER_MA_PER_ZERO_WRITE * (LINE_BITS - ones)


# ---------------------------------------------------------------------------
# The campaign plan: every probe point of the measurement campaign, with a
# stable noise key per point. The plan is vendor-independent (pair data and
# row samples depend only on rng_seed), so one plan — and its padded batched
# form — is shared across all three vendors and both engines.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CampaignPlan:
    idd_points: list[ProbePoint]    # measured on EVERY module of a vendor
    probe_points: list[ProbePoint]  # measured on the probe-module subset
    rows: list[int]                 # row addresses of the activation sweep

    @functools.cached_property
    def idd_batch(self) -> ProbeBatch:
        return ProbeBatch.from_points(self.idd_points)

    @functools.cached_property
    def probe_batch(self) -> ProbeBatch:
        return ProbeBatch.from_points(self.probe_points)


def _sample_rows(n_rows: int, rng_seed: int) -> list[int]:
    """Row addresses covering address popcounts 0..ROW_BAND_SHIFT, all
    inside row band 0 (bits below ``ROW_BAND_SHIFT``) so the row-ones
    slope fit is not confounded by the per-(bank, row-band) structural
    surface — band 0 is the surface's reference band (factor 1.0); the
    dedicated surface campaign covers the other bands at constant
    popcount."""
    rng = np.random.default_rng(rng_seed + 1)
    rows = []
    for ro in range(dram.ROW_BAND_SHIFT + 1):
        for _ in range(max(1, n_rows // (dram.ROW_BAND_SHIFT + 1))):
            bits = rng.choice(dram.ROW_BAND_SHIFT, size=ro, replace=False)
            rows.append(int(sum(1 << int(b) for b in bits)))
    return rows


# Every surface probe's row has this address popcount, so cell-to-cell
# current differences isolate the surface factor from the row-ones slope.
SURFACE_ROW_POPCOUNT = 3


def surface_probe_row(band: int) -> int:
    """The probe row of a surface band: band bits at the top, low bits
    padding the address popcount to :data:`SURFACE_ROW_POPCOUNT`."""
    pad = SURFACE_ROW_POPCOUNT - bin(band).count("1")
    return (band << dram.ROW_BAND_SHIFT) | ((1 << pad) - 1)


@functools.lru_cache(maxsize=4)
def campaign_plan(probe_reps: int = 256, n_rows: int = 24,
                  rng_seed: int = 0) -> CampaignPlan:
    idd_points = [
        ProbePoint(("idd", key), idd_loops.IDD_LOOPS[key](), 0,
                   _IDD_KEY_BASE + i)
        for i, key in enumerate(IDD_KEYS)]

    pts: list[tuple[tuple, dram.CommandTrace, int]] = []
    for mode in IL_MODES:
        for oi, op in enumerate(OPS):
            if mode == "none":
                for n1 in ONES_POINTS:
                    tr, skip = idd_loops.ones_sweep_point(n1, op=op,
                                                          reps=probe_reps)
                    pts.append((("sweep", mode, oi, n1, 0), tr, skip))
            else:
                for n1 in PAIR_ONES:
                    for tg in PAIR_TOGGLES:
                        if not _feasible(n1, tg):
                            continue
                        a, b = pair_lines(n1, tg, seed=rng_seed)
                        tr, skip = idd_loops.interleave_sweep_point(
                            a, b, mode, op=op, reps=probe_reps // 2)
                        pts.append((("sweep", mode, oi, n1, tg), tr, skip))
    pts.append((("i2n_probe",), idd_loops.idd2n(), 0))
    for b in range(8):
        tr, skip = idd_loops.bank_idle_probe(b)
        pts.append((("bank_idle", b), tr, skip))
    for oi, op in enumerate(OPS):
        for b in range(8):
            tr, skip = idd_loops.bank_read_probe(b, op=op, reps=probe_reps)
            pts.append((("bank_rw", oi, b), tr, skip))
    rows = _sample_rows(n_rows, rng_seed)
    for i, r in enumerate(rows):
        tr, skip = idd_loops.row_act_probe(r, reps=probe_reps)
        pts.append((("row", i), tr, skip))
    # surface campaign (appended LAST so earlier probes keep their noise
    # keys): one ACT/PRE loop per (bank, row-band) cell
    for b in range(dram.N_BANKS):
        for band in range(dram.N_ROW_BANDS):
            tr, skip = idd_loops.surface_act_probe(
                b, surface_probe_row(band), reps=probe_reps)
            pts.append((("surface", b, band), tr, skip))

    probe_points = [ProbePoint(label, tr, skip, _PROBE_KEY_BASE + i)
                    for i, (label, tr, skip) in enumerate(pts)]
    return CampaignPlan(idd_points, probe_points, rows)


# ---------------------------------------------------------------------------
# The campaign
# ---------------------------------------------------------------------------
def characterize_vendor(modules, vendor: int, *, probe_modules: int = 5,
                        probe_reps: int = 256, n_rows: int = 24,
                        rng_seed: int = 0, engine: str = "batched",
                        impl: str = "vectorized") -> VendorCharacterization:
    probes = modules[:probe_modules]
    plan = campaign_plan(probe_reps=probe_reps, n_rows=n_rows,
                         rng_seed=rng_seed)

    # ---- measurement: two batched dispatches (or the serial oracle) -------
    # ``impl`` picks the batched engine's evaluation path (vectorized jnp
    # vs the fused Pallas kernels) through the shared impl registry
    idd_currents = fleet.run_probes(            # (all modules, 9 IDD loops)
        modules, plan.idd_points, engine=engine, impl=impl,
        batch=plan.idd_batch if engine == "batched" else None)
    probe_currents = fleet.run_probes(          # (probe modules, all probes)
        probes, plan.probe_points, engine=engine, impl=impl,
        batch=plan.probe_batch if engine == "batched" else None)
    probe_mean = probe_currents.mean(axis=0)
    cur = {pt.label: float(probe_mean[i])
           for i, pt in enumerate(plan.probe_points)}

    # ---- 1. IDD loops on every module ------------------------------------
    idd_measured = {key: idd_currents[:, i] for i, key in enumerate(IDD_KEYS)}
    return invert_campaign(plan, vendor, cur, idd_measured)


def invert_campaign(plan: CampaignPlan, vendor: int, cur: dict,
                    idd_measured: dict) -> VendorCharacterization:
    """The slot-accounting inversions: per-probe-cell mean currents (the
    campaign's, or the streaming fitter's decayed sufficient statistics —
    ``repro.core.recalibrate``) -> one fitted ``VendorCharacterization``.

    ``cur`` maps every probe-point label of ``plan`` to its mean current
    over the probe modules; ``idd_measured`` maps each IDD key to the
    per-module current vector of the vendor's whole module population."""
    ds_vals, ds_r2 = extrapolated_datasheets()

    # ---- 2. data-dependency fits (Section 5 / Table 5) --------------------
    datadep = np.zeros((4, 2, 3))
    datadep_r2 = np.zeros((4, 2))
    ones_sweep_raw = {}
    for mi, mode in enumerate(IL_MODES):
        for oi, op in enumerate(OPS):
            sweep = [(lab, c) for lab, c in cur.items()
                     if lab[0] == "sweep" and lab[1] == mode and lab[2] == oi]
            ones_a = np.asarray([lab[3] for lab, _ in sweep],
                                dtype=np.float64)
            tog_a = np.asarray([lab[4] for lab, _ in sweep],
                               dtype=np.float64)
            cur_a = np.asarray([c for _, c in sweep], dtype=np.float64)
            corrected = cur_a - _io_estimate(op, ones_a)
            fit = fitting.fit_ones_toggles(ones_a, tog_a, corrected)
            datadep[mi, oi] = fit.coef
            datadep_r2[mi, oi] = fit.r2
            ones_sweep_raw[(mode, "RD" if op == RD else "WR")] = {
                "ones": ones_a, "toggles": tog_a, "current": cur_a,
                "corrected": corrected,
            }
    # 'none' mode cannot expose toggling; pin its coefficient to 0.
    datadep[0, :, 2] = 0.0

    # ---- 3. structural probes (Section 6) ---------------------------------
    # The structural/background fits must use the *same* module population
    # as the probes (process variation otherwise biases the subtractions).
    i2n_probe = cur[("i2n_probe",)]
    i2n = float(np.mean(idd_measured["IDD2N"]))
    bank_idle = np.array([cur[("bank_idle", b)] for b in range(8)])
    bank_open_delta = np.maximum(bank_idle - i2n_probe, 0.05)

    rd_cur = np.array([cur[("bank_rw", 0, b)] for b in range(8)])
    wr_cur = np.array([cur[("bank_rw", 1, b)] for b in range(8)])
    bank_read_factor = rd_cur / rd_cur[0]
    bank_write_factor = wr_cur / wr_cur[0]

    # per-row activation sweep: rows chosen to cover address popcounts 0..15
    rows = plan.rows
    row_cur = np.array([cur[("row", i)] for i in range(len(rows))])
    row_ones = np.array([bin(r).count("1") for r in rows], dtype=np.float64)
    d = np.stack([np.ones_like(row_ones), row_ones], axis=1)
    rf = fitting.lstsq_fit(d, row_cur)
    # I(ro) = bg + q(1+s*ro)/tRC  =>  s = c1 / (c0 - bg).  Loop background
    # matches the integrator: bank closed during the ACT slot (tRAS), open
    # during the PRE slot (tRP) — same weighting as the surface fit below.
    t = dram.TIMING
    bg_loop = (i2n_probe * t.tRAS
               + (i2n_probe + bank_open_delta[0]) * t.tRP) / t.tRC
    q_actpre = max(float(rf.coef[0]) - bg_loop, 1.0) * t.tRC
    row_ones_slope = float(rf.coef[1]) * t.tRC / q_actpre

    # ---- 3b. surface campaign (Figs 19-22) --------------------------------
    # Every probe shares one row popcount, so within a bank the ACT part of
    # the loop current varies ONLY through the structural surface; band 0
    # is the reference (factor 1.0), exactly as the simulator plants it.
    # Loop background: the bank is closed during the ACT slot (tRAS) and
    # open during the PRE slot (tRP) — background follows the state BEFORE
    # each command, so the open-bank increment weights tRP, not tRAS.
    surf_cur = np.array(
        [[cur[("surface", b, band)] for band in range(dram.N_ROW_BANDS)]
         for b in range(dram.N_BANKS)])
    bg_bank = (i2n_probe * t.tRAS
               + (i2n_probe + bank_open_delta) * t.tRP) / t.tRC  # (8,)
    act_part = np.maximum(surf_cur - bg_bank[:, None], 1e-3)
    act_surface = np.clip(act_part / act_part[:, :1], 0.2, 5.0)

    # ---- 4. refresh & power-down ------------------------------------------
    idd5b = float(np.mean(idd_measured["IDD5B"]))
    q_ref = (idd5b - i2n) * float(t.tRFC)
    i_pd = float(np.mean(idd_measured["IDD2P1"]))

    # ---- 4b. low-power background states (Section 4.2 / Fig 14) -----------
    # IDD2P0's loop never powers back up (like IDD2P1), so after the first
    # entry the whole loop dwells in slow power-down — the direct mean IS
    # the fitted current.  IDD3P and IDD6 loops must power up every
    # repetition (ACT is illegal during power-down; self-refresh admits
    # only NOP/SRX), so the powered-up slots — billed at the state BEFORE
    # each command, like everywhere else in the integrator — are subtracted
    # analytically before dividing by the low-power dwell (which includes
    # the exit slot: PDX/SRX are the last slots billed at low-power rate).
    i_pd_slow = float(np.mean(idd_measured["IDD2P0"]))

    idle8 = idd_loops.IDLE_SLOT * 8
    idd3p_mean = float(np.mean(idd_measured["IDD3P"]))
    tot3p = t.tRCD + t.tCKE + idle8 + t.tXP + t.tRP
    up3p = (i2n * t.tRCD
            + (i2n + float(bank_open_delta[0])) * (t.tCKE + t.tRP)
            + q_actpre)
    i_actpd = max((idd3p_mean * tot3p - up3p) / (idle8 + t.tXP), 0.1)

    idd6_mean = float(np.mean(idd_measured["IDD6"]))
    tot6 = t.tRP + t.tCKE + idle8 + t.tXS
    i_sr = max((idd6_mean * tot6 - i2n * (t.tRP + t.tCKE))
               / (idle8 + t.tXS), 0.1)

    vc = VendorCharacterization(
        act_surface=act_surface,
        vendor=vendor, idd_measured=idd_measured,
        idd_datasheet=ds_vals[vendor], idd_extrapolation_r2=ds_r2[vendor],
        datadep=datadep, datadep_r2=datadep_r2, ones_sweep=ones_sweep_raw,
        i2n=i2n, bank_open_delta=bank_open_delta,
        bank_read_factor=bank_read_factor,
        bank_write_factor=bank_write_factor, q_actpre=q_actpre,
        row_ones_slope=row_ones_slope,
        row_sweep={"row_ones": row_ones, "current": row_cur, "r2": rf.r2},
        q_ref=q_ref, i_pd=i_pd,
        i_pd_slow=i_pd_slow, i_actpd=i_actpd, i_sr=i_sr)
    vc.build_params()
    return vc


def characterize_fleet(modules=None, **kw) -> dict[int, VendorCharacterization]:
    modules = device_sim.make_fleet() if modules is None else modules
    out = {}
    for v in range(3):
        mods = device_sim.vendor_modules(modules, v)
        if mods:
            out[v] = characterize_vendor(mods, v, **kw)
    return out
