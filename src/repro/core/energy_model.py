"""The shared DRAM energy integrator.

Both the ground-truth module simulation (`device_sim`) and the fitted VAMPIRE
model (`vampire`) evaluate command traces through this integrator; they differ
only in the parameter values (true per-module vs. fitted per-vendor) and in
the noise/unmodeled terms the simulator adds on top.

Semantics
---------
Each command owns a slot of ``dt`` DRAM clock cycles. During a slot the module
draws the *background* current implied by its bank/power-down state; commands
add charge on top:

* ``ACT``   — one activate+precharge pair's worth of charge (the paper shows
  the two cannot be measured separately; we assign the pair charge to the ACT
  and make PRE free), scaled by the row-address-ones structural factor.
* ``RD/WR`` — for ``tBURST`` cycles the module draws the data-dependent
  current ``I(mode, N_ones, N_toggles)`` (paper Eq. 2 / Table 5) times the
  per-bank structural factor, plus the I/O-driver current the measurement rig
  captures; the slot's background is credited back for those cycles.
* ``REF``   — a fixed charge above background per refresh burst.

The background current itself is resolved through a **state machine over
the idle/low-power lattice**, not a boolean: every command slot carries an
integer background state (``BG_*`` codes below) derived once per trace by
the same cumulative-event-index trick that tracks bank state, and the
state indexes a per-state current LUT (:func:`background_current`):

* ``BG_ACTIVE`` (0)   — powered up: ``i2n`` plus the open-bank deltas
  (precharge standby when all banks are closed, active standby otherwise).
* ``BG_PDN_FAST`` (1) — fast power-down (``PDE`` with all banks closed,
  DLL on): ``i_pd`` (datasheet ``IDD2P1``).
* ``BG_PDN_SLOW`` (2) — slow power-down (``PDE_SLOW``, DLL off):
  ``i_pd_slow`` (``IDD2P0``).
* ``BG_PDN_ACT`` (3)  — active power-down (``PDE`` while any bank is
  open; the open state is frozen until ``PDX``): ``i_actpd`` (``IDD3P``).
* ``BG_SR`` (4)       — self-refresh (``SRE``/``SRX``): ``i_sr``
  (``IDD6``).  Refresh is internal while in this state, so a trace in
  self-refresh owes no ``REF`` commands (and may not issue any —
  ``dram.validate_low_power_transitions``).

Entry commands (``PDE``/``PDE_SLOW``/``SRE``) and exits (``PDX``/``SRX``)
bill their own slot at the state in force BEFORE them: the entry slot is
still at the powered-up rate, the dwell rides on the slots after it, and
the exit slot is the last one billed at the low-power rate.

Charge is accumulated in mA x cycles; energy = charge * tCK * VDD.

Two implementations are provided with identical semantics:

* :func:`trace_energy_scan` — `lax.scan` command-by-command oracle.
* :func:`trace_energy_vectorized` — bank state via cumulative max over event
  indices, data dependency via popcount/XOR, everything fused elementwise.
  This is the production path (it is what makes 1e7+ command traces cheap)
  and is cross-checked against the oracle in tests.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dram
from repro.core.dram import (ACT, PRE, PREA, RD, WR, REF, PDE, PDX,
                             PDE_SLOW, SRE, SRX,
                             IL_NONE, IL_COL, IL_BANK, IL_BANKCOL,
                             LINE_BITS, N_BANKS, N_ROW_BANDS, TIMING,
                             TCK_NS, VDD, CommandTrace, line_ones,
                             line_toggles, popcount_u32, row_band)

# flattened (bank, row-band) cell count of the structural-variation surface
N_SURFACE_CELLS = N_BANKS * N_ROW_BANDS

# ---------------------------------------------------------------------------
# The background-state lattice (see the module docstring).  Code 0 is the
# powered-up state, so a trace with no low-power commands carries an
# all-zero state vector and bills exactly as before the lattice existed.
# ---------------------------------------------------------------------------
BG_ACTIVE = 0     # powered up: i2n + open-bank deltas
BG_PDN_FAST = 1   # fast power-down (IDD2P1): i_pd
BG_PDN_SLOW = 2   # slow power-down, DLL off (IDD2P0): i_pd_slow
BG_PDN_ACT = 3    # active power-down, banks open (IDD3P): i_actpd
BG_SR = 4         # self-refresh (IDD6): i_sr
BG_STATE_NAMES = {BG_ACTIVE: "active", BG_PDN_FAST: "pdn_fast",
                  BG_PDN_SLOW: "pdn_slow", BG_PDN_ACT: "pdn_active",
                  BG_SR: "self_refresh"}


def background_current(pp: "PowerParams", bg_state, i_up):
    """The per-state background-current LUT: ``bg_state`` (int codes above)
    gathered against the low-power leaves of ``pp``; ``i_up`` is the
    powered-up current (``i2n`` + open-bank deltas), supplied by the caller
    because it is the only state whose current is trace-dependent.  All
    three impls (vectorized, reference scan, both Pallas kernel families)
    resolve the background through this one shape."""
    i_low = jnp.where(bg_state == BG_PDN_FAST, pp.i_pd,
                      jnp.where(bg_state == BG_PDN_SLOW, pp.i_pd_slow,
                                jnp.where(bg_state == BG_PDN_ACT,
                                          pp.i_actpd, pp.i_sr)))
    return jnp.where(bg_state == BG_ACTIVE, i_up, i_low)


class DataOps(NamedTuple):
    """The two data-stream reductions of the feature pass — per-line
    popcount and bus-XOR toggle count — as injectable callables: the seam
    that isolates the O(N x 512 bit) work from the index bookkeeping.
    ``extract_structural_features`` takes one, so a SINGLE-trace feature
    pass can run through the ``kernels/popcount`` / ``kernels/toggle``
    Pallas ops (:func:`kernel_data_ops`; the parity suite pins it equal
    to the jnp default).  The batched ``impl='pallas'`` path does not
    come through here — it fuses both reductions into one kernel over the
    whole batch (``kernels/vampire_energy.batched_features_pallas``)."""
    line_ones: object    # (N, 16) uint32 -> (N,) counts
    line_toggles: object  # ((N, 16), (N, 16)) uint32 -> (N,) counts


JNP_DATA_OPS = DataOps(line_ones=line_ones, line_toggles=line_toggles)


def kernel_data_ops() -> DataOps:
    """The Pallas-kernel-backed :class:`DataOps` (``kernels/popcount`` +
    ``kernels/toggle``), resolved lazily so importing this module never
    pulls in the kernel stack."""
    from repro.kernels.popcount import ops as pc_ops
    from repro.kernels.toggle import ops as tg_ops
    return DataOps(line_ones=pc_ops.line_ones, line_toggles=tg_ops.line_toggles)


class PowerParams(NamedTuple):
    """Everything the integrator needs, as JAX arrays (so params are a pytree
    and fitting can be jitted/vmapped over modules)."""
    datadep: jax.Array            # (4 modes, 2 ops, 3 coeffs) mA
    i2n: jax.Array                # () mA   background, all banks closed
    bank_open_delta: jax.Array    # (8,) mA added per open bank (structural)
    bank_read_factor: jax.Array   # (8,) multiplicative on read current
    bank_write_factor: jax.Array  # (8,)
    q_actpre: jax.Array           # () mA*cycles charge per ACT(+PRE) pair
    row_ones_slope: jax.Array     # () fractional act-charge per row-addr one
    q_ref: jax.Array              # () mA*cycles above background per REF
    i_pd: jax.Array               # () mA background in fast power-down
    io_read_ma_per_one: jax.Array   # () rig-visible I/O driver current
    io_write_ma_per_zero: jax.Array # ()
    ones_quad: jax.Array          # () unmodeled curvature (sim-only; 0 in fit)
    # (8, N_ROW_BANDS) structural ACT factor per (bank, row band); band 0
    # == 1.0.  Defaulted (neutral, np so importing this module never
    # initializes a jax backend) so parameter sets pickled before the
    # surface existed keep unpickling.
    act_surface: jax.Array = np.ones((N_BANKS, N_ROW_BANDS), np.float32)
    # the rest of the background-state LUT (fast power-down i_pd sits
    # above for leaf-order compatibility).  Defaulted (np scalars) so
    # parameter sets serialized before the state lattice keep loading;
    # traces without the new low-power commands never read them.
    i_pd_slow: jax.Array = np.float32(0.0)  # () mA slow PDN, DLL off (IDD2P0)
    i_actpd: jax.Array = np.float32(0.0)    # () mA active power-down (IDD3P)
    i_sr: jax.Array = np.float32(0.0)       # () mA self-refresh (IDD6)

    @property
    def i3n(self):
        return self.i2n + jnp.sum(self.bank_open_delta)


def zeros_like_params() -> PowerParams:
    z = jnp.zeros(())
    return PowerParams(jnp.zeros((4, 2, 3)), z, jnp.zeros(8), jnp.ones(8),
                       jnp.ones(8), z, z, z, z, z, z, z,
                       jnp.ones((N_BANKS, N_ROW_BANDS)), z, z, z)


class TraceFeatures(NamedTuple):
    """Per-command derived features (vectorized preprocessing)."""
    is_rw: jax.Array       # (N,) bool
    op: jax.Array          # (N,) int32: 0 read / 1 write (valid where is_rw)
    il_mode: jax.Array     # (N,) int32 in [0,4)
    ones: jax.Array        # (N,) int32
    toggles: jax.Array     # (N,) int32 (global bus, vs previous RD/WR)
    open_banks: jax.Array  # (N,) float32: number of open banks (weighted)
    bg_delta_sum: jax.Array  # (N,) float32: sum of bank_open_delta over open
    bg_state: jax.Array    # (N,) int32 background-state code (BG_*)
    row_ones: jax.Array    # (N,) int32 popcount of row addr (ACT rows)


class StructuralFeatures(NamedTuple):
    """The parameter-independent part of feature extraction: everything
    derivable from the trace alone. Extracting these ONCE per trace and
    finalizing per parameter set is what lets the batched estimation engine
    amortize the popcount/XOR/cummax work across vendors (the only
    param-dependent feature is the open-bank background sum)."""
    is_rw: jax.Array         # (N,) bool
    op: jax.Array            # (N,) int32
    il_mode: jax.Array       # (N,) int32 in [0,4)
    ones: jax.Array          # (N,) int32
    toggles: jax.Array       # (N,) int32
    open_before: jax.Array   # (N, 8) bool: bank open state before each cmd
    bg_state: jax.Array      # (N,) int32 background-state code (BG_*)
    row_ones: jax.Array      # (N,) int32


# ---------------------------------------------------------------------------
# Vectorized feature extraction
# ---------------------------------------------------------------------------
def _exclusive_cummax(x: jax.Array) -> jax.Array:
    """cummax over axis 0, exclusive (state *before* each element)."""
    shifted = jnp.concatenate(
        [jnp.full_like(x[:1], -1), jax.lax.cummax(x, axis=0)[:-1]], axis=0)
    return shifted


class StructuralState(NamedTuple):
    """The index-bookkeeping half of the structural pass: everything the
    trace alone determines EXCEPT the O(N x 512 bit) data reductions.
    Splitting it out lets the Pallas impl run the same state machine and
    feed ``prev_data`` to its fused feature kernel over a whole batch."""
    is_rw: jax.Array        # (N,) bool
    op: jax.Array           # (N,) int32
    il_mode: jax.Array      # (N,) int32 in [0,4)
    open_before: jax.Array  # (N, 8) bool
    bg_state: jax.Array     # (N,) int32 background-state code (BG_*)
    row_ones: jax.Array     # (N,) int32
    prev_data: jax.Array    # (N, 16) uint32: previous RD/WR line (0 if none)
    has_prev: jax.Array     # (N,) bool


def structural_state(trace: CommandTrace) -> StructuralState:
    cmd, bank = trace.cmd, trace.bank
    n = cmd.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)

    is_rw = (cmd == RD) | (cmd == WR)
    op = jnp.where(cmd == WR, 1, 0).astype(jnp.int32)

    # ---- bank open/closed state before each command -----------------------
    bank_oh = jax.nn.one_hot(bank, N_BANKS, dtype=jnp.bool_)  # (N,8)
    act_ev = (cmd == ACT)[:, None] & bank_oh
    pre_ev = ((cmd == PRE)[:, None] & bank_oh) | (cmd == PREA)[:, None]
    last_act = _exclusive_cummax(jnp.where(act_ev, idx[:, None], -1))  # (N,8)
    last_pre = _exclusive_cummax(jnp.where(pre_ev, idx[:, None], -1))
    open_before = last_act > last_pre                                  # (N,8)

    # ---- background-state lattice (power-down / self-refresh) -------------
    # Same cumulative-event-index trick as the bank state: the most recent
    # entry vs exit event before each slot decides the state; which ENTRY
    # is most recent decides the power-down flavor.  A fast entry with any
    # bank open is ACTIVE power-down — PDE freezes (not closes) the banks,
    # and since ACT/PRE are illegal inside power-down the per-slot
    # ``open_before`` equals the open state at entry.
    last_pdf = _exclusive_cummax(jnp.where(cmd == PDE, idx, -1))
    last_pds = _exclusive_cummax(jnp.where(cmd == PDE_SLOW, idx, -1))
    last_pdx = _exclusive_cummax(jnp.where(cmd == PDX, idx, -1))
    last_sre = _exclusive_cummax(jnp.where(cmd == SRE, idx, -1))
    last_srx = _exclusive_cummax(jnp.where(cmd == SRX, idx, -1))
    in_pdn = jnp.maximum(last_pdf, last_pds) > last_pdx
    in_sr = last_sre > last_srx
    any_open = jnp.any(open_before, axis=1)
    pd_kind = jnp.where(last_pdf >= last_pds,
                        jnp.where(any_open, BG_PDN_ACT, BG_PDN_FAST),
                        BG_PDN_SLOW)
    bg_state = jnp.where(in_sr, BG_SR,
                         jnp.where(in_pdn, pd_kind, BG_ACTIVE)
                         ).astype(jnp.int32)

    # ---- previous RD/WR on the bus (for toggles & interleave mode) --------
    prev_rw = _exclusive_cummax(jnp.where(is_rw, idx, -1))            # (N,)
    has_prev = prev_rw >= 0
    prev_rw_c = jnp.maximum(prev_rw, 0)
    prev_data = jnp.where(has_prev[:, None], trace.data[prev_rw_c],
                          jnp.zeros_like(trace.data))                 # (N,16)
    prev_bank = jnp.where(has_prev, bank[prev_rw_c], -1)

    # last RD/WR column per bank, before each command
    rw_in_bank = is_rw[:, None] & bank_oh                             # (N,8)
    last_rw_in_bank = _exclusive_cummax(jnp.where(rw_in_bank, idx[:, None], -1))
    this_bank_last = jnp.take_along_axis(last_rw_in_bank, bank[:, None],
                                         axis=1)[:, 0]                # (N,)
    has_bank_prev = this_bank_last >= 0
    prev_col_same_bank = jnp.where(
        has_bank_prev, trace.col[jnp.maximum(this_bank_last, 0)], -1)

    same_bank = has_prev & (prev_bank == bank)
    same_col_prev = trace.col[prev_rw_c] == trace.col
    same_col_in_bank = has_bank_prev & (prev_col_same_bank == trace.col)
    il_mode = jnp.where(
        ~has_prev, IL_NONE,
        jnp.where(same_bank,
                  jnp.where(same_col_prev, IL_NONE, IL_COL),
                  jnp.where(same_col_in_bank, IL_BANK, IL_BANKCOL)))
    il_mode = il_mode.astype(jnp.int32)

    row_ones = popcount_u32(trace.row.astype(jnp.uint32))
    return StructuralState(is_rw, op, il_mode, open_before, bg_state,
                           row_ones, prev_data, has_prev)


def extract_structural_features(trace: CommandTrace,
                                data_ops: DataOps = JNP_DATA_OPS
                                ) -> StructuralFeatures:
    """The parameter-independent feature pass (see StructuralFeatures).

    ``data_ops`` injects the popcount/toggle reductions — pure jnp by
    default, the Pallas kernel ops under the ``impl`` registry."""
    st = structural_state(trace)
    ones = data_ops.line_ones(trace.data)
    toggles = jnp.where(st.has_prev & st.is_rw,
                        data_ops.line_toggles(trace.data, st.prev_data), 0)
    return StructuralFeatures(st.is_rw, st.op, st.il_mode, ones, toggles,
                              st.open_before, st.bg_state, st.row_ones)


def finalize_features(sf: StructuralFeatures,
                      pp: PowerParams) -> TraceFeatures:
    """Attach the (cheap) parameter-dependent features to a structural
    pass: the per-command open-bank background-current sum."""
    bg_delta_sum = jnp.sum(jnp.where(sf.open_before, pp.bank_open_delta, 0.0),
                           axis=1)
    open_banks = jnp.sum(sf.open_before.astype(jnp.float32), axis=1)
    return TraceFeatures(sf.is_rw, sf.op, sf.il_mode, sf.ones, sf.toggles,
                         open_banks, bg_delta_sum, sf.bg_state,
                         sf.row_ones)


def extract_features(trace: CommandTrace, pp: PowerParams) -> TraceFeatures:
    return finalize_features(extract_structural_features(trace), pp)


def distribution_features(sf: StructuralFeatures, ones_frac,
                          toggle_frac) -> StructuralFeatures:
    """The paper's no-data-trace mode: replace the measured per-command data
    features with expected ones/toggle fractions. First-access semantics
    match ``extract_structural_features``: the first RD/WR on the bus has no
    previous burst to toggle against, so its expected toggle count is 0
    regardless of ``toggle_frac``. The single source of truth for this rule
    — the serial and batched estimators both go through it."""
    n = sf.is_rw.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    prev_rw = _exclusive_cummax(jnp.where(sf.is_rw, idx, -1))
    has_prev = prev_rw >= 0
    ones = jnp.where(sf.is_rw,
                     jnp.asarray(ones_frac, jnp.float32) * LINE_BITS, 0.0)
    togg = jnp.where(sf.is_rw & has_prev,
                     jnp.asarray(toggle_frac, jnp.float32) * LINE_BITS, 0.0)
    return sf._replace(ones=ones.astype(jnp.float32),
                       toggles=togg.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Charge accumulation from features (shared by sim and model)
# ---------------------------------------------------------------------------
def rw_current(pp: PowerParams, op, il_mode, ones, toggles, bank):
    """Data-dependent RD/WR current (paper Eq. 2), incl. structural bank
    factor and the rig-visible I/O driver current. All args broadcastable."""
    coeffs = pp.datadep[il_mode, op]                  # (..., 3)
    onesf = ones.astype(jnp.float32)
    togf = toggles.astype(jnp.float32)
    base = coeffs[..., 0] + coeffs[..., 1] * onesf + coeffs[..., 2] * togf
    # optional unmodeled curvature (ground-truth sim only; 0 when fitted)
    base = base + pp.ones_quad * coeffs[..., 1] * onesf * (
        onesf / dram.LINE_BITS - 0.5)
    factor = jnp.where(op == 0, pp.bank_read_factor[bank],
                       pp.bank_write_factor[bank])
    io = jnp.where(op == 0,
                   pp.io_read_ma_per_one * onesf,
                   pp.io_write_ma_per_zero * (dram.LINE_BITS - onesf))
    return base * factor + io


def integrate_charges(trace: CommandTrace, feats: TraceFeatures,
                      pp: PowerParams, i_rw: jax.Array) -> jax.Array:
    """The integrator: bank-state background over each command's slot,
    RD/WR burst crediting, ACT (+PRE pair) and REF charges — the
    fixed-shape form every ``impl`` shares.  ``i_rw`` is the
    data-dependent RD/WR current, supplied by the caller (``rw_current``
    on the vectorized path, the fused Pallas kernel on the ``pallas``
    path).  Returns per-command (N,) charges in mA*cycles; a dt=0 pad
    slot contributes exactly zero."""
    dt = trace.dt.astype(jnp.float32)
    i_bg = background_current(pp, feats.bg_state,
                              pp.i2n + feats.bg_delta_sum)
    charge = i_bg * dt

    # RD/WR burst charge above background
    burst = jnp.minimum(dt, float(TIMING.tBURST))
    charge = charge + jnp.where(feats.is_rw, (i_rw - i_bg) * burst, 0.0)

    # ACT (+PRE pair) charge with the row-address structural factor and the
    # per-(bank, row-band) structural surface (paper Section 6)
    act_q = pp.q_actpre * (1.0 + pp.row_ones_slope
                           * feats.row_ones.astype(jnp.float32))
    act_q = act_q * pp.act_surface[trace.bank, row_band(trace.row)]
    charge = charge + jnp.where(trace.cmd == ACT, act_q, 0.0)

    # REF charge above background
    charge = charge + jnp.where(trace.cmd == REF, pp.q_ref, 0.0)
    return charge


def charge_from_features(trace: CommandTrace, feats: TraceFeatures,
                         pp: PowerParams):
    """Per-command charge (mA*cycles). Returns (N,) charges."""
    i_rw = rw_current(pp, feats.op, feats.il_mode, feats.ones, feats.toggles,
                      trace.bank)
    return integrate_charges(trace, feats, pp, i_rw)


def masked_totals(trace: CommandTrace, weight: jax.Array,
                  charges: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reduce per-command charges to (masked charge, masked cycles) under a
    validity/measurement mask — the shared tail of every fixed-shape
    batched evaluation (padding and setup slots carry weight 0)."""
    cycles = jnp.sum(trace.dt * weight.astype(jnp.int32), dtype=jnp.int32)
    return jnp.sum(charges * weight), cycles


# ---------------------------------------------------------------------------
# The structural-variation surface reduction (mode='surface'): the grouped
# twin of ``masked_totals``.  Every impl shares the same cell bookkeeping —
# a command belongs to the (bank, row-band) cell of its bank/row address —
# so the surfaces are parity-held across impls by construction, and summing
# a surface over its cells recovers the mode='mean' totals exactly.
# ---------------------------------------------------------------------------
def surface_cells(trace: CommandTrace) -> jax.Array:
    """(N,) flattened (bank, row-band) cell index of every command."""
    return trace.bank * N_ROW_BANDS + row_band(trace.row)


def surface_charge(trace: CommandTrace, weight: jax.Array,
                   charges: jax.Array) -> jax.Array:
    """Masked per-command charges grouped onto the structural surface ->
    (8, N_ROW_BANDS) mA*cycles.  A weight-0 (pad/setup) slot contributes
    exactly zero to its cell."""
    grouped = jax.ops.segment_sum(charges * weight, surface_cells(trace),
                                  num_segments=N_SURFACE_CELLS)
    return grouped.reshape(N_BANKS, N_ROW_BANDS)


def surface_cycles(trace: CommandTrace, weight: jax.Array) -> jax.Array:
    """Masked cycles grouped onto the surface -> (8, N_ROW_BANDS) int32
    (parameter-independent: shared across every vendor of a dispatch)."""
    grouped = jax.ops.segment_sum(trace.dt * weight.astype(jnp.int32),
                                  surface_cells(trace),
                                  num_segments=N_SURFACE_CELLS)
    return grouped.reshape(N_BANKS, N_ROW_BANDS).astype(jnp.int32)


class EnergyReport(NamedTuple):
    charge_ma_cycles: jax.Array
    cycles: jax.Array
    avg_current_ma: jax.Array
    energy_pj: jax.Array   # charge * tCK_ns * VDD  (mA*ns*V == pJ)
    time_ns: jax.Array


def _report(total_charge, total_cycles) -> EnergyReport:
    t_ns = total_cycles.astype(jnp.float32) * TCK_NS
    avg = total_charge / jnp.maximum(total_cycles.astype(jnp.float32), 1.0)
    return EnergyReport(total_charge, total_cycles, avg,
                        total_charge * TCK_NS * VDD, t_ns)


def scale_report(rep: EnergyReport, factor) -> EnergyReport:
    """Apply a multiplicative current factor to a report: charge, current,
    and energy scale together; the trace's duration does not."""
    return EnergyReport(rep.charge_ma_cycles * factor, rep.cycles,
                        rep.avg_current_ma * factor, rep.energy_pj * factor,
                        rep.time_ns)


@functools.partial(jax.jit, static_argnames=())
def trace_energy_vectorized(trace: CommandTrace, pp: PowerParams) -> EnergyReport:
    feats = extract_features(trace, pp)
    charges = charge_from_features(trace, feats, pp)
    return _report(jnp.sum(charges), trace.total_cycles())


def per_command_energy(trace: CommandTrace, pp: PowerParams) -> jax.Array:
    """(N,) per-command energy in pJ (vectorized path)."""
    feats = extract_features(trace, pp)
    charges = charge_from_features(trace, feats, pp)
    return charges * TCK_NS * VDD


# ---------------------------------------------------------------------------
# Scan oracle (identical semantics, sequential state machine)
# ---------------------------------------------------------------------------
class _ScanState(NamedTuple):
    bank_open: jax.Array        # (8,) bool
    # background ENTRY kind: BG_ACTIVE / BG_PDN_FAST / BG_PDN_SLOW / BG_SR;
    # the fast-vs-active distinction is resolved per step from bank_open
    # (matching the vectorized lattice's per-slot ``open_before``)
    bg_mode: jax.Array          # () int32
    prev_data: jax.Array        # (16,) uint32
    has_prev: jax.Array         # () bool
    prev_bank: jax.Array        # () int32
    last_col_in_bank: jax.Array # (8,) int32 (-1 = never)
    charge: jax.Array           # () float32


@jax.jit
def trace_charges_scan(trace: CommandTrace, pp: PowerParams) -> jax.Array:
    """(N,) per-command charges (mA*cycles) from the sequential oracle —
    the ``impl='reference'`` source for the surface decomposition."""
    def step(s: _ScanState, x):
        cmd, bank, row, col, data, dt = x
        dtf = dt.astype(jnp.float32)
        bg_state = jnp.where(
            (s.bg_mode == BG_PDN_FAST) & jnp.any(s.bank_open),
            BG_PDN_ACT, s.bg_mode)
        i_bg = background_current(
            pp, bg_state,
            pp.i2n + jnp.sum(jnp.where(s.bank_open, pp.bank_open_delta, 0.0)))
        charge = i_bg * dtf

        is_rw = (cmd == RD) | (cmd == WR)
        op = jnp.where(cmd == WR, 1, 0)
        same_bank = s.has_prev & (s.prev_bank == bank)
        prev_col_b = s.last_col_in_bank[bank]
        il_mode = jnp.where(
            ~s.has_prev, IL_NONE,
            jnp.where(same_bank,
                      jnp.where(prev_col_b == col, IL_NONE, IL_COL),
                      jnp.where(prev_col_b == col, IL_BANK, IL_BANKCOL)))
        ones = line_ones(data)
        toggles = jnp.where(s.has_prev,
                            line_ones(jnp.bitwise_xor(data, s.prev_data)), 0)
        i_rw = rw_current(pp, op, il_mode, ones, toggles, bank)
        burst = jnp.minimum(dtf, float(TIMING.tBURST))
        charge = charge + jnp.where(is_rw, (i_rw - i_bg) * burst, 0.0)

        row_ones = jnp.sum(popcount_u32(row.astype(jnp.uint32)[None]))
        act_q = pp.q_actpre * (1.0 + pp.row_ones_slope * row_ones)
        act_q = act_q * pp.act_surface[bank, row_band(row)]
        charge = charge + jnp.where(cmd == ACT, act_q, 0.0)
        charge = charge + jnp.where(cmd == REF, pp.q_ref, 0.0)

        bank_oh = jax.nn.one_hot(bank, N_BANKS, dtype=jnp.bool_)
        bank_open = jnp.where(cmd == ACT, s.bank_open | bank_oh, s.bank_open)
        bank_open = jnp.where(cmd == PRE, bank_open & ~bank_oh, bank_open)
        bank_open = jnp.where(cmd == PREA, jnp.zeros_like(bank_open), bank_open)
        bg_mode = s.bg_mode
        bg_mode = jnp.where(cmd == PDE, BG_PDN_FAST, bg_mode)
        bg_mode = jnp.where(cmd == PDE_SLOW, BG_PDN_SLOW, bg_mode)
        bg_mode = jnp.where(cmd == SRE, BG_SR, bg_mode)
        bg_mode = jnp.where((cmd == PDX) | (cmd == SRX), BG_ACTIVE, bg_mode)
        new = _ScanState(
            bank_open=bank_open,
            bg_mode=bg_mode.astype(jnp.int32),
            prev_data=jnp.where(is_rw, data, s.prev_data),
            has_prev=s.has_prev | is_rw,
            prev_bank=jnp.where(is_rw, bank, s.prev_bank),
            last_col_in_bank=jnp.where(
                is_rw & bank_oh, col, s.last_col_in_bank),
            charge=s.charge + charge)
        return new, charge

    n = trace.n
    init = _ScanState(
        bank_open=jnp.zeros(N_BANKS, dtype=jnp.bool_),
        bg_mode=jnp.asarray(BG_ACTIVE, dtype=jnp.int32),
        prev_data=jnp.zeros(dram.LINE_WORDS, dtype=jnp.uint32),
        has_prev=jnp.asarray(False),
        prev_bank=jnp.asarray(-1, dtype=jnp.int32),
        last_col_in_bank=jnp.full(N_BANKS, -1, dtype=jnp.int32),
        charge=jnp.asarray(0.0, dtype=jnp.float32))
    xs = (trace.cmd, trace.bank, trace.row, trace.col, trace.data, trace.dt)
    _, charges = jax.lax.scan(step, init, xs)
    return charges


@jax.jit
def trace_energy_scan(trace: CommandTrace, pp: PowerParams) -> EnergyReport:
    charges = trace_charges_scan(trace, pp)
    return _report(jnp.sum(charges), trace.total_cycles())
