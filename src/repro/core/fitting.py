"""Regression utilities used by the characterization pipeline.

The paper fits every relationship with linear least squares (Section 5.3,
Section 4's frequency extrapolation); we do the same, in JAX.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


class LinearFit(NamedTuple):
    coef: np.ndarray   # (k,) including intercept first
    r2: float
    resid_rms: float


def lstsq_fit(design: np.ndarray, y: np.ndarray) -> LinearFit:
    """Least-squares fit y ~ design @ coef; design includes the 1s column."""
    design = jnp.asarray(design, dtype=jnp.float32)
    y = jnp.asarray(y, dtype=jnp.float32)
    coef, _, _, _ = jnp.linalg.lstsq(design, y, rcond=None)
    pred = design @ coef
    ss_res = jnp.sum((y - pred) ** 2)
    ss_tot = jnp.sum((y - jnp.mean(y)) ** 2)
    r2 = float(1.0 - ss_res / jnp.maximum(ss_tot, 1e-12))
    return LinearFit(np.asarray(coef), r2,
                     float(jnp.sqrt(ss_res / y.shape[0])))


def fit_ones_toggles(ones: np.ndarray, toggles: np.ndarray,
                     currents: np.ndarray) -> LinearFit:
    """Fit paper Eq. 2: I = I_zero + dI_one * N_ones + dI_tog * N_toggles."""
    d = np.stack([np.ones_like(ones, dtype=np.float64),
                  np.asarray(ones, dtype=np.float64),
                  np.asarray(toggles, dtype=np.float64)], axis=1)
    return lstsq_fit(d, np.asarray(currents, dtype=np.float64))


# ---------------------------------------------------------------------------
# Section 4: extrapolating datasheet IDD values to 800 MT/s.
# Vendors publish IDDs at 1066/1333/1600 MT/s; at constant voltage,
# P = IV ~ V^2 f implies I is linear in f. We fit I = a + b*f by linear
# least squares and evaluate at 800 MT/s, checking goodness of fit against
# the paper's worst reported R^2 of 0.9783.
# ---------------------------------------------------------------------------
DATASHEET_FREQS_MT = (1066.0, 1333.0, 1600.0)
TARGET_FREQ_MT = 800.0


def synth_datasheet_freq_table(i_at_800: float, slope_frac: float = 4.2e-4,
                               curvature: float = 0.008,
                               seed: int = 0) -> np.ndarray:
    """Generate per-frequency datasheet entries consistent with a 'true'
    800 MT/s value: linear in f with a small curvature + rounding, which is
    what makes the extrapolation fit slightly imperfect (paper: worst
    R^2 = 0.9783 for Vendor C)."""
    rng = np.random.default_rng(seed)
    f = np.asarray(DATASHEET_FREQS_MT)
    base = i_at_800 * (1.0 + slope_frac * (f - TARGET_FREQ_MT))
    bend = 1.0 + curvature * ((f - f.mean()) / np.ptp(f)) ** 2
    vals = base * bend * (1.0 + rng.normal(0, 0.004, size=f.shape))
    # datasheets publish integer mA; the small low-power currents (IDD2P0,
    # IDD6) get half-mA steps, else quantization alone drags the
    # extrapolation R^2 under the paper's observed floor
    step = 0.5 if i_at_800 < 18.0 else 1.0
    return np.round(vals / step) * step


def extrapolate_idd_to_800(freq_values: np.ndarray) -> tuple[float, float]:
    """Fit I = a + b*f over the datasheet frequencies, return (I_800, R^2)."""
    f = np.asarray(DATASHEET_FREQS_MT)
    d = np.stack([np.ones_like(f), f], axis=1)
    fit = lstsq_fit(d, np.asarray(freq_values, dtype=np.float64))
    i800 = float(fit.coef[0] + fit.coef[1] * TARGET_FREQ_MT)
    return i800, fit.r2


# ---------------------------------------------------------------------------
# Streaming sufficient statistics (repro.core.recalibrate): decayed running
# moments per probe cell.  Kept here, next to the batch regressions, so the
# one numeric definition of "exponentially weighted mean" is shared by the
# jitted update step and the decay-equivalence tests.
# ---------------------------------------------------------------------------
def decayed_moment_update(weight, mean, observed, decay):
    """One decayed-moment step: old evidence keeps ``decay`` of its mass,
    the new observation enters with mass 1.

        w' = decay * w + 1
        m' = (decay * w * m + x) / w'

    With ``decay=1`` this is the exact running mean (from-scratch refit on
    the whole window); with ``decay<1`` old ticks fade geometrically.
    Pure elementwise jnp — safe inside jit, float32 in -> float32 out."""
    old_mass = decay * weight
    new_weight = old_mass + 1.0
    new_mean = (old_mass * mean + observed) / new_weight
    return new_weight, new_mean
