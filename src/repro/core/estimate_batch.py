"""Batched multi-trace estimation engine (the consumer-side twin of
``repro.core.fleet``).

``fleet`` collapsed the *characterization* campaign into vmapped dispatches;
this module does the same for a fitted model's *estimation* path, which is
where every downstream study (encodings, validation, serving) spends its
time once a model exists. One (trace, vendor) pair per Python call is one
separately-dispatched, separately-compiled JAX program per trace length;
here the whole (traces x vendors) energy-report matrix is a single jitted
``vmap(vmap(...))`` over the shared integrator:

* heterogeneous :class:`CommandTrace` lengths are NOP/dt=0-padded into one
  fixed-shape :class:`TraceBatch` (``dram.batch_traces`` — a zero-cycle NOP
  draws no charge and perturbs no integrator state, so padding is exact);
* :func:`batched_reports` evaluates every (trace, paramset) pair in one
  dispatch and returns an :class:`EnergyReport` whose leaves have shape
  ``(traces, vendors)``;
* :func:`batched_range_reports` additionally vmaps the per-vendor process-
  variation band -> (lo, mean, hi) report matrices;
* :func:`batched_distribution_reports` is the paper's no-data-trace mode
  (caller-supplied ones/toggle fractions) over the same batch;
* :func:`batched_surface_reports` is the structural-variation surface mode
  (paper Figs 19-22): the same integrator grouped per (bank, row-band)
  cell -> ``(traces, vendors, banks, row_bands)``-shaped report leaves,
  the whole fleet in one dispatch;
* the ``pallas_*`` twins evaluate the identical contracts through the
  fused Pallas kernel family (``impl='pallas'`` in the registry): the
  param-independent feature kernel once per batch, the per-vendor energy
  kernel gridded over the vendor axis.

This module holds the ENGINE only.  The model-facing surface is the
unified estimator protocol (``repro.core.model_api``): every estimator's
``estimate(traces, vendors, mode=...)`` feeds these dispatches with its
own stacked parameter leaves (stacked once at fit/construction time, not
per call).  Callers scoring the same trace set repeatedly (the serving
power loop, the encoding study) should build the :class:`TraceBatch` once
and reuse it — models also memoize the padding of recently seen trace
sets (``model_api.TraceBatchCache``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.dram import CommandTrace, batch_traces
from repro.core.energy_model import (EnergyReport, PowerParams, _report,
                                     charge_from_features,
                                     distribution_features,
                                     extract_structural_features,
                                     finalize_features, scale_report,
                                     surface_charge, surface_cycles)
from repro.core.fleet import batched_pair_totals


@dataclasses.dataclass(frozen=True)
class TraceBatch:
    """A fixed-shape batch of command traces (leading trace axis on every
    field) plus the validity mask that excludes padding slots."""
    trace: CommandTrace   # (T, N) on every field
    weight: jax.Array     # (T, N) float32: 1 for real commands, 0 for pad

    @classmethod
    def from_traces(cls, traces: Sequence[CommandTrace]) -> "TraceBatch":
        batch, weight = batch_traces([(tr, 0) for tr in traces])
        return cls(batch, weight)

    @property
    def n_traces(self) -> int:
        return self.trace.cmd.shape[0]


def as_trace_batch(traces) -> TraceBatch:
    """Accept a prebuilt :class:`TraceBatch`, a single trace, or a sequence
    of (ragged) traces."""
    if isinstance(traces, TraceBatch):
        return traces
    if isinstance(traces, CommandTrace):
        traces = [traces]
    return TraceBatch.from_traces(list(traces))


def bucketed_trace_batch(traces: Sequence[CommandTrace], n_slots: int,
                         length: int) -> TraceBatch:
    """Pad ragged traces into a FIXED ``(n_slots, length)`` batch shape.

    ``TraceBatch.from_traces`` pads to the request's own max length/count,
    so every distinct request shape is a fresh compile of the batched
    dispatches; this builder instead targets a caller-chosen bucket shape
    (the serving ring's vocabulary): the command axis NOP/dt=0-pads to
    ``length`` and whole zero-weight pad rows fill the trace axis up to
    ``n_slots``.  Both paddings are exact — pad commands draw no charge
    and move no state, pad rows contribute neither charge nor cycles."""
    if not traces:
        raise ValueError("bucketed_trace_batch needs at least one trace")
    if len(traces) > n_slots:
        raise ValueError(f"{len(traces)} traces exceed {n_slots} slots")
    longest = max(int(tr.n) for tr in traces)
    if longest > length:
        raise ValueError(f"longest trace ({longest} commands) exceeds the "
                         f"length bucket ({length})")
    from repro.core.dram import pad_trace
    padded = [pad_trace(tr, length) for tr in traces]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)
    weight = jnp.stack([(jnp.arange(length) < int(tr.n)).astype(jnp.float32)
                        for tr in traces])
    pad_rows = n_slots - len(traces)
    if pad_rows:
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.concatenate(
                [x, jnp.zeros((pad_rows,) + x.shape[1:], x.dtype)]), stacked)
        weight = jnp.concatenate(
            [weight, jnp.zeros((pad_rows, length), jnp.float32)])
    return TraceBatch(stacked, weight)


def original_traces(traces, tb: TraceBatch) -> list[CommandTrace]:
    """The caller's ragged traces when recoverable from the ``estimate``
    argument, else the padded batch rows — exact either way (a dt=0 NOP
    draws no charge and moves no integrator state).  Shared by every
    pair-at-a-time ``impl='reference'`` oracle."""
    if isinstance(traces, CommandTrace):
        return [traces]
    if isinstance(traces, (list, tuple)):
        return list(traces)
    return [jax.tree_util.tree_map(lambda x: x[i], tb.trace)
            for i in range(tb.n_traces)]


# ---------------------------------------------------------------------------
# The batched dispatches
# ---------------------------------------------------------------------------
@jax.jit
def batched_reports(trace: CommandTrace, weight: jax.Array,
                    stacked: PowerParams) -> EnergyReport:
    """Energy reports of every (trace, vendor) pair in one dispatch.

    ``trace``/``weight`` are a TraceBatch's padded fields; ``stacked`` is
    ``stack_params`` over the fitted vendor params. Returns an EnergyReport
    whose every leaf has shape (traces, vendors); the charge/cycle core is
    ``fleet.batched_pair_totals``, shared with the campaign engine."""
    def one_trace(tr: CommandTrace, w: jax.Array):
        return batched_pair_totals(tr, w, extract_structural_features(tr),
                                   stacked)

    charge, cycles = jax.vmap(one_trace)(trace, weight)   # (T, V), (T,)
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


@jax.jit
def batched_range_reports(trace: CommandTrace, weight: jax.Array,
                          stacked: PowerParams, band: jax.Array
                          ) -> tuple[EnergyReport, EnergyReport, EnergyReport]:
    """(lo, mean, hi) report matrices across the per-vendor process-variation
    band. ``band`` is a float32 (vendors, 2) array of multiplicative
    (lo, hi) factors, broadcast over the (traces, vendors) matrix inside the
    same dispatch rather than applied to a scalar current after the fact,
    so *every* report field (charge, current, energy) carries the band."""
    mean = batched_reports(trace, weight, stacked)
    lo = scale_report(mean, band[None, :, 0])   # (1, V) over the trace axis
    hi = scale_report(mean, band[None, :, 1])
    return lo, mean, hi


@jax.jit
def batched_distribution_reports(trace: CommandTrace, weight: jax.Array,
                                 stacked: PowerParams, ones_frac: jax.Array,
                                 toggle_frac: jax.Array) -> EnergyReport:
    """No-data-trace mode over the batch: expected ones/toggle fractions
    replace the per-command data features (paper Section 9.2 fallback).

    ``ones_frac``/``toggle_frac`` broadcast per trace: scalars or (T,)
    arrays. First-access semantics match ``extract_features``: the first
    RD/WR on the bus has no previous burst, so its expected toggles are 0.
    """
    ones_frac = jnp.broadcast_to(jnp.asarray(ones_frac, jnp.float32),
                                 (trace.cmd.shape[0],))
    toggle_frac = jnp.broadcast_to(jnp.asarray(toggle_frac, jnp.float32),
                                   (trace.cmd.shape[0],))

    def one_trace(tr: CommandTrace, w, of, tf):
        sf = distribution_features(extract_structural_features(tr), of, tf)
        return batched_pair_totals(tr, w, sf, stacked)

    charge, cycles = jax.vmap(one_trace)(trace, weight, ones_frac,
                                         toggle_frac)
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


@jax.jit
def batched_surface_reports(trace: CommandTrace, weight: jax.Array,
                            stacked: PowerParams) -> EnergyReport:
    """The fleet-wide structural-variation surfaces (``mode='surface'``):
    every (trace, vendor) pair's per-(bank, row-band) energy decomposition
    in ONE dispatch — no per-module Python sweeps.  Returns an
    :class:`EnergyReport` whose every leaf has shape
    ``(traces, vendors, banks, row_bands)``; summing the cell axes
    recovers :func:`batched_reports` exactly (same integrator, grouped by
    the structural cell index instead of totalled)."""
    def one_trace(tr: CommandTrace, w: jax.Array):
        sf = extract_structural_features(tr)

        def one_paramset(pp: PowerParams):
            charges = charge_from_features(tr, finalize_features(sf, pp), pp)
            return surface_charge(tr, w, charges)          # (8, R)

        charge = jax.vmap(one_paramset)(stacked)           # (V, 8, R)
        return charge, surface_cycles(tr, w)               # cycles: (8, R)

    charge, cycles = jax.vmap(one_trace)(trace, weight)    # (T,V,8,R), (T,8,R)
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


# ---------------------------------------------------------------------------
# The fused Pallas dispatches (impl='pallas'): same contracts as the
# vectorized trio above, evaluated by the batched kernel family in
# ``repro.kernels.vampire_energy`` (feature kernel once per batch, energy
# kernel gridded over the vendor axis).  Interpret-vs-compiled resolves per
# call inside ``ops.batched_charge_matrix``.
# ---------------------------------------------------------------------------
def pallas_batched_reports(trace: CommandTrace, weight: jax.Array,
                           stacked: PowerParams) -> EnergyReport:
    """impl='pallas' twin of :func:`batched_reports`."""
    from repro.kernels.vampire_energy import ops as vops
    charge, cycles = vops.batched_charge_matrix(trace, weight, stacked)
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


def pallas_batched_range_reports(trace: CommandTrace, weight: jax.Array,
                                 stacked: PowerParams, band: jax.Array
                                 ) -> tuple[EnergyReport, EnergyReport,
                                            EnergyReport]:
    """impl='pallas' twin of :func:`batched_range_reports`."""
    mean = pallas_batched_reports(trace, weight, stacked)
    lo = scale_report(mean, band[None, :, 0])
    hi = scale_report(mean, band[None, :, 1])
    return lo, mean, hi


def pallas_batched_distribution_reports(trace: CommandTrace,
                                        weight: jax.Array,
                                        stacked: PowerParams,
                                        ones_frac: jax.Array,
                                        toggle_frac: jax.Array
                                        ) -> EnergyReport:
    """impl='pallas' twin of :func:`batched_distribution_reports` (the
    feature kernel is skipped; expected fractions feed the energy kernel
    directly — scalar or per-trace, normalized by the kernel assembler —
    with first-access toggles pinned to 0)."""
    from repro.kernels.vampire_energy import ops as vops
    charge, cycles = vops.batched_charge_matrix(
        trace, weight, stacked, ones_frac=ones_frac, toggle_frac=toggle_frac)
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


def pallas_batched_surface_reports(trace: CommandTrace, weight: jax.Array,
                                   stacked: PowerParams) -> EnergyReport:
    """impl='pallas' twin of :func:`batched_surface_reports`: the energy
    kernel swaps its scalar charge sum for an in-kernel cell reduction over
    the (bank, row-band) one-hot plane, same (vendors, traces, blocks)
    grid."""
    from repro.kernels.vampire_energy import ops as vops
    charge, cycles = vops.batched_charge_matrix(trace, weight, stacked,
                                                surface=True)
    return _report(charge,
                   jnp.broadcast_to(cycles[:, None], charge.shape))
