"""Batched multi-trace estimation engine (the consumer-side twin of
``repro.core.fleet``).

``fleet`` collapsed the *characterization* campaign into vmapped dispatches;
this module does the same for a fitted model's *estimation* path, which is
where every downstream study (encodings, validation, serving) spends its
time once a model exists. One (trace, vendor) pair per Python call is one
separately-dispatched, separately-compiled JAX program per trace length;
here the whole (traces x vendors) energy-report matrix is a single jitted
``vmap(vmap(...))`` over the shared integrator:

* heterogeneous :class:`CommandTrace` lengths are NOP/dt=0-padded into one
  fixed-shape :class:`TraceBatch` (``dram.batch_traces`` — a zero-cycle NOP
  draws no charge and perturbs no integrator state, so padding is exact);
* :func:`batched_reports` evaluates every (trace, paramset) pair in one
  dispatch and returns an :class:`EnergyReport` whose leaves have shape
  ``(traces, vendors)``;
* :func:`batched_range_reports` additionally vmaps the per-vendor process-
  variation band -> (lo, mean, hi) report matrices;
* :func:`batched_distribution_reports` is the paper's no-data-trace mode
  (caller-supplied ones/toggle fractions) over the same batch;
* :func:`batched_surface_reports` is the structural-variation surface mode
  (paper Figs 19-22): the same integrator grouped per (bank, row-band)
  cell -> ``(traces, vendors, banks, row_bands)``-shaped report leaves,
  the whole fleet in one dispatch;
* the ``pallas_*`` twins evaluate the identical contracts through the
  fused Pallas kernel family (``impl='pallas'`` in the registry): the
  param-independent feature kernel once per batch, the per-vendor energy
  kernel gridded over the vendor axis.

This module holds the ENGINE only.  The model-facing surface is the
unified estimator protocol (``repro.core.model_api``): every estimator's
``estimate(traces, vendors, mode=...)`` feeds these dispatches with its
own stacked parameter leaves (stacked once at fit/construction time, not
per call).  Callers scoring the same trace set repeatedly (the serving
power loop, the encoding study) should build the :class:`TraceBatch` once
and reuse it — models also memoize the padding of recently seen trace
sets (``model_api.TraceBatchCache``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.dram import CommandTrace, N_BANKS, N_ROW_BANDS, batch_traces
from repro.core.energy_model import (EnergyReport, PowerParams, _report,
                                     charge_from_features,
                                     distribution_features,
                                     extract_structural_features,
                                     finalize_features, scale_report,
                                     surface_charge, surface_cycles)
from repro.core.fleet import batched_pair_totals


@dataclasses.dataclass(frozen=True)
class TraceBatch:
    """A fixed-shape batch of command traces (leading trace axis on every
    field) plus the validity mask that excludes padding slots."""
    trace: CommandTrace   # (T, N) on every field
    weight: jax.Array     # (T, N) float32: 1 for real commands, 0 for pad

    @classmethod
    def from_traces(cls, traces: Sequence[CommandTrace]) -> "TraceBatch":
        batch, weight = batch_traces([(tr, 0) for tr in traces])
        return cls(batch, weight)

    @property
    def n_traces(self) -> int:
        return self.trace.cmd.shape[0]


def as_trace_batch(traces) -> TraceBatch:
    """Accept a prebuilt :class:`TraceBatch`, a single trace, or a sequence
    of (ragged) traces."""
    if isinstance(traces, TraceBatch):
        return traces
    if isinstance(traces, CommandTrace):
        traces = [traces]
    return TraceBatch.from_traces(list(traces))


def bucketed_trace_batch(traces: Sequence[CommandTrace], n_slots: int,
                         length: int) -> TraceBatch:
    """Pad ragged traces into a FIXED ``(n_slots, length)`` batch shape.

    ``TraceBatch.from_traces`` pads to the request's own max length/count,
    so every distinct request shape is a fresh compile of the batched
    dispatches; this builder instead targets a caller-chosen bucket shape
    (the serving ring's vocabulary): the command axis NOP/dt=0-pads to
    ``length`` and whole zero-weight pad rows fill the trace axis up to
    ``n_slots``.  Both paddings are exact — pad commands draw no charge
    and move no state, pad rows contribute neither charge nor cycles."""
    if not traces:
        raise ValueError("bucketed_trace_batch needs at least one trace")
    if len(traces) > n_slots:
        raise ValueError(f"{len(traces)} traces exceed {n_slots} slots")
    longest = max(int(tr.n) for tr in traces)
    if longest > length:
        raise ValueError(f"longest trace ({longest} commands) exceeds the "
                         f"length bucket ({length})")
    from repro.core.dram import pad_trace
    padded = [pad_trace(tr, length) for tr in traces]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)
    weight = jnp.stack([(jnp.arange(length) < int(tr.n)).astype(jnp.float32)
                        for tr in traces])
    pad_rows = n_slots - len(traces)
    if pad_rows:
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.concatenate(
                [x, jnp.zeros((pad_rows,) + x.shape[1:], x.dtype)]), stacked)
        weight = jnp.concatenate(
            [weight, jnp.zeros((pad_rows, length), jnp.float32)])
    return TraceBatch(stacked, weight)


def original_traces(traces, tb: TraceBatch) -> list[CommandTrace]:
    """The caller's ragged traces when recoverable from the ``estimate``
    argument, else the padded batch rows — exact either way (a dt=0 NOP
    draws no charge and moves no integrator state).  Shared by every
    pair-at-a-time ``impl='reference'`` oracle."""
    if isinstance(traces, CommandTrace):
        return [traces]
    if isinstance(traces, (list, tuple)):
        return list(traces)
    return [jax.tree_util.tree_map(lambda x: x[i], tb.trace)
            for i in range(tb.n_traces)]


# ---------------------------------------------------------------------------
# The batched dispatches
# ---------------------------------------------------------------------------
@jax.jit
def batched_reports(trace: CommandTrace, weight: jax.Array,
                    stacked: PowerParams) -> EnergyReport:
    """Energy reports of every (trace, vendor) pair in one dispatch.

    ``trace``/``weight`` are a TraceBatch's padded fields; ``stacked`` is
    ``stack_params`` over the fitted vendor params. Returns an EnergyReport
    whose every leaf has shape (traces, vendors); the charge/cycle core is
    ``fleet.batched_pair_totals``, shared with the campaign engine."""
    def one_trace(tr: CommandTrace, w: jax.Array):
        return batched_pair_totals(tr, w, extract_structural_features(tr),
                                   stacked)

    charge, cycles = jax.vmap(one_trace)(trace, weight)   # (T, V), (T,)
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


@jax.jit
def batched_range_reports(trace: CommandTrace, weight: jax.Array,
                          stacked: PowerParams, band: jax.Array
                          ) -> tuple[EnergyReport, EnergyReport, EnergyReport]:
    """(lo, mean, hi) report matrices across the per-vendor process-variation
    band. ``band`` is a float32 (vendors, 2) array of multiplicative
    (lo, hi) factors, broadcast over the (traces, vendors) matrix inside the
    same dispatch rather than applied to a scalar current after the fact,
    so *every* report field (charge, current, energy) carries the band."""
    mean = batched_reports(trace, weight, stacked)
    lo = scale_report(mean, band[None, :, 0])   # (1, V) over the trace axis
    hi = scale_report(mean, band[None, :, 1])
    return lo, mean, hi


@jax.jit
def batched_distribution_reports(trace: CommandTrace, weight: jax.Array,
                                 stacked: PowerParams, ones_frac: jax.Array,
                                 toggle_frac: jax.Array) -> EnergyReport:
    """No-data-trace mode over the batch: expected ones/toggle fractions
    replace the per-command data features (paper Section 9.2 fallback).

    ``ones_frac``/``toggle_frac`` broadcast per trace: scalars or (T,)
    arrays. First-access semantics match ``extract_features``: the first
    RD/WR on the bus has no previous burst, so its expected toggles are 0.
    """
    ones_frac = jnp.broadcast_to(jnp.asarray(ones_frac, jnp.float32),
                                 (trace.cmd.shape[0],))
    toggle_frac = jnp.broadcast_to(jnp.asarray(toggle_frac, jnp.float32),
                                   (trace.cmd.shape[0],))

    def one_trace(tr: CommandTrace, w, of, tf):
        sf = distribution_features(extract_structural_features(tr), of, tf)
        return batched_pair_totals(tr, w, sf, stacked)

    charge, cycles = jax.vmap(one_trace)(trace, weight, ones_frac,
                                         toggle_frac)
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


def batched_surface_reports(trace: CommandTrace, weight: jax.Array,
                            stacked: PowerParams) -> EnergyReport:
    """The fleet-wide structural-variation surfaces (``mode='surface'``):
    every (trace, vendor) pair's per-(bank, row-band) energy decomposition
    in ONE dispatch — no per-module Python sweeps.  Returns an
    :class:`EnergyReport` whose every leaf has shape
    ``(traces, vendors, banks, row_bands)``; summing the cell axes
    recovers :func:`batched_reports` exactly (same integrator, grouped by
    the structural cell index instead of totalled).

    The charge program is the SAME jitted chunk program the fleet-scale
    chunked dispatch runs (:func:`_surface_chunk_charge` with the whole
    module axis as one chunk), so chunked-vs-one-shot parity is bitwise
    by construction, not merely allclose."""
    charge = _surface_chunk_charge(trace, weight, stacked, False, False)
    cycles = _surface_cycles_batch(trace, weight)          # (T, 8, R)
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


# ---------------------------------------------------------------------------
# Chunked surface dispatch: the fleet-scale twin of
# ``batched_surface_reports``.
#
# The one-shot surface dispatch materializes every (trace, module) pair's
# per-command intermediates at once — for a 10k-50k module fleet that is
# tens of GB of finalize/charge planes for a result that is only
# ``(T, V, 8, R)``.  The chunked path bounds live memory to ONE module
# chunk's intermediates: a Python loop over fixed-shape chunk programs
# (the loop is host-side so the compiled-program count depends on the
# chunk SIZE, never the chunk COUNT — growing the fleet reuses the same
# program, the property ``analysis.dispatch_audit.audit_fleet_chunked``
# asserts), each chunk's charge scattered into a DONATED full-width
# accumulator (``_scatter_chunk`` donates its carry, so XLA updates the
# surface in place instead of copying it per chunk).  Exact parity with
# the one-shot path: identical per-(trace, module) math, identical
# ``_report`` finalization, pad modules (chunk-size remainder) sliced off
# before the report is built.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("pallas", "interpret"))
def _surface_chunk_charge(trace: CommandTrace, weight, chunk_pp: PowerParams,
                          pallas: bool, interpret: bool):
    """One module chunk's surface charge -> (T, chunk, 8, R) f32.  The
    per-pair math is verbatim :func:`batched_surface_reports` (vectorized)
    or the fused surface kernel (pallas), so chunked == one-shot holds
    leaf-exactly."""
    if pallas:
        from repro.kernels.vampire_energy import ops as vops
        charge, _ = vops.batched_charge_matrix(trace, weight, chunk_pp,
                                               surface=True,
                                               interpret=interpret)
        return charge

    def one_trace(tr: CommandTrace, w: jax.Array):
        sf = extract_structural_features(tr)

        def one_paramset(pp: PowerParams):
            charges = charge_from_features(tr, finalize_features(sf, pp), pp)
            return surface_charge(tr, w, charges)          # (8, R)

        return jax.vmap(one_paramset)(chunk_pp)            # (chunk, 8, R)

    return jax.vmap(one_trace)(trace, weight)              # (T, chunk, 8, R)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_chunk(acc, charge, t_start, m_start):
    """Write one chunk's (t, c, 8, R) charge into the full surface at the
    (trace, module) offset (traced i32 scalars, so every chunk index
    reuses one compiled program).  ``acc`` is donated: the accumulator is
    updated in place across the chunk loop, never copied."""
    zero = jnp.int32(0)
    return jax.lax.dynamic_update_slice(
        acc, charge, (jnp.asarray(t_start, jnp.int32),
                      jnp.asarray(m_start, jnp.int32), zero, zero))


@jax.jit
def _surface_cycles_batch(trace: CommandTrace, weight) -> jax.Array:
    return jax.vmap(surface_cycles)(trace, weight)         # (T, 8, R)


def _pad_leading(tree, pad: int):
    """Extend every leaf's leading axis by ``pad`` rows replicating row 0
    (any valid params work — pad modules are sliced off before the report;
    replication keeps the chunk numerically well-behaved)."""
    if pad == 0:
        return tree
    return jax.tree_util.tree_map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])]), tree)


def chunked_surface_reports(trace: CommandTrace, weight, stacked: PowerParams,
                            *, module_chunk: int,
                            trace_chunk: int | None = None,
                            impl: str = "vectorized",
                            interpret: bool | None = None) -> EnergyReport:
    """Memory-bounded ``mode='surface'`` over a stacked module axis of any
    size: :func:`batched_surface_reports`' exact result, evaluated
    ``module_chunk`` modules (and optionally ``trace_chunk`` traces) at a
    time.  ``impl`` is ``'vectorized'`` or ``'pallas'``."""
    from repro.kernels.common import interpret_default
    pallas = impl == "pallas"
    if interpret is None:
        interpret = interpret_default()
    # interpret only steers the pallas lowering; pin it on the vectorized
    # path so both the one-shot and chunked dispatch share ONE jit entry
    interpret = bool(interpret) if pallas else False
    n_modules = stacked.i2n.shape[0]
    n_traces = trace.cmd.shape[0]
    module_chunk = min(int(module_chunk), n_modules)
    trace_chunk = (n_traces if trace_chunk is None
                   else min(int(trace_chunk), n_traces))

    m_pad = (-n_modules) % module_chunk
    stacked = _pad_leading(stacked, m_pad)
    t_pad = (-n_traces) % trace_chunk
    if t_pad:
        # zero-weight pad rows are exact by the TraceBatch contract
        trace = _pad_leading(trace, t_pad)
        weight = jnp.concatenate(
            [weight, jnp.zeros((t_pad,) + weight.shape[1:], weight.dtype)])

    acc = jnp.zeros((n_traces + t_pad, n_modules + m_pad, N_BANKS,
                     N_ROW_BANDS), jnp.float32)
    for ti in range(0, n_traces + t_pad, trace_chunk):
        tr_c = jax.tree_util.tree_map(lambda x: x[ti:ti + trace_chunk],
                                      trace)
        w_c = weight[ti:ti + trace_chunk]
        for mi in range(0, n_modules + m_pad, module_chunk):
            chunk_pp = jax.tree_util.tree_map(
                lambda x: x[mi:mi + module_chunk], stacked)
            charge = _surface_chunk_charge(tr_c, w_c, chunk_pp, pallas,
                                           interpret)
            acc = _scatter_chunk(acc, charge, jnp.int32(ti), jnp.int32(mi))
    charge = acc[:n_traces, :n_modules]
    cycles = _surface_cycles_batch(
        jax.tree_util.tree_map(lambda x: x[:n_traces], trace),
        weight[:n_traces])
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


# ---------------------------------------------------------------------------
# The fused Pallas dispatches (impl='pallas'): same contracts as the
# vectorized trio above, evaluated by the batched kernel family in
# ``repro.kernels.vampire_energy`` (feature kernel once per batch, energy
# kernel gridded over the vendor axis).  Interpret-vs-compiled resolves per
# call inside ``ops.batched_charge_matrix``.
# ---------------------------------------------------------------------------
def pallas_batched_reports(trace: CommandTrace, weight: jax.Array,
                           stacked: PowerParams) -> EnergyReport:
    """impl='pallas' twin of :func:`batched_reports`."""
    from repro.kernels.vampire_energy import ops as vops
    charge, cycles = vops.batched_charge_matrix(trace, weight, stacked)
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


def pallas_batched_range_reports(trace: CommandTrace, weight: jax.Array,
                                 stacked: PowerParams, band: jax.Array
                                 ) -> tuple[EnergyReport, EnergyReport,
                                            EnergyReport]:
    """impl='pallas' twin of :func:`batched_range_reports`."""
    mean = pallas_batched_reports(trace, weight, stacked)
    lo = scale_report(mean, band[None, :, 0])
    hi = scale_report(mean, band[None, :, 1])
    return lo, mean, hi


def pallas_batched_distribution_reports(trace: CommandTrace,
                                        weight: jax.Array,
                                        stacked: PowerParams,
                                        ones_frac: jax.Array,
                                        toggle_frac: jax.Array
                                        ) -> EnergyReport:
    """impl='pallas' twin of :func:`batched_distribution_reports` (the
    feature kernel is skipped; expected fractions feed the energy kernel
    directly — scalar or per-trace, normalized by the kernel assembler —
    with first-access toggles pinned to 0)."""
    from repro.kernels.vampire_energy import ops as vops
    charge, cycles = vops.batched_charge_matrix(
        trace, weight, stacked, ones_frac=ones_frac, toggle_frac=toggle_frac)
    return _report(charge, jnp.broadcast_to(cycles[:, None], charge.shape))


def pallas_batched_surface_reports(trace: CommandTrace, weight: jax.Array,
                                   stacked: PowerParams) -> EnergyReport:
    """impl='pallas' twin of :func:`batched_surface_reports`: the energy
    kernel swaps its scalar charge sum for an in-kernel cell reduction over
    the (bank, row-band) one-hot plane, same (vendors, traces, blocks)
    grid."""
    from repro.kernels.vampire_energy import ops as vops
    charge, cycles = vops.batched_charge_matrix(trace, weight, stacked,
                                                surface=True)
    return _report(charge,
                   jnp.broadcast_to(cycles[:, None], charge.shape))
