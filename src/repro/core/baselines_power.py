"""State-of-the-art baseline DRAM power models the paper validates against
(Section 9.1): the Micron power calculator (TN-41-01) and DRAMPower.

Both are IDD/datasheet-driven. We implement them *faithfully to their
documented flaws* (as characterized in the paper and in [26, 65]):

Micron model:
  * uses worst-case datasheet IDD values;
  * background power assumes the device is in the all-banks-active state
    whenever the trace is active (does not track the number of open banks);
  * activate/precharge power is computed from IDD0 at the *specification*
    command spacing (tRC), not the actual spacing in the trace;
  * no data dependency, no structural variation, no process variation.

DRAMPower:
  * uses datasheet IDD values, but integrates with the *actual* command
    timing from the trace;
  * background state tracked as precharged (IDD2N) vs. >=1 bank active
    (IDD3N) — not per-bank;
  * read/write energies from IDD4R/IDD4W over the actual burst windows;
  * no data dependency, no structural variation.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.dram import (ACT, RD, WR, REF, CommandTrace, TIMING)
from repro.core.energy_model import (EnergyReport, _report,
                                     extract_features, zeros_like_params)

_T = TIMING


def _features(trace: CommandTrace):
    # reuse the vectorized state machine with dummy params (only bank/PD
    # state and rw/op masks are needed)
    return extract_features(trace, zeros_like_params())


def micron_power(trace: CommandTrace, ds: dict[str, float]) -> EnergyReport:
    """TN-41-01-style estimate from datasheet IDDs."""
    f = _features(trace)
    dt = trace.dt.astype(jnp.float32)
    # Worst-case background: all-banks-active current whenever not powered
    # down (the flaw reported by [65] and Section 9.1).
    i_bg = jnp.where(f.powered_down, ds["IDD2P1"], ds["IDD3N"])
    charge = i_bg * dt
    # ACT/PRE power at the *specification* row-cycling rate: the calculator
    # charges one ACT/PRE pair per spec tRC of active time, regardless of the
    # actual command spacing in the trace ([26]'s "does not account for any
    # additional time that may elapse between two DRAM commands").
    q_act = (ds["IDD0"] - (ds["IDD3N"] * _T.tRAS + ds["IDD2N"] * _T.tRP)
             / _T.tRC) * _T.tRC
    q_act = jnp.maximum(q_act, 0.0)
    any_act = jnp.any(trace.cmd == ACT)
    charge = charge + jnp.where(~f.powered_down & any_act,
                                q_act * dt / _T.tRC, 0.0)
    # Read/write power stacked on the (already worst-case) background — the
    # calculator's documented mishandling of bank-state/command interaction
    # ([65]; Section 9.1: "significantly overestimates the power").
    burst = jnp.minimum(dt, float(_T.tBURST))
    charge = charge + jnp.where(trace.cmd == RD, ds["IDD4R"] * burst, 0.0)
    charge = charge + jnp.where(trace.cmd == WR, ds["IDD4W"] * burst, 0.0)
    charge = charge + jnp.where(
        trace.cmd == REF, (ds["IDD5B"] - ds["IDD2N"]) * _T.tRFC, 0.0)
    return _report(jnp.sum(charge), trace.total_cycles())


def drampower(trace: CommandTrace, ds: dict[str, float]) -> EnergyReport:
    """DRAMPower-style estimate: datasheet IDDs, actual timing."""
    f = _features(trace)
    dt = trace.dt.astype(jnp.float32)
    # Bank-sensitive background (DRAMPower includes the [65, 107] extension:
    # linear interpolation between IDD2N and IDD3N by open-bank count), but
    # with datasheet values and no per-bank structure.
    i_bg = jnp.where(
        f.powered_down, ds["IDD2P1"],
        ds["IDD2N"] + (ds["IDD3N"] - ds["IDD2N"]) * f.open_banks / 8.0)
    charge = i_bg * dt
    # ACT/PRE pair charge above the active background, from IDD0:
    q_act = (ds["IDD0"] - (ds["IDD3N"] * _T.tRAS + ds["IDD2N"] * _T.tRP)
             / _T.tRC) * _T.tRC
    q_act = jnp.maximum(q_act, 0.0)
    charge = charge + jnp.where(trace.cmd == ACT, q_act, 0.0)
    burst = jnp.minimum(dt, float(_T.tBURST))
    charge = charge + jnp.where(
        trace.cmd == RD, (ds["IDD4R"] - i_bg) * burst, 0.0)
    charge = charge + jnp.where(
        trace.cmd == WR, (ds["IDD4W"] - i_bg) * burst, 0.0)
    charge = charge + jnp.where(
        trace.cmd == REF, (ds["IDD5B"] - ds["IDD2N"]) * _T.tRFC, 0.0)
    return _report(jnp.sum(charge), trace.total_cycles())


MODELS = {"micron": micron_power, "drampower": drampower}
