"""State-of-the-art baseline DRAM power models the paper validates against
(Section 9.1): the Micron power calculator (TN-41-01) and DRAMPower.

Both are IDD/datasheet-driven. We implement them *faithfully to their
documented flaws* (as characterized in the paper and in [26, 65]):

Micron model:
  * uses worst-case datasheet IDD values;
  * background power assumes the device is in the all-banks-active state
    whenever the trace is active (does not track the number of open banks);
  * activate/precharge power is computed from IDD0 at the *specification*
    command spacing (tRC), not the actual spacing in the trace;
  * no data dependency, no structural variation, no process variation.

DRAMPower:
  * uses datasheet IDD values, but integrates with the *actual* command
    timing from the trace;
  * background state tracked as precharged (IDD2N) vs. >=1 bank active
    (IDD3N) — not per-bank;
  * read/write energies from IDD4R/IDD4W over the actual burst windows;
  * no data dependency, no structural variation.

Both are exposed two ways:

* the per-trace functions :func:`micron_power` / :func:`drampower`
  (one trace, one datasheet dict) — the paper-figure form;
* :class:`MicronModel` / :class:`DRAMPowerModel`, estimators implementing
  the unified protocol (``repro.core.model_api``): pytree-native (the
  stacked (vendors, keys) IDD table is the array leaf), scored over a
  padded :class:`~repro.core.estimate_batch.TraceBatch` through the SAME
  shared structural-feature pass as VAMPIRE, one vmapped dispatch per
  (traces x vendors) grid.

Neither baseline models data dependency or process variation — that is the
paper's point — so ``mode='distribution'`` degenerates to ``'mean'`` (the
ones/toggle fractions cannot matter) and ``mode='range'`` returns a
collapsed (mean, mean, mean) band.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model_api
from repro.core.dram import (ACT, RD, WR, REF, CommandTrace, TIMING)
from repro.core.energy_model import (BG_ACTIVE, BG_PDN_ACT, BG_PDN_FAST,
                                     BG_PDN_SLOW, EnergyReport,
                                     StructuralFeatures, _report,
                                     extract_structural_features,
                                     surface_charge, surface_cycles)

_T = TIMING

# datasheet keys the baseline formulas consume, in stacked-table order;
# the low-power keys are appended at the END so stacked tables saved
# before the background-state lattice keep their column meaning
BASELINE_IDD_KEYS = ("IDD0", "IDD2N", "IDD2P1", "IDD3N", "IDD4R", "IDD4W",
                     "IDD5B", "IDD2P0", "IDD3P", "IDD6")
_LOWPOWER_KEYS = ("IDD2P0", "IDD3P", "IDD6")


def with_lowpower_defaults(ds) -> dict:
    """Datasheet dicts predating the background-state lattice lack the
    low-power keys; default them to the fast power-down current (the old
    models' single power-down rate), keeping old blobs loadable."""
    if all(k in ds for k in _LOWPOWER_KEYS):
        return dict(ds)
    out = dict(ds)
    for k in _LOWPOWER_KEYS:
        out.setdefault(k, out["IDD2P1"])
    return out


def _bg_state(sf: StructuralFeatures):
    """The two structural facts both baselines consume, from the shared
    param-independent feature pass: per-command open-bank count and the
    background-state code (BG_*)."""
    return jnp.sum(sf.open_before.astype(jnp.float32), axis=1), sf.bg_state


def _bg_lut(bg_state, i_active, ds):
    """Background current from the state code — the baselines' datasheet
    LUT twin of :func:`energy_model.background_current`."""
    i_low = jnp.where(bg_state == BG_PDN_FAST, ds["IDD2P1"],
                      jnp.where(bg_state == BG_PDN_SLOW, ds["IDD2P0"],
                                jnp.where(bg_state == BG_PDN_ACT,
                                          ds["IDD3P"], ds["IDD6"])))
    return jnp.where(bg_state == BG_ACTIVE, i_active, i_low)


def act_pair_charge(idd0, idd2n, idd3n) -> jax.Array:
    """ACT/PRE pair charge above the active background, from IDD0 at the
    specification row-cycle — the ONE definition of this physics, shared
    by both baselines here and by the fused ``kernels/baseline_energy``
    kernel (so ``impl='pallas'`` cannot drift from ``'vectorized'``)."""
    return jnp.maximum(
        (idd0 - (idd3n * _T.tRAS + idd2n * _T.tRP) / _T.tRC) * _T.tRC, 0.0)


def _act_pair_charge(ds) -> jax.Array:
    return act_pair_charge(ds["IDD0"], ds["IDD2N"], ds["IDD3N"])


def micron_charges(trace: CommandTrace, open_banks, bg_state,
                   ds) -> jax.Array:
    """Per-command charge (mA*cycles) of the TN-41-01-style estimate.
    ``ds`` maps IDD key -> current; values broadcast against the trace."""
    del open_banks  # the calculator's documented flaw: bank count ignored
    dt = trace.dt.astype(jnp.float32)
    # Worst-case background: all-banks-active current whenever not in a
    # low-power state (the flaw reported by [65] and Section 9.1).
    i_bg = _bg_lut(bg_state, ds["IDD3N"], ds)
    charge = i_bg * dt
    # ACT/PRE power at the *specification* row-cycling rate: the calculator
    # charges one ACT/PRE pair per spec tRC of active time, regardless of the
    # actual command spacing in the trace ([26]'s "does not account for any
    # additional time that may elapse between two DRAM commands").
    q_act = _act_pair_charge(ds)
    any_act = jnp.any(trace.cmd == ACT)
    charge = charge + jnp.where((bg_state == BG_ACTIVE) & any_act,
                                q_act * dt / _T.tRC, 0.0)
    # Read/write power stacked on the (already worst-case) background — the
    # calculator's documented mishandling of bank-state/command interaction
    # ([65]; Section 9.1: "significantly overestimates the power").
    burst = jnp.minimum(dt, float(_T.tBURST))
    charge = charge + jnp.where(trace.cmd == RD, ds["IDD4R"] * burst, 0.0)
    charge = charge + jnp.where(trace.cmd == WR, ds["IDD4W"] * burst, 0.0)
    charge = charge + jnp.where(
        trace.cmd == REF, (ds["IDD5B"] - ds["IDD2N"]) * _T.tRFC, 0.0)
    return charge


def drampower_charges(trace: CommandTrace, open_banks, bg_state,
                      ds) -> jax.Array:
    """Per-command charge (mA*cycles) of the DRAMPower-style estimate:
    datasheet IDDs, actual timing."""
    dt = trace.dt.astype(jnp.float32)
    # Bank-sensitive background (DRAMPower includes the [65, 107] extension:
    # linear interpolation between IDD2N and IDD3N by open-bank count), but
    # with datasheet values and no per-bank structure.
    i_bg = _bg_lut(
        bg_state,
        ds["IDD2N"] + (ds["IDD3N"] - ds["IDD2N"]) * open_banks / 8.0, ds)
    charge = i_bg * dt
    charge = charge + jnp.where(trace.cmd == ACT, _act_pair_charge(ds), 0.0)
    burst = jnp.minimum(dt, float(_T.tBURST))
    charge = charge + jnp.where(
        trace.cmd == RD, (ds["IDD4R"] - i_bg) * burst, 0.0)
    charge = charge + jnp.where(
        trace.cmd == WR, (ds["IDD4W"] - i_bg) * burst, 0.0)
    charge = charge + jnp.where(
        trace.cmd == REF, (ds["IDD5B"] - ds["IDD2N"]) * _T.tRFC, 0.0)
    return charge


_CHARGE_FNS = {"micron": micron_charges, "drampower": drampower_charges}


def micron_power(trace: CommandTrace, ds: dict[str, float]) -> EnergyReport:
    """TN-41-01-style estimate from datasheet IDDs (single trace)."""
    ds = with_lowpower_defaults(ds)
    ob, pd = _bg_state(extract_structural_features(trace))
    charge = micron_charges(trace, ob, pd,
                            {k: jnp.float32(ds[k]) for k in BASELINE_IDD_KEYS})
    return _report(jnp.sum(charge), trace.total_cycles())


def drampower(trace: CommandTrace, ds: dict[str, float]) -> EnergyReport:
    """DRAMPower-style estimate: datasheet IDDs, actual timing (single
    trace)."""
    ds = with_lowpower_defaults(ds)
    ob, pd = _bg_state(extract_structural_features(trace))
    charge = drampower_charges(
        trace, ob, pd, {k: jnp.float32(ds[k]) for k in BASELINE_IDD_KEYS})
    return _report(jnp.sum(charge), trace.total_cycles())


MODELS = {"micron": micron_power, "drampower": drampower}


# ---------------------------------------------------------------------------
# Batched dispatches (one per baseline, shared skeleton)
# ---------------------------------------------------------------------------
def _batched_baseline(charge_fn):
    @jax.jit
    def dispatch(trace: CommandTrace, weight: jax.Array,
                 table: jax.Array) -> EnergyReport:
        """Energy reports of every (trace, vendor) pair in one dispatch.
        ``trace``/``weight`` are a TraceBatch's padded fields; ``table`` is
        the stacked (vendors, len(BASELINE_IDD_KEYS)) datasheet matrix."""
        def one_trace(tr: CommandTrace, w: jax.Array):
            ob, pd = _bg_state(extract_structural_features(tr))
            cycles = jnp.sum(tr.dt * w.astype(jnp.int32), dtype=jnp.int32)

            def one_vendor(row):
                ds = {k: row[i] for i, k in enumerate(BASELINE_IDD_KEYS)}
                return jnp.sum(charge_fn(tr, ob, pd, ds) * w)

            return jax.vmap(one_vendor)(table), cycles

        charge, cycles = jax.vmap(one_trace)(trace, weight)   # (T, V), (T,)
        return _report(charge,
                       jnp.broadcast_to(cycles[:, None], charge.shape))
    return dispatch


_BATCHED = {kind: _batched_baseline(fn) for kind, fn in _CHARGE_FNS.items()}


def _batched_baseline_surface(charge_fn):
    @jax.jit
    def dispatch(trace: CommandTrace, weight: jax.Array,
                 table: jax.Array) -> EnergyReport:
        """``mode='surface'`` twin of the mean dispatch: the identical
        per-command charges grouped onto the (bank, row-band) cells ->
        (traces, vendors, banks, row_bands)-shaped report leaves.  The
        baselines model no structural variation — that is the paper's
        point — so their surfaces are flat in everything but workload
        placement; the decomposition is what exposes that flatness next
        to VAMPIRE's."""
        def one_trace(tr: CommandTrace, w: jax.Array):
            ob, pd = _bg_state(extract_structural_features(tr))

            def one_vendor(row):
                ds = {k: row[i] for i, k in enumerate(BASELINE_IDD_KEYS)}
                return surface_charge(tr, w, charge_fn(tr, ob, pd, ds))

            return jax.vmap(one_vendor)(table), surface_cycles(tr, w)

        charge, cycles = jax.vmap(one_trace)(trace, weight)
        return _report(charge,
                       jnp.broadcast_to(cycles[:, None], charge.shape))
    return dispatch


_BATCHED_SURFACE = {kind: _batched_baseline_surface(fn)
                    for kind, fn in _CHARGE_FNS.items()}


# ---------------------------------------------------------------------------
# Protocol estimators
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DatasheetModel(model_api.StackedEstimatorMixin):
    """Base of the baseline estimators: per-vendor datasheet IDD values as
    one stacked pytree leaf, scored through the shared batched engine."""
    datasheets: dict[int, dict[str, float]]
    idd_table: jax.Array = None  # type: ignore  # (V, K) float32 leaf

    kind = None  # class attribute (NOT a field), overridden per subclass

    def __post_init__(self):
        self.datasheets = {v: with_lowpower_defaults(d)
                           for v, d in self.datasheets.items()}
        if self.idd_table is None:
            self.idd_table = jnp.asarray(
                [[self.datasheets[v][k] for k in BASELINE_IDD_KEYS]
                 for v in sorted(self.datasheets)], jnp.float32)
        elif self.idd_table.shape[-1] < len(BASELINE_IDD_KEYS):
            # stacked table saved before the background-state lattice:
            # pad the missing low-power columns with the IDD2P1 column
            pd_col = self.idd_table[:, BASELINE_IDD_KEYS.index("IDD2P1")]
            pad = jnp.tile(pd_col[:, None],
                           (1, len(BASELINE_IDD_KEYS)
                            - self.idd_table.shape[-1]))
            self.idd_table = jnp.concatenate([self.idd_table, pad], axis=-1)

    # ------------------------------------------------------- construction
    @classmethod
    def from_datasheets(cls, datasheets: dict[int, dict[str, float]]):
        return cls(datasheets={v: dict(d) for v, d in datasheets.items()})

    @classmethod
    def from_vampire(cls, model):
        """Share the fitted VAMPIRE model's derived per-vendor datasheets
        (what the vendor would publish; paper Section 4)."""
        return cls.from_datasheets(
            {v: model.by_vendor[v].idd_datasheet for v in model.by_vendor})

    @property
    def vendors(self) -> tuple[int, ...]:
        return tuple(sorted(self.datasheets))

    def _table_for(self, idx: tuple[int, ...]) -> jax.Array:
        if idx == tuple(range(self.idd_table.shape[0])):
            return self.idd_table
        return self._memo_subset(
            idx, self.idd_table,
            lambda: self.idd_table[jnp.asarray(idx, jnp.int32)])

    # ----------------------------------------------------------- estimate
    def estimate(self, traces, vendors=None, *,
                 mode: model_api.EstimateMode = "mean",
                 impl: str = "vectorized", data=None,
                 ones_frac=None, toggle_frac=None):
        """Unified protocol entry point.  ``mode='distribution'`` equals
        ``'mean'`` (no data dependency to feed the fractions into) and
        ``mode='range'`` collapses to (mean, mean, mean) — these baselines
        model neither, which is Section 9.1's finding.  ``mode='surface'``
        returns the (traces, vendors, banks, row_bands) decomposition of
        the same charges: structurally flat (the physics has no
        per-bank/row terms), varying only with workload placement — the
        contrast against VAMPIRE's surfaces.  ``impl`` resolves
        through the shared registry: ``'vectorized'`` (one vmapped
        dispatch), ``'pallas'`` (the fused baseline-energy kernel gridded
        over vendors), ``'reference'`` (the pair-at-a-time per-trace
        functions ``micron_power``/``drampower``)."""
        # one shared argument contract across every estimator: fractions
        # (typed DataProfile or the loose kwargs) are required WITH
        # mode='distribution' (even though this physics ignores their
        # values) and rejected without it
        profile = model_api.normalize_data_profile(data, ones_frac,
                                                   toggle_frac)
        model_api.validate_data_profile(mode, profile)
        impl = model_api.resolve_impl(impl, mode=mode).name
        model_api.require_impl_path(self.kind, impl,
                                    ("vectorized", "pallas", "reference"))
        _, idx = model_api.resolve_vendor_indices(self.vendors, vendors)
        tb = self._batch_cache.get(traces)
        if mode == "surface":
            if impl == "vectorized":
                return _BATCHED_SURFACE[self.kind](tb.trace, tb.weight,
                                                   self._table_for(idx))
            if impl == "pallas":
                from repro.kernels.baseline_energy import ops as bops
                charge, cycles = bops.baseline_charge_matrix(
                    tb.trace, tb.weight, self._table_for(idx), self.kind,
                    surface=True)
                return _report(charge, jnp.broadcast_to(cycles[:, None],
                                                        charge.shape))
            return self._reference_surface(traces, tb, idx)
        if impl == "vectorized":
            rep = _BATCHED[self.kind](tb.trace, tb.weight,
                                      self._table_for(idx))
        elif impl == "pallas":
            from repro.kernels.baseline_energy import ops as bops
            charge, cycles = bops.baseline_charge_matrix(
                tb.trace, tb.weight, self._table_for(idx), self.kind)
            rep = _report(charge,
                          jnp.broadcast_to(cycles[:, None], charge.shape))
        else:
            rep = self._reference_matrix(traces, tb, idx)
        if mode == "range":
            return rep, rep, rep
        return rep

    def _reference_surface(self, traces, tb, idx) -> EnergyReport:
        """``impl='reference'`` for ``mode='surface'``: the paper-figure
        per-trace charge formulas, grouped onto the (bank, row-band) cells
        one (trace, vendor) pair at a time."""
        from repro.core.estimate_batch import original_traces
        originals = original_traces(traces, tb)
        order = self.vendors
        charge_fn = _CHARGE_FNS[self.kind]
        per_trace = []
        for tr in originals:
            ob, pd = _bg_state(extract_structural_features(tr))
            w = jnp.ones(tr.n, jnp.float32)
            pairs = []
            for j in idx:
                ds = {k: jnp.float32(self.datasheets[order[j]][k])
                      for k in BASELINE_IDD_KEYS}
                pairs.append(_report(
                    surface_charge(tr, w, charge_fn(tr, ob, pd, ds)),
                    surface_cycles(tr, w)))
            per_trace.append(jax.tree_util.tree_map(
                lambda *leaves: jnp.stack(leaves), *pairs))
        return jax.tree_util.tree_map(lambda *rows: jnp.stack(rows),
                                      *per_trace)

    def _reference_matrix(self, traces, tb, idx) -> EnergyReport:
        """``impl='reference'``: the paper-figure per-trace functions
        (``micron_power``/``drampower``), one call per (trace, vendor)."""
        from repro.core.estimate_batch import original_traces
        originals = original_traces(traces, tb)
        order = self.vendors
        fn = MODELS[self.kind]
        per_trace = [
            jax.tree_util.tree_map(
                lambda *leaves: jnp.stack(leaves),
                *[fn(tr, self.datasheets[order[j]]) for j in idx])
            for tr in originals]
        return jax.tree_util.tree_map(lambda *rows: jnp.stack(rows),
                                      *per_trace)

    # ----------------------------------------------------------------- io
    def save(self, path: str, *, meta: dict | None = None):
        model_api.save_estimator(self, path, meta=meta)

    @classmethod
    def load(cls, path: str):
        model = model_api.load_estimator(path)
        if not isinstance(model, cls):
            raise TypeError(f"{path} holds a {type(model).__name__}, "
                            f"not a {cls.__name__}")
        return model


@dataclasses.dataclass
class MicronModel(DatasheetModel):
    kind = "micron"


@dataclasses.dataclass
class DRAMPowerModel(DatasheetModel):
    kind = "drampower"


def _baseline_flatten(m):
    return (m.idd_table,), (m._aux_static(m.datasheets),)


def _make_baseline_unflatten(cls):
    def unflatten(aux, children):
        m = object.__new__(cls)
        m.datasheets = aux[0].value
        m.idd_table = children[0]
        m.__dict__["_aux"] = aux[0]   # stable treedefs across round trips
        return m
    return unflatten


for _cls in (MicronModel, DRAMPowerModel):
    jax.tree_util.register_pytree_node(_cls, _baseline_flatten,
                                       _make_baseline_unflatten(_cls))

BASELINE_MODELS = {"micron": MicronModel, "drampower": DRAMPowerModel}
