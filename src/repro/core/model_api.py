"""The unified estimator protocol and versioned model serialization.

Every power model in this repo — the fitted VAMPIRE model and the
datasheet-driven baselines (Micron calculator, DRAMPower) — implements ONE
entry point:

    model.estimate(traces, vendors=None, *, mode='mean'|'range'|'distribution',
                   impl='vectorized', data=DataProfile(...) | None,
                   ones_frac=None, toggle_frac=None)

* ``traces`` is a single :class:`~repro.core.dram.CommandTrace`, a sequence
  of (ragged) traces, or a prebuilt :class:`~repro.core.estimate_batch.TraceBatch`;
* ``vendors`` defaults to every vendor the model covers;
* every leaf of the returned :class:`~repro.core.energy_model.EnergyReport`
  has shape ``(traces, vendors)`` — ``mode='range'`` returns a
  ``(lo, mean, hi)`` triple of such reports;
* ``mode='distribution'`` is the paper's no-data-trace mode and takes a
  :class:`DataProfile` (``data=``) — or the legacy loose
  ``ones_frac``/``toggle_frac`` kwargs (scalar or per trace), normalized
  through :func:`normalize_data_profile`;
* ``mode='surface'`` is the structural-variation decomposition (paper
  Section 6 / Figs 19-22): leaves are ``(traces, vendors, banks,
  row_bands)``-shaped, each command's charge grouped onto its
  (bank, row-band) cell; summing over the cell axes recovers ``'mean'``;
* ``impl`` picks HOW the matrix is evaluated, through the impl registry
  (:func:`register_impl` / :func:`resolve_impl`): ``'vectorized'`` (the
  jnp/XLA batched engine), ``'pallas'`` (the fused Pallas kernel family —
  compiled on TPU, interpret-mode fallback elsewhere), or ``'reference'``
  (the pair-at-a-time per-command oracle; ``'scan'`` is a legacy alias).
  Every estimator kind supports every registered impl for every mode, and
  the parity suite holds them allclose to each other.

Models are pytrees: their parameters are array leaves stacked along a
leading vendor axis, so a model can be ``jax.jit``-traced, ``jax.vmap``-ped,
``jax.device_put`` onto a mesh, and scored through the shared batched
engine (``repro.core.estimate_batch``) regardless of which physics it
implements.  ``validate.run_validation``, the encoding study, and
``launch/serve.py --power-report`` all consume the protocol, never a
concrete class.

Fitting (the ``Fitter`` registry)
---------------------------------
HOW a model's parameters are obtained goes through the same
registry-template as impls: :func:`register_fitter` /
:func:`resolve_fitter` over :class:`FitterSpec` entries, dispatched by the
unified :func:`fit` entry point.  Two fitters ship: ``'campaign'`` (the
one-shot offline characterization campaign — ``repro.core.characterize``,
behavior-identical to the legacy ``Vampire.fit``) and ``'streaming'`` (the
incremental decayed-sufficient-statistics fitter in
``repro.core.recalibrate``, which consumes telemetry ticks and emits
treedef-stable model refreshes for ``ServingEngine.update_model``).
``Vampire.fit`` remains as a thin, warning-free shim onto
``fit('vampire', fleet, fitter='campaign', ...)``.

Serialization (schema v2)
-------------------------
:func:`save_estimator` writes a single file: a ``.npz`` archive whose
entries are plain (pickle-free) numpy arrays plus a ``__manifest__`` JSON
string recording the schema version, the estimator kind, the vendor/IDD-key
ordering of the arrays, and optional caller metadata.  :func:`load_estimator`
sniffs the on-disk format and also accepts the legacy schema-v1 pickle
blobs (``Vampire.save`` before the unified API) with a
``DeprecationWarning`` — re-save to migrate.
"""
from __future__ import annotations

import dataclasses
import json
import pickle
import warnings
import zipfile
from typing import Literal, Protocol, Sequence, runtime_checkable

import numpy as np

SCHEMA_VERSION = 2
MANIFEST_KEY = "__manifest__"

EstimateMode = Literal["mean", "range", "distribution", "surface"]


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------
@runtime_checkable
class Estimator(Protocol):
    """What every power model exposes (see the module docstring).

    Portable protocol code passes ``vendors`` as a sequence or ``None``.
    A bare int vendor together with a single ``CommandTrace`` is reserved
    for ``Vampire``'s legacy ``estimate(trace, vendor)`` shim (scalar-leaf
    report + ``DeprecationWarning``); estimators without a legacy API
    treat an int vendor as a one-element sequence."""

    kind: str                        # 'vampire' | 'micron' | 'drampower'

    @property
    def vendors(self) -> tuple[int, ...]:
        """Vendor ids the model covers, in the stacked-leaf order."""
        ...

    def estimate(self, traces, vendors=None, *, mode: EstimateMode = "mean",
                 impl: str = "vectorized", data: "DataProfile | None" = None,
                 ones_frac=None, toggle_frac=None):
        ...

    def save(self, path: str) -> None:
        ...


class _Static:
    """Hashable identity wrapper for non-array pytree aux data (the
    characterization detail a model carries alongside its array leaves).
    Hash/eq are by identity: two flattenings of the SAME model share a
    treedef (so jit caches hit), distinct models never collide."""

    __slots__ = ("value",)

    def __init__(self, value):
        self.value = value

    def __hash__(self):
        return id(self.value)

    def __eq__(self, other):
        return isinstance(other, _Static) and self.value is other.value


def _tracer_type():
    """The JAX tracer class, resolved defensively: ``jax.core.Tracer`` has
    moved between jax releases, and this module must import (and the
    deprecation-clean CI job must pass) on whichever jax the environment
    provides.  Returns ``None`` when no tracer class can be found — callers
    then skip caching entirely (fail safe: never cache a possible tracer)."""
    import jax
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for resolve in (lambda: jax.core.Tracer,
                        lambda: jax.extend.core.Tracer):
            try:
                return resolve()
            except AttributeError:
                continue
    return None


# ---------------------------------------------------------------------------
# Impl registry: HOW an estimate() is evaluated, orthogonal to the estimator
# kind (WHICH physics).  Registered like estimator kinds; every estimator's
# estimate() resolves its ``impl=`` argument here, so all three estimators
# and all three modes dispatch through one registry.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EstimateImpl:
    """One way of evaluating the (traces, vendors) report matrix."""
    name: str
    description: str
    modes: tuple[str, ...] = ("mean", "range", "distribution", "surface")
    aliases: tuple[str, ...] = ()


_IMPLS: dict[str, EstimateImpl] = {}
_IMPL_ALIASES: dict[str, str] = {}


def register_impl(impl: EstimateImpl) -> EstimateImpl:
    """Register an impl (or re-register to override). Returns it, so the
    definition can double as a module-level constant."""
    _IMPLS[impl.name] = impl
    for alias in impl.aliases:
        _IMPL_ALIASES[alias] = impl.name
    return impl


def registered_impls() -> tuple[str, ...]:
    return tuple(sorted(_IMPLS))


def resolve_impl(name: str, *, mode: str | None = None) -> EstimateImpl:
    """Resolve an ``impl=`` argument (canonical name or alias) against the
    registry, with the capability check against the requested mode."""
    impl = _IMPLS.get(_IMPL_ALIASES.get(name, name))
    if impl is None:
        raise ValueError(f"unknown impl {name!r}; registered impls: "
                         f"{list(registered_impls())}")
    if mode is not None and mode not in impl.modes:
        raise ValueError(f"impl {impl.name!r} does not support mode "
                         f"{mode!r} (supports {list(impl.modes)})")
    return impl


def impl_execution_mode(name: str) -> str:
    """``'compiled'`` or ``'interpret'`` — how the impl would execute on
    the current backend.  The ``pallas`` impl compiles on TPU and falls
    back to Pallas interpret mode everywhere else (so it is runnable,
    parity-checkable, but exempt from speed expectations off-TPU)."""
    impl = resolve_impl(name)
    if impl.name != "pallas":
        return "compiled"
    from repro.kernels.common import interpret_default
    return "interpret" if interpret_default() else "compiled"


def require_impl_path(kind: str, impl: str,
                      supported: tuple[str, ...]) -> None:
    """Loud guard at the tail of an estimator's name-keyed dispatch: the
    registry stores no evaluation callable, so an impl that is registered
    but that this estimator has no branch for must error, never silently
    fall through to another path."""
    if impl not in supported:
        raise ValueError(
            f"estimator kind {kind!r} has no evaluation path for impl "
            f"{impl!r} (it implements {list(supported)}); registering an "
            f"impl does not give existing estimators a dispatch for it")


VECTORIZED_IMPL = register_impl(EstimateImpl(
    "vectorized",
    "fused-elementwise jnp over the (traces, vendors) grid, one jitted "
    "vmap(vmap) dispatch (the XLA production path)",
    modes=("mean", "range", "distribution", "surface")))
PALLAS_IMPL = register_impl(EstimateImpl(
    "pallas",
    "fused Pallas kernel family: one param-independent popcount/toggle "
    "feature kernel per batch + a per-vendor current/energy kernel gridded "
    "over (vendors, traces, blocks); compiled on TPU, interpret elsewhere",
    modes=("mean", "range", "distribution", "surface")))
REFERENCE_IMPL = register_impl(EstimateImpl(
    "reference",
    "pair-at-a-time per-command oracle (lax.scan state machine for "
    "measured-data modes), kept for cross-checking",
    modes=("mean", "range", "distribution", "surface"),
    aliases=("scan",)))


@dataclasses.dataclass(frozen=True)
class DataProfile:
    """Typed description of a trace set's data dependence: the fraction of
    ones on the bus and the fraction of toggling bit lanes (scalar, or one
    value per trace).  This is the single object the estimate protocol, the
    serving config, and the telemetry/recalibration path log and fit
    against — the loose ``ones_frac=``/``toggle_frac=`` kwargs remain
    accepted everywhere and are mapped onto a profile through
    :func:`normalize_data_profile`."""
    ones_frac: object = None
    toggle_frac: object = None

    @property
    def empty(self) -> bool:
        return self.ones_frac is None and self.toggle_frac is None


def normalize_data_profile(data: "DataProfile | None" = None,
                           ones_frac=None,
                           toggle_frac=None) -> DataProfile:
    """The one normalization helper between the typed ``data=`` argument
    and the legacy loose kwargs.  Exactly one spelling may be used per
    call; the result is always a :class:`DataProfile`."""
    if data is not None:
        if not isinstance(data, DataProfile):
            raise TypeError(f"data= must be a DataProfile, got "
                            f"{type(data).__name__}")
        if ones_frac is not None or toggle_frac is not None:
            raise ValueError("pass data=DataProfile(...) OR the loose "
                             "ones_frac=/toggle_frac= kwargs, not both")
        return data
    return DataProfile(ones_frac=ones_frac, toggle_frac=toggle_frac)


def validate_estimate_args(mode: str, ones_frac, toggle_frac) -> None:
    """The one argument contract every estimator's ``estimate`` enforces
    (shared so the implementations cannot drift): fractions are required
    with ``mode='distribution'`` and rejected with any other mode."""
    if mode not in ("mean", "range", "distribution", "surface"):
        raise ValueError(f"unknown mode {mode!r}")
    if mode == "distribution":
        if ones_frac is None or toggle_frac is None:
            raise ValueError("mode='distribution' requires ones_frac "
                             "and toggle_frac")
    elif ones_frac is not None or toggle_frac is not None:
        raise ValueError("ones_frac/toggle_frac are only meaningful "
                         "with mode='distribution'")


def validate_data_profile(mode: str, profile: DataProfile) -> None:
    """:func:`validate_estimate_args` over a normalized profile."""
    validate_estimate_args(mode, profile.ones_frac, profile.toggle_frac)


# ---------------------------------------------------------------------------
# Fitter registry: HOW a model's parameters are obtained, registered with
# the same template as estimator kinds and impls.  The registry stores no
# fit callable (mirroring the impl registry); the unified :func:`fit` entry
# point owns the name-keyed dispatch and errors loudly on a registered
# fitter it has no branch for.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FitterSpec:
    """One way of producing fitted model parameters.

    ``streaming=False`` fitters are one-shot: ``fit()`` returns a fitted
    estimator.  ``streaming=True`` fitters are incremental: ``fit()``
    returns a stateful fitter object that consumes telemetry ticks
    (``observe``) and emits treedef-stable model refreshes (``refit``)."""
    name: str
    description: str
    streaming: bool
    aliases: tuple[str, ...] = ()


_FITTERS: dict[str, FitterSpec] = {}
_FITTER_ALIASES: dict[str, str] = {}


def register_fitter(spec: FitterSpec) -> FitterSpec:
    """Register a fitter (or re-register to override). Returns it, so the
    definition can double as a module-level constant."""
    _FITTERS[spec.name] = spec
    for alias in spec.aliases:
        _FITTER_ALIASES[alias] = spec.name
    return spec


def registered_fitters() -> tuple[str, ...]:
    return tuple(sorted(_FITTERS))


def resolve_fitter(name: str, *,
                   streaming: bool | None = None) -> FitterSpec:
    """Resolve a ``fitter=`` argument (canonical name or alias) against the
    registry, with the capability check against the requested execution
    style (``streaming=True`` demands an incremental fitter)."""
    spec = _FITTERS.get(_FITTER_ALIASES.get(name, name))
    if spec is None:
        raise ValueError(f"unknown fitter {name!r}; registered fitters: "
                         f"{list(registered_fitters())}")
    if streaming is not None and streaming != spec.streaming:
        style = "streaming" if spec.streaming else "one-shot"
        want = "streaming" if streaming else "one-shot"
        raise ValueError(f"fitter {spec.name!r} is {style}, not {want}")
    return spec


CAMPAIGN_FITTER = register_fitter(FitterSpec(
    "campaign",
    "one-shot offline characterization campaign (repro.core.characterize): "
    "measure every probe cell on the rig, invert the slot accounting once; "
    "behavior-identical to the legacy Vampire.fit path",
    streaming=False,
    aliases=("offline",)))
STREAMING_FITTER = register_fitter(FitterSpec(
    "streaming",
    "incremental fitter (repro.core.recalibrate): decayed per-probe-cell "
    "sufficient statistics updated from telemetry ticks, re-inverted into "
    "treedef-stable FleetModel refreshes for ServingEngine.update_model",
    streaming=True,
    aliases=("online",)))


def fit(kind: str = "vampire", fleet=None, *, fitter: str = "campaign",
        **kw):
    """The unified fit entry point (see the module docstring).

    ``fitter='campaign'`` runs the offline campaign and returns a fitted
    estimator of ``kind`` (extra kwargs go to
    ``characterize.characterize_fleet``; bit-for-bit the legacy
    ``Vampire.fit`` result).  ``fitter='streaming'`` returns a
    :class:`repro.core.recalibrate.StreamingFitter` primed on an initial
    model (``init_model=``, or a fresh campaign fit when omitted)."""
    spec = resolve_fitter(fitter)
    if spec.name == "campaign":
        from repro.core import characterize
        from repro.core.vampire import Vampire
        model = Vampire(by_vendor=characterize.characterize_fleet(fleet,
                                                                  **kw))
        model.fleet  # stack the per-vendor params ONCE, at fit time
        return model if kind == "vampire" else make_estimator(kind, model)
    if spec.name == "streaming":
        if kind != "vampire":
            raise ValueError("fitter='streaming' recalibrates the fitted "
                             "VAMPIRE model; derive baselines from it via "
                             "make_estimator")
        from repro.core import recalibrate
        return recalibrate.streaming_fitter(fleet, **kw)
    raise ValueError(
        f"fitter {spec.name!r} is registered but fit() has no dispatch "
        f"branch for it; registering a fitter does not give fit() an "
        f"execution path")


def resolve_vendor_indices(order: Sequence[int],
                           vendors) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Normalize a ``vendors`` argument against a model's stacked vendor
    order -> (vendor ids, row indices into the stacked leaves)."""
    order = list(order)
    if vendors is None:
        vs = tuple(order)
    elif isinstance(vendors, (int, np.integer)):
        vs = (int(vendors),)
    else:
        vs = tuple(int(v) for v in vendors)
    try:
        idx = tuple(order.index(v) for v in vs)
    except ValueError:
        missing = [v for v in vs if v not in order]
        raise KeyError(f"vendor(s) {missing} not fitted; model covers "
                       f"{order}") from None
    return vs, idx


# ---------------------------------------------------------------------------
# Trace-batch padding cache (shared by every estimator implementation)
# ---------------------------------------------------------------------------
class TraceBatchCache:
    """Remembers the padded :class:`TraceBatch` of the last few trace sets
    scored through a model, keyed by trace identity, so repeated
    ``estimate`` calls over the same (sequence of) trace objects stop
    re-padding per call.  Entries hold strong references to the traces, so
    an id can never be recycled while its entry is alive."""

    def __init__(self, maxsize: int = 4):
        self.maxsize = maxsize
        self._entries: list[tuple[tuple, object]] = []

    def get(self, traces):
        from repro.core.dram import CommandTrace
        from repro.core.estimate_batch import TraceBatch, as_trace_batch
        if isinstance(traces, TraceBatch):
            return traces
        key = ((traces,) if isinstance(traces, CommandTrace)
               else tuple(traces))
        for held, tb in self._entries:
            if len(held) == len(key) and all(a is b
                                             for a, b in zip(held, key)):
                return tb
        tb = as_trace_batch(list(key))
        self._entries.append((key, tb))
        del self._entries[:-self.maxsize]
        return tb


class StackedEstimatorMixin:
    """The per-model caches every stacked estimator shares:

    * ``_batch_cache`` — the :class:`TraceBatchCache` padding memo;
    * ``_memo_subset`` — memoizes vendor-subset slices of the stacked
      leaves per vendor-index tuple, EXCEPT while the stacked leaves are
      being traced (a cached tracer would escape its trace).

    Lives in ``__dict__`` (not dataclass fields) so pytree-unflattened
    instances — which skip ``__init__`` — lazily grow fresh caches."""

    @property
    def _batch_cache(self) -> TraceBatchCache:
        return self.__dict__.setdefault("_batches", TraceBatchCache())

    def _memo_subset(self, idx: tuple[int, ...], stacked, build):
        import jax
        cache = self.__dict__.setdefault("_subsets", {})
        hit = cache.get(idx)
        if hit is None:
            hit = build()
            tracer = _tracer_type()
            if tracer is not None and not any(
                    isinstance(leaf, tracer)
                    for leaf in jax.tree_util.tree_leaves(stacked)):
                cache[idx] = hit
        return hit

    def _aux_static(self, value) -> _Static:
        """The pytree aux wrapper, built ONCE per instance: repeated
        flattens of the same model must yield equal treedefs (identity-
        hashed aux), or every jit over the model retraces per call."""
        aux = self.__dict__.get("_aux")
        if aux is None:
            aux = _Static(value)
            self.__dict__["_aux"] = aux
        return aux


def device_resident(model, mesh=None, *, axis: str | None = None):
    """``jax.device_put`` a pytree model once, so repeat dispatches stop
    re-transferring parameters per call.

    With a mesh and no ``axis``, the model lands replicated across every
    mesh device (``NamedSharding(mesh, PartitionSpec())``) — exactly what
    a ``shard_map`` over the trace axis wants for its parameter operand.
    With ``axis`` (e.g. ``'model'``), every leaf's LEADING dimension is
    sharded over that mesh axis instead
    (``NamedSharding(mesh, PartitionSpec(axis))``) — the stacked-fleet
    layout, where the module axis lives distributed and each shard holds
    only its modules' params.  Without a mesh, it lands on the default
    device.  Either way the treedef is preserved (``device_put`` copies
    leaves, not aux data, and the aux wrapper hashes by identity), so jit
    caches keyed on the resident model keep hitting across calls."""
    import jax
    if mesh is None:
        return jax.device_put(model)
    spec = (jax.sharding.PartitionSpec() if axis is None
            else jax.sharding.PartitionSpec(axis))
    sharding = jax.sharding.NamedSharding(mesh, spec)
    return jax.device_put(model, sharding)


# ---------------------------------------------------------------------------
# Versioned serialization
# ---------------------------------------------------------------------------
def save_estimator(model, path: str, *, meta: dict | None = None) -> None:
    """Write any estimator as a schema-v2 ``.npz`` + JSON-manifest file.

    ``meta`` is caller metadata stored verbatim in the manifest (e.g. the
    benchmark cache's fit-configuration tag)."""
    kind = getattr(model, "kind", None)
    if kind == "vampire":
        arrays, manifest = _vampire_payload(model)
    elif kind in ("micron", "drampower"):
        arrays, manifest = _baseline_payload(model)
    else:
        raise TypeError(f"cannot serialize estimator kind {kind!r}")
    manifest["schema"] = SCHEMA_VERSION
    manifest["kind"] = kind
    if meta is not None:
        manifest["meta"] = meta
    payload = {MANIFEST_KEY: np.array(json.dumps(manifest))}
    payload.update(arrays)
    with open(path, "wb") as f:
        np.savez(f, **payload)


def read_manifest(path: str) -> dict | None:
    """The v2 manifest of a saved estimator, or ``None`` for v1 pickles."""
    if not zipfile.is_zipfile(path):
        return None
    with np.load(path, allow_pickle=False) as npz:
        return json.loads(npz[MANIFEST_KEY].item())


def load_estimator(path: str):
    """Load any saved estimator, from schema v2 (``.npz`` + manifest) or a
    legacy schema-v1 pickle blob (with a :class:`DeprecationWarning`)."""
    if not zipfile.is_zipfile(path):
        return _load_v1_pickle(path)
    with np.load(path, allow_pickle=False) as npz:
        manifest = json.loads(npz[MANIFEST_KEY].item())
        schema = manifest.get("schema")
        if schema != SCHEMA_VERSION:
            raise ValueError(f"unsupported model schema {schema!r} in {path}")
        kind = manifest.get("kind")
        if kind == "vampire":
            return _vampire_from_payload(npz, manifest)
        if kind in ("micron", "drampower"):
            return _baseline_from_payload(npz, manifest)
        raise ValueError(f"unknown estimator kind {kind!r} in {path}")


# ---- VAMPIRE payload ------------------------------------------------------
_FITTED_FIELDS = ("datadep", "datadep_r2", "i2n", "bank_open_delta",
                  "bank_read_factor", "bank_write_factor", "q_actpre",
                  "row_ones_slope", "q_ref", "i_pd", "act_surface",
                  "i_pd_slow", "i_actpd", "i_sr")
# low-power LUT scalars absent on blobs written before the background-state
# lattice; they default to the blob's fast power-down current on load
_LOWPOWER_FIELDS = ("i_pd_slow", "i_actpd", "i_sr")
_SWEEP_FIELDS = ("ones", "toggles", "current", "corrected")


def _vendor_field(vc, field: str):
    """One fitted quantity of a vendor record.  ``act_surface`` may be
    absent on records unpickled from pre-surface blobs — serialize the
    documented neutral (all-ones) surface for those.  The low-power LUT
    scalars may likewise be absent (pre-lattice blobs) — serialize their
    documented fallback, the fast power-down current."""
    value = getattr(vc, field, None)
    if value is None and field == "act_surface":
        from repro.core.dram import N_BANKS, N_ROW_BANDS
        return np.ones((N_BANKS, N_ROW_BANDS))
    if value is None and field in _LOWPOWER_FIELDS:
        return np.float64(vc.i_pd)
    return value


def _vampire_payload(model) -> tuple[dict, dict]:
    vs = sorted(model.by_vendor)
    arrays: dict[str, np.ndarray] = {
        "vendor_ids": np.asarray(vs, np.int64),
        "band": np.asarray([model.variation_band[v] for v in vs], np.float64),
    }
    for field in _FITTED_FIELDS:
        arrays[field] = np.stack(
            [np.asarray(_vendor_field(model.by_vendor[v], field), np.float64)
             for v in vs])
    idd_keys = sorted(model.by_vendor[vs[0]].idd_datasheet)
    arrays["idd_datasheet"] = np.asarray(
        [[model.by_vendor[v].idd_datasheet[k] for k in idd_keys] for v in vs],
        np.float64)
    manifest: dict = {"vendors": vs, "idd_keys": idd_keys,
                      "idd_r2": {}, "row_r2": {}, "raw": False}
    # raw campaign data (present on freshly fitted models; benchmarks plot
    # the sweeps, so the bench fit cache must round-trip them)
    for v in vs:
        vc = model.by_vendor[v]
        manifest["idd_r2"][str(v)] = dict(vc.idd_extrapolation_r2)
        if vc.row_sweep:
            manifest["row_r2"][str(v)] = float(vc.row_sweep.get("r2", 0.0))
        if not (vc.idd_measured or vc.ones_sweep or vc.row_sweep):
            continue
        manifest["raw"] = True
        for key, arr in vc.idd_measured.items():
            arrays[f"raw/{v}/idd_measured/{key}"] = np.asarray(arr, np.float64)
        for (mode, op), sweep in vc.ones_sweep.items():
            for field in _SWEEP_FIELDS:
                arrays[f"raw/{v}/ones_sweep/{mode}/{op}/{field}"] = \
                    np.asarray(sweep[field], np.float64)
        for field in ("row_ones", "current"):
            if vc.row_sweep:
                arrays[f"raw/{v}/row_sweep/{field}"] = \
                    np.asarray(vc.row_sweep[field], np.float64)
    return arrays, manifest


def _rebuild_vendor(vendor: int, fitted: dict, *, idd_measured=None,
                    idd_r2=None, datadep_r2=None, ones_sweep=None,
                    row_sweep=None):
    """Reconstruct one fitted ``VendorCharacterization`` from plain values
    (the single shared reconstruction used by both the v2 and the legacy
    v1 loaders; raw campaign records are optional).  ``act_surface`` is
    optional in ``fitted`` — blobs written before the structural-variation
    surface existed load with the neutral all-ones surface."""
    from repro.core import characterize
    surface = fitted.get("act_surface")
    vc = characterize.VendorCharacterization(
        vendor=vendor,
        act_surface=(np.asarray(surface) if surface is not None else None),
        idd_measured=idd_measured or {},
        idd_datasheet=dict(fitted["idd_datasheet"]),
        idd_extrapolation_r2=idd_r2 or {},
        datadep=np.asarray(fitted["datadep"]),
        datadep_r2=(np.asarray(datadep_r2) if datadep_r2 is not None
                    else np.zeros((4, 2))),
        ones_sweep=ones_sweep or {},
        i2n=float(fitted["i2n"]),
        bank_open_delta=np.asarray(fitted["bank_open_delta"]),
        bank_read_factor=np.asarray(fitted["bank_read_factor"]),
        bank_write_factor=np.asarray(fitted["bank_write_factor"]),
        q_actpre=float(fitted["q_actpre"]),
        row_ones_slope=float(fitted["row_ones_slope"]),
        row_sweep=row_sweep or {},
        q_ref=float(fitted["q_ref"]),
        i_pd=float(fitted["i_pd"]),
        i_pd_slow=(float(fitted["i_pd_slow"])
                   if fitted.get("i_pd_slow") is not None else None),
        i_actpd=(float(fitted["i_actpd"])
                 if fitted.get("i_actpd") is not None else None),
        i_sr=(float(fitted["i_sr"])
              if fitted.get("i_sr") is not None else None))
    vc.build_params()
    return vc


def _vampire_from_payload(npz, manifest):
    from repro.core.vampire import Vampire
    vs = [int(v) for v in np.asarray(npz["vendor_ids"])]
    idd_keys = list(manifest["idd_keys"])
    by_vendor, bands = {}, {}
    for i, v in enumerate(vs):
        raw_idd, raw_sweep, raw_row = {}, {}, {}
        if manifest.get("raw"):
            prefix = f"raw/{v}/"
            for name in npz.files:
                if not name.startswith(prefix):
                    continue
                parts = name[len(prefix):].split("/")
                if parts[0] == "idd_measured":
                    raw_idd[parts[1]] = np.asarray(npz[name])
                elif parts[0] == "ones_sweep":
                    mode, op, field = parts[1], parts[2], parts[3]
                    raw_sweep.setdefault((mode, op), {})[field] = \
                        np.asarray(npz[name])
                elif parts[0] == "row_sweep":
                    raw_row[parts[1]] = np.asarray(npz[name])
            if raw_row:
                raw_row["r2"] = manifest.get("row_r2", {}).get(str(v), 0.0)
        fitted = {field: npz[field][i] for field in _FITTED_FIELDS
                  if field != "datadep_r2" and field in npz.files}
        fitted["idd_datasheet"] = {k: float(npz["idd_datasheet"][i, j])
                                   for j, k in enumerate(idd_keys)}
        by_vendor[v] = _rebuild_vendor(
            v, fitted,
            idd_measured=raw_idd,
            idd_r2={k: float(r) for k, r in
                    manifest.get("idd_r2", {}).get(str(v), {}).items()},
            datadep_r2=npz["datadep_r2"][i],
            ones_sweep=raw_sweep, row_sweep=raw_row)
        bands[v] = (float(npz["band"][i, 0]), float(npz["band"][i, 1]))
    return Vampire(by_vendor=by_vendor, variation_band=bands)


# ---- baseline payload -----------------------------------------------------
def _baseline_payload(model) -> tuple[dict, dict]:
    vs = list(model.vendors)
    idd_keys = sorted(model.datasheets[vs[0]])
    arrays = {
        "vendor_ids": np.asarray(vs, np.int64),
        "idd_table": np.asarray(
            [[model.datasheets[v][k] for k in idd_keys] for v in vs],
            np.float64),
    }
    return arrays, {"vendors": vs, "idd_keys": idd_keys}


def _baseline_from_payload(npz, manifest):
    from repro.core.baselines_power import BASELINE_MODELS
    cls = BASELINE_MODELS[manifest["kind"]]
    vs = [int(v) for v in np.asarray(npz["vendor_ids"])]
    idd_keys = list(manifest["idd_keys"])
    table = np.asarray(npz["idd_table"], np.float64)
    return cls.from_datasheets(
        {v: {k: float(table[i, j]) for j, k in enumerate(idd_keys)}
         for i, v in enumerate(vs)})


# ---- legacy v1 pickle -----------------------------------------------------
def _load_v1_pickle(path: str):
    """Load a schema-v1 pickle: either a ``Vampire.save`` blob (dict keyed
    by vendor id) or the old benchmark fit cache (``{"tag", "model"}``)."""
    from repro.core.vampire import Vampire
    with open(path, "rb") as f:
        blob = pickle.load(f)
    warnings.warn(
        f"{path} is a schema-v1 pickle model blob; loading via the legacy "
        "migration path. Re-save it with model.save() to get the v2 "
        ".npz + manifest format.", DeprecationWarning, stacklevel=2)
    if isinstance(blob, dict) and isinstance(blob.get("model"), Vampire):
        return blob["model"]     # old benchmarks/common.py fit cache
    if not (isinstance(blob, dict)
            and all(isinstance(v, (int, np.integer)) for v in blob)):
        raise ValueError(f"unrecognized v1 model blob in {path}")
    by_vendor = {v: _rebuild_vendor(v, d) for v, d in blob.items()}
    bands = {v: tuple(d["band"]) for v, d in blob.items()}
    return Vampire(by_vendor=by_vendor, variation_band=bands)


def _save_v1_pickle(model, path: str) -> None:
    """Write the legacy schema-v1 pickle blob.  Kept ONLY to generate
    migration-test fixtures; production code saves v2."""
    blob = {v: {"datadep": np.asarray(vc.datadep),
                "i2n": vc.i2n,
                "bank_open_delta": np.asarray(vc.bank_open_delta),
                "bank_read_factor": np.asarray(vc.bank_read_factor),
                "bank_write_factor": np.asarray(vc.bank_write_factor),
                "q_actpre": vc.q_actpre,
                "row_ones_slope": vc.row_ones_slope,
                "q_ref": vc.q_ref, "i_pd": vc.i_pd,
                "idd_datasheet": vc.idd_datasheet,
                "band": model.variation_band[v]}
            for v, vc in model.by_vendor.items()}
    with open(path, "wb") as f:
        pickle.dump(blob, f)


# ---------------------------------------------------------------------------
# Registry (the serving CLI's --power-model flag resolves through this)
# ---------------------------------------------------------------------------
def make_estimator(kind: str, vampire) -> "Estimator":
    """Build the requested estimator kind from a fitted VAMPIRE model (the
    baselines share its derived per-vendor datasheets)."""
    if kind == "vampire":
        return vampire
    from repro.core.baselines_power import BASELINE_MODELS
    if kind in BASELINE_MODELS:
        return BASELINE_MODELS[kind].from_vampire(vampire)
    raise ValueError(f"unknown estimator kind {kind!r}; expected 'vampire', "
                     f"'micron', or 'drampower'")


ESTIMATOR_KINDS = ("vampire", "micron", "drampower")
