"""Checkpoint manager: atomic, keep-K, optionally asynchronous, reshardable.

Layout: ``<dir>/step_<n>/ {manifest.json, arrays.npz}`` written to a temp
directory and atomically renamed (a partially-written checkpoint can never
be restored). Restore takes a target pytree of ShapeDtypeStructs + shardings
and re-shards on load, which is what elastic rescaling uses (train on one
mesh, resume on another).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree, extra: dict | None = None):
        arrays = _flatten_with_paths(tree)
        host_arrays = {k: np.asarray(v) for k, v in arrays.items()}
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_arrays, extra or {}))
            self._thread.start()
        else:
            self._write(step, host_arrays, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_arrays: dict, extra: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **host_arrays)
            manifest = {"step": step, "time": time.time(), "extra": extra,
                        "keys": sorted(host_arrays)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """target_tree: pytree of arrays or ShapeDtypeStructs (the template).
        shardings: matching pytree of NamedSharding (optional -> resharded
        on load; this is the elastic-rescale path)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shard_flat = (treedef.flatten_up_to(shardings)
                      if shardings is not None else [None] * len(leaves_p))
        out = []
        for (pth, template), shd in zip(leaves_p, shard_flat):
            key = jax.tree_util.keystr(pth)
            arr = data[key]
            target = np.dtype(template.dtype)
            if arr.dtype.kind == "V" and arr.dtype.itemsize == \
                    target.itemsize:
                # npz round-trips ml_dtypes (bfloat16, int8 variants...) as
                # raw void records; reinterpret in place
                arr = arr.view(target)
            arr = arr.astype(target)
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_manifest(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f)
