"""Declarative JEDEC-style DRAM protocol linter over command traces.

The paper's measurement methodology rests on precisely-timed command loops:
an IDD loop that violates tFAW, precharges inside tRAS, or drifts past the
tREFI deadline measures the wrong thing (PR 2 and PR 6 each found such bugs
only after they had corrupted energy numbers).  This module turns every
timing/state rule the generators must obey into a registered
:class:`TimingRule` evaluated in one of three interchangeable engines:

* :func:`lint_trace` — single trace, numpy, the construction-time hook the
  repo's generators call through :func:`check_generated`;
* :func:`lint_batch` / :func:`lint_traces` — the whole padded
  :class:`~repro.core.estimate_batch.TraceBatch` linted in ONE jitted
  dispatch (vectorized cumulative-index/segment passes, no per-command
  Python), for serving ingestion and the CI corpus sweep;
* :func:`reference_lint` — an independent per-command Python walk kept as
  the parity oracle (and the benchmark comparator).

All engines return structured :class:`Diagnostic` records (rule id, command
index, bank, severity, deficit in cycles) instead of a bare raise.

Rule semantics
--------------
Command *i* issues at ``t[i] = sum(dt[:i])``; ``dt`` is the cycles the slot
owns, so a dt=0 NOP is exactly invisible (the padding contract).  Every
rule sees only state from commands strictly before *i* ("last event time"
tables built by exclusive cumulative max — valid because ``t`` is
monotone; open/background-state questions use event *indices* so dt=0 ties
resolve by program order).  ``tREFI`` is a deadline on the *scheduler*, not
an interface timing, so it lints as a WARNING with one refresh-pair's worth
of slack (:data:`REFI_SLACK`); traces with no REF at all are vacuously
clean — JEDEC IDD loops measure with refresh suspended.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Callable, Sequence

import numpy as np

from repro.core import dram
from repro.core.dram import (ACT, CMD_NAMES, NOP, N_BANKS, PDE, PDE_SLOW,
                             PDX, PRE, PREA, RD, REF, SRE, SRX, TIMING, WR,
                             CommandTrace, _PDN_ILLEGAL, _SR_LEGAL)

NEG = -(1 << 30)          # "never happened" sentinel time/index
ERROR = "error"
WARNING = "warning"

# Slack on the tREFI deadline: the refresh pair's own slots (tRFC + tRP)
# plus one maximal request slot (the generators refresh after the RD/WR
# that crosses the deadline; app_trace's largest non-low-power slot is
# tBURST + 128 cycles of gap).
REFI_SLACK = TIMING.tRFC + TIMING.tRP + 160


# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One rule violation at one command of one trace."""
    rule: str
    severity: str          # ERROR | WARNING
    trace_index: int
    cmd_index: int
    bank: int
    margin: int            # cycles short of the constraint (>0 = violated)
    message: str

    def __str__(self) -> str:  # pragma: no cover - formatting convenience
        return self.message


def _message(rule_id: str, cmd: int, i: int, b: int, margin: int) -> str:
    name = CMD_NAMES.get(int(cmd), str(int(cmd)))
    tail = f" (short by {margin} cycles)" if margin > 0 else ""
    return (f"{rule_id}: {name} at command #{i} bank {b} violates "
            f"{RULES[rule_id].description}{tail}")


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TimingRule:
    """A declaratively registered protocol rule.

    ``check(ctx) -> (mask, deficit, bank)``: per-command violation mask,
    cycles-short deficit, and the bank each violation charges against —
    computed with backend-agnostic array code (the same formula runs under
    numpy and under jit/vmap).
    """
    rule_id: str
    severity: str
    description: str
    check: Callable


RULES: dict[str, TimingRule] = {}


def rule(rule_id: str, description: str, severity: str = ERROR):
    """Decorator registering a rule's check function."""
    def deco(fn):
        RULES[rule_id] = TimingRule(rule_id, severity, description, fn)
        return fn
    return deco


# ---------------------------------------------------------------------------
# Backend adapters (the only three primitives numpy and jax spell apart)
# ---------------------------------------------------------------------------
class _NumpyBackend:
    name = "numpy"

    @staticmethod
    def xp():
        return np

    @staticmethod
    def exclusive_cummax(x):
        c = np.maximum.accumulate(x, axis=0)
        out = np.empty_like(c)
        out[:1] = NEG
        out[1:] = c[:-1]
        return out

    @staticmethod
    def scatter_times(size: int, slot, times):
        """``arr = full(size, NEG); arr[slot] = times`` with slot
        ``size - 1`` reserved as a guaranteed-NEG dump index."""
        arr = np.full(size, NEG, dtype=np.asarray(times).dtype)
        arr[slot] = times
        arr[size - 1] = NEG
        return arr


class _JaxBackend:
    name = "jax"

    @staticmethod
    def xp():
        import jax.numpy as jnp
        return jnp

    @staticmethod
    def exclusive_cummax(x):
        import jax
        import jax.numpy as jnp
        c = jax.lax.cummax(x, axis=0)
        return jnp.concatenate(
            [jnp.full_like(c[:1], NEG), c[:-1]], axis=0)

    @staticmethod
    def scatter_times(size: int, slot, times):
        import jax.numpy as jnp
        arr = jnp.full(size, NEG, dtype=times.dtype)
        return arr.at[slot].set(times).at[size - 1].set(NEG)


# ---------------------------------------------------------------------------
# Context: every derived table the rules read, built in one vectorized pass
# ---------------------------------------------------------------------------
class _Ctx:
    """Per-trace rule-evaluation context (plain attribute bag)."""

    def __init__(self, cmd, bank, dt, backend):
        xp = backend.xp()
        self.xp = xp
        self.T = TIMING
        n = cmd.shape[0]
        self.n = n
        self.cmd = cmd
        self.bank = bank
        self.dt = dt
        idx = xp.arange(n)
        self.t = xp.cumsum(dt, axis=0) - dt           # issue time of slot i

        self.is_act = cmd == ACT
        self.is_pre = cmd == PRE
        self.is_prea = cmd == PREA
        self.is_rd = cmd == RD
        self.is_wr = cmd == WR
        self.is_rw = self.is_rd | self.is_wr
        self.is_ref = cmd == REF
        self.nonnop = cmd != NOP

        onehot = bank[:, None] == xp.arange(N_BANKS)[None, :]
        act_b = self.is_act[:, None] & onehot
        close_b = (self.is_pre[:, None] & onehot) | self.is_prea[:, None]
        wr_b = self.is_wr[:, None] & onehot
        rd_b = self.is_rd[:, None] & onehot
        self.close_b = close_b

        def last_t(ev):
            return backend.exclusive_cummax(xp.where(ev, self.t, NEG))

        def last_t_b(ev_b):
            return backend.exclusive_cummax(
                xp.where(ev_b, self.t[:, None], NEG))

        def last_i(ev):
            return backend.exclusive_cummax(xp.where(ev, idx, -1))

        def last_i_b(ev_b):
            return backend.exclusive_cummax(xp.where(ev_b, idx[:, None], -1))

        def own(tbl):
            return xp.take_along_axis(tbl, bank[:, None], axis=1)[:, 0]

        # per-bank last-event time tables (strictly before i) + own gathers
        self.t_act_b = last_t_b(act_b)
        self.t_wr_b = last_t_b(wr_b)
        self.t_rd_b = last_t_b(rd_b)
        self.t_act_own = own(self.t_act_b)
        self.t_close_own = own(last_t_b(close_b))

        # bank open state before i: index-based so dt=0 ties keep order
        self.open_b = last_i_b(act_b) > last_i_b(close_b)
        self.open_own = own(self.open_b)

        # any-bank scalars
        self.t_act_any = last_t(self.is_act)
        self.t_wr_any = last_t(self.is_wr)
        self.t_rw_any = last_t(self.is_rw)
        self.t_ref = last_t(self.is_ref)

        # background-state machine (power-down / self-refresh)
        is_pde = cmd == PDE
        is_pds = cmd == PDE_SLOW
        is_pdx = cmd == PDX
        is_sre = cmd == SRE
        is_srx = cmd == SRX
        self.in_pdn = last_i(is_pde | is_pds) > last_i(is_pdx)
        self.in_sr = last_i(is_sre) > last_i(is_srx)
        self.t_pdx = last_t(is_pdx)
        self.t_srx = last_t(is_srx)
        # a PDX exiting a SLOW power-down needs the DLL relock (tXPDLL)
        slow_entry = last_i(is_pds) > last_i(is_pde)
        self.t_pdx_slow = last_t(is_pdx & slow_entry)

        # tFAW: time of the 4th-previous ACT (rolling four-activate window)
        k = xp.cumsum(self.is_act.astype(self.t.dtype), axis=0)
        slot = xp.where(self.is_act, k - 1, n)
        act_times = backend.scatter_times(n + 1, slot, self.t)
        gather = xp.where(self.is_act & (k >= 5), k - 5, n)
        self.t_act_4ago = act_times[gather]


# ---------------------------------------------------------------------------
# The rules (check(ctx) -> (mask, deficit, bank))
# ---------------------------------------------------------------------------
def _scalar(ctx, base, req):
    """Helper for rules on the command's own bank: violated when the base
    condition holds and the command issues before ``req``."""
    deficit = req - ctx.t
    return base & (deficit > 0), deficit, ctx.bank


def _per_bank(ctx, viol_b, deficit_b):
    """Helper for close-side rules that can violate on any bank at once:
    report the worst-deficit bank (first such bank on ties)."""
    deficit_b = ctx.xp.where(viol_b, deficit_b, 0)
    return (viol_b.any(axis=1), deficit_b.max(axis=1),
            deficit_b.argmax(axis=1).astype(ctx.bank.dtype))


@rule("tRCD", "RD/WR before the bank's activate completed (tRCD)")
def _r_trcd(c):
    mask, deficit, bank = _scalar(c, c.is_rw, c.t_act_own + c.T.tRCD)
    return mask & c.open_own, deficit, bank


@rule("tRP", "ACT before the bank's precharge completed (tRP)")
def _r_trp(c):
    return _scalar(c, c.is_act, c.t_close_own + c.T.tRP)


@rule("tRAS", "precharge before the bank's row was open tRAS cycles")
def _r_tras(c):
    req = c.t_act_b + c.T.tRAS
    viol = c.close_b & c.open_b & (c.t[:, None] < req)
    return _per_bank(c, viol, req - c.t[:, None])


@rule("tRC", "ACT-to-ACT on one bank inside tRC")
def _r_trc(c):
    return _scalar(c, c.is_act, c.t_act_own + c.T.tRC)


@rule("tRRD", "ACT-to-ACT across banks inside tRRD")
def _r_trrd(c):
    return _scalar(c, c.is_act, c.t_act_any + c.T.tRRD)


@rule("tFAW", "fifth ACT inside the rolling four-activate window (tFAW)")
def _r_tfaw(c):
    return _scalar(c, c.is_act, c.t_act_4ago + c.T.tFAW)


@rule("tWR", "precharge inside the write-recovery window (tWR)")
def _r_twr(c):
    req = c.t_wr_b + c.T.tBURST + c.T.tWR
    viol = c.close_b & c.open_b & (c.t[:, None] < req)
    return _per_bank(c, viol, req - c.t[:, None])


@rule("tRTP", "precharge inside the read-to-precharge window (tRTP)")
def _r_trtp(c):
    req = c.t_rd_b + c.T.tRTP
    viol = c.close_b & c.open_b & (c.t[:, None] < req)
    return _per_bank(c, viol, req - c.t[:, None])


@rule("tWTR", "read inside the write-to-read turnaround (tWTR)")
def _r_twtr(c):
    return _scalar(c, c.is_rd, c.t_wr_any + c.T.tBURST + c.T.tWTR)


@rule("tCCD", "column command inside the column-to-column window (tCCD)")
def _r_tccd(c):
    return _scalar(c, c.is_rw, c.t_rw_any + c.T.tCCD)


@rule("tRFC", "command issued while a refresh was still in flight (tRFC)")
def _r_trfc(c):
    return _scalar(c, c.nonnop, c.t_ref + c.T.tRFC)


@rule("tXP", "command issued inside the power-down exit latency (tXP)")
def _r_txp(c):
    return _scalar(c, c.nonnop, c.t_pdx + c.T.tXP)


@rule("tXPDLL", "RD/WR before the DLL relocked after a slow power-down "
                "exit (tXPDLL)")
def _r_txpdll(c):
    return _scalar(c, c.is_rw, c.t_pdx_slow + c.T.tXPDLL)


@rule("tXS", "command issued inside the self-refresh exit latency (tXS)")
def _r_txs(c):
    return _scalar(c, c.nonnop, c.t_srx + c.T.tXS)


@rule("BANK_RW_CLOSED", "RD/WR to a bank with no open row")
def _r_rw_closed(c):
    mask = c.is_rw & ~c.open_own
    return mask, c.xp.where(mask, 1, 0), c.bank


@rule("BANK_ACT_OPEN", "ACT to a bank that already has an open row")
def _r_act_open(c):
    mask = c.is_act & c.open_own
    return mask, c.xp.where(mask, 1, 0), c.bank


@rule("REF_BANK_OPEN", "REF issued with banks still open")
def _r_ref_open(c):
    viol = c.is_ref[:, None] & c.open_b
    return _per_bank(c, viol, c.xp.where(viol, 1, 0))


@rule("PDN_ILLEGAL_CMD", "command not legal during power-down")
def _r_pdn(c):
    illegal = c.cmd == _PDN_ILLEGAL[0]
    for code in _PDN_ILLEGAL[1:]:
        illegal = illegal | (c.cmd == code)
    mask = c.in_pdn & illegal
    return mask, c.xp.where(mask, 1, 0), c.bank


@rule("SR_ILLEGAL_CMD", "command not legal during self-refresh")
def _r_sr(c):
    legal = c.cmd == _SR_LEGAL[0]
    for code in _SR_LEGAL[1:]:
        legal = legal | (c.cmd == code)
    mask = c.in_sr & ~legal
    return mask, c.xp.where(mask, 1, 0), c.bank


@rule("DT_NEGATIVE", "command slot owns a negative number of cycles")
def _r_dt(c):
    mask = c.dt < 0
    return mask, c.xp.where(mask, -c.dt, 0), c.bank


@rule("tREFI", "refresh arrived past the tREFI deadline (plus scheduling "
               "slack)", severity=WARNING)
def _r_trefi(c):
    anchor = c.xp.maximum(c.xp.maximum(c.t_ref, c.t_srx),
                          c.xp.zeros_like(c.t))
    deadline = anchor + c.T.tREFI + REFI_SLACK
    deficit = c.t - deadline
    return c.is_ref & (deficit > 0), deficit, c.bank


_RULE_ORDER: tuple[str, ...] = tuple(RULES)


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------
def _eval_rules(cmd, bank, dt, backend):
    """(R, n) stacked (mask, deficit, bank) over every registered rule."""
    ctx = _Ctx(cmd, bank, dt, backend)
    xp = ctx.xp
    masks, deficits, banks = [], [], []
    for rid in _RULE_ORDER:
        m, d, b = RULES[rid].check(ctx)
        masks.append(m)
        deficits.append(xp.where(m, d, 0))
        banks.append(b)
    return xp.stack(masks), xp.stack(deficits), xp.stack(banks)


def _extract(mask, deficit, bank, cmd, trace_index: int) -> list[Diagnostic]:
    out = []
    rule_rows, cmd_idx = np.nonzero(mask)
    for r, i in zip(rule_rows.tolist(), cmd_idx.tolist()):
        rid = _RULE_ORDER[r]
        margin = int(deficit[r, i])
        b = int(bank[r, i])
        out.append(Diagnostic(rid, RULES[rid].severity, trace_index, i, b,
                              margin, _message(rid, int(cmd[i]), i, b,
                                               margin)))
    out.sort(key=lambda d: (d.trace_index, d.cmd_index,
                            _RULE_ORDER.index(d.rule)))
    return out


def lint_trace(trace: CommandTrace, trace_index: int = 0) -> list[Diagnostic]:
    """Lint one trace with the numpy engine (the construction-time hook)."""
    cmd = np.asarray(trace.cmd, dtype=np.int64)
    bank = np.asarray(trace.bank, dtype=np.int64)
    dt = np.asarray(trace.dt, dtype=np.int64)
    mask, deficit, bank_r = _eval_rules(cmd, bank, dt, _NumpyBackend)
    return _extract(mask, deficit, bank_r, cmd, trace_index)


_lint_batch_kernel = None


def _get_batch_kernel():
    """The jitted (T, N) batch linter, built lazily (keeps numpy-only
    callers of :func:`lint_trace` free of any jax dispatch)."""
    global _lint_batch_kernel
    if _lint_batch_kernel is None:
        import jax

        @jax.jit
        def kernel(cmd, bank, dt):
            def one(c, b, d):
                return _eval_rules(c, b, d, _JaxBackend)
            return jax.vmap(one)(cmd, bank, dt)     # (T, R, N) each

        _lint_batch_kernel = kernel
    return _lint_batch_kernel


def lint_arrays_batched(cmd, bank, dt) -> list[Diagnostic]:
    """Lint a padded (T, N) command batch in one jitted dispatch."""
    mask, deficit, bank_r = _get_batch_kernel()(cmd, bank, dt)
    mask = np.asarray(mask)
    deficit = np.asarray(deficit)
    bank_r = np.asarray(bank_r)
    cmd = np.asarray(cmd)
    out = []
    for ti in range(mask.shape[0]):
        out.extend(_extract(mask[ti], deficit[ti], bank_r[ti], cmd[ti], ti))
    return out


def lint_batch(tb) -> list[Diagnostic]:
    """Lint a prebuilt :class:`~repro.core.estimate_batch.TraceBatch` in one
    jitted dispatch.  NOP/dt=0 padding is inert under every rule, so no
    weight masking is needed — pad rows simply cannot violate anything."""
    return lint_arrays_batched(tb.trace.cmd, tb.trace.bank, tb.trace.dt)


def lint_traces(traces: Sequence[CommandTrace]) -> list[Diagnostic]:
    """Lint a sequence of ragged traces through the batched engine, padding
    to the next power of two so repeated calls share compiled shapes.

    Only the three fields the rules read are padded (host-side, one
    allocation each): the NOP/dt=0 pad rows are inert under every rule, so
    no per-trace :func:`~repro.core.dram.pad_trace` round-trip (which
    would also ship the untouched data payload) is needed."""
    traces = list(traces)
    if not traces:
        return []
    longest = max(int(tr.n) for tr in traces)
    length = 1 << max(longest - 1, 1).bit_length()
    cmd = np.zeros((len(traces), length), np.int32)   # NOP == 0
    bank = np.zeros((len(traces), length), np.int32)
    dt = np.zeros((len(traces), length), np.int32)
    for i, tr in enumerate(traces):
        n = int(tr.n)
        cmd[i, :n] = np.asarray(tr.cmd)
        bank[i, :n] = np.asarray(tr.bank)
        dt[i, :n] = np.asarray(tr.dt)
    return lint_arrays_batched(cmd, bank, dt)


# ---------------------------------------------------------------------------
# Reference engine: an independent per-command Python walk (parity oracle)
# ---------------------------------------------------------------------------
def reference_lint(trace: CommandTrace,
                   trace_index: int = 0) -> list[Diagnostic]:
    """Per-command reference checker, deliberately implemented as a plain
    state-machine walk sharing nothing with the vectorized engine beyond
    the rule table — the parity tests pin the two against each other."""
    T = TIMING
    cmd = np.asarray(trace.cmd).tolist()
    bank = np.asarray(trace.bank).tolist()
    dts = np.asarray(trace.dt).tolist()
    out: list[Diagnostic] = []

    act_t = [NEG] * N_BANKS
    close_t = [NEG] * N_BANKS
    wr_t = [NEG] * N_BANKS
    rd_t = [NEG] * N_BANKS
    open_b = [False] * N_BANKS
    act_times: list[int] = []
    last_act = last_wr = last_rw = NEG
    last_ref = last_pdx = last_pdx_slow = last_srx = NEG
    in_pdn = in_sr = False
    slow_entry = False
    t = 0

    def add(rid, i, b, margin):
        out.append(Diagnostic(rid, RULES[rid].severity, trace_index, i,
                              int(b), int(margin),
                              _message(rid, cmd[i], i, int(b), int(margin))))

    def worst_open(i, targets, ref_t, lead, rid):
        deficit, at = 0, -1
        for b in targets:
            if open_b[b] and t < ref_t[b] + lead:
                d = ref_t[b] + lead - t
                if d > deficit:
                    deficit, at = d, b
        if at >= 0:
            add(rid, i, at, deficit)

    for i in range(len(cmd)):
        c, b, d = cmd[i], bank[i], dts[i]
        if d < 0:
            add("DT_NEGATIVE", i, b, -d)
        if c != NOP:
            if t < last_ref + T.tRFC:
                add("tRFC", i, b, last_ref + T.tRFC - t)
            if t < last_pdx + T.tXP:
                add("tXP", i, b, last_pdx + T.tXP - t)
            if t < last_srx + T.tXS:
                add("tXS", i, b, last_srx + T.tXS - t)
        if in_pdn and c in _PDN_ILLEGAL:
            add("PDN_ILLEGAL_CMD", i, b, 1)
        if in_sr and c not in _SR_LEGAL:
            add("SR_ILLEGAL_CMD", i, b, 1)

        if c == ACT:
            if open_b[b]:
                add("BANK_ACT_OPEN", i, b, 1)
            if t < close_t[b] + T.tRP:
                add("tRP", i, b, close_t[b] + T.tRP - t)
            if t < act_t[b] + T.tRC:
                add("tRC", i, b, act_t[b] + T.tRC - t)
            if t < last_act + T.tRRD:
                add("tRRD", i, b, last_act + T.tRRD - t)
            if len(act_times) >= 4 and t < act_times[-4] + T.tFAW:
                add("tFAW", i, b, act_times[-4] + T.tFAW - t)
            act_t[b] = t
            open_b[b] = True
            last_act = t
            act_times.append(t)
        elif c in (RD, WR):
            if not open_b[b]:
                add("BANK_RW_CLOSED", i, b, 1)
            elif t < act_t[b] + T.tRCD:
                add("tRCD", i, b, act_t[b] + T.tRCD - t)
            if t < last_rw + T.tCCD:
                add("tCCD", i, b, last_rw + T.tCCD - t)
            if t < last_pdx_slow + T.tXPDLL:
                add("tXPDLL", i, b, last_pdx_slow + T.tXPDLL - t)
            if c == RD:
                if t < last_wr + T.tBURST + T.tWTR:
                    add("tWTR", i, b, last_wr + T.tBURST + T.tWTR - t)
                rd_t[b] = t
            else:
                wr_t[b] = t
                last_wr = t
            last_rw = t
        elif c in (PRE, PREA):
            targets = range(N_BANKS) if c == PREA else (b,)
            worst_open(i, targets, act_t, T.tRAS, "tRAS")
            worst_open(i, targets, wr_t, T.tBURST + T.tWR, "tWR")
            worst_open(i, targets, rd_t, T.tRTP, "tRTP")
            for tb in targets:
                close_t[tb] = t
                open_b[tb] = False
        elif c == REF:
            for ob in range(N_BANKS):
                if open_b[ob]:
                    add("REF_BANK_OPEN", i, ob, 1)
                    break
            anchor = max(last_ref, last_srx, 0)
            if t > anchor + T.tREFI + REFI_SLACK:
                add("tREFI", i, b, t - (anchor + T.tREFI + REFI_SLACK))
            last_ref = t
        elif c == PDE:
            in_pdn = True
            slow_entry = False
        elif c == PDE_SLOW:
            in_pdn = True
            slow_entry = True
        elif c == PDX:
            last_pdx = t
            if slow_entry:
                last_pdx_slow = t
            in_pdn = False
        elif c == SRE:
            in_sr = True
        elif c == SRX:
            in_sr = False
            last_srx = t
        t += d
    out.sort(key=lambda di: (di.trace_index, di.cmd_index,
                             _RULE_ORDER.index(di.rule)))
    return out


# ---------------------------------------------------------------------------
# Policy surface: how producers/consumers consume the diagnostics
# ---------------------------------------------------------------------------
class TraceProtocolError(ValueError):
    """A trace violated ERROR-severity protocol rules.  Carries the
    structured diagnostics so callers (serving ingestion, tests) can match
    on rule id / command index instead of parsing the message."""

    def __init__(self, diagnostics: Sequence[Diagnostic], origin: str = ""):
        self.diagnostics = tuple(diagnostics)
        self.origin = origin
        shown = [d.message for d in self.diagnostics[:8]]
        if len(self.diagnostics) > len(shown):
            shown.append(f"... {len(self.diagnostics) - len(shown)} more")
        super().__init__(
            f"protocol-illegal trace from {origin or 'caller'}: "
            f"{len(self.diagnostics)} violation(s)\n  " + "\n  ".join(shown))


def errors_of(diags: Sequence[Diagnostic]) -> list[Diagnostic]:
    return [d for d in diags if d.severity == ERROR]


def _is_traced(trace: CommandTrace) -> bool:
    try:
        import jax
        tracer = jax.core.Tracer
    except Exception:  # pragma: no cover - exotic jax layouts
        return True    # fail safe: cannot tell, skip linting
    return isinstance(trace.cmd, tracer)


def check_generated(trace: CommandTrace, origin: str) -> CommandTrace:
    """The strict construction-time guard every repo generator calls on its
    output: raises :class:`TraceProtocolError` on ERROR diagnostics, warns
    on WARNING ones, and passes the trace through.  Traced/abstract inputs
    are skipped (shape-polymorphic callers cannot be walked).  Set
    ``REPRO_TRACE_LINT=off`` to disable (e.g. when intentionally producing
    broken traces to study)."""
    if os.environ.get("REPRO_TRACE_LINT", "").lower() == "off":
        return trace
    if _is_traced(trace):
        return trace
    diags = lint_trace(trace)
    errors = errors_of(diags)
    if errors:
        raise TraceProtocolError(errors, origin)
    for d in diags:
        warnings.warn(f"[{origin}] {d.message}", stacklevel=3)
    return trace


def check_trace(trace: CommandTrace, origin: str = "make_trace",
                mode: str = "strict") -> list[Diagnostic]:
    """The opt-in ``dram.make_trace`` hook (``REPRO_TRACE_LINT=warn|strict``):
    lint any concrete construction, warn or raise per ``mode``."""
    if _is_traced(trace):
        return []
    diags = lint_trace(trace)
    if mode == "strict":
        errors = errors_of(diags)
        if errors:
            raise TraceProtocolError(errors, origin)
    for d in diags:
        warnings.warn(f"[{origin}] {d.message}", stacklevel=3)
    return diags


def lint_ingested(traces: Sequence[CommandTrace],
                  origin: str = "ingestion") -> None:
    """Strict batched gate for externally ingested traces (the serving
    ``--power-report`` path): one jitted lint dispatch over the whole
    sequence, raising with rule id + command index on any ERROR."""
    errors = errors_of(lint_traces(traces))
    if errors:
        raise TraceProtocolError(errors, origin)
