"""Static verification layer: trace protocol linting, compile-time dispatch
auditing, and repo-invariant AST linting.

Three passes, all runnable as ``python -m repro.analysis`` (the CI gate):

* :mod:`repro.analysis.trace_lint` — a declarative JEDEC-style timing/state
  rule engine over :class:`~repro.core.dram.CommandTrace`; every trace
  producer in the repo (IDD loops, ``app_trace``, encodings, the power-down
  policy) and the serving ingestion path run it.
* :mod:`repro.analysis.dispatch_audit` — walks the jaxpr / lowered HLO of
  every registered (estimator kind x impl x mode) dispatch and flags
  float64 promotion, host callbacks, missing pad-row masking, and jit
  recompilation hazards.
* :mod:`repro.analysis.repo_lint` — an AST pass enforcing the Model API
  invariants the ROADMAP states in prose (no deprecated-shim calls, impls
  declare their modes, call-time ``interpret_default()``, serialization
  schema covers every ``PowerParams`` field).
"""
from repro.analysis.trace_lint import (Diagnostic, TimingRule,  # noqa: F401
                                       TraceProtocolError, check_generated,
                                       lint_batch, lint_trace, lint_traces,
                                       reference_lint)
