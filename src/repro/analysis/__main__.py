"""``python -m repro.analysis`` — the static-analysis CI gate.

Runs the three passes over a representative corpus and exits non-zero on
any ERROR finding:

1. **trace lint** — every generator in the repo (IDD loops, probes,
   validation sweeps, SPEC application traces, encoded traces,
   power-down policy traces) linted against the full JEDEC rule set
   with the batched engine;
2. **dispatch audit** — every registered (estimator kind x impl x mode)
   combination traced + lowered and checked for float64 promotion, host
   callbacks, dead pad-masking, and recompilation hazards; plus the
   online-recalibration probe (the incremental update step compiles
   once and stays float32, and a streaming refit hot-swapped through
   ``ServingEngine.update_model`` adds zero new compiled programs);
3. **repo lint** — the AST invariants over ``src/repro``.

Pass ``--skip-dispatch`` to run only the cheap static passes (the
dispatch audit fits a quick model and jit-compiles every combination,
which dominates the runtime).
"""
from __future__ import annotations

import argparse
import sys


def _corpus():
    """(label, CommandTrace) pairs covering every generator family."""
    import numpy as np

    from repro.core import applications, dram, encodings, idd_loops, traces

    out = []

    def add(label, obj):
        # several generators return (trace, skip) pairs
        tr = obj if isinstance(obj, dram.CommandTrace) else obj[0]
        out.append((label, tr))

    for name, fn in idd_loops.IDD_LOOPS.items():
        add(f"idd_loops.{name}", fn())
    add("idd_loops.ones_sweep_point(8)", idd_loops.ones_sweep_point(8))
    add("idd_loops.interleave_sweep_point",
        idd_loops.interleave_sweep_point(
            np.zeros(dram.LINE_WORDS, np.uint32),
            np.full(dram.LINE_WORDS, 0xFFFFFFFF, np.uint32), "bankcol"))
    add("idd_loops.bank_idle_probe(3)", idd_loops.bank_idle_probe(3))
    add("idd_loops.bank_read_probe(5)", idd_loops.bank_read_probe(5))
    add("idd_loops.row_act_probe(7)", idd_loops.row_act_probe(7))
    add("idd_loops.column_read_probe(9)", idd_loops.column_read_probe(9))
    for n in (0, 1, 4, 16):
        add(f"idd_loops.validation_sweep({n})",
            idd_loops.validation_sweep(n))

    apps = {}
    for app in traces.SPEC_APPS:
        tr = traces.app_trace(app, n_requests=256)
        apps[app.name] = tr
        add(f"traces.app_trace({app.name})", tr)

    demo = apps[traces.SPEC_APPS[3].name]
    for enc in encodings.ENCODINGS:
        add(f"encodings.encode_trace({enc})",
            encodings.encode_trace(demo, enc))
    for timeout in (32, 256):
        add(f"applications.apply_powerdown_policy(t={timeout})",
            applications.apply_powerdown_policy(demo, timeout))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--skip-dispatch", action="store_true",
                    help="skip the (slow) compile-time dispatch audit")
    args = ap.parse_args(argv)

    from repro.analysis import dispatch_audit, repo_lint, trace_lint

    n_errors = 0

    corpus = _corpus()
    diags = trace_lint.lint_traces([tr for _, tr in corpus])
    labels = [label for label, _ in corpus]
    errs = trace_lint.errors_of(diags)
    n_errors += len(errs)
    for d in diags:
        stream = sys.stderr if d.severity == trace_lint.ERROR else sys.stdout
        print(f"trace_lint[{labels[d.trace_index]}]: {d}", file=stream)
    print(f"trace lint: {len(corpus)} traces, "
          f"{len(errs)} errors, {len(diags) - len(errs)} warnings")

    if not args.skip_dispatch:
        from repro.core import vampire as V
        model = V.reference_vampire()
        findings = dispatch_audit.audit_all(model)
        findings.extend(dispatch_audit.audit_serving(model))
        findings.extend(dispatch_audit.audit_fleet_chunked())
        findings.extend(dispatch_audit.audit_recalibration(model))
        errs = dispatch_audit.errors_of(findings)
        n_errors += len(errs)
        for f in findings:
            print(f"dispatch_audit: {f}",
                  file=sys.stderr if f.severity == dispatch_audit.ERROR
                  else sys.stdout)
        print(f"dispatch audit: {len(errs)} errors, "
              f"{len(findings) - len(errs)} warnings")
    else:
        print("dispatch audit: skipped")

    findings = repo_lint.run_repo_lint()
    errs = repo_lint.errors_of(findings)
    n_errors += len(errs)
    for f in findings:
        print(f"repo_lint: {f}",
              file=sys.stderr if f.severity == repo_lint.ERROR
              else sys.stdout)
    print(f"repo lint: {len(errs)} errors, "
          f"{len(findings) - len(errs)} warnings")

    if n_errors:
        print(f"FAILED: {n_errors} error(s)", file=sys.stderr)
        return 1
    print("analysis clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
