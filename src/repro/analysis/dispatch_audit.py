"""Compile-time dispatch auditor: prove every registered (estimator kind x
impl x mode) combination is jit-clean WITHOUT running the integrator.

For each combination the auditor traces the ``estimate`` dispatch to a
jaxpr and lowers it to StableHLO text (the same artifact
``launch/hlo_analysis.py`` mines for cost totals), then checks:

* **float64 promotion** — no ``f64``/``c128`` buffers anywhere in the
  lowered module: the energy pipeline is a float32 contract end to end,
  and a stray Python float in the wrong place silently doubles every
  buffer;
* **host callbacks** — no ``pure_callback`` / ``io_callback`` / debug
  primitives inside the traced dispatch: a host round-trip per call would
  serialize the batched engine;
* **pad-row masking** — the :class:`~repro.core.estimate_batch.TraceBatch`
  validity ``weight`` must survive dead-code elimination, i.e. the
  result really depends on the mask (a dispatch that drops it bills
  padding rows);
* **recompilation hazards** — repeated calls, same-shape re-pads of a
  different ragged trace set, and equal-size vendor subsets must hit the
  jit cache of the shared batched dispatchers (``_cache_size`` growth
  probes, generalizing the PR 3 regression test into a pass); the
  serving stack gets its own probe (:func:`audit_serving`) asserting the
  ring's pad-shape bucketing bounds the engine's compiled-program count.

Findings are structured (:class:`AuditFinding`); ``python -m
repro.analysis`` fails the CI gate on any ERROR severity.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Iterable, Sequence

import numpy as np

ERROR = "error"
WARNING = "warning"

#: substrings of primitive names that imply a host round-trip
_CALLBACK_MARKERS = ("callback", "outside_call", "infeed", "outfeed",
                    "debug_print")

# HLO spells the dtype inside the shape ("tensor<4xf64>"), so a plain \b
# never fires after the 'x' — accept either a word boundary or that 'x'.
_F64_RE = re.compile(r"(?:\b|x)(?:f64|c128)\b")

#: impls whose batched dispatch consumes the padded batch directly and must
#: therefore consume the validity mask (the reference oracle instead slices
#: per ragged trace, where a dt=0 NOP pad row is exact by construction)
_MASKED_IMPLS = ("vectorized", "pallas")

_MODES = ("mean", "range", "distribution", "surface")


@dataclasses.dataclass(frozen=True)
class AuditFinding:
    """One dispatch-audit diagnostic."""
    kind: str       # estimator kind ('vampire' | 'micron' | 'drampower')
    impl: str       # registry impl name
    mode: str       # estimate mode
    check: str      # 'float64' | 'host_callback' | 'pad_masking' |
                    # 'recompile' | 'audit_trace'
    severity: str   # 'error' | 'warning'
    detail: str

    def __str__(self):  # pragma: no cover - formatting
        return (f"[{self.severity.upper()}] {self.check}: "
                f"kind={self.kind} impl={self.impl} mode={self.mode} — "
                f"{self.detail}")


def errors_of(findings: Iterable[AuditFinding]) -> list[AuditFinding]:
    return [f for f in findings if f.severity == ERROR]


# ---------------------------------------------------------------------------
# Shared probe inputs
# ---------------------------------------------------------------------------
def default_audit_batch():
    """A small heterogeneous TraceBatch (real padding rows present, so the
    pad-masking check is not vacuous)."""
    from repro.core import idd_loops, traces
    from repro.core.estimate_batch import TraceBatch
    trs = [idd_loops.idd0(reps=4),
           idd_loops.idd4r(reps=2),
           traces.app_trace(traces.SPEC_APPS[0], n_requests=24)]
    return TraceBatch.from_traces(trs)


def _estimate_fn(model, impl: str, mode: str) -> Callable:
    """The (trace, weight) -> report function the audit traces: exactly the
    production dispatch, model params closed over as constants."""
    from repro.core.estimate_batch import TraceBatch

    def fn(trace, weight):
        kwargs = {}
        if mode == "distribution":
            kwargs = dict(ones_frac=0.5, toggle_frac=0.25)
        return model.estimate(TraceBatch(trace, weight), mode=mode,
                              impl=impl, **kwargs)
    return fn


# ---------------------------------------------------------------------------
# jaxpr helpers
# ---------------------------------------------------------------------------
def _iter_jaxprs(jaxpr):
    """The jaxpr and every sub-jaxpr reachable through equation params
    (pjit bodies, scan/while carries, cond branches, pallas kernels)."""
    import jax.extend as jex  # noqa: F401  (presence varies by version)
    seen = []
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        seen.append(j)
        for eqn in j.eqns:
            for val in eqn.params.values():
                for sub in _as_jaxprs(val):
                    stack.append(sub)
    return seen


def _as_jaxprs(val):
    out = []
    vals = val if isinstance(val, (list, tuple)) else (val,)
    for v in vals:
        inner = getattr(v, "jaxpr", None)
        if inner is not None and hasattr(inner, "eqns"):
            out.append(inner)          # ClosedJaxpr
        elif hasattr(v, "eqns") and hasattr(v, "invars"):
            out.append(v)              # raw Jaxpr
    return out


def _primitive_names(jaxpr) -> set[str]:
    return {eqn.primitive.name for j in _iter_jaxprs(jaxpr)
            for eqn in j.eqns}


def _dce_used_invars(jaxpr) -> list[bool] | None:
    """Which top-level invars survive DCE (None when the partial-eval API
    is unavailable in this jax version — callers then skip the check
    rather than report a false positive)."""
    try:
        from jax._src.interpreters import partial_eval as pe
        _, used = pe.dce_jaxpr(jaxpr, [True] * len(jaxpr.outvars))
        return list(used)
    except Exception:
        return None


# ---------------------------------------------------------------------------
# The per-combination audit
# ---------------------------------------------------------------------------
def audit_combination(model, impl: str, mode: str,
                      tb=None) -> list[AuditFinding]:
    """Trace + lower one (kind, impl, mode) dispatch and run the static
    checks. Returns findings (empty when clean)."""
    import jax

    if tb is None:
        tb = default_audit_batch()
    kind = model.kind
    fn = _estimate_fn(model, impl, mode)
    findings: list[AuditFinding] = []

    try:
        closed = jax.make_jaxpr(fn)(tb.trace, tb.weight)
    except Exception as exc:  # infra failure, not a verified dispatch bug
        return [AuditFinding(kind, impl, mode, "audit_trace", ERROR,
                             f"dispatch failed to trace: {exc!r}")]

    prims = _primitive_names(closed.jaxpr)
    hits = sorted(p for p in prims
                  if any(m in p for m in _CALLBACK_MARKERS))
    if hits:
        findings.append(AuditFinding(
            kind, impl, mode, "host_callback", ERROR,
            f"host-callback primitives in traced dispatch: {hits}"))

    if impl in _MASKED_IMPLS:
        used = _dce_used_invars(closed.jaxpr)
        if used is not None and not used[-1]:  # weight flattens last
            findings.append(AuditFinding(
                kind, impl, mode, "pad_masking", ERROR,
                "the TraceBatch validity weight is dead code: padding "
                "rows would be billed as real commands"))

    try:
        text = jax.jit(fn).lower(tb.trace, tb.weight).as_text()
    except Exception as exc:
        findings.append(AuditFinding(
            kind, impl, mode, "audit_trace", WARNING,
            f"dispatch traced but failed to lower: {exc!r}"))
        return findings

    m = _F64_RE.search(text)
    if m:
        findings.append(AuditFinding(
            kind, impl, mode, "float64", ERROR,
            f"lowered HLO contains {m.group(0)} buffers (float32 contract "
            f"violated)"))
    return findings


# ---------------------------------------------------------------------------
# Recompilation-hazard probes (vectorized impl: the @jax.jit dispatchers)
# ---------------------------------------------------------------------------
def _mode_dispatcher(mode: str):
    from repro.core import estimate_batch as EB
    # surface's jitted core is the shared chunk-charge program (the public
    # wrapper is a plain function so the chunked dispatch can reuse it)
    return {"mean": EB.batched_reports,
            "range": EB.batched_range_reports,
            "distribution": EB.batched_distribution_reports,
            "surface": EB._surface_chunk_charge}[mode]


def audit_recompilation(model, modes: Sequence[str] = _MODES,
                        tb=None, tb_same_shape=None) -> list[AuditFinding]:
    """Drive the production ``estimate`` path and assert the shared jitted
    dispatchers stop compiling once warm: repeated calls, a same-shape
    re-pad of a DIFFERENT ragged trace set, and equal-size vendor subsets
    must all hit the cache."""
    if tb is None:
        tb = default_audit_batch()
    if tb_same_shape is None:
        from repro.core import dram
        from repro.core.estimate_batch import TraceBatch
        # different ragged content, identical padded shape
        perm = list(range(tb.n_traces))[::-1]
        import jax
        trace = jax.tree_util.tree_map(lambda x: x[np.asarray(perm)],
                                       tb.trace)
        tb_same_shape = TraceBatch(trace, tb.weight[np.asarray(perm)])
    kind = model.kind
    vendors = list(model.vendors)
    findings: list[AuditFinding] = []

    for mode in modes:
        fn = _mode_dispatcher(mode)
        kwargs = ({"ones_frac": 0.5, "toggle_frac": 0.25}
                  if mode == "distribution" else {})

        def call(batch, vs):
            model.estimate(batch, vs, mode=mode, impl="vectorized",
                           **kwargs)

        call(tb, vendors)                       # warm
        base = fn._cache_size()
        call(tb, vendors)                       # repeat: must hit
        if fn._cache_size() != base:
            findings.append(AuditFinding(
                kind, "vectorized", mode, "recompile", ERROR,
                "repeated estimate over an identical TraceBatch "
                "recompiled the batched dispatcher"))
        call(tb_same_shape, vendors)            # same shape, new content
        if fn._cache_size() != base:
            findings.append(AuditFinding(
                kind, "vectorized", mode, "recompile", ERROR,
                "a same-shape re-pad of a different ragged trace set "
                "recompiled the batched dispatcher"))
        if len(vendors) >= 3:
            call(tb, vendors[:2])               # first subset of size 2
            grown = fn._cache_size()
            if grown > base + 1:
                findings.append(AuditFinding(
                    kind, "vectorized", mode, "recompile", ERROR,
                    "a vendor subset compiled more than one new program"))
            call(tb, vendors[1:])               # same-size subset: must hit
            if fn._cache_size() != grown:
                findings.append(AuditFinding(
                    kind, "vectorized", mode, "recompile", ERROR,
                    "an equal-size vendor subset recompiled the batched "
                    "dispatcher (subset slicing is shape-unstable)"))
    return findings


# ---------------------------------------------------------------------------
# Serving-path recompile probe (the ring's bucketing contract)
# ---------------------------------------------------------------------------
def audit_serving(model, impl: str = "vectorized") -> list[AuditFinding]:
    """Drive the serving stack's dispatch path and assert the ring's
    pad-shape bucketing bounds the engine's compiled-program count:
    arrival mixes that vary WITHIN one (count, length) bucket must hit
    the cache, and crossing into a new bucket compiles exactly one new
    program.  This is the serving twin of :func:`audit_recompilation` —
    it guards the property that made ``serve.power_report``'s
    exact-request-shape re-pads a bug."""
    from repro.core import idd_loops
    from repro.serving import EstimationService, RingConfig, ServiceConfig

    kind = model.kind
    findings: list[AuditFinding] = []
    short = [idd_loops.idd0(reps=2), idd_loops.idd0(reps=3),
             idd_loops.idd4r(reps=2)]
    long = idd_loops.validation_sweep(64)
    b1 = 1 << (max(int(tr.n) for tr in short) - 1).bit_length()
    b2 = max(1 << (int(long.n) - 1).bit_length(), b1 * 2)
    svc = EstimationService(model, ServiceConfig(
        ring=RingConfig(length_buckets=(b1, b2), count_buckets=(4, 8)),
        impl=impl, lint=False))

    def run(traces):
        svc.submit_many(traces)
        svc.drain()
        return svc.engine.cache_size()

    base = run(short)                          # warm: one (4, b1) program
    if run(short[:2]) != base or run(short + short[:1]) != base:
        findings.append(AuditFinding(
            kind, impl, "mean", "recompile", ERROR,
            "varying arrival mixes within one (count, length) bucket "
            "recompiled the serving dispatch (ring bucketing broken)"))
    crossed = run([long])                      # new length bucket: (4, b2)
    if crossed > base + 1:
        findings.append(AuditFinding(
            kind, impl, "mean", "recompile", ERROR,
            "crossing one length bucket compiled more than one new "
            "serving program"))
    if run([long] + short[:1]) != crossed:     # mixed window, known bucket
        findings.append(AuditFinding(
            kind, impl, "mean", "recompile", ERROR,
            "a mixed-length window landing in an already-compiled bucket "
            "recompiled the serving dispatch"))
    return findings


# ---------------------------------------------------------------------------
# Fleet-scale chunked-dispatch probe (the zero-restack scaling contract)
# ---------------------------------------------------------------------------
def audit_fleet_chunked(tb=None, module_chunk: int = 4
                        ) -> list[AuditFinding]:
    """Drive the fleet-scale chunked surface dispatch and assert its
    scaling contract: the compiled-program count of the chunk charge
    program depends on the chunk SIZE, never the chunk COUNT — growing
    the fleet at a fixed chunk size must reuse the warm program (the
    property that makes a 50k-module surface map cost one compile), and
    the donated scatter carry must stay float32 (a stray f64 in the
    accumulator doubles the one buffer the chunked path exists to
    bound)."""
    import jax
    import jax.numpy as jnp

    from repro.core import device_sim
    from repro.core import estimate_batch as EB
    from repro.core.dram import N_BANKS, N_ROW_BANDS

    if tb is None:
        tb = default_audit_batch()
    findings: list[AuditFinding] = []

    _, small = device_sim.synth_fleet_params(2 * module_chunk)
    _, big = device_sim.synth_fleet_params(4 * module_chunk)
    EB.chunked_surface_reports(tb.trace, tb.weight, small,
                               module_chunk=module_chunk)        # warm
    base = EB._surface_chunk_charge._cache_size()
    EB.chunked_surface_reports(tb.trace, tb.weight, big,
                               module_chunk=module_chunk)        # 2x chunks
    if EB._surface_chunk_charge._cache_size() != base:
        findings.append(AuditFinding(
            "fleet", "vectorized", "surface", "recompile", ERROR,
            "growing the fleet at a fixed module_chunk recompiled the "
            "chunk charge program (compiled-program count must depend on "
            "chunk size, not chunk count)"))
    EB.chunked_surface_reports(tb.trace, tb.weight, small,
                               module_chunk=module_chunk)        # revisit
    if EB._surface_chunk_charge._cache_size() != base:
        findings.append(AuditFinding(
            "fleet", "vectorized", "surface", "recompile", ERROR,
            "revisiting an already-seen fleet size recompiled the chunk "
            "charge program"))

    # float64 promotion in the donated scatter carry
    t = tb.trace.cmd.shape[0]
    acc = jnp.zeros((t, 2 * module_chunk, N_BANKS, N_ROW_BANDS),
                    jnp.float32)
    charge = jnp.zeros((t, module_chunk, N_BANKS, N_ROW_BANDS),
                       jnp.float32)
    try:
        text = EB._scatter_chunk.lower(acc, charge, jnp.int32(0),
                                       jnp.int32(0)).as_text()
    except Exception as exc:
        findings.append(AuditFinding(
            "fleet", "vectorized", "surface", "audit_trace", WARNING,
            f"chunk scatter failed to lower: {exc!r}"))
        return findings
    m = _F64_RE.search(text)
    if m:
        findings.append(AuditFinding(
            "fleet", "vectorized", "surface", "float64", ERROR,
            f"the donated chunk-scatter carry lowers with {m.group(0)} "
            f"buffers (float32 contract violated)"))
    return findings


# ---------------------------------------------------------------------------
# Online-recalibration probe (the fit-while-serving contract)
# ---------------------------------------------------------------------------
def audit_recalibration(model=None) -> list[AuditFinding]:
    """Audit the streaming-fit path (``repro.core.recalibrate``):

    * the ONE incremental update step (``_update_stats``) lowers f64-free
      (the sufficient statistics are a float32 pytree end to end);
    * a round-robin telemetry stream — fixed slice width, moving cell
      window, advancing tick — compiles the update step exactly ONCE;
    * a streaming refit pushed through ``ServingEngine.update_model`` is
      treedef-stable: the warm engine re-dispatches with ZERO new
      compiled programs (the property that makes fit-while-serving free).
    """
    import jax.numpy as jnp

    from repro.core import params as P
    from repro.core import recalibrate
    from repro.serving.engine import ServingEngine

    if model is None:
        from repro.core import vampire as V
        model = V.reference_vampire()
    cfg = recalibrate.RecalConfig(probe_reps=64, n_rows=8,
                                  probe_modules=2, slice_size=32)
    specs = [P.ModuleSpec(v, i, 2015)
             for v in model.vendors for i in range(2)]
    fitter = recalibrate.StreamingFitter(model, specs, cfg)
    findings: list[AuditFinding] = []

    # ---- float64 promotion in the lowered update step --------------------
    cur = jnp.zeros((len(specs), cfg.slice_size), jnp.float32)
    idx = jnp.arange(cfg.slice_size, dtype=jnp.int32)
    try:
        text = recalibrate._update_stats.lower(
            fitter.stats, cur, idx, fitter._decay, fitter._predicted,
            fitter._floor).as_text()
    except Exception as exc:
        findings.append(AuditFinding(
            "recalibrate", "streaming", "fit", "audit_trace", WARNING,
            f"incremental update step failed to lower: {exc!r}"))
    else:
        m = _F64_RE.search(text)
        if m:
            findings.append(AuditFinding(
                "recalibrate", "streaming", "fit", "float64", ERROR,
                f"the incremental update step lowers with {m.group(0)} "
                f"buffers (the sufficient statistics must stay float32)"))

    # ---- one compiled program across the telemetry stream ----------------
    n_cells = fitter.n_cells
    before = recalibrate._update_stats._cache_size()
    fitter.observe(np.asarray(fitter._predicted[:, :cfg.slice_size]),
                   np.arange(cfg.slice_size), tick=1)        # warm
    base = recalibrate._update_stats._cache_size()
    if base > before + 1:
        findings.append(AuditFinding(
            "recalibrate", "streaming", "fit", "recompile", ERROR,
            "the first telemetry slice compiled more than one update "
            "program"))
    shifted = (np.arange(cfg.slice_size) + cfg.slice_size) % n_cells
    fitter.observe(
        np.asarray(fitter._predicted)[:, shifted], shifted, tick=2)
    if recalibrate._update_stats._cache_size() != base:
        findings.append(AuditFinding(
            "recalibrate", "streaming", "fit", "recompile", ERROR,
            "the round-robin telemetry stream recompiled the update step "
            "(a fixed-width slice at a new tick must hit the cache)"))

    # ---- streaming refit -> update_model: zero new programs --------------
    engine = ServingEngine(model)
    tb = default_audit_batch()
    engine.dispatch(tb)                                      # warm
    warm = engine.cache_size()
    engine.update_model(fitter.refit())
    engine.dispatch(tb)
    if engine.cache_size() != warm:
        findings.append(AuditFinding(
            "recalibrate", "streaming", "fit", "recompile", ERROR,
            "a streaming refit pushed through ServingEngine.update_model "
            "compiled new programs (the refresh is not treedef-stable)"))
    return findings


# ---------------------------------------------------------------------------
# Whole-registry sweep
# ---------------------------------------------------------------------------
def audit_model(model, impls: Sequence[str] | None = None,
                modes: Sequence[str] | None = None,
                tb=None, recompile: bool = True) -> list[AuditFinding]:
    """Audit every (impl x mode) dispatch of one estimator."""
    from repro.core import model_api
    if tb is None:
        tb = default_audit_batch()
    findings: list[AuditFinding] = []
    for impl in (impls if impls is not None else model_api.registered_impls()):
        for mode in (modes if modes is not None else
                     model_api.resolve_impl(impl).modes):
            findings.extend(audit_combination(model, impl, mode, tb))
    if recompile:
        findings.extend(audit_recompilation(
            model, modes if modes is not None else _MODES, tb))
    return findings


def audit_all(vampire, kinds: Sequence[str] | None = None,
              **kwargs) -> list[AuditFinding]:
    """Audit every registered estimator kind built from one fitted model."""
    from repro.core import model_api
    findings: list[AuditFinding] = []
    for kind in (kinds if kinds is not None else model_api.ESTIMATOR_KINDS):
        model = model_api.make_estimator(kind, vampire)
        findings.extend(audit_model(model, **kwargs))
    return findings
