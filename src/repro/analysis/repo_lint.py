"""AST lint: the Model API invariants the ROADMAP states in prose, made
machine-checkable.

Five rules over ``src/repro`` (reported as :class:`RepoFinding`; the CI
gate fails on any ERROR):

* **R1 no-deprecated-shims** — no internal call sites of the deprecated
  ``Vampire.estimate_range`` / ``estimate_distribution`` /
  ``estimate_many`` / ``estimate_range_many`` /
  ``estimate_distribution_many`` shims (their def sites in ``vampire.py``
  are the one allowed home; everything else goes through the unified
  ``estimate(traces, vendors, mode=..., impl=...)``).
* **R2 impls-declare-modes** — every ``register_impl(EstimateImpl(...))``
  passes an explicit ``modes=`` tuple: an impl that silently inherits
  "all modes" would advertise capabilities nobody wired a dispatch for.
* **R3 call-time-interpret** — kernel modules resolve Pallas
  interpret-vs-compiled PER CALL via ``interpret_default()``: no
  module-level ``*INTERPRET*`` flag assignments (a module-level read of
  the env var freezes the choice at import time and breaks the CI
  pallas-interpret job), and every module invoking ``pallas_call`` must
  reference ``interpret_default``.
* **R5 fitters-declare-streaming** — every
  ``register_fitter(FitterSpec(...))`` passes an explicit ``streaming=``
  flag (the fitter-registry twin of R2): whether a fitter consumes a
  one-shot campaign or a telemetry stream decides which call shapes
  ``model_api.fit`` accepts, so it must be declared, never defaulted.
* **R4 params-serialization-covered** — every ``PowerParams`` field is
  either in the v2 serialization field list (``model_api._FITTED_FIELDS``)
  or derived at load time (a keyword of the ``PowerParams(...)``
  construction in ``characterize.build_params``); and every serialized
  field added after the legacy v1 schema carries a NamedTuple backfill
  default, so pre-existing blobs keep loading.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Iterable

ERROR = "error"
WARNING = "warning"

DEPRECATED_SHIMS = ("estimate_range", "estimate_distribution",
                    "estimate_many", "estimate_range_many",
                    "estimate_distribution_many")

#: files allowed to mention the shims: their definitions and this linter
_SHIM_DEF_FILES = ("core/vampire.py", "analysis/repo_lint.py")


@dataclasses.dataclass(frozen=True)
class RepoFinding:
    rule: str       # 'no-deprecated-shims' | 'impls-declare-modes' |
                    # 'call-time-interpret' | 'params-serialization-covered'
    severity: str
    path: str       # repo-relative
    line: int
    message: str

    def __str__(self):  # pragma: no cover - formatting
        return (f"[{self.severity.upper()}] {self.rule}: "
                f"{self.path}:{self.line} — {self.message}")


def errors_of(findings: Iterable[RepoFinding]) -> list[RepoFinding]:
    return [f for f in findings if f.severity == ERROR]


def _repo_src() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[1]  # src/repro


def _parse(path: pathlib.Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def _iter_sources(root: pathlib.Path | None = None):
    root = root or _repo_src()
    for path in sorted(root.rglob("*.py")):
        yield path.relative_to(root).as_posix(), _parse(path)


# ---------------------------------------------------------------------------
# R1 — no internal deprecated-shim calls
# ---------------------------------------------------------------------------
def check_no_deprecated_shims(sources=None) -> list[RepoFinding]:
    findings = []
    for rel, tree in (sources if sources is not None else _iter_sources()):
        if rel in _SHIM_DEF_FILES:
            continue
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in DEPRECATED_SHIMS):
                findings.append(RepoFinding(
                    "no-deprecated-shims", ERROR, rel, node.lineno,
                    f"internal call of deprecated shim "
                    f".{node.func.attr}(); use estimate(..., mode=...)"))
    return findings


# ---------------------------------------------------------------------------
# R2 — register_impl declares modes
# ---------------------------------------------------------------------------
def check_impls_declare_modes(sources=None) -> list[RepoFinding]:
    findings = []
    for rel, tree in (sources if sources is not None else _iter_sources()):
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "register_impl" and node.args):
                continue
            arg = node.args[0]
            if not (isinstance(arg, ast.Call)
                    and isinstance(arg.func, ast.Name)
                    and arg.func.id == "EstimateImpl"):
                continue  # re-registration of an existing constant: fine
            if not any(kw.arg == "modes" for kw in arg.keywords):
                findings.append(RepoFinding(
                    "impls-declare-modes", ERROR, rel, node.lineno,
                    "register_impl(EstimateImpl(...)) without an explicit "
                    "modes= declaration"))
    return findings


# ---------------------------------------------------------------------------
# R5 — register_fitter declares streaming
# ---------------------------------------------------------------------------
def check_fitters_declare_streaming(sources=None) -> list[RepoFinding]:
    findings = []
    for rel, tree in (sources if sources is not None else _iter_sources()):
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "register_fitter" and node.args):
                continue
            arg = node.args[0]
            if not (isinstance(arg, ast.Call)
                    and isinstance(arg.func, ast.Name)
                    and arg.func.id == "FitterSpec"):
                continue  # re-registration of an existing constant: fine
            if not any(kw.arg == "streaming" for kw in arg.keywords):
                findings.append(RepoFinding(
                    "fitters-declare-streaming", ERROR, rel, node.lineno,
                    "register_fitter(FitterSpec(...)) without an explicit "
                    "streaming= declaration"))
    return findings


# ---------------------------------------------------------------------------
# R3 — kernels resolve interpret mode per call
# ---------------------------------------------------------------------------
def _module_names(tree: ast.Module) -> set[str]:
    return {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)} | \
           {n.attr for n in ast.walk(tree) if isinstance(n, ast.Attribute)}


def check_call_time_interpret(sources=None) -> list[RepoFinding]:
    findings = []
    if sources is None:
        root = _repo_src() / "kernels"
        sources = [(f"kernels/{rel}", tree)
                   for rel, tree in _iter_sources(root)]
    for rel, tree in sources:
        # (a) no module-level *INTERPRET* flag assignment
        for node in tree.body:
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign):
                targets = [node.target]
            for t in targets:
                if isinstance(t, ast.Name) and "INTERPRET" in t.id.upper():
                    findings.append(RepoFinding(
                        "call-time-interpret", ERROR, rel, node.lineno,
                        f"module-level interpret flag {t.id!r}: the mode "
                        f"must resolve per call via interpret_default()"))
        # (b) pallas_call users must reference interpret_default
        names = _module_names(tree)
        if "pallas_call" in names and "interpret_default" not in names \
                and not rel.endswith("common.py"):
            findings.append(RepoFinding(
                "call-time-interpret", ERROR, rel, 1,
                "module invokes pallas_call but never references "
                "interpret_default()"))
    return findings


# ---------------------------------------------------------------------------
# R4 — PowerParams fields covered by the v2 serialization schema
# ---------------------------------------------------------------------------
def _class_fields(tree: ast.Module, cls: str) -> list[tuple[str, bool]]:
    """(field, has_default) per AnnAssign of the class, in order."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls:
            return [(s.target.id, s.value is not None) for s in node.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)]
    raise ValueError(f"class {cls} not found")


def _tuple_literal(tree: ast.Module, name: str) -> list[str]:
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == name
                and isinstance(node.value, (ast.Tuple, ast.List))):
            return [ast.literal_eval(e) for e in node.value.elts]
    raise ValueError(f"tuple literal {name} not found")


def _v1_anchor_fields(tree: ast.Module) -> set[str]:
    """The legacy schema-v1 blob keys, read from ``_save_v1_pickle``'s dict
    literal — fields beyond this set must carry backfill defaults."""
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == "_save_v1_pickle":
            for sub in ast.walk(node):
                if isinstance(sub, ast.Dict) and len(sub.keys) >= 5:
                    return {k.value for k in sub.keys
                            if isinstance(k, ast.Constant)}
    return set()


def _constructor_keywords(tree: ast.Module, func: str, cls: str) -> set[str]:
    """Keywords passed to ``cls(...)`` anywhere inside method/func ``func``."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == func:
            for sub in ast.walk(node):
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Name)
                        and sub.func.id == cls):
                    out |= {kw.arg for kw in sub.keywords if kw.arg}
    return out


def check_params_serialization(src_root: pathlib.Path | None = None
                               ) -> list[RepoFinding]:
    root = src_root or _repo_src()
    em = _parse(root / "core" / "energy_model.py")
    ma = _parse(root / "core" / "model_api.py")
    ch = _parse(root / "core" / "characterize.py")

    fields = _class_fields(em, "PowerParams")
    fitted = set(_tuple_literal(ma, "_FITTED_FIELDS"))
    derived = _constructor_keywords(ch, "build_params", "PowerParams")
    v1 = _v1_anchor_fields(ma)

    findings = []
    for name, has_default in fields:
        if name not in fitted and name not in derived:
            findings.append(RepoFinding(
                "params-serialization-covered", ERROR,
                "core/energy_model.py", 1,
                f"PowerParams.{name} is neither serialized "
                f"(_FITTED_FIELDS) nor derived in characterize."
                f"build_params: save/load would drop it"))
        if name in fitted and name not in v1 and not has_default:
            findings.append(RepoFinding(
                "params-serialization-covered", ERROR,
                "core/energy_model.py", 1,
                f"PowerParams.{name} is serialized but post-v1 and has no "
                f"backfill default: legacy blobs would fail to load"))
    return findings


def run_repo_lint() -> list[RepoFinding]:
    """All five rules over the live repo tree."""
    sources = list(_iter_sources())
    findings = []
    findings += check_no_deprecated_shims(sources)
    findings += check_impls_declare_modes(sources)
    findings += check_fitters_declare_streaming(sources)
    findings += check_call_time_interpret()
    findings += check_params_serialization()
    return findings
