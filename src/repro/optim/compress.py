"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-row absmax quantization of gradients before the cross-replica
reduction, with a persistent error-feedback buffer so the quantization error
is re-injected the next step (Seide et al.-style EF-SGD generalization).
On a real pod this halves/quarters the reduce-scatter payload on the slow
cross-pod links; here we implement the transform + its invariants and expose
a shard_map-based reduction for the pod axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(x):
    """-> (int8 values, f32 row scales)."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-20)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, error_buf):
    """Quantize grads + accumulated error; return (q_tree, new_error_buf)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = compress(g)
        deq = decompress(q, s)
        return (q, s), g - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_buf)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    q_tree = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return q_tree, new_e


def decompress_tree(q_tree):
    return jax.tree_util.tree_map(
        lambda qs: decompress(*qs), q_tree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)


def init_error_buf(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def crosspod_compressed_psum(grads, axis_name: str):
    """Inside shard_map: quantize, all-reduce the int8 payload as f32 sums
    of dequantized values (collective payload stays int8 + tiny scales in a
    real implementation; XLA models the semantics here)."""
    def one(g):
        q, s = compress(g.astype(jnp.float32))
        return jax.lax.psum(decompress(q, s), axis_name)
    return jax.tree_util.tree_map(one, grads)
