"""AdamW in pure JAX pytrees, with optional int8-quantized moments.

The quantized variant stores both Adam moments as int8 with one f32 scale
per leading row (per-channel absmax), cutting optimizer-state memory 4x —
what lets jamba-1.5-large-398B train on 16 GiB chips (see sharding.rules).
Dequantize-update-requantize happens inside the jitted train step, so the
f32 moments never exist in HBM at rest.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    quantize_moments: bool = False

    def schedule(self, step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - self.warmup_steps)
                        / max(self.decay_steps - self.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * (0.1 + 0.9 * cos)


# ---------------------------------------------------------------------------
# int8 moment quantization
# ---------------------------------------------------------------------------
def _quantize(x):
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def init(params, cfg: AdamWConfig):
    def zeros_like_f32(p):
        return jnp.zeros(p.shape, jnp.float32)

    if cfg.quantize_moments:
        def qz(p):
            q = jnp.zeros(p.shape, jnp.int8)
            scale = jnp.zeros(p.shape[:-1] + (1,), jnp.float32) \
                if p.ndim else jnp.zeros((1,), jnp.float32)
            return {"q": q, "scale": scale}
        state = {"m": jax.tree_util.tree_map(qz, params),
                 "v": jax.tree_util.tree_map(qz, params)}
    else:
        state = {"m": jax.tree_util.tree_map(zeros_like_f32, params),
                 "v": jax.tree_util.tree_map(zeros_like_f32, params)}
    state["step"] = jnp.zeros((), jnp.int32)
    return state


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = cfg.schedule(step)
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        if cfg.quantize_moments:
            m_f = _dequantize(m["q"], m["scale"])
            # v is stored in sqrt-domain: int8 steps are uniform in
            # sqrt(v), so the relative error of the update denominator
            # sqrt(vhat) stays ~1/127 of the row max instead of blowing
            # up on small-v elements
            v_f = jnp.square(_dequantize(v["q"], v["scale"]))
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        mhat = m_f / bc1
        vhat = v_f / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_p = (p.astype(jnp.float32)
                 - lr * (delta + cfg.weight_decay * p.astype(jnp.float32)))
        if cfg.quantize_moments:
            mq, ms = _quantize(m_f)
            vq, vs = _quantize(jnp.sqrt(v_f))
            return new_p.astype(p.dtype), {"q": mq, "scale": ms}, \
                {"q": vq, "scale": vs}
        return new_p.astype(p.dtype), m_f, v_f

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics


def state_meta(param_meta, cfg: AdamWConfig):
    """ParamMeta tree for the optimizer state (for dry-run specs)."""
    from repro.models.meta import ParamMeta, is_meta

    def mom(m: ParamMeta):
        if cfg.quantize_moments:
            return {"q": ParamMeta(m.shape, m.logical, init="zeros",
                                   dtype=jnp.int8),
                    "scale": ParamMeta(m.shape[:-1] + (1,),
                                       m.logical[:-1] + (None,),
                                       init="zeros", dtype=jnp.float32)}
        return ParamMeta(m.shape, m.logical, init="zeros",
                         dtype=jnp.float32)

    m_tree = jax.tree_util.tree_map(mom, param_meta, is_leaf=is_meta)
    return {"m": m_tree, "v": m_tree,
            "step": ParamMeta((), (), init="zeros", dtype=jnp.int32)}
