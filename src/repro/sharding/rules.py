"""Logical-axis -> mesh-axis rules for every parallelism mode.

The production meshes are (data=16, model=16) per pod and
(pod=2, data=16, model=16) across pods. Parallelism is selected by rules,
not by model changes:

* TP       : "heads_dh"/"kv_dh"/"ffn"/"vocab" -> "model"
* EP       : "experts" -> "model" (expert weights sharded; tokens gathered)
* DP       : the "batch" activation axis -> ("pod", "data")
* FSDP     : "embed" -> "data" (ZeRO-3-style parameter+optimizer sharding
             within a pod; replicated across pods for cheap cross-pod DP)
* SP       : sequence activation axis -> "model" at norm boundaries
* KV-shard : decode caches' "kv_seq" -> "data" when the batch is too small
             to occupy the data axis (long-context decode)
"""
from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.meta import ShardingRules


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def make_rules(cfg: ModelConfig, *, multi_pod: bool = False,
               fsdp: bool = False, kv_seq_axis=None) -> ShardingRules:
    rules = {
        "vocab": "model",
        "embed": "data" if fsdp else None,
        "heads_dh": "model",   # fused (heads * d_head) projection dim
        "kv_dh": "model",
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "experts": "model",
        "layers": None,
        "batch": list(batch_axes(multi_pod)),
        "kv_seq": kv_seq_axis,
    }
    return ShardingRules(rules)


def wants_fsdp(cfg: ModelConfig) -> bool:
    """Full parameter+optimizer data-axis sharding: only the very largest
    models (bf16 weights alone would not fit TP-replicated)."""
    return cfg.n_params_estimate > 5.0e10


def wants_zero1(cfg: ModelConfig) -> bool:
    """ZeRO-1 (optimizer-state-only data sharding + interior activation
    pin): mid-size models whose f32 Adam moments overflow under TP-only
    sharding but whose bf16 weights fit replicated. Measured strictly
    better than FSDP on this mesh (EXPERIMENTS.md §Perf H1: 5.6x fewer
    collective bytes on yi-34b)."""
    return 9.0e9 < cfg.n_params_estimate <= 5.0e10


def wants_quantized_moments(cfg: ModelConfig) -> bool:
    """int8 Adam moments for the very largest models (jamba-398B)."""
    return cfg.n_params_estimate > 1.5e11


def batch_spec(multi_pod: bool, extra_dims: int = 1) -> P:
    return P(batch_axes(multi_pod), *([None] * extra_dims))


@dataclasses.dataclass(frozen=True)
class CellPlan:
    """Everything the dry-run / launchers need for one (arch x shape)."""
    rules: ShardingRules
    fsdp: bool
    quantized_moments: bool
    multi_pod: bool
    microbatches: int = 1
    # ZeRO-1: optimizer state sharded over data, weights only TP-sharded
    # (kills the per-microbatch FSDP weight all-gathers at the cost of
    # replicated bf16 weights). Set by hillclimb variants.
    zero1: bool = False

    def opt_rules(self, cfg, multi_pod: bool):
        if not self.zero1:
            return self.rules
        return make_rules(cfg, multi_pod=multi_pod, fsdp=True,
                          kv_seq_axis=self.rules.rules.get("kv_seq"))

    def data_shards(self, mesh) -> int:
        shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        n = shape.get("data", 1)
        if self.multi_pod:
            n *= shape.get("pod", 1)
        return n


def plan_for(cfg: ModelConfig, shape_kind: str, global_batch: int, mesh,
             multi_pod: bool, seq_len: int = 0) -> CellPlan:
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data = mesh_shape.get("data", 1) * (mesh_shape.get("pod", 1)
                                          if multi_pod else 1)
    # Training: FSDP only for the very largest models (ZeRO-1 is measured
    # better in the 9-50B range). Serving: weight data-sharding is ~free
    # (decode/prefill re-read weights every step regardless) and buys the
    # memory back, so apply it from 9B up.
    if shape_kind == "train":
        fsdp = wants_fsdp(cfg)
        zero1 = wants_zero1(cfg)
    else:
        fsdp = cfg.n_params_estimate > 9.0e9
        zero1 = False
    # Decode KV caches shard their sequence dim over "model" (KV heads are
    # usually < 16 and would otherwise replicate); with an unshardable tiny
    # batch (long-context, B=1), also spread the sequence over "data".
    kv_seq_axis = None
    if shape_kind == "decode":
        kv_seq_axis = (["data", "model"]
                       if global_batch % n_data != 0 else "model")
    # microbatch count: keep per-device saved-activation stacks ~<= 4 GiB
    micro = 1
    if shape_kind == "train" and seq_len:
        b_loc = max(global_batch // n_data, 1)
        stack = b_loc * seq_len * cfg.d_model * 2 * cfg.n_layers
        while micro < b_loc and stack / micro > 4e9:
            micro *= 2
    return CellPlan(
        rules=make_rules(cfg, multi_pod=multi_pod, fsdp=fsdp,
                         kv_seq_axis=kv_seq_axis),
        fsdp=fsdp,
        quantized_moments=wants_quantized_moments(cfg),
        multi_pod=multi_pod,
        microbatches=micro,
        zero1=zero1)
