"""Shared Pallas kernel plumbing.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with ``interpret=True``, which executes the kernel body in
Python. ``INTERPRET`` flips automatically off-TPU; set REPRO_PALLAS_INTERPRET
to force either way.
"""
from __future__ import annotations

import os

import jax

_env = os.environ.get("REPRO_PALLAS_INTERPRET")
if _env is not None:
    INTERPRET = _env not in ("0", "false", "False")
else:
    INTERPRET = jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x, multiple: int, axis: int = 0, value=0):
    """Pad axis up to a multiple (kernels require whole blocks)."""
    import jax.numpy as jnp
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n
