"""Shared Pallas kernel plumbing.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated everywhere else with ``interpret=True``, which executes the kernel
body in Python.  :func:`interpret_default` resolves the mode *per call* from
``jax.default_backend()`` — compiled on TPU, interpreted on CPU/GPU — so the
kernels are runnable on any backend without a hand-set flag, and a backend
selected after import (tests, ``jax.config`` changes) is still honoured.
Set ``REPRO_PALLAS_INTERPRET`` to force either way (the CI pallas-interpret
job exports ``REPRO_PALLAS_INTERPRET=1``).
"""
from __future__ import annotations

import os

import jax


def interpret_default() -> bool:
    """Whether a kernel launched *now* should run in interpret mode:
    the ``REPRO_PALLAS_INTERPRET`` env override if set, else compiled on
    TPU and interpreted everywhere else."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x, multiple: int, axis: int = 0, value=0):
    """Pad axis up to a multiple (kernels require whole blocks)."""
    import jax.numpy as jnp
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n
