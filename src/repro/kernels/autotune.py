"""Kernel-grid autotuner for the ``(vendors, traces, blocks)`` families.

The fused kernels (``vampire_energy``, ``baseline_energy``) historically
launched with one hand-set command-axis block size (``BLOCK_N = 512``) and
one grid layout (vendor-major).  Neither was ever tuned: the best block
depends on the backend's VMEM/cache geometry and on how much of the padded
command axis a trace actually fills, and the best grid-major order depends
on which operand (the per-vendor parameter blocks vs the per-trace feature
planes) is cheaper to keep resident across consecutive grid cells.

This module is the small registry the dispatch paths consult:

* :func:`best_config` — the committed winner for the current
  ``(backend, family, shape-bucket)``, falling back to the historical
  defaults when the table has no entry.  Consulted by the
  ``resolve_impl``-dispatched assemblers (``kernels/*/ops.py``) whenever
  the caller does not pin ``block_n``/``grid_layout`` explicitly.
* :func:`sweep` — time a family's dispatch over the candidate
  (block, layout) grid for a set of shapes and return the winners.
  In interpret mode (any non-TPU/GPU backend without an override) every
  grid cell is a Python-loop iteration, so the candidate set is capped to
  the large blocks — the sweep is exempt from being a real tuning pass
  there and exists to keep CI time bounded while still recording choices.
* :func:`update_table` — merge sweep winners into the committed JSON
  table (``kernels/autotune_table.json``); ``python -m
  repro.kernels.autotune`` regenerates the current backend's entries.

The winners are cached per (backend, shape-bucket): shapes bucket to
powers of two, exactly like the serving ring's pad-shape vocabulary, so a
handful of table rows covers every production launch and ``block_n``
stays a static jit argument with a bounded number of distinct values.
"""
from __future__ import annotations

import functools
import json
import os
import pathlib
import time

import jax

from repro.kernels.common import interpret_default

TABLE_PATH = pathlib.Path(__file__).with_name("autotune_table.json")

#: command-axis block candidates (powers of two bracketing the historical
#: hand-set default)
CANDIDATE_BLOCKS = (128, 256, 512, 1024)
#: interpret-mode cap: each grid cell is a Python iteration, so small
#: blocks multiply wall-clock superlinearly — only the coarse blocks are
#: worth timing there
COARSE_BLOCKS = (512, 1024)
#: grid-major orders: vendor-major (parameters resident across traces) vs
#: trace-major (feature planes resident across vendors)
CANDIDATE_LAYOUTS = ("vti", "tvi")

#: the tuned dispatch families and their historical defaults
FAMILIES = ("vampire_energy", "baseline_energy")
DEFAULT_BLOCK = 512
DEFAULT_LAYOUT = "vti"


def backend_key() -> str:
    """The table's backend partition: the raw backend name for compiled
    launches, ``<backend>-interpret`` under the Pallas interpreter — the
    interpreter's cost model (Python loop over grid cells) is unrelated to
    the compiled one, so winners never cross-contaminate."""
    backend = jax.default_backend()
    return f"{backend}-interpret" if interpret_default() else backend


def shape_bucket(n_traces: int, n_cmds: int) -> str:
    """Power-of-two shape bucket, e.g. ``t32n4096`` — the same rounding
    the serving ring applies to pad shapes, so one table row covers every
    launch that lands in the bucket."""
    def up(v: int) -> int:
        return 1 << max(int(v) - 1, 0).bit_length()
    return f"t{up(n_traces)}n{up(n_cmds)}"


@functools.lru_cache(maxsize=1)
def _table() -> dict:
    try:
        with open(TABLE_PATH) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def reload_table() -> None:
    """Drop the cached table (tests / post-``update_table`` refresh)."""
    _table.cache_clear()


def best_config(family: str, n_traces: int, n_cmds: int) -> dict:
    """The tuned ``{"block_n": int, "layout": str}`` for this
    (backend, family, shape bucket), or the historical defaults when the
    committed table has no entry.  ``REPRO_AUTOTUNE=0`` disables the
    lookup entirely (pure defaults, e.g. for A/B timing the tuner)."""
    cfg = {"block_n": DEFAULT_BLOCK, "layout": DEFAULT_LAYOUT}
    if os.environ.get("REPRO_AUTOTUNE", "1") in ("0", "false", "False"):
        return cfg
    entry = (_table().get(backend_key(), {}).get(family, {})
             .get(shape_bucket(n_traces, n_cmds)))
    if entry:
        cfg["block_n"] = int(entry.get("block_n", DEFAULT_BLOCK))
        cfg["layout"] = str(entry.get("layout", DEFAULT_LAYOUT))
    return cfg


def choices(families=FAMILIES) -> dict:
    """The current backend's committed winners per family (for the bench
    artifacts to record alongside their timings)."""
    sub = _table().get(backend_key(), {})
    return {f: sub.get(f, {}) for f in families}


def candidate_space() -> tuple[tuple[int, ...], tuple[str, ...]]:
    """(blocks, layouts) to sweep on the current backend: the full grid on
    compiled backends, the interpret-exempt cap elsewhere (layout is
    meaningless to the interpreter's Python loop, so only the default is
    timed)."""
    if interpret_default():
        return COARSE_BLOCKS, (DEFAULT_LAYOUT,)
    return CANDIDATE_BLOCKS, CANDIDATE_LAYOUTS


def sweep(family: str, run_fn, shapes, blocks=None, layouts=None,
          repeats: int = 3) -> dict:
    """Time ``run_fn(n_traces, n_cmds, block_n, layout)`` over the
    candidate space for each ``(n_traces, n_cmds)`` shape.

    Returns ``{bucket: {"block_n", "layout", "us", "candidates_us"}}`` for
    the current backend.  ``run_fn`` must block on its result (the sweep
    calls ``jax.block_until_ready`` around it regardless) and is invoked
    once untimed per candidate to absorb compilation."""
    if blocks is None or layouts is None:
        auto_blocks, auto_layouts = candidate_space()
        blocks = auto_blocks if blocks is None else blocks
        layouts = auto_layouts if layouts is None else layouts
    out = {}
    for n_traces, n_cmds in shapes:
        timings = {}
        for layout in layouts:
            for block in blocks:
                jax.block_until_ready(
                    run_fn(n_traces, n_cmds, block, layout))   # compile
                best_s = float("inf")
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    jax.block_until_ready(
                        run_fn(n_traces, n_cmds, block, layout))
                    best_s = min(best_s, time.perf_counter() - t0)
                timings[f"{layout}/b{block}"] = best_s * 1e6
        win = min(timings, key=timings.get)
        layout, block = win.split("/b")
        out[shape_bucket(n_traces, n_cmds)] = {
            "block_n": int(block), "layout": layout,
            "us": timings[win],
            "candidates_us": {k: round(v, 1) for k, v in timings.items()},
        }
    return out


def update_table(family: str, entries: dict, path=TABLE_PATH) -> dict:
    """Merge sweep winners for the current backend into the committed
    table and rewrite it (winners only — the per-candidate timings stay in
    the bench artifacts).  Returns the merged table."""
    try:
        with open(path) as f:
            table = json.load(f)
    except (OSError, ValueError):
        table = {}
    rows = table.setdefault(backend_key(), {}).setdefault(family, {})
    for bucket, entry in entries.items():
        rows[bucket] = {"block_n": int(entry["block_n"]),
                        "layout": str(entry["layout"])}
    with open(path, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
        f.write("\n")
    reload_table()
    return table


# ---------------------------------------------------------------------------
# Maintenance CLI: regenerate the current backend's table entries against
# the real dispatch paths (synthetic traces, vendor-true parameters).
# ---------------------------------------------------------------------------
def _family_runners():
    """family -> ``run_fn(n_traces, n_cmds, block_n, layout)`` over the
    production assemblers, memoizing the probe inputs per shape."""
    import jax.numpy as jnp

    from repro.core import device_sim, idd_loops
    from repro.core import params as P
    from repro.core.baselines_power import BASELINE_IDD_KEYS
    from repro.core.estimate_batch import TraceBatch
    from repro.core.fleet import stack_params
    from repro.kernels.baseline_energy import ops as bops
    from repro.kernels.vampire_energy import ops as vops

    stacked = stack_params([device_sim.true_vendor_params(v)
                            for v in range(3)])
    table = jnp.asarray(
        [[float(P.MEASURED_IDD.get(k, (100.0, 100.0, 100.0))[v])
          for k in BASELINE_IDD_KEYS] for v in range(3)], jnp.float32)

    @functools.lru_cache(maxsize=8)
    def batch(n_traces: int, n_cmds: int) -> TraceBatch:
        reps = n_cmds // 10 + 1          # validation_sweep(8): 10 cmds/rep
        trs = [idd_loops.validation_sweep(8, reps=reps)
               for _ in range(n_traces)]
        tb = TraceBatch.from_traces(trs)
        trace = jax.tree_util.tree_map(lambda x: x[:, :n_cmds], tb.trace)
        return TraceBatch(trace, tb.weight[:, :n_cmds].astype(jnp.float32))

    def vampire_run(n_traces, n_cmds, block_n, layout):
        tb = batch(n_traces, n_cmds)
        return vops.batched_charge_matrix(tb.trace, tb.weight, stacked,
                                          block_n=block_n,
                                          grid_layout=layout)

    def baseline_run(n_traces, n_cmds, block_n, layout):
        tb = batch(n_traces, n_cmds)
        return bops.baseline_charge_matrix(tb.trace, tb.weight, table,
                                           "micron", block_n=block_n,
                                           grid_layout=layout)

    return {"vampire_energy": vampire_run, "baseline_energy": baseline_run}


def main(argv=None) -> int:  # pragma: no cover - maintenance entry point
    import argparse
    ap = argparse.ArgumentParser(prog="python -m repro.kernels.autotune",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--shapes", default="8x1024,32x1024,128x4096",
                    help="comma-separated TRACESxCOMMANDS probe shapes")
    ap.add_argument("--dry-run", action="store_true",
                    help="print winners without rewriting the table")
    args = ap.parse_args(argv)
    shapes = [tuple(int(v) for v in s.split("x"))
              for s in args.shapes.split(",")]
    for family, run_fn in _family_runners().items():
        winners = sweep(family, run_fn, shapes)
        for bucket, entry in winners.items():
            print(f"{backend_key()}/{family}/{bucket}: "
                  f"block_n={entry['block_n']} layout={entry['layout']} "
                  f"({entry['us']:.0f}us)")
        if not args.dry_run:
            update_table(family, winners)
    return 0


if __name__ == "__main__":  # pragma: no cover
    import sys
    sys.exit(main())
