"""Pallas TPU kernel: blockwise (flash) attention with online softmax.

Used by the framework's long-context paths (prefill_32k / long_500k shapes),
where materializing (S, S) scores is impossible. Grid = (batch*q_heads,
q_blocks, kv_blocks); the TPU executes the last grid axis sequentially, so
the running max / normalizer / accumulator live in VMEM scratch across the
kv sweep and the output is finalized on the last kv block.

GQA is handled in the index maps: kv tensors are indexed by
``head // group_size``, so grouped K/V are never materialized per-q-head.

Shapes: q (BH, S_q, D), k/v (BH_kv, S_kv, D) -> out (BH, S_q, D).
Causal masking compares global q/k positions built from program ids.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, interpret_default

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            sm_scale: float, causal: bool, block_q: int, block_k: int,
            kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (BQ, D)
    k = k_ref[0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0].astype(jnp.float32)          # (BK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]                        # (BQ, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                     # (BQ, BK)
    alpha = jnp.exp(m_prev - m_new)            # (BQ, 1)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "sm_scale", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           sm_scale: float | None = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool | None = None):
    """q (BH, Sq, D); k, v (BH_kv, Skv, D) with BH % BH_kv == 0."""
    if interpret is None:
        interpret = interpret_default()
    bh, sq, d = q.shape
    bh_kv, skv, _ = k.shape
    assert bh % bh_kv == 0, (bh, bh_kv)
    group = bh // bh_kv
    if sm_scale is None:
        sm_scale = d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq, nk = cdiv(sq, block_q), cdiv(skv, block_k)
    assert sq % block_q == 0 and skv % block_k == 0, "pad seq to block size"

    from jax.experimental.pallas import tpu as pltpu
    kernel = functools.partial(
        _kernel, sm_scale=float(sm_scale), causal=causal,
        block_q=block_q, block_k=block_k, kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, qi, ki, group=group: (b // group, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, qi, ki, group=group: (b // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, 1), jnp.float32),
                        pltpu.VMEM((block_q, 1), jnp.float32),
                        pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
