"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, sm_scale=None):
    """q (BH, Sq, D); k, v (BH_kv, Skv, D). Plain softmax attention."""
    bh, sq, d = q.shape
    bh_kv = k.shape[0]
    group = bh // bh_kv
    if sm_scale is None:
        sm_scale = d ** -0.5
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * sm_scale
    if causal:
        qi = jnp.arange(sq)[:, None]
        ki = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(qi >= ki, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)
