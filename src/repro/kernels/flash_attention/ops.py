"""Jitted public wrappers for flash attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas)


@functools.partial(jax.jit, static_argnames=("causal", "use_kernel",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, use_kernel: bool = True,
                    block_q: int = 256, block_k: int = 256):
    """Blockwise attention; q (BH, Sq, D), k/v (BH_kv, Skv, D)."""
    if use_kernel:
        return flash_attention_pallas(q, k, v, causal=causal,
                                      block_q=block_q, block_k=block_k)
    return ref.attention_ref(q, k, v, causal=causal)
