"""Jitted assembler for the fused baseline (Micron / DRAMPower) path:
builds the per-command structural planes from a padded TraceBatch and runs
the (vendors, traces, blocks)-gridded baseline energy kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dram import ACT, N_BANKS, N_ROW_BANDS, RD, REF, WR, \
    CommandTrace
from repro.core.energy_model import (N_SURFACE_CELLS, structural_state,
                                     surface_cells, surface_cycles)
from repro.kernels.baseline_energy.baseline_energy import (
    BLOCK_N, baseline_energy_pallas)
from repro.kernels.common import interpret_default


@functools.partial(jax.jit,
                   static_argnames=("kind", "surface", "block_n",
                                    "interpret", "grid_layout"))
def _charge_matrix(trace: CommandTrace, weight, table, kind: str,
                   surface: bool, block_n: int, interpret: bool,
                   grid_layout: str):
    st = jax.vmap(structural_state)(trace)
    planes = {
        "dt": trace.dt.astype(jnp.float32),
        "is_rd": (trace.cmd == RD).astype(jnp.float32),
        "is_wr": (trace.cmd == WR).astype(jnp.float32),
        "is_act": (trace.cmd == ACT).astype(jnp.float32),
        "is_ref": (trace.cmd == REF).astype(jnp.float32),
        "open_banks": jnp.sum(st.open_before.astype(jnp.float32), axis=2),
        "pd": st.bg_state.astype(jnp.float32),
        "w": weight.astype(jnp.float32),
    }
    any_act = jnp.any(trace.cmd == ACT, axis=1).astype(jnp.float32)
    if surface:
        t = trace.cmd.shape[0]
        cells = jax.vmap(surface_cells)(trace)                   # (T, N)
        cell_t = jax.nn.one_hot(cells, N_SURFACE_CELLS,
                                dtype=jnp.float32).transpose(0, 2, 1)
        charge = baseline_energy_pallas(kind, planes, any_act, table,
                                        block_n=block_n,
                                        interpret=interpret, cell_t=cell_t,
                                        grid_layout=grid_layout)
        return (charge.reshape(t, -1, N_BANKS, N_ROW_BANDS),
                jax.vmap(surface_cycles)(trace, weight))
    charge = baseline_energy_pallas(kind, planes, any_act, table,
                                    block_n=block_n, interpret=interpret,
                                    grid_layout=grid_layout)
    cycles = jnp.sum(trace.dt * weight.astype(jnp.int32), axis=1,
                     dtype=jnp.int32)
    return charge, cycles


def baseline_charge_matrix(trace: CommandTrace, weight, table, kind: str, *,
                           surface: bool = False, block_n: int | None = None,
                           interpret: bool | None = None,
                           grid_layout: str | None = None):
    """Masked charge of every (trace, vendor) pair for one baseline kind
    -> ``((T, V) charge in mA*cycles, (T,) masked cycles)``, or with
    ``surface=True`` the per-(bank, row-band) structural decomposition
    ``((T, V, 8, N_ROW_BANDS) charge, (T, 8, N_ROW_BANDS) cycles)``.
    ``block_n``/``grid_layout`` default to the autotuner's committed
    winner for this (backend, shape-bucket)
    (``kernels.autotune.best_config``)."""
    if interpret is None:
        interpret = interpret_default()
    if block_n is None or grid_layout is None:
        from repro.kernels import autotune
        cfg = autotune.best_config("baseline_energy", trace.cmd.shape[0],
                                   trace.cmd.shape[1])
        block_n = cfg["block_n"] if block_n is None else block_n
        grid_layout = (cfg["layout"] if grid_layout is None
                       else grid_layout)
    return _charge_matrix(trace, weight, table, kind, surface, block_n,
                          interpret, grid_layout)
