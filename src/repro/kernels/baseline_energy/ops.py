"""Jitted assembler for the fused baseline (Micron / DRAMPower) path:
builds the per-command structural planes from a padded TraceBatch and runs
the (vendors, traces, blocks)-gridded baseline energy kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dram import ACT, RD, REF, WR, CommandTrace
from repro.core.energy_model import structural_state
from repro.kernels.baseline_energy.baseline_energy import (
    BLOCK_N, baseline_energy_pallas)
from repro.kernels.common import interpret_default


@functools.partial(jax.jit,
                   static_argnames=("kind", "block_n", "interpret"))
def _charge_matrix(trace: CommandTrace, weight, table, kind: str,
                   block_n: int, interpret: bool):
    st = jax.vmap(structural_state)(trace)
    planes = {
        "dt": trace.dt.astype(jnp.float32),
        "is_rd": (trace.cmd == RD).astype(jnp.float32),
        "is_wr": (trace.cmd == WR).astype(jnp.float32),
        "is_act": (trace.cmd == ACT).astype(jnp.float32),
        "is_ref": (trace.cmd == REF).astype(jnp.float32),
        "open_banks": jnp.sum(st.open_before.astype(jnp.float32), axis=2),
        "pd": st.powered_down.astype(jnp.float32),
        "w": weight.astype(jnp.float32),
    }
    any_act = jnp.any(trace.cmd == ACT, axis=1).astype(jnp.float32)
    charge = baseline_energy_pallas(kind, planes, any_act, table,
                                    block_n=block_n, interpret=interpret)
    cycles = jnp.sum(trace.dt * weight.astype(jnp.int32), axis=1,
                     dtype=jnp.int32)
    return charge, cycles


def baseline_charge_matrix(trace: CommandTrace, weight, table, kind: str, *,
                           block_n: int = BLOCK_N,
                           interpret: bool | None = None):
    """Masked charge of every (trace, vendor) pair for one baseline kind
    -> ``((T, V) charge in mA*cycles, (T,) masked cycles)``."""
    if interpret is None:
        interpret = interpret_default()
    return _charge_matrix(trace, weight, table, kind, block_n, interpret)
