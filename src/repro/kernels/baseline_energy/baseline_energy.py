"""Pallas TPU kernel: fused (traces x vendors) datasheet-baseline energy.

The ``impl='pallas'`` path for the Micron-calculator and DRAMPower
estimators (``repro.core.baselines_power``).  Both physics are pure
per-command formulas over the shared structural facts (open-bank count,
power-down state) and a per-vendor datasheet IDD row, so one kernel body
per baseline, gridded over ``(vendors, traces, command blocks)`` exactly
like the VAMPIRE energy kernel, covers the whole report matrix: per grid
cell it reads one (1, BLOCK) slab of per-command planes plus this vendor's
(1, K) IDD row and writes one masked partial charge sum.

IDD row layout follows ``baselines_power.BASELINE_IDD_KEYS``:
``(IDD0, IDD2N, IDD2P1, IDD3N, IDD4R, IDD4W, IDD5B, IDD2P0, IDD3P,
IDD6)`` — the low-power keys appended at the end.  The ``pd`` plane
carries the background-state code (``energy_model.BG_*``: 0 active,
1 fast PDN, 2 slow PDN, 3 active PDN, 4 self-refresh) as f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.baselines_power import act_pair_charge
from repro.core.dram import TIMING
from repro.core.energy_model import N_SURFACE_CELLS
from repro.kernels.common import cdiv, interpret_default, pad_to

BLOCK_N = 512
_T = TIMING

# per-command (T, N) planes, in kernel argument order
PLANES = ("dt", "is_rd", "is_wr", "is_act", "is_ref", "open_banks", "pd", "w")


def _masked_charge(kind: str, dt, is_rd, is_wr, is_act, is_ref, open_banks,
                   pd, w, any_act, idd):
    """The fused per-command baseline charge body shared by the scalar-sum
    and the surface-cell kernels.  Returns the masked (B,) charge vector
    in mA*cycles."""
    idd0, idd2n, idd2p1, idd3n = idd[0], idd[1], idd[2], idd[3]
    idd4r, idd4w, idd5b = idd[4], idd[5], idd[6]
    idd2p0, idd3p, idd6 = idd[7], idd[8], idd[9]

    # state-code LUT over the ``pd`` plane — the kernel twin of
    # ``baselines_power._bg_lut``
    i_low = jnp.where(pd == 1.0, idd2p1,
                      jnp.where(pd == 2.0, idd2p0,
                                jnp.where(pd == 3.0, idd3p, idd6)))
    active = (pd == 0.0).astype(jnp.float32)

    burst = jnp.minimum(dt, float(_T.tBURST))
    q_act = act_pair_charge(idd0, idd2n, idd3n)
    if kind == "micron":
        # worst-case background, spec-rate ACT/PRE, RD/WR stacked on top
        i_bg = jnp.where(pd == 0.0, idd3n, i_low)
        charge = i_bg * dt
        charge = charge + active * any_act * q_act * dt / _T.tRC
        charge = charge + is_rd * idd4r * burst + is_wr * idd4w * burst
    else:                             # drampower: actual timing
        i_bg = jnp.where(
            pd == 0.0, idd2n + (idd3n - idd2n) * open_banks / 8.0, i_low)
        charge = i_bg * dt
        charge = charge + is_act * q_act
        charge = charge + is_rd * (idd4r - i_bg) * burst
        charge = charge + is_wr * (idd4w - i_bg) * burst
    charge = charge + is_ref * (idd5b - idd2n) * _T.tRFC
    return charge * w


def _make_kernel(kind: str):
    def kernel(dt_ref, isrd_ref, iswr_ref, isact_ref, isref_ref, open_ref,
               pd_ref, w_ref, anyact_ref, idd_ref, o_ref):
        cw = _masked_charge(kind, dt_ref[0], isrd_ref[0], iswr_ref[0],
                            isact_ref[0], isref_ref[0], open_ref[0],
                            pd_ref[0], w_ref[0], anyact_ref[0], idd_ref[0])
        o_ref[0, 0, 0] = jnp.sum(cw)
    return kernel


def _make_surface_kernel(kind: str):
    def kernel(dt_ref, isrd_ref, iswr_ref, isact_ref, isref_ref, open_ref,
               pd_ref, w_ref, cell_ref, anyact_ref, idd_ref, o_ref):
        cw = _masked_charge(kind, dt_ref[0], isrd_ref[0], iswr_ref[0],
                            isact_ref[0], isref_ref[0], open_ref[0],
                            pd_ref[0], w_ref[0], anyact_ref[0], idd_ref[0])
        # (bank, row-band) cell reduction over the one-hot cell plane
        o_ref[0, 0, 0, :] = jnp.sum(cell_ref[0] * cw[None, :], axis=1)
    return kernel


_KERNELS = {kind: _make_kernel(kind) for kind in ("micron", "drampower")}
_SURFACE_KERNELS = {kind: _make_surface_kernel(kind)
                    for kind in ("micron", "drampower")}


def baseline_energy_pallas(kind: str, planes: dict, any_act, table,
                           block_n: int = BLOCK_N,
                           interpret: bool | None = None,
                           cell_t=None,
                           grid_layout: str = "vti") -> jax.Array:
    """(T, V) masked charge matrix of one baseline physics.  ``planes``
    maps :data:`PLANES` to (T, N) f32 arrays; ``any_act`` is (T,) f32;
    ``table`` is the stacked (V, K) datasheet matrix.  Passing ``cell_t``
    (the (T, CELLS, N) one-hot structural cell plane) switches to the
    surface kernel and returns the (T, V, CELLS) charge decomposition.
    ``grid_layout`` picks the grid-major order (vendor- vs trace-
    outermost, ``kernels.vampire_energy._grid_maps``) — pure scheduling,
    identical partial sums either way."""
    from repro.kernels.vampire_energy.vampire_energy import _grid_maps
    if interpret is None:
        interpret = interpret_default()
    padded = {}
    for name in PLANES:
        padded[name], _ = pad_to(planes[name].astype(jnp.float32),
                                 block_n, axis=1)
    n_traces, n_pad = padded["dt"].shape
    n_vendors, n_keys = table.shape
    grid_n = cdiv(n_pad, block_n)
    grid, as_map = _grid_maps(grid_layout, n_vendors, n_traces, grid_n)

    spec_2d = pl.BlockSpec((1, block_n), as_map(lambda v, t, i: (t, i)))
    tail_specs = [pl.BlockSpec((1,), as_map(lambda v, t, i: (t,))),
                  pl.BlockSpec((1, n_keys), as_map(lambda v, t, i: (v, 0)))]
    args = [padded[n] for n in PLANES]
    if cell_t is None:
        kernel, cell_specs = _KERNELS[kind], []
        out_spec = pl.BlockSpec((1, 1, 1), as_map(lambda v, t, i: (v, t, i)))
        out_shape = jax.ShapeDtypeStruct((n_vendors, n_traces, grid_n),
                                         jnp.float32)
    else:
        kernel = _SURFACE_KERNELS[kind]
        padded_cell, _ = pad_to(cell_t.astype(jnp.float32), block_n, axis=2)
        args.append(padded_cell)
        cell_specs = [pl.BlockSpec((1, N_SURFACE_CELLS, block_n),
                                   as_map(lambda v, t, i: (t, 0, i)))]
        out_spec = pl.BlockSpec((1, 1, 1, N_SURFACE_CELLS),
                                as_map(lambda v, t, i: (v, t, i, 0)))
        out_shape = jax.ShapeDtypeStruct(
            (n_vendors, n_traces, grid_n, N_SURFACE_CELLS), jnp.float32)
    partial = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec_2d] * len(PLANES) + cell_specs + tail_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*args, any_act.astype(jnp.float32), table.astype(jnp.float32))
    if cell_t is None:
        return jnp.sum(partial, axis=2).T                # (T, V)
    return jnp.sum(partial, axis=2).transpose(1, 0, 2)   # (T, V, CELLS)
