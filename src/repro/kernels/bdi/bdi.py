"""Pallas TPU kernel: Base-Delta-Immediate compressibility detection.

Computes, per 64-byte line, the best BDI scheme and its encoded size — the
hot inner loop when scanning large tensors/traces for compressibility
(Section 10's BDI encoding; full byte packing happens offline in
``repro.core.encodings``, which this kernel must agree with bit-exactly).

Scheme ids: 0=raw(64 B) 1=zeros(1) 2=rep8(8) 3=b8d1(16) 4=b8d2(24)
5=b8d4(40) 6=rep4(4) 7=b4d1(20) 8=b4d2(36) 9=rep2(2) 10=b2d1(34)

Input  bytes (N, 64) int32 (values 0..255)
Output sizes (N,) int32, schemes (N,) int32

Arithmetic notes (TPU lanes are 32-bit):
* 2-byte bases: sign-extended into int32, exact signed deltas.
* 4-byte bases: int32 subtraction with explicit signed-overflow detection
  (overflowing deltas cannot fit any 1/2-byte range).
* 8-byte bases: two uint32 limbs with borrow; matches the oracle's int64
  mod-2^64 semantics limb-for-limb.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, interpret_default, pad_to

BLOCK_N = 512

SCHEME_SIZES = {0: 64, 1: 1, 2: 8, 3: 16, 4: 24, 5: 40, 6: 4, 7: 20,
                8: 36, 9: 2, 10: 34}


def _take(cond, size, scheme, bs, bsch):
    upd = cond & (size < bs)
    return jnp.where(upd, size, bs), jnp.where(upd, scheme, bsch)


def _kernel(b_ref, size_ref, scheme_ref):
    by = b_ref[...]                                   # (BN, 64) int32
    n = by.shape[0]
    best_size = jnp.full((n,), 64, dtype=jnp.int32)
    best_scheme = jnp.zeros((n,), dtype=jnp.int32)

    zeros = jnp.all(by == 0, axis=1)
    best_size, best_scheme = _take(zeros, 1, 1, best_size, best_scheme)

    # ---- 8-byte bases: two uint32 limbs ---------------------------------
    byu = by.astype(jnp.uint32)
    lo8 = (byu[:, 0::8] | (byu[:, 1::8] << 8) | (byu[:, 2::8] << 16)
           | (byu[:, 3::8] << 24))                    # (BN, 8)
    hi8 = (byu[:, 4::8] | (byu[:, 5::8] << 8) | (byu[:, 6::8] << 16)
           | (byu[:, 7::8] << 24))
    d_lo = lo8 - lo8[:, :1]
    borrow = (lo8 < lo8[:, :1]).astype(jnp.uint32)
    d_hi = hi8 - hi8[:, :1] - borrow
    rep8 = jnp.all((d_lo == 0) & (d_hi == 0), axis=1)
    best_size, best_scheme = _take(rep8, 8, 2, best_size, best_scheme)
    ffff = jnp.uint32(0xFFFFFFFF)
    for db, scheme in ((1, 3), (2, 4), (4, 5)):
        if db < 4:
            half = jnp.uint32(1 << (8 * db - 1))
            pos = (d_hi == 0) & (d_lo < half)
            neg = (d_hi == ffff) & (d_lo >= (jnp.uint32(0) - half))
        else:
            pos = (d_hi == 0) & (d_lo < jnp.uint32(0x80000000))
            neg = (d_hi == ffff) & (d_lo >= jnp.uint32(0x80000000))
        fits = jnp.all(pos | neg, axis=1)
        best_size, best_scheme = _take(fits & ~rep8, 8 + 8 * db, scheme,
                                       best_size, best_scheme)

    # ---- 4-byte bases: int32 with overflow detection ---------------------
    v4 = (lo8.reshape(n, 8, 1), hi8.reshape(n, 8, 1))
    v4 = jnp.concatenate(v4, axis=2).reshape(n, 16).astype(jnp.int32)
    b4 = v4[:, :1]
    d4 = v4 - b4                                      # wraps on overflow
    ovf = ((v4 < 0) != (b4 < 0)) & ((d4 < 0) == (b4 < 0))
    rep4 = jnp.all((d4 == 0) & ~ovf, axis=1)
    best_size, best_scheme = _take(rep4, 4, 6, best_size, best_scheme)
    for db, scheme in ((1, 7), (2, 8)):
        half = 1 << (8 * db - 1)
        fits = jnp.all(~ovf & (d4 >= -half) & (d4 < half), axis=1)
        best_size, best_scheme = _take(fits & ~rep4, 4 + 16 * db, scheme,
                                       best_size, best_scheme)

    # ---- 2-byte bases: exact in int32 -------------------------------------
    v2 = (by[:, 0::2] | (by[:, 1::2] << 8)).astype(jnp.int32)  # (BN, 32)
    v2 = ((v2 ^ 0x8000) - 0x8000)                     # sign-extend 16 -> 32
    d2 = v2 - v2[:, :1]
    rep2 = jnp.all(d2 == 0, axis=1)
    best_size, best_scheme = _take(rep2, 2, 9, best_size, best_scheme)
    fits2 = jnp.all((d2 >= -128) & (d2 < 128), axis=1)
    best_size, best_scheme = _take(fits2 & ~rep2, 2 + 32, 10,
                                   best_size, best_scheme)

    size_ref[...] = best_size
    scheme_ref[...] = best_scheme


def bdi_sizes_pallas(bytes_i32: jax.Array, block_n: int = BLOCK_N,
                     interpret: bool | None = None):
    """(N, 64) int32 bytes -> (sizes (N,), schemes (N,)) int32."""
    if interpret is None:
        interpret = interpret_default()
    x, n = pad_to(bytes_i32.astype(jnp.int32), block_n, axis=0)
    grid = (cdiv(x.shape[0], block_n),)
    sizes, schemes = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, 64), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
                   jax.ShapeDtypeStruct((x.shape[0],), jnp.int32)],
        interpret=interpret,
    )(x)
    return sizes[:n], schemes[:n]
