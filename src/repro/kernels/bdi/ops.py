"""Jitted public wrappers for the BDI detection kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bdi.bdi import bdi_sizes_pallas
from repro.kernels.byte_lut import ref as blref


@functools.partial(jax.jit)
def bdi_sizes(lines: jax.Array):
    """(N, 16) uint32 lines -> (sizes (N,) int32, schemes (N,) int32)."""
    b = blref.words_to_bytes(lines)
    return bdi_sizes_pallas(b)


@functools.partial(jax.jit)
def compression_ratio(lines: jax.Array) -> jax.Array:
    sizes, _ = bdi_sizes(lines)
    return jnp.sum(sizes.astype(jnp.float32)) / (lines.shape[0] * 64.0)
