"""Oracle for the BDI kernel: the offline numpy encoder from
``repro.core.encodings`` (int64 arithmetic, independently implemented)."""
from __future__ import annotations

import numpy as np

from repro.core import encodings


def bdi_sizes(lines_u32: np.ndarray) -> np.ndarray:
    """(N, 16) uint32 lines -> (N,) encoded sizes in bytes."""
    _, sizes = encodings.bdi_encode_lines(np.asarray(lines_u32))
    return sizes


def bytes_from_lines(lines_u32: np.ndarray) -> np.ndarray:
    return encodings.words_to_bytes(np.asarray(lines_u32)).astype(np.int32)
