"""Jitted public wrappers for the toggle kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.toggle import ref
from repro.kernels.toggle.toggle import line_toggles_pallas


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def line_toggles(cur: jax.Array, prev: jax.Array,
                 use_kernel: bool = True) -> jax.Array:
    if use_kernel:
        return line_toggles_pallas(cur, prev)
    return ref.line_toggles(cur, prev)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def line_toggles_seq(lines: jax.Array, use_kernel: bool = True) -> jax.Array:
    """Toggles of each line vs. its predecessor; first entry is 0."""
    prev = jnp.concatenate([lines[:1], lines[:-1]], axis=0)
    t = line_toggles(lines, prev, use_kernel=use_kernel)
    return t.at[0].set(0)
