"""Pallas TPU kernel: bus-toggle count between consecutive cache lines.

Inputs  cur  (N, 16) uint32 — line on the bus at step i
        prev (N, 16) uint32 — line on the bus at step i-1 (precomputed shift)
Output  (N,) int32          — wires toggling = popcount(cur ^ prev)

Same VMEM tiling as the popcount kernel; the XOR is fused with the
popcount so the (N,16) intermediate never round-trips to HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, interpret_default, pad_to
from repro.kernels.popcount.popcount import _popcount_u32

BLOCK_N = 1024


def _kernel(cur_ref, prev_ref, o_ref):
    x = jnp.bitwise_xor(cur_ref[...], prev_ref[...])
    o_ref[...] = jnp.sum(_popcount_u32(x), axis=1)


def line_toggles_pallas(cur: jax.Array, prev: jax.Array,
                        block_n: int = BLOCK_N,
                        interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = interpret_default()
    cur, n = pad_to(cur.astype(jnp.uint32), block_n, axis=0)
    prev, _ = pad_to(prev.astype(jnp.uint32), block_n, axis=0)
    grid = (cdiv(cur.shape[0], block_n),)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, 16), lambda i: (i, 0)),
                  pl.BlockSpec((block_n, 16), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cur.shape[0],), jnp.int32),
        interpret=interpret,
    )(cur, prev)
    return out[:n]
