"""Pure-jnp oracle for the toggle kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.popcount.ref import line_ones


def line_toggles(cur: jax.Array, prev: jax.Array) -> jax.Array:
    return line_ones(jnp.bitwise_xor(cur.astype(jnp.uint32),
                                     prev.astype(jnp.uint32)))


def line_toggles_seq(lines: jax.Array) -> jax.Array:
    """Toggles of each line vs. its predecessor; first entry is 0."""
    prev = jnp.concatenate([lines[:1], lines[:-1]], axis=0)
    t = line_toggles(lines, prev)
    return t.at[0].set(0)
