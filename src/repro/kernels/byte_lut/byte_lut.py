"""Pallas TPU kernel: 256-entry byte LUT via one-hot MXU matmul.

This is the TPU-native reformulation of the paper's in-DRAM encoding table
(Section 10.1): instead of a scalar SRAM lookup per byte (no efficient
per-lane gather on the TPU VPU), each block of bytes is one-hot expanded and
multiplied against the LUT as a (BLOCK_B, 256) x (256, 1) matmul on the MXU.

Input  bytes (M,) int32 in [0,256)   (M = 64 * n_lines)
       lut   (256,) int32
Output (M,) int32 encoded bytes

Tiling: BLOCK_B = 2048 bytes -> one-hot (2048, 256) f32 = 2 MiB in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, interpret_default, pad_to

BLOCK_B = 2048


def _kernel(b_ref, lut_ref, o_ref):
    b = b_ref[...]                                  # (BLOCK_B,) int32
    lut = lut_ref[...].astype(jnp.float32)          # (256,)
    onehot = (b[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (b.shape[0], 256), 1)).astype(jnp.float32)
    enc = jax.lax.dot_general(
        onehot, lut[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (BLOCK_B, 1) on the MXU
    o_ref[...] = enc[:, 0].astype(jnp.int32)


def byte_lut_pallas(b: jax.Array, lut: jax.Array, block_b: int = BLOCK_B,
                    interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = interpret_default()
    b32 = b.astype(jnp.int32)
    x, n = pad_to(b32, block_b, axis=0)
    grid = (cdiv(x.shape[0], block_b),)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b,), lambda i: (i,)),
                  pl.BlockSpec((256,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
        interpret=interpret,
    )(x, lut.astype(jnp.int32))
    return out[:n]
