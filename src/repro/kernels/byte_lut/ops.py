"""Jitted public wrappers for the byte-LUT kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.byte_lut import ref
from repro.kernels.byte_lut.byte_lut import byte_lut_pallas


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def apply_lut_lines(lines: jax.Array, lut: jax.Array,
                    use_kernel: bool = True) -> jax.Array:
    """Encode (N, 16) uint32 cache lines through a 256-byte LUT."""
    b = ref.words_to_bytes(lines).reshape(-1)
    if use_kernel:
        enc = byte_lut_pallas(b, lut)
    else:
        enc = ref.byte_lut(b, lut)
    return ref.bytes_to_words(enc.reshape(lines.shape[0], 64))
