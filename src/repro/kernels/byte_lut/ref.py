"""Pure-jnp oracle for the byte-LUT kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def byte_lut(b: jax.Array, lut: jax.Array) -> jax.Array:
    return jnp.take(lut.astype(jnp.int32), b.astype(jnp.int32), axis=0)


def words_to_bytes(lines: jax.Array) -> jax.Array:
    """(..., 16) uint32 -> (..., 64) int32 bytes."""
    lines = lines.astype(jnp.uint32)
    parts = [((lines >> (8 * i)) & jnp.uint32(0xFF)).astype(jnp.int32)
             for i in range(4)]
    out = jnp.stack(parts, axis=-1)                  # (..., 16, 4)
    return out.reshape(*lines.shape[:-1], 64)


def bytes_to_words(b: jax.Array) -> jax.Array:
    """(..., 64) int32 -> (..., 16) uint32."""
    b = b.astype(jnp.uint32).reshape(*b.shape[:-1], 16, 4)
    return (b[..., 0] | (b[..., 1] << 8) | (b[..., 2] << 16)
            | (b[..., 3] << 24))


def apply_lut_lines(lines: jax.Array, lut: jax.Array) -> jax.Array:
    """(N, 16) uint32 lines -> encoded lines via the byte LUT."""
    return bytes_to_words(byte_lut(words_to_bytes(lines), lut))
