"""Pallas TPU kernel: per-cache-line population count.

Input  (N, 16) uint32  — 64-byte lines as 16 words
Output (N,)    int32   — number of set bits per line

Tiling: blocks of (BLOCK_N, 16) words live in VMEM; the popcount is pure
VPU bit arithmetic (shifts/ands/multiplies), no MXU use. BLOCK_N = 1024
keeps the block at 64 KiB — far under VMEM while amortizing grid overhead.
The 16-wide lane dimension under-fills the 128-lane VREG; the fused
vampire_energy kernel avoids this by keeping the reduction in-kernel, and
`ops.line_ones_flat` offers a (N*16 -> 128-lane) layout variant for pure
throughput use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dram import popcount_u32 as _popcount_u32
from repro.kernels.common import cdiv, interpret_default, pad_to

BLOCK_N = 1024


def _kernel(x_ref, o_ref):
    x = x_ref[...]                       # (BLOCK_N, 16) uint32
    o_ref[...] = jnp.sum(_popcount_u32(x), axis=1)


def line_ones_pallas(lines: jax.Array, block_n: int = BLOCK_N,
                     interpret: bool | None = None) -> jax.Array:
    """(N, 16) uint32 -> (N,) int32 ones per line."""
    if interpret is None:
        interpret = interpret_default()
    x, n = pad_to(lines.astype(jnp.uint32), block_n, axis=0)
    grid = (cdiv(x.shape[0], block_n),)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, 16), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
        interpret=interpret,
    )(x)
    return out[:n]
