"""Pure-jnp oracle for the popcount kernel.

The single bit-twiddle definition lives in ``repro.core.dram``; this module
re-exports it so kernel tests keep one oracle import path (the duplicated
helper that used to live here is gone)."""
from __future__ import annotations

from repro.core.dram import line_ones, popcount_u32  # noqa: F401
