"""Pure-jnp oracle for the popcount kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def popcount_u32(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def line_ones(lines: jax.Array) -> jax.Array:
    """(N, 16) uint32 -> (N,) int32."""
    return jnp.sum(popcount_u32(lines), axis=-1)
