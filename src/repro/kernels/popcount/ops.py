"""Jitted public wrappers for the popcount kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.popcount import ref
from repro.kernels.popcount.popcount import line_ones_pallas


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def line_ones(lines: jax.Array, use_kernel: bool = True) -> jax.Array:
    """(N, 16) uint32 -> (N,) int32 population count per 64-byte line."""
    if use_kernel:
        return line_ones_pallas(lines)
    return ref.line_ones(lines)
