"""Pallas TPU kernel: fused per-command VAMPIRE read/write current.

Fuses, for every RD/WR command: line popcount, bus-XOR toggle popcount, the
(interleave-mode, op) coefficient select, the structural bank factor, and the
I/O-driver term — paper Eq. 2 evaluated in one VMEM pass. The coefficient
select is a masked sum over the 8 (mode, op) combinations (no per-lane
gathers on the TPU VPU).

Inputs  data    (N, 16) uint32   line on the bus
        prev    (N, 16) uint32   previous RD/WR line on the bus
        op      (N,)   int32     0 = read, 1 = write
        mode    (N,)   int32     interleave mode 0..3
        bankfac (N,)   f32       structural factor of the target bank
        coeffs  (4, 2, 3) f32    Table-5 parameters
        io      (2,)   f32       (io_read_ma_per_one, io_write_ma_per_zero)
Output  (N,) f32 current in mA

The surrounding integrator (bank-state background, ACT/REF charges) stays in
vectorized jnp — those terms touch O(N) scalars, not the O(N x 512 bit)
data stream this kernel owns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, cdiv, pad_to
from repro.kernels.popcount.popcount import _popcount_u32

BLOCK_N = 1024
LINE_BITS = 512.0


def _kernel(data_ref, prev_ref, op_ref, mode_ref, bankfac_ref,
            coeff_ref, io_ref, o_ref):
    data = data_ref[...]
    prev = prev_ref[...]
    op = op_ref[...]
    mode = mode_ref[...]
    bankfac = bankfac_ref[...]
    coeffs = coeff_ref[...]          # (4, 2, 3)
    io = io_ref[...]                 # (2,)

    ones = jnp.sum(_popcount_u32(data), axis=1).astype(jnp.float32)
    togg = jnp.sum(_popcount_u32(jnp.bitwise_xor(data, prev)),
                   axis=1).astype(jnp.float32)

    cur = jnp.zeros_like(ones)
    for m in range(4):
        for o in range(2):
            sel = ((mode == m) & (op == o)).astype(jnp.float32)
            c = coeffs[m, o]
            cur = cur + sel * (c[0] + c[1] * ones + c[2] * togg)
    io_cur = jnp.where(op == 0, io[0] * ones, io[1] * (LINE_BITS - ones))
    o_ref[...] = cur * bankfac + io_cur


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def rw_current_pallas(data, prev, op, mode, bankfac, coeffs, io,
                      block_n: int = BLOCK_N,
                      interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = INTERPRET
    data, n = pad_to(data.astype(jnp.uint32), block_n, axis=0)
    prev, _ = pad_to(prev.astype(jnp.uint32), block_n, axis=0)
    op, _ = pad_to(op.astype(jnp.int32), block_n, axis=0)
    mode, _ = pad_to(mode.astype(jnp.int32), block_n, axis=0)
    bankfac, _ = pad_to(bankfac.astype(jnp.float32), block_n, axis=0)
    grid = (cdiv(data.shape[0], block_n),)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, 16), lambda i: (i, 0)),
                  pl.BlockSpec((block_n, 16), lambda i: (i, 0)),
                  pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((4, 2, 3), lambda i: (0, 0, 0)),
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((data.shape[0],), jnp.float32),
        interpret=interpret,
    )(data, prev, op, mode, bankfac,
      coeffs.astype(jnp.float32), io.astype(jnp.float32))
    return out[:n]
