"""Pallas TPU kernels: fused (traces x vendors) VAMPIRE energy.

The batched kernel family behind ``impl='pallas'`` (the unified estimator
protocol's fast path).  Two kernels split the work exactly where the model
does:

1. :func:`batched_features_pallas` — the **param-independent feature
   kernel**.  Consumes a padded TraceBatch's data stream once: per-line
   popcount and bus-XOR toggle popcount (the O(N x 512 bit) work, fusing
   the ``kernels/popcount`` and ``kernels/toggle`` bodies into one VMEM
   pass) with validity masking over NOP/dt=0 pad rows.  Runs ONCE per
   batch; its outputs are shared by every vendor.

2. :func:`batched_energy_pallas` — the **per-vendor fused current/energy
   kernel**, gridded over ``(vendors, traces, command blocks)``.  For each
   vendor it fuses the (interleave-mode, op) coefficient select of paper
   Eq. 2 (masked sum — no per-lane gathers on the VPU), the structural
   bank factor and open-bank background (8-wide masked reductions over
   transposed (8, N) layouts, keeping the command axis on the VREG lanes),
   the I/O-driver term, the bank-state background integrator with burst
   crediting, ACT/REF charges with the per-(bank, row-band) structural
   surface factor (gathered into a per-command plane by the assembler — a
   VMEM multiply here, not a kernel gather), the optional ``ones_quad``
   curvature (so the *true* simulator params ride the same kernel during
   characterization), and the pad-row weight mask — one partial charge sum
   per grid cell, reduced to the (traces, vendors) matrix outside.

   Passing ``cell_t`` (the one-hot structural cell plane) switches the
   same launch to the ``mode='surface'`` kernel variant: the identical
   fused charge body, but instead of one scalar sum per grid cell it
   reduces against the (surface-cells, N) plane (the same
   transposed-layout trick as the bank reductions, 64 lanes wide) and
   writes one partial charge row per structural cell -> the
   (traces, vendors, banks, row_bands) surface.

The index bookkeeping that decides bank state / interleave mode / previous
line (``energy_model.structural_state``) stays in vectorized jnp: it is
O(N) scalars and gathers, not the O(N x 512 bit) stream these kernels own.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dram import TIMING
from repro.core.energy_model import N_SURFACE_CELLS
from repro.kernels.common import cdiv, interpret_default, pad_to
from repro.kernels.popcount.popcount import _popcount_u32

BLOCK_N = 512
LINE_BITS = 512.0
_T_BURST = float(TIMING.tBURST)

# layout of the packed per-vendor scalar row (see pack_param_blocks);
# the low-power LUT entries are appended at the END so the first eight
# slots keep their historical positions
_SCAL_FIELDS = ("i2n", "q_actpre", "row_ones_slope", "q_ref", "i_pd",
                "io_read_ma_per_one", "io_write_ma_per_zero", "ones_quad",
                "i_pd_slow", "i_actpd", "i_sr")


def pack_param_blocks(stacked):
    """Pack a stacked (leading vendor axis) ``PowerParams`` into the three
    fixed-shape blocks the energy kernel tiles over the vendor grid axis:
    ``coeffs (V,4,2,3)``, ``scal (V,11)`` (order ``_SCAL_FIELDS``), and
    ``bvec (V,3,8)`` (open-bank delta, read factor, write factor)."""
    coeffs = stacked.datadep.astype(jnp.float32)
    scal = jnp.stack([getattr(stacked, f).astype(jnp.float32)
                      for f in _SCAL_FIELDS], axis=-1)
    bvec = jnp.stack([stacked.bank_open_delta.astype(jnp.float32),
                      stacked.bank_read_factor.astype(jnp.float32),
                      stacked.bank_write_factor.astype(jnp.float32)], axis=1)
    return coeffs, scal, bvec


# ---------------------------------------------------------------------------
# 1. param-independent feature kernel
# ---------------------------------------------------------------------------
def _features_kernel(data_ref, prev_ref, tmask_ref, ones_ref, togg_ref):
    data = data_ref[...]                              # (B, 16) uint32
    prev = prev_ref[...]
    ones = jnp.sum(_popcount_u32(data), axis=1).astype(jnp.float32)
    togg = jnp.sum(_popcount_u32(jnp.bitwise_xor(data, prev)),
                   axis=1).astype(jnp.float32)
    ones_ref[...] = ones
    togg_ref[...] = togg * tmask_ref[...]             # mask pad/first-access


def batched_features_pallas(data, prev, tmask, block_n: int = BLOCK_N,
                            interpret: bool | None = None):
    """(M, 16) u32 data/prev + (M,) f32 toggle-validity mask ->
    ((M,) ones, (M,) toggles) as f32, in one fused pass."""
    if interpret is None:
        interpret = interpret_default()
    data, m = pad_to(data.astype(jnp.uint32), block_n, axis=0)
    prev, _ = pad_to(prev.astype(jnp.uint32), block_n, axis=0)
    tmask, _ = pad_to(tmask.astype(jnp.float32), block_n, axis=0)
    grid = (cdiv(data.shape[0], block_n),)
    ones, togg = pl.pallas_call(
        _features_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, 16), lambda i: (i, 0)),
                  pl.BlockSpec((block_n, 16), lambda i: (i, 0)),
                  pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((data.shape[0],), jnp.float32),
                   jax.ShapeDtypeStruct((data.shape[0],), jnp.float32)],
        interpret=interpret,
    )(data, prev, tmask)
    return ones[:m], togg[:m]


# ---------------------------------------------------------------------------
# 2. per-vendor fused current/energy kernel
# ---------------------------------------------------------------------------
# feature-plane order shared by the kernel signature and the ops wrapper
FEATURE_PLANES = ("ones", "togg", "op", "mode", "dt", "is_rw", "is_act",
                  "is_ref", "pd", "row_ones", "w")


def _masked_charge(ones, togg, op, mode, dt, is_rw, is_act, is_ref, pd,
                   row_ones, w, surf, bank_t, open_t, coeffs, scal, bvec):
    """The fused per-command charge body shared by the scalar-sum and the
    surface-cell kernels.  All per-command args are (B,) f32 except
    ``bank_t``/``open_t`` (8, B); ``surf`` is this vendor's per-command
    structural ACT factor (gathered by the assembler).  Returns the masked
    (B,) charge vector in mA*cycles."""
    i2n, q_actpre, slope, q_ref_chg = scal[0], scal[1], scal[2], scal[3]
    i_pd, io_r, io_w, ones_quad = scal[4], scal[5], scal[6], scal[7]
    i_pd_slow, i_actpd, i_sr = scal[8], scal[9], scal[10]

    # background current from the bank state and the background-state code
    # carried in the ``pd`` plane (energy_model.BG_*: 0 active, 1 fast PDN,
    # 2 slow PDN, 3 active PDN, 4 self-refresh) — the kernel twin of
    # ``energy_model.background_current``
    bg_delta = jnp.sum(open_t * bvec[0][:, None], axis=0)        # (B,)
    i_low = jnp.where(pd == 1.0, i_pd,
                      jnp.where(pd == 2.0, i_pd_slow,
                                jnp.where(pd == 3.0, i_actpd, i_sr)))
    i_bg = jnp.where(pd == 0.0, i2n + bg_delta, i_low)

    # paper Eq. 2: masked (mode, op) coefficient select + quad curvature
    cur = jnp.zeros_like(ones)
    for m in range(4):
        for o in range(2):
            sel = ((mode == m) & (op == o)).astype(jnp.float32)
            c = coeffs[m, o]
            base = c[0] + c[1] * ones + c[2] * togg
            base = base + ones_quad * c[1] * ones * (ones / LINE_BITS - 0.5)
            cur = cur + sel * base
    rd_fac = jnp.sum(bank_t * bvec[1][:, None], axis=0)
    wr_fac = jnp.sum(bank_t * bvec[2][:, None], axis=0)
    io_cur = jnp.where(op == 0, io_r * ones, io_w * (LINE_BITS - ones))
    i_rw = cur * jnp.where(op == 0, rd_fac, wr_fac) + io_cur

    # the integrator: background over the slot, burst crediting, ACT/REF
    burst = jnp.minimum(dt, _T_BURST)
    charge = i_bg * dt
    charge = charge + is_rw * (i_rw - i_bg) * burst
    charge = charge + is_act * q_actpre * (1.0 + slope * row_ones) * surf
    charge = charge + is_ref * q_ref_chg
    return charge * w


def _energy_kernel(ones_ref, togg_ref, op_ref, mode_ref, dt_ref, isrw_ref,
                   isact_ref, isref_ref, pd_ref, rowones_ref, w_ref,
                   surf_ref, bank_t_ref, open_t_ref, coeff_ref, scal_ref,
                   bvec_ref, o_ref):
    cw = _masked_charge(
        ones_ref[0], togg_ref[0], op_ref[0], mode_ref[0], dt_ref[0],
        isrw_ref[0], isact_ref[0], isref_ref[0], pd_ref[0], rowones_ref[0],
        w_ref[0], surf_ref[0, 0], bank_t_ref[0], open_t_ref[0],
        coeff_ref[0], scal_ref[0], bvec_ref[0])
    o_ref[0, 0, 0] = jnp.sum(cw)


def _surface_kernel(ones_ref, togg_ref, op_ref, mode_ref, dt_ref, isrw_ref,
                    isact_ref, isref_ref, pd_ref, rowones_ref, w_ref,
                    surf_ref, cell_ref, bank_t_ref, open_t_ref, coeff_ref,
                    scal_ref, bvec_ref, o_ref):
    cw = _masked_charge(
        ones_ref[0], togg_ref[0], op_ref[0], mode_ref[0], dt_ref[0],
        isrw_ref[0], isact_ref[0], isref_ref[0], pd_ref[0], rowones_ref[0],
        w_ref[0], surf_ref[0, 0], bank_t_ref[0], open_t_ref[0],
        coeff_ref[0], scal_ref[0], bvec_ref[0])
    # cell one-hot reduction (the 8-wide bank trick, CELLS lanes wide):
    # one partial charge per (bank, row-band) cell of this block
    o_ref[0, 0, 0, :] = jnp.sum(cell_ref[0] * cw[None, :], axis=1)


def _grid_maps(grid_layout: str, n_vendors: int, n_traces: int,
               grid_n: int):
    """The grid tuple plus an index-map builder for one grid-major order.

    ``'vti'`` (the historical order) iterates vendors outermost, keeping
    one trace's feature planes resident across the vendor sweep of a
    block; ``'tvi'`` iterates traces outermost, keeping one vendor's
    parameter blocks resident instead.  The autotuner
    (``kernels/autotune``) picks per (backend, shape-bucket).  ``as_map``
    lifts a ``(v, t, i) -> block index`` function into the grid's own
    coordinate order, so the kernels and BlockSpecs stay layout-agnostic.
    """
    if grid_layout == "tvi":
        grid = (n_traces, n_vendors, grid_n)

        def as_map(sel):
            return lambda t, v, i: sel(v, t, i)
    elif grid_layout == "vti":
        grid = (n_vendors, n_traces, grid_n)

        def as_map(sel):
            return lambda v, t, i: sel(v, t, i)
    else:
        raise ValueError(f"unknown grid_layout {grid_layout!r}")
    return grid, as_map


def batched_energy_pallas(feats: dict, coeffs, scal, bvec,
                          block_n: int = BLOCK_N,
                          interpret: bool | None = None,
                          cell_t=None,
                          grid_layout: str = "vti") -> jax.Array:
    """The (vendors, traces, blocks)-gridded charge reduction.

    ``feats`` maps :data:`FEATURE_PLANES` names to (T, N) arrays, plus
    ``surf`` as the (V, T, N) per-command structural ACT factor and
    ``bank_t``/``open_t`` as (T, 8, N) transposed layouts so the 8-wide
    reductions keep the command axis on the VREG lanes.  Returns the
    (T, V) masked charge matrix in mA*cycles — or, when ``cell_t`` (the
    (T, CELLS, N) one-hot structural cell plane) is passed, switches the
    grid to the surface kernel and returns the (T, V, CELLS) charge
    decomposition of ``mode='surface'``.  ``grid_layout`` picks the
    grid-major order (see :func:`_grid_maps`) — pure scheduling, the
    partial sums are identical either way."""
    if interpret is None:
        interpret = interpret_default()
    padded = {}
    for name in FEATURE_PLANES:
        padded[name], _ = pad_to(feats[name], block_n, axis=1)
    padded["surf"], _ = pad_to(feats["surf"], block_n, axis=2)
    for name in ("bank_t", "open_t"):
        padded[name], _ = pad_to(feats[name], block_n, axis=2)
    n_traces, n_pad = padded["ones"].shape
    n_vendors = coeffs.shape[0]
    grid_n = cdiv(n_pad, block_n)
    grid, as_map = _grid_maps(grid_layout, n_vendors, n_traces, grid_n)

    spec_2d = pl.BlockSpec((1, block_n), as_map(lambda v, t, i: (t, i)))
    spec_surf = pl.BlockSpec((1, 1, block_n),
                             as_map(lambda v, t, i: (v, t, i)))
    spec_8 = pl.BlockSpec((1, 8, block_n), as_map(lambda v, t, i: (t, 0, i)))
    param_specs = [pl.BlockSpec((1, 4, 2, 3),
                                as_map(lambda v, t, i: (v, 0, 0, 0))),
                   pl.BlockSpec((1, len(_SCAL_FIELDS)),
                                as_map(lambda v, t, i: (v, 0))),
                   pl.BlockSpec((1, 3, 8),
                                as_map(lambda v, t, i: (v, 0, 0)))]
    args = [padded[n] for n in FEATURE_PLANES] + [padded["surf"]]
    if cell_t is None:
        kernel, cell_specs = _energy_kernel, []
        out_spec = pl.BlockSpec((1, 1, 1), as_map(lambda v, t, i: (v, t, i)))
        out_shape = jax.ShapeDtypeStruct((n_vendors, n_traces, grid_n),
                                         jnp.float32)
    else:
        kernel = _surface_kernel
        padded_cell, _ = pad_to(cell_t, block_n, axis=2)
        args.append(padded_cell)
        cell_specs = [pl.BlockSpec((1, N_SURFACE_CELLS, block_n),
                                   as_map(lambda v, t, i: (t, 0, i)))]
        out_spec = pl.BlockSpec((1, 1, 1, N_SURFACE_CELLS),
                                as_map(lambda v, t, i: (v, t, i, 0)))
        out_shape = jax.ShapeDtypeStruct(
            (n_vendors, n_traces, grid_n, N_SURFACE_CELLS), jnp.float32)
    args += [padded["bank_t"], padded["open_t"], coeffs, scal, bvec]
    partial = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=([spec_2d] * len(FEATURE_PLANES) + [spec_surf]
                  + cell_specs + [spec_8, spec_8] + param_specs),
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if cell_t is None:
        return jnp.sum(partial, axis=2).T                # (T, V)
    return jnp.sum(partial, axis=2).transpose(1, 0, 2)   # (T, V, CELLS)
