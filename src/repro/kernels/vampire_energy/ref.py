"""Pure-jnp oracle for the batched VAMPIRE energy kernel family: the
production vectorized integrator from ``repro.core.energy_model``, applied
pair by pair over the padded batch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.energy_model import (PowerParams, charge_from_features,
                                     extract_features)


def batched_charge_ref(trace, weight, stacked: PowerParams):
    """Same contract as ``ops.batched_charge_matrix`` (measured-data mode),
    via the unfused vectorized path."""
    def one_pair(tr, w, pp):
        charges = charge_from_features(tr, extract_features(tr, pp), pp)
        return jnp.sum(charges * w)

    def one_trace(tr, w):
        return jax.vmap(lambda pp: one_pair(tr, w, pp))(stacked)

    charge = jax.vmap(one_trace)(trace, weight.astype(jnp.float32))
    cycles = jnp.sum(trace.dt * weight.astype(jnp.int32), axis=1,
                     dtype=jnp.int32)
    return charge, cycles
