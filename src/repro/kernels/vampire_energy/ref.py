"""Pure-jnp oracle for the fused VAMPIRE energy kernel: the production
vectorized path from repro.core.energy_model."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.energy_model import PowerParams, rw_current


def rw_current_ref(data, prev, op, mode, bankfac_index, pp: PowerParams):
    """Same contract as the kernel, taking bank *indices* + PowerParams."""
    from repro.core.dram import line_ones
    ones = line_ones(data)
    togg = line_ones(jnp.bitwise_xor(data.astype(jnp.uint32),
                                     prev.astype(jnp.uint32)))
    # rw_current applies pp.ones_quad too; the kernel is the fitted-model
    # (linear) path, so callers pass params with ones_quad == 0.
    return rw_current(pp, op, mode, ones, togg, bankfac_index)
