"""Jitted assembler for the fused (traces x vendors) VAMPIRE energy path.

:func:`batched_charge_matrix` is the single entry point both consumers of
``impl='pallas'`` share — the estimation engine
(``repro.core.estimate_batch``) and the characterization fleet engine
(``repro.core.fleet``, where the "vendor" axis is the stacked module
params).  It runs the vectorized ``structural_state`` bookkeeping over the
padded batch, the param-independent feature kernel once, and the
per-vendor fused energy kernel over the (vendors, traces, blocks) grid.

``mode='distribution'`` support: passing ``ones_frac``/``toggle_frac``
skips the feature kernel and substitutes the expected per-command data
features (first-access toggles stay 0, matching
``energy_model.distribution_features``).  ``surface=True`` swaps the
scalar-sum energy kernel for the cell-reducing surface kernel
(``mode='surface'``: per-(bank, row-band) charge decomposition).

The old single-(trace, paramset) entry point ``trace_energy_kernel`` is a
shim onto the batched kernels (a (1, 1) grid)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dram import (ACT, LINE_BITS, N_BANKS, N_ROW_BANDS, REF,
                             CommandTrace)
from repro.core.energy_model import (EnergyReport, N_SURFACE_CELLS,
                                     PowerParams, _report, structural_state,
                                     surface_cells, surface_cycles)
from repro.kernels.common import interpret_default
from repro.kernels.vampire_energy.vampire_energy import (
    BLOCK_N, batched_energy_pallas, batched_features_pallas,
    pack_param_blocks)


@functools.partial(jax.jit,
                   static_argnames=("surface", "block_n", "interpret",
                                    "grid_layout"))
def _charge_matrix(trace: CommandTrace, weight, stacked: PowerParams,
                   ones_frac, toggle_frac, surface: bool, block_n: int,
                   interpret: bool, grid_layout: str):
    st = jax.vmap(structural_state)(trace)
    t, n = trace.cmd.shape
    if ones_frac is None:
        # measured-data modes: the fused popcount/toggle feature kernel
        # over the whole batch's data stream, once
        tmask = (st.has_prev & st.is_rw).astype(jnp.float32)
        ones, togg = batched_features_pallas(
            trace.data.reshape(t * n, -1), st.prev_data.reshape(t * n, -1),
            tmask.reshape(t * n), block_n=block_n, interpret=interpret)
        ones, togg = ones.reshape(t, n), togg.reshape(t, n)
    else:
        # no-data-trace mode: expected fractions replace the data features
        of = jnp.broadcast_to(jnp.asarray(ones_frac, jnp.float32), (t,))
        tf = jnp.broadcast_to(jnp.asarray(toggle_frac, jnp.float32), (t,))
        ones = jnp.where(st.is_rw, of[:, None] * LINE_BITS, 0.0)
        togg = jnp.where(st.is_rw & st.has_prev, tf[:, None] * LINE_BITS, 0.0)

    bank_oh = jax.nn.one_hot(trace.bank, N_BANKS, dtype=jnp.float32)
    # the per-command structural ACT factor of every vendor: the (bank,
    # row-band) gather happens HERE (vectorized jnp bookkeeping), so the
    # kernel sees a plain (V, T, N) multiply plane
    cells = jax.vmap(surface_cells)(trace)                       # (T, N)
    surf = stacked.act_surface.reshape(-1, N_SURFACE_CELLS)[:, cells]
    feats = {
        "ones": ones, "togg": togg,
        "op": st.op, "mode": st.il_mode,
        "dt": trace.dt.astype(jnp.float32),
        "is_rw": st.is_rw.astype(jnp.float32),
        "is_act": (trace.cmd == ACT).astype(jnp.float32),
        "is_ref": (trace.cmd == REF).astype(jnp.float32),
        "pd": st.bg_state.astype(jnp.float32),
        "row_ones": st.row_ones.astype(jnp.float32),
        "w": weight.astype(jnp.float32),
        "surf": surf.astype(jnp.float32),                        # (V, T, N)
        "bank_t": bank_oh.transpose(0, 2, 1),                    # (T, 8, N)
        "open_t": st.open_before.astype(jnp.float32).transpose(0, 2, 1),
    }
    coeffs, scal, bvec = pack_param_blocks(stacked)
    if surface:
        cell_t = jax.nn.one_hot(cells, N_SURFACE_CELLS,
                                dtype=jnp.float32).transpose(0, 2, 1)
        charge = batched_energy_pallas(feats, coeffs, scal, bvec,
                                       block_n=block_n, interpret=interpret,
                                       cell_t=cell_t,
                                       grid_layout=grid_layout)
        return (charge.reshape(t, -1, N_BANKS, N_ROW_BANDS),
                jax.vmap(surface_cycles)(trace, weight))
    charge = batched_energy_pallas(feats, coeffs, scal, bvec,
                                   block_n=block_n, interpret=interpret,
                                   grid_layout=grid_layout)
    cycles = jnp.sum(trace.dt * weight.astype(jnp.int32), axis=1,
                     dtype=jnp.int32)
    return charge, cycles


def batched_charge_matrix(trace: CommandTrace, weight, stacked: PowerParams,
                          *, ones_frac=None, toggle_frac=None,
                          surface: bool = False, block_n: int | None = None,
                          interpret: bool | None = None,
                          grid_layout: str | None = None):
    """Masked charge of every (trace, paramset) pair through the fused
    kernels -> ``((T, V) charge in mA*cycles, (T,) masked cycles)``, or
    with ``surface=True`` the structural decomposition
    ``((T, V, 8, N_ROW_BANDS) charge, (T, 8, N_ROW_BANDS) cycles)``.

    ``trace``/``weight`` are a padded TraceBatch's (T, N) fields;
    ``stacked`` carries a leading paramset axis.  ``interpret`` resolves
    per call (compiled on TPU, interpreted elsewhere) BEFORE entering the
    jitted body, so it participates in the jit cache key.  ``block_n`` /
    ``grid_layout`` likewise resolve per call: when not pinned by the
    caller, the autotuner's committed winner for this (backend,
    shape-bucket) applies (``kernels.autotune.best_config``), defaulting
    to the historical ``BLOCK_N``/vendor-major grid where untuned."""
    if interpret is None:
        interpret = interpret_default()
    if block_n is None or grid_layout is None:
        from repro.kernels import autotune
        cfg = autotune.best_config("vampire_energy", trace.cmd.shape[0],
                                   trace.cmd.shape[1])
        block_n = cfg["block_n"] if block_n is None else block_n
        grid_layout = (cfg["layout"] if grid_layout is None
                       else grid_layout)
    return _charge_matrix(trace, weight, stacked, ones_frac, toggle_frac,
                          surface, block_n, interpret, grid_layout)


def trace_energy_kernel(trace: CommandTrace, pp: PowerParams) -> EnergyReport:
    """Legacy single-(trace, paramset) entry point, shimmed onto the
    batched kernel family as a (1 trace, 1 vendor) grid."""
    batch = jax.tree_util.tree_map(lambda x: x[None], trace)
    weight = jnp.ones((1, trace.n), jnp.float32)
    stacked = jax.tree_util.tree_map(lambda x: x[None], pp)
    charge, cycles = batched_charge_matrix(batch, weight, stacked)
    return _report(charge[0, 0], cycles[0])
