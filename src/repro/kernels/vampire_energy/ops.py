"""Jitted wrapper: full-trace VAMPIRE energy with the fused Pallas kernel
on the RD/WR hot path. Semantics identical to
``repro.core.energy_model.trace_energy_vectorized`` for linear (fitted)
params (``ones_quad == 0``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dram import ACT, REF, TIMING, CommandTrace, popcount_u32
from repro.core.energy_model import (EnergyReport, PowerParams, _report,
                                     _exclusive_cummax, extract_features)
from repro.kernels.vampire_energy.vampire_energy import rw_current_pallas


@jax.jit
def trace_energy_kernel(trace: CommandTrace, pp: PowerParams) -> EnergyReport:
    feats = extract_features(trace, pp)
    n = trace.cmd.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    prev_rw = _exclusive_cummax(jnp.where(feats.is_rw, idx, -1))
    prev_data = jnp.where((prev_rw >= 0)[:, None],
                          trace.data[jnp.maximum(prev_rw, 0)],
                          jnp.zeros_like(trace.data))

    bankfac = jnp.where(feats.op == 0,
                        pp.bank_read_factor[trace.bank],
                        pp.bank_write_factor[trace.bank])
    io = jnp.stack([pp.io_read_ma_per_one, pp.io_write_ma_per_zero])
    i_rw = rw_current_pallas(trace.data, prev_data, feats.op, feats.il_mode,
                             bankfac, pp.datadep, io)

    dt = trace.dt.astype(jnp.float32)
    i_bg = jnp.where(feats.powered_down, pp.i_pd, pp.i2n + feats.bg_delta_sum)
    charge = i_bg * dt
    burst = jnp.minimum(dt, float(TIMING.tBURST))
    charge = charge + jnp.where(feats.is_rw, (i_rw - i_bg) * burst, 0.0)
    act_q = pp.q_actpre * (1.0 + pp.row_ones_slope
                           * feats.row_ones.astype(jnp.float32))
    charge = charge + jnp.where(trace.cmd == ACT, act_q, 0.0)
    charge = charge + jnp.where(trace.cmd == REF, pp.q_ref, 0.0)
    return _report(jnp.sum(charge), trace.total_cycles())
