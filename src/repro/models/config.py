"""Model configuration shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    every: int = 1              # MoE every k-th layer (jamba: 2)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    chunk: int = 256
    conv_width: int = 4

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    # mixer pattern: per-layer kinds, cycled (period must divide n_layers).
    # kinds: "attn" | "mamba" | "xattn" (cross-attention to aux embeddings)
    pattern: tuple[str, ...] = ("attn",)
    attn_kind: str = "gqa"            # gqa | mla
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    mla: MLAConfig | None = None
    # encoder-decoder (whisper) / multimodal (vision) frontends
    n_encoder_layers: int = 0         # >0: encoder-decoder; decoder layers
                                      # get cross-attention to encoder output
    aux_seq: int = 0                  # encoder frames / image patch tokens
    # long-context handling
    attention_block: int = 512        # blockwise-attention KV block
    subquadratic: bool = False        # True for SSM/hybrid: long_500k legal
    # numerics
    dtype: str = "bfloat16"

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a TP-friendly multiple (512): embedding and
        unembedding tables use this size; loss/decode mask the pad ids.
        Mathematically inert (pad logits forced to -inf)."""
        return -(-self.vocab // 512) * 512

    @property
    def pattern_full(self) -> tuple[str, ...]:
        p = tuple(self.pattern)
        assert self.n_layers % len(p) == 0, (self.name, len(p), self.n_layers)
        return p * (self.n_layers // len(p))

    def layer_kind(self, i: int) -> str:
        return self.pattern[i % len(self.pattern)]

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every
                                         == self.moe.every - 1)

    @property
    def n_params_estimate(self) -> float:
        """Rough parameter count (embeddings + blocks), for 6ND math."""
        d, L = self.d_model, self.n_layers
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(L):
            kind = self.layer_kind(i)
            if kind == "attn":
                if self.attn_kind == "mla" and self.mla:
                    m = self.mla
                    total += d * (self.n_heads * (m.d_nope + m.d_rope))
                    total += d * (m.kv_lora + m.d_rope)
                    total += m.kv_lora * self.n_heads * (m.d_nope + m.d_v)
                    total += self.n_heads * m.d_v * d
                else:
                    total += d * self.n_heads * self.d_head * 2
                    total += d * self.n_kv * self.d_head * 2
            elif kind == "mamba":
                s = self.ssm
                di = s.d_inner(d)
                total += d * (2 * di + 2 * s.n_groups * s.d_state
                              + s.n_heads(d)) + di * d
            elif kind == "xattn":
                total += d * self.n_heads * self.d_head * 2
                total += d * self.n_kv * self.d_head * 2
            # mlp
            if self.is_moe_layer(i):
                e = self.moe
                total += (e.n_experts + e.n_shared) * 3 * d * e.d_ff_expert
                total += d * e.n_experts
            else:
                total += 3 * d * self.d_ff
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (
                4 * d * self.n_heads * self.d_head + 3 * d * self.d_ff)
            # decoder cross-attention
            total += self.n_layers * (2 * d * self.n_heads * self.d_head
                                      + 2 * d * self.n_kv * self.d_head)
        return float(total)

    def active_params_estimate(self) -> float:
        """Active (per-token) parameters for MoE models (6*N_active*D)."""
        if self.moe is None:
            return self.n_params_estimate
        e = self.moe
        inactive_frac_ff = (e.n_experts - e.top_k) / e.n_experts
        moe_layers = sum(1 for i in range(self.n_layers)
                         if self.is_moe_layer(i))
        inactive = moe_layers * e.n_experts * 3 * self.d_model \
            * e.d_ff_expert * inactive_frac_ff / e.n_experts * e.n_experts
        # simpler: routed params minus active routed params
        routed = moe_layers * e.n_experts * 3 * self.d_model * e.d_ff_expert
        active_routed = moe_layers * e.top_k * 3 * self.d_model * e.d_ff_expert
        return self.n_params_estimate - routed + active_routed
