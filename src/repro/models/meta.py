"""Parameter metadata: one source of truth for shapes, init, and sharding.

Every model parameter is declared once as a :class:`ParamMeta` carrying its
shape and *logical* axis names ("embed", "ffn", "heads", ...). The same meta
tree then produces:

* materialized parameters (`materialize`) for smoke tests / real training,
* `jax.ShapeDtypeStruct`s (`abstractify`) for the multi-pod dry-run,
* `PartitionSpec`s (`specs_for`) through a :class:`ShardingRules` mapping of
  logical axes onto mesh axes (DP/TP/EP/FSDP are all rule changes, not model
  changes).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]       # logical name per dim
    init: str = "normal"                  # normal | zeros | ones
    scale: float | None = None            # stddev; default fan-in
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def _std(meta: ParamMeta) -> float:
    if meta.scale is not None:
        return meta.scale
    fan_in = meta.shape[0] if len(meta.shape) >= 2 else max(meta.shape[-1], 1)
    return float(1.0 / np.sqrt(max(fan_in, 1)))


def materialize(meta_tree, key: jax.Array, dtype=None):
    """Instantiate real parameter arrays from a meta tree."""
    leaves, treedef = jax.tree_util.tree_flatten(meta_tree, is_leaf=is_meta)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, m in zip(keys, leaves):
        dt = dtype or m.dtype
        if m.init == "zeros":
            out.append(jnp.zeros(m.shape, dt))
        elif m.init == "ones":
            out.append(jnp.ones(m.shape, dt))
        else:
            out.append((jax.random.normal(k, m.shape, jnp.float32)
                        * _std(m)).astype(dt))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstractify(meta_tree, dtype=None):
    """ShapeDtypeStructs for dry-run lowering (no allocation)."""
    return jax.tree_util.tree_map(
        lambda m: jax.ShapeDtypeStruct(m.shape, dtype or m.dtype),
        meta_tree, is_leaf=is_meta)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical axis -> mesh axis (or tuple of mesh axes, or None)."""
    rules: dict[str, Any]

    def spec(self, meta: ParamMeta) -> P:
        axes = []
        used: set = set()
        for name in meta.logical:
            ax = self.rules.get(name) if name else None
            # a mesh axis may appear only once per spec
            key = tuple(ax) if isinstance(ax, (list, tuple)) else ax
            if key is not None and key in used:
                ax = None
            elif key is not None:
                used.add(key)
            axes.append(tuple(ax) if isinstance(ax, list) else ax)
        return P(*axes)

    def divisibility_ok(self, meta: ParamMeta, mesh_shape: dict[str, int]
                        ) -> bool:
        for dim, name in zip(meta.shape, meta.logical):
            ax = self.rules.get(name) if name else None
            if ax is None:
                continue
            axes = ax if isinstance(ax, (list, tuple)) else (ax,)
            k = int(np.prod([mesh_shape[a] for a in axes]))
            if dim % k != 0:
                return False
        return True


def specs_for(meta_tree, rules: ShardingRules, mesh=None):
    """PartitionSpec tree; falls back to replication when a dim does not
    divide the mesh axis (e.g. 2 KV heads on a 16-way model axis)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else None

    def one(m: ParamMeta) -> P:
        if mesh_shape is None or rules.divisibility_ok(m, mesh_shape):
            return rules.spec(m)
        # drop offending axes only
        axes = []
        for dim, name in zip(m.shape, m.logical):
            ax = rules.rules.get(name) if name else None
            if ax is not None:
                axs = ax if isinstance(ax, (list, tuple)) else (ax,)
                k = int(np.prod([mesh_shape[a] for a in axs]))
                if dim % k != 0:
                    ax = None
            axes.append(tuple(ax) if isinstance(ax, list) else ax)
        # de-duplicate mesh axes used twice after fallbacks
        seen: set = set()
        final = []
        for ax in axes:
            key = ax
            if key is not None and key in seen:
                final.append(None)
            else:
                if key is not None:
                    seen.add(key)
                final.append(ax)
        return P(*final)

    return jax.tree_util.tree_map(one, meta_tree, is_leaf=is_meta)
