"""Model layers: norms, RoPE, blockwise attention (GQA), MLA, MoE, Mamba2.

Conventions
-----------
* Every layer exposes ``*_meta(cfg) -> meta tree`` (ParamMeta leaves) and
  ``*_apply(params, ...)`` / ``*_decode(params, cache, ...)`` functions.
* Activations: (B, S, d_model); compute in the config dtype, reductions and
  softmax in f32.
* Long sequences never materialize (S, S): attention uses a nested
  q-block/kv-block online-softmax scan (the pure-jnp twin of the Pallas
  flash kernel in ``repro.kernels.flash_attention``; on real TPU the kernel
  substitutes via the ``use_flash_kernel`` flag).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.meta import ParamMeta

Params = Any
F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norm / RoPE
# ---------------------------------------------------------------------------
def rmsnorm_meta(d: int) -> ParamMeta:
    return ParamMeta((d,), ("embed",), init="ones")


def rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=F32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    ang = positions.astype(F32)[..., None] * freqs    # (B, S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                           # (B, S, 1, D/2)
    sin = sin[..., None, :]
    x1, x2 = x[..., 0::2].astype(F32), x[..., 1::2].astype(F32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (pure-jnp flash twin)
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def _attn_block(q, k, v, m, l, acc, causal_mask):
    """One online-softmax update. q: (B, bq, H, D); k/v: (B, bk, Kh, D)."""
    b, bq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, bq, kh, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(F32), k.astype(F32))
    s = s * (d ** -0.5)
    if causal_mask is not None:
        s = jnp.where(causal_mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))       # (B,Kh,G,bq)
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = alpha * l + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(F32))
    acc_new = acc * alpha[..., None] + pv
    return m_new, l_new, acc_new


def blockwise_attention(q, k, v, *, causal: bool, block: int = 512,
                        q_offset=0):
    """q: (B, Sq, H, D); k/v: (B, Skv, Kh, D) -> (B, Sq, H, D).

    Nested scan: outer over q blocks, inner over kv blocks, carrying the
    online-softmax state; score blocks are (B, Kh, G, bq, bk). Sequences are
    padded internally to whole blocks (padded KV positions are masked out).
    """
    b, sq0, h, d = q.shape
    skv0, kh = k.shape[1], k.shape[2]
    bq = min(block, sq0)
    bk = min(block, skv0)

    def _pad_seq(x, mult):
        pad = (-x.shape[1]) % mult
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[1] = (0, pad)
        return jnp.pad(x, widths)

    q = _pad_seq(q, bq)
    k = _pad_seq(k, bk)
    v = _pad_seq(v, bk)
    sq, skv = q.shape[1], k.shape[1]
    kv_valid = skv0
    nq, nk = sq // bq, skv // bk
    g = h // kh
    dv = v.shape[-1]                                   # may differ (MLA)

    k_blocks = k.reshape(b, nk, bk, kh, d).swapaxes(0, 1)  # (nk,B,bk,Kh,D)
    v_blocks = v.reshape(b, nk, bk, kh, dv).swapaxes(0, 1)
    q_blocks = q.reshape(b, nq, bq, h, d).swapaxes(0, 1)

    # NOTE 1: block positions are threaded through the scan CARRIES (not
    # taken from iota xs): index-only quantities get loop-hoisted by XLA
    # into an (nq x nk x bq x bk) precomputed mask stack — 2 GiB at 32k.
    # Carry-dependence keeps the (bq, bk) mask transient per iteration.
    # NOTE 2: the inner body is jax.checkpoint'ed: without it, reverse-mode
    # saves every block's (bq, bk) scores/probabilities across all nq x nk
    # iterations — the full S^2 flash attention is meant to avoid. Remat
    # recomputes each block's scores in its own backward (flash-bwd style).
    def outer(q_base, qb):
        q_pos = q_offset + q_base + jnp.arange(bq)

        @jax.checkpoint
        def inner(carry, kb_vb):
            m, l, acc, k_base = carry
            kb, vb = kb_vb
            k_pos = k_base + jnp.arange(bk)
            mask = (k_pos < kv_valid)[None, :]
            if causal:
                mask = mask & (q_pos[:, None] >= k_pos[None, :])
            else:
                mask = jnp.broadcast_to(mask, (bq, bk))
            m, l, acc = _attn_block(qb, kb, vb, m, l, acc, mask)
            return (m, l, acc, k_base + bk), None

        init = (jnp.full((b, kh, g, bq), NEG_INF, F32),
                jnp.zeros((b, kh, g, bq), F32),
                jnp.zeros((b, kh, g, bq, dv), F32),
                jnp.zeros((), jnp.int32))
        (m, l, acc, _), _ = jax.lax.scan(
            init=init, xs=(k_blocks, v_blocks), f=inner)
        o = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,Kh,G,bq,Dv)
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, bq, h, dv)
        return q_base + bq, o.astype(q.dtype)

    _, outs = jax.lax.scan(outer, jnp.zeros((), jnp.int32), q_blocks)
    return outs.swapaxes(0, 1).reshape(b, sq, h, dv)[:, :sq0]


def decode_attention(q, k_cache, v_cache, kv_len):
    """Single-step attention over a cache. q: (B, 1, H, D);
    k/v_cache: (B, S, Kh, D); kv_len: () valid prefix length."""
    b, _, h, d = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(F32),
                        k_cache.astype(F32)) * (d ** -0.5)
    mask = jnp.arange(s)[None, None, None, :] < kv_len
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(F32))
    return o.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------
def attn_meta(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    meta = {
        "wq": ParamMeta((d, h * dh), ("embed", "heads_dh")),
        "wk": ParamMeta((d, kv * dh), ("embed", "kv_dh")),
        "wv": ParamMeta((d, kv * dh), ("embed", "kv_dh")),
        "wo": ParamMeta((h * dh, d), ("heads_dh", "embed")),
        "norm": rmsnorm_meta(d),
    }
    if cfg.qkv_bias and not cross:
        meta["bq"] = ParamMeta((h * dh,), ("heads_dh",), init="zeros")
        meta["bk"] = ParamMeta((kv * dh,), ("kv_dh",), init="zeros")
        meta["bv"] = ParamMeta((kv * dh,), ("kv_dh",), init="zeros")
    return meta


def _qkv(params, x, cfg: ModelConfig, positions=None, rope: bool = True):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kv, dh)
    v = v.reshape(b, s, kv, dh)
    if rope:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(params, x, cfg: ModelConfig, *, causal: bool = True,
               positions=None):
    """Full-sequence self-attention (train / prefill). Returns (out, (k, v))
    so prefill can seed the decode cache."""
    b, s, _ = x.shape
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)
    q, k, v = _qkv(params, xn, cfg, positions=positions)
    o = blockwise_attention(q, k, v, causal=causal,
                            block=cfg.attention_block)
    o = o.reshape(b, s, cfg.n_heads * cfg.d_head)
    return o @ params["wo"].astype(x.dtype), (k, v)


def quantize_kv(t):
    """(B, S, Kh, Dh) -> (int8 values, f32 per-(B,S,Kh) scales)."""
    absmax = jnp.max(jnp.abs(t.astype(F32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(t.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def attn_decode(params, x, cache, cfg: ModelConfig):
    """x: (B, 1, d); cache: {"k","v": (B, Smax, Kh, Dh), "pos": ()}.

    int8-quantized cache variant (a *data encoding* in the paper's sense —
    Section 10 — applied to the KV stream): cache additionally holds
    per-(B, S, Kh) f32 scales as "k_s"/"v_s"; K/V are dequantized into the
    attention in f32. Halves decode HBM cache traffic + capacity vs bf16."""
    b = x.shape[0]
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)
    pos = cache["pos"]
    q, k, v = _qkv(params, xn, cfg,
                   positions=jnp.full((b, 1), pos, dtype=jnp.int32))
    quantized = "k_s" in cache
    if quantized:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], kq, pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], vq, pos, axis=1)
        ks_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k_s"], ks, pos, axis=1)
        vs_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v_s"], vs, pos, axis=1)
        k_full = k_cache.astype(F32) * ks_cache
        v_full = v_cache.astype(F32) * vs_cache
        o = decode_attention(q, k_full, v_full, pos + 1)
        new_cache = {"k": k_cache, "v": v_cache, "k_s": ks_cache,
                     "v_s": vs_cache, "pos": pos + 1}
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos,
                                                      axis=1)
        o = decode_attention(q, k_cache, v_cache, pos + 1)
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
    o = o.reshape(b, 1, cfg.n_heads * cfg.d_head)
    return o @ params["wo"].astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Cross-attention (vision adapters, enc-dec): KV from auxiliary embeddings
# ---------------------------------------------------------------------------
def xattn_apply(params, x, aux_kv, cfg: ModelConfig):
    """aux_kv: precomputed (k, v): (B, S_aux, Kh, Dh)."""
    b, s, _ = x.shape
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)
    h, dh = cfg.n_heads, cfg.d_head
    q = (xn @ params["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    k, v = aux_kv
    o = blockwise_attention(q, k, v, causal=False, block=cfg.attention_block)
    o = o.reshape(b, s, h * dh)
    return o @ params["wo"].astype(x.dtype)


def xattn_kv(params, aux, cfg: ModelConfig):
    """Project auxiliary embeddings once: (B, S_aux, d) -> (k, v)."""
    b, s, _ = aux.shape
    kv, dh = cfg.n_kv, cfg.d_head
    k = (aux @ params["wk"].astype(aux.dtype)).reshape(b, s, kv, dh)
    v = (aux @ params["wv"].astype(aux.dtype)).reshape(b, s, kv, dh)
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — compressed KV latent attention
# ---------------------------------------------------------------------------
def mla_meta(cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    return {
        "wq": ParamMeta((d, h * (m.d_nope + m.d_rope)), ("embed", "heads_dh")),
        "w_dkv": ParamMeta((d, m.kv_lora), ("embed", None)),
        "w_kr": ParamMeta((d, m.d_rope), ("embed", None)),
        "w_uk": ParamMeta((m.kv_lora, h * m.d_nope), (None, "heads_dh")),
        "w_uv": ParamMeta((m.kv_lora, h * m.d_v), (None, "heads_dh")),
        "wo": ParamMeta((h * m.d_v, d), ("heads_dh", "embed")),
        "norm": rmsnorm_meta(d),
        "kv_norm": ParamMeta((m.kv_lora,), (None,), init="ones"),
    }


def mla_apply(params, x, cfg: ModelConfig, positions=None):
    """Training/prefill MLA: expand K/V from the latent, blockwise attention.
    Returns (out, (c_kv, k_rope)) for cache seeding."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)
    if positions is None:
        positions = jnp.arange(s)[None, :]

    q = (xn @ params["wq"].astype(x.dtype)).reshape(b, s, h,
                                                    m.d_nope + m.d_rope)
    q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rmsnorm(xn @ params["w_dkv"].astype(x.dtype), params["kv_norm"],
                   cfg.norm_eps)                       # (B, S, kv_lora)
    k_rope = apply_rope((xn @ params["w_kr"].astype(x.dtype))[:, :, None, :],
                        positions, cfg.rope_theta)     # (B, S, 1, d_rope)
    k_nope = (c_kv @ params["w_uk"].astype(x.dtype)).reshape(
        b, s, h, m.d_nope)
    v = (c_kv @ params["w_uv"].astype(x.dtype)).reshape(b, s, h, m.d_v)

    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, s, h, m.d_rope))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = blockwise_attention(q_full, k, v, causal=True,
                            block=cfg.attention_block)
    o = o.reshape(b, s, h * m.d_v)
    return o @ params["wo"].astype(x.dtype), (c_kv, k_rope[:, :, 0, :])


def mla_decode(params, x, cache, cfg: ModelConfig):
    """Absorbed-matrix MLA decode: attention runs directly over the latent
    cache (B, S, kv_lora) + shared rope key (B, S, d_rope)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)
    pos = cache["pos"]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)

    q = (xn @ params["wq"].astype(x.dtype)).reshape(b, 1, h,
                                                    m.d_nope + m.d_rope)
    q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_new = rmsnorm(xn @ params["w_dkv"].astype(x.dtype), params["kv_norm"],
                    cfg.norm_eps)
    kr_new = apply_rope((xn @ params["w_kr"].astype(x.dtype))[:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], c_new, pos,
                                              axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_new, pos, axis=1)

    # absorb W_uk into q: q' = q_nope . W_uk^T  -> (B, H, kv_lora)
    w_uk = params["w_uk"].reshape(m.kv_lora, h, m.d_nope)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(F32),
                       w_uk.astype(F32))
    s_len = ckv.shape[1]
    scores = (jnp.einsum("bhl,bsl->bhs", q_lat, ckv.astype(F32))
              + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(F32),
                           kr.astype(F32)))
    scores = scores * ((m.d_nope + m.d_rope) ** -0.5)
    mask = jnp.arange(s_len)[None, None, :] < (pos + 1)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhs,bsl->bhl", p, ckv.astype(F32))  # (B, H, kv_lora)
    w_uv = params["w_uv"].astype(x.dtype).reshape(m.kv_lora, h, m.d_v)
    o = jnp.einsum("bhl,lhv->bhv", o_lat,
                   w_uv.astype(F32))                    # (B, H, d_v)
    o = o.reshape(b, 1, h * m.d_v).astype(x.dtype)
    new_cache = {"ckv": ckv, "kr": kr, "pos": pos + 1}
    return o @ params["wo"].astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_meta(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "wg": ParamMeta((d, f), ("embed", "ffn")),
        "wu": ParamMeta((d, f), ("embed", "ffn")),
        "wd": ParamMeta((f, d), ("ffn", "embed")),
        "norm": rmsnorm_meta(d),
    }


def mlp_apply(params, x, cfg: ModelConfig):
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)
    h = jax.nn.silu(xn @ params["wg"].astype(x.dtype)) \
        * (xn @ params["wu"].astype(x.dtype))
    return h @ params["wd"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-based dispatch, optional shared experts)
# ---------------------------------------------------------------------------
def moe_meta(cfg: ModelConfig) -> dict:
    e = cfg.moe
    d = cfg.d_model
    meta = {
        "router": ParamMeta((d, e.n_experts), ("embed", None), scale=0.02),
        "wg": ParamMeta((e.n_experts, d, e.d_ff_expert),
                        ("experts", "embed", "ffn")),
        "wu": ParamMeta((e.n_experts, d, e.d_ff_expert),
                        ("experts", "embed", "ffn")),
        "wd": ParamMeta((e.n_experts, e.d_ff_expert, d),
                        ("experts", "ffn", "embed")),
        "norm": rmsnorm_meta(d),
    }
    if e.n_shared:
        meta["shared"] = {
            "wg": ParamMeta((d, e.d_ff_expert * e.n_shared), ("embed", "ffn")),
            "wu": ParamMeta((d, e.d_ff_expert * e.n_shared), ("embed", "ffn")),
            "wd": ParamMeta((e.d_ff_expert * e.n_shared, d), ("ffn", "embed")),
        }
    return meta


def moe_apply(params, x, cfg: ModelConfig, expert_sharding=None):
    """x: (B, S, d). Deterministic argsort dispatch with capacity drop.
    ``expert_sharding``: NamedSharding hint for the (E, capacity, d)
    dispatch buffers (expert-parallel over the model axis)."""
    def _eshard(t):
        if expert_sharding is not None:
            return jax.lax.with_sharding_constraint(t, expert_sharding)
        return t

    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)
    xf = xn.reshape(t, d)

    logits = (xf @ params["router"].astype(x.dtype)).astype(F32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, e.top_k)                  # (T, k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    flat_e = expert.reshape(-1)                                    # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), e.top_k)
    flat_g = gate.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e.n_experts),
                              side="left")
    pos_in_e = jnp.arange(t * e.top_k, dtype=jnp.int32) - starts[sorted_e]
    cap = max(8, int(t * e.top_k / e.n_experts * e.capacity_factor))
    if cap >= 128:  # shardable capacity (see expert_sharding)
        cap = -(-cap // 128) * 128
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, t * e.top_k)  # drop ->
    tok = flat_t[order]

    xbuf = jnp.zeros((e.n_experts * cap + 1, d), x.dtype)
    xbuf = xbuf.at[slot].set(xf[tok])
    xe = _eshard(xbuf[:-1].reshape(e.n_experts, cap, d))

    h = _eshard(jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                                       params["wg"].astype(x.dtype)))
                * jnp.einsum("ecd,edf->ecf", xe,
                             params["wu"].astype(x.dtype)))
    ye = _eshard(jnp.einsum("ecf,efd->ecd", h, params["wd"].astype(x.dtype)))
    ybuf = ye.reshape(e.n_experts * cap, d)

    contrib = jnp.where(keep, flat_g[order], 0.0)[:, None].astype(x.dtype) \
        * ybuf[jnp.minimum(slot, e.n_experts * cap - 1)]
    y = jnp.zeros((t, d), x.dtype).at[tok].add(contrib)

    if "shared" in params:
        sh = params["shared"]
        hs = jax.nn.silu(xf @ sh["wg"].astype(x.dtype)) \
            * (xf @ sh["wu"].astype(x.dtype))
        y = y + hs @ sh["wd"].astype(x.dtype)
    return y.reshape(b, s, d)


def moe_apply_shardmap(params, x, cfg: ModelConfig, mesh, dp_axes=None,
                       ep_axis: str = "model", fsdp: bool = False):
    """Expert-parallel MoE via shard_map: per-device LOCAL routing.

    Layout facts this exploits: activations x are sharded over the data
    axes and *replicated* across the model axis; expert weights are sharded
    over the model axis. So every device already holds (its token slice,
    its expert slice): route the local tokens locally, compute the local
    experts, combine partial outputs with one psum over the model axis —
    the same single collective a TP MLP needs. No global argsort, no
    cross-shard scatter (GSPMD's auto-partitioned global dispatch replicates
    those "as a last resort"). Capacity is enforced per (data shard,
    expert) — standard practice. Under FSDP the expert weights arrive
    data-sharded and are all-gathered per layer (the FSDP contract).
    """
    from jax.experimental.shard_map import shard_map
    e = cfg.moe
    b, s, d = x.shape

    wspec = P(ep_axis, "data" if fsdp else None, None)
    wdspec = P(ep_axis, None, "data" if fsdp else None)
    especs = {"router": P(), "norm": P(), "wg": wspec, "wu": wspec,
              "wd": wdspec}
    if "shared" in params:
        especs["shared"] = {
            "wg": P("data" if fsdp else None, ep_axis),
            "wu": P("data" if fsdp else None, ep_axis),
            "wd": P(ep_axis, "data" if fsdp else None)}
    xspec = P(dp_axes, None, None)

    def gather(w, ax):
        return (jax.lax.all_gather(w, "data", axis=ax, tiled=True)
                if fsdp else w)

    def local(p, xl):
        bl, sl, _ = xl.shape
        t = bl * sl
        xn = rmsnorm(xl, p["norm"], cfg.norm_eps)
        xf = xn.reshape(t, d)
        logits = (xf @ p["router"].astype(xl.dtype)).astype(F32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert = jax.lax.top_k(probs, e.top_k)
        gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True),
                                  1e-9)
        flat_e = expert.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), e.top_k)
        flat_g = gate.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(e.n_experts),
                                  side="left")
        pos = jnp.arange(t * e.top_k, dtype=jnp.int32) - starts[sorted_e]
        cap = max(8, int(t * e.top_k / e.n_experts * e.capacity_factor))
        keep = pos < cap
        # keep only this device's experts
        wg = gather(p["wg"], 1)
        wu = gather(p["wu"], 1)
        wd = gather(p["wd"], 2)
        e_loc = wg.shape[0]
        e_lo = jax.lax.axis_index(ep_axis) * e_loc
        mine = (sorted_e >= e_lo) & (sorted_e < e_lo + e_loc) & keep
        slot = jnp.where(mine, (sorted_e - e_lo) * cap + pos, e_loc * cap)
        tok = flat_t[order]
        xbuf = jnp.zeros((e_loc * cap + 1, d), xl.dtype)
        xbuf = xbuf.at[slot].set(jnp.where(mine[:, None], xf[tok], 0))
        xe = xbuf[:-1].reshape(e_loc, cap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                                   wg.astype(xl.dtype))) \
            * jnp.einsum("ecd,edf->ecf", xe, wu.astype(xl.dtype))
        ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(xl.dtype))
        ybuf = ye.reshape(e_loc * cap, d)
        contrib = jnp.where(mine, flat_g[order], 0.0)[:, None].astype(
            xl.dtype) * ybuf[jnp.minimum(slot, e_loc * cap - 1)]
        y = jnp.zeros((t, d), xl.dtype).at[tok].add(contrib)
        if "shared" in p:
            sh = p["shared"]
            swg = gather(sh["wg"], 0)
            swu = gather(sh["wu"], 0)
            swd = gather(sh["wd"], 1)
            hs = jax.nn.silu(xf @ swg.astype(xl.dtype)) \
                * (xf @ swu.astype(xl.dtype))
            y = y + hs @ swd.astype(xl.dtype)
        y = jax.lax.psum(y, ep_axis)
        return y.reshape(bl, sl, d)

    try:
        sm = shard_map(local, mesh=mesh, in_specs=(especs, xspec),
                       out_specs=xspec, check_vma=False)
    except TypeError:  # older jax: check_rep
        sm = shard_map(local, mesh=mesh, in_specs=(especs, xspec),
                       out_specs=xspec, check_rep=False)
    return sm(params, x)


def moe_aux_loss(params, x, cfg: ModelConfig):
    """Load-balancing auxiliary loss (Switch-style)."""
    e = cfg.moe
    b, s, d = x.shape
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)
    logits = (xn.reshape(-1, d) @ params["router"].astype(x.dtype)).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, expert = jax.lax.top_k(probs, e.top_k)
    counts = jnp.zeros(e.n_experts, F32).at[expert.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(jnp.sum(counts), 1.0)
    frac_probs = jnp.mean(probs, axis=0)
    return e.n_experts * jnp.sum(frac_tokens * frac_probs)


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state space duality, chunked scan)
# ---------------------------------------------------------------------------
def mamba_meta(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_dim = di + 2 * s.n_groups * s.d_state
    return {
        "in_proj": ParamMeta(
            (d, 2 * di + 2 * s.n_groups * s.d_state + nh),
            ("embed", "heads_dh")),
        "conv_w": ParamMeta((s.conv_width, conv_dim), (None, "heads_dh"),
                            scale=0.5),
        "conv_b": ParamMeta((conv_dim,), ("heads_dh",), init="zeros"),
        "a_log": ParamMeta((nh,), ("heads",), init="zeros"),
        "d_skip": ParamMeta((nh,), ("heads",), init="ones"),
        "dt_bias": ParamMeta((nh,), ("heads",), init="zeros"),
        "out_norm": ParamMeta((di,), ("heads_dh",), init="ones"),
        "out_proj": ParamMeta((di, d), ("heads_dh", "embed")),
        "norm": rmsnorm_meta(d),
    }


def _mamba_split(params, xn, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    gn = s.n_groups * s.d_state
    nh = s.n_heads(d)
    proj = xn @ params["in_proj"].astype(xn.dtype)
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * gn], axis=-1)
    return z, xbc, dt, di, gn, nh


def _causal_conv(xbc, w, b, prev=None):
    """Depthwise causal conv along seq. xbc: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([prev, xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(width):
        out = out + xp[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype)
    return jax.nn.silu(out + b.astype(xbc.dtype)), xp[:, -(width - 1):]


def mamba_apply(params, x, cfg: ModelConfig):
    """Chunked SSD forward (training/prefill). Returns (out, final_state)."""
    s = cfg.ssm
    b, S0, d = x.shape
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)
    # pad at the FRONT to a whole number of chunks: with zero inputs and a
    # zero initial state this is exact (zero tokens add nothing; decay of a
    # zero state is zero), unlike tail padding which would corrupt the
    # carried-out state.
    front = (-S0) % min(s.chunk, max(S0, 1))
    if front:
        xn = jnp.pad(xn, ((0, 0), (front, 0), (0, 0)))
    S = S0 + front
    z, xbc, dt, di, gn, nh = _mamba_split(params, xn, cfg)
    xbc, conv_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs, B_, C_ = jnp.split(xbc, [di, di + gn], axis=-1)
    p = s.head_dim
    n = s.d_state
    g = s.n_groups
    xs = xs.reshape(b, S, nh, p)
    # Keep B/C in their (G << heads) group form: broadcasting them to all
    # heads would materialize (B,S,heads,N) tensors (0.5 GiB+ at scale) and
    # make the inter-position dot products redundantly per-head.
    B_ = B_.reshape(b, S, g, n)
    C_ = C_.reshape(b, S, g, n)
    dt = jax.nn.softplus(dt.astype(F32)
                         + params["dt_bias"].astype(F32))   # (B,S,nh)
    a = -jnp.exp(params["a_log"].astype(F32))               # (nh,)
    da = dt * a                                             # (B,S,nh)

    cl = min(s.chunk, S)
    assert S % cl == 0
    nc = S // cl
    hg = nh // g                                            # heads per group

    # checkpointed: the chunk scan's backward otherwise saves every chunk's
    # (cl x cl x heads) decay/score matrices across all chunks & layers
    @jax.checkpoint
    def chunk_fn(state, inp):
        # xc (B,cl,nh,P); bc/cc (B,cl,G,N); dac/dtc (B,cl,nh)
        xc, bc, cc, dac, dtc = inp
        cum = jnp.cumsum(dac, axis=1)                       # (B,cl,nh)
        seg = cum[:, :, None, :] - cum[:, None, :, :]       # (B,i,j,nh)
        il = jnp.arange(cl)
        causal = il[:, None] >= il[None, :]
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        sc = jnp.einsum("bign,bjgn->bijg", cc.astype(F32),
                        bc.astype(F32))                     # (B,i,j,G)
        sch = jnp.repeat(sc, hg, axis=3) if g > 1 else sc   # broadcast ok
        w = sch * L * dtc[:, None, :, :]                    # (B,i,j,nh)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xc.astype(F32))
        # contribution of carried-in state (state: (B,nh,N,P))
        if g == 1:
            y_inter = jnp.einsum(
                "bin,bhnp->bihp", cc[:, :, 0].astype(F32), state) \
                * jnp.exp(cum)[..., None]
        else:
            cexp = jnp.repeat(cc, hg, axis=2).astype(F32) \
                * jnp.exp(cum)[..., None]
            y_inter = jnp.einsum("bihn,bhnp->bihp", cexp, state)
        # new state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)        # (B,cl,nh)
        if g == 1:
            sstate = jnp.einsum("bjn,bjh,bjhp->bhnp",
                                bc[:, :, 0].astype(F32),
                                (dtc * decay_to_end),
                                xc.astype(F32))
        else:
            bch = jnp.repeat(bc, hg, axis=2).astype(F32)
            sstate = jnp.einsum("bjhn,bjh,bjhp->bhnp", bch,
                                (dtc * decay_to_end), xc.astype(F32))
        state = state * jnp.exp(cum[:, -1])[..., None, None] + sstate
        return state, (y_intra + y_inter)

    xs_c = xs.reshape(b, nc, cl, nh, p).swapaxes(0, 1)
    B_c = B_.reshape(b, nc, cl, g, n).swapaxes(0, 1)
    C_c = C_.reshape(b, nc, cl, g, n).swapaxes(0, 1)
    da_c = da.reshape(b, nc, cl, nh).swapaxes(0, 1)
    dt_c = dt.reshape(b, nc, cl, nh).swapaxes(0, 1)
    state0 = jnp.zeros((b, nh, n, p), F32)
    final_state, ys = jax.lax.scan(chunk_fn, state0,
                                   (xs_c, B_c, C_c, da_c, dt_c))
    y = ys.swapaxes(0, 1).reshape(b, S, nh, p)
    y = y + xs.astype(F32) * params["d_skip"].astype(F32)[None, None, :, None]
    y = y.reshape(b, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    out = (y @ params["out_proj"].astype(x.dtype))[:, front:]
    return out, {"state": final_state, "conv": conv_tail}


def mamba_decode(params, x, cache, cfg: ModelConfig):
    """Single-token recurrent step. cache: {"state": (B,nh,N,P),
    "conv": (B,W-1,conv_dim)}."""
    s = cfg.ssm
    b = x.shape[0]
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)
    z, xbc, dt, di, gn, nh = _mamba_split(params, xn, cfg)
    xbc, conv_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                  prev=cache["conv"])
    xs, B_, C_ = jnp.split(xbc, [di, di + gn], axis=-1)
    p, n, g = s.head_dim, s.d_state, s.n_groups
    rep = nh // g
    xs = xs.reshape(b, nh, p)
    Bh = jnp.repeat(B_.reshape(b, g, n), rep, axis=1)
    Ch = jnp.repeat(C_.reshape(b, g, n), rep, axis=1)
    dt1 = jax.nn.softplus(dt.astype(F32)[:, 0]
                          + params["dt_bias"].astype(F32))   # (B,nh)
    a = -jnp.exp(params["a_log"].astype(F32))
    decay = jnp.exp(dt1 * a)                                 # (B,nh)
    state = cache["state"] * decay[..., None, None] \
        + jnp.einsum("bhn,bh,bhp->bhnp", Bh.astype(F32), dt1,
                     xs.astype(F32))
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(F32), state)
    y = y + xs.astype(F32) * params["d_skip"].astype(F32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(x.dtype)
    return out, {"state": state, "conv": conv_tail}
