"""Generic decoder LM assembled from the layer library.

One model class covers all 10 assigned architectures:

* mixer pattern per layer ("attn" | "mamba" | "xattn"), cycled with period P;
* optional MoE MLPs every k-th layer;
* optional MLA attention (DeepSeek);
* optional encoder stack + per-decoder-layer cross attention (Whisper);
* optional auxiliary-embedding cross attention (Llama-3.2 Vision).

Layers are stacked into R = n_layers / P "super-layers" and executed with
``jax.lax.scan`` over the stacked parameters, keeping HLO size and compile
time independent of depth; ``jax.checkpoint`` wraps the super-layer body
(full remat — only block inputs are saved).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.meta import ParamMeta, is_meta

F32 = jnp.float32


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _mask_pad_vocab(logits, cfg: ModelConfig):
    """Force pad-vocab logits to -inf (keeps the padded table inert)."""
    if cfg.vocab_padded == cfg.vocab:
        return logits
    ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(ids < cfg.vocab, logits, -1e30)


def _stack_meta(meta_tree, r: int):
    """Add a leading stacked-layers axis of size r to every ParamMeta."""
    return jax.tree_util.tree_map(
        lambda m: ParamMeta((r,) + m.shape, ("layers",) + m.logical,
                            init=m.init, scale=m.scale, dtype=m.dtype),
        meta_tree, is_leaf=is_meta)


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.period = len(cfg.pattern)
        if cfg.moe is not None:
            import math
            self.period = math.lcm(self.period, cfg.moe.every)
        assert cfg.n_layers % self.period == 0, (cfg.name, self.period)
        self.repeats = cfg.n_layers // self.period
        # Optional sequence-parallel activation sharding (Megatron-SP): a
        # NamedSharding for (B, S, d) residual-stream activations, applied
        # at super-layer boundaries. The saved-for-backward layer inputs
        # then shard over the model axis instead of being replicated.
        self.act_sharding = None
        # Expert-major MoE dispatch-buffer sharding hint (EP): without it
        # GSPMD may replicate the (E, capacity, d) buffers.
        self.moe_sharding = None
        # shard_map MoE execution plan: {"mesh", "dp_axes", "fsdp"} — the
        # production EP path (local routing + psum); None = GSPMD dispatch.
        self.moe_exec = None
        # int8 KV cache (decode): None = config dtype.
        self.kv_cache_dtype = None
        # Boundary-SP: pair of (sharded, interior) NamedShardings. The scan
        # carry (== the remat-saved layer input) is pinned to `sharded`
        # (seq over model) while the layer interior is pinned back to
        # `interior`, so saved activations shard over the model axis
        # without re-partitioning the whole layer along the sequence.
        self.boundary_sp = None

    def _moe(self, p, x):
        if self.moe_exec is not None:
            return L.moe_apply_shardmap(p, x, self.cfg, **self.moe_exec)
        return L.moe_apply(p, x, self.cfg,
                           expert_sharding=self.moe_sharding)

    def _constrain(self, x):
        if self.act_sharding is not None and x.ndim == 3 \
                and x.shape[1] % self.act_sharding.mesh.shape.get(
                    "model", 1) == 0:
            return jax.lax.with_sharding_constraint(x, self.act_sharding)
        return x

    # ------------------------------------------------------------ metadata
    def _sublayer_meta(self, j: int) -> dict:
        cfg = self.cfg
        kind = cfg.layer_kind(j)
        meta: dict = {}
        if kind == "attn":
            meta["mixer"] = (L.mla_meta(cfg) if cfg.attn_kind == "mla"
                             else L.attn_meta(cfg))
        elif kind == "mamba":
            meta["mixer"] = L.mamba_meta(cfg)
        elif kind == "xattn":
            meta["mixer"] = L.attn_meta(cfg, cross=True)
        else:
            raise ValueError(kind)
        if cfg.n_encoder_layers and kind == "attn":
            meta["xattn"] = L.attn_meta(cfg, cross=True)  # enc-dec cross
        if cfg.is_moe_layer(j):
            meta["mlp"] = L.moe_meta(cfg)
        elif cfg.d_ff > 0:
            meta["mlp"] = L.mlp_meta(cfg)   # Mamba2 blocks have no MLP
        return meta

    def param_meta(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        meta: dict = {
            "embed": ParamMeta((cfg.vocab_padded, d), ("vocab", "embed"),
                               scale=0.02),
            "final_norm": L.rmsnorm_meta(d),
            "layers": _stack_meta(
                {f"sub{j}": self._sublayer_meta(j)
                 for j in range(self.period)}, self.repeats),
        }
        if not cfg.tie_embeddings:
            meta["unembed"] = ParamMeta((d, cfg.vocab_padded),
                                        ("embed", "vocab"))
        if cfg.n_encoder_layers:
            meta["encoder"] = {
                "layers": _stack_meta(
                    {"attn": L.attn_meta(cfg), "mlp": L.mlp_meta(cfg)},
                    cfg.n_encoder_layers),
                "final_norm": L.rmsnorm_meta(d),
            }
        return meta

    def init(self, key: jax.Array):
        from repro.models.meta import materialize
        return materialize(self.param_meta(), key, dtype=_dtype(self.cfg))

    # ------------------------------------------------------------- encoder
    def encode(self, params, aux):
        """Whisper-style bidirectional encoder over frame embeddings."""
        cfg = self.cfg

        def body(x, p):
            a, _ = L.attn_apply(p["attn"], x, cfg, causal=False)
            x = x + a
            x = x + L.mlp_apply(p["mlp"], x, cfg)
            return x, None

        x, _ = jax.lax.scan(jax.checkpoint(body), aux,
                            params["encoder"]["layers"])
        return L.rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)

    def _aux_memory(self, params, aux):
        """The cross-attention memory: encoder output (enc-dec) or the
        auxiliary embeddings themselves (vision)."""
        if aux is None:
            return None
        if self.cfg.n_encoder_layers:
            return self.encode(params, aux)
        return aux

    # ------------------------------------------------------------- forward
    def _superlayer(self, x, p, memory, with_cache: bool, aux_loss0):
        cfg = self.cfg
        caches = {}
        aux_loss = aux_loss0
        for j in range(self.period):
            sp = p[f"sub{j}"]
            kind = cfg.layer_kind(j)
            if kind == "attn":
                if cfg.attn_kind == "mla":
                    a, kv = L.mla_apply(sp["mixer"], x, cfg)
                    if with_cache:
                        caches[f"sub{j}"] = {"ckv": kv[0], "kr": kv[1]}
                else:
                    a, kv = L.attn_apply(sp["mixer"], x, cfg, causal=True)
                    if with_cache:
                        caches[f"sub{j}"] = {"k": kv[0], "v": kv[1]}
                x = x + a
                if cfg.n_encoder_layers:
                    xkv = L.xattn_kv(sp["xattn"], memory, cfg)
                    x = x + L.xattn_apply(sp["xattn"], x, xkv, cfg)
                    if with_cache:
                        caches[f"sub{j}_x"] = {"k": xkv[0], "v": xkv[1]}
            elif kind == "mamba":
                a, state = L.mamba_apply(sp["mixer"], x, cfg)
                x = x + a
                if with_cache:
                    caches[f"sub{j}"] = state
            elif kind == "xattn":
                xkv = L.xattn_kv(sp["mixer"], memory, cfg)
                x = x + L.xattn_apply(sp["mixer"], x, xkv, cfg)
                if with_cache:
                    caches[f"sub{j}"] = {"k": xkv[0], "v": xkv[1]}
            if cfg.is_moe_layer(j):
                aux_loss = aux_loss + L.moe_aux_loss(sp["mlp"], x, cfg)
                x = x + self._moe(sp["mlp"], x)
            elif "mlp" in sp:
                x = x + L.mlp_apply(sp["mlp"], x, cfg)
        return x, caches, aux_loss

    def forward(self, params, tokens, aux=None, with_cache: bool = False,
                logits_last_only: bool = False):
        """tokens (B, S) -> logits (B, S, V). Optionally returns the stacked
        per-layer caches (prefill). ``logits_last_only`` skips the full
        (B, S, V) unembedding — prefill needs only the last position."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
        memory = self._aux_memory(params, aux)

        x = self._constrain(x)
        bsp = self.boundary_sp
        if bsp is not None:
            x = jax.lax.with_sharding_constraint(x, bsp[0])

        def body(carry, p):
            x, aux_loss = carry
            if bsp is not None:
                x = jax.lax.with_sharding_constraint(x, bsp[1])
            x, caches, aux_loss = self._superlayer(x, p, memory,
                                                   with_cache, aux_loss)
            if bsp is not None:
                x = jax.lax.with_sharding_constraint(x, bsp[0])
            return (self._constrain(x), aux_loss), caches

        body_fn = jax.checkpoint(body) if not with_cache else body
        (x, aux_loss), caches = jax.lax.scan(
            body_fn, (x, jnp.zeros((), F32)), params["layers"])
        if logits_last_only:
            x = x[:, -1:]
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        unembed = (params["embed"].T if cfg.tie_embeddings
                   else params["unembed"])
        logits = (x @ unembed.astype(x.dtype)).astype(F32)
        logits = _mask_pad_vocab(logits, cfg)
        if with_cache:
            return logits, caches, aux_loss
        return logits, aux_loss

    def loss(self, params, batch):
        logits, aux_loss = self.forward(params, batch["tokens"],
                                        aux=batch.get("aux"))
        labels = batch["labels"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        nll = jnp.mean(logz - gold)
        return nll + 0.01 * aux_loss, {"nll": nll, "aux_loss": aux_loss}

    # ------------------------------------------------------------- serving
    def prefill(self, params, tokens, aux=None, max_len: int | None = None):
        """Run the full prompt, return (last-token logits, decode cache)."""
        cfg = self.cfg
        logits, caches, _ = self.forward(params, tokens, aux=aux,
                                         with_cache=True,
                                         logits_last_only=True)
        s = tokens.shape[1]
        max_len = max_len or s
        caches = self._grow_caches(caches, s, max_len)
        caches["pos"] = jnp.asarray(s, jnp.int32)
        return logits[:, -1], caches

    def _grow_caches(self, caches, s: int, max_len: int):
        """Pad seq axis of stacked KV caches (axis 2: layers, batch, seq)."""
        if max_len <= s:
            return caches

        def pad(x):
            if x.ndim >= 3 and x.shape[2] == s:
                widths = [(0, 0)] * x.ndim
                widths[2] = (0, max_len - s)
                return jnp.pad(x, widths)
            return x

        return jax.tree_util.tree_map(pad, caches)

    def init_cache_meta(self, batch: int, max_len: int) -> dict:
        """Abstract decode-cache structure (for dry-run input_specs)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        caches: dict = {}
        for j in range(self.period):
            kind = cfg.layer_kind(j)
            r = self.repeats
            if kind == "attn":
                if cfg.attn_kind == "mla":
                    m = cfg.mla
                    caches[f"sub{j}"] = {
                        "ckv": ParamMeta((r, batch, max_len, m.kv_lora),
                                         ("layers", "batch", "kv_seq", None),
                                         dtype=dt),
                        "kr": ParamMeta((r, batch, max_len, m.d_rope),
                                        ("layers", "batch", "kv_seq", None),
                                        dtype=dt),
                    }
                else:
                    kvdt = self.kv_cache_dtype or dt
                    caches[f"sub{j}"] = {
                        "k": ParamMeta(
                            (r, batch, max_len, cfg.n_kv, cfg.d_head),
                            ("layers", "batch", "kv_seq", "kv_heads", None),
                            dtype=kvdt),
                        "v": ParamMeta(
                            (r, batch, max_len, cfg.n_kv, cfg.d_head),
                            ("layers", "batch", "kv_seq", "kv_heads", None),
                            dtype=kvdt),
                    }
                    if self.kv_cache_dtype is not None:
                        for key in ("k_s", "v_s"):
                            caches[f"sub{j}"][key] = ParamMeta(
                                (r, batch, max_len, cfg.n_kv, 1),
                                ("layers", "batch", "kv_seq", "kv_heads",
                                 None), dtype=jnp.float32)
                if cfg.n_encoder_layers:
                    caches[f"sub{j}_x"] = self._xattn_cache_meta(batch)
            elif kind == "mamba":
                s = cfg.ssm
                nh = s.n_heads(cfg.d_model)
                conv_dim = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state
                caches[f"sub{j}"] = {
                    "state": ParamMeta((r, batch, nh, s.d_state, s.head_dim),
                                       ("layers", "batch", "heads",
                                        None, None), dtype=jnp.float32),
                    "conv": ParamMeta(
                        (r, batch, s.conv_width - 1, conv_dim),
                        ("layers", "batch", None, "heads_dh"), dtype=dt),
                }
            elif kind == "xattn":
                caches[f"sub{j}"] = self._xattn_cache_meta(batch)
        caches["pos"] = ParamMeta((), (), dtype=jnp.int32)
        return caches

    def _xattn_cache_meta(self, batch: int):
        cfg = self.cfg
        dt = _dtype(cfg)
        return {
            "k": ParamMeta((self.repeats, batch, cfg.aux_seq, cfg.n_kv,
                            cfg.d_head),
                           ("layers", "batch", None, "kv_heads", None),
                           dtype=dt),
            "v": ParamMeta((self.repeats, batch, cfg.aux_seq, cfg.n_kv,
                            cfg.d_head),
                           ("layers", "batch", None, "kv_heads", None),
                           dtype=dt),
        }

    def decode_step(self, params, caches, tokens):
        """tokens (B, 1) -> (logits (B, V), updated caches)."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
        pos = caches["pos"]
        layer_caches = {k: v for k, v in caches.items() if k != "pos"}

        def body(x, p_and_c):
            p, c = p_and_c
            new_c = {}
            for j in range(self.period):
                sp = p[f"sub{j}"]
                kind = cfg.layer_kind(j)
                if kind == "attn":
                    sub = dict(c[f"sub{j}"])
                    sub["pos"] = pos
                    if cfg.attn_kind == "mla":
                        a, nc = L.mla_decode(sp["mixer"], x, sub, cfg)
                    else:
                        a, nc = L.attn_decode(sp["mixer"], x, sub, cfg)
                    nc.pop("pos")
                    new_c[f"sub{j}"] = nc
                    x = x + a
                    if cfg.n_encoder_layers:
                        xc = c[f"sub{j}_x"]
                        x = x + L.xattn_apply(sp["xattn"], x,
                                              (xc["k"], xc["v"]), cfg)
                        new_c[f"sub{j}_x"] = xc
                elif kind == "mamba":
                    a, nc = L.mamba_decode(sp["mixer"], x, c[f"sub{j}"], cfg)
                    new_c[f"sub{j}"] = nc
                    x = x + a
                elif kind == "xattn":
                    xc = c[f"sub{j}"]
                    x = x + L.xattn_apply(sp["mixer"], x,
                                          (xc["k"], xc["v"]), cfg)
                    new_c[f"sub{j}"] = xc
                if cfg.is_moe_layer(j):
                    x = x + self._moe(sp["mlp"], x)
                elif "mlp" in sp:
                    x = x + L.mlp_apply(sp["mlp"], x, cfg)
            return x, new_c

        x, new_layer_caches = jax.lax.scan(
            body, x, (params["layers"], layer_caches))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        unembed = (params["embed"].T if cfg.tie_embeddings
                   else params["unembed"])
        logits = _mask_pad_vocab((x[:, 0] @ unembed.astype(x.dtype))
                                 .astype(F32), cfg)
        new_caches: dict = dict(new_layer_caches)
        new_caches["pos"] = pos + 1
        return logits, new_caches
