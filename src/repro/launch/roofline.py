"""Roofline analysis (deliverable g): three terms per (arch x mesh) cell.

    compute    = HLO_FLOPs_per_device            / peak_FLOPs_per_chip
    memory     = HLO_traffic_bytes_per_device    / HBM_bandwidth_per_chip
    collective = collective_bytes_per_device     / ICI_link_bandwidth

All inputs are per-device numbers from the SPMD-partitioned module (the
dry-run JSON artifacts), already multiplied by while-loop trip counts
(see hlo_analysis.py — XLA's own cost_analysis() visits loop bodies once).

Caveats recorded with every table: the traffic term is an HBM proxy parsed
from CPU-backend HLO (fusion boundaries and loop copies differ on real TPU;
plain copies are excluded), so its absolute value is an upper-bound estimate
— the per-cell *dominant term* and the before/after deltas in §Perf are the
meaningful outputs.

MODEL_FLOPS uses 6·N·D for training (N = active params for MoE) and 2·N·D
for inference forward passes; the MODEL/HLO ratio flags remat and padding
waste (train with full remat recomputes the forward => ratio ~0.75 of the
no-waste 6ND accounting is expected... values far below that indicate real
redundancy).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_LINK_BW = 50e9              # B/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_device: float
    hlo_flops_per_device: float
    peak_gib: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the binding roofline that useful compute occupies:
        (model_flops / peak) / max(term). 1.0 = compute-bound at peak."""
        ideal = self.model_flops_per_device / PEAK_FLOPS_BF16
        return ideal / max(self.bound_s, 1e-30)

    @property
    def flops_ratio(self) -> float:
        return self.model_flops_per_device / max(self.hlo_flops_per_device,
                                                 1e-30)


def model_flops_per_device(cfg, shape, n_devices: int) -> float:
    """6ND (train) / 2ND (inference) useful-model FLOPs per device."""
    n_active = cfg.active_params_estimate()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices


def from_artifact(art: dict) -> Roofline:
    from repro.configs import registry
    cfg = registry.get_config(art["arch"], smoke=art.get("smoke", False))
    shape = registry.SHAPES[art["shape"]]
    mf = model_flops_per_device(cfg, shape, art["n_devices"])
    return Roofline(
        arch=art["arch"], shape=art["shape"], mesh=art["mesh"],
        compute_s=art["hlo_flops_per_device"] / PEAK_FLOPS_BF16,
        memory_s=art["hlo_traffic_bytes_per_device"] / HBM_BW,
        collective_s=art["collective_total_bytes_per_device"] / ICI_LINK_BW,
        model_flops_per_device=mf,
        hlo_flops_per_device=art["hlo_flops_per_device"],
        peak_gib=art.get("memory", {}).get("peak_bytes_est", 0) / 2 ** 30,
    )


def load_artifacts(directory: str = "artifacts/dryrun",
                   mesh_tag: str | None = "16x16") -> list[Roofline]:
    out = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            art = json.load(f)
        if mesh_tag and art["mesh"] != mesh_tag:
            continue
        out.append(from_artifact(art))
    return out


def table(rows: list[Roofline]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collect':>10s} {'dominant':>10s} {'roofl%':>7s} "
           f"{'6ND/HLO':>8s} {'peakGiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.compute_s:10.3e} "
            f"{r.memory_s:10.3e} {r.collective_s:10.3e} {r.dominant:>10s} "
            f"{100*r.roofline_fraction:6.1f}% {r.flops_ratio:8.2f} "
            f"{r.peak_gib:8.2f}")
    return "\n".join(lines)


def main():
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="artifacts/dryrun")
    p.add_argument("--mesh", default="16x16")
    args = p.parse_args()
    rows = load_artifacts(args.dir, args.mesh)
    print(table(rows))


if __name__ == "__main__":
    main()
