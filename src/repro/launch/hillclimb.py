import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Reproduce the EXPERIMENTS.md §Perf hillclimbs (before/after per
iteration). Each variant is a real framework configuration; the flash-
kernel memory substitution uses the measured score-tile traffic (see
hlo_analysis.HloReport.kernel_adjusted_traffic).

    python -m repro.launch.hillclimb [--cell yi_train|yi_prefill|granite_decode]
"""

import argparse

from repro.launch import roofline


def _row(tag, res, kernel_sub=False):
    traffic = (res["kernel_adjusted_traffic_bytes_per_device"] if kernel_sub
               else res["hlo_traffic_bytes_per_device"])
    comp = res["hlo_flops_per_device"] / roofline.PEAK_FLOPS_BF16
    mem = traffic / roofline.HBM_BW
    coll = res["collective_total_bytes_per_device"] / roofline.ICI_LINK_BW
    peak = res.get("memory", {}).get("peak_bytes_est", 0) / 2 ** 30
    print(f"  {tag:34s} compute={comp:8.2f}s memory={mem:8.2f}s "
          f"collective={coll:8.2f}s bound={max(comp, mem, coll):8.2f}s "
          f"peak={peak:6.2f}GiB")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--cell", default="all",
                   choices=("all", "yi_train", "yi_prefill",
                            "granite_decode"))
    args = p.parse_args()

    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=False)

    if args.cell in ("all", "yi_train"):
        print("H1: yi-34b train_4k (most collective-bound)")
        base = steps.dryrun_cell("yi-34b", "train_4k", mesh,
                                 multi_pod=False, zero1=False, fsdp=True)
        _row("baseline (FSDP + boundary-SP)", base)
        it1 = steps.dryrun_cell("yi-34b", "train_4k", mesh,
                                multi_pod=False, zero1=True,
                                interior_pin=True)
        _row("iter1: ZeRO-1 + interior pin", it1)
        _row("iter2: + flash-kernel memory", it1, kernel_sub=True)

    if args.cell in ("all", "yi_prefill"):
        print("H2: yi-34b prefill_32k (worst roofline fraction)")
        # the baseline predates the prefill fixes; reproduce its numbers
        # from the archived artifact if present, then measure current code
        import json
        bpath = "artifacts/dryrun/yi-34b__prefill_32k__16x16.json"
        if os.path.exists(bpath):
            _row("baseline (archived)", json.load(open(bpath)))
        cur = steps.dryrun_cell("yi-34b", "prefill_32k", mesh,
                                multi_pod=False)
        _row("iter1: pin+cache-shard+last-logit", cur)
        _row("iter2: + flash-kernel memory", cur, kernel_sub=True)

    if args.cell in ("all", "granite_decode"):
        print("H3: granite-8b decode_32k (paper-representative)")
        base = steps.dryrun_cell("granite-8b", "decode_32k", mesh,
                                 multi_pod=False)
        _row("baseline (bf16 KV cache)", base)
        q = steps.dryrun_cell("granite-8b", "decode_32k", mesh,
                              multi_pod=False, kv_cache_dtype="int8")
        _row("int8 KV cache encoding", q)


if __name__ == "__main__":
    main()
