"""Step builders + the dry-run cell pipeline (mesh-agnostic).

`dryrun_cell` is the heart of deliverable (e): build the step function for
an (arch x shape) cell, shard everything by the cell plan, lower + compile
against ShapeDtypeStructs (no allocation), and extract memory / cost /
collective statistics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.launch import hlo_analysis
from repro.models.lm import LM
from repro.models.meta import abstractify, specs_for
from repro.optim import adamw
from repro.sharding import rules as R


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------
def make_train_step(lm: LM, ocfg: adamw.AdamWConfig,
                    microbatches: int = 1, grad_dtype=jnp.float32,
                    mb_sharding=None):
    """Gradient-accumulating train step. With k > 1 microbatches the batch
    is split (k, B/k, ...) and per-microbatch grads are averaged with a
    scan — saved-activation memory scales with B/k, not B.

    ``mb_sharding(leaf)`` re-pins the split batch's sharding: the
    (B,) -> (k, B/k) reshape otherwise loses the batch partitioning and
    every microbatch silently runs replicated (k x the flops)."""
    grad_fn = jax.value_and_grad(lm.loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, extras), grads = grad_fn(params, batch)
        else:
            def split(x):
                y = x.reshape(microbatches, x.shape[0] // microbatches,
                              *x.shape[1:])
                return mb_sharding(y) if mb_sharding is not None else y
            mbs = jax.tree_util.tree_map(split, batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, grad_dtype), params)

            def mb_body(carry, mb):
                g_acc, loss_acc, nll_acc, aux_acc = carry
                (loss, extras), grads = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(grad_dtype) / microbatches,
                    g_acc, grads)
                return (g_acc, loss_acc + loss / microbatches,
                        nll_acc + extras["nll"] / microbatches,
                        aux_acc + extras["aux_loss"] / microbatches), None

            (grads, loss, nll, aux), _ = jax.lax.scan(
                mb_body, (g0, jnp.zeros((), jnp.float32),
                          jnp.zeros((), jnp.float32),
                          jnp.zeros((), jnp.float32)), mbs)
            extras = {"nll": nll, "aux_loss": aux}
        params, opt_state, om = adamw.update(grads, opt_state, params, ocfg)
        metrics = {"loss": loss, "nll": extras["nll"],
                   "aux_loss": extras["aux_loss"], **om}
        return params, opt_state, metrics
    return train_step


def make_prefill_step(lm: LM):
    def prefill_step(params, batch):
        return lm.prefill(params, batch["tokens"], aux=batch.get("aux"))
    return prefill_step


def make_decode_step(lm: LM):
    def decode_step(params, caches, tokens):
        return lm.decode_step(params, caches, tokens)
    return decode_step


# ---------------------------------------------------------------------------
# Cell assembly
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: Any
    lm: LM
    plan: R.CellPlan
    mesh: Any
    jitted: Any            # the jit-wrapped step
    example_args: tuple    # ShapeDtypeStructs, shardings attached
    kind: str


def shard_tree(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree (shared with serve.py)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


_shard = shard_tree  # internal alias used below


def _with_sharding(sds_tree, shard_tree):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, shard_tree)


def build_cell(arch: str, shape_name: str, mesh, *, multi_pod: bool,
               smoke: bool = False, batch_override: int | None = None,
               fsdp: bool | None = None, seq_parallel: bool = False,
               zero1: bool = False, interior_pin: bool = False,
               kv_cache_dtype=None) -> Cell:
    cfg = registry.get_config(arch, smoke=smoke)
    spec = registry.SHAPES[shape_name]
    gb = batch_override or spec.global_batch
    plan = R.plan_for(cfg, spec.kind, gb, mesh, multi_pod,
                      seq_len=spec.seq_len)
    if zero1:
        # ZeRO-1: weights TP-only (fsdp=False), optimizer state data-sharded
        plan = dataclasses.replace(
            plan, fsdp=False, zero1=True,
            rules=R.make_rules(cfg, multi_pod=multi_pod, fsdp=False,
                               kv_seq_axis=plan.rules.rules.get("kv_seq")))
    if fsdp is not None:
        plan = dataclasses.replace(
            plan, fsdp=fsdp,
            rules=R.make_rules(cfg, multi_pod=multi_pod, fsdp=fsdp,
                               kv_seq_axis=plan.rules.rules.get("kv_seq")))
    lm = LM(cfg)
    if kv_cache_dtype is not None:
        lm.kv_cache_dtype = jnp.dtype(kv_cache_dtype)
    if cfg.moe is not None:
        # Production EP path: shard_map local routing + single psum (see
        # layers.moe_apply_shardmap). Without it, GSPMD's auto-partitioned
        # global dispatch replicates scatters and idles the data axis.
        baxes0 = R.batch_axes(multi_pod)
        n_d = 1
        msh = dict(zip(mesh.axis_names, mesh.devices.shape))
        for a in baxes0:
            n_d *= msh.get(a, 1)
        dp = baxes0 if gb % n_d == 0 else None
        lm.moe_exec = {"mesh": mesh, "dp_axes": dp, "fsdp": plan.fsdp}
    if seq_parallel and spec.kind in ("train", "prefill"):
        baxes0 = R.batch_axes(multi_pod)
        lm.act_sharding = NamedSharding(mesh, P(baxes0, "model", None))
    # Boundary-SP: shard remat-saved layer inputs over the model axis.
    # Effective for attention-only stacks; SSM blocks reshard badly under
    # it (measured: jamba peak rose 39 -> 66 GiB), so hybrid/SSM skip it.
    if plan.fsdp and spec.kind == "train" \
            and spec.seq_len % mesh.shape.get("model", 1) == 0 \
            and "mamba" not in cfg.pattern:
        baxes0 = R.batch_axes(multi_pod)
        lm.boundary_sp = (
            NamedSharding(mesh, P(baxes0, "model", None)),
            NamedSharding(mesh, P(baxes0, None, None)))
    elif (interior_pin or plan.zero1) and spec.kind == "train":
        # pin layer-interior activations to (batch-sharded, replicated):
        # prevents GSPMD from replicating attention internals over the
        # model axis (measured 3.6x redundant flops on yi-34b) without
        # seq-sharding the saved carries
        baxes0 = R.batch_axes(multi_pod)
        pin = NamedSharding(mesh, P(baxes0, None, None))
        lm.boundary_sp = (pin, pin)
    dt = jnp.dtype(cfg.dtype)

    pmeta = lm.param_meta()
    pspecs = specs_for(pmeta, plan.rules, mesh)
    pshard = _shard(mesh, pspecs)
    params_sds = _with_sharding(abstractify(pmeta, dtype=dt), pshard)

    baxes = tuple(R.batch_axes(multi_pod))
    n_data = 1
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in baxes:
        n_data *= mesh_shape.get(a, 1)
    # batch-dim sharding entry: None (replicated) when not divisible —
    # NEVER an empty spec, which would shift later entries onto dim 0
    bentry = baxes if gb % n_data == 0 else None

    inputs = registry.input_specs(cfg, spec, batch_override=gb)

    if spec.kind == "train":
        ocfg = adamw.AdamWConfig(
            quantize_moments=plan.quantized_moments)
        # grads accumulate in bf16 for the very largest models (the f32
        # accumulator would not fit next to their int8 moments)
        gdt = jnp.bfloat16 if plan.quantized_moments else jnp.float32
        ometa = adamw.state_meta(pmeta, ocfg)
        ospecs = specs_for(ometa, plan.opt_rules(cfg, multi_pod), mesh)
        oshard = _shard(mesh, ospecs)
        opt_sds = _with_sharding(abstractify(ometa), oshard)
        batch_shard = {"tokens": NamedSharding(mesh, P(bentry, None)),
                       "labels": NamedSharding(mesh, P(bentry, None))}
        if "aux" in inputs:
            batch_shard["aux"] = NamedSharding(mesh, P(bentry, None, None))
        batch_sds = _with_sharding(inputs, batch_shard)
        scalar = NamedSharding(mesh, P())
        metrics_shard = {k: scalar for k in
                         ("loss", "nll", "aux_loss", "grad_norm", "lr")}
        def mb_sharding(y, _mesh=mesh, _bentry=bentry):
            spec = P(None, _bentry, *([None] * (y.ndim - 2)))
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(_mesh, spec))

        step = make_train_step(lm, ocfg, microbatches=plan.microbatches,
                               grad_dtype=gdt, mb_sharding=mb_sharding)
        jitted = jax.jit(step,
                         out_shardings=(pshard, oshard, metrics_shard),
                         donate_argnums=(0, 1))
        args = (params_sds, opt_sds, batch_sds)
    elif spec.kind == "prefill":
        batch_shard = {"tokens": NamedSharding(mesh, P(bentry, None))}
        if "aux" in inputs:
            batch_shard["aux"] = NamedSharding(mesh, P(bentry, None, None))
        batch_sds = _with_sharding(inputs, batch_shard)
        # pin layer-interior activations (same GSPMD-replication hazard as
        # training) and shard the emitted KV caches like decode caches
        pin = NamedSharding(mesh, P(baxes if gb % n_data == 0 else None,
                                    None, None))
        lm.boundary_sp = (pin, pin)
        cache_meta = lm.init_cache_meta(gb, spec.seq_len)
        kv_rules = R.make_rules(
            cfg, multi_pod=multi_pod, fsdp=plan.fsdp, kv_seq_axis="model")
        cspecs = specs_for(cache_meta, kv_rules, mesh)
        cshard = _shard(mesh, cspecs)
        logits_shard = NamedSharding(mesh, P(bentry, "model"))
        step = make_prefill_step(lm)
        jitted = jax.jit(step, out_shardings=(logits_shard, cshard))
        args = (params_sds, batch_sds)
    elif spec.kind == "decode":
        cache_meta = lm.init_cache_meta(gb, spec.seq_len)
        cspecs = specs_for(cache_meta, plan.rules, mesh)
        cshard = _shard(mesh, cspecs)
        cache_sds = _with_sharding(abstractify(cache_meta), cshard)
        tok_sds = _with_sharding(
            inputs["tokens"], NamedSharding(mesh, P(bentry, None)))
        step = make_decode_step(lm)
        # logits (B, V_padded): batch axis only when divisible; padded vocab
        # is always divisible by the model axis
        logits_shard = NamedSharding(mesh, P(bentry, "model"))
        jitted = jax.jit(step, out_shardings=(logits_shard, cshard),
                         donate_argnums=(1,))
        args = (params_sds, cache_sds, tok_sds)
    else:
        raise ValueError(spec.kind)
    return Cell(arch, shape_name, cfg, lm, plan, mesh, jitted, args,
                spec.kind)


# ---------------------------------------------------------------------------
# Dry run: lower + compile + analyze
# ---------------------------------------------------------------------------
def _f32_twin_bytes(text: str) -> float:
    """Bytes of large f32 buffers that are CPU-backend twins of bf16 loop
    buffers (same dims; >=64 MiB). See dryrun memory accounting note."""
    import re
    dims_by_dtype: dict[str, set] = {"f32": set(), "bf16": set()}
    for m in re.finditer(r"= (f32|bf16)\[([0-9,]+)\]\S* "
                         r"(dynamic-update-slice|get-tuple-element|fusion)",
                         text):
        dims_by_dtype[m.group(1)].add(m.group(2))
    total = 0.0
    for dims in dims_by_dtype["f32"] & dims_by_dtype["bf16"]:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 >= 64 * 2 ** 20:
            total += n * 4
    return total



def dryrun_cell(arch: str, shape_name: str, mesh, *, multi_pod: bool,
                smoke: bool = False, fsdp: bool | None = None,
                batch_override: int | None = None,
                seq_parallel: bool = False, zero1: bool = False,
                interior_pin: bool = False, kv_cache_dtype=None,
                keep_text: bool = False) -> dict:
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, multi_pod=multi_pod,
                      smoke=smoke, fsdp=fsdp, batch_override=batch_override,
                      seq_parallel=seq_parallel, zero1=zero1,
                      interior_pin=interior_pin,
                      kv_cache_dtype=kv_cache_dtype)
    lowered = cell.jitted.lower(*cell.example_args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    rep = hlo_analysis.analyze_hlo(text,
                                   score_block=cell.cfg.attention_block)

    n_dev = mesh.devices.size
    out = {
        "arch": arch, "shape": shape_name, "kind": cell.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod, "n_devices": int(n_dev),
        "smoke": smoke, "fsdp": cell.plan.fsdp,
        "zero1": cell.plan.zero1,
        "seq_parallel": seq_parallel,
        "microbatches": cell.plan.microbatches,
        "quantized_moments": cell.plan.quantized_moments,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "xla_flops_per_device": float(ca.get("flops", 0.0)) if ca else 0.0,
        "xla_bytes_per_device": float(ca.get("bytes accessed", 0.0))
        if ca else 0.0,
        "hlo_flops_per_device": rep.flops,
        "hlo_traffic_bytes_per_device": rep.traffic_bytes,
        "score_traffic_bytes_per_device": rep.score_traffic_bytes,
        "kernel_adjusted_traffic_bytes_per_device":
            rep.kernel_adjusted_traffic,
        "collective_bytes_per_device": rep.collective_bytes,
        "collective_total_bytes_per_device": rep.total_collective_bytes,
        "n_collectives": rep.n_collectives,
        "missing_trip_counts": rep.missing_trip_counts,
    }
    if ma is not None:
        peak = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        # The CPU backend materializes f32 working twins of big bf16 loop
        # buffers (bf16 is not native on CPU); a TPU compile keeps them
        # bf16. Subtract f32 stacks that have a same-shape bf16 twin for a
        # TPU-representative estimate (both numbers are recorded).
        f32_twin = _f32_twin_bytes(text)
        out["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_cpu": peak,
            "f32_twin_bytes": int(f32_twin),
            "peak_bytes_est": int(max(peak - f32_twin, 0)),
        }
    if keep_text:
        out["hlo_text"] = text
    return out
