"""Mesh construction for single-pod and multi-pod deployments.

All builders are FUNCTIONS (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh spans 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh for smoke tests / examples on however many devices exist."""
    if pod:
        return jax.make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
