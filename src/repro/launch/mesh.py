"""Mesh construction for single-pod and multi-pod deployments.

All builders are FUNCTIONS (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across JAX versions: ``jax.sharding.AxisType`` (and the
    ``axis_types=`` kwarg) only exist on newer JAX; fall back to the plain
    call on 0.4.x, where every axis is implicitly auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh spans 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh for smoke tests / examples on however many devices exist."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))
