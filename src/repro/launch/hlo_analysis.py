"""Whole-program analysis of optimized HLO text.

``compiled.cost_analysis()`` visits each computation once and does NOT
multiply by while-loop trip counts — with scan-over-layers models that
undercounts by the layer count (verified empirically; see EXPERIMENTS.md
§Dry-run). This module parses ``compiled.as_text()`` and computes
execution-count-weighted totals:

* matmul FLOPs (dot ops: 2 x result_elems x contraction_elems),
* collective bytes by kind (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute), result-buffer sized,
* an HBM-traffic proxy: operand+result bytes of every fusion / dot /
  copy / dynamic-(update-)slice / gather / collective instruction.

Execution counts come from the call graph: ENTRY x1, while bodies x their
``known_trip_count`` backend_config (1 + warn if absent), fusions x1.
All numbers are per-device (the text is the SPMD-partitioned module).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT )?%?([\w.\-]+) = (.*?) ([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \((.*?)\) -> ")
_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"?n"?\s*:\s*"?(\d+)')
_CALLEE_RE = re.compile(r"(?:condition|body|calls|to_apply)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_FUSED_CALLEES: set = set()
# NOTE: plain `copy` is excluded: the CPU backend's loop double-buffering
# inserts whole-carry copies per iteration that a TPU compile aliases away;
# counting them would swamp the memory term with backend artifacts.
_TRAFFIC_OPS = COLLECTIVE_OPS + (
    "fusion", "dot", "convolution", "dynamic-update-slice",
    "dynamic-slice", "gather", "scatter", "custom-call", "sort",
    "reduce-window", "select-and-scatter", "cholesky", "triangular-solve")


def shape_bytes(shape_text: str) -> int:
    """Total bytes of every `type[dims]` group in the text (tuples sum)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_elems(shape_text: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    traffic: float = 0.0
    score_traffic: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    # (callee, multiplier) edges
    calls: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class HloReport:
    flops: float
    traffic_bytes: float
    collective_bytes: dict[str, float]
    n_collectives: dict[str, int]
    missing_trip_counts: int
    # HBM traffic attributable to (block x block) attention score tensors
    # round-tripping through HBM in the pure-jnp blockwise attention. The
    # Pallas flash kernel keeps these in VMEM (validated in
    # tests/test_kernels.py), so `traffic - score_traffic` models the
    # kernel-substituted memory term.
    score_traffic_bytes: float = 0.0

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    @property
    def kernel_adjusted_traffic(self) -> float:
        return max(self.traffic_bytes - self.score_traffic_bytes, 0.0)


def _dot_flops(line: str, result_shape: str, symbols: dict) -> float:
    """2 * result_elems * contraction_elems."""
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if not m:
        return 0.0
    cdims = [int(x) for x in m.group(1).split(",") if x]
    ops = _operands(line)
    if not ops:
        return 0.0
    lhs_shape = symbols.get(ops[0], "")
    groups = _SHAPE_RE.findall(lhs_shape)
    if not groups:
        return 0.0
    dims = [int(x) for x in groups[0][1].split(",") if x]
    contract = 1
    for c in cdims:
        if c < len(dims):
            contract *= dims[c]
    return 2.0 * shape_elems(result_shape) * contract


def _traffic_bytes(base: str, line: str, result_shape: str,
                   symbols: dict) -> float:
    """HBM-traffic estimate per instruction, mirroring HloCostAnalysis'
    special cases:

    * dynamic-slice / gather read only the sliced window (~= result);
    * dynamic-update-slice reads+writes only the update operand;
    * a fusion's operand reads are capped at its result size (big loop
      -resident buffers consumed through internal slices would otherwise be
      charged in full on every loop iteration);
    * dot reads operands in full (streaming weights from HBM) + writes out.
    """
    result = shape_bytes(result_shape)
    ops = _operands(line)
    if base in ("dynamic-slice", "gather"):
        return 2.0 * result
    if base == "dynamic-update-slice":
        upd = shape_bytes(symbols.get(ops[1], "")) if len(ops) > 1 else result
        return 2.0 * upd
    if base in ("dot", "convolution", "custom-call"):
        t = result
        for op in ops:
            t += shape_bytes(symbols.get(op, ""))
        return t
    # fusion / copy / sort / scatter / collectives / etc.
    t = result
    for op in ops:
        t += min(shape_bytes(symbols.get(op, "")), max(result, 1))
    return t


def _operands(line: str) -> list[str]:
    """Operand instruction names inside the first (...) argument list."""
    start = line.find("(")
    if start < 0:
        return []
    depth = 0
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_RE.findall(line[start:end + 1])


def _is_score_shaped(shape_text: str, block: int) -> bool:
    """Result tensors whose trailing dims are (block, block) — the
    blockwise-attention score/probability tiles."""
    for _, dims in _SHAPE_RE.findall(shape_text):
        d = [int(x) for x in dims.split(",") if x]
        if len(d) >= 2 and d[-1] == block and d[-2] == block:
            return True
    return False


def analyze_hlo(text: str, score_block: int | None = None) -> HloReport:
    global _FUSED_CALLEES
    _FUSED_CALLEES = set()
    comps: dict[str, CompStats] = {}
    entry: str | None = None
    current: CompStats | None = None
    symbols: dict[str, str] = {}
    missing_trips = 0
    n_coll: dict[str, int] = defaultdict(int)

    for raw in text.splitlines():
        if raw and not raw.startswith(" "):
            m = _COMP_RE.match(raw)
            if m:
                name = m.group(1)
                current = CompStats()
                comps[name] = current
                symbols = {}
                if raw.startswith("ENTRY"):
                    entry = name
                # header parameters: "name: shape, ..."
                for pm in re.finditer(r"([\w.\-]+): ([^,)]+)", m.group(2)):
                    symbols[pm.group(1)] = pm.group(2)
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(raw)
        if not im:
            continue
        name, shape_text, opcode, _rest = im.groups()
        symbols[name] = shape_text

        if opcode == "dot":
            current.flops += _dot_flops(raw, shape_text, symbols)
        base = opcode
        for suffix in ("-start", "-done", "-update"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base in COLLECTIVE_OPS and not opcode.endswith("-done"):
            b = shape_bytes(shape_text)
            current.coll_bytes[base] += b
            n_coll[base] += 1
        if base in _TRAFFIC_OPS and not opcode.endswith("-done"):
            t = _traffic_bytes(base, raw, shape_text, symbols)
            current.traffic += t
            if score_block:
                if _is_score_shaped(shape_text, score_block):
                    current.score_traffic += t
                else:
                    # score-shaped OPERANDS (e.g. the P tile read by the
                    # P @ V dot) also stay in VMEM under the flash kernel
                    for op in _operands(raw):
                        osh = symbols.get(op, "")
                        if _is_score_shaped(osh, score_block):
                            current.score_traffic += min(
                                shape_bytes(osh),
                                max(shape_bytes(shape_text), 1)
                                if base not in ("dot", "convolution",
                                                "custom-call")
                                else shape_bytes(osh))
        if opcode == "while":
            body = None
            trip = None
            bm = re.search(r"body=%?([\w.\-]+)", raw)
            cm = re.search(r"condition=%?([\w.\-]+)", raw)
            tm = _TRIP_RE.search(raw)
            if tm:
                trip = int(tm.group(1))
            else:
                missing_trips += 1
                trip = 1
            if bm:
                current.calls.append((bm.group(1), trip))
            if cm:
                current.calls.append((cm.group(1), trip + 1))
        elif opcode in ("call", "fusion", "custom-call", "reduce",
                        "map", "sort", "reduce-window", "scatter",
                        "select-and-scatter", "conditional", "async-start"):
            fused = opcode != "call" and opcode != "conditional"
            for callee in _CALLEE_RE.findall(raw):
                current.calls.append((callee, 1))
                if fused:
                    _FUSED_CALLEES.add(callee)
            if opcode == "conditional":
                for bmatch in re.finditer(
                        r"branch_computations=\{([^}]*)\}", raw):
                    for callee in _OPERAND_RE.findall(bmatch.group(1)):
                        current.calls.append((callee, 1))

    # propagate execution counts (call graph is a DAG in HLO)
    exec_count: dict[str, float] = defaultdict(float)

    def visit(name: str, mult: float, depth=0):
        if name not in comps or depth > 64:
            return
        exec_count[name] += mult
        for callee, k in comps[name].calls:
            visit(callee, mult * k, depth + 1)

    if entry:
        visit(entry, 1.0)

    flops = sum(c.flops * exec_count[n] for n, c in comps.items())
    # fused computations' instruction traffic stays on-chip: count only the
    # fusion call site (operands + result), not the body
    traffic = sum(c.traffic * exec_count[n] for n, c in comps.items()
                  if n not in _FUSED_CALLEES)
    score_traffic = sum(c.score_traffic * exec_count[n]
                        for n, c in comps.items()
                        if n not in _FUSED_CALLEES)
    coll: dict[str, float] = defaultdict(float)
    for n, c in comps.items():
        for k, v in c.coll_bytes.items():
            coll[k] += v * exec_count[n]
    return HloReport(flops=float(flops), traffic_bytes=float(traffic),
                     collective_bytes=dict(coll), n_collectives=dict(n_coll),
                     missing_trip_counts=missing_trips,
                     score_traffic_bytes=float(score_traffic))
