"""Training driver: checkpointed, fault-tolerant, power-monitored.

Runs a real (small) training job on the local devices — the same step
builders the dry-run lowers at production scale. Demonstrates end-to-end:

* sharded train step (pjit) from the cell plan rules,
* deterministic restartable data pipeline,
* atomic keep-K checkpointing (+ async), restore-on-fault retry loop,
* straggler monitoring,
* per-step HBM energy estimates from the paper's model (VAMPIRE -> HBM
  adaptation) using compiled cost analysis + live tensor statistics.

Usage (CPU example, also exercised by examples/train_lm.py):
    python -m repro.launch.train --arch qwen2.5-3b --smoke --steps 50 \
        --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --fail-at 17
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticDataset
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh
from repro.models.lm import LM
from repro.models.meta import materialize, specs_for
from repro.optim import adamw
from repro.runtime.fault import (FaultInjector, SimulatedFault,
                                 StepTimer, StragglerMonitor)
from repro.sharding import rules as R


@dataclasses.dataclass
class TrainJob:
    arch: str
    smoke: bool = True
    steps: int = 50
    batch: int = 8
    seq: int = 128
    ckpt_dir: str | None = None
    ckpt_every: int = 10
    fail_at: tuple[int, ...] = ()
    data: int = 1
    model: int = 1
    power_every: int = 20
    seed: int = 0
    config: object = None   # explicit ModelConfig overrides arch lookup


class PowerMonitor:
    """Per-step HBM energy via the paper's data-dependent model."""

    def __init__(self, compiled=None):
        self.model = None
        self.read_bytes = 0.0
        self.write_bytes = 0.0
        if compiled is not None:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            total = float(ca.get("bytes accessed", 0.0)) if ca else 0.0
            self.read_bytes = 0.6 * total
            self.write_bytes = 0.4 * total

    def report(self, params, step_seconds: float):
        from repro.core import hbm
        from repro.core.vampire import reference_vampire
        if self.model is None:
            self.model = hbm.HbmEnergyModel.from_vampire(
                reference_vampire().params(0))
        leaves = [x for x in jax.tree_util.tree_leaves(params)
                  if hasattr(x, "dtype") and x.dtype in (jnp.bfloat16,
                                                         jnp.float32)]
        big = max(leaves, key=lambda x: x.size)
        ones, togg = hbm.tensor_stats(big[:4096] if big.ndim == 1
                                      else big.reshape(-1)[:65536])
        return hbm.step_energy(
            self.model, read_bytes=self.read_bytes,
            write_bytes=self.write_bytes, step_seconds=step_seconds,
            ones_frac=ones, toggle_frac=togg)


def run(job: TrainJob) -> dict:
    cfg = job.config or registry.get_config(job.arch, smoke=job.smoke)
    lm = LM(cfg)
    mesh = make_local_mesh(data=job.data, model=job.model)
    rules = R.make_rules(cfg, multi_pod=False)
    ocfg = adamw.AdamWConfig(warmup_steps=5, decay_steps=max(job.steps, 10))

    pmeta = lm.param_meta()
    pspecs = specs_for(pmeta, rules, mesh)
    pshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
    params = jax.jit(lambda k: materialize(pmeta, k,
                                           dtype=jnp.dtype(cfg.dtype)),
                     out_shardings=pshard)(jax.random.key(job.seed))
    opt_state = jax.jit(lambda p: adamw.init(p, ocfg))(params)

    step_fn = jax.jit(steps_lib.make_train_step(lm, ocfg),
                      donate_argnums=(0, 1))

    ds = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq_len=job.seq,
                                     global_batch=job.batch,
                                     seed=job.seed + 7))
    ckpt = (CheckpointManager(job.ckpt_dir, keep=2, async_save=True)
            if job.ckpt_dir else None)
    injector = FaultInjector(fail_at_steps=tuple(job.fail_at))
    straggler = StragglerMonitor()
    compiled = None
    power = None

    step = 0
    if ckpt and ckpt.latest_step() is not None:
        step = ckpt.latest_step()
        state = ckpt.restore(step, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]

    losses, energies, recoveries = [], [], 0
    while step < job.steps:
        batch = ds.global_batch(step)
        if cfg.aux_seq:
            batch["aux"] = jnp.zeros((job.batch, cfg.aux_seq, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
        try:
            injector.check(step)
            with StepTimer() as t:
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
                loss = float(metrics["loss"])
            straggler.record(step, t.seconds)
            if power is None:
                compiled = step_fn.lower(params, opt_state, batch).compile()
                power = PowerMonitor(compiled)
            losses.append(loss)
            if job.power_every and step % job.power_every == 0:
                rep = power.report(params, t.seconds)
                energies.append((step, rep.total_j))
            if ckpt and step % job.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          extra={"loss": loss})
            step += 1
        except SimulatedFault:
            recoveries += 1
            if ckpt and ckpt.latest_step() is not None:
                restore_step = ckpt.latest_step()
                state = ckpt.restore(restore_step,
                                     {"params": params, "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                step = restore_step
            # without a checkpoint dir we simply retry the step
    if ckpt:
        ckpt.save(step, {"params": params, "opt": opt_state})
        ckpt.wait()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "losses": losses, "recoveries": recoveries,
            "straggler_flags": straggler.flagged, "energies": energies,
            "steps_run": len(losses)}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2.5-3b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--full", dest="smoke", action="store_false")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--fail-at", type=int, nargs="*", default=[])
    p.add_argument("--data", type=int, default=1)
    p.add_argument("--model", type=int, default=1)
    args = p.parse_args()
    res = run(TrainJob(arch=args.arch, smoke=args.smoke, steps=args.steps,
                       batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt_dir,
                       fail_at=tuple(args.fail_at), data=args.data,
                       model=args.model))
    print(f"steps={res['steps_run']} final_loss={res['final_loss']:.4f} "
          f"recoveries={res['recoveries']}")
    for s, e in res["energies"]:
        print(f"  step {s}: est. HBM energy {e:.3f} J/step/device")


if __name__ == "__main__":
    main()
