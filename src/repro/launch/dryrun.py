import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture x input-shape) cell against the
production meshes — 16x16 (one pod, 256 chips) and 2x16x16 (two pods,
512 chips) — using ShapeDtypeStruct inputs only (no allocation), prints
``memory_analysis()`` / ``cost_analysis()`` evidence, and writes one JSON
artifact per cell under artifacts/dryrun/ for the roofline stage.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both
    python -m repro.launch.dryrun --all --mesh pod --jobs-file cells.txt
"""

import argparse
import json
import sys
import time
import traceback


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--mesh", choices=("pod", "multipod", "both"),
                   default="pod")
    p.add_argument("--out", default="artifacts/dryrun")
    p.add_argument("--fsdp", default=None,
                   help="override FSDP: on|off (default: auto per plan)")
    p.add_argument("--skip-existing", action="store_true")
    args = p.parse_args()

    from repro.configs import registry
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh

    if args.all:
        cells = registry.all_cells()
    elif args.arch and not args.shape:
        cells = [(a, s) for a, s in registry.all_cells() if a == args.arch]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    fsdp = {None: None, "on": True, "off": False}[args.fsdp]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        tag = "2x16x16" if multi_pod else "16x16"
        for arch, shape in cells:
            name = f"{arch}__{shape}__{tag}"
            path = os.path.join(args.out, name + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {name}")
                continue
            t0 = time.time()
            try:
                res = steps.dryrun_cell(arch, shape, mesh,
                                        multi_pod=multi_pod, fsdp=fsdp)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                mem = res.get("memory", {})
                print(f"[ok]   {name}: compile={res['compile_s']:.0f}s "
                      f"flops/dev={res['hlo_flops_per_device']:.3e} "
                      f"coll/dev={res['collective_total_bytes_per_device']:.3e}B "
                      f"peak/dev={mem.get('peak_bytes_est', 0)/2**30:.2f}GiB")
            except Exception as e:  # noqa: BLE001 - record and continue
                failures.append((name, repr(e)))
                print(f"[FAIL] {name}: {e!r} ({time.time()-t0:.0f}s)")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for n, e in failures:
            print(" ", n, e)
        return 1
    print("\nall cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
