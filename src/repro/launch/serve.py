"""Serving driver: batched prefill + decode with continuous batching hooks.

Demonstrates the inference side of the framework end-to-end on local
devices: prefill a batch of prompts, then decode tokens with the sharded
KV/SSM caches, with per-token latency stats and HBM energy estimates from
the paper's power model.

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --batch 4 \
        --prompt-len 64 --decode-tokens 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh
from repro.models.lm import LM


@dataclasses.dataclass
class ServeJob:
    arch: str
    smoke: bool = True
    batch: int = 4
    prompt_len: int = 64
    decode_tokens: int = 32
    data: int = 1
    model: int = 1
    seed: int = 0
    temperature: float = 0.0


def run(job: ServeJob) -> dict:
    cfg = registry.get_config(job.arch, smoke=job.smoke)
    lm = LM(cfg)
    mesh = make_local_mesh(data=job.data, model=job.model)
    params = lm.init(jax.random.key(job.seed))

    rng = np.random.default_rng(job.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(job.batch, job.prompt_len)),
        dtype=jnp.int32)
    aux = None
    if cfg.aux_seq:
        aux = jnp.zeros((job.batch, cfg.aux_seq, cfg.d_model),
                        jnp.dtype(cfg.dtype))

    max_len = job.prompt_len + job.decode_tokens
    t0 = time.perf_counter()
    prefill = jax.jit(lambda p, t: lm.prefill(p, t, aux=aux,
                                              max_len=max_len))
    logits, caches = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(lm.decode_step, donate_argnums=(1,))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    generated = [tok]
    lat = []
    for i in range(job.decode_tokens - 1):
        t1 = time.perf_counter()
        logits, caches = decode(params, caches, tok)
        logits.block_until_ready()
        lat.append(time.perf_counter() - t1)
        if job.temperature > 0:
            key = jax.random.fold_in(jax.random.key(job.seed + 1), i)
            tok = jax.random.categorical(
                key, logits / job.temperature, axis=-1).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        generated.append(tok)

    tokens = jnp.concatenate(generated, axis=1)
    lat = np.asarray(lat[1:]) if len(lat) > 1 else np.asarray(lat)
    return {
        "tokens": np.asarray(tokens),
        "prefill_s": t_prefill,
        "decode_p50_ms": float(np.median(lat) * 1e3) if lat.size else 0.0,
        "decode_p99_ms": float(np.percentile(lat, 99) * 1e3)
        if lat.size else 0.0,
        "tokens_per_s": (job.batch * lat.size / lat.sum())
        if lat.size and lat.sum() > 0 else 0.0,
    }


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2.5-3b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--decode-tokens", type=int, default=32)
    args = p.parse_args()
    res = run(ServeJob(arch=args.arch, smoke=args.smoke, batch=args.batch,
                       prompt_len=args.prompt_len,
                       decode_tokens=args.decode_tokens))
    print(f"prefill={res['prefill_s']:.2f}s decode p50={res['decode_p50_ms']:.1f}ms "
          f"p99={res['decode_p99_ms']:.1f}ms throughput={res['tokens_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
