"""Serving driver: batched prefill + decode with continuous batching hooks.

Demonstrates the inference side of the framework end-to-end on local
devices: prefill a batch of prompts, then decode tokens with the sharded
KV/SSM caches, with per-token latency stats and HBM energy estimates from
the paper's power model.

Params and caches are sharded under a ``make_local_mesh(data, model)``
mesh via the same sharding-rule machinery the dry-run cells use, so the
smoke path exercises the production layout (trivially, on one device).

``--power-report`` turns on the power side: the compiled decode step's
HBM traffic (execution-count-weighted HLO analysis, as in the dry run) is
apportioned per sequence, wrapped into DRAM command traces carrying the
decode batch's actual output bytes, and scored through the estimation
service (``repro.serving``): lint-gated admission, ring-bucketed pad
shapes (bounded jit cache across ``--batch`` sizes), the model
device-resident, one batched dispatch per window — plus the
HBM2e-anchored extrapolation (``repro.core.hbm``).  The scorer is any
unified-protocol estimator (``repro.core.model_api``): ``--power-model
vampire|micron|drampower`` picks the physics, ``--power-impl
vectorized|pallas|reference`` picks the impl-registry evaluation path
(``pallas`` = the fused (traces x vendors) kernel family), and
``--vampire PATH`` loads a saved model (v2 ``.npz`` or legacy v1 pickle)
instead of the quick reference fit.

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --batch 4 \
        --prompt-len 64 --decode-tokens 32 --data 1 --model 1 \
        --temperature 0.7 --power-report --power-model vampire
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.launch import hlo_analysis
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import shard_tree
from repro.models.lm import LM
from repro.models.meta import specs_for
from repro.sharding import rules as R


@dataclasses.dataclass
class ServeJob:
    arch: str
    smoke: bool = True
    batch: int = 4
    prompt_len: int = 64
    decode_tokens: int = 32
    data: int = 1
    model: int = 1
    seed: int = 0
    temperature: float = 0.0
    # power reporting (off by default: it fits/loads a VAMPIRE model)
    power_report: bool = False
    power_vendors: tuple[int, ...] = (0, 1, 2)
    power_model: str = "vampire"      # estimator kind: vampire|micron|drampower
    power_impl: str = "vectorized"    # impl registry: vectorized|pallas|reference
    vampire_path: str | None = None   # saved model blob (model_api v2 / v1)


def run(job: ServeJob) -> dict:
    cfg = registry.get_config(job.arch, smoke=job.smoke)
    lm = LM(cfg)
    mesh = make_local_mesh(data=job.data, model=job.model)
    max_len = job.prompt_len + job.decode_tokens
    plan = R.plan_for(cfg, "decode", job.batch, mesh, False, seq_len=max_len)

    # ---- params sharded under the mesh by the cell sharding rules --------
    params = lm.init(jax.random.key(job.seed))
    pshard = shard_tree(mesh, specs_for(lm.param_meta(), plan.rules, mesh))
    params = jax.device_put(params, pshard)

    n_data = mesh.shape.get("data", 1)
    bentry = "data" if job.batch % n_data == 0 else None
    rng = np.random.default_rng(job.seed)
    prompts = jax.device_put(
        jnp.asarray(rng.integers(0, cfg.vocab,
                                 size=(job.batch, job.prompt_len)),
                    dtype=jnp.int32),
        NamedSharding(mesh, P(bentry, None)))
    aux = None
    if cfg.aux_seq:
        aux = jnp.zeros((job.batch, cfg.aux_seq, cfg.d_model),
                        jnp.dtype(cfg.dtype))

    # ---- prefill: emit the decode-layout (mesh-sharded) caches -----------
    cshard = shard_tree(
        mesh, specs_for(lm.init_cache_meta(job.batch, max_len),
                        plan.rules, mesh))
    logits_shard = NamedSharding(mesh, P(bentry, "model"))
    t0 = time.perf_counter()
    prefill = jax.jit(lambda p, t: lm.prefill(p, t, aux=aux,
                                              max_len=max_len),
                      out_shardings=(logits_shard, cshard))
    logits, caches = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    # one AOT compile: the decode loop and the power report's HLO traffic
    # analysis share the same compiled executable
    decode = jax.jit(lm.decode_step, donate_argnums=(1,),
                     out_shardings=(logits_shard, cshard)
                     ).lower(params, caches, tok).compile()
    generated = [tok]
    lat = []
    for i in range(job.decode_tokens - 1):
        t1 = time.perf_counter()
        logits, caches = decode(params, caches, tok)
        logits.block_until_ready()
        lat.append(time.perf_counter() - t1)
        if job.temperature > 0:
            key = jax.random.fold_in(jax.random.key(job.seed + 1), i)
            tok = jax.random.categorical(
                key, logits / job.temperature, axis=-1).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        generated.append(tok)

    tokens = jnp.concatenate(generated, axis=1)
    lat = np.asarray(lat[1:]) if len(lat) > 1 else np.asarray(lat)
    res = {
        "tokens": np.asarray(tokens),
        "prefill_s": t_prefill,
        "decode_p50_ms": float(np.median(lat) * 1e3) if lat.size else 0.0,
        "decode_p99_ms": float(np.percentile(lat, 99) * 1e3)
        if lat.size else 0.0,
        "tokens_per_s": (job.batch * lat.size / lat.sum())
        if lat.size and lat.sum() > 0 else 0.0,
    }
    if job.power_report:
        res["power"] = power_report(job, decode, logits, tokens,
                                    n_data=n_data,
                                    step_seconds=float(np.median(lat))
                                    if lat.size else 1e-3,
                                    mesh=mesh)
    return res


# ---------------------------------------------------------------------------
# Power reporting (the "HBM energy estimates" half of the module contract)
# ---------------------------------------------------------------------------
def _decode_traffic_bytes(compiled) -> float:
    """Per-step, per-device HBM traffic of the compiled decode step
    (execution-count-weighted HLO analysis; falls back to XLA's own
    'bytes accessed' when the text analysis finds nothing)."""
    rep = hlo_analysis.analyze_hlo(compiled.as_text())
    if rep.traffic_bytes > 0:
        return float(rep.traffic_bytes)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("bytes accessed", 0.0)) if ca else 0.0


def _load_estimator(job: ServeJob):
    """Resolve the power model: a saved blob if given (any kind the v2
    loader knows), else the quick reference fit — then adapt it to the
    requested ``--power-model`` kind through the protocol registry."""
    from repro.core import model_api
    from repro.core.vampire import reference_vampire
    if job.vampire_path:
        model = model_api.load_estimator(job.vampire_path)
        if model.kind == job.power_model:
            return model
        if model.kind != "vampire":
            raise ValueError(
                f"{job.vampire_path} holds a {model.kind!r} estimator but "
                f"--power-model={job.power_model!r} was requested")
    else:
        model = reference_vampire()
    return model_api.make_estimator(job.power_model, model)


def lint_ingested(seq_traces) -> None:
    """Batched protocol lint of traces bound for the power report.
    Raises :class:`repro.analysis.TraceProtocolError` carrying the
    structured diagnostics (rule id, trace + command index, bank) when any
    ingested trace is protocol-illegal — a corrupt external trace must be
    rejected, not silently priced.

    ``power_report`` itself now admits through the
    :class:`~repro.serving.EstimationService` (whose gate runs the same
    linter and raises with the same origin); this standalone hook remains
    for callers linting traces without standing up a service."""
    from repro.analysis import trace_lint
    trace_lint.lint_ingested(seq_traces, origin="serve.power_report")


def power_report(job: ServeJob, compiled_decode, logits, tokens, *,
                 n_data: int, step_seconds: float, mesh=None) -> dict:
    """Score one decode batch's HBM traffic through the estimation service.

    One DRAM command trace per sequence (carrying that sequence's actual
    logits/token bytes as line data), admitted through the
    :class:`~repro.serving.EstimationService` — lint-gated ingestion, the
    ring's bucketed pad shapes (so varying ``--batch`` sizes stop growing
    the jit cache: windows land on a small fixed shape vocabulary), the
    model kept device-resident, and the dispatch sharded over ``mesh``
    when it has more than one device.  Energies scale from each trace's
    modeled bytes to the step's measured traffic share; the service's
    metrics snapshot rides along under ``"serving"``."""
    from repro.core import hbm, traces
    from repro.core.dram import LINE_BYTES

    model = _load_estimator(job)
    vendors = [v for v in job.power_vendors if v in model.vendors]
    traffic = _decode_traffic_bytes(compiled_decode)
    # the HLO traffic is per DEVICE; with the batch sharded over the data
    # axis each device's step only covers batch/n_data sequences
    local_batch = (job.batch // n_data if job.batch % n_data == 0
                   else job.batch)
    bytes_per_seq = traffic / max(local_batch, 1)

    logits_np = np.asarray(logits, np.float32)
    tokens_np = np.asarray(tokens)
    seq_traces = []
    for b in range(job.batch):
        # the sequence's real decode output bytes, recycled to fill the
        # traffic share (decode re-reads the same weights every step, so
        # repeating content is the honest analogue)
        payload = logits_np[b].tobytes() + tokens_np[b].tobytes()
        lines = traces.lines_from_bytes(payload)
        n_req = int(min(max(bytes_per_seq // LINE_BYTES, 8), 512))
        reps = int(np.ceil(n_req / max(len(lines), 1)))
        lines = np.tile(lines, (max(reps, 1), 1))[:n_req]
        spec = traces.AppSpec(f"decode{b}", intensity=0.8, row_hit=0.7,
                              read_frac=0.85, data_dist="random",
                              seed=job.seed + b)
        seq_traces.append(traces.app_trace(spec, n_requests=n_req,
                                           lines=lines))

    # ingestion + scoring through the serving stack: the service lints on
    # admission (never bill a protocol-illegal trace) and dispatches the
    # whole batch on the ring's bucketed pad shapes
    from repro.analysis import trace_lint
    from repro.serving import EstimationService, ServiceConfig
    svc = EstimationService(model, ServiceConfig(impl=job.power_impl),
                            mesh=mesh)
    tickets, rejections = svc.submit_many(seq_traces, vendors)
    if rejections:
        raise trace_lint.TraceProtocolError(
            [d for r in rejections for d in r.diagnostics],
            origin="serve.power_report")
    svc.close()
    rows = [svc.result(t) for t in tickets]               # B vendor-rows

    modeled_bytes = np.asarray(
        [traces.trace_request_lines(tr).shape[0] * LINE_BYTES
         for tr in seq_traces], np.float64)
    scale = (bytes_per_seq / np.maximum(modeled_bytes, 1.0))[:, None]
    energy_pj = np.asarray([r.energy_pj for r in rows],
                           np.float64) * scale            # (B, V) per step

    out = {
        "vendors": list(vendors),
        "power_model": model.kind,
        "traffic_bytes_per_step": traffic,
        "bytes_per_seq_per_step": bytes_per_seq,
        "ddr_energy_pj_per_seq_step": energy_pj,          # (B, V)
        "ddr_energy_uj_per_token_mean": float(energy_pj.mean() * 1e-6),
        "serving": dataclasses.asdict(svc.metrics()),
    }
    # the HBM2e-anchored extrapolation needs fitted VAMPIRE PowerParams;
    # the datasheet baselines have none (no data dependency to anchor)
    if model.kind == "vampire":
        ones_frac, toggle_frac = hbm.tensor_stats(logits)
        hmodel = hbm.HbmEnergyModel.from_vampire(model.params(vendors[0]))
        step = hbm.step_energy(hmodel, read_bytes=traffic * 0.85,
                               write_bytes=traffic * 0.15,
                               step_seconds=step_seconds,
                               ones_frac=ones_frac, toggle_frac=toggle_frac)
        out.update(hbm_step_energy_uj=step.total_pj * 1e-6,
                   hbm_ones_frac=ones_frac, hbm_toggle_frac=toggle_frac)
    return out


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2.5-3b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--decode-tokens", type=int, default=32)
    p.add_argument("--data", type=int, default=1,
                   help="data-parallel mesh axis size")
    p.add_argument("--model", type=int, default=1,
                   help="model-parallel mesh axis size")
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--power-report", action="store_true")
    p.add_argument("--power-model", default="vampire",
                   choices=("vampire", "micron", "drampower"),
                   help="estimator kind scoring the decode HBM traffic")
    from repro.core import model_api
    p.add_argument("--power-impl", default="vectorized",
                   choices=model_api.registered_impls(),
                   help="impl-registry evaluation path for the power "
                        "report (pallas = fused kernels; compiled on TPU, "
                        "interpret elsewhere)")
    p.add_argument("--vampire", default=None,
                   help="saved model blob (model.save: v2 .npz, or legacy "
                        "v1 pickle); quick reference fit when omitted")
    args = p.parse_args()
    res = run(ServeJob(arch=args.arch, smoke=args.smoke, batch=args.batch,
                       prompt_len=args.prompt_len,
                       decode_tokens=args.decode_tokens,
                       data=args.data, model=args.model, seed=args.seed,
                       temperature=args.temperature,
                       power_report=args.power_report,
                       power_model=args.power_model,
                       power_impl=args.power_impl,
                       vampire_path=args.vampire))
    print(f"prefill={res['prefill_s']:.2f}s decode p50={res['decode_p50_ms']:.1f}ms "
          f"p99={res['decode_p99_ms']:.1f}ms throughput={res['tokens_per_s']:.1f} tok/s")
    if "power" in res:
        pw = res["power"]
        line = (f"power[{pw['power_model']}]: "
                f"{pw['traffic_bytes_per_step']/1e6:.1f} MB/step HBM "
                f"traffic, DDR-model {pw['ddr_energy_uj_per_token_mean']:.2f} "
                f"uJ/token (vendors {pw['vendors']})")
        if "hbm_step_energy_uj" in pw:
            line += f", HBM2e-anchored {pw['hbm_step_energy_uj']:.1f} uJ/step"
        print(line)


if __name__ == "__main__":
    main()
