"""The serving mesh engine: resident model + sharded batched dispatch.

One :class:`ServingEngine` owns one estimator for the lifetime of the
service.  At construction the model pytree is ``jax.device_put`` ONCE —
replicated over a ``make_local_mesh(data, model)`` mesh when given — and
every subsequent dispatch closes over that resident copy, so parameters
never re-transfer per tick (the PR 3 pytree property is exactly the hook:
``device_put`` preserves the identity-hashed aux, so the resident model's
treedef equals the original's and jit caches keyed on it keep hitting).

Dispatch is the ring's bucket-shaped :class:`TraceBatch` through
``model.estimate(...)``, wrapped in ``jax.jit`` and — on a multi-device
mesh — ``shard_map`` with the trace axis split over EVERY mesh axis
(``P(("data", "model"))``): per-trace estimation is embarrassingly
parallel (no cross-trace reduction anywhere in the integrator), so the
sharded result is bitwise identical to the single-device one, which the
parity suite asserts.  The vendor/module-axis half of the mesh story
lives in ``fleet.fleet_surface_energy(mesh=)``, where the module axis is
the dispatch's vendor axis and shards over ``'model'``.

Graceful degradation: a 1-device mesh (or no mesh) skips ``shard_map``
entirely, and a batch whose trace count does not divide the device count
falls back to the plain jitted dispatch — same numerics on every path.

The compiled-program cache is keyed on (vendors, mode/impl are fixed per
engine, sharded-or-not); with ring bucketing bounding the batch shapes,
``cache_size()`` is bounded by ``len(count_buckets) * len(length_buckets)``
per key — the dispatch auditor's serving probe holds this.
"""
from __future__ import annotations

import math

import jax

from repro.core import model_api
from repro.core.estimate_batch import TraceBatch


class ServingEngine:
    """Resident-model dispatcher over an optional ``(data, model)`` mesh.

    ``mode``/``impl``/fractions are fixed per engine (a service serves ONE
    estimation configuration); ``vendors`` varies per dispatch (vendor-
    subset requests are grouped by the ring)."""

    def __init__(self, model, *, mesh=None, impl: str = "vectorized",
                 mode: str = "mean", data=None, ones_frac=None,
                 toggle_frac=None):
        self.data = model_api.normalize_data_profile(data, ones_frac,
                                                     toggle_frac)
        model_api.validate_data_profile(mode, self.data)
        self.impl = model_api.resolve_impl(impl, mode=mode).name
        self.mode = mode
        self.ones_frac = self.data.ones_frac
        self.toggle_frac = self.data.toggle_frac
        self.mesh = mesh
        self.n_shards = (math.prod(mesh.shape.values())
                         if mesh is not None else 1)
        # serving shard_maps the TRACE axis, so the model rides replicated
        # (axis=None); the module-axis twin — stacked fleet params sharded
        # over 'model' — lives in fleet.FleetStackCache
        self.resident = model_api.device_resident(model, mesh, axis=None)
        self._fns: dict[tuple, object] = {}

    # ------------------------------------------------------------ dispatch
    def dispatch(self, tb: TraceBatch, vendors=None):
        """Score one bucket-shaped batch -> the model's report (leaves
        (traces, vendors)-shaped; mode='range' a (lo, mean, hi) triple).
        Shards the trace axis when the mesh has >1 device and the batch
        divides it; identical numerics either way."""
        vendors = (tuple(int(v) for v in vendors)
                   if vendors is not None else None)
        sharded = self.n_shards > 1 and tb.n_traces % self.n_shards == 0
        return self._dispatch_fn(vendors, sharded)(
            self.resident, tb.trace, tb.weight)

    def _dispatch_fn(self, vendors, sharded: bool):
        # The model rides as a traced ARGUMENT, not a closure: the jit
        # cache keys on its treedef (identity-hashed aux), so a treedef-
        # stable parameter update (see update_model) re-uses every
        # compiled program instead of recompiling the world.
        fn = self._fns.get((vendors, sharded))
        if fn is None:
            def call(m, trace, weight):
                return m.estimate(
                    TraceBatch(trace, weight), vendors, mode=self.mode,
                    impl=self.impl, ones_frac=self.ones_frac,
                    toggle_frac=self.toggle_frac)

            if sharded:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P
                spec = P(tuple(self.mesh.axis_names))
                call = shard_map(call, mesh=self.mesh,
                                 in_specs=(P(), spec, spec), out_specs=spec,
                                 check_rep=False)
            fn = jax.jit(call)
            self._fns[(vendors, sharded)] = fn
        return fn

    # ----------------------------------------------------------- lifecycle
    def cache_size(self) -> int:
        """Total compiled programs across every dispatch function — the
        quantity the serving recompile probe bounds."""
        return sum(fn._cache_size() for fn in self._fns.values())

    def update_model(self, model) -> None:
        """Swap in updated parameters (the online-recalibration hook:
        fit-while-serving pushes refreshed fits here between ticks).

        Treedef-stable updates — derived from the engine's current model,
        e.g. ``tree_map`` over ``self.resident``, which preserves the
        identity-hashed aux — re-use every compiled program (the model is
        a traced argument, so the jit cache keys on its treedef).  A
        structurally new model works too, at the cost of a recompile."""
        self.resident = model_api.device_resident(model, self.mesh)
