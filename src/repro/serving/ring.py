"""Persistent TraceBatch ring: continuous admission + bucketed re-padding.

A serving loop cannot afford one compiled program per request shape: ragged
traces arrive continuously, and every distinct padded ``(count, length)``
shape of the batched dispatchers is a separate XLA compile.  The ring is
the fix — it admits ragged :class:`~repro.core.dram.CommandTrace`\\ s as
they arrive and, on each dispatch tick, re-pads the pending window *in
place* (persistent host-side buffers, one per bucket shape) into a small
FIXED set of pad shapes:

* the command axis rounds up to the next **length bucket**
  (:attr:`RingConfig.length_buckets`);
* the trace axis rounds up to the next **count bucket**
  (:attr:`RingConfig.count_buckets`) with all-NOP/dt=0 rows of zero
  weight.

Both paddings are exact by the repo-wide padding contract (a zero-cycle
NOP draws no charge and moves no integrator state; a zero-weight row
contributes neither charge nor cycles), so bucketed results equal the
exact-shape pad bit for bit — and the jit cache of every downstream
dispatcher is bounded by ``len(count_buckets) * len(length_buckets)``
programs no matter what traffic arrives (the dispatch auditor's
serving-path recompile probe holds this).

Count buckets are multiples of 8 so a padded batch always divides the
multi-device meshes the engine shards over (2/4/8-way ``data*model``).

The ring is dispatch-cadence infrastructure only: it never lints, never
estimates, and keeps no results — that is :mod:`repro.serving.service`.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.dram import LINE_WORDS, CommandTrace
from repro.core.estimate_batch import TraceBatch


@dataclasses.dataclass(frozen=True)
class RingConfig:
    """The fixed pad-shape vocabulary (ascending, final entries = caps)."""
    length_buckets: tuple[int, ...] = (256, 1024, 4096, 16384)
    count_buckets: tuple[int, ...] = (8, 16, 32, 64)

    def __post_init__(self):
        for name in ("length_buckets", "count_buckets"):
            buckets = getattr(self, name)
            if not buckets or list(buckets) != sorted(set(buckets)):
                raise ValueError(f"{name} must be non-empty, ascending, "
                                 f"unique; got {buckets}")

    @property
    def max_batch(self) -> int:
        return self.count_buckets[-1]

    @property
    def max_length(self) -> int:
        return self.length_buckets[-1]


class TraceTooLongError(ValueError):
    """An admitted trace exceeds the largest length bucket — it can never
    be padded into a ring shape, so admission rejects it up front."""

    def __init__(self, n: int, limit: int):
        self.n = int(n)
        self.limit = int(limit)
        super().__init__(
            f"trace of {self.n} commands exceeds the ring's largest length "
            f"bucket ({self.limit}); chunk it (traces.py evaluates long "
            f"applications in chunks) or configure larger buckets")


def bucket_for(value: int, buckets: Sequence[int]) -> int | None:
    """Smallest bucket >= ``value``, or None when the largest is exceeded."""
    for b in buckets:
        if value <= b:
            return int(b)
    return None


@dataclasses.dataclass(frozen=True)
class RingBatch:
    """One dispatch window: a bucket-shaped TraceBatch whose first
    ``len(tickets)`` rows are the real admitted traces, in order."""
    batch: TraceBatch
    tickets: tuple[int, ...]
    group: tuple[int, ...] | None   # the vendor-subset key the entries share

    @property
    def n_real(self) -> int:
        return len(self.tickets)

    @property
    def slots(self) -> int:
        return self.batch.n_traces

    @property
    def fill(self) -> float:
        return self.n_real / self.slots


class TraceRing:
    """FIFO admission buffer over persistent per-bucket pad buffers."""

    def __init__(self, config: RingConfig | None = None):
        self.config = config or RingConfig()
        self._pending: collections.deque = collections.deque()
        self._next_ticket = 0
        # (count_bucket, length_bucket) -> dict of reused host arrays; the
        # "re-pad in place" half of the contract: admission churn never
        # allocates fresh pad storage once a bucket shape has been seen
        self._buffers: dict[tuple[int, int], dict[str, np.ndarray]] = {}

    def __len__(self) -> int:
        return len(self._pending)

    # ----------------------------------------------------------- admission
    def admit(self, trace: CommandTrace, ticket: int | None = None,
              group: tuple[int, ...] | None = None) -> int:
        """Queue one ragged trace; returns its ticket.  Raises
        :class:`TraceTooLongError` when no length bucket can hold it."""
        n = int(trace.n)
        if bucket_for(n, self.config.length_buckets) is None:
            raise TraceTooLongError(n, self.config.max_length)
        if ticket is None:
            ticket = self._next_ticket
        self._next_ticket = max(self._next_ticket, ticket) + 1
        self._pending.append((int(ticket), trace, group))
        return int(ticket)

    # ------------------------------------------------------------ dispatch
    def take(self, max_batch: int | None = None) -> RingBatch | None:
        """Pop the oldest dispatch window and re-pad it into its bucket
        shape.  Entries sharing the head entry's ``group`` (vendor-subset
        key) are collected FIFO up to ``max_batch``; other groups keep
        their order for later ticks.  Returns None when the ring is empty
        (the empty flush is a no-op, not an error)."""
        if not self._pending:
            return None
        limit = min(max_batch or self.config.max_batch,
                    self.config.max_batch)
        group = self._pending[0][2]
        picked, kept = [], []
        for entry in self._pending:
            if entry[2] == group and len(picked) < limit:
                picked.append(entry)
            else:
                kept.append(entry)
        self._pending = collections.deque(kept)

        tickets = tuple(t for t, _, _ in picked)
        trs = [tr for _, tr, _ in picked]
        cbucket = bucket_for(len(trs), self.config.count_buckets)
        lbucket = bucket_for(max(int(tr.n) for tr in trs),
                             self.config.length_buckets)
        buf = self._buffers_for(cbucket, lbucket)
        for arr in buf.values():
            arr.fill(0)                      # NOP == 0, dt == 0, weight == 0
        for i, tr in enumerate(trs):
            n = int(tr.n)
            buf["cmd"][i, :n] = np.asarray(tr.cmd)
            buf["bank"][i, :n] = np.asarray(tr.bank)
            buf["row"][i, :n] = np.asarray(tr.row)
            buf["col"][i, :n] = np.asarray(tr.col)
            buf["data"][i, :n] = np.asarray(tr.data)
            buf["dt"][i, :n] = np.asarray(tr.dt)
            buf["weight"][i, :n] = 1.0
        batch = CommandTrace(cmd=jnp.asarray(buf["cmd"]),
                             bank=jnp.asarray(buf["bank"]),
                             row=jnp.asarray(buf["row"]),
                             col=jnp.asarray(buf["col"]),
                             data=jnp.asarray(buf["data"]),
                             dt=jnp.asarray(buf["dt"]))
        return RingBatch(TraceBatch(batch, jnp.asarray(buf["weight"])),
                         tickets, group)

    def _buffers_for(self, count: int, length: int) -> dict[str, np.ndarray]:
        buf = self._buffers.get((count, length))
        if buf is None:
            buf = {
                "cmd": np.zeros((count, length), np.int32),
                "bank": np.zeros((count, length), np.int32),
                "row": np.zeros((count, length), np.int32),
                "col": np.zeros((count, length), np.int32),
                "data": np.zeros((count, length, LINE_WORDS), np.uint32),
                "dt": np.zeros((count, length), np.int32),
                "weight": np.zeros((count, length), np.float32),
            }
            self._buffers[(count, length)] = buf
        return buf
