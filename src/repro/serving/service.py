"""Admission + metrics layer: the estimation service itself.

:class:`EstimationService` glues the serving stack together —

    submit / submit_many           (lint gate -> ring admission)
        -> TraceRing               (bucketed re-padding, FIFO windows)
        -> ServingEngine.dispatch  (resident model, sharded jit)
        -> per-ticket result rows  (+ latency / throughput counters)

Admission routes every ingested trace through the ``trace_lint`` JEDEC
gate: a protocol-illegal trace is returned as a structured
:class:`Rejection` (rule id, command index, bank — the linter's
diagnostics verbatim), never silently priced, and never blocks the legal
traces admitted alongside it.  A trace longer than the ring's largest
length bucket rejects the same way (reason ``'too-long'``).

Dispatch happens on :meth:`step` (one ring window), :meth:`maybe_step`
(cadence-gated, for an ingestion loop's hot path), or :meth:`drain`
(flush everything — shutdown).  Results are keyed by ticket: each
admitted trace's row of the batched report matrix, sliced out after the
dispatch completes.

:meth:`metrics` snapshots the per-dispatch counters the ROADMAP's
serving item asks for: queue depth, batch fill, sustained traces/s,
p50/p99 submit-to-result latency, rejection counts by rule, and the
engine's compiled-program count (the quantity the recompile probe
bounds).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import numpy as np

from repro.core.dram import CommandTrace
from repro.serving.engine import ServingEngine
from repro.serving.ring import RingConfig, TraceRing, TraceTooLongError


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of one service instance (one estimation configuration).

    ``data`` is the typed :class:`~repro.core.model_api.DataProfile`
    spelling of the data-dependence fractions; the loose
    ``ones_frac``/``toggle_frac`` fields remain accepted and both
    spellings meet in the engine's ``normalize_data_profile`` call."""
    ring: RingConfig = RingConfig()
    mode: str = "mean"
    impl: str = "vectorized"
    lint: bool = True            # the ingestion gate; off only for trusted
    cadence_s: float = 0.0       # maybe_step dispatch period (0 = every call)
    max_batch: int | None = None   # per-window cap (<= ring max_batch)
    data: object | None = None     # model_api.DataProfile
    ones_frac: float | None = None
    toggle_frac: float | None = None


@dataclasses.dataclass(frozen=True)
class Rejection:
    """One refused submission, with the evidence."""
    ticket: int
    reason: str                  # 'protocol' | 'too-long'
    diagnostics: tuple           # linter Diagnostics ('protocol' only)

    @property
    def rules(self) -> tuple[str, ...]:
        if self.reason != "protocol":
            return (self.reason,)
        return tuple(sorted({d.rule for d in self.diagnostics}))


@dataclasses.dataclass(frozen=True)
class MetricsSnapshot:
    """Counters since service construction (one dispatch granularity)."""
    admitted: int
    rejected: int
    rejected_by_rule: dict[str, int]
    dispatches: int
    dispatched_traces: int
    completed: int
    queue_depth: int
    batch_fill: float            # mean real-slots / padded-slots
    traces_per_s: float          # admitted traces through dispatch time
    latency_p50_ms: float        # submit -> result available
    latency_p99_ms: float
    dispatch_p50_ms: float       # one engine dispatch, block_until_ready
    dispatch_p99_ms: float
    engine_programs: int         # compiled-program count (bounded by ring)
    # online-recalibration telemetry (zeros unless a fitter is attached)
    drift_score: float = 0.0     # last observe_telemetry's detector score
    drift_peak: float = 0.0      # max score seen since construction
    drift_by_key: dict[str, float] = dataclasses.field(default_factory=dict)
    recalibrations: int = 0      # refits pushed through update_model


def _pct(samples: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(samples), q) * 1e3) \
        if samples else 0.0


class EstimationService:
    """The continuously batched estimation front end (single process:
    the concurrency is in the batched dispatch, not in threads)."""

    def __init__(self, model=None, config: ServiceConfig | None = None, *,
                 mesh=None, engine: ServingEngine | None = None,
                 fitter=None):
        self.config = config or ServiceConfig()
        self.ring = TraceRing(self.config.ring)
        # a prebuilt engine carries its resident model AND its compiled
        # programs into the new service (fresh counters, warm jit cache)
        self.engine = engine if engine is not None else ServingEngine(
            model, mesh=mesh, impl=self.config.impl, mode=self.config.mode,
            data=self.config.data,
            ones_frac=self.config.ones_frac,
            toggle_frac=self.config.toggle_frac)
        # optional streaming fitter (repro.core.recalibrate.StreamingFitter):
        # telemetry flows in through observe_telemetry, refreshed fits flow
        # out through engine.update_model — fit-while-serving
        self.fitter = fitter
        self._drift_last: object | None = None
        self._drift_peak = 0.0
        self._recalibrations = 0
        self._results: dict[int, object] = {}
        self._submit_t: dict[int, float] = {}
        self._next_ticket = 0
        self._closed = False
        self._last_dispatch_t = 0.0
        # counters
        self._admitted = 0
        self._rejected_by_rule: dict[str, int] = {}
        self._rejections: list[Rejection] = []
        self._dispatches = 0
        self._dispatched = 0
        self._completed = 0
        self._fills: list[float] = []
        self._dispatch_s: list[float] = []
        self._latency_s: list[float] = []

    # ----------------------------------------------------------- admission
    def submit(self, trace: CommandTrace,
               vendors: Sequence[int] | None = None) -> int | Rejection:
        """Admit one trace.  Returns its ticket, or a :class:`Rejection`
        when the lint gate (or the ring's length cap) refuses it."""
        tickets, rejections = self.submit_many([trace], vendors)
        return rejections[0] if rejections else tickets[0]

    def submit_many(self, traces: Sequence[CommandTrace],
                    vendors: Sequence[int] | None = None
                    ) -> tuple[list[int | None], list[Rejection]]:
        """Admit a burst: ONE batched lint dispatch over the whole burst,
        then per-trace admission.  Illegal traces become
        :class:`Rejection`\\ s (their slot in ``tickets`` is ``None``);
        the legal ones are admitted regardless — a mixed burst never
        blocks its clean members.  ``vendors`` scopes the whole burst
        (the ring groups windows by vendor subset)."""
        if self._closed:
            raise RuntimeError("service is closed")
        from repro.analysis import trace_lint
        traces = list(traces)
        errors_by_trace: dict[int, list] = {}
        if self.config.lint and traces:
            for d in trace_lint.errors_of(trace_lint.lint_traces(traces)):
                errors_by_trace.setdefault(d.trace_index, []).append(d)
        group = (tuple(int(v) for v in vendors)
                 if vendors is not None else None)
        tickets: list[int | None] = []
        rejections: list[Rejection] = []
        now = time.perf_counter()
        for i, tr in enumerate(traces):
            ticket = self._next_ticket
            self._next_ticket += 1
            diags = errors_by_trace.get(i)
            if diags:
                rejections.append(self._reject(
                    Rejection(ticket, "protocol", tuple(diags))))
                tickets.append(None)
                continue
            try:
                self.ring.admit(tr, ticket=ticket, group=group)
            except TraceTooLongError:
                rejections.append(self._reject(
                    Rejection(ticket, "too-long", ())))
                tickets.append(None)
                continue
            self._submit_t[ticket] = now
            self._admitted += 1
            tickets.append(ticket)
        return tickets, rejections

    def _reject(self, r: Rejection) -> Rejection:
        self._rejections.append(r)
        for rule in r.rules:
            self._rejected_by_rule[rule] = \
                self._rejected_by_rule.get(rule, 0) + 1
        return r

    # ------------------------------------------------------------ dispatch
    def step(self) -> int:
        """Dispatch ONE ring window; returns how many real traces it
        scored (0 on an empty ring — the empty flush is a no-op)."""
        rb = self.ring.take(self.config.max_batch)
        if rb is None:
            return 0
        t0 = time.perf_counter()
        rep = self.engine.dispatch(rb.batch, rb.group)
        jax.block_until_ready(rep)
        t1 = time.perf_counter()
        self._last_dispatch_t = t1
        self._dispatches += 1
        self._dispatched += rb.n_real
        self._fills.append(rb.fill)
        self._dispatch_s.append(t1 - t0)
        for i, ticket in enumerate(rb.tickets):
            self._results[ticket] = jax.tree_util.tree_map(
                lambda x: np.asarray(x)[i], rep)
            self._latency_s.append(t1 - self._submit_t.pop(ticket, t0))
            self._completed += 1
        return rb.n_real

    def maybe_step(self) -> int:
        """The ingestion loop's hot-path tick: dispatch only when the
        cadence period has elapsed (and the ring is non-empty)."""
        if not len(self.ring):
            return 0
        if time.perf_counter() - self._last_dispatch_t < self.config.cadence_s:
            return 0
        return self.step()

    def drain(self) -> int:
        """Flush every pending window (shutdown / end-of-burst); returns
        the total real traces dispatched."""
        total = 0
        while True:
            n = self.step()
            if n == 0:
                return total
            total += n

    def close(self) -> int:
        """Drain, then refuse further submissions."""
        n = self.drain()
        self._closed = True
        return n

    # ----------------------------------------------------------- telemetry
    def observe_telemetry(self, currents, cell_idx, tick: int):
        """Feed one tick of fleet telemetry to the attached streaming
        fitter; when its drift detector fires, refit from the accumulated
        sufficient statistics and hot-swap the refreshed parameters into
        the engine (treedef-stable, so no dispatch recompiles).  Returns
        the fitter's :class:`~repro.core.recalibrate.DriftReport`."""
        if self.fitter is None:
            raise RuntimeError(
                "no streaming fitter attached; construct the service with "
                "fitter=model_api.fit(fitter='streaming', ...)")
        report = self.fitter.observe(currents, cell_idx, tick)
        self._drift_last = report
        self._drift_peak = max(self._drift_peak, report.score)
        if report.triggered:
            self.engine.update_model(self.fitter.refit())
            self._recalibrations += 1
        return report

    # ------------------------------------------------------------- results
    def result(self, ticket: int):
        """Pop one completed ticket's report row (leaves vendor-shaped;
        ``mode='range'`` a (lo, mean, hi) triple of rows).  Raises
        ``KeyError`` while the ticket is still queued."""
        if ticket not in self._results and ticket in self._submit_t:
            raise KeyError(f"ticket {ticket} not yet dispatched "
                           f"(queue depth {len(self.ring)}; call step/drain)")
        return self._results.pop(ticket)

    @property
    def rejections(self) -> tuple[Rejection, ...]:
        return tuple(self._rejections)

    # ------------------------------------------------------------- metrics
    def metrics(self) -> MetricsSnapshot:
        dispatch_time = sum(self._dispatch_s)
        return MetricsSnapshot(
            admitted=self._admitted,
            rejected=len(self._rejections),
            rejected_by_rule=dict(self._rejected_by_rule),
            dispatches=self._dispatches,
            dispatched_traces=self._dispatched,
            completed=self._completed,
            queue_depth=len(self.ring),
            batch_fill=float(np.mean(self._fills)) if self._fills else 0.0,
            traces_per_s=(self._dispatched / dispatch_time
                          if dispatch_time > 0 else 0.0),
            latency_p50_ms=_pct(self._latency_s, 50),
            latency_p99_ms=_pct(self._latency_s, 99),
            dispatch_p50_ms=_pct(self._dispatch_s, 50),
            dispatch_p99_ms=_pct(self._dispatch_s, 99),
            engine_programs=self.engine.cache_size(),
            drift_score=(self._drift_last.score
                         if self._drift_last is not None else 0.0),
            drift_peak=self._drift_peak,
            drift_by_key=(dict(self._drift_last.by_key)
                          if self._drift_last is not None else {}),
            recalibrations=self._recalibrations)
