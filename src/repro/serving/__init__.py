"""Sharded, continuously batched estimation-as-a-service.

The serving stack the ROADMAP's backbone item names, in three layers:

* :mod:`repro.serving.ring` — the persistent :class:`TraceRing`:
  continuous ragged admission, re-padded in place into a small fixed
  vocabulary of bucketed pad shapes, dispatched on a cadence (bounded
  jit cache by construction);
* :mod:`repro.serving.engine` — the :class:`ServingEngine`: the model
  pytree ``device_put`` once and kept resident, dispatches ``shard_map``-
  sharded over a ``make_local_mesh(data, model)`` mesh with graceful
  single-device fallback (identical numerics);
* :mod:`repro.serving.service` — the :class:`EstimationService`:
  ``trace_lint``-gated admission with structured :class:`Rejection`\\ s,
  per-ticket results, and per-dispatch metrics (queue depth, batch fill,
  traces/s, p50/p99 latency, rejection counts).

Quick loop::

    svc = EstimationService(model, ServiceConfig(), mesh=mesh)
    tickets, rejections = svc.submit_many(traces)
    svc.drain()
    rows = [svc.result(t) for t in tickets if t is not None]
    print(svc.metrics())
"""
from repro.serving.engine import ServingEngine
from repro.serving.ring import (RingBatch, RingConfig, TraceRing,
                                TraceTooLongError)
from repro.serving.service import (EstimationService, MetricsSnapshot,
                                   Rejection, ServiceConfig)

__all__ = [
    "EstimationService", "MetricsSnapshot", "Rejection", "RingBatch",
    "RingConfig", "ServiceConfig", "ServingEngine", "TraceRing",
    "TraceTooLongError",
]
