"""Vendored fallback for the `hypothesis` property-testing library.

The test suite declares `hypothesis` as a test dependency (see
``pyproject.toml``); when the real library is importable anywhere else on
``sys.path`` this package transparently loads it instead of itself, so an
installed hypothesis always wins. The fallback below implements only the
tiny API surface the suite uses — ``@given`` / ``@settings`` /
``strategies.integers`` / ``strategies.lists`` — with deterministic,
boundary-first example generation, so the suite stays runnable in offline
containers where `pip install hypothesis` is impossible.
"""
from __future__ import annotations

import functools
import importlib.machinery
import importlib.util
import inspect
import os
import random as _random
import sys
import types
import zlib


def _load_real_hypothesis():
    """Load a real hypothesis installation if one exists elsewhere."""
    here = os.path.dirname(os.path.abspath(__file__))
    parent = os.path.dirname(here)
    paths = [p for p in sys.path
             if os.path.abspath(p if p else os.getcwd()) != parent]
    try:
        spec = importlib.machinery.PathFinder.find_spec("hypothesis", paths)
    except (ImportError, ValueError):
        return None
    if spec is None or spec.origin is None:
        return None
    if os.path.abspath(os.path.dirname(spec.origin)) == here:
        return None
    mod = importlib.util.module_from_spec(spec)
    sys.modules["hypothesis"] = mod
    spec.loader.exec_module(mod)
    return mod


_real = _load_real_hypothesis()

if _real is None:
    # ------------------------------------------------------------------
    # Minimal fallback implementation
    # ------------------------------------------------------------------
    class UnsatisfiedAssumption(Exception):
        pass

    def assume(condition):
        if not condition:
            raise UnsatisfiedAssumption()
        return True

    class _Strategy:
        """A strategy draws one value; index 0/1 hit the boundaries."""

        def __init__(self, draw):
            self._draw = draw

        def do_draw(self, rng, example_index):
            return self._draw(rng, example_index)

        def map(self, fn):
            return _Strategy(lambda rng, i: fn(self._draw(rng, i)))

    def _integers(min_value=None, max_value=None):
        lo = -(2 ** 63) if min_value is None else int(min_value)
        hi = 2 ** 63 - 1 if max_value is None else int(max_value)

        def draw(rng, i):
            if i == 0:
                return lo
            if i == 1:
                return hi
            return rng.randint(lo, hi)
        return _Strategy(draw)

    def _booleans():
        return _Strategy(lambda rng, i: (False, True)[i]
                         if i < 2 else rng.random() < 0.5)

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)

        def draw(rng, i):
            if i == 0:
                return lo
            if i == 1:
                return hi
            return rng.uniform(lo, hi)
        return _Strategy(draw)

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            lambda rng, i: elements[i % len(elements)] if i < 2
            else rng.choice(elements))

    def _just(value):
        return _Strategy(lambda rng, i: value)

    def _lists(elements, min_size=0, max_size=None, **_kw):
        cap = (min_size + 10) if max_size is None else int(max_size)

        def draw(rng, i):
            if i == 0:
                size = min_size
            elif i == 1:
                size = cap
            else:
                size = rng.randint(min_size, cap)
            return [elements.do_draw(rng, min(i, 2)) for _ in range(size)]
        return _Strategy(draw)

    def _tuples(*strategies):
        return _Strategy(lambda rng, i: tuple(s.do_draw(rng, i)
                                              for s in strategies))

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _integers
    strategies.booleans = _booleans
    strategies.floats = _floats
    strategies.lists = _lists
    strategies.sampled_from = _sampled_from
    strategies.just = _just
    strategies.tuples = _tuples
    sys.modules["hypothesis.strategies"] = strategies

    class settings:
        """Decorator storing run options on the test function."""

        def __init__(self, max_examples=50, deadline=None, **_ignored):
            self.max_examples = max_examples
            self.deadline = deadline

        def __call__(self, fn):
            fn._hypothesis_settings = self
            return fn

    _DEFAULT_SETTINGS = settings()

    def given(*given_args, **given_kwargs):
        def decorate(fn):
            sig = inspect.signature(fn)
            param_names = list(sig.parameters)
            pos_names = param_names[:len(given_args)]
            drawn = set(pos_names) | set(given_kwargs)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                opts = getattr(wrapper, "_hypothesis_settings",
                               _DEFAULT_SETTINGS)
                seed = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = _random.Random(seed)
                ran = 0
                attempts = 0
                while ran < opts.max_examples and attempts < \
                        10 * opts.max_examples:
                    i = attempts
                    attempts += 1
                    try:
                        d_args = [s.do_draw(rng, i) for s in given_args]
                        d_kwargs = {k: s.do_draw(rng, i)
                                    for k, s in given_kwargs.items()}
                        fn(*args, *d_args, **kwargs, **d_kwargs)
                    except UnsatisfiedAssumption:
                        continue
                    ran += 1
                if ran == 0:
                    raise AssertionError(
                        f"{fn.__qualname__}: assume() rejected all "
                        f"{attempts} generated examples; the test never "
                        "ran (real hypothesis would error here too)")

            # hide drawn parameters from pytest's fixture resolution
            remaining = [p for n, p in sig.parameters.items()
                         if n not in drawn]
            wrapper.__signature__ = sig.replace(parameters=remaining)
            try:
                del wrapper.__wrapped__
            except AttributeError:
                pass
            wrapper.is_hypothesis_test = True
            return wrapper
        return decorate

    def example(*_args, **_kwargs):  # explicit examples: no-op passthrough
        def decorate(fn):
            return fn
        return decorate

    __version__ = "0.0.0+repro-fallback"
