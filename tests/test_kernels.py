"""Per-kernel allclose sweeps against the pure-jnp/numpy oracles
(interpret=True on CPU), across shapes and dtypes."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bdi import ops as bdi_ops, ref as bdi_ref
from repro.kernels.byte_lut import ops as lut_ops, ref as lut_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.popcount import ops as pc_ops, ref as pc_ref
from repro.kernels.toggle import ops as tg_ops, ref as tg_ref


@pytest.mark.parametrize("n", [1, 7, 256, 1023, 1024, 4096])
def test_popcount_shapes(n, rng):
    x = jnp.asarray(rng.integers(0, 2 ** 32, size=(n, 16), dtype=np.uint32))
    np.testing.assert_array_equal(pc_ops.line_ones(x), pc_ref.line_ones(x))


@hypothesis.settings(deadline=None, max_examples=25)
@hypothesis.given(st.lists(st.integers(0, 2 ** 32 - 1), min_size=16,
                           max_size=16))
def test_popcount_matches_python_bitcount(words):
    line = jnp.asarray(np.asarray(words, dtype=np.uint32)[None])
    expected = sum(int(w).bit_count() for w in words)
    assert int(pc_ops.line_ones(line)[0]) == expected


@pytest.mark.parametrize("n", [2, 63, 512, 2048])
def test_toggle_shapes(n, rng):
    cur = jnp.asarray(rng.integers(0, 2 ** 32, size=(n, 16), dtype=np.uint32))
    prev = jnp.asarray(rng.integers(0, 2 ** 32, size=(n, 16),
                                    dtype=np.uint32))
    np.testing.assert_array_equal(tg_ops.line_toggles(cur, prev),
                                  tg_ref.line_toggles(cur, prev))
    np.testing.assert_array_equal(tg_ops.line_toggles_seq(cur),
                                  tg_ref.line_toggles_seq(cur))


@pytest.mark.parametrize("n", [1, 33, 512])
def test_byte_lut_shapes(n, rng):
    x = jnp.asarray(rng.integers(0, 2 ** 32, size=(n, 16), dtype=np.uint32))
    lut = jnp.asarray(rng.permutation(256).astype(np.int32))
    np.testing.assert_array_equal(lut_ops.apply_lut_lines(x, lut),
                                  lut_ref.apply_lut_lines(x, lut))


def _bdi_corpus(rng, n=64):
    return np.concatenate([
        rng.integers(0, 2 ** 32, size=(n, 16), dtype=np.uint32),
        np.zeros((8, 16), dtype=np.uint32),
        np.full((8, 16), 0xDEADBEEF, dtype=np.uint32),
        (rng.integers(0, 5, size=(n, 16)).astype(np.uint32) + 0x7FFFFFF0),
        np.repeat(rng.integers(0, 2 ** 16, size=(8, 1)).astype(np.uint32)
                  * 0x00010001, 16, axis=1),
    ])


def test_bdi_sizes_match_offline_encoder(rng):
    lines = _bdi_corpus(rng)
    sizes_k, _ = bdi_ops.bdi_sizes(jnp.asarray(lines))
    sizes_ref = bdi_ref.bdi_sizes(lines)
    np.testing.assert_array_equal(np.asarray(sizes_k), sizes_ref)


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(base=st.integers(0, 2 ** 31), delta=st.integers(-100, 100))
def test_bdi_detects_small_delta_lines(base, delta):
    vals = np.asarray([(base + delta * i) & 0xFFFFFFFFFFFFFFFF
                       for i in range(8)], dtype=np.uint64)
    by = vals.view(np.uint8).reshape(1, 64)
    line = bdi_ref.bytes_from_lines(
        np.ascontiguousarray(by).view(np.uint32).reshape(1, 16))
    from repro.kernels.bdi.bdi import bdi_sizes_pallas
    sizes, _ = bdi_sizes_pallas(jnp.asarray(line))
    assert int(sizes[0]) <= 24 if delta != 0 else int(sizes[0]) <= 8


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,skv,h,kh,d", [
    (256, 256, 4, 2, 32), (512, 512, 2, 2, 64), (256, 512, 8, 2, 16)])
def test_flash_attention_sweep(dtype, sq, skv, h, kh, d, rng):
    q = jnp.asarray(rng.standard_normal((h, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((kh, skv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((kh, skv, d)), dtype)
    for causal in (True, False):
        if causal and sq != skv:
            continue
        out = fa_ops.flash_attention(q, k, v, causal=causal,
                                     block_q=128, block_k=128)
        ref = fa_ref.attention_ref(q, k, v, causal=causal)
        atol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=atol)


def test_vampire_energy_kernel_matches_vectorized():
    from repro.core import device_sim, idd_loops
    from repro.core.energy_model import trace_energy_vectorized
    from repro.kernels.vampire_energy.ops import trace_energy_kernel
    pp = device_sim.true_vendor_params(1)._replace(
        ones_quad=jnp.zeros(()))
    for loop in (idd_loops.idd4r(), idd_loops.idd4w(), idd_loops.idd7()):
        a = trace_energy_vectorized(loop, pp)
        b = trace_energy_kernel(loop, pp)
        np.testing.assert_allclose(float(a.avg_current_ma),
                                   float(b.avg_current_ma), rtol=1e-4)


def test_blockwise_attention_matches_flash_ref(rng):
    """The models' pure-jnp blockwise attention == the kernel oracle."""
    from repro.models.layers import blockwise_attention
    b, s, h, kh, d = 2, 256, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kh, d)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=True, block=64)
    # oracle over (b*h) layout
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kh, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kh, s, d)
    ref = fa_ref.attention_ref(qr, kr, vr, causal=True)
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
