"""The impl registry (``model_api.resolve_impl``) and its golden parity
suite: ``impl='pallas'`` (interpret mode on CPU) == ``impl='vectorized'``
== the per-command ``impl='reference'`` oracle, leaf for leaf, for all
three estimator kinds x all three modes, over ragged NOP/dt=0-padded
batches and vendor subsets — and pad rows must contribute exactly zero
energy.  Also covers the call-time platform detection in
``kernels/common`` and the campaign engine's fused measurement path."""
import jax
import numpy as np
import pytest

from repro.core import dram, idd_loops, model_api, traces
from repro.core.baselines_power import DRAMPowerModel, MicronModel
from repro.core.dram import (ACT, NOP, PDE, PDE_SLOW, PDX, PRE, PREA, RD,
                             SRE, SRX, WR, TIMING)
from repro.kernels import common as kcommon

_T = TIMING

MODE_KW = {"mean": {}, "range": {}, "surface": {},
           "distribution": dict(ones_frac=0.35, toggle_frac=0.15)}


def _pde_trace():
    """PDE/PDX around RD/WR activity (background-state edge cases)."""
    return dram.make_trace(
        [ACT, RD, RD, PREA, PDE, PDX, ACT, WR, PRE],
        [0, 0, 0, 0, 0, 0, 2, 2, 2],
        [5, 5, 5, 0, 0, 0, 9, 9, 0],
        [0, 0, 1, 0, 0, 0, 0, 3, 0],
        None,
        [_T.tRCD, _T.tCCD, _T.tCCD, _T.tRP, 200, _T.tCKE,
         _T.tRCD, _T.tBURST, _T.tRP])


def _lowpower_trace():
    """Every background state in one trace: fast PDN, slow PDN (DLL off),
    active PDN (bank open across the window), and self-refresh."""
    return dram.make_trace(
        [ACT, RD, PREA, PDE, NOP, PDX,
         PDE_SLOW, NOP, PDX,
         ACT, PDE, NOP, PDX, PREA,
         SRE, NOP, SRX, ACT, WR, PRE],
        [0, 0, 0, 0, 0, 0,
         0, 0, 0,
         3, 3, 3, 3, 3,
         0, 0, 0, 1, 1, 1],
        [5, 5, 0, 0, 0, 0,
         0, 0, 0,
         9, 9, 9, 9, 0,
         0, 0, 0, 2, 2, 0],
        [0, 1, 0, 0, 0, 0,
         0, 0, 0,
         0, 0, 0, 0, 0,
         0, 0, 0, 0, 3, 0],
        None,
        [_T.tRCD, _T.tBURST, _T.tRP, _T.tCKE, 120, _T.tXP,
         _T.tCKE, 300, _T.tXPDLL,
         _T.tRCD, _T.tCKE, 180, _T.tXP, _T.tRP,
         _T.tCKE, 900, _T.tXS, _T.tRCD, _T.tBURST, _T.tRP])


@pytest.fixture(scope="module")
def ragged():
    trs = [traces.app_trace(traces.SPEC_APPS[i], n_requests=n)
           for i, n in ((0, 90), (4, 150))]
    trs.append(idd_loops.validation_sweep(24))
    trs.append(_pde_trace())
    trs.append(_lowpower_trace())
    return trs


@pytest.fixture(scope="module")
def estimators(quick_vampire):
    return (quick_vampire, MicronModel.from_vampire(quick_vampire),
            DRAMPowerModel.from_vampire(quick_vampire))


def _reports(rep, mode):
    return rep if mode == "range" else (rep,)


# ---------------------------------------------------------------------------
# Golden parity: all estimators x all modes x all impls
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ("mean", "range", "distribution", "surface"))
def test_golden_parity_every_estimator_and_impl(estimators, ragged, mode):
    kw = MODE_KW[mode]
    shape = ((len(ragged), 3, dram.N_BANKS, dram.N_ROW_BANDS)
             if mode == "surface" else (len(ragged), 3))
    for est in estimators:
        base = est.estimate(ragged, mode=mode, **kw)
        assert _reports(base, mode)[0].energy_pj.shape == shape
        for impl in ("pallas", "reference"):
            other = est.estimate(ragged, mode=mode, impl=impl, **kw)
            for b, o in zip(_reports(base, mode), _reports(other, mode)):
                for name, lb, lo in zip(b._fields, b, o):
                    np.testing.assert_allclose(
                        np.asarray(lo), np.asarray(lb), rtol=1e-5,
                        err_msg=f"{est.kind} mode={mode} impl={impl} "
                                f"leaf {name}")


def test_vendor_subset_parity(estimators, ragged):
    for est in estimators:
        full = est.estimate(ragged, impl="pallas")
        sub = est.estimate(ragged, (0, 2), impl="pallas")
        np.testing.assert_allclose(np.asarray(sub.energy_pj),
                                   np.asarray(full.energy_pj)[:, [0, 2]],
                                   rtol=1e-6, err_msg=est.kind)
        vec = est.estimate(ragged, (0, 2))
        np.testing.assert_allclose(np.asarray(sub.energy_pj),
                                   np.asarray(vec.energy_pj), rtol=1e-5,
                                   err_msg=est.kind)


def test_pad_rows_contribute_exactly_zero(quick_vampire):
    """Explicitly NOP/dt=0-padding a batch member to 3x its length must
    not change a single report leaf, on either batched impl — including
    per surface cell (pad NOPs land on cell (0, 0) and must add exactly
    zero charge AND zero cycles there)."""
    tr = idd_loops.validation_sweep(16)
    longer = idd_loops.validation_sweep(64)
    padded = dram.pad_trace(tr, 3 * tr.n)
    for impl in ("vectorized", "pallas"):
        for mode in ("mean", "surface"):
            a = quick_vampire.estimate([tr, longer], impl=impl, mode=mode)
            b = quick_vampire.estimate([padded, longer], impl=impl,
                                       mode=mode)
            for name, la, lb in zip(a._fields, a, b):
                np.testing.assert_allclose(
                    np.asarray(lb), np.asarray(la), rtol=1e-6,
                    err_msg=f"{impl} mode={mode} leaf {name}")


def test_batch_member_matches_solo_estimate(quick_vampire, ragged):
    """Each ragged member scored inside the padded batch == scored alone
    at its own (unpadded) shape, through the fused kernels."""
    rep = quick_vampire.estimate(ragged, impl="pallas")
    for i, tr in enumerate(ragged):
        one = quick_vampire.estimate([tr], impl="pallas")
        np.testing.assert_allclose(np.asarray(rep.energy_pj)[i],
                                   np.asarray(one.energy_pj)[0], rtol=1e-5)


def test_kernel_family_matches_its_ref_oracle(quick_vampire, ragged):
    """The pure-jnp oracle shipped beside the kernels
    (``vampire_energy/ref.batched_charge_ref``) pins the raw
    (charge, cycles) contract of ``ops.batched_charge_matrix``."""
    from repro.core.estimate_batch import TraceBatch
    from repro.kernels.vampire_energy import ops as vops
    from repro.kernels.vampire_energy import ref as vref
    tb = TraceBatch.from_traces(list(ragged))
    stacked = quick_vampire.fleet.params
    a_charge, a_cycles = vops.batched_charge_matrix(tb.trace, tb.weight,
                                                    stacked)
    b_charge, b_cycles = vref.batched_charge_ref(tb.trace, tb.weight,
                                                 stacked)
    np.testing.assert_allclose(np.asarray(a_charge), np.asarray(b_charge),
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(a_cycles),
                                  np.asarray(b_cycles))


def test_single_trace_kernel_shim_matches_batched(quick_vampire):
    """The legacy single-(trace, paramset) kernel entry point is a shim
    onto the batched kernel family."""
    from repro.kernels.vampire_energy.ops import trace_energy_kernel
    tr = idd_loops.validation_sweep(32)
    pp = quick_vampire.params(1)
    one = trace_energy_kernel(tr, pp)
    rep = quick_vampire.estimate([tr], (1,), impl="pallas")
    np.testing.assert_allclose(float(one.energy_pj),
                               np.asarray(rep.energy_pj)[0, 0], rtol=1e-6)


# ---------------------------------------------------------------------------
# Registry mechanics
# ---------------------------------------------------------------------------
def test_registry_resolution_and_errors():
    assert model_api.resolve_impl("scan").name == "reference"  # alias
    assert set(model_api.registered_impls()) >= {"vectorized", "pallas",
                                                 "reference"}
    for name in model_api.registered_impls():
        assert model_api.resolve_impl(name).name == name
    with pytest.raises(ValueError, match="unknown impl"):
        model_api.resolve_impl("typo")
    with pytest.raises(ValueError, match="unknown impl"):
        model_api.resolve_impl("kernel")  # the removed legacy entry point


def test_registry_accepts_new_impls_like_estimator_kinds():
    extra = model_api.EstimateImpl("test-only", "registry probe",
                                   modes=("mean",))
    model_api.register_impl(extra)
    try:
        assert model_api.resolve_impl("test-only") is extra
        assert "test-only" in model_api.registered_impls()
        with pytest.raises(ValueError, match="does not support mode"):
            model_api.resolve_impl("test-only", mode="range")
    finally:
        model_api._IMPLS.pop("test-only")


def test_estimate_rejects_unknown_impl(quick_vampire, ragged):
    with pytest.raises(ValueError, match="unknown impl"):
        quick_vampire.estimate(ragged, impl="typo")


def test_estimate_is_loud_for_registered_impl_without_a_path(quick_vampire,
                                                            estimators,
                                                            ragged):
    """Registering an impl does not give existing estimators a dispatch
    for it: estimate() must raise, never silently fall through to the
    reference oracle."""
    extra = model_api.EstimateImpl("no-path", "registry probe")
    model_api.register_impl(extra)
    try:
        for est in estimators:
            with pytest.raises(ValueError, match="no evaluation path"):
                est.estimate(ragged, impl="no-path")
    finally:
        model_api._IMPLS.pop("no-path")


# ---------------------------------------------------------------------------
# Platform detection / interpret fallback (kernels/common)
# ---------------------------------------------------------------------------
def test_interpret_default_resolves_per_call(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert kcommon.interpret_default() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert kcommon.interpret_default() is True
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert kcommon.interpret_default() is (jax.default_backend() != "tpu")


def test_impl_execution_mode_reports_fallback(monkeypatch):
    assert model_api.impl_execution_mode("vectorized") == "compiled"
    assert model_api.impl_execution_mode("reference") == "compiled"
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert model_api.impl_execution_mode("pallas") == "interpret"
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert model_api.impl_execution_mode("pallas") == "compiled"


# ---------------------------------------------------------------------------
# Satellite wiring: kernel data ops + the campaign's fused path
# ---------------------------------------------------------------------------
def test_extract_structural_features_accepts_kernel_data_ops():
    """The popcount/toggle kernel ops wire into the shared feature pass
    and agree bit-for-bit with the jnp default."""
    from repro.core.energy_model import (extract_structural_features,
                                         kernel_data_ops)
    tr = traces.app_trace(traces.SPEC_APPS[2], n_requests=60)
    a = extract_structural_features(tr)
    b = extract_structural_features(tr, data_ops=kernel_data_ops())
    np.testing.assert_array_equal(np.asarray(a.ones), np.asarray(b.ones))
    np.testing.assert_array_equal(np.asarray(a.toggles),
                                  np.asarray(b.toggles))


def test_campaign_measures_identically_through_pallas(tiny_fleet):
    from repro.core import fleet as fleet_mod
    from repro.core.characterize import campaign_plan
    plan = campaign_plan(probe_reps=16, n_rows=4)
    mods = tiny_fleet[:4]
    a = fleet_mod.run_probes(mods, plan.idd_points, impl="vectorized")
    b = fleet_mod.run_probes(mods, plan.idd_points, impl="pallas")
    np.testing.assert_allclose(b, a, rtol=1e-5)
    with pytest.raises(ValueError, match="serial"):
        fleet_mod.run_probes(mods, plan.idd_points, impl="reference")
    # the serial oracle IS impl='reference'; asking it for the fused path
    # must be loud, not silently oracle-measured
    with pytest.raises(ValueError, match="batched"):
        fleet_mod.run_probes(mods, plan.idd_points, engine="serial",
                             impl="pallas")
