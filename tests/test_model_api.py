"""The unified estimator protocol (``repro.core.model_api``).

* golden equivalence: the new ``estimate(mode=...)`` entry point matches
  the legacy ``estimate``/``estimate_range``/``estimate_distribution``
  (+``_many``) outputs leaf for leaf;
* the legacy methods are shims that emit ``DeprecationWarning``;
* the model is a registered pytree (jit with the model as a traced
  argument, ``device_put``);
* repeated ``estimate`` calls re-use the fit-time parameter stack and the
  memoized trace padding — no re-stacking, no recompilation;
* the datasheet baselines implement the same protocol through the same
  batched path;
* schema-v2 save/load round-trips every estimator type and still loads
  v1 pickles (with a warning).
"""
import warnings

import jax
import numpy as np
import pytest

from repro.core import estimate_batch, idd_loops, model_api, traces
from repro.core.baselines_power import (DRAMPowerModel, MicronModel,
                                        drampower, micron_power)
from repro.core.vampire import Vampire


def _leafwise_close(a, b, rtol=2e-6, squeeze=False):
    for name, la, lb in zip(a._fields, a, b):
        la, lb = np.asarray(la), np.asarray(lb)
        if squeeze:
            la = la[0, 0]
        np.testing.assert_allclose(la, lb, rtol=rtol, err_msg=f"leaf {name}")


@pytest.fixture(scope="module")
def ragged_traces():
    trs = [traces.app_trace(traces.SPEC_APPS[i], n_requests=n)
           for i, n in ((0, 100), (5, 180))]
    trs.append(idd_loops.validation_sweep(24))
    return trs


# ---------------------------------------------------------------------------
# Golden equivalence: unified entry point vs the six legacy methods
# ---------------------------------------------------------------------------
def test_estimate_matches_legacy_estimate_leaf_for_leaf(quick_vampire,
                                                        ragged_traces):
    rep = quick_vampire.estimate(ragged_traces)
    assert rep.energy_pj.shape == (len(ragged_traces), 3)
    for i, tr in enumerate(ragged_traces):
        for j, v in enumerate(quick_vampire.vendors):
            with pytest.warns(DeprecationWarning):
                legacy = quick_vampire.estimate(tr, v)
            for name, a, b in zip(rep._fields, rep, legacy):
                np.testing.assert_allclose(
                    np.asarray(a)[i, j], np.asarray(b), rtol=2e-6,
                    err_msg=f"trace {i} vendor {v} leaf {name}")


def test_estimate_mode_range_matches_legacy_range(quick_vampire,
                                                  ragged_traces):
    tr, v = ragged_traces[1], 2
    new = quick_vampire.estimate([tr], (v,), mode="range")
    with pytest.warns(DeprecationWarning):
        old = quick_vampire.estimate_range(tr, v)
    for n, o in zip(new, old):
        _leafwise_close(n, o, squeeze=True)
    with pytest.warns(DeprecationWarning):
        old_many = quick_vampire.estimate_range_many(ragged_traces)
    new_many = quick_vampire.estimate(ragged_traces, mode="range")
    for n, o in zip(new_many, old_many):
        _leafwise_close(n, o)


def test_estimate_mode_distribution_matches_legacy(quick_vampire,
                                                   ragged_traces):
    new = quick_vampire.estimate(ragged_traces, mode="distribution",
                                 ones_frac=0.4, toggle_frac=0.2)
    with pytest.warns(DeprecationWarning):
        old = quick_vampire.estimate_distribution_many(
            ragged_traces, ones_frac=0.4, toggle_frac=0.2)
    _leafwise_close(new, old)
    with pytest.warns(DeprecationWarning):
        one = quick_vampire.estimate_distribution(ragged_traces[0], 1,
                                                  0.4, 0.2)
    np.testing.assert_allclose(np.asarray(new.energy_pj)[0, 1],
                               float(one.energy_pj), rtol=2e-6)


def test_estimate_matches_legacy_many(quick_vampire, ragged_traces):
    with pytest.warns(DeprecationWarning):
        old = quick_vampire.estimate_many(ragged_traces, (0, 2))
    _leafwise_close(quick_vampire.estimate(ragged_traces, (0, 2)), old)


def test_every_legacy_method_warns(quick_vampire):
    tr = idd_loops.validation_sweep(4)
    for call in (lambda: quick_vampire.estimate(tr, 0),
                 lambda: quick_vampire.estimate_range(tr, 0),
                 lambda: quick_vampire.estimate_distribution(tr, 0, 0.5, 0.1),
                 lambda: quick_vampire.estimate_many([tr]),
                 lambda: quick_vampire.estimate_range_many([tr]),
                 lambda: quick_vampire.estimate_distribution_many(
                     [tr], ones_frac=0.5, toggle_frac=0.1)):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            call()


def test_unified_api_does_not_warn(quick_vampire, ragged_traces):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        quick_vampire.estimate(ragged_traces, (0, 1))
        quick_vampire.estimate(ragged_traces[0])       # single trace, new API
        quick_vampire.estimate(ragged_traces, mode="range")


def test_estimate_scan_impl_matches_vectorized(quick_vampire, ragged_traces):
    vec = quick_vampire.estimate(ragged_traces, (1,))
    scan = quick_vampire.estimate(ragged_traces, (1,), impl="scan")
    _leafwise_close(scan, vec, rtol=1e-5)


def test_estimate_argument_validation(quick_vampire, ragged_traces):
    with pytest.raises(ValueError, match="distribution"):
        quick_vampire.estimate(ragged_traces, mode="distribution")
    with pytest.raises(ValueError, match="unknown mode"):
        quick_vampire.estimate(ragged_traces, mode="typo")
    with pytest.raises(ValueError, match="only meaningful"):
        quick_vampire.estimate(ragged_traces, ones_frac=0.5)
    with pytest.raises(KeyError, match="not fitted"):
        quick_vampire.estimate(ragged_traces, (7,))
    # the legacy (trace, int vendor) form is mean-mode only: explicit
    # new-API kwargs must be rejected, not silently discarded
    tr = ragged_traces[0]
    with pytest.raises(TypeError, match="legacy"):
        quick_vampire.estimate(tr, 0, mode="range")
    with pytest.raises(TypeError, match="legacy"):
        quick_vampire.estimate(tr, 0, mode="distribution",
                               ones_frac=0.5, toggle_frac=0.2)
    # positional impl (the legacy 3-arg form) demands exactly one trace:
    # squeezing a multi-trace matrix would silently drop every other trace
    with pytest.raises(TypeError, match="one CommandTrace"):
        quick_vampire.estimate(list(ragged_traces), 0, "scan")


# ---------------------------------------------------------------------------
# Pytree-native model
# ---------------------------------------------------------------------------
def test_vampire_is_a_pytree_jit_and_device_put(quick_vampire,
                                                ragged_traces):
    """The acceptance bar: the model compiles as a TRACED argument and can
    be placed on devices as a pytree."""
    tb = estimate_batch.TraceBatch.from_traces(ragged_traces)
    ref = np.asarray(quick_vampire.estimate(tb).energy_pj)

    jitted = jax.jit(lambda m: m.estimate(tb).energy_pj)
    np.testing.assert_allclose(np.asarray(jitted(quick_vampire)), ref,
                               rtol=2e-6)

    moved = jax.device_put(quick_vampire)
    assert isinstance(moved, Vampire)
    np.testing.assert_allclose(np.asarray(moved.estimate(tb).energy_pj),
                               ref, rtol=2e-6)

    leaves = jax.tree_util.tree_leaves(quick_vampire)
    assert all(hasattr(leaf, "shape") for leaf in leaves)
    # the stacked bundle leads with the vendor axis
    fm = quick_vampire.fleet
    assert fm.params.datadep.shape[0] == fm.band.shape[0] \
        == fm.vendor_ids.shape[0] == len(quick_vampire.vendors)


def test_flatten_yields_stable_treedefs_and_no_retrace(quick_vampire,
                                                       baseline_models,
                                                       ragged_traces):
    """Regression: the pytree aux is built once per instance, so repeated
    flattens compare equal and a jitted function taking the model as a
    traced argument compiles exactly once."""
    micron, _ = baseline_models
    for model in (quick_vampire, micron):
        _, td1 = jax.tree_util.tree_flatten(model)
        _, td2 = jax.tree_util.tree_flatten(model)
        assert td1 == td2
        # device_put round trip keeps the treedef too
        _, td3 = jax.tree_util.tree_flatten(jax.device_put(model))
        assert td1 == td3
    tb = estimate_batch.TraceBatch.from_traces(list(ragged_traces))
    jitted = jax.jit(lambda m: m.estimate(tb).energy_pj)
    jitted(quick_vampire)
    jitted(quick_vampire)
    assert jitted._cache_size() == 1


def test_fleet_params_stacked_once_and_reused(quick_vampire, ragged_traces):
    fm1 = quick_vampire.fleet
    quick_vampire.estimate(ragged_traces)
    quick_vampire.estimate(ragged_traces, (0, 2))
    assert quick_vampire.fleet is fm1          # no re-stacking per call
    # vendor subsets are sliced once and memoized per vendor tuple
    s1 = quick_vampire._stacked_for((0, 2))
    s2 = quick_vampire._stacked_for((0, 2))
    assert s1[0] is s2[0] and s1[1] is s2[1]


def test_second_estimate_call_triggers_no_recompilation(quick_vampire,
                                                        ragged_traces):
    """Regression: repeated estimate calls over the same vendor set must
    re-use the fit-time stack and the memoized padding — i.e. hit the jit
    cache instead of recompiling (cache-size check)."""
    trs = list(ragged_traces)
    quick_vampire.estimate(trs)                 # warm (pad + compile)
    n_programs = estimate_batch.batched_reports._cache_size()
    tb1 = quick_vampire._batch_cache.get(trs)
    quick_vampire.estimate(trs)                 # same list object again
    assert estimate_batch.batched_reports._cache_size() == n_programs
    assert quick_vampire._batch_cache.get(trs) is tb1   # padding memoized
    # a different vendor subset of the same batch: still no new program
    quick_vampire.estimate(trs, (0, 1))
    quick_vampire.estimate(trs, (0, 1))
    assert estimate_batch.batched_reports._cache_size() <= n_programs + 1


# ---------------------------------------------------------------------------
# Baselines through the same protocol
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def baseline_models(quick_vampire):
    return (MicronModel.from_vampire(quick_vampire),
            DRAMPowerModel.from_vampire(quick_vampire))


def test_baselines_match_per_trace_functions(quick_vampire, baseline_models,
                                             ragged_traces):
    micron, dpow = baseline_models
    ds = {v: quick_vampire.by_vendor[v].idd_datasheet
          for v in quick_vampire.vendors}
    for model, fn in ((micron, micron_power), (dpow, drampower)):
        rep = model.estimate(ragged_traces)
        assert rep.energy_pj.shape == (len(ragged_traces), 3)
        for i, tr in enumerate(ragged_traces):
            for j, v in enumerate(model.vendors):
                np.testing.assert_allclose(
                    np.asarray(rep.avg_current_ma)[i, j],
                    float(fn(tr, ds[v]).avg_current_ma), rtol=2e-6,
                    err_msg=f"{model.kind} trace {i} vendor {v}")


def test_baseline_modes_degenerate_without_variation(baseline_models,
                                                     ragged_traces):
    micron, _ = baseline_models
    mean = micron.estimate(ragged_traces)
    lo, mid, hi = micron.estimate(ragged_traces, mode="range")
    np.testing.assert_array_equal(np.asarray(lo.energy_pj),
                                  np.asarray(hi.energy_pj))
    dist = micron.estimate(ragged_traces, mode="distribution",
                           ones_frac=0.9, toggle_frac=0.9)
    np.testing.assert_array_equal(np.asarray(dist.energy_pj),
                                  np.asarray(mean.energy_pj))


def test_baseline_argument_validation_matches_vampire(baseline_models,
                                                      ragged_traces):
    micron, _ = baseline_models
    with pytest.raises(ValueError, match="only meaningful"):
        micron.estimate(ragged_traces, ones_frac=0.5)
    with pytest.raises(ValueError, match="requires ones_frac"):
        micron.estimate(ragged_traces, mode="distribution")
    with pytest.raises(ValueError, match="unknown mode"):
        micron.estimate(ragged_traces, mode="typo")
    with pytest.raises(ValueError, match="unknown impl"):
        micron.estimate(ragged_traces, impl="typo")
    with pytest.raises(KeyError, match="not fitted"):
        micron.estimate(ragged_traces, (9,))


def test_baselines_are_pytrees(baseline_models, ragged_traces):
    micron, _ = baseline_models
    tb = estimate_batch.TraceBatch.from_traces(list(ragged_traces))
    ref = np.asarray(micron.estimate(tb).energy_pj)
    jitted = jax.jit(lambda m: m.estimate(tb).energy_pj)
    np.testing.assert_allclose(np.asarray(jitted(micron)), ref, rtol=2e-6)
    moved = jax.device_put(micron)
    assert isinstance(moved, MicronModel)
    np.testing.assert_allclose(np.asarray(moved.estimate(tb).energy_pj),
                               ref, rtol=2e-6)


def test_run_validation_accepts_any_estimator(quick_vampire, tiny_fleet,
                                              baseline_models):
    from repro.core.validate import run_validation
    micron, dpow = baseline_models
    res = run_validation(quick_vampire, fleet=tiny_fleet,
                         n_values=(0, 8, 64),
                         estimators={"vampire": quick_vampire,
                                     "micron": micron,
                                     "drampower": dpow})
    assert set(res.mape) == {"vampire", "micron", "drampower"}
    assert all(np.isfinite(m) for m in res.mape_mean.values())


def test_make_estimator_registry(quick_vampire):
    assert model_api.make_estimator("vampire", quick_vampire) \
        is quick_vampire
    assert isinstance(model_api.make_estimator("micron", quick_vampire),
                      MicronModel)
    assert isinstance(model_api.make_estimator("drampower", quick_vampire),
                      DRAMPowerModel)
    with pytest.raises(ValueError, match="unknown estimator kind"):
        model_api.make_estimator("speculative", quick_vampire)


# ---------------------------------------------------------------------------
# Versioned serialization
# ---------------------------------------------------------------------------
def test_v2_roundtrip_every_estimator_type(quick_vampire, baseline_models,
                                           ragged_traces, tmp_path):
    estimators = (quick_vampire,) + baseline_models
    for est in estimators:
        path = str(tmp_path / f"{est.kind}.npz")
        est.save(path)
        manifest = model_api.read_manifest(path)
        assert manifest["schema"] == model_api.SCHEMA_VERSION
        assert manifest["kind"] == est.kind
        with warnings.catch_warnings():
            warnings.simplefilter("error")     # v2 loads silently
            loaded = model_api.load_estimator(path)
        assert type(loaded) is type(est)
        assert loaded.vendors == est.vendors
        _leafwise_close(loaded.estimate(ragged_traces),
                        est.estimate(ragged_traces), rtol=1e-6)


def test_v2_manifest_meta_roundtrip(quick_vampire, tmp_path):
    path = str(tmp_path / "tagged.npz")
    quick_vampire.save(path, meta={"fit_kw": {"probe_reps": 64}})
    assert model_api.read_manifest(path)["meta"] == {
        "fit_kw": {"probe_reps": 64}}


def test_v1_pickle_migrates_with_warning(quick_vampire, ragged_traces,
                                         tmp_path):
    """v1 pickle -> load (warns) -> v2 save -> load (silent): the fitted
    quantities survive both hops exactly."""
    v1 = str(tmp_path / "model_v1.pkl")
    model_api._save_v1_pickle(quick_vampire, v1)
    with pytest.warns(DeprecationWarning, match="schema-v1 pickle"):
        migrated = Vampire.load(v1)
    for v in quick_vampire.vendors:
        for name, a, b in zip(migrated.params(v)._fields,
                              migrated.params(v), quick_vampire.params(v)):
            if name == "act_surface":
                # the v1 format predates the structural surface: migrated
                # models carry the documented neutral (all-ones) surface
                np.testing.assert_array_equal(np.asarray(a),
                                              np.ones_like(np.asarray(a)))
                continue
            if name in ("i_pd_slow", "i_actpd", "i_sr"):
                # the v1 format also predates the background-state
                # lattice: migrated models fall back to the fast
                # power-down current for the deeper states
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(migrated.params(v).i_pd),
                    err_msg=f"vendor {v} leaf {name}")
                continue
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"vendor {v} leaf {name}")
        assert migrated.variation_band[v] == quick_vampire.variation_band[v]
    v2 = str(tmp_path / "model_v2.npz")
    migrated.save(v2)
    reloaded = model_api.load_estimator(v2)
    mig_rep = migrated.estimate(ragged_traces)
    _leafwise_close(reloaded.estimate(ragged_traces), mig_rep, rtol=1e-6)


def test_v1_fixture_artifact_loads(ragged_traces):
    """The checked-in v1 fixture (the pre-redesign benchmark fit cache)
    must keep loading through the migration path."""
    import os
    fixture = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "vampire_fit_v1.pkl")
    if not os.path.exists(fixture):
        pytest.skip("v1 fixture artifact not present")
    with pytest.warns(DeprecationWarning, match="schema-v1 pickle"):
        model = model_api.load_estimator(fixture)
    assert isinstance(model, Vampire)
    rep = model.estimate(ragged_traces)
    assert np.all(np.asarray(rep.energy_pj) > 0)


def test_v2_roundtrips_raw_campaign_sweeps(quick_vampire, tmp_path):
    """The benchmark fit cache rides the same format, so the raw sweep
    record must survive (the per-figure benchmarks plot it)."""
    path = str(tmp_path / "with_raw.npz")
    quick_vampire.save(path)
    loaded = Vampire.load(path)
    for v, vc in quick_vampire.by_vendor.items():
        lvc = loaded.by_vendor[v]
        assert lvc.idd_datasheet == vc.idd_datasheet     # exact (float64)
        for key, arr in vc.idd_measured.items():
            np.testing.assert_array_equal(lvc.idd_measured[key], arr)
        assert set(lvc.ones_sweep) == set(vc.ones_sweep)
        sk = ("none", "RD")
        np.testing.assert_array_equal(lvc.ones_sweep[sk]["current"],
                                      vc.ones_sweep[sk]["current"])
        np.testing.assert_array_equal(lvc.row_sweep["row_ones"],
                                      vc.row_sweep["row_ones"])
