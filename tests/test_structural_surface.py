"""The structural-variation surfaces (paper Section 6, Figs 19-22):
``mode='surface'`` semantics, the planted per-(bank, row-band) ground
truth, and the surface-fit campaign's recovery of it."""
import jax
import numpy as np
import pytest

from repro.core import device_sim, dram, idd_loops, validate
from repro.core import params as P
from repro.core.baselines_power import DRAMPowerModel, MicronModel
from repro.core.estimate_batch import TraceBatch


@pytest.fixture(scope="module")
def surface_traces():
    return [validate.surface_sweep_trace(reps=2),
            idd_loops.validation_sweep(24)]


# ---------------------------------------------------------------------------
# mode='surface' semantics
# ---------------------------------------------------------------------------
def test_surface_sums_to_mean_for_every_estimator(quick_vampire,
                                                  surface_traces):
    """The surface is a decomposition, not a different physics: summing
    the (bank, row-band) cells recovers mode='mean' leaf for leaf."""
    ests = (quick_vampire, MicronModel.from_vampire(quick_vampire),
            DRAMPowerModel.from_vampire(quick_vampire))
    for est in ests:
        mean = est.estimate(surface_traces)
        surf = est.estimate(surface_traces, mode="surface")
        np.testing.assert_allclose(
            np.asarray(surf.charge_ma_cycles).sum(axis=(2, 3)),
            np.asarray(mean.charge_ma_cycles), rtol=1e-5, err_msg=est.kind)
        np.testing.assert_array_equal(
            np.asarray(surf.cycles).sum(axis=(2, 3)),
            np.asarray(mean.cycles), err_msg=est.kind)


def test_surface_vendor_subset_parity(quick_vampire, surface_traces):
    full = quick_vampire.estimate(surface_traces, mode="surface")
    sub = quick_vampire.estimate(surface_traces, (0, 2), mode="surface")
    np.testing.assert_allclose(np.asarray(sub.energy_pj),
                               np.asarray(full.energy_pj)[:, [0, 2]],
                               rtol=1e-6)


def test_surface_rejects_distribution_fractions(quick_vampire,
                                                surface_traces):
    with pytest.raises(ValueError, match="only meaningful"):
        quick_vampire.estimate(surface_traces, mode="surface",
                               ones_frac=0.5, toggle_frac=0.5)


def test_surface_act_energy_lands_on_the_right_cell(quick_vampire):
    """An ACT to (bank, row) charges exactly the (bank, row_band(row))
    cell above background."""
    bank, row = 5, (6 << dram.ROW_BAND_SHIFT) | 3
    tr = dram.make_trace([dram.ACT, dram.PRE], [bank] * 2, [row] * 2,
                         [0, 0], None, [dram.TIMING.tRAS, dram.TIMING.tRP])
    rep = quick_vampire.estimate([tr], (0,), mode="surface")
    surf = np.asarray(rep.charge_ma_cycles)[0, 0]
    # only the target bank's row-band cell and the (0,0) background cells
    # carry charge: commands live on bank 5 (ACT: band 6, PRE: band 6)
    nonzero = {tuple(c) for c in np.argwhere(surf > 0)}
    assert nonzero == {(bank, dram.row_band(row))}
    cyc = np.asarray(rep.cycles)[0, 0]
    assert cyc[bank, dram.row_band(row)] == dram.TIMING.tRAS + dram.TIMING.tRP


def test_surface_mode_is_jit_and_device_put_safe(quick_vampire,
                                                 surface_traces):
    """The pytree property extends to the surface dispatch: the model can
    be traced and device_put with mode='surface' riding estimate()."""
    tb = TraceBatch.from_traces(surface_traces)
    ref = np.asarray(quick_vampire.estimate(tb, mode="surface").energy_pj)
    jitted = jax.jit(lambda m: m.estimate(tb, mode="surface").energy_pj)
    np.testing.assert_allclose(np.asarray(jitted(quick_vampire)), ref,
                               rtol=2e-6)
    moved = jax.device_put(quick_vampire)
    np.testing.assert_allclose(
        np.asarray(moved.estimate(tb, mode="surface").energy_pj), ref,
        rtol=2e-6)


# ---------------------------------------------------------------------------
# The planted ground truth is structural (vendor-level), and recovered
# ---------------------------------------------------------------------------
def test_planted_surface_is_structural_and_band0_normalized():
    for v in range(3):
        surf = device_sim.structural_surface(v)
        assert surf.shape == (dram.N_BANKS, dram.N_ROW_BANDS)
        np.testing.assert_array_equal(surf[:, 0], 1.0)
        # identical across modules of the vendor — structural, not process
        a = device_sim.true_module_params(P.ModuleSpec(v, 0, 2015))
        b = device_sim.true_module_params(P.ModuleSpec(v, 7, 2015))
        np.testing.assert_array_equal(np.asarray(a.act_surface),
                                      np.asarray(b.act_surface))
        np.testing.assert_allclose(np.asarray(a.act_surface), surf,
                                   rtol=1e-6)
    # vendor C's surface is the uneven one (paper: C's outsized structural
    # variation); A's is mild
    assert np.ptp(device_sim.structural_surface(2)) > \
        np.ptp(device_sim.structural_surface(0))


def test_surface_fit_campaign_recovers_planted_surface(quick_vampire):
    """The surface campaign (constant-popcount ACT/PRE probes per cell)
    must find the planted per-bank/row factors — including vendor C's
    hottest cell — from a reduced 2-probe-module campaign."""
    for v, vc in quick_vampire.by_vendor.items():
        fitted = np.asarray(vc.act_surface)
        planted = device_sim.structural_surface(v)
        np.testing.assert_array_equal(fitted[:, 0], 1.0)
        np.testing.assert_allclose(fitted, planted, atol=0.08,
                                   err_msg=f"vendor {v}")
    fitted_c = np.asarray(quick_vampire.by_vendor[2].act_surface)
    planted_c = device_sim.structural_surface(2)
    assert np.unravel_index(fitted_c.argmax(), fitted_c.shape) == \
        np.unravel_index(planted_c.argmax(), planted_c.shape)


def test_fitted_surface_round_trips_through_v2_blob(quick_vampire,
                                                    tmp_path):
    from repro.core.vampire import Vampire
    path = str(tmp_path / "m.npz")
    quick_vampire.save(path)
    loaded = Vampire.load(path)
    for v, vc in quick_vampire.by_vendor.items():
        np.testing.assert_allclose(np.asarray(loaded.by_vendor[v].act_surface),
                                   np.asarray(vc.act_surface), rtol=1e-12)


# ---------------------------------------------------------------------------
# Fleet maps + rendering (validate / fleet)
# ---------------------------------------------------------------------------
def test_structural_surface_maps_normalized_and_vendorwise(quick_vampire):
    maps = validate.structural_surface_maps(quick_vampire)
    assert maps.shape == (3, dram.N_BANKS, dram.N_ROW_BANDS)
    np.testing.assert_allclose(maps.sum(axis=(1, 2)), 1.0, rtol=1e-9)
    text = validate.render_surface_heatmap(maps[2], "vendor C")
    assert text.startswith("vendor C") and "bank 7" in text


def test_fleet_surface_energy_whole_fleet_one_dispatch(tiny_fleet):
    from repro.core import fleet as fleet_mod
    tb = TraceBatch.from_traces([validate.surface_sweep_trace(reps=1)])
    rep = fleet_mod.fleet_surface_energy(tiny_fleet, tb.trace, tb.weight)
    assert rep.energy_pj.shape == (1, len(tiny_fleet), dram.N_BANKS,
                                   dram.N_ROW_BANDS)
    # the module axis rides the same engine as vendors: each module's
    # surface equals its own solo report
    solo = fleet_mod.fleet_surface_energy(tiny_fleet[3:4], tb.trace,
                                          tb.weight)
    np.testing.assert_allclose(np.asarray(rep.energy_pj)[:, 3],
                               np.asarray(solo.energy_pj)[:, 0], rtol=1e-6)
    with pytest.raises(ValueError, match="reference"):
        fleet_mod.fleet_surface_energy(tiny_fleet, tb.trace, tb.weight,
                                       impl="reference")
