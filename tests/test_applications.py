"""Section 9.3 applications: page allocation + power-down scheduling."""
import numpy as np
import pytest

from repro.core import applications as A
from repro.core import dram, traces


def test_breakeven_positive_and_sane(quick_vampire):
    bes = {v: A.breakeven_idle_cycles(quick_vampire.params(v))
           for v in quick_vampire.by_vendor}
    for v, be in bes.items():
        assert 10 < be < 500, (v, be)  # tens-to-hundreds of ns regime
    # Vendor A pays the largest activation-restore charge (largest fitted
    # q_actpre) -> longest break-even despite the most effective PD mode
    assert bes[0] == max(bes.values())


def test_powerdown_policy_inserts_valid_commands(quick_vampire):
    tr = traces.app_trace(traces.SPEC_APPS[21], n_requests=200)  # povray
    ptr = A.apply_powerdown_policy(tr, timeout_cycles=64)
    cmd = np.asarray(ptr.cmd)
    # PDE always preceded by PREA and followed (eventually) by PDX
    pde_idx = np.flatnonzero(cmd == dram.PDE)
    assert len(pde_idx) > 0
    for i in pde_idx:
        assert cmd[i - 1] == dram.PREA
        after = cmd[i + 1:]
        nxt = after[np.isin(after, (dram.PDX, dram.PDE))]
        assert len(nxt) == 0 or nxt[0] == dram.PDX
    # total busy work preserved: same RD/WR count
    for op in (dram.RD, dram.WR):
        assert (np.asarray(tr.cmd) == op).sum() == (cmd == op).sum()


def test_powerdown_saves_on_idle_app(quick_vampire):
    res = A.powerdown_study(quick_vampire, traces.SPEC_APPS[21], vendor=0,
                            n_requests=300)
    assert res["breakeven_saving"] > 0
    # too-aggressive powering down must not beat the break-even policy by
    # much on overhead-dominated traces; lazy must save less
    assert res["lazy_saving"] <= res["breakeven_saving"] + 0.02


def test_page_remap_preserves_workload(quick_vampire):
    tr = traces.app_trace(traces.SPEC_APPS[3], n_requests=200)
    remapped = A.remap_trace(tr, quick_vampire.params(2))
    np.testing.assert_array_equal(np.asarray(tr.cmd),
                                  np.asarray(remapped.cmd))
    np.testing.assert_array_equal(np.asarray(tr.data),
                                  np.asarray(remapped.data))
    assert not np.array_equal(np.asarray(tr.bank),
                              np.asarray(remapped.bank))


def test_page_allocation_saves_on_vendor_c(quick_vampire):
    """Vendor C has real structural bank variation -> remap must help."""
    res = A.page_allocation_study(quick_vampire, traces.SPEC_APPS[3],
                                  vendor=2, n_requests=400)
    assert res["saving_frac"] > 0.0


def test_cheap_rows_low_popcount():
    rows = A.cheap_rows(16)
    pops = [bin(int(r)).count("1") for r in rows]
    assert max(pops) <= 2


def test_app_trace_ref_density_tracks_trefi():
    """Regression: app_trace must count EVERY appended command's dt (PRE/ACT
    row-miss cycles included) toward the refresh deadline; skipping them made
    synthetic apps refresh ~2-3x late relative to tREFI on low-locality
    apps."""
    t = dram.TIMING
    app = traces.SPEC_APPS[3]  # mcf: row_hit=0.25 -> PRE/ACT dominate time
    tr = traces.app_trace(app, n_requests=4000)
    total = int(np.asarray(tr.dt, dtype=np.int64).sum())
    n_ref = int((np.asarray(tr.cmd) == dram.REF).sum())
    # each refresh period costs ~tREFI of counted cycles plus the PREA+REF
    # slots themselves (plus sub-percent per-period overshoot)
    period = t.tREFI + t.tRP + t.tRFC
    expected = total / period
    assert expected > 5  # trace long enough for the density to be meaningful
    assert n_ref >= 0.8 * expected
    assert n_ref <= expected + 2
