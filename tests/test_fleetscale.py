"""Fleet-scale estimation: synthetic fleets, the chunked surface dispatch,
the zero-restack stacked-params cache, the kernel autotuner registry, and
the module-axis shard_map twin (multi-device lane)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import device_sim, estimate_batch, fleet, idd_loops
from repro.core.dram import batch_traces


def _surface_batch():
    return batch_traces([(idd_loops.validation_sweep(8, reps=3), 2),
                         (idd_loops.validation_sweep(16, reps=2), 2)])


# ---------------------------------------------------------------------------
# synthetic fleets
# ---------------------------------------------------------------------------
def test_synth_fleet_shapes_and_vendor_cycle():
    vendors, pp = device_sim.synth_fleet_params(9)
    assert vendors.shape == (9,)
    np.testing.assert_array_equal(vendors, np.arange(9) % 3)
    for leaf in jax.tree_util.tree_leaves(pp):
        assert leaf.shape[0] == 9


def test_synth_fleet_seed_stable_prefix():
    """A smaller fleet is a PREFIX of a larger one: module identity (not
    fleet size) seeds each module's process variation."""
    _, small = device_sim.synth_fleet_params(16)
    _, big = device_sim.synth_fleet_params(64)
    for a, b in zip(jax.tree_util.tree_leaves(small),
                    jax.tree_util.tree_leaves(big)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[:16])


def test_synth_fleet_vendor_consistent():
    """Modules of one vendor vary around that vendor's true params — the
    log-space factors are mean-preserving, so a large fleet's per-vendor
    median lands near the vendor center, and vendor identity (not module
    id) sets the center."""
    vendors, pp = device_sim.synth_fleet_params(300)
    base = [device_sim.true_vendor_params(v) for v in range(3)]
    for v in range(3):
        i2n_v = np.asarray(pp.i2n)[vendors == v]
        center = float(np.asarray(base[v].i2n))
        med = float(np.median(i2n_v))
        assert abs(np.log(med / center)) < 0.5
        assert np.all(i2n_v > 0)


def test_synth_fleet_explicit_ids_match_default():
    v_d, pp_d = device_sim.synth_fleet_params(6)
    v_e, pp_e = device_sim.synth_fleet_params(
        vendors=np.arange(6) % 3, module_ids=np.arange(6))
    np.testing.assert_array_equal(v_d, v_e)
    for a, b in zip(jax.tree_util.tree_leaves(pp_d),
                    jax.tree_util.tree_leaves(pp_e)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# chunked surface dispatch
# ---------------------------------------------------------------------------
def test_chunked_vs_oneshot_parity_1k_modules():
    """The acceptance bar: a >=1k-module synthetic fleet's chunked surface
    equals the one-shot dispatch on EVERY report leaf."""
    trace, weight = _surface_batch()
    _, pp = device_sim.synth_fleet_params(1000)
    one = estimate_batch.batched_surface_reports(trace, weight, pp)
    ch = estimate_batch.chunked_surface_reports(trace, weight, pp,
                                                module_chunk=256)
    for f in one._fields:
        np.testing.assert_allclose(np.asarray(getattr(one, f)),
                                   np.asarray(getattr(ch, f)))


def test_chunked_parity_is_bitwise_across_chunkings():
    """Stronger than allclose: the one-shot and every chunking (module
    and trace chunks, dividing or not) run the SAME charge program, so
    results are bitwise identical."""
    trace, weight = _surface_batch()
    _, pp = device_sim.synth_fleet_params(23)      # prime: nothing divides
    one = estimate_batch.batched_surface_reports(trace, weight, pp)
    for mc, tc in ((23, None), (8, None), (5, 1), (7, 2)):
        ch = estimate_batch.chunked_surface_reports(
            trace, weight, pp, module_chunk=mc, trace_chunk=tc)
        for f in one._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(one, f)), np.asarray(getattr(ch, f)),
                err_msg=f"leaf {f} chunking ({mc}, {tc})")


def test_chunked_pallas_matches_oneshot_pallas():
    trace, weight = _surface_batch()
    _, pp = device_sim.synth_fleet_params(10)
    one = estimate_batch.pallas_batched_surface_reports(trace, weight, pp)
    ch = estimate_batch.chunked_surface_reports(trace, weight, pp,
                                                module_chunk=4,
                                                impl="pallas")
    for f in one._fields:
        np.testing.assert_array_equal(np.asarray(getattr(one, f)),
                                      np.asarray(getattr(ch, f)))


def test_chunked_vendor_subset_slice():
    """Slicing one vendor's modules out of the chunked fleet surface
    equals running that subset alone (chunk-size invariance again, from
    the consumer's side)."""
    trace, weight = _surface_batch()
    vendors, pp = device_sim.synth_fleet_params(12)
    full = estimate_batch.chunked_surface_reports(trace, weight, pp,
                                                  module_chunk=5)
    idx = np.flatnonzero(vendors == 1)
    sub_pp = jax.tree_util.tree_map(lambda x: x[idx], pp)
    sub = estimate_batch.chunked_surface_reports(trace, weight, sub_pp,
                                                 module_chunk=3)
    np.testing.assert_array_equal(np.asarray(full.energy_pj)[:, idx],
                                  np.asarray(sub.energy_pj))


def test_chunked_pad_rows_contribute_zero():
    """Trace padding added by the chunked dispatch is zero-weight: a
    trace_chunk that forces pad rows changes nothing, and the pad region
    never leaks into the sliced-off result (checked via a chunking whose
    pad row count differs)."""
    trace, weight = _surface_batch()
    _, pp = device_sim.synth_fleet_params(6)
    no_pad = estimate_batch.chunked_surface_reports(
        trace, weight, pp, module_chunk=6, trace_chunk=2)   # 2 % 2 == 0
    padded = estimate_batch.chunked_surface_reports(
        trace, weight, pp, module_chunk=4, trace_chunk=3)   # pads t and m
    for f in no_pad._fields:
        np.testing.assert_array_equal(np.asarray(getattr(no_pad, f)),
                                      np.asarray(getattr(padded, f)))
    assert np.asarray(no_pad.energy_pj).shape[:2] == (2, 6)


def test_chunked_charge_program_count_fixed_across_fleet_sizes():
    """The scaling contract the dispatch auditor gates: growing the fleet
    at a fixed chunk size must NOT grow the chunk charge program's jit
    cache (program count depends on chunk size, never chunk count)."""
    trace, weight = _surface_batch()
    _, small = device_sim.synth_fleet_params(8)
    _, big = device_sim.synth_fleet_params(32)
    estimate_batch.chunked_surface_reports(trace, weight, small,
                                           module_chunk=4)
    base = estimate_batch._surface_chunk_charge._cache_size()
    estimate_batch.chunked_surface_reports(trace, weight, big,
                                           module_chunk=4)
    assert estimate_batch._surface_chunk_charge._cache_size() == base


# ---------------------------------------------------------------------------
# zero-restack dispatch (the memoized stacked-fleet artifact)
# ---------------------------------------------------------------------------
def test_run_probes_stacks_once_across_calls(tiny_fleet, monkeypatch):
    """The PR 3-style regression: two run_probes calls and a surface map
    over the same fleet perform ONE stack_params, and the jitted
    measurement's program count stays flat."""
    points = [fleet.ProbePoint(("p", n),
                               idd_loops.validation_sweep(n, reps=2), 2,
                               900 + n)
              for n in (4, 8)]
    modules = list(tiny_fleet)
    fleet.FLEET_STACK_CACHE.clear()
    calls = {"n": 0}
    real = fleet.stack_params

    def counting(params):
        calls["n"] += 1
        return real(params)

    monkeypatch.setattr(fleet, "stack_params", counting)
    first = fleet.run_probes(modules, points)
    programs = fleet.fleet_measure_current._cache_size()
    second = fleet.run_probes(modules, points)
    trace, weight = _surface_batch()
    fleet.fleet_surface_energy(modules, trace, weight)
    assert calls["n"] == 1
    assert fleet.fleet_measure_current._cache_size() == programs
    np.testing.assert_array_equal(first, second)
    assert fleet.FLEET_STACK_CACHE.hits >= 2


def test_fleet_stack_cache_identity_keyed_and_bounded(tiny_fleet):
    fleet.FLEET_STACK_CACHE.clear()
    mods = list(tiny_fleet)
    s1 = fleet.fleet_stacked(mods)
    s2 = fleet.fleet_stacked(mods)
    assert s1 is s2                      # memoized, not rebuilt
    sub = fleet.fleet_stacked(mods[:4])  # different fleet -> different entry
    assert sub.i2n.shape[0] == 4
    assert len(fleet.FLEET_STACK_CACHE._entries) == 2
    for i in range(fleet.FLEET_STACK_CACHE.maxsize + 1):
        fleet.fleet_stacked(mods[: 2 + i % 3])
    assert (len(fleet.FLEET_STACK_CACHE._entries)
            <= fleet.FLEET_STACK_CACHE.maxsize)


def test_fleet_stacked_passthrough_for_stacked_params():
    _, pp = device_sim.synth_fleet_params(5)
    assert fleet.fleet_stacked(pp) is pp


def test_stack_params_vectorized_matches_tree_stack(tiny_fleet):
    params = [m.params for m in tiny_fleet]
    fast = fleet.stack_params(params)
    slow = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *params)
    for a, b in zip(jax.tree_util.tree_leaves(fast),
                    jax.tree_util.tree_leaves(slow)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunked_and_mesh_are_mutually_exclusive(tiny_fleet):
    from repro.launch.mesh import make_local_mesh
    trace, weight = _surface_batch()
    with pytest.raises(ValueError, match="mutually exclusive"):
        fleet.fleet_surface_energy(list(tiny_fleet), trace, weight,
                                   mesh=make_local_mesh(data=1, model=1),
                                   module_chunk=3)


# ---------------------------------------------------------------------------
# module-axis shard_map (multi-device lane)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs the forced multi-device CPU lane")
def test_sharded_fleet_surface_bitwise_with_synth_fleet():
    from repro.launch.mesh import make_local_mesh
    n_dev = jax.device_count()
    n_model = 4 if n_dev % 4 == 0 else 2
    mesh = make_local_mesh(data=n_dev // n_model, model=n_model)
    trace, weight = _surface_batch()
    _, pp = device_sim.synth_fleet_params(4 * n_model)
    plain = fleet.fleet_surface_energy(pp, trace, weight)
    sharded = fleet.fleet_surface_energy(pp, trace, weight, mesh=mesh)
    for f in plain._fields:
        np.testing.assert_array_equal(np.asarray(getattr(plain, f)),
                                      np.asarray(getattr(sharded, f)),
                                      err_msg=f"leaf {f}")


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs the forced multi-device CPU lane")
def test_sharded_run_probes_bitwise(tiny_fleet):
    from repro.launch.mesh import make_local_mesh
    n_dev = jax.device_count()
    n_model = 3 if n_dev % 3 == 0 else (4 if n_dev % 4 == 0 else 1)
    mesh = make_local_mesh(data=n_dev // n_model, model=n_model)
    modules = list(tiny_fleet)[:9 - (9 % n_model)]
    points = [fleet.ProbePoint(("s", n),
                               idd_loops.validation_sweep(n, reps=2), 2,
                               700 + n)
              for n in range(4, 4 + 2 * mesh.shape["data"])]
    fleet.FLEET_STACK_CACHE.clear()
    plain = fleet.run_probes(modules, points)
    sharded = fleet.run_probes(modules, points, mesh=mesh)
    np.testing.assert_array_equal(plain, sharded)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs the forced multi-device CPU lane")
def test_fleet_stacked_lands_module_sharded(tiny_fleet):
    from repro.launch.mesh import make_local_mesh
    n_dev = jax.device_count()
    n_model = 3 if n_dev % 3 == 0 else 2
    mesh = make_local_mesh(data=n_dev // n_model, model=n_model)
    fleet.FLEET_STACK_CACHE.clear()
    mods = list(tiny_fleet)[:9 - (9 % n_model)]
    stacked = fleet.fleet_stacked(mods, mesh)
    spec = stacked.i2n.sharding.spec
    assert tuple(spec)[:1] == ("model",)


# ---------------------------------------------------------------------------
# autotuner registry
# ---------------------------------------------------------------------------
def test_autotune_shape_bucket_powers_of_two():
    from repro.kernels import autotune
    assert autotune.shape_bucket(8, 1024) == "t8n1024"
    assert autotune.shape_bucket(9, 1025) == "t16n2048"
    assert autotune.shape_bucket(1, 1) == "t1n1"


def test_autotune_best_config_defaults_when_untuned():
    from repro.kernels import autotune
    cfg = autotune.best_config("vampire_energy", 7, 131072)  # absurd bucket
    assert cfg == {"block_n": autotune.DEFAULT_BLOCK,
                   "layout": autotune.DEFAULT_LAYOUT}


def test_autotune_env_kill_switch(monkeypatch):
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    cfg = autotune.best_config("vampire_energy", 8, 1024)
    assert cfg == {"block_n": autotune.DEFAULT_BLOCK,
                   "layout": autotune.DEFAULT_LAYOUT}


def test_autotune_table_roundtrip(tmp_path, monkeypatch):
    from repro.kernels import autotune
    path = tmp_path / "table.json"
    monkeypatch.setattr(autotune, "TABLE_PATH", path)
    autotune.update_table("vampire_energy", {
        "t8n1024": {"block_n": 256, "layout": "tvi", "us": 12.0}},
        path=path)
    try:
        table = json.loads(path.read_text())
        key = autotune.backend_key()
        assert table[key]["vampire_energy"]["t8n1024"] == {
            "block_n": 256, "layout": "tvi"}      # winners only, no timings
        cfg = autotune.best_config("vampire_energy", 8, 1024)
        assert cfg == {"block_n": 256, "layout": "tvi"}
    finally:
        autotune.reload_table()


def test_committed_autotune_table_is_valid():
    """The committed table parses and every entry is a sane launch
    config."""
    from repro.kernels import autotune
    assert os.path.exists(autotune.TABLE_PATH)
    with open(autotune.TABLE_PATH) as f:
        table = json.load(f)
    for backend, families in table.items():
        for family, buckets in families.items():
            assert family in autotune.FAMILIES
            for bucket, entry in buckets.items():
                assert bucket == autotune.shape_bucket(
                    int(bucket[1:bucket.index("n")]),
                    int(bucket[bucket.index("n") + 1:]))
                assert entry["block_n"] in autotune.CANDIDATE_BLOCKS
                assert entry["layout"] in autotune.CANDIDATE_LAYOUTS


def test_grid_layouts_agree_bitwise():
    """Both grid-major orders compute the same charge matrix — layout is
    a pure scheduling choice."""
    from repro.core.fleet import stack_params
    from repro.kernels.vampire_energy import ops as vops
    trace, weight = _surface_batch()
    stacked = stack_params([device_sim.true_vendor_params(v)
                            for v in range(3)])
    out = {}
    for layout in ("vti", "tvi"):
        charge, cycles = vops.batched_charge_matrix(
            trace, weight, stacked, grid_layout=layout)
        out[layout] = (np.asarray(charge), np.asarray(cycles))
    np.testing.assert_allclose(out["vti"][0], out["tvi"][0], rtol=1e-6)
    np.testing.assert_array_equal(out["vti"][1], out["tvi"][1])


def test_dispatch_consults_autotune_table(monkeypatch, tmp_path):
    """An entry in the table steers the jitted dispatch: pinning a
    different block size via the table lands a new program in the jit
    cache keyed on that block."""
    from repro.core.fleet import stack_params
    from repro.kernels import autotune
    from repro.kernels.vampire_energy import ops as vops
    trace, weight = _surface_batch()
    stacked = stack_params([device_sim.true_vendor_params(v)
                            for v in range(3)])
    bucket = autotune.shape_bucket(trace.cmd.shape[0], trace.cmd.shape[1])
    path = tmp_path / "table.json"
    monkeypatch.setattr(autotune, "TABLE_PATH", path)
    autotune.reload_table()
    try:
        default = vops.batched_charge_matrix(trace, weight, stacked)
        autotune.update_table("vampire_energy", {
            bucket: {"block_n": 128, "layout": "tvi"}}, path=path)
        assert autotune.best_config(
            "vampire_energy", trace.cmd.shape[0],
            trace.cmd.shape[1]) == {"block_n": 128, "layout": "tvi"}
        tuned = vops.batched_charge_matrix(trace, weight, stacked)
        np.testing.assert_allclose(np.asarray(default[0]),
                                   np.asarray(tuned[0]), rtol=1e-6)
    finally:
        autotune.reload_table()


# ---------------------------------------------------------------------------
# the fleet-chunked dispatch auditor probe
# ---------------------------------------------------------------------------
def test_audit_fleet_chunked_clean():
    from repro.analysis import dispatch_audit
    assert dispatch_audit.audit_fleet_chunked() == []
