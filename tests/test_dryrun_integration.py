"""Dry-run integration: run the real pipeline in a subprocess with 16
placeholder devices (the pytest process must keep seeing 1 device), on
smoke configs, and check the artifact invariants."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json, sys
    import jax
    from repro.launch import steps
    from repro.launch.mesh import make_local_mesh, make_production_mesh

    out = {}
    mesh = make_local_mesh(data=4, model=4)
    for arch, shape in [("qwen2.5-3b", "train_4k"),
                        ("qwen3-moe-30b-a3b", "decode_32k"),
                        ("mamba2-780m", "long_500k")]:
        res = steps.dryrun_cell(arch, shape, mesh, multi_pod=False,
                                smoke=True, batch_override=8)
        res.pop("hlo_text", None)
        out[f"{arch}__{shape}"] = res
    # multi-pod smoke mesh
    mesh = make_local_mesh(data=2, model=4, pod=2)
    res = steps.dryrun_cell("qwen2.5-3b", "train_4k", mesh, multi_pod=True,
                            smoke=True, batch_override=8)
    out["qwen2.5-3b__train_4k__mp"] = res
    print(json.dumps(out))
    """)


@pytest.fixture(scope="module")
def dryrun_results(tmp_path_factory):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_all_cells_compile(dryrun_results):
    assert len(dryrun_results) == 4


def test_artifact_invariants(dryrun_results):
    for name, res in dryrun_results.items():
        assert res["hlo_flops_per_device"] > 0, name
        assert res["hlo_traffic_bytes_per_device"] > 0, name
        assert res["missing_trip_counts"] == 0, name
        assert res["memory"]["peak_bytes_est"] > 0, name


def test_sharded_cells_have_collectives(dryrun_results):
    res = dryrun_results["qwen2.5-3b__train_4k"]
    assert res["collective_total_bytes_per_device"] > 0
    assert any(k in res["collective_bytes_per_device"]
               for k in ("all-reduce", "all-gather", "reduce-scatter"))


def test_multipod_shards_pod_axis(dryrun_results):
    sp = dryrun_results["qwen2.5-3b__train_4k"]
    mp = dryrun_results["qwen2.5-3b__train_4k__mp"]
    assert mp["n_devices"] == 16 and sp["n_devices"] == 16
    assert mp["multi_pod"] and not sp["multi_pod"]
    # cross-pod data parallelism must add reduction traffic
    assert mp["collective_total_bytes_per_device"] > 0


def test_roofline_terms_computable(dryrun_results):
    from repro.launch import roofline
    for res in dryrun_results.values():
        r = roofline.from_artifact(res)
        assert r.compute_s > 0 and r.memory_s > 0
        assert r.dominant in ("compute", "memory", "collective")
