"""Model validation ordering (paper Fig 24) + encoding study (Section 10)."""
import numpy as np
import pytest

from repro.core import encodings, traces
from repro.core.dram import RD, WR
from repro.core.validate import run_validation


@pytest.fixture(scope="module")
def validation(quick_vampire, tiny_fleet):
    return run_validation(quick_vampire, fleet=tiny_fleet,
                          n_values=(0, 2, 8, 16, 64, 256, 764))


def test_vampire_beats_baselines(validation):
    """The paper's headline: VAMPIRE MAPE << DRAMPower << Micron."""
    m = validation.mape_mean
    assert m["vampire"] < 0.5 * m["drampower"]
    assert m["drampower"] < m["micron"]
    assert m["vampire"] < 12.0          # paper: 6.8%
    assert m["micron"] > 50.0           # paper: 160.6%


def test_vampire_range_covers_mean(quick_vampire):
    from repro.core import idd_loops
    tr = idd_loops.validation_sweep(16)
    lo, mid, hi = quick_vampire.estimate_range(tr, 0)
    assert lo < mid < hi


def test_distribution_mode_close_to_data_mode(quick_vampire):
    """Feeding (ones_frac, toggle_frac) instead of real data should land
    near the data-driven estimate for homogeneous data."""
    from repro.core import idd_loops
    tr = idd_loops.validation_sweep(64, byte=0xAA)
    data_est = float(quick_vampire.estimate(tr, 1).avg_current_ma)
    # 0xAA: half the bits set; alternating columns with same byte: 0 toggles
    dist_est = float(quick_vampire.estimate_distribution(
        tr, 1, ones_frac=0.5, toggle_frac=0.0).avg_current_ma)
    assert abs(data_est - dist_est) / data_est < 0.05


# ---------------------------------------------------------------------------
# Encodings
# ---------------------------------------------------------------------------
def test_optimized_lut_is_bijection():
    hist = np.arange(256)[::-1]
    lut = encodings.optimized_lut(hist)
    assert sorted(lut.tolist()) == list(range(256))


def test_optimized_lut_assigns_low_popcount_to_frequent():
    hist = np.zeros(256)
    hist[0x41] = 100  # most frequent byte
    hist[0x42] = 50
    lut = encodings.optimized_lut(hist)
    assert lut[0x41] == 0x00
    assert bin(lut[0x42]).count("1") <= 1


def test_bdi_roundtrip_sizes():
    lines = np.zeros((4, 16), dtype=np.uint32)
    enc, sizes = encodings.bdi_encode_lines(lines)
    assert (sizes == 1).all()
    rnd = np.random.default_rng(0).integers(
        0, 2 ** 32, size=(16, 16), dtype=np.uint32)
    _, sz = encodings.bdi_encode_lines(rnd)
    assert (sz <= 64).all() and (sz >= 1).all()


def test_owi_reduces_energy_on_apps(quick_vampire):
    """Section 10: OWI must save DRAM energy vs baseline; Optimized ~ none."""
    app = traces.SPEC_APPS[7]  # libquantum: memory-bound, zeros-heavy
    tr = traces.app_trace(app, n_requests=400)
    base = float(quick_vampire.estimate(
        encodings.encode_trace(tr, "baseline"), 0).energy_pj)
    owi = float(quick_vampire.estimate(
        encodings.encode_trace(tr, "owi"), 0).energy_pj)
    assert owi < base


def test_encode_trace_adds_latency_for_lut_encodings():
    app = traces.SPEC_APPS[0]
    tr = traces.app_trace(app, n_requests=100)
    t_opt = encodings.encode_trace(tr, "optimized")
    import numpy as np
    rw = (np.asarray(tr.cmd) == RD) | (np.asarray(tr.cmd) == WR)
    assert (np.asarray(t_opt.dt)[rw] == np.asarray(tr.dt)[rw] + 1).all()
    assert int(t_opt.total_cycles()) > int(tr.total_cycles())


def test_owi_write_data_is_inverted_optimized():
    app = traces.SPEC_APPS[2]
    tr = traces.app_trace(app, n_requests=200)
    lut = encodings.optimized_lut(
        encodings.byte_histogram(traces.trace_request_lines(tr)))
    t_opt = encodings.encode_trace(tr, "optimized", lut=lut)
    t_owi = encodings.encode_trace(tr, "owi", lut=lut)
    cmd = np.asarray(tr.cmd)
    wr = cmd == WR
    rd = cmd == RD
    assert (np.asarray(t_owi.data)[wr]
            == np.asarray(~np.asarray(t_opt.data))[wr]).all()
    assert (np.asarray(t_owi.data)[rd] == np.asarray(t_opt.data)[rd]).all()


def test_app_traces_row_state_machine():
    """Every RD/WR must target the currently-open row of its bank."""
    from repro.core import dram
    tr = traces.app_trace(traces.SPEC_APPS[3], n_requests=300)
    cmd = np.asarray(tr.cmd); bank = np.asarray(tr.bank)
    row = np.asarray(tr.row)
    open_row = {b: None for b in range(8)}
    for i in range(len(cmd)):
        c = cmd[i]
        if c == dram.ACT:
            open_row[bank[i]] = row[i]
        elif c == dram.PRE:
            open_row[bank[i]] = None
        elif c == dram.REF:
            open_row = {b: None for b in range(8)}
        elif c in (RD, WR):
            assert open_row[bank[i]] == row[i], i
