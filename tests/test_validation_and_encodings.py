"""Model validation ordering (paper Fig 24) + encoding study (Section 10).

Several tests here predate the unified ``estimate`` entry point and keep
exercising the legacy shims on purpose (module-wide DeprecationWarning
filter); ``test_model_api.py`` covers the unified API."""
import numpy as np
import pytest

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

from repro.core import encodings, traces
from repro.core.dram import RD, WR
from repro.core.validate import run_validation


@pytest.fixture(scope="module")
def validation(quick_vampire, tiny_fleet):
    return run_validation(quick_vampire, fleet=tiny_fleet,
                          n_values=(0, 2, 8, 16, 64, 256, 764))


def test_vampire_beats_baselines(validation):
    """The paper's headline: VAMPIRE MAPE << DRAMPower << Micron."""
    m = validation.mape_mean
    assert m["vampire"] < 0.5 * m["drampower"]
    assert m["drampower"] < m["micron"]
    assert m["vampire"] < 12.0          # paper: 6.8%
    assert m["micron"] > 50.0           # paper: 160.6%


def test_vampire_range_covers_mean(quick_vampire):
    from repro.core import idd_loops
    tr = idd_loops.validation_sweep(16)
    lo, mid, hi = quick_vampire.estimate_range(tr, 0)
    assert float(lo.avg_current_ma) < float(mid.avg_current_ma) \
        < float(hi.avg_current_ma)
    # the bugfix: the band reaches *energy* (and charge), not just current
    assert float(lo.energy_pj) < float(mid.energy_pj) < float(hi.energy_pj)
    assert float(lo.charge_ma_cycles) < float(hi.charge_ma_cycles)
    # duration is not a process-variation quantity
    assert int(lo.cycles) == int(mid.cycles) == int(hi.cycles)


def test_distribution_mode_close_to_data_mode(quick_vampire):
    """Feeding (ones_frac, toggle_frac) instead of real data should land
    near the data-driven estimate for homogeneous data."""
    from repro.core import idd_loops
    tr = idd_loops.validation_sweep(64, byte=0xAA)
    data_est = float(quick_vampire.estimate(tr, 1).avg_current_ma)
    # 0xAA: half the bits set; alternating columns with same byte: 0 toggles
    dist_est = float(quick_vampire.estimate_distribution(
        tr, 1, ones_frac=0.5, toggle_frac=0.0).avg_current_ma)
    assert abs(data_est - dist_est) / data_est < 0.05


# ---------------------------------------------------------------------------
# Encodings
# ---------------------------------------------------------------------------
def test_optimized_lut_is_bijection():
    hist = np.arange(256)[::-1]
    lut = encodings.optimized_lut(hist)
    assert sorted(lut.tolist()) == list(range(256))


def test_optimized_lut_assigns_low_popcount_to_frequent():
    hist = np.zeros(256)
    hist[0x41] = 100  # most frequent byte
    hist[0x42] = 50
    lut = encodings.optimized_lut(hist)
    assert lut[0x41] == 0x00
    assert bin(lut[0x42]).count("1") <= 1


def test_bdi_roundtrip_sizes():
    lines = np.zeros((4, 16), dtype=np.uint32)
    enc, sizes = encodings.bdi_encode_lines(lines)
    assert (sizes == 1).all()
    rnd = np.random.default_rng(0).integers(
        0, 2 ** 32, size=(16, 16), dtype=np.uint32)
    _, sz = encodings.bdi_encode_lines(rnd)
    assert (sz <= 64).all() and (sz >= 1).all()


def test_owi_reduces_energy_on_apps(quick_vampire):
    """Section 10: OWI must save DRAM energy vs baseline; Optimized ~ none."""
    app = traces.SPEC_APPS[7]  # libquantum: memory-bound, zeros-heavy
    tr = traces.app_trace(app, n_requests=400)
    base = float(quick_vampire.estimate(
        encodings.encode_trace(tr, "baseline"), 0).energy_pj)
    owi = float(quick_vampire.estimate(
        encodings.encode_trace(tr, "owi"), 0).energy_pj)
    assert owi < base


def test_encode_trace_adds_latency_for_lut_encodings():
    app = traces.SPEC_APPS[0]
    tr = traces.app_trace(app, n_requests=100)
    t_opt = encodings.encode_trace(tr, "optimized")
    rw_o = np.isin(np.asarray(tr.cmd), (RD, WR))
    rw_e = np.isin(np.asarray(t_opt.cmd), (RD, WR))
    # rescheduling preserves RD/WR count and order; each slot gains 1 cycle
    assert rw_o.sum() == rw_e.sum()
    assert (np.asarray(t_opt.dt)[rw_e] == np.asarray(tr.dt)[rw_o] + 1).all()
    assert int(t_opt.total_cycles()) > int(tr.total_cycles())


def test_encode_trace_conforms_refresh_deadline():
    """The LUT latency must not push the scheduled refreshes past tREFI
    (the PR-1 deadline-accounting bug class, on the encoding side)."""
    from repro.core import dram
    t = dram.TIMING
    app = traces.SPEC_APPS[7]  # libquantum: dense bursts -> max drift
    tr = traces.app_trace(app, n_requests=3000)
    raw = encodings.encode_trace(tr, "owi", conform_refresh=False)
    fixed = encodings.encode_trace(tr, "owi")
    slack = 2 * max(t.tBURST + 1, t.tRCD + t.tRP)  # <= one slot's overshoot
    assert traces.refresh_deadline_overshoot(raw) > \
        traces.refresh_deadline_overshoot(tr) + 64   # the bug, visible
    assert traces.refresh_deadline_overshoot(fixed) <= \
        traces.refresh_deadline_overshoot(tr) + slack
    # same REF density bound app_trace itself honors
    total = int(np.asarray(fixed.dt, dtype=np.int64).sum())
    n_ref = int((np.asarray(fixed.cmd) == dram.REF).sum())
    assert n_ref >= 0.8 * total / (t.tREFI + t.tRP + t.tRFC)


def test_encoded_trace_keeps_row_state_valid():
    """After rescheduling, every RD/WR must still target the open row."""
    from repro.core import dram
    tr = traces.app_trace(traces.SPEC_APPS[3], n_requests=1500)  # low hit
    enc = encodings.encode_trace(tr, "optimized")
    cmd = np.asarray(enc.cmd); bank = np.asarray(enc.bank)
    row = np.asarray(enc.row)
    open_row = {b: None for b in range(8)}
    for i in range(len(cmd)):
        c = cmd[i]
        if c == dram.ACT:
            open_row[bank[i]] = row[i]
        elif c == dram.PRE:
            open_row[bank[i]] = None
        elif c in (dram.REF, dram.PREA):
            open_row = {b: None for b in range(8)}
        elif c in (RD, WR):
            assert open_row[bank[i]] == row[i], i
    for op in (RD, WR):
        assert (np.asarray(tr.cmd) == op).sum() == (cmd == op).sum()


def test_encoding_energy_study_batched_matches_serial(quick_vampire):
    """One estimate_many dispatch must score the apps x encodings grid the
    way the per-(app, encoding, vendor) Python loop would."""
    tba = {a.name: traces.app_trace(a, n_requests=150)
           for a in traces.SPEC_APPS[:3]}
    vendors = (0, 2)
    study = encodings.encoding_energy_study(tba, quick_vampire, vendors)
    for app, tr in tba.items():
        for enc in encodings.ENCODINGS:
            te = encodings.encode_trace(tr, enc)
            serial = np.mean([float(quick_vampire.estimate(te, v).energy_pj)
                              for v in vendors])
            np.testing.assert_allclose(study[app][enc], serial, rtol=2e-6,
                                       err_msg=f"{app}/{enc}")


def test_owi_write_data_is_inverted_optimized():
    app = traces.SPEC_APPS[2]
    tr = traces.app_trace(app, n_requests=200)
    lut = encodings.optimized_lut(
        encodings.byte_histogram(traces.trace_request_lines(tr)))
    t_opt = encodings.encode_trace(tr, "optimized", lut=lut)
    t_owi = encodings.encode_trace(tr, "owi", lut=lut)

    def op_data(t, op):
        return np.asarray(t.data)[np.asarray(t.cmd) == op]

    assert (op_data(t_owi, WR) == ~op_data(t_opt, WR)).all()
    assert (op_data(t_owi, RD) == op_data(t_opt, RD)).all()


def test_app_traces_row_state_machine():
    """Every RD/WR must target the currently-open row of its bank."""
    from repro.core import dram
    tr = traces.app_trace(traces.SPEC_APPS[3], n_requests=300)
    cmd = np.asarray(tr.cmd); bank = np.asarray(tr.bank)
    row = np.asarray(tr.row)
    open_row = {b: None for b in range(8)}
    for i in range(len(cmd)):
        c = cmd[i]
        if c == dram.ACT:
            open_row[bank[i]] = row[i]
        elif c == dram.PRE:
            open_row[bank[i]] = None
        elif c == dram.REF:
            open_row = {b: None for b in range(8)}
        elif c in (RD, WR):
            assert open_row[bank[i]] == row[i], i
