"""The shared background-state machine (tentpole): dwell billing equals
dwell x per-state LUT exactly in every impl, illegal low-power transitions
fail at trace construction, and the campaign recovers the planted
low-power anchors (paper Fig 14)."""
import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

from repro.core import device_sim, dram, idd_loops, validate
from repro.core import params as P
from repro.core.dram import (ACT, NOP, PDE, PDE_SLOW, PDX, PRE, PREA, RD,
                             REF, SRE, SRX, WR, TIMING)
from repro.core.energy_model import (BG_ACTIVE, BG_PDN_ACT, BG_PDN_FAST,
                                     BG_PDN_SLOW, BG_SR, background_current,
                                     trace_energy_scan,
                                     trace_energy_vectorized)

_T = TIMING
PP = device_sim.true_vendor_params(0)

LOWPOWER_KEYS = (("i_pd", "IDD2P1"), ("i_pd_slow", "IDD2P0"),
                 ("i_actpd", "IDD3P"), ("i_sr", "IDD6"))


def _lp_trace(d_fast=1, d_slow=1, d_act=1, d_sr=1):
    """One NOP-dwell window in each low-power state: fast power-down,
    slow power-down (DLL off), active power-down (bank 0 open), and
    self-refresh — entry slots bill powered-up, dwell rides the NOP slot,
    the exit slot is the last billed at the low-power rate."""
    cmds = [PREA, PDE, NOP, PDX,
            PDE_SLOW, NOP, PDX,
            ACT, PDE, NOP, PDX, PREA,
            SRE, NOP, SRX]
    banks = [0] * len(cmds)
    rows = [0] * 7 + [5] + [0] * 7
    dts = [_T.tRP, _T.tCKE, d_fast, _T.tXP,
           _T.tCKE, d_slow, _T.tXPDLL,
           _T.tRCD, _T.tCKE, d_act, _T.tXP, _T.tRP,
           _T.tCKE, d_sr, _T.tXS]
    return dram.make_trace(cmds, banks, rows, [0] * len(cmds), None, dts)


def _charge(report) -> float:
    return float(report.charge_ma_cycles)


# ---------------------------------------------------------------------------
# Dwell billing == dwell x LUT, exactly, in every impl
# ---------------------------------------------------------------------------
@hypothesis.settings(deadline=None, max_examples=15)
@hypothesis.given(dwells=st.tuples(*[st.integers(1, 400)] * 4))
def test_dwell_charge_is_dwell_times_lut(dwells):
    """Stretching any command-free dwell window by k cycles must add
    exactly k x LUT(state) charge — nothing else in the integrator may
    scale with a low-power slot's duration."""
    base_scan = _charge(trace_energy_scan(_lp_trace(), PP))
    base_vec = _charge(trace_energy_vectorized(_lp_trace(), PP))
    tr = _lp_trace(*dwells)
    expected = sum(
        (d - 1) * float(getattr(PP, leaf))
        for d, (leaf, _) in zip(dwells, LOWPOWER_KEYS))
    got_scan = _charge(trace_energy_scan(tr, PP)) - base_scan
    got_vec = _charge(trace_energy_vectorized(tr, PP)) - base_vec
    np.testing.assert_allclose(got_scan, expected, rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(got_vec, expected, rtol=1e-4, atol=1e-2)


def test_dwell_charge_matches_lut_through_pallas():
    """Same property through the fused Pallas kernel entry point."""
    from repro.kernels.vampire_energy.ops import trace_energy_kernel
    dwells = (64, 128, 96, 256)
    base = _charge(trace_energy_kernel(_lp_trace(), PP))
    got = _charge(trace_energy_kernel(_lp_trace(*dwells), PP)) - base
    expected = sum(
        (d - 1) * float(getattr(PP, leaf))
        for d, (leaf, _) in zip(dwells, LOWPOWER_KEYS))
    np.testing.assert_allclose(got, expected, rtol=1e-4, atol=1e-2)
    # and the three impls agree on the absolute totals, not just deltas
    tr = _lp_trace(*dwells)
    a = _charge(trace_energy_kernel(tr, PP))
    b = _charge(trace_energy_vectorized(tr, PP))
    c = _charge(trace_energy_scan(tr, PP))
    np.testing.assert_allclose(a, b, rtol=1e-5)
    np.testing.assert_allclose(c, b, rtol=1e-5)


def test_background_current_lut_resolves_every_state():
    i_up = 123.0
    got = {
        int(code): float(background_current(PP, np.int32(code), i_up))
        for code in (BG_ACTIVE, BG_PDN_FAST, BG_PDN_SLOW, BG_PDN_ACT, BG_SR)}
    assert got[BG_ACTIVE] == i_up
    assert got[BG_PDN_FAST] == pytest.approx(float(PP.i_pd))
    assert got[BG_PDN_SLOW] == pytest.approx(float(PP.i_pd_slow))
    assert got[BG_PDN_ACT] == pytest.approx(float(PP.i_actpd))
    assert got[BG_SR] == pytest.approx(float(PP.i_sr))


def test_deeper_states_draw_less_background_current():
    """The lattice must be ordered: slow PDN < fast PDN < idle standby,
    self-refresh below fast PDN, active PDN above fast PDN (banks open)
    — for every vendor's true params AND the planted anchors."""
    for v in range(3):
        pp = device_sim.true_vendor_params(v)
        assert float(pp.i_pd_slow) < float(pp.i_pd) < float(pp.i2n)
        assert float(pp.i_sr) < float(pp.i_pd)
        assert float(pp.i_actpd) > float(pp.i_pd)
        assert P.MEASURED_IDD["IDD2P0"][v] < P.MEASURED_IDD["IDD2P1"][v]
        assert P.MEASURED_IDD["IDD3P"][v] > P.MEASURED_IDD["IDD2P1"][v]


# ---------------------------------------------------------------------------
# Illegal transitions fail at trace construction
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bad", (ACT, RD, WR, REF, PDE, PDE_SLOW, PREA, PRE))
def test_illegal_command_during_self_refresh_raises(bad):
    with pytest.raises(ValueError, match="self-refresh"):
        dram.make_trace([PREA, SRE, bad, SRX], None, None, None, None,
                        [_T.tRP, _T.tCKE, 8, _T.tXS])


@pytest.mark.parametrize("entry", (PDE, PDE_SLOW))
@pytest.mark.parametrize("bad", (ACT, RD, WR, REF, SRE))
def test_illegal_command_during_power_down_raises(entry, bad):
    with pytest.raises(ValueError, match="power-down"):
        dram.make_trace([entry, bad, PDX], None, None, None, None,
                        [_T.tCKE, 8, _T.tXP])


def test_tile_seam_commands_stay_legal_during_power_down():
    """PREA / PDE re-entry / PDX inside a power-down window are legal —
    the tiled IDD2P1/IDD2P0 measurement loops depend on it."""
    dram.make_trace([PREA, PDE, NOP, PREA, PDE, NOP, PDX], None, None,
                    None, None, [_T.tRP, _T.tCKE, 32, _T.tRP, _T.tCKE, 32,
                                 _T.tXP])
    for loop in (idd_loops.idd2p1, idd_loops.idd2p0, idd_loops.idd3p,
                 idd_loops.idd6):
        dram.tile_trace(loop(), 3)  # construction must not raise


# ---------------------------------------------------------------------------
# Idle-state selection (applications satellite)
# ---------------------------------------------------------------------------
def test_select_idle_state_picks_deepest_affordable():
    from repro.core import applications as apps
    assert apps.select_idle_state(8 * _T.tXS) == (SRE, SRX, _T.tXS)
    assert apps.select_idle_state(8 * _T.tXS - 1) == (PDE_SLOW, PDX,
                                                     _T.tXPDLL)
    assert apps.select_idle_state(8 * _T.tXPDLL) == (PDE_SLOW, PDX,
                                                    _T.tXPDLL)
    assert apps.select_idle_state(8 * _T.tXPDLL - 1) == (PDE, PDX, _T.tXP)
    assert apps.select_idle_state(10) == (PDE, PDX, _T.tXP)


def test_powerdown_policy_uses_deeper_states_on_long_gaps():
    from repro.core import applications as apps
    line = np.zeros((1, dram.LINE_WORDS), np.uint32)
    tr = dram.make_trace(
        [ACT, RD, RD, RD],
        [0, 0, 0, 0], [5, 5, 5, 5], [0, 1, 2, 3],
        np.repeat(line, 4, axis=0),
        [_T.tRCD,
         _T.tBURST + 100,                  # fast-PDN-sized gap
         _T.tBURST + 8 * _T.tXPDLL,        # slow-PDN-sized gap
         _T.tBURST + 8 * _T.tXS])          # self-refresh-sized gap
    out = apps.apply_powerdown_policy(tr, timeout_cycles=64)
    cmd = np.asarray(out.cmd)
    assert int((cmd == PDE).sum()) == 1
    assert int((cmd == PDE_SLOW).sum()) == 1
    assert int((cmd == SRE).sum()) == 1
    assert int((cmd == SRX).sum()) == 1
    assert int((cmd == RD).sum()) == 3       # work preserved
    dram.validate_low_power_transitions(cmd)  # stream stays legal


# ---------------------------------------------------------------------------
# Campaign recovery of the planted low-power anchors (paper Fig 14)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def lp_vampire():
    """A fit with enough probe modules for fleet means to converge on the
    planted per-vendor anchors (the 2-module quick fit is ~10% noisy)."""
    from repro.core.vampire import Vampire
    specs = [P.ModuleSpec(v, i, 2015) for v in range(3) for i in range(8)]
    fleet = device_sim.make_fleet(specs)
    return Vampire.fit(fleet, probe_modules=8, probe_reps=64, n_rows=8)


def test_campaign_recovers_lowpower_anchors(lp_vampire):
    for v in range(3):
        vc = lp_vampire.by_vendor[v]
        for leaf, key in LOWPOWER_KEYS[1:]:      # the three new params
            got = float(getattr(vc, leaf))
            want = P.MEASURED_IDD[key][v]
            assert abs(got - want) / want < 0.05, (v, leaf, got, want)


def test_fig14_lowpower_reductions_reproduced(lp_vampire):
    """measured/datasheet ratios for the low-power keys land on the
    paper's Fig 14 reductions; the report includes every new key."""
    ratios = validate.measured_over_datasheet(lp_vampire)
    for _, key in LOWPOWER_KEYS:
        for v in range(3):
            got = ratios[v][key]
            want = P.MEASURED_OVER_DATASHEET[key][v]
            assert abs(got - want) / want < 0.10, (key, v, got, want)
            assert got < 1.0  # measured always below worst-case datasheet
    table = validate.render_fig14_table(ratios)
    for key in ("IDD2P0", "IDD3P", "IDD6"):
        assert key in table
