"""Sharding rules + parameter-meta layer: the single source of truth for
shapes/specs must behave under divisibility fallbacks and axis dedup."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.models.meta import (ParamMeta, ShardingRules, abstractify,
                               materialize, specs_for)
from repro.sharding import rules as SR


def test_spec_basic_mapping():
    rules = ShardingRules({"embed": None, "ffn": "model", "vocab": "model"})
    m = ParamMeta((64, 128), ("embed", "ffn"))
    assert tuple(rules.spec(m)) == (None, "model")


def test_spec_dedups_repeated_mesh_axis():
    rules = ShardingRules({"experts": "model", "ffn": "model"})
    m = ParamMeta((8, 16, 32), ("experts", None, "ffn"))
    spec = rules.spec(m)
    # "model" may appear once: experts wins, ffn falls back to None
    assert tuple(spec) == ("model", None, None)


def test_divisibility_fallback(tmp_path):
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(data=1, model=1)
    # 7 does not divide any >1 axis, but with model=1 everything divides
    rules = ShardingRules({"ffn": "model"})
    m = {"w": ParamMeta((3, 7), (None, "ffn"))}
    specs = specs_for(m, rules, mesh=mesh)
    assert tuple(specs["w"]) == (None, "model")


def test_materialize_and_abstractify_agree():
    meta = {"a": ParamMeta((4, 8), ("embed", "ffn")),
            "b": {"c": ParamMeta((3,), (None,), init="zeros",
                                 dtype=jnp.int32)}}
    arrs = materialize(meta, jax.random.key(0))
    sds = abstractify(meta)
    assert arrs["a"].shape == sds["a"].shape == (4, 8)
    assert arrs["b"]["c"].dtype == sds["b"]["c"].dtype == jnp.int32
    assert bool(jnp.all(arrs["b"]["c"] == 0))


def test_param_meta_validates_rank():
    with pytest.raises(AssertionError):
        ParamMeta((4, 8), ("embed",))


def test_plan_policies_by_size():
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(data=1, model=1)
    small = R.get_config("qwen2.5-3b")
    mid = R.get_config("yi-34b")
    big = R.get_config("jamba-1.5-large-398b")
    p_small = SR.plan_for(small, "train", 256, mesh, False, seq_len=4096)
    p_mid = SR.plan_for(mid, "train", 256, mesh, False, seq_len=4096)
    p_big = SR.plan_for(big, "train", 256, mesh, False, seq_len=4096)
    assert not p_small.fsdp and not p_small.zero1
    assert p_mid.zero1 and not p_mid.fsdp
    assert p_big.fsdp and not p_big.zero1
    assert p_big.quantized_moments and not p_mid.quantized_moments
    # serving: weight data-sharding from 9B up
    s_mid = SR.plan_for(mid, "decode", 128, mesh, False, seq_len=32768)
    assert s_mid.fsdp and not s_mid.zero1


def test_decode_kv_seq_rules():
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(data=1, model=1)
    cfg = R.get_config("granite-8b")
    p = SR.plan_for(cfg, "decode", 128, mesh, False, seq_len=32768)
    assert p.rules.rules["kv_seq"] == "model"
    # unshardable batch -> sequence spreads over data too
    p1 = SR.plan_for(cfg, "decode", 1, mesh, False, seq_len=524288)
    # (mesh data=1 so 1 % 1 == 0; emulate big mesh via direct rules check)
    from repro.launch.mesh import make_local_mesh as mk


def test_microbatch_sizing():
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(data=1, model=1)
    cfg = R.get_config("yi-34b")
    p = SR.plan_for(cfg, "train", 256, mesh, False, seq_len=4096)
    # stacks for B_loc=256 x 4k x 7168 x 60L are way over 4 GiB -> many mbs
    assert p.microbatches >= 16
    p2 = SR.plan_for(cfg, "decode", 128, mesh, False, seq_len=32768)
    assert p2.microbatches == 1


def test_batch_axes():
    assert SR.batch_axes(False) == ("data",)
    assert SR.batch_axes(True) == ("pod", "data")
