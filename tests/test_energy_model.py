"""Unit + property tests for the shared energy integrator."""
import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import device_sim, dram, idd_loops
from repro.core.energy_model import (trace_energy_scan,
                                     trace_energy_vectorized)

PP = device_sim.true_vendor_params(0)


def _random_trace(rng, n=64):
    cmds, banks, rows, cols, datas, dts = [], [], [], [], [], []
    open_banks = set()
    for _ in range(n):
        r = rng.random()
        if r < 0.2 or not open_banks:
            b = int(rng.integers(0, 8))
            cmds.append(dram.ACT); open_banks.add(b)
        elif r < 0.7:
            b = int(rng.choice(sorted(open_banks)))
            cmds.append(dram.RD if rng.random() < 0.6 else dram.WR)
        elif r < 0.8:
            b = int(rng.choice(sorted(open_banks)))
            cmds.append(dram.PRE); open_banks.discard(b)
        elif r < 0.9:
            b = 0
            cmds.append(dram.NOP)
        else:
            b = 0
            open_banks.clear()
            cmds.append(dram.PREA)
        banks.append(b)
        rows.append(int(rng.integers(0, 1 << 15)))
        cols.append(int(rng.integers(0, 128)))
        datas.append(rng.integers(0, 2 ** 32, size=16, dtype=np.uint32))
        dts.append(int(rng.integers(1, 30)))
    return dram.make_trace(cmds, banks, rows, cols, np.stack(datas), dts)


@pytest.mark.parametrize("seed", range(4))
def test_scan_matches_vectorized_random_traces(seed):
    rng = np.random.default_rng(seed)
    tr = _random_trace(rng, n=96)
    a = trace_energy_scan(tr, PP)
    b = trace_energy_vectorized(tr, PP)
    np.testing.assert_allclose(float(a.avg_current_ma),
                               float(b.avg_current_ma), rtol=1e-5)
    np.testing.assert_allclose(float(a.energy_pj), float(b.energy_pj),
                               rtol=1e-5)


def test_scan_matches_vectorized_on_idd_loops():
    for name, fn in idd_loops.IDD_LOOPS.items():
        tr = fn()
        a = trace_energy_scan(tr, PP)
        b = trace_energy_vectorized(tr, PP)
        np.testing.assert_allclose(float(a.avg_current_ma),
                                   float(b.avg_current_ma), rtol=5e-5,
                                   err_msg=name)


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(n_ones=st.integers(0, 512))
def test_read_current_increases_with_ones(n_ones):
    tr0, s0 = idd_loops.ones_sweep_point(0, op=dram.RD, reps=16)
    tr1, s1 = idd_loops.ones_sweep_point(n_ones, op=dram.RD, reps=16)
    i0 = float(trace_energy_vectorized(tr0, PP).avg_current_ma)
    i1 = float(trace_energy_vectorized(tr1, PP).avg_current_ma)
    assert i1 >= i0 - 1e-3  # monotone non-decreasing in ones (reads)


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(n_ones=st.integers(0, 512))
def test_write_current_decreases_with_ones(n_ones):
    tr0, _ = idd_loops.ones_sweep_point(0, op=dram.WR, reps=16)
    tr1, _ = idd_loops.ones_sweep_point(n_ones, op=dram.WR, reps=16)
    i0 = float(trace_energy_vectorized(tr0, PP).avg_current_ma)
    i1 = float(trace_energy_vectorized(tr1, PP).avg_current_ma)
    assert i1 <= i0 + 1e-3


def test_power_down_reduces_idle_current():
    idle = float(trace_energy_vectorized(idd_loops.idd2n(), PP)
                 .avg_current_ma)
    pd = float(trace_energy_vectorized(idd_loops.idd2p1(), PP)
               .avg_current_ma)
    assert pd < idle


def test_energy_scales_with_trace_repetition():
    tr = idd_loops.idd0(reps=8)
    tr2 = dram.tile_trace(tr, 2)
    e1 = float(trace_energy_vectorized(tr, PP).energy_pj)
    e2 = float(trace_energy_vectorized(tr2, PP).energy_pj)
    np.testing.assert_allclose(e2, 2 * e1, rtol=1e-4)


def test_bank_structural_factors_visible_in_read_current():
    ppc = device_sim.true_vendor_params(2)  # vendor C
    tr0, s = idd_loops.bank_read_probe(0)
    tr5, _ = idd_loops.bank_read_probe(5)
    i0 = float(trace_energy_vectorized(tr0, ppc).avg_current_ma)
    i5 = float(trace_energy_vectorized(tr5, ppc).avg_current_ma)
    expected = float(ppc.bank_read_factor[5])
    assert abs(i5 / i0 - expected) < 0.05
