"""Tests for the static-analysis layer (repro.analysis): the JEDEC trace
linter (seeded-mutation per-rule coverage + engine parity), the
compile-time dispatch auditor, and the repo AST lint."""
import ast
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import dispatch_audit, repo_lint, trace_lint
from repro.core import dram, idd_loops, traces
from repro.core.dram import (ACT, NOP, PDE, PDE_SLOW, PDX, PRE, PREA, RD,
                             REF, SRE, SRX, TIMING, WR)

T = TIMING


def raw_trace(script):
    """Build a CommandTrace from (cmd, bank, dt) triples WITHOUT the
    construction-time low-power validation (the linter is the system under
    test; it must see illegal streams)."""
    import jax.numpy as jnp
    cmd, bank, dt = (np.array(c, np.int32) for c in zip(*script))
    n = len(cmd)
    z = jnp.zeros(n, jnp.int32)
    return dram.CommandTrace(jnp.asarray(cmd), jnp.asarray(bank), z, z,
                             jnp.zeros((n, dram.LINE_WORDS), jnp.uint32),
                             jnp.asarray(dt))


def fired(trace):
    """{(rule, cmd_index, bank)} from the numpy engine."""
    return {(d.rule, d.cmd_index, d.bank) for d in trace_lint.lint_trace(trace)}


# ---------------------------------------------------------------------------
# Per-rule seeded mutations: each entry is a minimal illegal stream plus the
# exact diagnostic it must produce (rule id, command index, bank).
# ---------------------------------------------------------------------------
SEEDED = {
    "tRCD": ([(ACT, 0, T.tRCD - 1), (RD, 0, 1)], 1, 0),
    "tRP": ([(ACT, 0, T.tRAS + 2), (PRE, 0, T.tRP - 1), (ACT, 0, 1)], 2, 0),
    "tRAS": ([(ACT, 0, T.tRAS - 1), (PRE, 0, 1)], 1, 0),
    "tRC": ([(ACT, 0, T.tRAS), (PRE, 0, T.tRP - 1), (ACT, 0, 1)], 2, 0),
    "tRRD": ([(ACT, 0, T.tRRD - 1), (ACT, 1, 1)], 1, 1),
    "tFAW": ([(ACT, 0, T.tRRD), (ACT, 1, T.tRRD), (ACT, 2, T.tRRD),
              (ACT, 3, T.tRRD - 1), (ACT, 4, 1)], 4, 4),
    "tWR": ([(ACT, 0, T.tRCD), (WR, 0, T.tBURST + T.tWR - 1),
             (PRE, 0, 1)], 2, 0),
    "tRTP": ([(ACT, 0, T.tRAS - T.tRTP + 1), (RD, 0, T.tRTP - 1),
              (PRE, 0, 1)], 2, 0),
    "tWTR": ([(ACT, 0, T.tRCD), (WR, 0, T.tBURST + T.tWTR - 1),
              (RD, 0, 1)], 2, 0),
    "tCCD": ([(ACT, 0, T.tRCD), (RD, 0, T.tCCD - 1), (RD, 0, 1)], 2, 0),
    "tRFC": ([(REF, 0, T.tRFC - 1), (ACT, 0, 1)], 1, 0),
    "tXP": ([(PDE, 0, T.tCKE), (PDX, 0, T.tXP - 1), (ACT, 0, 1)], 2, 0),
    "tXPDLL": ([(PDE_SLOW, 0, T.tCKE), (PDX, 0, T.tXPDLL - T.tRCD - 1),
                (ACT, 0, T.tRCD), (RD, 0, 1)], 3, 0),
    "tXS": ([(SRE, 0, T.tCKE), (SRX, 0, T.tXS - 1), (ACT, 0, 1)], 2, 0),
    "BANK_RW_CLOSED": ([(RD, 2, 1)], 0, 2),
    "BANK_ACT_OPEN": ([(ACT, 0, T.tRC), (ACT, 0, 1)], 1, 0),
    "REF_BANK_OPEN": ([(ACT, 0, T.tRAS), (REF, 0, 1)], 1, 0),
    "PDN_ILLEGAL_CMD": ([(PDE, 0, T.tCKE), (ACT, 0, 1)], 1, 0),
    "SR_ILLEGAL_CMD": ([(SRE, 0, T.tCKE), (ACT, 0, 1)], 1, 0),
    "DT_NEGATIVE": ([(NOP, 0, -1)], 0, 0),
}

#: rules whose minimal violation necessarily co-fires a second rule
#: (DDR3L-800 has tRAS + tRP == tRC and 4 * tRRD == tFAW exactly)
_COFIRE_OK = {"tRC", "tFAW", "BANK_ACT_OPEN"}


@pytest.mark.parametrize("rule_id", sorted(SEEDED))
def test_seeded_mutation_fires_exactly_that_rule(rule_id):
    script, idx, bank = SEEDED[rule_id]
    hits = fired(raw_trace(script))
    assert (rule_id, idx, bank) in hits, hits
    if rule_id not in _COFIRE_OK:
        assert hits == {(rule_id, idx, bank)}, hits


#: state-machine rules: no amount of waiting legalizes the stream, so the
#: stretch-by-one minimality probe below does not apply
_STATEFUL = {"DT_NEGATIVE", "BANK_RW_CLOSED", "BANK_ACT_OPEN",
             "REF_BANK_OPEN", "PDN_ILLEGAL_CMD", "SR_ILLEGAL_CMD"}


def test_seeded_mutations_are_minimal():
    """Stretching the violated slot by one cycle legalizes every timing
    seed (proof each seed sits exactly on the rule's boundary)."""
    for rule_id, (script, idx, _) in SEEDED.items():
        if rule_id in _STATEFUL:
            continue
        legal = [list(c) for c in script]
        legal[idx - 1][2] += 1
        hits = fired(raw_trace([tuple(c) for c in legal]))
        assert not hits, (rule_id, hits)


def test_trefi_is_a_warning_at_the_late_ref():
    tr = raw_trace([(NOP, 0, T.tREFI + trace_lint.REFI_SLACK + 10),
                    (REF, 0, 1)])
    diags = trace_lint.lint_trace(tr)
    assert [(d.rule, d.severity, d.cmd_index) for d in diags] == \
        [("tREFI", trace_lint.WARNING, 1)]


def test_diagnostic_carries_margin_and_message():
    script, idx, bank = SEEDED["tRCD"]
    (d,) = trace_lint.lint_trace(raw_trace(script))
    assert (d.rule, d.cmd_index, d.bank, d.margin) == ("tRCD", idx, bank, 1)
    assert "tRCD" in d.message and "#1" in d.message


# ---------------------------------------------------------------------------
# Property tests (vendored hypothesis): seeded edits and engine parity
# ---------------------------------------------------------------------------
@settings(max_examples=20)
@given(gap=st.integers(min_value=1, max_value=T.tRP - 1))
def test_property_short_precharge_gap_fires_trp(gap):
    tr = raw_trace([(ACT, 0, T.tRC), (PRE, 0, gap), (ACT, 0, 1)])
    (d,) = trace_lint.lint_trace(tr)
    assert (d.rule, d.cmd_index, d.margin) == ("tRP", 2, T.tRP - gap)


@settings(max_examples=10)
@given(wait=st.integers(min_value=0, max_value=200))
def test_property_dropped_srx_fires_sr_illegal(wait):
    tr = raw_trace([(SRE, 0, T.tCKE), (NOP, 0, wait), (ACT, 0, 1)])
    hits = fired(tr)
    assert ("SR_ILLEGAL_CMD", 2, 0) in hits


_CMDS = st.sampled_from([NOP, ACT, PRE, RD, WR, REF, PDE, PDX, PREA,
                         PDE_SLOW, SRE, SRX])
_STEP = st.tuples(_CMDS, st.integers(min_value=0, max_value=7),
                  st.integers(min_value=0, max_value=2 * T.tRC))


@settings(max_examples=30)
@given(script=st.lists(_STEP, min_size=1, max_size=40))
def test_property_engines_agree_on_arbitrary_streams(script):
    """The vectorized numpy engine, the jitted batched engine, and the
    independent reference walk produce identical diagnostics for ANY
    command stream, legal or not."""
    tr = raw_trace(script)
    key = lambda ds: sorted((d.rule, d.cmd_index, d.bank, d.margin)
                            for d in ds)
    vec = key(trace_lint.lint_trace(tr))
    ref = key(trace_lint.reference_lint(tr))
    bat = key(trace_lint.lint_traces([tr]))
    assert vec == ref == bat


def test_batched_engine_reports_trace_index():
    bad = raw_trace(SEEDED["tRCD"][0])
    good = idd_loops.idd2n(reps=2)
    diags = trace_lint.lint_traces([good, bad, good])
    assert diags and all(d.trace_index == 1 for d in diags)


# ---------------------------------------------------------------------------
# Generator regressions: the exact illegal schedules this PR fixed, pinned
# to the rule that now catches them.
# ---------------------------------------------------------------------------
def test_old_naive_idd7_schedule_fires_tras():
    """Pre-fix IDD7 precharged each bank immediately after its read; the
    linter's tRAS rule is what makes that bug unrepresentable now."""
    script = []
    for b in range(8):
        script += [(ACT, b, T.tRCD), (RD, b, T.tCCD), (PRE, b, 1)]
    hits = fired(raw_trace(script))
    assert any(r == "tRAS" for r, _, _ in hits)


def test_old_tiled_idd3n_setup_fires_bank_act_open():
    """Pre-fix IDD3N tiled the all-banks ACT prologue into every loop rep,
    re-activating banks that were already open."""
    prologue = [(ACT, b, T.tRC) for b in range(8)]
    hits = fired(raw_trace(prologue * 2))
    assert any(r == "BANK_ACT_OPEN" for r, _, _ in hits)


def test_all_repo_generators_are_clean():
    """Every generator lints clean (they now self-check via
    check_generated, so construction succeeding is itself the assertion —
    this pins a couple of representative ones explicitly)."""
    for tr in (idd_loops.idd3n(reps=3), idd_loops.idd7(reps=2),
               traces.app_trace(traces.SPEC_APPS[0], n_requests=64)):
        assert trace_lint.lint_trace(tr) == []


# ---------------------------------------------------------------------------
# Ingestion guard (serve --power-report)
# ---------------------------------------------------------------------------
def test_serve_rejects_corrupt_trace_with_structured_error():
    from repro.launch import serve
    corrupt = raw_trace(SEEDED["tRCD"][0])
    good = idd_loops.idd0(reps=2)
    with pytest.raises(trace_lint.TraceProtocolError) as ei:
        serve.lint_ingested([good, corrupt])
    err = ei.value
    assert err.origin == "serve.power_report"
    (d,) = err.diagnostics
    assert (d.rule, d.trace_index, d.cmd_index, d.bank) == ("tRCD", 1, 1, 0)
    assert "tRCD" in str(err)


def test_check_generated_raises_and_is_disableable(monkeypatch):
    bad = raw_trace(SEEDED["tRAS"][0])
    with pytest.raises(trace_lint.TraceProtocolError):
        trace_lint.check_generated(bad, "test")
    monkeypatch.setenv("REPRO_TRACE_LINT", "off")
    assert trace_lint.check_generated(bad, "test") is bad


def test_make_trace_hook_is_opt_in(monkeypatch):
    cmds, banks, dts = zip(*SEEDED["tRCD"][0])
    dram.make_trace(list(cmds), list(banks), dts=list(dts))  # off: no raise
    monkeypatch.setenv("REPRO_TRACE_LINT", "strict")
    with pytest.raises(trace_lint.TraceProtocolError):
        dram.make_trace(list(cmds), list(banks), dts=list(dts))


# ---------------------------------------------------------------------------
# Dispatch audit
# ---------------------------------------------------------------------------
def test_dispatch_audit_clean_on_registered_impls(quick_vampire):
    tb = dispatch_audit.default_audit_batch()
    findings = []
    for impl in ("reference", "vectorized"):
        findings += dispatch_audit.audit_combination(
            quick_vampire, impl, "mean", tb)
    findings += dispatch_audit.audit_recompilation(
        quick_vampire, modes=("mean",), tb=tb)
    assert findings == []


def test_dispatch_audit_flags_dead_weight():
    """A dispatch that ignores the validity mask must be caught by DCE."""
    import jax
    jaxpr = jax.make_jaxpr(lambda x, w: x.sum())(
        np.ones(4, np.float32), np.ones(4, np.float32))
    used = dispatch_audit._dce_used_invars(jaxpr.jaxpr)
    assert used is not None and used == [True, False]


def test_dispatch_audit_flags_f64_text():
    assert dispatch_audit._F64_RE.search("tensor<4xf64>")
    assert not dispatch_audit._F64_RE.search("tensor<4xf32>")


# ---------------------------------------------------------------------------
# Repo lint
# ---------------------------------------------------------------------------
def _src(code):
    return [("core/sample.py", ast.parse(textwrap.dedent(code)))]


def test_repo_lint_clean_on_live_tree():
    assert repo_lint.errors_of(repo_lint.run_repo_lint()) == []


def test_repo_lint_flags_deprecated_shim_call():
    (f,) = repo_lint.check_no_deprecated_shims(
        _src("model.estimate_range_many(traces)"))
    assert f.rule == "no-deprecated-shims" and "estimate_range_many" \
        in f.message
    assert repo_lint.check_no_deprecated_shims(
        [("core/vampire.py", ast.parse("self.estimate_many(t)"))]) == []


def test_repo_lint_flags_modeless_impl():
    (f,) = repo_lint.check_impls_declare_modes(
        _src("register_impl(EstimateImpl(name='x', fn=f))"))
    assert f.rule == "impls-declare-modes"
    assert repo_lint.check_impls_declare_modes(
        _src("register_impl(EstimateImpl(name='x', modes=('mean',)))")) == []


def test_repo_lint_flags_module_level_interpret():
    (f,) = repo_lint.check_call_time_interpret(
        [("kernels/k.py", ast.parse("INTERPRET = True"))])
    assert f.rule == "call-time-interpret" and "INTERPRET" in f.message
    (f,) = repo_lint.check_call_time_interpret(
        [("kernels/k.py", ast.parse("y = pl.pallas_call(f)(x)"))])
    assert "interpret_default" in f.message
    assert repo_lint.check_call_time_interpret(
        [("kernels/k.py", ast.parse(
            "y = pl.pallas_call(f, interpret=interpret_default())(x)"))]) == []


def test_repo_lint_params_coverage_negative(tmp_path):
    (tmp_path / "core").mkdir()
    (tmp_path / "core" / "energy_model.py").write_text(textwrap.dedent("""
        class PowerParams(NamedTuple):
            a: int
            b: int
            orphan: int
            late: int
    """))
    (tmp_path / "core" / "model_api.py").write_text(textwrap.dedent("""
        _FITTED_FIELDS = ("a", "late")
        def _save_v1_pickle(m):
            blob = {"a": m.a, "k1": 0, "k2": 0, "k3": 0, "k4": 0}
    """))
    (tmp_path / "core" / "characterize.py").write_text(textwrap.dedent("""
        def build_params(x):
            return PowerParams(b=x)
    """))
    findings = repo_lint.check_params_serialization(tmp_path)
    msgs = " | ".join(f.message for f in findings)
    assert len(findings) == 2
    assert "orphan" in msgs          # neither fitted nor derived
    assert "late" in msgs            # fitted, post-v1, no default


def test_repo_lint_params_coverage_live():
    assert repo_lint.check_params_serialization() == []
