"""Optimizer, data pipeline, checkpointing, fault tolerance, elasticity."""
import os

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticDataset
from repro.optim import adamw, compress
from repro.runtime.fault import FaultInjector, SimulatedFault, StragglerMonitor


# ------------------------------------------------------------------- adamw
def test_adamw_optimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            decay_steps=200)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_quantized_moments_track_exact():
    cfg_q = adamw.AdamWConfig(lr=0.05, weight_decay=0.0,
                              quantize_moments=True, warmup_steps=1,
                              decay_steps=100)
    cfg_f = adamw.AdamWConfig(lr=0.05, weight_decay=0.0,
                              quantize_moments=False, warmup_steps=1,
                              decay_steps=100)
    p_q = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8)}
    p_f = jax.tree_util.tree_map(jnp.copy, p_q)
    s_q = adamw.init(p_q, cfg_q)
    s_f = adamw.init(p_f, cfg_f)
    key = jax.random.key(0)
    for i in range(30):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (8, 8))}
        p_q, s_q, _ = adamw.update(g, s_q, p_q, cfg_q)
        p_f, s_f, _ = adamw.update(g, s_f, p_f, cfg_f)
    err = float(jnp.max(jnp.abs(p_q["w"] - p_f["w"])))
    assert err < 0.08, err


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(st.integers(0, 2 ** 31 - 1))
def test_grad_compression_error_feedback_bounded(seed):
    """EF invariant: residual error stays bounded by one quantization step."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    err = jnp.zeros_like(g)
    for _ in range(5):
        (q, s), err = compress.ef_compress_tree(g, err)
    step = float(jnp.max(jnp.abs(g + 0 * err))) / 127.0
    assert float(jnp.max(jnp.abs(err))) <= 2.0 * step + 1e-6


def test_compress_roundtrip_small_error(rng):
    x = jnp.asarray(rng.standard_normal((16, 256)), jnp.float32)
    q, s = compress.compress(x)
    err = jnp.abs(compress.decompress(q, s) - x)
    assert float(jnp.max(err)) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


# -------------------------------------------------------------------- data
def test_data_deterministic_and_shardable():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=5)
    ds = SyntheticDataset(cfg)
    a = ds.global_batch(3)
    b = ds.global_batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.global_batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    for step in (1, 2, 3, 4):
        mgr.save(step, tree, extra={"loss": float(step)})
    assert mgr.all_steps() == [3, 4]
    out = mgr.restore(4, jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["nested"]["b"].dtype == jnp.bfloat16
    assert mgr.restore_manifest(4)["extra"]["loss"] == 4.0


def test_checkpoint_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"x": jnp.zeros(3)})
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]
    assert not leftovers


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(7, {"x": jnp.arange(10)})
    mgr.wait()
    assert mgr.latest_step() == 7


def test_checkpoint_reshard_on_restore(tmp_path):
    """Elastic path: restore with different target shardings (here: single
    device, different layout trees) still reproduces values."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(data=1, model=1)
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr.save(1, tree)
    shard = {"w": NamedSharding(mesh, P("data", "model"))}
    out = mgr.restore(1, {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
                      shardings=shard)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


# ------------------------------------------------------------------- fault
def test_fault_injector_fires_once():
    inj = FaultInjector(fail_at_steps=(3,))
    inj.check(2)
    with pytest.raises(SimulatedFault):
        inj.check(3)
    inj.check(3)  # second pass: already fired


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0)
    for step in range(10):
        mon.record(step, 0.1)
    assert mon.record(10, 0.5)
    assert mon.flagged and mon.flagged[0][0] == 10


def test_train_driver_recovers_from_fault(tmp_path):
    """End-to-end: training hits an injected fault, restores from the
    checkpoint, and completes all steps."""
    from repro.launch.train import TrainJob, run
    res = run(TrainJob(arch="qwen2.5-3b", smoke=True, steps=12, batch=2,
                       seq=32, ckpt_dir=str(tmp_path), ckpt_every=4,
                       fail_at=(7,), power_every=0))
    assert res["recoveries"] == 1
    assert res["steps_run"] >= 12
    assert np.isfinite(res["final_loss"])


def test_train_loss_decreases():
    from repro.launch.train import TrainJob, run
    res = run(TrainJob(arch="qwen2.5-3b", smoke=True, steps=30, batch=4,
                       seq=64, power_every=0))
    first = np.mean(res["losses"][:5])
    last = np.mean(res["losses"][-5:])
    assert last < first


# ------------------------------------------------------------------ serve
def test_serve_smoke_with_power_report(quick_vampire, tmp_path):
    """Serving end-to-end: mesh-sharded params/caches, temperature sampling,
    and the power-report mode feeding decode HBM traffic through the
    unified estimate() dispatch (the module's long-promised 'HBM energy
    estimates') — riding the fused impl='pallas' path via --power-impl."""
    from repro.launch.serve import ServeJob, run
    fit = str(tmp_path / "fit.pkl")
    quick_vampire.save(fit)
    res = run(ServeJob(arch="qwen2.5-3b", smoke=True, batch=2, prompt_len=8,
                       decode_tokens=4, data=1, model=1, temperature=0.7,
                       power_report=True, power_impl="pallas",
                       vampire_path=fit))
    assert res["tokens"].shape == (2, 4)
    pw = res["power"]
    assert pw["traffic_bytes_per_step"] > 0
    # one report per (sequence, vendor), all positive
    assert pw["ddr_energy_pj_per_seq_step"].shape == (2, 3)
    assert (pw["ddr_energy_pj_per_seq_step"] > 0).all()
    assert pw["hbm_step_energy_uj"] > 0
    assert 0.0 <= pw["hbm_ones_frac"] <= 1.0


# ---------------------------------------------------------------- elastic
def test_reshard_plan_reports_fallbacks():
    from repro.launch.mesh import make_local_mesh
    from repro.models.meta import ParamMeta
    from repro.runtime.elastic import reshard_plan
    from repro.sharding.rules import make_rules
    from repro.configs import registry as R
    cfg = R.get_config("qwen2.5-3b", smoke=True)
    mesh = make_local_mesh(data=1, model=1)
    meta = {"w": ParamMeta((6, 8), ("embed", "ffn"))}
    specs, fallbacks = reshard_plan(meta, make_rules(cfg), mesh)
    assert "w" in str(jax.tree_util.tree_structure(specs)) or specs
