"""Batched multi-trace estimation engine: estimate_many equivalence with the
per-trace path (leaf-by-leaf, over ragged padding and PDE/PDX traces), the
vmapped variation band, batched distribution mode, and scan-vs-vectorized
first-RD/WR-per-bank interleave edge cases.

These tests predate the unified ``estimate`` entry point and deliberately
keep exercising the legacy ``estimate*`` shims (which now delegate to it
with a DeprecationWarning — hence the module-wide filter); the unified API
itself is covered leaf-for-leaf in ``test_model_api.py``."""
import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

from repro.core import device_sim, dram, estimate_batch, idd_loops, traces
from repro.core.dram import (ACT, NOP, PDE, PDE_SLOW, PDX, PRE, PREA, RD,
                             SRE, SRX, WR, TIMING)
from repro.core.energy_model import (trace_energy_scan,
                                     trace_energy_vectorized)

_T = TIMING


def _pde_trace():
    """Hand-built trace exercising PDE/PDX around RD/WR activity."""
    return dram.make_trace(
        [ACT, RD, RD, PREA, PDE, PDX, ACT, WR, PRE],
        [0, 0, 0, 0, 0, 0, 2, 2, 2],
        [5, 5, 5, 0, 0, 0, 9, 9, 0],
        [0, 0, 1, 0, 0, 0, 0, 3, 0],
        None,
        [_T.tRCD, _T.tCCD, _T.tCCD, _T.tRP, 200, _T.tCKE,
         _T.tRCD, _T.tBURST, _T.tRP])


def _lowpower_trace():
    """Slow power-down and self-refresh windows mid-trace (the background
    states the original PDE/PDX fixture cannot reach)."""
    return dram.make_trace(
        [ACT, RD, PREA, PDE_SLOW, NOP, PDX, SRE, NOP, SRX, ACT, WR, PRE],
        [0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1],
        [5, 5, 0, 0, 0, 0, 0, 0, 0, 2, 2, 0],
        [0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 3, 0],
        None,
        [_T.tRCD, _T.tBURST, _T.tRP, _T.tCKE, 250, _T.tXPDLL,
         _T.tCKE, 800, _T.tXS, _T.tRCD, _T.tBURST, _T.tRP])


def _ragged_traces():
    trs = [traces.app_trace(traces.SPEC_APPS[i], n_requests=n)
           for i, n in ((0, 120), (3, 220), (7, 60))]
    trs.append(idd_loops.idd2p1())          # power-down loop
    trs.append(idd_loops.idd6())            # self-refresh loop
    trs.append(idd_loops.validation_sweep(16))
    trs.append(_pde_trace())                # PDE/PDX mid-trace
    trs.append(_lowpower_trace())           # slow PDN + SR mid-trace
    return trs


def test_estimate_many_matches_per_trace_leaf_by_leaf(quick_vampire):
    """The tentpole's acceptance bar: one vmap(vmap) dispatch over padded
    ragged traces must reproduce every per-trace report leaf."""
    trs = _ragged_traces()
    assert len({t.n for t in trs}) > 2  # genuinely ragged
    vendors = sorted(quick_vampire.by_vendor)
    rep = quick_vampire.estimate_many(trs, vendors)
    assert rep.energy_pj.shape == (len(trs), len(vendors))
    for i, tr in enumerate(trs):
        for j, v in enumerate(vendors):
            one = quick_vampire.estimate(tr, v)
            for name, a, b in zip(rep._fields, rep, one):
                np.testing.assert_allclose(
                    np.asarray(a)[i, j], np.asarray(b), rtol=2e-6,
                    err_msg=f"trace {i} vendor {v} leaf {name}")


def test_estimate_many_accepts_single_trace_and_prebuilt_batch(quick_vampire):
    tr = idd_loops.validation_sweep(8)
    rep1 = quick_vampire.estimate_many(tr, (0, 1))
    assert rep1.energy_pj.shape == (1, 2)
    tb = estimate_batch.TraceBatch.from_traces([tr, idd_loops.idd2n()])
    rep2 = quick_vampire.estimate_many(tb, (0,))
    np.testing.assert_allclose(np.asarray(rep2.energy_pj)[0, 0],
                               np.asarray(rep1.energy_pj)[0, 0], rtol=1e-6)


def test_estimate_range_many_vmaps_band_over_energy(quick_vampire):
    """The band must reach every report field (the estimate_range bugfix),
    batched and per-trace alike."""
    trs = [idd_loops.validation_sweep(n) for n in (4, 64)]
    vendors = sorted(quick_vampire.by_vendor)
    lo, mid, hi = quick_vampire.estimate_range_many(trs, vendors)
    assert np.all(np.asarray(lo.energy_pj) < np.asarray(mid.energy_pj))
    assert np.all(np.asarray(mid.energy_pj) < np.asarray(hi.energy_pj))
    assert np.all(np.asarray(lo.avg_current_ma)
                  < np.asarray(hi.avg_current_ma))
    np.testing.assert_array_equal(np.asarray(lo.cycles),
                                  np.asarray(hi.cycles))
    for i, tr in enumerate(trs):
        for j, v in enumerate(vendors):
            for batched, single in zip((lo, mid, hi),
                                       quick_vampire.estimate_range(tr, v)):
                np.testing.assert_allclose(
                    np.asarray(batched.energy_pj)[i, j],
                    float(single.energy_pj), rtol=2e-6)


def test_estimate_distribution_many_matches_single(quick_vampire):
    trs = [idd_loops.validation_sweep(16), idd_loops.validation_sweep(64)]
    rep = quick_vampire.estimate_distribution_many(
        trs, (0, 2), ones_frac=0.5, toggle_frac=0.25)
    for i, tr in enumerate(trs):
        for j, v in enumerate((0, 2)):
            one = quick_vampire.estimate_distribution(tr, v, 0.5, 0.25)
            np.testing.assert_allclose(np.asarray(rep.energy_pj)[i, j],
                                       float(one.energy_pj), rtol=2e-6)
    # per-trace fractions broadcast along the trace axis
    rep2 = quick_vampire.estimate_distribution_many(
        trs, (0,), ones_frac=np.asarray([0.1, 0.9]),
        toggle_frac=np.asarray([0.0, 0.5]))
    one0 = quick_vampire.estimate_distribution(trs[0], 0, 0.1, 0.0)
    one1 = quick_vampire.estimate_distribution(trs[1], 0, 0.9, 0.5)
    np.testing.assert_allclose(np.asarray(rep2.energy_pj)[0, 0],
                               float(one0.energy_pj), rtol=2e-6)
    np.testing.assert_allclose(np.asarray(rep2.energy_pj)[1, 0],
                               float(one1.energy_pj), rtol=2e-6)


# ---------------------------------------------------------------------------
# Scan-vs-vectorized property test: first-RD/WR-per-bank interleave edges
# ---------------------------------------------------------------------------
_PP = device_sim.true_vendor_params(1)


def _interleave_trace(accesses):
    """ACT a few banks, then replay drawn (bank, col, is_write) accesses —
    the first RD/WR of each bank exercises the has_bank_prev=False
    interleave classification, cross-bank toggles, and the global
    first-access special case."""
    cmds = [ACT] * 4
    banks = [0, 1, 2, 3]
    rows = [3, 1, 4, 1]
    cols = [0] * 4
    datas = [np.zeros(dram.LINE_WORDS, np.uint32)] * 4
    dts = [_T.tRC] * 4
    for k, (b, c, is_wr) in enumerate(accesses):
        cmds.append(WR if is_wr else RD)
        banks.append(b)
        rows.append([3, 1, 4, 1][b])
        cols.append(c)
        datas.append(dram.line_with_n_ones((k * 91 + 64 * b) % 513))
        dts.append(_T.tCCD)
    return dram.make_trace(cmds, banks, rows, cols, np.stack(datas), dts)


@hypothesis.settings(deadline=None, max_examples=25)
@hypothesis.given(st.lists(
    st.tuples(st.integers(0, 3), st.integers(0, 1), st.booleans()),
    min_size=1, max_size=12))
def test_scan_matches_vectorized_first_rw_per_bank_interleave(accesses):
    tr = _interleave_trace(accesses)
    a = trace_energy_scan(tr, _PP)
    b = trace_energy_vectorized(tr, _PP)
    np.testing.assert_allclose(float(a.avg_current_ma),
                               float(b.avg_current_ma), rtol=1e-5)
    np.testing.assert_allclose(float(a.energy_pj), float(b.energy_pj),
                               rtol=1e-5)


def test_scan_matches_vectorized_on_batched_members(quick_vampire):
    """Padding must not change what the scan oracle would say about the
    original trace: compare the batched reports against the scan oracle
    trace by trace."""
    trs = [_pde_trace(), idd_loops.validation_sweep(4)]
    rep = quick_vampire.estimate_many(trs, (1,))
    for i, tr in enumerate(trs):
        oracle = trace_energy_scan(tr, quick_vampire.params(1))
        np.testing.assert_allclose(np.asarray(rep.energy_pj)[i, 0],
                                   float(oracle.energy_pj), rtol=1e-5)
