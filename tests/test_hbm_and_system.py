"""TPU/HBM adaptation layer + end-to-end system behaviour.

``test_end_to_end_power_study`` keeps exercising the legacy per-(trace,
vendor) shim on purpose (DeprecationWarning filter below)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def test_hbm_model_data_dependency(quick_vampire):
    from repro.core import hbm
    m = hbm.HbmEnergyModel.from_vampire(quick_vampire.params(0))
    lo = m.read_energy_pj(1e6, ones_frac=0.1)
    hi = m.read_energy_pj(1e6, ones_frac=0.9)
    assert hi > lo > 0
    # writes: inverse dependency (paper Section 5.1)
    wlo = m.write_energy_pj(1e6, ones_frac=0.9)
    whi = m.write_energy_pj(1e6, ones_frac=0.1)
    assert whi > wlo > 0


def test_hbm_anchor_scale(quick_vampire):
    """A random-data read must land on the HBM2e pJ/bit anchor."""
    from repro.core import hbm
    m = hbm.HbmEnergyModel.from_vampire(quick_vampire.params(0))
    pj = float(m.read_energy_pj(64, ones_frac=0.5, toggle_frac=0.0))
    per_bit = pj / 512
    assert abs(per_bit - hbm.HBM2E_PJ_PER_BIT_READ) < 0.4


def test_tensor_stats():
    from repro.core import hbm
    zeros = jnp.zeros((64, 64), jnp.float32)
    ones_frac, togg = hbm.tensor_stats(zeros)
    assert ones_frac == 0.0
    x = jax.random.normal(jax.random.key(0), (64, 64), jnp.float32)
    of, tf = hbm.tensor_stats(x)
    assert 0.1 < of < 0.9
    assert 0.0 < tf < 0.9


def test_step_energy_combines_terms(quick_vampire):
    from repro.core import hbm
    m = hbm.HbmEnergyModel.from_vampire(quick_vampire.params(1))
    rep = hbm.step_energy(m, read_bytes=1e9, write_bytes=5e8,
                          step_seconds=0.1, ones_frac=0.4)
    assert rep.total_pj == pytest.approx(
        rep.read_pj + rep.write_pj + rep.static_pj)
    assert rep.total_j > 0


def test_end_to_end_power_study(quick_vampire):
    """System test: generate app traces, evaluate all encodings with the
    fitted model, reproduce the Section 10 ordering on a small sample."""
    from repro.core import encodings, traces
    apps = [traces.SPEC_APPS[i] for i in (3, 7, 12)]
    ratios = {}
    for app in apps:
        tr = traces.app_trace(app, n_requests=300)
        base = float(quick_vampire.estimate(tr, 2).energy_pj)
        owi = float(quick_vampire.estimate(
            encodings.encode_trace(tr, "owi"), 2).energy_pj)
        ratios[app.name] = owi / base
    mean_saving = 1 - np.mean(list(ratios.values()))
    assert mean_saving > 0.02, ratios  # OWI saves energy on average


def test_tensor_bytes_to_trace_roundtrip():
    from repro.core import traces
    buf = np.arange(256, dtype=np.uint8).tobytes()
    lines = traces.lines_from_bytes(buf)
    assert lines.shape == (4, 16)
    back = lines.view(np.uint8) if lines.flags["C_CONTIGUOUS"] else None
    assert bytes(np.ascontiguousarray(lines).view(np.uint8)
                 .reshape(-1)[:256]) == buf
