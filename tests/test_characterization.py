"""Characterization pipeline: IDD reproduction, Table-5 recovery, fits."""
import numpy as np
import pytest

from repro.core import characterize, device_sim, fitting, idd_loops
from repro.core import params as P
from repro.core.energy_model import trace_energy_vectorized


def test_vendor_mean_idd_matches_anchors():
    """The simulated vendor means must land on the paper's numeric anchors
    (IDD0/IDD1 are given numerically in Section 4.2)."""
    for v, (idd0, idd1) in enumerate(zip(P.MEASURED_IDD["IDD0"],
                                         P.MEASURED_IDD["IDD1"])):
        pp = device_sim.true_vendor_params(v)
        got0 = float(trace_energy_vectorized(idd_loops.idd0(), pp)
                     .avg_current_ma)
        assert abs(got0 - idd0) / idd0 < 0.05
        got1 = float(trace_energy_vectorized(idd_loops.idd1(), pp)
                     .avg_current_ma)
        assert abs(got1 - idd1) / idd1 < 0.15


def test_measured_over_datasheet_ratios_by_construction():
    ds = characterize.derive_datasheets()
    for v in range(3):
        pp = device_sim.true_vendor_params(v)
        for key in ("IDD2N", "IDD0", "IDD4W", "IDD5B"):
            loop = idd_loops.IDD_LOOPS[key]()
            measured = float(trace_energy_vectorized(loop, pp)
                             .avg_current_ma)
            ratio = measured / ds[v][key]
            target = P.MEASURED_OVER_DATASHEET[key][v]
            np.testing.assert_allclose(ratio, target, rtol=1e-3)


def test_frequency_extrapolation_r2_above_paper_floor():
    _, r2s = characterize.extrapolated_datasheets()
    worst = min(min(d.values()) for d in r2s.values())
    assert worst >= 0.97  # paper: worst R^2 = 0.9783


def test_datadep_fit_recovers_table5(quick_vampire):
    """Fitted Eq.-2 parameters must recover the published Table 5 within
    process-variation tolerance."""
    for v, vc in quick_vampire.by_vendor.items():
        truth = P.TABLE5[v]
        fit = vc.datadep
        # tolerances sized for 2-probe-module process variation (~6% s.e.)
        np.testing.assert_allclose(fit[:, :, 0], truth[:, :, 0], rtol=0.15)
        np.testing.assert_allclose(fit[:, :, 1], truth[:, :, 1], atol=0.08)
        np.testing.assert_allclose(fit[:, :, 2], truth[:, :, 2], atol=0.08)


def test_datadep_linearity_r2(quick_vampire):
    """Paper: R^2 of the ones/toggle linearity is never below 0.990 (where
    a slope exists; flat relationships make R^2 meaningless)."""
    for v, vc in quick_vampire.by_vendor.items():
        for mi in range(4):
            for oi in range(2):
                if abs(P.TABLE5[v][mi][oi][1]) < 0.05:
                    continue  # flat: vendor C writes
                assert vc.datadep_r2[mi, oi] > 0.97, (v, mi, oi)


def test_structural_bank_recovery(quick_vampire):
    vc = quick_vampire.by_vendor[2]  # vendor C
    # bank-open increments: bank1 >> bank0 for vendor C (paper Fig 19)
    assert vc.bank_open_delta[1] > 2.0 * vc.bank_open_delta[0]
    # read factors recovered within a few %
    np.testing.assert_allclose(vc.bank_read_factor,
                               P.BANK_READ_FACTORS[2], atol=0.04)


def test_row_address_slope_recovered(quick_vampire):
    for v, vc in quick_vampire.by_vendor.items():
        truth = P.ROW_ONES_SLOPE[v]
        assert abs(vc.row_ones_slope - truth) < 0.6 * truth + 2e-3, v


def test_pair_lines_have_exact_ones_and_toggles():
    from repro.core.dram import line_ones, line_toggles
    import jax.numpy as jnp
    for n1, tg in ((64, 32), (256, 128), (448, 64)):
        a, b = characterize.pair_lines(n1, tg, seed=3)
        assert int(line_ones(jnp.asarray(a[None]))[0]) == n1
        assert int(line_ones(jnp.asarray(b[None]))[0]) == n1
        assert int(line_toggles(jnp.asarray(a[None]),
                                jnp.asarray(b[None]))[0]) == tg
