"""Serving subsystem: ring bucketing, mesh engine, admission + metrics.

The multi-device assertions (shard_map ≡ single-device parity) skip on a
single-device host and run in the CI lane that forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idd_loops
from repro.core.dram import CommandTrace
from repro.core.estimate_batch import bucketed_trace_batch
from repro.launch.mesh import make_local_mesh
from repro.serving import (EstimationService, RingConfig, ServiceConfig,
                           TraceRing, TraceTooLongError)


def _sweeps(ns=(1, 8, 16, 64)):
    return [idd_loops.validation_sweep(n) for n in ns]


def _corrupt(trace: CommandTrace) -> CommandTrace:
    """A protocol-illegal copy: first ACT->PRE gap squeezed to 2 cycles."""
    return CommandTrace(trace.cmd, trace.bank, trace.row, trace.col,
                        trace.data, trace.dt.at[0].set(2))


# ---------------------------------------------------------------------------
# TraceRing
# ---------------------------------------------------------------------------
def test_ring_empty_flush_is_noop():
    ring = TraceRing()
    assert ring.take() is None
    assert len(ring) == 0


def test_ring_pads_to_bucket_shapes():
    ring = TraceRing(RingConfig(length_buckets=(256,), count_buckets=(4,)))
    traces = _sweeps((1, 8, 16))           # lengths 24, 80, 144
    for tr in traces:
        ring.admit(tr)
    rb = ring.take()
    assert rb.batch.trace.cmd.shape == (4, 256)
    assert rb.tickets == (0, 1, 2)
    assert rb.n_real == 3 and rb.slots == 4 and rb.fill == 0.75
    # the weight mask covers exactly the real commands
    np.testing.assert_array_equal(
        np.asarray(rb.batch.weight).sum(axis=1),
        [int(tr.n) for tr in traces] + [0])
    assert len(ring) == 0 and ring.take() is None


def test_ring_rejects_trace_longer_than_largest_bucket():
    ring = TraceRing(RingConfig(length_buckets=(64, 128),
                                count_buckets=(4,)))
    with pytest.raises(TraceTooLongError) as ei:
        ring.admit(idd_loops.validation_sweep(16))   # 144 commands
    assert ei.value.n == 144 and ei.value.limit == 128


def test_ring_windows_group_by_vendor_subset_fifo():
    ring = TraceRing(RingConfig(length_buckets=(256,), count_buckets=(4,)))
    trs = _sweeps((1, 4, 8, 16))
    ring.admit(trs[0], group=(0, 1))
    ring.admit(trs[1], group=(0, 1))
    ring.admit(trs[2], group=(2,))
    ring.admit(trs[3], group=(0, 1))
    first = ring.take()
    assert first.group == (0, 1) and first.tickets == (0, 1, 3)
    second = ring.take()
    assert second.group == (2,) and second.tickets == (2,)
    assert ring.take() is None


def test_ring_reuses_pad_buffers_in_place():
    ring = TraceRing(RingConfig(length_buckets=(256,), count_buckets=(4,)))
    ring.admit(_sweeps((8,))[0])
    ring.take()
    ring.admit(_sweeps((16,))[0])
    ring.take()
    assert list(ring._buffers) == [(4, 256)]   # one persistent buffer set


def test_ring_max_batch_caps_window():
    ring = TraceRing(RingConfig(length_buckets=(256,), count_buckets=(2, 4)))
    for tr in _sweeps((1, 4, 8)):
        ring.admit(tr)
    rb = ring.take(max_batch=2)
    assert rb.tickets == (0, 1) and rb.slots == 2
    assert len(ring) == 1


# ---------------------------------------------------------------------------
# bucketed_trace_batch (the core hook the ring pads through on device)
# ---------------------------------------------------------------------------
def test_bucketed_trace_batch_matches_exact_pad(quick_vampire):
    trs = _sweeps((1, 8, 16))
    exact = quick_vampire.estimate(trs)
    tb = bucketed_trace_batch(trs, n_slots=8, length=512)
    assert tb.trace.cmd.shape == (8, 512)
    bucketed = quick_vampire.estimate(tb)
    np.testing.assert_allclose(
        np.asarray(bucketed.avg_current_ma)[:3],
        np.asarray(exact.avg_current_ma), rtol=1e-5)


def test_bucketed_trace_batch_validates_shape():
    trs = _sweeps((1, 8))
    with pytest.raises(ValueError):
        bucketed_trace_batch(trs, n_slots=1, length=512)
    with pytest.raises(ValueError):
        bucketed_trace_batch(trs, n_slots=4, length=64)
    with pytest.raises(ValueError):
        bucketed_trace_batch([], n_slots=4, length=64)


# ---------------------------------------------------------------------------
# EstimationService: admission, modes, metrics, lifecycle
# ---------------------------------------------------------------------------
def test_service_every_mode_matches_direct_estimate(quick_vampire):
    trs = _sweeps()
    for mode, kwargs in (("mean", {}), ("range", {}), ("surface", {}),
                         ("distribution",
                          dict(ones_frac=0.5, toggle_frac=0.25))):
        svc = EstimationService(
            quick_vampire, ServiceConfig(mode=mode, **kwargs))
        tickets, rejections = svc.submit_many(trs)
        assert not rejections
        assert svc.drain() == len(trs)
        direct = quick_vampire.estimate(trs, mode=mode, **kwargs)
        for i, t in enumerate(tickets):
            row = svc.result(t)
            got, want = ((row,), (direct,)) if mode != "range" \
                else (row, direct)
            for g, w in zip(got, want):
                np.testing.assert_allclose(
                    np.asarray(g.energy_pj),
                    np.asarray(w.energy_pj)[i], rtol=1e-5)


def test_service_vendor_subset_requests(quick_vampire):
    trs = _sweeps((1, 8, 16))
    svc = EstimationService(quick_vampire, ServiceConfig())
    ta, _ = svc.submit_many(trs[:2], vendors=(1, 2))
    tb, _ = svc.submit_many(trs[2:], vendors=(0,))
    # two vendor groups -> two dispatch windows
    assert svc.drain() == 3 and svc.metrics().dispatches == 2
    direct12 = quick_vampire.estimate(trs[:2], (1, 2))
    direct0 = quick_vampire.estimate(trs[2:], (0,))
    for i, t in enumerate(ta):
        row = np.asarray(svc.result(t).avg_current_ma)
        assert row.shape == (2,)
        np.testing.assert_allclose(row,
                                   np.asarray(direct12.avg_current_ma)[i],
                                   rtol=1e-5)
    np.testing.assert_allclose(np.asarray(svc.result(tb[0]).avg_current_ma),
                               np.asarray(direct0.avg_current_ma)[0],
                               rtol=1e-5)


def test_service_mixed_admission_rejects_and_still_dispatches(quick_vampire):
    legal = _sweeps((8, 16))
    bad = _corrupt(legal[0])
    svc = EstimationService(quick_vampire, ServiceConfig())
    tickets, rejections = svc.submit_many([legal[0], bad, legal[1]])
    assert tickets[1] is None and len(rejections) == 1
    assert rejections[0].reason == "protocol" and rejections[0].rules
    assert rejections[0].diagnostics[0].rule
    # the legal traces ride through regardless
    assert svc.drain() == 2
    direct = quick_vampire.estimate(legal)
    for i, t in enumerate((tickets[0], tickets[2])):
        np.testing.assert_allclose(
            np.asarray(svc.result(t).avg_current_ma),
            np.asarray(direct.avg_current_ma)[i], rtol=1e-5)
    m = svc.metrics()
    assert m.admitted == 2 and m.rejected == 1
    assert sum(m.rejected_by_rule.values()) >= 1


def test_service_too_long_is_a_structured_rejection(quick_vampire):
    svc = EstimationService(quick_vampire, ServiceConfig(
        ring=RingConfig(length_buckets=(64,), count_buckets=(4,))))
    r = svc.submit(idd_loops.validation_sweep(16))     # 144 > 64
    assert r.reason == "too-long" and r.rules == ("too-long",)
    assert svc.metrics().rejected_by_rule == {"too-long": 1}


def test_service_shutdown_drain_and_close(quick_vampire):
    trs = _sweeps((1, 8, 16, 64, 4))
    svc = EstimationService(quick_vampire, ServiceConfig(max_batch=2))
    tickets, _ = svc.submit_many(trs)
    assert svc.close() == len(trs)                     # drains every window
    for t in tickets:
        assert np.asarray(svc.result(t).energy_pj).shape == (3,)
    with pytest.raises(RuntimeError):
        svc.submit_many(trs[:1])
    m = svc.metrics()
    assert m.queue_depth == 0 and m.completed == len(trs)
    assert m.dispatches == 3                           # windows of <= 2


def test_service_metrics_snapshot(quick_vampire):
    svc = EstimationService(quick_vampire, ServiceConfig())
    tickets, _ = svc.submit_many(_sweeps((1, 8)))
    assert svc.metrics().queue_depth == 2
    svc.drain()
    m = svc.metrics()
    assert dataclasses.asdict(m)                       # plain-dict friendly
    assert m.dispatched_traces == 2 and m.batch_fill == pytest.approx(0.25)
    assert m.traces_per_s > 0
    assert m.latency_p99_ms >= m.dispatch_p50_ms > 0
    assert m.engine_programs == 1


def test_service_result_before_dispatch_raises(quick_vampire):
    svc = EstimationService(quick_vampire, ServiceConfig())
    t = svc.submit(_sweeps((1,))[0])
    with pytest.raises(KeyError):
        svc.result(t)
    svc.drain()
    svc.result(t)


# ---------------------------------------------------------------------------
# Recompile bound + recalibration hook
# ---------------------------------------------------------------------------
def test_serving_recompile_probe_holds(quick_vampire):
    from repro.analysis import dispatch_audit
    assert dispatch_audit.audit_serving(quick_vampire) == []


def test_treedef_stable_model_update_reuses_programs(quick_vampire):
    trs = _sweeps((1, 8))
    svc = EstimationService(quick_vampire, ServiceConfig())
    t0, _ = svc.submit_many(trs)
    svc.drain()
    before = np.asarray(svc.result(t0[0]).avg_current_ma)
    programs = svc.engine.cache_size()
    bump = lambda x: (x * 1.05 if jnp.issubdtype(x.dtype, jnp.floating)
                      else x)
    svc.engine.update_model(
        jax.tree_util.tree_map(bump, svc.engine.resident))
    t1, _ = svc.submit_many(trs)
    svc.drain()
    after = np.asarray(svc.result(t1[0]).avg_current_ma)
    assert svc.engine.cache_size() == programs         # no recompile
    assert not np.allclose(after, before)              # new params applied


# ---------------------------------------------------------------------------
# Mesh parity: single-host fallback everywhere, shard_map on the CI lane
# ---------------------------------------------------------------------------
def test_single_device_mesh_falls_back_bitwise(quick_vampire):
    trs = _sweeps()
    svc_mesh = EstimationService(quick_vampire, ServiceConfig(),
                                 mesh=make_local_mesh(data=1, model=1))
    svc_none = EstimationService(quick_vampire, ServiceConfig())
    assert svc_mesh.engine.n_shards == 1
    tm, _ = svc_mesh.submit_many(trs)
    tn, _ = svc_none.submit_many(trs)
    svc_mesh.drain(), svc_none.drain()
    for a, b in zip(tm, tn):
        np.testing.assert_array_equal(
            np.asarray(svc_mesh.result(a).energy_pj),
            np.asarray(svc_none.result(b).energy_pj))


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs the forced multi-device CPU lane")
def test_shard_map_matches_single_device_bitwise(quick_vampire):
    n_dev = jax.device_count()
    mesh = make_local_mesh(data=n_dev // 2, model=2) if n_dev % 2 == 0 \
        else make_local_mesh(data=n_dev, model=1)
    trs = _sweeps((1, 4, 8, 16, 24, 32, 48, 64))       # 8 % n_shards == 0
    svc_mesh = EstimationService(quick_vampire, ServiceConfig(), mesh=mesh)
    svc_none = EstimationService(quick_vampire, ServiceConfig())
    assert svc_mesh.engine.n_shards == n_dev > 1
    tm, _ = svc_mesh.submit_many(trs)
    tn, _ = svc_none.submit_many(trs)
    svc_mesh.drain(), svc_none.drain()
    for a, b in zip(tm, tn):
        np.testing.assert_array_equal(
            np.asarray(svc_mesh.result(a).energy_pj),
            np.asarray(svc_none.result(b).energy_pj))
    # a window that does not divide the mesh falls back, still exact
    t3, _ = svc_mesh.submit_many(trs[:3])
    svc_mesh.drain()
    direct = quick_vampire.estimate(trs[:3])
    np.testing.assert_allclose(
        np.asarray(svc_mesh.result(t3[0]).avg_current_ma),
        np.asarray(direct.avg_current_ma)[0], rtol=1e-5)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs the forced multi-device CPU lane")
def test_fleet_surface_mesh_shards_modules_bitwise(tiny_fleet):
    from repro.core.fleet import fleet_surface_energy
    from repro.core.validate import surface_sweep_trace
    n_dev = jax.device_count()
    n_model = 3 if n_dev % 3 == 0 else 1
    mesh = make_local_mesh(data=n_dev // n_model, model=n_model)
    n_data = mesh.shape["data"]
    tb = bucketed_trace_batch([surface_sweep_trace()] * n_data,
                              n_data, 4096)
    modules = list(tiny_fleet)[:9 - (9 % mesh.shape["model"])]
    sharded = fleet_surface_energy(modules, tb.trace, tb.weight, mesh=mesh)
    plain = fleet_surface_energy(modules, tb.trace, tb.weight)
    np.testing.assert_array_equal(np.asarray(sharded.energy_pj),
                                  np.asarray(plain.energy_pj))


def test_fleet_surface_mesh_fallback_single_device(tiny_fleet):
    from repro.core.fleet import fleet_surface_energy
    from repro.core.validate import surface_sweep_trace
    mesh = make_local_mesh(data=1, model=1)
    tb = bucketed_trace_batch([surface_sweep_trace()], 1, 4096)
    modules = list(tiny_fleet)[:3]
    with_mesh = fleet_surface_energy(modules, tb.trace, tb.weight,
                                     mesh=mesh)
    plain = fleet_surface_energy(modules, tb.trace, tb.weight)
    np.testing.assert_array_equal(np.asarray(with_mesh.energy_pj),
                                  np.asarray(plain.energy_pj))
