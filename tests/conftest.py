"""Shared fixtures. NOTE: no XLA_FLAGS / device-count manipulation here —
smoke tests and benches must see the real (single) device; only
launch/dryrun.py sets the 512-device placeholder flag, and the dry-run
integration test uses a subprocess."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def tiny_fleet():
    from repro.core import device_sim, params as P
    specs = [P.ModuleSpec(v, i, 2015) for v in range(3) for i in range(3)]
    return device_sim.make_fleet(specs)


@pytest.fixture(scope="session")
def quick_vampire(tiny_fleet):
    """A reduced-campaign VAMPIRE fit shared across the suite."""
    from repro.core.vampire import Vampire
    return Vampire.fit(tiny_fleet, probe_modules=2, probe_reps=64, n_rows=8)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
