"""Per-architecture smoke tests (deliverable f) + decode consistency +
Mamba2 SSD chunked-vs-recurrent property."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models.lm import LM
from repro.optim import adamw

ARCHS = list(R.ARCH_NAMES)


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step on CPU; shapes + finite."""
    cfg = R.get_config(arch, smoke=True)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.aux_seq:
        batch["aux"] = jnp.full((B, cfg.aux_seq, cfg.d_model), 0.01,
                                jnp.dtype(cfg.dtype))
    logits, aux_loss = lm.forward(params, tokens, aux=batch.get("aux"))
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab])))
    # pad-vocab columns are masked inert
    if cfg.vocab_padded > cfg.vocab:
        assert bool(jnp.all(logits[..., cfg.vocab:] <= -1e29))

    ocfg = adamw.AdamWConfig(warmup_steps=1, decay_steps=4)
    opt = adamw.init(params, ocfg)

    def loss_fn(p):
        return lm.loss(p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    new_params, _, metrics = adamw.update(grads, opt, params, ocfg)
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually changed
    diff = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0].astype(jnp.float32)
                                               - x[1].astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, b: (a, b), params, new_params),
        0.0)
    assert diff > 0


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-780m",
                                  "whisper-small", "llama-3.2-vision-11b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_teacher_forcing(arch):
    cfg = R.get_config(arch, smoke=True)
    if cfg.moe is not None:  # disable capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    lm = LM(cfg)
    params = lm.init(jax.random.key(2))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab)
    aux = (jnp.full((B, cfg.aux_seq, cfg.d_model), 0.01,
                    jnp.dtype(cfg.dtype)) if cfg.aux_seq else None)
    full, _ = lm.forward(params, tokens, aux=aux)
    _, cache = lm.prefill(params, tokens[:, :S - 2], aux=aux, max_len=S)
    lg1, cache = lm.decode_step(params, cache, tokens[:, S - 2:S - 1])
    lg2, cache = lm.decode_step(params, cache, tokens[:, S - 1:S])
    scale = float(jnp.std(full[:, S - 2])) + 1e-6
    assert float(jnp.max(jnp.abs(lg1 - full[:, S - 2]))) < 0.15 * scale + 0.05
    assert float(jnp.max(jnp.abs(lg2 - full[:, S - 1]))) < 0.15 * scale + 0.05


def test_mla_decode_close_to_teacher_forcing():
    """MLA's absorbed-matrix decode reorders matmuls; allow a looser bf16
    tolerance (documented in DESIGN.md)."""
    cfg = R.get_config("deepseek-v2-lite-16b", smoke=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    lm = LM(cfg)
    params = lm.init(jax.random.key(2))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab)
    full, _ = lm.forward(params, tokens)
    _, cache = lm.prefill(params, tokens[:, :S - 1], max_len=S)
    lg, _ = lm.decode_step(params, cache, tokens[:, S - 1:S])
    scale = float(jnp.std(full[:, S - 1])) + 1e-6
    assert float(jnp.max(jnp.abs(lg - full[:, S - 1]))) < 0.5 * scale


def test_mamba_chunked_equals_recurrent():
    """Property: the chunked SSD scan == step-by-step recurrence."""
    from repro.models import layers as L
    from repro.models.meta import materialize
    cfg = R.get_config("mamba2-780m", smoke=True)
    meta = L.mamba_meta(cfg)
    params = materialize(meta, jax.random.key(5), dtype=jnp.float32)
    B, S = 2, 24
    x = jax.random.normal(jax.random.key(6), (B, S, cfg.d_model),
                          jnp.float32) * 0.3
    full_out, final = L.mamba_apply(params, x, cfg)

    s = cfg.ssm
    conv_dim = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state
    cache = {"state": jnp.zeros((B, s.n_heads(cfg.d_model), s.d_state,
                                 s.head_dim), jnp.float32),
             "conv": jnp.zeros((B, s.conv_width - 1, conv_dim),
                               jnp.float32)}
    outs = []
    for t in range(S):
        o, cache = L.mamba_decode(params, x[:, t:t + 1], cache, cfg)
        outs.append(o)
    rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full_out), np.asarray(rec),
                               atol=2e-3, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(final["state"]),
                               np.asarray(cache["state"]),
                               atol=2e-3, rtol=2e-2)


def test_moe_capacity_drops_bounded():
    """With capacity_factor=1.0 some tokens drop, but the layer stays finite
    and routed mass is preserved for kept tokens."""
    from repro.models import layers as L
    from repro.models.meta import materialize
    cfg = R.get_config("qwen3-moe-30b-a3b", smoke=True)
    meta = L.moe_meta(cfg)
    params = materialize(meta, jax.random.key(7), dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(8), (2, 64, cfg.d_model))
    y = L.moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_config_param_estimates_sane():
    expected = {  # rough public parameter counts
        "qwen2.5-3b": (2.5e9, 4.0e9),
        "granite-8b": (7e9, 9.5e9),
        "qwen2-7b": (6.5e9, 8.5e9),
        "yi-34b": (3.0e10, 3.9e10),
        "mamba2-780m": (6e8, 1.0e9),
        "qwen3-moe-30b-a3b": (2.6e10, 3.4e10),
        "deepseek-v2-lite-16b": (1.2e10, 1.9e10),
        "whisper-small": (1.5e8, 3.5e8),
        "jamba-1.5-large-398b": (3.1e11, 4.5e11),
        "llama-3.2-vision-11b": (8e9, 1.2e10),
    }
    for arch, (lo, hi) in expected.items():
        n = R.get_config(arch).n_params_estimate
        assert lo <= n <= hi, (arch, n)


def test_input_specs_cover_all_cells():
    for arch, shape in R.all_cells():
        cfg = R.get_config(arch)
        specs = R.input_specs(cfg, R.SHAPES[shape])
        assert "tokens" in specs
        if R.SHAPES[shape].kind == "decode":
            assert "caches" in specs
    assert len(R.all_cells()) + len(R.skipped_cells()) == 40


def test_int8_kv_cache_decode_close():
    """int8-quantized KV cache (H3 encoding) stays close to bf16 decode."""
    import jax
    import jax.numpy as jnp
    from repro.models import layers as L
    from repro.models.meta import materialize
    cfg = R.get_config("granite-8b", smoke=True)
    params = materialize(L.attn_meta(cfg), jax.random.key(11),
                         dtype=jnp.float32)
    B, S = 2, 24
    x = jax.random.normal(jax.random.key(12), (B, 1, cfg.d_model)) * 0.5
    kv_shape = (B, S, cfg.n_kv, cfg.d_head)
    k0 = jax.random.normal(jax.random.key(13), kv_shape) * 0.5
    v0 = jax.random.normal(jax.random.key(14), kv_shape) * 0.5
    pos = jnp.asarray(S - 4, jnp.int32)
    cache_bf = {"k": k0, "v": v0, "pos": pos}
    o_bf, _ = L.attn_decode(params, x, cache_bf, cfg)
    kq, ks = L.quantize_kv(k0)
    vq, vs = L.quantize_kv(v0)
    cache_q = {"k": kq, "v": vq, "k_s": ks, "v_s": vs, "pos": pos}
    o_q, nc = L.attn_decode(params, x, cache_q, cfg)
    assert nc["k"].dtype == jnp.int8
    err = float(jnp.max(jnp.abs(o_q - o_bf)))
    scale = float(jnp.std(o_bf)) + 1e-6
    assert err < 0.1 * scale + 0.02, (err, scale)
