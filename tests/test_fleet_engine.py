"""Batched fleet-evaluation engine: padding/masking invariance, counter-based
measurement noise, batched-vs-serial campaign equivalence, model IO.

Some tests keep exercising the legacy ``estimate*`` shims on purpose
(module-wide DeprecationWarning filter); ``test_model_api.py`` covers the
unified entry point."""
import numpy as np
import pytest

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

import jax.numpy as jnp

from repro.core import device_sim, dram, fleet, idd_loops
from repro.core import params as P
from repro.core.vampire import Vampire


def _specs():
    return [P.ModuleSpec(v, i, 2015) for v in range(3) for i in range(2)]


def test_stack_params_adds_leading_module_axis(tiny_fleet):
    stacked = fleet.stack_params([m.params for m in tiny_fleet])
    n = len(tiny_fleet)
    assert stacked.datadep.shape == (n, 4, 2, 3)
    assert stacked.i2n.shape == (n,)
    assert stacked.bank_open_delta.shape == (n, 8)
    np.testing.assert_array_equal(np.asarray(stacked.q_ref[3]),
                                  np.asarray(tiny_fleet[3].params.q_ref))


def test_pad_trace_preserves_energy():
    pp = device_sim.true_vendor_params(1)
    from repro.core.energy_model import trace_energy_vectorized
    tr = idd_loops.idd4r(reps=8)
    padded = dram.pad_trace(tr, tr.n + 37)
    a = trace_energy_vectorized(tr, pp)
    b = trace_energy_vectorized(padded, pp)
    np.testing.assert_allclose(float(a.energy_pj), float(b.energy_pj),
                               rtol=1e-6)
    assert int(a.cycles) == int(b.cycles)


def test_batch_traces_mask_generalizes_skip():
    """The padded/masked batch must reproduce the serial ``skip=`` average
    for probes of unequal length."""
    mod = device_sim.SimulatedModule(P.ModuleSpec(0, 0, 2015))
    points = []
    for i, (tr, skip) in enumerate([idd_loops.ones_sweep_point(256, reps=8),
                                    idd_loops.bank_idle_probe(3),
                                    idd_loops.row_act_probe(0x55, reps=16)]):
        points.append(fleet.ProbePoint(("p", i), tr, skip, key=900 + i))
    mat = fleet.run_probes([mod], points, engine="batched", noisy=False)
    for j, pt in enumerate(points):
        serial = mod.measure_current(pt.trace, noisy=False, skip=pt.skip)
        np.testing.assert_allclose(mat[0, j], serial, rtol=1e-5)


def test_noise_matrix_matches_per_call_draws():
    """The vectorized (modules, probes) noise matrix must be bit-identical
    to the scalar per-measurement draws of the serial oracle."""
    specs = _specs()
    keys = [5, 17, 4096]
    mat = device_sim.measurement_noise_factors(specs, keys)
    assert mat.shape == (len(specs), len(keys))
    for i, s in enumerate(specs):
        for j, k in enumerate(keys):
            one = device_sim.measurement_noise_factors([s], [k])[0, 0]
            assert mat[i, j] == one
    # seed-stable across processes/orders: same inputs -> same matrix
    np.testing.assert_array_equal(
        mat, device_sim.measurement_noise_factors(specs, keys))
    # distribution: multiplicative lognormal around 1 with tiny sigma
    assert abs(np.log(mat).std() - P.MEASUREMENT_NOISE) < P.MEASUREMENT_NOISE


def test_measure_current_probe_key_pins_noise():
    mod = device_sim.SimulatedModule(P.ModuleSpec(2, 1, 2015))
    tr = idd_loops.idd2n()
    a = mod.measure_current(tr, probe_key=7)
    b = mod.measure_current(tr, probe_key=7)
    assert a == b
    # unkeyed calls consume the ad-hoc counter -> fresh draws
    assert mod.measure_current(tr) != mod.measure_current(tr)


def test_batched_campaign_matches_serial_oracle(quick_vampire, tiny_fleet):
    """The tentpole's acceptance bar: the batched engine must fit the same
    PowerParams as the one-measurement-at-a-time oracle on the
    reference-sized reduced fleet, to float32 tolerance."""
    serial = Vampire.fit(tiny_fleet, probe_modules=2, probe_reps=64,
                         n_rows=8, engine="serial")
    assert set(serial.by_vendor) == set(quick_vampire.by_vendor)
    for v in serial.by_vendor:
        pb, ps = quick_vampire.params(v), serial.params(v)
        for name, a, b in zip(pb._fields, pb, ps):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6,
                err_msg=f"vendor {v} leaf {name}")
        np.testing.assert_allclose(quick_vampire.variation_band[v],
                                   serial.variation_band[v], rtol=1e-6)


def test_distribution_mode_first_rw_has_no_toggles(quick_vampire):
    """estimate_distribution must match extract_features' first-access
    semantics: with exactly one RD there is no previous burst, so the
    estimate cannot depend on toggle_frac."""
    tr = dram.make_trace([dram.ACT, dram.RD, dram.NOP], [0, 0, 0], [0, 0, 0],
                         [0, 0, 0], None, [6, 4, 64])
    a = float(quick_vampire.estimate_distribution(
        tr, 0, ones_frac=0.5, toggle_frac=0.0).avg_current_ma)
    b = float(quick_vampire.estimate_distribution(
        tr, 0, ones_frac=0.5, toggle_frac=1.0).avg_current_ma)
    assert a == b
    # with two RDs (column-interleaved so the toggle coefficient is nonzero)
    # the second access does toggle -> toggle_frac must matter
    tr2 = dram.make_trace([dram.ACT, dram.RD, dram.RD], [0, 0, 0], [0, 0, 0],
                          [0, 0, 1], None, [6, 4, 64])
    c = float(quick_vampire.estimate_distribution(
        tr2, 0, ones_frac=0.5, toggle_frac=0.0).avg_current_ma)
    d = float(quick_vampire.estimate_distribution(
        tr2, 0, ones_frac=0.5, toggle_frac=1.0).avg_current_ma)
    assert d > c


def test_vampire_save_load_roundtrip(quick_vampire, tmp_path):
    path = str(tmp_path / "model.pkl")
    quick_vampire.save(path)
    loaded = Vampire.load(path)
    assert set(loaded.by_vendor) == set(quick_vampire.by_vendor)
    tr = idd_loops.validation_sweep(16)
    for v in quick_vampire.by_vendor:
        for name, a, b in zip(loaded.params(v)._fields, loaded.params(v),
                              quick_vampire.params(v)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       err_msg=f"vendor {v} leaf {name}")
        np.testing.assert_allclose(
            float(loaded.estimate(tr, v).avg_current_ma),
            float(quick_vampire.estimate(tr, v).avg_current_ma), rtol=1e-6)
        for a, b in zip(loaded.estimate_range(tr, v),
                        quick_vampire.estimate_range(tr, v)):
            np.testing.assert_allclose(float(a.energy_pj),
                                       float(b.energy_pj), rtol=1e-6)
            np.testing.assert_allclose(float(a.avg_current_ma),
                                       float(b.avg_current_ma), rtol=1e-6)
        assert loaded.by_vendor[v].idd_datasheet == \
            quick_vampire.by_vendor[v].idd_datasheet
