"""Unit tests for the trip-count-aware HLO analyzer."""
import textwrap

from repro.launch.hlo_analysis import analyze_hlo, shape_bytes

SYNTH = textwrap.dedent("""\
    HloModule test, is_scheduled=true

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
      %w = f32[8,8]{1,0} constant({...})
      %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum
      %t = (s32[], f32[8,8]{1,0}) tuple(%i, %ar)
      ROOT %r = (s32[], f32[8,8]{1,0}) copy(%t)
    }

    %cond (p2: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]{1,0}) parameter(0)
      ROOT %lt = pred[] constant(true)
    }

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x0: f32[8,8]) -> f32[8,8] {
      %x0 = f32[8,8]{1,0} parameter(0)
      %c = s32[] constant(0)
      %tup = (s32[], f32[8,8]{1,0}) tuple(%c, %x0)
      %loop = (s32[], f32[8,8]{1,0}) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
      %ag = f32[16,8]{1,0} all-gather(%x0), dimensions={0}
      ROOT %out = f32[8,8]{1,0} get-tuple-element(%loop), index=1
    }
    """)


def test_shape_bytes():
    assert shape_bytes("f32[8,8]{1,0}") == 256
    assert shape_bytes("bf16[4,2]") == 16
    assert shape_bytes("(f32[2], s8[4])") == 12
    assert shape_bytes("f32[]") == 4
    assert shape_bytes("pred[3]") == 3


def test_trip_count_multiplication():
    rep = analyze_hlo(SYNTH)
    # dot: 2 * 64 elems * 8 contraction = 1024 flops, x5 trips
    assert rep.flops == 5 * 2 * 64 * 8
    assert rep.missing_trip_counts == 0


def test_collective_accounting():
    rep = analyze_hlo(SYNTH)
    # all-reduce inside loop: 256 B x 5; all-gather outside: 512 B x 1
    assert rep.collective_bytes["all-reduce"] == 5 * 256
    assert rep.collective_bytes["all-gather"] == 512
    assert rep.total_collective_bytes == 5 * 256 + 512
    assert rep.n_collectives == {"all-reduce": 1, "all-gather": 1}


def test_missing_trip_count_flagged():
    txt = SYNTH.replace(', backend_config={"known_trip_count":{"n":"5"}}',
                        "")
    rep = analyze_hlo(txt)
    assert rep.missing_trip_counts == 1
    assert rep.flops == 1024  # counted once


def test_traffic_counts_dot_and_collectives():
    rep = analyze_hlo(SYNTH)
    # per body iteration: dot (256*3) + all-reduce (256*2, capped operand)
    per_iter = 256 * 3 + 256 * 2
    # entry: all-gather result 512 + operand min(256, 512)
    assert rep.traffic_bytes == 5 * per_iter + (512 + 256)
