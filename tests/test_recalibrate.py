"""Online recalibration (repro.core.recalibrate) + the fitter registry.

Covers the ISSUE 10 acceptance gates: seed-stable drift, decayed
sufficient-statistics equivalence, detector TP/FP on a planted step,
frozen-vs-recalibrated tracking (frozen grows monotonically >=5x worse,
recalibrated stays within 2x of a freshly-refit oracle), campaign-fitter
bit-for-bit equivalence, and fit-while-serving with zero recompiles.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (characterize, device_sim, fitting, fleet,
                        model_api, recalibrate)
from repro.core import params as P
from repro.core.device_sim import NO_DRIFT, DriftProcess


@pytest.fixture(scope="module")
def tiny_specs():
    return [P.ModuleSpec(v, i, 2015) for v in range(3) for i in range(3)]


# ---------------------------------------------------------------------------
# Drift process
# ---------------------------------------------------------------------------
def test_drift_factors_seed_stable(tiny_specs):
    v = [s.vendor for s in tiny_specs]
    m = [s.module_id for s in tiny_specs]
    bg1, act1 = device_sim.drift_factors(v, m, 17)
    bg2, act2 = device_sim.drift_factors(v, m, 17)
    np.testing.assert_array_equal(bg1, bg2)
    np.testing.assert_array_equal(act1, act2)
    # any tick is reconstructible per module, independent of which other
    # modules ride in the batch (counter-based, not sequential draws)
    bg_sub, act_sub = device_sim.drift_factors(v[3:5], m[3:5], 17)
    np.testing.assert_array_equal(bg_sub, bg1[3:5])
    np.testing.assert_array_equal(act_sub, act1[3:5])
    # different ticks draw different jitter
    bg3, _ = device_sim.drift_factors(v, m, 18)
    assert not np.array_equal(bg1, bg3)


def test_drift_no_drift_is_identity(tiny_specs):
    v = [s.vendor for s in tiny_specs]
    m = [s.module_id for s in tiny_specs]
    bg, act = device_sim.drift_factors(v, m, 123, NO_DRIFT)
    np.testing.assert_allclose(bg, 1.0, rtol=1e-6)
    np.testing.assert_allclose(act, 1.0, rtol=1e-6)


def test_drift_aging_monotone_and_step():
    drift = DriftProcess(temp_amp=0.0, aging_rate=2e-3, act_aging_rate=1e-3,
                         noise_sigma=0.0)
    bgs = [device_sim.drift_factors([0], [0], t, drift)[0][0]
           for t in (0, 10, 50, 200)]
    assert all(b2 > b1 for b1, b2 in zip(bgs, bgs[1:]))
    step = dataclasses.replace(NO_DRIFT, step_tick=8, step_frac=0.2)
    before, _ = device_sim.drift_factors([0], [0], 7, step)
    after, after_act = device_sim.drift_factors([0], [0], 8, step)
    np.testing.assert_allclose(before, 1.0, rtol=1e-6)
    np.testing.assert_allclose(after, 1.2, rtol=1e-6)
    np.testing.assert_allclose(after_act, 1.2, rtol=1e-6)


def test_apply_drift_scales_expected_fields(tiny_specs):
    mods = device_sim.make_fleet(tiny_specs[:2])
    stacked = fleet.stack_params([m.params for m in mods])
    drift = DriftProcess(temp_amp=0.0, aging_rate=5e-3, act_aging_rate=0.0,
                         noise_sigma=0.0)
    drifted = device_sim.apply_drift(
        stacked, [s.vendor for s in tiny_specs[:2]],
        [s.module_id for s in tiny_specs[:2]], 100, drift)
    np.testing.assert_allclose(np.asarray(drifted.i2n),
                               np.asarray(stacked.i2n) * 1.5, rtol=1e-5)
    # act group has zero aging here: untouched
    np.testing.assert_allclose(np.asarray(drifted.q_actpre),
                               np.asarray(stacked.q_actpre), rtol=1e-6)


# ---------------------------------------------------------------------------
# Decayed sufficient statistics
# ---------------------------------------------------------------------------
def test_update_stats_matches_numpy_reference(rng):
    M, C, width = 3, 10, 4
    stats = recalibrate.RunningStats(
        np.zeros((M, C), np.float32), np.zeros((M, C), np.float32))
    w_ref = np.zeros((M, C), np.float32)
    m_ref = np.zeros((M, C), np.float32)
    decay = np.float32(0.8)
    pred = np.zeros((M, C), np.float32)
    for k in range(6):
        idx = np.asarray([(k * width + j) % C for j in range(width)])
        obs = rng.normal(10.0, 1.0, size=(M, width)).astype(np.float32)
        stats, _ = recalibrate._update_stats(stats, obs, idx, decay, pred,
                                             np.float32(0.01))
        old = decay * w_ref[:, idx]
        w_ref[:, idx] = old + 1.0
        m_ref[:, idx] = (old * m_ref[:, idx] + obs) / w_ref[:, idx]
    np.testing.assert_allclose(np.asarray(stats.weight), w_ref, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(stats.mean), m_ref, rtol=1e-5)


def test_decay_one_is_exact_running_mean(rng):
    w = np.float32(0.0)
    m = np.float32(0.0)
    xs = rng.normal(5.0, 2.0, size=12).astype(np.float32)
    for i, x in enumerate(xs):
        w, m = fitting.decayed_moment_update(w, m, x, 1.0)
        np.testing.assert_allclose(float(m), np.mean(xs[:i + 1]), rtol=1e-5)
        assert float(w) == pytest.approx(i + 1)


def test_streaming_refit_equals_from_scratch_refit(quick_vampire,
                                                   tiny_fleet, tiny_specs):
    """With decay=1 and no seed mass, the streaming refit over the fed
    telemetry equals ``invert_campaign`` run from scratch on the plain
    per-cell means of the same stream."""
    cfg = recalibrate.RecalConfig(decay=1.0, seed_weight=0.0,
                                  slice_size=10_000)  # one full-set slice
    fitter = recalibrate.StreamingFitter(quick_vampire, tiny_specs, cfg)
    src = recalibrate.TelemetrySource(tiny_fleet, cfg, drift=NO_DRIFT,
                                      noisy=False)
    for tick in range(2):
        cur, idx = src.measure(tick)
        fitter.observe(cur, idx, tick)
    streamed = fitter.refit()

    mean = np.asarray(fitter.stats.mean, np.float64)
    plan = fitter.plan
    fitted = []
    for v in quick_vampire.vendors:
        rows = [i for i, s in enumerate(tiny_specs) if s.vendor == v]
        idd = {key: mean[rows, i]
               for i, key in enumerate(characterize.IDD_KEYS)}
        pm = mean[rows[:cfg.probe_modules],
                  len(characterize.IDD_KEYS):].mean(axis=0)
        cur = {pt.label: float(pm[i])
               for i, pt in enumerate(plan.probe_points)}
        fitted.append(characterize.invert_campaign(plan, v, idd_measured=idd,
                                                   cur=cur).fitted)
    scratch = fleet.stack_params(fitted)
    for got, want in zip(jax.tree_util.tree_leaves(streamed.fleet.params),
                         jax.tree_util.tree_leaves(scratch)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Fitter registry + campaign equivalence
# ---------------------------------------------------------------------------
def test_fitter_registry_resolution():
    assert set(model_api.registered_fitters()) >= {"campaign", "streaming"}
    assert model_api.resolve_fitter("campaign").streaming is False
    assert model_api.resolve_fitter("offline").name == "campaign"
    assert model_api.resolve_fitter("online").name == "streaming"
    assert model_api.resolve_fitter("streaming", streaming=True).streaming
    with pytest.raises(ValueError, match="registered fitters"):
        model_api.resolve_fitter("nope")
    with pytest.raises(ValueError, match="one-shot"):
        model_api.resolve_fitter("campaign", streaming=True)
    with pytest.raises(ValueError, match="streaming"):
        model_api.resolve_fitter("streaming", streaming=False)


def test_campaign_fitter_bit_for_bit(quick_vampire, tiny_fleet):
    """``fit(fitter='campaign')`` (and the ``Vampire.fit`` shim onto it)
    reproduces the pre-registry fit body exactly, leaf for leaf."""
    from repro.core.vampire import Vampire
    legacy = Vampire(by_vendor=characterize.characterize_fleet(
        tiny_fleet, probe_modules=2, probe_reps=64, n_rows=8))
    legacy.fleet
    for got, want in zip(jax.tree_util.tree_leaves(quick_vampire),
                         jax.tree_util.tree_leaves(legacy)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_vampire_fit_shim_warning_free(tiny_fleet, recwarn):
    from repro.core.vampire import Vampire
    Vampire.fit(tiny_fleet, probe_modules=2, probe_reps=64, n_rows=8)
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


def test_fit_streaming_requires_vampire(tiny_fleet):
    with pytest.raises(ValueError, match="VAMPIRE"):
        model_api.fit("micron", tiny_fleet, fitter="streaming")


# ---------------------------------------------------------------------------
# DataProfile
# ---------------------------------------------------------------------------
def test_data_profile_normalization():
    prof = model_api.DataProfile(ones_frac=0.5, toggle_frac=0.25)
    assert model_api.normalize_data_profile(prof) is prof
    loose = model_api.normalize_data_profile(None, 0.5, 0.25)
    assert loose == prof
    assert model_api.DataProfile().empty and not prof.empty
    with pytest.raises(ValueError, match="not both"):
        model_api.normalize_data_profile(prof, ones_frac=0.5)
    with pytest.raises(TypeError):
        model_api.normalize_data_profile({"ones_frac": 0.5})


def test_estimate_accepts_data_profile(quick_vampire):
    from repro.core import idd_loops
    trs = [idd_loops.idd0(reps=2), idd_loops.idd4r(reps=2)]
    prof = model_api.DataProfile(ones_frac=0.5, toggle_frac=0.25)
    a = quick_vampire.estimate(trs, mode="distribution", data=prof)
    b = quick_vampire.estimate(trs, mode="distribution",
                               ones_frac=0.5, toggle_frac=0.25)
    np.testing.assert_array_equal(np.asarray(a.energy_pj),
                                  np.asarray(b.energy_pj))
    with pytest.raises(ValueError):
        quick_vampire.estimate(trs, mode="distribution")  # fractions missing
    with pytest.raises(ValueError):
        quick_vampire.estimate(trs, mode="mean", data=prof)  # rejected
    # the baselines share the same contract
    baseline = model_api.make_estimator("micron", quick_vampire)
    c = baseline.estimate(trs, mode="distribution", data=prof)
    d = baseline.estimate(trs, mode="distribution",
                          ones_frac=0.5, toggle_frac=0.25)
    np.testing.assert_array_equal(np.asarray(c.energy_pj),
                                  np.asarray(d.energy_pj))


# ---------------------------------------------------------------------------
# Drift detector
# ---------------------------------------------------------------------------
def test_detector_fires_on_planted_step(quick_vampire, tiny_fleet,
                                        tiny_specs):
    cfg = recalibrate.RecalConfig()
    step = dataclasses.replace(NO_DRIFT, step_tick=4, step_frac=0.15)
    fitter = recalibrate.StreamingFitter(quick_vampire, tiny_specs, cfg)
    src = recalibrate.TelemetrySource(tiny_fleet, cfg, drift=step)
    reports = []
    for tick in range(1, 7):
        cur, idx = src.measure(tick)
        reports.append(fitter.observe(cur, idx, tick))
    assert not any(r.triggered for r in reports[:3])   # before the step
    assert all(r.triggered for r in reports[3:])       # from the step on
    assert reports[3].score > 2 * cfg.drift_threshold
    assert set(reports[3].by_key)  # per-key scores surfaced


def test_detector_quiet_without_drift(quick_vampire, tiny_fleet,
                                      tiny_specs):
    cfg = recalibrate.RecalConfig()
    fitter = recalibrate.StreamingFitter(quick_vampire, tiny_specs, cfg)
    src = recalibrate.TelemetrySource(tiny_fleet, cfg, drift=NO_DRIFT)
    scores = []
    for tick in range(1, 13):
        cur, idx = src.measure(tick)
        scores.append(fitter.observe(cur, idx, tick).score)
    assert max(scores) < cfg.drift_threshold  # no false positives


# ---------------------------------------------------------------------------
# The tracking gate: frozen diverges, recalibrated tracks
# ---------------------------------------------------------------------------
def test_frozen_diverges_recalibrated_tracks(quick_vampire, tiny_fleet,
                                             tiny_specs):
    cfg = recalibrate.RecalConfig(decay=0.7, slice_size=120)
    drift = DriftProcess(temp_amp=0.01, temp_period=64.0, aging_rate=8e-3,
                         act_aging_rate=5e-3, noise_sigma=1e-3)
    fitter = recalibrate.StreamingFitter(quick_vampire, tiny_specs, cfg)
    frozen = fitter.model
    src = recalibrate.TelemetrySource(tiny_fleet, cfg, drift=drift)
    tb = src.batch
    ckpts = (30, 60, 90, 120)
    frozen_err, recal_err = [], []
    for tick in range(1, ckpts[-1] + 1):
        cur, idx = src.measure(tick)
        if fitter.observe(cur, idx, tick).triggered:
            fitter.refit()
        if tick in ckpts:
            truth = src.true_params_at(tick)
            frozen_err.append(recalibrate.fleet_current_mape(
                frozen, tb.trace, tb.weight, tiny_specs, truth))
            recal_err.append(recalibrate.fleet_current_mape(
                fitter.model, tb.trace, tb.weight, tiny_specs, truth))
    # frozen error grows monotonically...
    assert all(b > a for a, b in zip(frozen_err, frozen_err[1:]))
    # ...to >=5x the recalibrated model's error
    assert frozen_err[-1] >= 5.0 * recal_err[-1]
    # the recalibrated model stays within 2x of a freshly-refit oracle
    final = ckpts[-1]
    truth = src.true_params_at(final)
    drifted = [device_sim.SimulatedModule(
        s, jax.tree_util.tree_map(lambda x, i=i: x[i], truth))
        for i, s in enumerate(tiny_specs)]
    oracle = model_api.fit("vampire", drifted, fitter="campaign",
                           probe_modules=2, probe_reps=64, n_rows=8)
    oracle_err = recalibrate.fleet_current_mape(
        oracle, tb.trace, tb.weight, tiny_specs, truth)
    assert recal_err[-1] <= 2.0 * oracle_err


# ---------------------------------------------------------------------------
# Fit-while-serving
# ---------------------------------------------------------------------------
def test_fit_while_serving_zero_recompiles(quick_vampire, tiny_fleet,
                                           tiny_specs):
    from repro.core import idd_loops
    from repro.serving import EstimationService, ServiceConfig

    # full-coverage slices: one tick touches every probe cell, so the
    # triggered refit moves every inverted parameter (not just the ones
    # the first round-robin slice happened to revisit)
    cfg = recalibrate.RecalConfig(slice_size=10_000)
    step = dataclasses.replace(NO_DRIFT, step_tick=1, step_frac=0.2)
    fitter = recalibrate.StreamingFitter(quick_vampire, tiny_specs, cfg)
    svc = EstimationService(quick_vampire, ServiceConfig(lint=False),
                            fitter=fitter)
    src = recalibrate.TelemetrySource(tiny_fleet, cfg, drift=step)
    trs = [idd_loops.idd0(reps=2), idd_loops.idd4r(reps=2)]

    tickets, _ = svc.submit_many(trs)
    svc.drain()
    before = svc.engine.cache_size()
    res_before = np.asarray(svc.result(tickets[0]).energy_pj)

    cur, idx = src.measure(1)
    report = svc.observe_telemetry(cur, idx, tick=1)
    assert report.triggered

    tickets2, _ = svc.submit_many(trs)
    svc.drain()
    res_after = np.asarray(svc.result(tickets2[0]).energy_pj)
    m = svc.metrics()
    assert m.recalibrations == 1
    assert m.drift_score == pytest.approx(report.score)
    assert m.drift_peak >= m.drift_score
    assert m.drift_by_key == report.by_key
    # the hot-swap is treedef-stable: zero new compiled programs...
    assert svc.engine.cache_size() == before
    assert m.engine_programs == before
    # ...and the refreshed parameters actually changed the answers
    assert not np.array_equal(res_before, res_after)


def test_service_without_fitter_raises(quick_vampire):
    from repro.serving import EstimationService, ServiceConfig
    svc = EstimationService(quick_vampire, ServiceConfig(lint=False))
    with pytest.raises(RuntimeError, match="streaming fitter"):
        svc.observe_telemetry(np.zeros((1, 1)), [0], tick=0)
